"""End-to-end LM training driver: deterministic data → scanned model →
AdamW → atomic checkpoints → auto-resume.

Default runs a reduced qwen3-family config for 200 steps on CPU (loss
drops visibly); `--arch mamba2-370m --full-width` trains the real-width
370M/100M-scale config for a few hundred steps on real hardware.

  PYTHONPATH=src python examples/lm_train.py --steps 200
  PYTHONPATH=src python examples/lm_train.py --arch qwen2-moe-a2.7b
"""
import argparse
import shutil
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--resume-demo", action="store_true",
                    help="kill at step N/2 and auto-resume")
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        if args.resume_demo:
            half = args.steps // 2
            print(f"--- phase 1: train to step {half}, checkpointing ---")
            train(args.arch, smoke=True, steps=half, batch=args.batch,
                  seq=args.seq, ckpt_dir=ckpt_dir, ckpt_every=10)
            print("--- phase 2: fresh process would auto-resume ---")
        state, history = train(args.arch, smoke=True, steps=args.steps,
                               batch=args.batch, seq=args.seq,
                               ckpt_dir=ckpt_dir, ckpt_every=25)
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"loss {first:.4f} -> {last:.4f} over "
              f"{len(history)} steps (arch={args.arch})")
        assert last < first, "loss should decrease"
        print("OK")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
