"""Batched serving example: prefill + decode over the cache pytree, for a
dense, an MoE, and an attention-free (Mamba2) architecture.

  PYTHONPATH=src python examples/lm_serve.py --tokens 24
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import ServeSession
from repro.models import model as M
from repro.models.params import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    for arch in ("qwen3-32b", "qwen2-moe-a2.7b", "mamba2-370m"):
        cfg = smoke_config(arch)
        params = init_params(M.model_specs(cfg), seed=0)
        sess = ServeSession(cfg, params,
                            max_len=16 + args.tokens + 1)
        prompts = rng.integers(0, cfg.vocab, (args.batch, 16)).astype(
            np.int32)
        t0 = time.perf_counter()
        out = sess.generate(prompts, args.tokens, temperature=0.8, seed=1)
        dt = time.perf_counter() - t0
        assert out.shape == (args.batch, args.tokens)
        assert (out >= 0).all() and (out < cfg.vocab).all()
        print(f"{arch:20s} generated {out.shape[0]}x{out.shape[1]} tokens "
              f"in {dt:.2f}s (incl. compile); sample: {out[0, :8].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
