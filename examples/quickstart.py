"""Quickstart: sparse CP decomposition with Dynasor (paper Alg. 1+2).

Builds a FROSTT-like synthetic sparse tensor, converts it to the FLYCOO
format (super-shards + LPT schedule), runs CP-ALS where every spMTTKRP
uses the Dynasor owner-sorted layout, then shows the ``repro.tune``
workflow: calibrate the backends on this host and decompose with a
tuned runtime (measured per-mode backend plans + per-transition remap
exchange sizing).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import distributed as dist
from repro.core.cpals import cp_als
from repro.core.flycoo import build_flycoo, choose_partition_params
from repro.core.tensors import frostt_like, low_rank_sparse_tensor
from repro.kernels.mttkrp import ops as kops
from repro import tune


def main():
    print("=== Dynasor quickstart ===")
    # 1. a FROSTT-profile synthetic tensor (power-law hubs, like Flickr)
    t = frostt_like("flickr", scale=0.1)
    print(f"tensor: shape={t.shape} nnz={t.nnz}")

    # 2. FLYCOO preprocessing: partition params via Eq. 2/3, super-shards,
    #    LPT schedule baked into a device-major row permutation
    params = choose_partition_params(t.shape, t.nnz, num_workers=8, rank=16)
    print(f"partition: m={params.m} g={params.g} (Eq.2/3 satisfied="
          f"{params.satisfied})")
    ft = build_flycoo(t, num_workers=8, params=params)
    print(f"bits/nnz in FLYCOO: {ft.bits_per_nonzero():.1f} "
          f"(COO would be {32 * (t.nmodes + 1)})")
    for n, mp in enumerate(ft.modes):
        loads = np.bincount(mp.super_to_device,
                            weights=mp.shard_counts, minlength=8)
        print(f"  mode {n}: {mp.num_super} super-shards, "
              f"load imbalance {loads.max() / loads.mean():.3f}")

    # 3. CP-ALS on the sparse samples
    res = cp_als(t, rank=16, iters=10, seed=0)
    print("CP-ALS fits:", " ".join(f"{f:.4f}" for f in res.fits))

    # 4. sanity: exact recovery of a dense rank-4 tensor stored as COO
    import itertools
    rng = np.random.default_rng(1)
    shape2, R = (20, 16, 12), 4
    facs = [rng.standard_normal((d, R)) for d in shape2]
    dense = np.einsum("ir,jr,kr->ijk", *facs)
    from repro.core.tensors import SparseTensor
    idx = np.array(list(itertools.product(*map(range, shape2))), np.int32)
    t2 = SparseTensor(idx, dense.reshape(-1).astype(np.float32), shape2)
    res2 = cp_als(t2, rank=R, iters=25, seed=2)
    print(f"low-rank recovery fit: {res2.fit:.4f}")
    assert res2.fit > 0.99

    # 5. tuning workflow: calibrate -> decompose with a tuned runtime.
    #    (`python -m repro.tune calibrate --quick` does this once per host
    #    and saves the table under experiments/tune/; here a micro-grid
    #    keeps the example fast.)
    grid = [tune.GridPoint(nmodes=3, rank=r, blk=32, tile_rows=8,
                           density=1.0) for r in (16, 128)]
    table = tune.find_table() or tune.calibrate(grid=grid)
    for rank in (16, 128):
        static = kops.select_backend("auto", nmodes=3, rank=rank,
                                     blk=32, tile_rows=8)
        tuned = kops.select_backend("auto", nmodes=3, rank=rank,
                                    blk=32, tile_rows=8, table=table)
        print(f"auto dispatch @rank={rank}: static={static} "
              f"calibrated={tuned}")
    # the rank-tiled kernel keeps huge ranks fused (docs/kernels.md):
    # the pre-PR-3 static model sent this config to the materialized path
    print("auto dispatch @nmodes=5, rank=8192:",
          kops.select_backend("auto", nmodes=5, rank=8192))
    rt, _ = dist.prepare_runtime(ft, rank=16, table=table)
    print("tuned per-mode plans:", rt.mode_plans)
    print("per-transition exchange caps:", rt.bucket_caps,
          f"(uniform cap would be {rt.bucket_cap})")
    # On a multi-device mesh the same table feeds the distributed solver:
    #   cp_als_distributed(ft, 16, mesh, backend="auto", table=table)
    print("OK")


if __name__ == "__main__":
    main()
