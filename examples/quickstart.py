"""Quickstart: sparse CP decomposition with Dynasor (paper Alg. 1+2).

Builds a FROSTT-like synthetic sparse tensor, converts it to the FLYCOO
format (super-shards + LPT schedule), and runs CP-ALS where every
spMTTKRP uses the Dynasor owner-sorted layout.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.cpals import cp_als
from repro.core.flycoo import build_flycoo, choose_partition_params
from repro.core.tensors import frostt_like, low_rank_sparse_tensor


def main():
    print("=== Dynasor quickstart ===")
    # 1. a FROSTT-profile synthetic tensor (power-law hubs, like Flickr)
    t = frostt_like("flickr", scale=0.1)
    print(f"tensor: shape={t.shape} nnz={t.nnz}")

    # 2. FLYCOO preprocessing: partition params via Eq. 2/3, super-shards,
    #    LPT schedule baked into a device-major row permutation
    params = choose_partition_params(t.shape, t.nnz, num_workers=8, rank=16)
    print(f"partition: m={params.m} g={params.g} (Eq.2/3 satisfied="
          f"{params.satisfied})")
    ft = build_flycoo(t, num_workers=8, params=params)
    print(f"bits/nnz in FLYCOO: {ft.bits_per_nonzero():.1f} "
          f"(COO would be {32 * (t.nmodes + 1)})")
    for n, mp in enumerate(ft.modes):
        loads = np.bincount(mp.super_to_device,
                            weights=mp.shard_counts, minlength=8)
        print(f"  mode {n}: {mp.num_super} super-shards, "
              f"load imbalance {loads.max() / loads.mean():.3f}")

    # 3. CP-ALS on the sparse samples
    res = cp_als(t, rank=16, iters=10, seed=0)
    print("CP-ALS fits:", " ".join(f"{f:.4f}" for f in res.fits))

    # 4. sanity: exact recovery of a dense rank-4 tensor stored as COO
    import itertools
    rng = np.random.default_rng(1)
    shape2, R = (20, 16, 12), 4
    facs = [rng.standard_normal((d, R)) for d in shape2]
    dense = np.einsum("ir,jr,kr->ijk", *facs)
    from repro.core.tensors import SparseTensor
    idx = np.array(list(itertools.product(*map(range, shape2))), np.int32)
    t2 = SparseTensor(idx, dense.reshape(-1).astype(np.float32), shape2)
    res2 = cp_als(t2, rank=R, iters=25, seed=2)
    print(f"low-rank recovery fit: {res2.fit:.4f}")
    assert res2.fit > 0.99
    print("OK")


if __name__ == "__main__":
    main()
