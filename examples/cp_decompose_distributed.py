"""End-to-end distributed CP decomposition (the paper's headline workload).

Runs Dynasor's owner-computes spMTTKRP with dynamic tensor remapping under
``shard_map`` on 8 (forced host) devices, decomposes a dense low-rank
tensor exactly, and compares against the nonzero-parallel + all-reduce
baseline (the ALTO/HiCOO traffic pattern).

  PYTHONPATH=src python examples/cp_decompose_distributed.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import itertools
import time

import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import distributed as dist
from repro.core.cpals import cp_als_distributed
from repro.core.flycoo import build_flycoo
from repro.core.tensors import SparseTensor, frostt_like


def main():
    print("=== distributed Dynasor CP-ALS (8 workers) ===")
    mesh = Mesh(np.array(jax.devices()), (dist.AXIS,))

    # exact recovery of a dense rank-4 tensor
    rng = np.random.default_rng(0)
    shape, R = (32, 24, 16), 4
    facs = [rng.standard_normal((d, R)) for d in shape]
    dense = np.einsum("ir,jr,kr->ijk", *facs)
    idx = np.array(list(itertools.product(*map(range, shape))), np.int32)
    t = SparseTensor(idx, dense.reshape(-1).astype(np.float32), shape)
    ft = build_flycoo(t, 8, m_bounds=(2, 8), g_bounds=(8, 64))
    res = cp_als_distributed(ft, R, mesh, iters=20, seed=1)
    rec = np.einsum("r,ir,jr,kr->ijk", res.lam, *res.factors)
    rel = np.linalg.norm(rec - dense) / np.linalg.norm(dense)
    print(f"fit={res.fit:.5f}  reconstruction rel-err={rel:.2e}  "
          f"iters={res.iters}")
    assert res.fit > 0.99

    # 4-mode decomposition through the fused N-mode Pallas path end-to-end
    # (backend="auto" dispatches every mode to the in-kernel-gather fused
    # kernel — the factors here easily fit VMEM-resident).
    shape4, R4 = (12, 10, 8, 6), 8   # R >= 8 so "auto" picks the fused path
    facs4 = [rng.standard_normal((d, R4)) for d in shape4]
    dense4 = np.einsum("ir,jr,kr,lr->ijkl", *facs4)
    idx4 = np.array(list(itertools.product(*map(range, shape4))), np.int32)
    t4 = SparseTensor(idx4, dense4.reshape(-1).astype(np.float32), shape4)
    ft4 = build_flycoo(t4, 8, m_bounds=(2, 8), g_bounds=(8, 64),
                       fused_gather=True)
    res4 = cp_als_distributed(ft4, R4, mesh, iters=15, seed=1,
                              backend="auto")
    rec4 = np.einsum("r,ir,jr,kr,lr->ijkl", res4.lam, *res4.factors)
    rel4 = np.linalg.norm(rec4 - dense4) / np.linalg.norm(dense4)
    print(f"4-mode fused CP-ALS: fit={res4.fit:.5f}  rel-err={rel4:.2e}")
    assert res4.fit > 0.99

    # Dynasor vs nonzero-parallel all-reduce baseline on a FROSTT profile
    t2 = frostt_like("nell-2", scale=0.15)
    ft2 = build_flycoo(t2, 8)
    rt, (pidx, pval, pmask) = dist.prepare_runtime(ft2, rank=16)
    factors = dist.init_factors(ft2, rt, seed=0)
    dynasor = dist.make_spmttkrp_all_modes(rt, mesh, backend="segsum")
    baseline = dist.make_baseline_all_modes(rt, mesh)
    bidx, bval, bmask = dist.even_split_pack(ft2, rt)

    for name, fn, args in (("dynasor", dynasor, (pidx, pval, pmask)),
                           ("allreduce-baseline", baseline,
                            (bidx, bval, bmask))):
        out = fn(*args, *factors)         # warmup/compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(*args, *factors))
        dt = (time.perf_counter() - t0) / 3
        print(f"{name:20s} all-modes spMTTKRP: {dt * 1e3:.1f} ms "
              f"(nnz={t2.nnz}, R=16, 8 workers)")
    print("note: on emulated same-host devices collectives are ~free, so "
          "the all-reduce baseline wins wall-clock at toy scale; the "
          "compiled collective-byte comparison (benchmarks/"
          "bench_collective_traffic.py) is the hardware-relevant metric "
          "(baseline moves 1.4-1.8x more bytes).")
    print("OK")


if __name__ == "__main__":
    main()
