"""repro.oocore — out-of-core spMTTKRP (PR-5 tentpole).

Coverage per the issue checklist:
  * ``fused_mttkrp_nmode_gather_stream`` bit-exact vs the resident
    ``pallas_fused_gather`` on fp32 across N ∈ {3, 4, 5}, including a
    forced multi-chunk execution through the ``oocore`` executor, plus
    the bf16 composition;
  * hypothesis property sweeps: (a) streamed ≡ resident bit-exact for
    random chunk/row-tile splits, (b) ``ResidencyPlan`` invariants —
    every factor row covered exactly once by the tile spans, the budget
    respected, and the plan monotone in the budget;
  * dispatch: ``select_backend`` / ``plan_modes`` route through
    ``plan_residency`` and choose the streaming backend only when
    whole/slab residency fails; ``ModePlan`` threads the window
    geometry;
  * tune schema v4: stream timings + ``stream_window_tiles`` recorded,
    committed v3 fixture still loads (back-compat window 1–3);
  * the ``tile_schedule`` correctness contract and the stream VMEM
    formula;
  * the legacy 3-mode kernel entry is a warning deprecated alias.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import tune
from repro.core import distributed as dist
from repro.core.tensors import random_sparse_tensor
from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import ops as kops
from repro.oocore import planner
from repro.oocore.executor import chunk_boundaries, mttkrp_out_of_core

BLK, TILE = 32, 8

# Mode-0 output; the *input* factors span multiple FACTOR_ROW_TILE tiles
# so the stream kernel actually pages tiles (not the degenerate 1-tile
# window).
SHAPES = {3: (20, 300, 170), 4: (12, 300, 170, 6), 5: (8, 300, 170, 6, 5)}

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sorted_case(shape, nnz, rank, mode, seed=0):
    rng = np.random.default_rng(seed)
    t = random_sparse_tensor(shape, nnz, seed=seed)
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    return idx, val, factors


def _device_step(idx, val, valid, factors, mode, rows_cap, backend,
                 gather_dtype="float32"):
    return kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=mode, rows_cap=rows_cap, row_offset=0, blk=BLK, tile_rows=TILE,
        interpret=True, backend=backend, gather_dtype=gather_dtype)


# ---------------------------------------------------------------------------
# Golden: streamed gather ≡ resident gather, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nmodes", [3, 4, 5])
@pytest.mark.parametrize("rank", [128, 256])
def test_stream_bitexact_vs_resident(nmodes, rank):
    """The stream kernel's windowed tiles hold exactly the rows the
    resident kernel gathers, so the arithmetic (and its order) is
    unchanged — bitwise agreement, not tolerance."""
    shape = SHAPES[nmodes]
    idx, val, factors = _sorted_case(shape, 150, rank, 0, seed=nmodes)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    resident = _device_step(idx, val, valid, factors, 0, rows_cap,
                            "pallas_fused_gather")
    streamed = _device_step(idx, val, valid, factors, 0, rows_cap,
                            "pallas_fused_gather_stream")
    np.testing.assert_array_equal(np.asarray(streamed), np.asarray(resident))


def test_stream_multichunk_forced_bitexact():
    """A working-set budget small enough to force many chunks must not
    change a single bit: the executor threads the accumulator through
    out_init, re-bracketing the same additions in the same order."""
    shape = SHAPES[4]
    idx, val, factors = _sorted_case(shape, 250, 256, 0, seed=9)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.arange(len(val)) < len(val) - 7       # trailing invalids
    val = np.where(valid, val, 0.0).astype(np.float32)
    resident = _device_step(idx, val, valid, factors, 0, rows_cap,
                            "pallas_fused_gather")
    out, stats = mttkrp_out_of_core(
        idx, val, valid, factors, mode=0, rows_cap=rows_cap, blk=BLK,
        tile_rows=TILE, max_chunk_bytes=1500)
    assert stats.chunks >= 3, stats.chunks
    np.testing.assert_array_equal(np.asarray(out), np.asarray(resident))
    # counted traffic is self-consistent
    assert stats.distinct_tile_bytes <= stats.scheduled_tile_bytes
    assert stats.pipelined_tile_bytes <= stats.scheduled_tile_bytes
    assert stats.window_vmem_bytes < stats.resident_equiv_vmem_bytes


def test_stream_bf16_composition_bitexact_vs_resident_bf16():
    shape = SHAPES[4]
    idx, val, factors = _sorted_case(shape, 150, 128, 0, seed=5)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    want = _device_step(idx, val, valid, factors, 0, rows_cap,
                        "pallas_fused_gather", gather_dtype="bfloat16")
    got = _device_step(idx, val, valid, factors, 0, rows_cap,
                       "pallas_fused_gather_stream", gather_dtype="bfloat16")
    assert np.asarray(got).dtype == np.float32       # fp32 accumulate
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    nnz=st.integers(40, 260),
    rank=st.sampled_from([128, 256]),
    tile_rows=st.sampled_from([8, 16]),
    blk=st.sampled_from([16, 32]),
    max_chunk_bytes=st.one_of(st.none(), st.integers(600, 20_000)),
)
def test_stream_chunk_split_property(seed, nnz, rank, tile_rows, blk,
                                     max_chunk_bytes):
    """(a) streamed ≡ resident, bit-exact on fp32, for random chunk /
    row-tile splits — the issue's property sweep."""
    shape = (40, 300, 170)
    idx, val, factors = _sorted_case(shape, nnz, rank, 0, seed=seed)
    rows_cap = -(-shape[0] // tile_rows) * tile_rows
    valid = np.ones(len(val), bool)
    resident = kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=0, rows_cap=rows_cap, row_offset=0, blk=blk,
        tile_rows=tile_rows, interpret=True, backend="pallas_fused_gather")
    out, _ = mttkrp_out_of_core(
        idx, val, valid, factors, mode=0, rows_cap=rows_cap, blk=blk,
        tile_rows=tile_rows, max_chunk_bytes=max_chunk_bytes)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(resident))


def test_chunk_boundaries_cover_and_prefer_tile_edges():
    tiles = np.array([0, 0, 0, 1, 1, 2, 2, 2, 2, 3])
    bounds = chunk_boundaries(tiles, 4)
    # exact cover, in order
    assert bounds[0][0] == 0 and bounds[-1][1] == len(tiles)
    for (a, b), (c, _) in zip(bounds, bounds[1:]):
        assert b == c and a < b
    # boundaries land on tile edges when a tile run fits the budget
    for _, stop in bounds[:-1]:
        assert tiles[stop] != tiles[stop - 1]
    # a run longer than the budget must still split (mid-tile)
    long_run = np.zeros(10, int)
    assert [b - a for a, b in chunk_boundaries(long_run, 4)] == [4, 4, 2]


# ---------------------------------------------------------------------------
# tile_schedule contract
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), blk=st.sampled_from([8, 16, 32]),
       rows=st.integers(1, 2000), blocks=st.integers(1, 6))
def test_tile_schedule_holds_every_touched_tile(seed, blk, rows, blocks):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, rows, size=blocks * blk).astype(np.int32)
    window = planner.stream_window_tiles(blk, rows)
    sched = np.asarray(kops.tile_schedule(jnp.asarray(idx), blk, window))
    assert sched.shape == (blocks, window)
    frow = kkernel.FACTOR_ROW_TILE
    for b in range(blocks):
        touched = set(idx[b * blk:(b + 1) * blk] // frow)
        assert touched <= set(sched[b]), (b, touched, sched[b])
        # and nothing out of range is ever scheduled
        assert set(sched[b]) <= set(idx[b * blk:(b + 1) * blk] // frow)


def test_gather_stream_vmem_bytes_formula():
    k, rpad, blk, tile, windows = 3, 512, 32, 8, (5, 3, 1)
    got = kkernel.gather_stream_vmem_bytes(k, rpad, blk, tile, windows)
    slab = kkernel.RANK_SLAB
    window_term = sum(w * kkernel.FACTOR_ROW_TILE * slab * 4
                      for w in windows)
    # The tile schedules live in SMEM via scalar prefetch (the body
    # reads them scalar-by-scalar) so, like tile_of_block, they add no
    # VMEM term.
    base = kkernel.fused_vmem_bytes(0, slab, blk, tile,
                                    index_stream_modes=k)
    assert got == window_term + base
    # independent of the factor sizes and (past one slab) of R
    assert kkernel.gather_stream_vmem_bytes(k, 1 << 16, blk, tile,
                                            windows) == got
    # bf16 halves exactly the window term
    bf16 = kkernel.gather_stream_vmem_bytes(k, rpad, blk, tile, windows,
                                            gather_itemsize=2)
    assert got - bf16 == window_term // 2


# ---------------------------------------------------------------------------
# ResidencyPlan invariants (issue property sweep (b))
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    nmodes=st.integers(3, 5),
    rank=st.sampled_from([8, 64, 128, 512, 4096]),
    blk=st.sampled_from([16, 32, 512]),
    tile_rows=st.sampled_from([8, 128]),
    rows=st.lists(st.integers(1, 2_000_000), min_size=2, max_size=4),
    budget_mb=st.integers(1, 256),
)
def test_residency_plan_invariants(nmodes, rank, blk, tile_rows, rows,
                                   budget_mb):
    rows = tuple(rows[:nmodes - 1]) + (64,) * max(0, nmodes - 1 - len(rows))
    budget = budget_mb << 20
    plan = planner.plan_residency(
        nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
        factor_rows=rows, vmem_budget=budget)
    assert plan.backend in kops.BACKENDS
    # budget respected (only the materializing last resort may exceed)
    assert plan.fits
    if plan.backend not in ("pallas", "ref"):
        assert plan.vmem_bytes <= budget
    # every factor row covered exactly once by the tile spans
    for f in plan.factors:
        spans = f.tile_spans()
        assert spans[0][0] == 0 and spans[-1][1] == f.rows
        for (a, b), (c, _) in zip(spans, spans[1:]):
            assert b == c and a < b
        assert 1 <= f.window_tiles <= f.row_tiles
        if f.policy == "stream":
            assert f.window_tiles == planner.stream_window_tiles(blk, f.rows)
            assert f.window_tiles < f.row_tiles
    if plan.streams:
        assert plan.window_tiles and len(plan.window_tiles) == nmodes - 1


@settings(max_examples=20, deadline=None)
@given(
    nmodes=st.integers(3, 5),
    rank=st.sampled_from([64, 128, 512]),
    blk=st.sampled_from([16, 32, 512]),
    rows=st.integers(100, 5_000_000),
    b1=st.integers(1, 512),
    b2=st.integers(1, 512),
)
def test_residency_plan_monotone_in_budget(nmodes, rank, blk, rows, b1, b2):
    """Growing the budget may only move the decision toward earlier
    (more-resident) rungs of the ladder — never the reverse."""
    lo, hi = sorted((b1, b2))
    order = ["ref", "pallas_fused_gather", "pallas_fused_gather_tiled",
             planner.STREAM_BACKEND, "pallas_fused", "pallas_fused_tiled",
             "pallas"]
    p_lo = planner.plan_residency(nmodes=nmodes, rank=rank, blk=blk,
                                  tile_rows=8, factor_rows=rows,
                                  vmem_budget=lo << 20)
    p_hi = planner.plan_residency(nmodes=nmodes, rank=rank, blk=blk,
                                  tile_rows=8, factor_rows=rows,
                                  vmem_budget=hi << 20)
    assert order.index(p_hi.backend) <= order.index(p_lo.backend)


# ---------------------------------------------------------------------------
# Dispatch: the streaming rung fires only when whole/slab residency fails
# ---------------------------------------------------------------------------

def test_auto_streams_only_when_residency_fails():
    # rank 512: whole residency costs rows·512·4 B, one slab rows·128·4 B,
    # so the whole/slab/stream rungs separate cleanly.
    kw = dict(nmodes=3, rank=512, blk=32, tile_rows=8)
    # resident fits -> resident gather, not stream
    assert kops.select_backend("auto", factor_rows=1_000,
                               **kw) == "pallas_fused_gather"
    # whole fails, slab fits -> slab-streamed, not out-of-core
    big = 80_000
    assert not kops.gather_fits_vmem(3, 512, 32, 8, big)
    assert kops.gather_fits_vmem(3, 512, 32, 8, big, tiled=True)
    assert kops.select_backend("auto", factor_rows=big,
                               **kw) == "pallas_fused_gather_tiled"
    # whole and slab both fail, window fits -> the out-of-core rung
    huge = 600_000_000
    assert not kops.gather_fits_vmem(3, 512, 32, 8, huge, tiled=True)
    assert kops.gather_stream_fits_vmem(3, 512, 32, 8, huge)
    assert kops.select_backend("auto", factor_rows=huge,
                               **kw) == kops.STREAM_BACKEND
    # window overflows too (shard-sized blocks) -> fused, as before PR 5
    assert not kops.gather_stream_fits_vmem(4, 128, 512, 128, huge)
    assert kops.select_backend("auto", nmodes=4, rank=128, blk=512,
                               tile_rows=128,
                               factor_rows=huge) == "pallas_fused"
    # no factor knowledge -> never the gather family at all
    assert kops.select_backend("auto", **kw) == "pallas_fused"


def test_select_backend_matches_planner_ladder():
    """select_backend's static decision IS plan_residency's backend."""
    for nmodes in (3, 4, 5):
        for rank in (4, 64, 256, 2048):
            for blk in (16, 512):
                for fr in (None, 1_000, 300_000, 600_000_000):
                    kw = dict(nmodes=nmodes, rank=rank, blk=blk,
                              tile_rows=8, factor_rows=fr)
                    assert kops.select_backend("auto", **kw) == \
                        planner.plan_residency(**kw).backend, kw


def test_device_step_auto_streams_under_tiny_budget_geometry():
    """End-to-end: mttkrp_device_step supplies per-mode factor_rows, so
    an explicitly requested stream backend matches ``auto``'s choice
    whenever the planner picks streaming — proven bitwise."""
    shape = SHAPES[3]
    idx, val, factors = _sorted_case(shape, 150, 128, 0, seed=2)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    auto = _device_step(idx, val, valid, factors, 0, rows_cap, "auto")
    explicit = _device_step(idx, val, valid, factors, 0, rows_cap,
                            "pallas_fused_gather_stream")
    # the small case resolves to the resident gather; both must agree
    # bitwise anyway (the stream kernel is bit-exact by contract)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))


# ---------------------------------------------------------------------------
# Runtime threading + tuned plans
# ---------------------------------------------------------------------------

def test_plan_for_stream_backend_records_slabs_and_windows():
    rt = dist.DynasorRuntime(
        num_workers=2, nmodes=3, rank=512, rows_cap=(8, 400, 300),
        i_pad=(16, 800, 600), nnz_cap=8, bucket_cap=8, shape=(16, 800, 600),
        blk=32)
    p = rt.plan_for(0, "pallas_fused_gather_stream")
    assert p.rank_slabs == kops.padded_rank(512) // kops.MXU_RANK_MULTIPLE
    assert p.window_tiles == (
        planner.stream_window_tiles(32, 800),
        planner.stream_window_tiles(32, 600))
    # non-stream backends carry no window metadata
    assert rt.plan_for(0, "pallas_fused_gather").window_tiles == ()


def test_plan_modes_can_choose_stream_and_records_geometry():
    from repro.core.flycoo import build_flycoo
    t = random_sparse_tensor((40, 30, 20), 400, seed=3,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64),
                      cache_bytes=1 << 20)
    entries = [
        tune.CalibrationEntry(
            nmodes=3, rank=r, blk=32, tile_rows=8, density=1.0,
            timings_s={"pallas_fused_gather_stream": 0.001, "pallas": 1.0,
                       "ref": 1.0}, factor_rows=128, stream_window_tiles=1)
        for r in (128, 512)
    ]
    plans = tune.plan_modes(tune.CalibrationTable(entries=entries), ft, 512)
    assert plans is not None
    for n, p in enumerate(plans):
        assert p.backend == "pallas_fused_gather_stream"
        assert p.rank_slabs == kops.padded_rank(512) // \
            kops.MXU_RANK_MULTIPLE
        assert len(p.window_tiles) == ft.nmodes - 1
        assert all(w >= 1 for w in p.window_tiles)


# ---------------------------------------------------------------------------
# Schema v4 + v3 back-compat
# ---------------------------------------------------------------------------

def test_v3_calibration_fixture_still_loads():
    path = os.path.join(REPO_ROOT, "experiments", "tune", "fixtures",
                        "calibration_v3_example.json")
    table = tune.load_table(path)
    assert table.schema_version == tune.SCHEMA_VERSION == 4
    assert table.meta.get("upgraded_from_schema") == 3
    assert table.entries
    for e in table.entries:
        assert e.factor_rows is not None          # v3 recorded it
        assert e.stream_window_tiles is None      # pre-v4: unrecorded
        assert "pallas_fused_gather_stream" not in e.timings_s
    key = table.shape_keys()[0]
    nmodes, rank, blk, tile_rows = key
    got = kops.select_backend("auto", nmodes=nmodes, rank=rank, blk=blk,
                              tile_rows=tile_rows, table=table)
    assert got in kops.AUTO_BACKENDS + ("ref",)


def test_v4_round_trip_records_stream_fields(tmp_path):
    table = tune.calibrate(measure=tune.stub_measure, quick=True)
    for e in table.entries:
        assert "pallas_fused_gather_stream" in e.timings_s
        assert e.stream_window_tiles == 1         # 64-row side factors
    path = table.save(str(tmp_path / "t.json"))
    loaded = tune.load_table(path)
    assert loaded.entries == table.entries
    assert loaded.schema_version == 4


# ---------------------------------------------------------------------------
# Legacy alias
# ---------------------------------------------------------------------------

def test_fused_mttkrp_3mode_is_deprecated_alias():
    rng = np.random.default_rng(0)
    blk, tile = 16, 8
    n_pad, rank, rows_cap = 32, 128, 16
    vals = jnp.asarray(rng.standard_normal(n_pad), jnp.float32)
    ra = jnp.asarray(rng.standard_normal((n_pad, rank)), jnp.float32)
    rb = jnp.asarray(rng.standard_normal((n_pad, rank)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, tile, n_pad), jnp.int32)
    tiles = jnp.asarray(np.sort(rng.integers(0, rows_cap // tile,
                                             n_pad // blk)), jnp.int32)
    with pytest.warns(DeprecationWarning, match="deprecated alias"):
        old = kkernel.fused_mttkrp_3mode(
            vals, ra, rb, rows, tiles, rows_cap=rows_cap, blk=blk,
            tile_rows=tile)
    new = kkernel.fused_mttkrp_nmode(
        vals, (ra, rb), rows, tiles, rows_cap=rows_cap, blk=blk,
        tile_rows=tile)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
