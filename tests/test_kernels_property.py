"""Property-based Pallas kernel sweep: random shapes/blocks vs the oracle
(per assignment: hypothesis sweeps for each Pallas kernel), plus the
``build_block_layout`` invariants every kernel's correctness rides on."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.mttkrp import ops as kops
from repro.kernels.mttkrp import ref as kref


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_el=st.integers(1, 400),
    tiles=st.integers(1, 6),
    tile_rows=st.sampled_from([8, 16, 32]),
    blk=st.sampled_from([16, 32, 64]),
    rank=st.integers(1, 24),
    frac_invalid=st.floats(0.0, 0.4),
)
def test_segment_accumulate_property(seed, n_el, tiles, tile_rows, blk,
                                     rank, frac_invalid):
    rows_cap = tiles * tile_rows
    rng = np.random.default_rng(seed)
    row = np.sort(rng.integers(0, rows_cap, n_el)).astype(np.int32)
    contrib = rng.standard_normal((n_el, rank)).astype(np.float32)
    valid = np.ones(n_el, bool)
    k = int(n_el * frac_invalid)
    if k:
        valid[-k:] = False
        contrib[-k:] = 0.0
        row[-k:] = rows_cap - 1
    out = kops.mttkrp_blocked(jnp.asarray(contrib), jnp.asarray(row),
                              jnp.asarray(valid), rows_cap=rows_cap,
                              blk=blk, tile_rows=tile_rows, interpret=True)
    ref = kref.segment_accumulate_ref(
        jnp.asarray(np.where(valid[:, None], contrib, 0)),
        jnp.asarray(np.where(valid, row, 0)), rows_cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    cap=st.integers(8, 200),
    rows_cap=st.sampled_from([16, 32, 64]),
    rank=st.integers(1, 16),
)
def test_fused_3mode_property(seed, cap, rows_cap, rank):
    rng = np.random.default_rng(seed)
    idx = np.stack([
        np.sort(rng.integers(0, rows_cap, cap)),
        rng.integers(0, 40, cap),
        rng.integers(0, 24, cap),
    ], axis=1).astype(np.int32)
    val = rng.standard_normal(cap).astype(np.float32)
    valid = rng.random(cap) > 0.2
    # invalid entries must trail (FLYCOO pack invariant)
    order = np.argsort(~valid, kind="stable")
    idx, val, valid = idx[order], val[order], valid[order]
    idx[:, 0] = np.sort(idx[:, 0])
    factors = [jnp.asarray(rng.standard_normal((n, rank)), jnp.float32)
               for n in (rows_cap, 40, 24)]
    kw = dict(mode=0, rows_cap=rows_cap, row_offset=0, blk=16, tile_rows=8,
              interpret=True)
    ref = kops.mttkrp_device_step(jnp.asarray(idx), jnp.asarray(val),
                                  jnp.asarray(valid), factors,
                                  backend="ref", **kw)
    got = kops.mttkrp_device_step(jnp.asarray(idx), jnp.asarray(val),
                                  jnp.asarray(valid), factors,
                                  backend="pallas_fused", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_el=st.integers(1, 300),
    tiles=st.integers(1, 6),
    tile_rows=st.sampled_from([8, 16, 32]),
    blk=st.sampled_from([16, 32, 64]),
    frac_invalid=st.floats(0.0, 0.5),
)
def test_build_block_layout_invariants(seed, n_el, tiles, tile_rows, blk,
                                       frac_invalid):
    """The layout contract every Pallas kernel here relies on:

      * valid elements get *injective* in-range slots; invalid elements
        all land on the dump slot ``n_pad``;
      * blocks are homogeneous per tile: a block never straddles an
        output row tile (each tile's run starts on a block boundary);
      * ``tile_of_block`` is non-decreasing and consistent with the
        slots — every valid element's block is attributed to exactly
        its own output tile.
    """
    rows_cap = tiles * tile_rows
    rng = np.random.default_rng(seed)
    row = np.sort(rng.integers(0, rows_cap, n_el)).astype(np.int32)
    valid = np.ones(n_el, bool)
    k = int(n_el * frac_invalid)
    if k:
        valid[-k:] = False          # invalid trail (FLYCOO pack invariant)
    n_pad = kops.n_pad_for(n_el, rows_cap, blk, tile_rows)
    slot, tile_of_block = kops.build_block_layout(
        jnp.asarray(row), jnp.asarray(valid), rows_cap=rows_cap,
        blk=blk, tile_rows=tile_rows)
    slot = np.asarray(slot)
    tile_of_block = np.asarray(tile_of_block)

    assert tile_of_block.shape == (n_pad // blk,)
    # invalid elements -> the dump slot, valid -> in-range
    assert np.all(slot[~valid] == n_pad)
    vslots = slot[valid]
    assert np.all((0 <= vslots) & (vslots < n_pad))
    # injectivity
    assert len(np.unique(vslots)) == len(vslots)

    vtile = row[valid] // tile_rows
    # consistency: each element's block is attributed to its own tile
    assert np.array_equal(tile_of_block[vslots // blk], vtile)
    # block-aligned per tile: every tile's first slot is a block boundary
    # and its elements occupy consecutive slots (sorted-run compaction)
    for t in np.unique(vtile):
        s = np.sort(vslots[vtile == t])
        assert s[0] % blk == 0, (t, s[0])
        assert np.array_equal(s, s[0] + np.arange(len(s)))
    # non-decreasing tile per block
    assert np.all(np.diff(tile_of_block) >= 0)
