#!/usr/bin/env python3
"""Docs consistency checks — run by the CI `docs` job and by pytest.

Three checks, all stdlib-only (no jax import, so the CI job needs
nothing but a Python interpreter):

1. **Intra-repo markdown links resolve.** Every relative
   ``[text](path)`` link in the repo's tracked ``*.md`` files must
   point at an existing file/directory (``#fragment`` suffixes are
   stripped; ``http(s)://`` / ``mailto:`` links are skipped).

2. **docs/kernels.md backend matrix ↔ ops.BACKENDS sync.** The matrix
   rows between the ``<!-- BACKENDS:BEGIN/END -->`` markers must list
   exactly the backends of ``repro.kernels.mttkrp.ops.BACKENDS`` plus
   the two dispatch-level names (``auto``, ``segsum``). ``BACKENDS`` is
   read from the source with ``ast`` so adding a backend without
   documenting it (or vice versa) fails CI.

3. **"lowers (Mosaic)" column ↔ BENCH_lowering.json sync.** The
   matrix's lowering column may only say "yes" for a backend whose
   every row in ``experiments/bench/BENCH_lowering.json`` (the artifact
   the ``interpret=False`` AOT sweep writes) has ``lowered_ok``; a
   backend the sweep saw fail must say "no". Dispatch-level rows
   (no kernel to lower) must carry an em-dash. So the docs claim
   exactly what the checked-in sweep demonstrated.

4. **docs/observability.md counter table ↔ obs NAMESPACES sync.** The
   rows between the ``<!-- COUNTERS:BEGIN/END -->`` markers must list
   exactly the names of ``repro.obs.counters.NAMESPACES`` (read with
   ``ast``, like BACKENDS). The registry rejects undocumented names at
   runtime; this closes the loop the other way — a namespace entry
   without a doc row fails CI.

Exit status 0 iff all checks pass; failures are printed one per line.
"""
from __future__ import annotations

import ast
import json
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OPS_PATH = os.path.join(REPO_ROOT, "src", "repro", "kernels", "mttkrp",
                        "ops.py")
KERNELS_DOC = os.path.join(REPO_ROOT, "docs", "kernels.md")
LOWERING_BENCH = os.path.join(REPO_ROOT, "experiments", "bench",
                              "BENCH_lowering.json")
LOWERING_COLUMN = "lowers (Mosaic)"
COUNTERS_PATH = os.path.join(REPO_ROOT, "src", "repro", "obs",
                             "counters.py")
OBS_DOC = os.path.join(REPO_ROOT, "docs", "observability.md")

# Names the matrix documents beyond ops.BACKENDS: the auto resolver and
# the distributed layer's plain-XLA path.
DISPATCH_LEVEL_NAMES = {"auto", "segsum"}

_SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude",
              "node_modules", ".venv"}
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_ROW_NAME_RE = re.compile(r"^\|\s*`([a-z0-9_]+)`")
# Counter names are dotted (`oocore.dma.scheduled_bytes`).
_COUNTER_ROW_RE = re.compile(r"^\|\s*`([a-z0-9_.]+)`")


def iter_markdown_files():
    for dirpath, dirnames, filenames in os.walk(REPO_ROOT):
        dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
        for name in sorted(filenames):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_links() -> tuple[list[str], int]:
    """Returns (errors, number_of_links_checked)."""
    errors, checked = [], 0
    for md in iter_markdown_files():
        with open(md, encoding="utf-8") as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            checked += 1
            path = target.split("#", 1)[0]
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), path))
            if not os.path.exists(resolved):
                rel = os.path.relpath(md, REPO_ROOT)
                errors.append(f"{rel}: broken link -> {target}")
    return errors, checked


def ops_backends() -> tuple[str, ...]:
    """`BACKENDS` from ops.py via ast — no jax import needed."""
    with open(OPS_PATH, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=OPS_PATH)
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "BACKENDS"
                for t in node.targets):
            value = ast.literal_eval(node.value)
            return tuple(value)
    raise AssertionError(f"no literal BACKENDS assignment found in "
                         f"{OPS_PATH}")


def documented_backends() -> set[str]:
    """Backend names in kernels.md's marked matrix rows."""
    with open(KERNELS_DOC, encoding="utf-8") as f:
        text = f.read()
    try:
        block = text.split("<!-- BACKENDS:BEGIN -->", 1)[1] \
                    .split("<!-- BACKENDS:END -->", 1)[0]
    except IndexError:
        raise AssertionError(
            "docs/kernels.md is missing the <!-- BACKENDS:BEGIN/END --> "
            "markers around the backend matrix")
    names = set()
    for line in block.splitlines():
        m = _ROW_NAME_RE.match(line.strip())
        if m:
            names.add(m.group(1))
    return names


def matrix_cells() -> tuple[list[str], dict[str, list[str]]]:
    """(header cells, {backend: row cells}) of the marked matrix."""
    with open(KERNELS_DOC, encoding="utf-8") as f:
        text = f.read()
    block = text.split("<!-- BACKENDS:BEGIN -->", 1)[1] \
                .split("<!-- BACKENDS:END -->", 1)[0]
    header, rows = [], {}
    for line in block.splitlines():
        line = line.strip()
        if not line.startswith("|") or set(line) <= {"|", "-", " "}:
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        m = _ROW_NAME_RE.match(line)
        if m:
            rows[m.group(1)] = cells
        elif not header:
            header = cells
    return header, rows


def lowering_status() -> dict[str, bool]:
    """{backend: every sweep point lowered_ok} from BENCH_lowering.json."""
    with open(LOWERING_BENCH, encoding="utf-8") as f:
        data = json.load(f)
    status: dict[str, bool] = {}
    for row in data:
        if row.get("bench") != "lowering":
            continue
        b = row["backend"]
        status[b] = status.get(b, True) and bool(row["lowered_ok"])
    return status


def check_lowering_sync() -> list[str]:
    """The matrix's "lowers (Mosaic)" column matches the sweep artifact."""
    if not os.path.exists(LOWERING_BENCH):
        return [f"{os.path.relpath(LOWERING_BENCH, REPO_ROOT)} is missing "
                "— run `PYTHONPATH=src python -m benchmarks.run --only "
                "lowering` and commit the artifact"]
    errors = []
    header, rows = matrix_cells()
    if LOWERING_COLUMN not in header:
        return [f"docs/kernels.md: matrix has no `{LOWERING_COLUMN}` "
                "column"]
    col = header.index(LOWERING_COLUMN)
    status = lowering_status()
    if not status:
        return [f"{os.path.relpath(LOWERING_BENCH, REPO_ROOT)} has no "
                "lowering rows"]
    for name, cells in sorted(rows.items()):
        if len(cells) <= col:
            errors.append(f"docs/kernels.md: row `{name}` is short a "
                          f"`{LOWERING_COLUMN}` cell")
            continue
        cell = cells[col]
        if name in DISPATCH_LEVEL_NAMES:
            if cell not in {"—", "-", "n/a"}:
                errors.append(
                    f"docs/kernels.md: dispatch-level `{name}` has no "
                    f"kernel to lower; `{LOWERING_COLUMN}` must be an "
                    f"em-dash, not {cell!r}")
            continue
        if name not in status:
            errors.append(
                f"docs/kernels.md: backend `{name}` has no rows in "
                f"BENCH_lowering.json — extend the sweep before "
                "claiming a lowering status")
            continue
        want = "yes" if status[name] else "no"
        if not cell.startswith(want):
            errors.append(
                f"docs/kernels.md: `{name}` `{LOWERING_COLUMN}` says "
                f"{cell!r} but BENCH_lowering.json records "
                f"lowered_ok={status[name]} — the docs may only claim "
                "what the sweep demonstrated")
    return errors


def obs_namespaces() -> tuple[str, ...]:
    """`NAMESPACES` from obs/counters.py via ast — no jax import."""
    with open(COUNTERS_PATH, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=COUNTERS_PATH)
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "NAMESPACES"
                for t in node.targets):
            return tuple(ast.literal_eval(node.value))
    raise AssertionError(f"no literal NAMESPACES assignment found in "
                         f"{COUNTERS_PATH}")


def documented_counters() -> set[str]:
    """Counter names in observability.md's marked table rows."""
    with open(OBS_DOC, encoding="utf-8") as f:
        text = f.read()
    try:
        block = text.split("<!-- COUNTERS:BEGIN -->", 1)[1] \
                    .split("<!-- COUNTERS:END -->", 1)[0]
    except IndexError:
        raise AssertionError(
            "docs/observability.md is missing the "
            "<!-- COUNTERS:BEGIN/END --> markers around the counter "
            "namespace table")
    names = set()
    for line in block.splitlines():
        m = _COUNTER_ROW_RE.match(line.strip())
        if m:
            names.add(m.group(1))
    return names


def check_counter_sync() -> list[str]:
    errors = []
    code = set(obs_namespaces())
    docs = documented_counters()
    for missing in sorted(code - docs):
        errors.append(
            f"docs/observability.md: counter `{missing}` is in "
            "obs.counters.NAMESPACES but missing from the namespace "
            "table")
    for stale in sorted(docs - code):
        errors.append(
            f"docs/observability.md: counter `{stale}` is documented "
            "but not in obs.counters.NAMESPACES — remove the row or "
            "register the name")
    return errors


def check_backend_sync() -> list[str]:
    errors = []
    code = set(ops_backends())
    docs = documented_backends()
    want = code | DISPATCH_LEVEL_NAMES
    for missing in sorted(want - docs):
        errors.append(
            f"docs/kernels.md: backend `{missing}` exists in ops.py "
            "(or is a dispatch-level name) but is missing from the "
            "decision matrix")
    for stale in sorted(docs - want):
        errors.append(
            f"docs/kernels.md: backend `{stale}` is documented but not "
            "in ops.BACKENDS — remove the row or add the backend")
    return errors


def main() -> int:
    link_errors, checked = check_links()
    sync_errors = check_backend_sync()
    lowering_errors = check_lowering_sync()
    counter_errors = check_counter_sync()
    for e in link_errors + sync_errors + lowering_errors + counter_errors:
        print(f"FAIL {e}")
    if link_errors or sync_errors or lowering_errors or counter_errors:
        return 1
    n_backends = len(ops_backends())
    n_lower = sum(lowering_status().values())
    print(f"docs checks passed: {checked} markdown links resolve, "
          f"{n_backends} backends in sync with docs/kernels.md, "
          f"{n_lower} lowering statuses match BENCH_lowering.json, "
          f"{len(obs_namespaces())} counters in sync with "
          "docs/observability.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
