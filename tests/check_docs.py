#!/usr/bin/env python3
"""Docs consistency checks — run by the CI `docs` job and by pytest.

Two checks, both stdlib-only (no jax import, so the CI job needs
nothing but a Python interpreter):

1. **Intra-repo markdown links resolve.** Every relative
   ``[text](path)`` link in the repo's tracked ``*.md`` files must
   point at an existing file/directory (``#fragment`` suffixes are
   stripped; ``http(s)://`` / ``mailto:`` links are skipped).

2. **docs/kernels.md backend matrix ↔ ops.BACKENDS sync.** The matrix
   rows between the ``<!-- BACKENDS:BEGIN/END -->`` markers must list
   exactly the backends of ``repro.kernels.mttkrp.ops.BACKENDS`` plus
   the two dispatch-level names (``auto``, ``segsum``). ``BACKENDS`` is
   read from the source with ``ast`` so adding a backend without
   documenting it (or vice versa) fails CI.

Exit status 0 iff both checks pass; failures are printed one per line.
"""
from __future__ import annotations

import ast
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OPS_PATH = os.path.join(REPO_ROOT, "src", "repro", "kernels", "mttkrp",
                        "ops.py")
KERNELS_DOC = os.path.join(REPO_ROOT, "docs", "kernels.md")

# Names the matrix documents beyond ops.BACKENDS: the auto resolver and
# the distributed layer's plain-XLA path.
DISPATCH_LEVEL_NAMES = {"auto", "segsum"}

_SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude",
              "node_modules", ".venv"}
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_ROW_NAME_RE = re.compile(r"^\|\s*`([a-z0-9_]+)`")


def iter_markdown_files():
    for dirpath, dirnames, filenames in os.walk(REPO_ROOT):
        dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
        for name in sorted(filenames):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_links() -> tuple[list[str], int]:
    """Returns (errors, number_of_links_checked)."""
    errors, checked = [], 0
    for md in iter_markdown_files():
        with open(md, encoding="utf-8") as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            checked += 1
            path = target.split("#", 1)[0]
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), path))
            if not os.path.exists(resolved):
                rel = os.path.relpath(md, REPO_ROOT)
                errors.append(f"{rel}: broken link -> {target}")
    return errors, checked


def ops_backends() -> tuple[str, ...]:
    """`BACKENDS` from ops.py via ast — no jax import needed."""
    with open(OPS_PATH, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=OPS_PATH)
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "BACKENDS"
                for t in node.targets):
            value = ast.literal_eval(node.value)
            return tuple(value)
    raise AssertionError(f"no literal BACKENDS assignment found in "
                         f"{OPS_PATH}")


def documented_backends() -> set[str]:
    """Backend names in kernels.md's marked matrix rows."""
    with open(KERNELS_DOC, encoding="utf-8") as f:
        text = f.read()
    try:
        block = text.split("<!-- BACKENDS:BEGIN -->", 1)[1] \
                    .split("<!-- BACKENDS:END -->", 1)[0]
    except IndexError:
        raise AssertionError(
            "docs/kernels.md is missing the <!-- BACKENDS:BEGIN/END --> "
            "markers around the backend matrix")
    names = set()
    for line in block.splitlines():
        m = _ROW_NAME_RE.match(line.strip())
        if m:
            names.add(m.group(1))
    return names


def check_backend_sync() -> list[str]:
    errors = []
    code = set(ops_backends())
    docs = documented_backends()
    want = code | DISPATCH_LEVEL_NAMES
    for missing in sorted(want - docs):
        errors.append(
            f"docs/kernels.md: backend `{missing}` exists in ops.py "
            "(or is a dispatch-level name) but is missing from the "
            "decision matrix")
    for stale in sorted(docs - want):
        errors.append(
            f"docs/kernels.md: backend `{stale}` is documented but not "
            "in ops.BACKENDS — remove the row or add the backend")
    return errors


def main() -> int:
    link_errors, checked = check_links()
    sync_errors = check_backend_sync()
    for e in link_errors + sync_errors:
        print(f"FAIL {e}")
    if link_errors or sync_errors:
        return 1
    n_backends = len(ops_backends())
    print(f"docs checks passed: {checked} markdown links resolve, "
          f"{n_backends} backends in sync with docs/kernels.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
