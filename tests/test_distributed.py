"""Distributed Dynasor (shard_map owner-computes + remap) — runs in a
subprocess so the 4-device XLA flag never leaks into other tests."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core.tensors import random_sparse_tensor, SparseTensor
from repro.core.flycoo import build_flycoo
from repro.core.mttkrp import mttkrp_elementwise_ref
from repro.core import distributed as dist
from repro.core.cpals import cp_als, cp_als_distributed
import itertools

# --- owner-computes == elementwise ref == all-reduce baseline -------------
t = random_sparse_tensor((60, 45, 30), 500, seed=1, distribution="powerlaw")
ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64), cache_bytes=1<<20)
rt, (idx, val, mask) = dist.prepare_runtime(ft, rank=8, tile_rows=8)
mesh = Mesh(np.array(jax.devices()), (dist.AXIS,))
factors = dist.init_factors(ft, rt, seed=0)

fn = dist.make_spmttkrp_all_modes(rt, mesh, backend="segsum", remap=True)
outs, packed2, diags = fn(idx, val, mask, *factors)
assert int(diags["dropped"]) == 0
perm_idx = dist._repad_indices(ft, ft.perm_indices.astype(np.int32), rt.rows_cap)
for n in range(3):
    ref = mttkrp_elementwise_ref(perm_idx, t.values, factors, n, out_rows=rt.i_pad[n])
    err = np.abs(np.asarray(outs[n]) - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err < 1e-4, (n, err)

# remap round-trip: a full mode cycle returns an equivalent layout
outs2, _, _ = fn(*packed2, *factors)
for n in range(3):
    err = np.abs(np.asarray(outs2[n]) - np.asarray(outs[n])).max()
    assert err < 1e-3, (n, err)

# lock-free claim: owner-computes equals nonzero-parallel + all-reduce
fnb = dist.make_baseline_all_modes(rt, mesh)
outsb = fnb(*dist.even_split_pack(ft, rt), *factors)
for n in range(3):
    r = np.asarray(outs[n]); g = np.asarray(outsb[n])
    assert np.abs(g - r).max() / (np.abs(r).max() + 1e-9) < 1e-4

# pallas backend inside shard_map
fnp = dist.make_spmttkrp_all_modes(rt, mesh, backend="pallas", remap=True)
outsp, _, _ = fnp(idx, val, mask, *factors)
for n in range(3):
    r = np.asarray(outs[n]); g = np.asarray(outsp[n])
    assert np.abs(g - r).max() / (np.abs(r).max() + 1e-9) < 1e-4

# --- 4-mode fused N-mode kernel end-to-end under shard_map ----------------
t4 = random_sparse_tensor((20, 15, 12, 10), 400, seed=2)
ft4 = build_flycoo(t4, 4, m_bounds=(2, 8), g_bounds=(8, 64), cache_bytes=1<<20,
                   fused_gather=True)
rt4, (idx4, val4, mask4) = dist.prepare_runtime(ft4, rank=8, tile_rows=8)
f4 = dist.init_factors(ft4, rt4, seed=0)
perm4 = dist._repad_indices(ft4, ft4.perm_indices.astype(np.int32), rt4.rows_cap)
for bk in ("pallas_fused", "auto"):
    fn4 = dist.make_spmttkrp_all_modes(rt4, mesh, backend=bk, remap=True)
    outs4, _, d4 = fn4(idx4, val4, mask4, *f4)
    assert int(d4["dropped"]) == 0
    for n in range(4):
        ref = mttkrp_elementwise_ref(perm4, t4.values, f4, n, out_rows=rt4.i_pad[n])
        err = np.abs(np.asarray(outs4[n]) - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err < 1e-4, ("4mode", bk, n, err)

# --- distributed CP-ALS == single-device CP-ALS ----------------------------
rng = np.random.default_rng(0)
shape = (24, 18, 12); R = 4
facs = [rng.standard_normal((d, R)) for d in shape]
dense = np.einsum("ir,jr,kr->ijk", *facs)
idx2 = np.array(list(itertools.product(*[range(d) for d in shape])), dtype=np.int32)
td = SparseTensor(idx2, dense.reshape(-1).astype(np.float32), shape)
res_s = cp_als(td, rank=R, iters=25, seed=1)
ft2 = build_flycoo(td, 4, m_bounds=(2, 8), g_bounds=(8, 64), cache_bytes=1<<20)
res_d = cp_als_distributed(ft2, R, mesh, iters=25, seed=1)
assert res_d.fit > 0.999, res_d.fits
rec = np.einsum("r,ir,jr,kr->ijk", res_d.lam, *res_d.factors)
assert np.linalg.norm(rec - dense) / np.linalg.norm(dense) < 1e-2

# --- owner-computes MoE (shard_map EP) == gather baseline, fwd + grad -----
from jax.sharding import Mesh as Mesh2
import jax.numpy as jnp
from repro.models import moe
from repro.models.params import init_params
from repro.models.sharding import use_mesh_rules, default_rules
mesh2 = Mesh2(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
d, f, E, K = 16, 32, 8, 2
mparams = init_params({"m": moe.moe_specs(d, f, E, 1, E)}, seed=0)["m"]
xm = jnp.asarray(np.random.default_rng(0).standard_normal((4, 8, d)),
                 jnp.float32)
ref_y, _ = moe._moe_apply_gather(mparams, xm, n_real=E, top_k=K,
                                 deterministic_cap=64)
with use_mesh_rules(mesh2, default_rules()):
    own_y, own_m = jax.jit(lambda p, x: moe.moe_apply_owner(
        p, x, n_real=E, top_k=K, deterministic_cap=64))(mparams, xm)
assert np.abs(np.asarray(own_y) - np.asarray(ref_y)).max() < 2e-4
assert int(own_m["moe_dropped"]) == 0

def loss_o(p):
    with use_mesh_rules(mesh2, default_rules()):
        y, _ = moe.moe_apply_owner(p, xm, n_real=E, top_k=K,
                                   deterministic_cap=64)
    return jnp.sum(y ** 2)
def loss_g(p):
    y, _ = moe._moe_apply_gather(p, xm, n_real=E, top_k=K,
                                 deterministic_cap=64)
    return jnp.sum(y ** 2)
g1 = jax.jit(jax.grad(loss_o))(mparams)
g2 = jax.jit(jax.grad(loss_g))(mparams)
for kk in ("w_gate", "w_up", "w_down", "router"):
    e = np.abs(np.asarray(g1[kk]) - np.asarray(g2[kk])).max()
    assert e / (np.abs(np.asarray(g2[kk])).max() + 1e-9) < 1e-3, kk
print("DISTRIBUTED-OK")
"""


@pytest.mark.slow
def test_distributed_dynasor_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "DISTRIBUTED-OK" in out.stdout, out.stdout + out.stderr
