"""Per-arch smoke tests (assignment deliverable f): every assigned
architecture instantiates a REDUCED config of the same family and runs one
forward + train step + prefill + decode on CPU, asserting shapes + no NaNs.
Also: decode path consistency vs. the full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim as O
from repro.configs import ARCHS, SHAPES, applicable, get_config, smoke_config
from repro.models import model as M
from repro.models import steps as S
from repro.models.params import init_params

B, L = 2, 32

# Archs whose smoke configs still take tens of seconds on 1 CPU core; they
# run in the full tier but are deselected by tests/run_fast.sh.
_HEAVY = {"jamba-1.5-large-398b", "llama4-scout-17b-a16e",
          "llama-3.2-vision-11b", "seamless-m4t-large-v2",
          "internlm2-20b", "minitron-8b"}


def _arch_params(names):
    return [pytest.param(n, marks=pytest.mark.slow) if n in _HEAVY else n
            for n in sorted(names)]


def _batch(cfg, rng, l=L):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, l)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, l)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, l, cfg.d_frontend)), jnp.float32)
    if cfg.family == "vlm":
        batch["img"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_frontend)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", _arch_params(ARCHS))
def test_arch_smoke_train_and_serve(name):
    cfg = smoke_config(name)
    params = init_params(M.model_specs(cfg), seed=0)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)

    logits, _ = M.forward(cfg, params, batch["tokens"],
                          frames=batch.get("frames"), img=batch.get("img"))
    assert logits.shape == (B, L, cfg.vocab_padded)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    opt = O.make_optimizer(cfg.optimizer, O.cosine_schedule(1e-3, 2, 10))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(S.make_train_step(cfg, opt))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1

    lg, cache = jax.jit(S.make_prefill_step(cfg))(params, batch)
    assert lg.shape == (B, 1, cfg.vocab_padded)
    dec = jax.jit(S.make_decode_step(cfg))
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    lg2, cache2 = dec(params, cache, tok, jnp.int32(L - 1))
    assert lg2.shape == (B, 1, cfg.vocab_padded)
    assert not np.any(np.isnan(np.asarray(lg2, np.float32)))


@pytest.mark.parametrize("name", _arch_params(ARCHS))
def test_prefill_logits_match_forward(name):
    """prefill's last-token logits == forward's last position."""
    cfg = smoke_config(name)
    params = init_params(M.model_specs(cfg), seed=1)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    full, _ = M.forward(cfg, params, batch["tokens"],
                        frames=batch.get("frames"), img=batch.get("img"),
                        remat=False)
    last, _ = M.prefill(cfg, params, batch["tokens"],
                        frames=batch.get("frames"), img=batch.get("img"))
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", _arch_params([
    "qwen3-32b", "mamba2-370m", "qwen2-moe-a2.7b", "llama-3.2-vision-11b"]))
def test_decode_consistent_with_forward(name):
    """Teacher-forcing forward at position l == prefill(l) + decode step."""
    cfg = smoke_config(name)
    params = init_params(M.model_specs(cfg), seed=2)
    rng = np.random.default_rng(2)
    l = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, l + 1)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["img"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_frontend)),
            jnp.float32)
    full, _ = M.forward(cfg, params, toks, remat=False, **kw)
    _, cache = M.prefill(cfg, params, toks[:, :l], **kw)
    # grow attention caches by one slot for the new token
    def grow(c):
        if c.ndim == 5 and c.shape[2] == l:
            return jnp.pad(c, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        return c
    cache = jax.tree.map(grow, cache)
    lg, _ = M.decode_step(cfg, params, cache, toks[:, l:], jnp.int32(l))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=3e-2, atol=3e-2)


def test_grid_accounting():
    """40 assigned cells: every (arch × shape) is either runnable or has a
    documented skip reason."""
    n_run, n_skip = 0, 0
    for name, cfg in ARCHS.items():
        for shape in SHAPES.values():
            if applicable(cfg, shape):
                n_run += 1
            else:
                n_skip += 1
    assert n_run + n_skip == 40
    # exactly the pure full-attention archs skip long_500k (7 of 10)
    assert n_skip == 7


def test_param_counts_match_published_sizes():
    """Analytic parameter counts are in the right ballpark of the names."""
    expect = {
        "qwen3-32b": (29e9, 36e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "internlm2-20b": (18e9, 23e9),
        "minitron-8b": (7e9, 10e9),
        "qwen2-moe-a2.7b": (13e9, 16e9),       # total (2.7B active)
        "llama4-scout-17b-a16e": (100e9, 118e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, n)
    # active < total for MoE
    for name in ("qwen2-moe-a2.7b", "llama4-scout-17b-a16e",
                 "jamba-1.5-large-398b"):
        cfg = get_config(name)
        assert cfg.param_count(active_only=True) < 0.5 * cfg.param_count()
