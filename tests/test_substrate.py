"""Substrate subsystems: optimizers, data pipeline, checkpointing, runner."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim as O
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData, make_batch_iterator
from repro.models.params import ParamSpec, abstract_params, init_params
from repro.runtime import StragglerMonitor, TrainLoopRunner


# ---------------------------------------------------------------- optim --

@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_decreases_quadratic(name):
    opt = O.make_optimizer(name, lambda s: jnp.float32(0.1))
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 8), jnp.float32)}
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.05 * l0


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_state_specs_match_init(name):
    opt = O.make_optimizer(name)
    pspecs = {"a": ParamSpec((6, 4), ("embed", "mlp")),
              "b": ParamSpec((5,), (None,))}
    params = init_params(pspecs, seed=0)
    state = opt.init(params)
    sspecs = opt.state_specs(pspecs)
    abstract = abstract_params(sspecs)
    real_shapes = jax.tree.map(lambda x: x.shape, state)
    spec_shapes = jax.tree.map(lambda x: x.shape, abstract)
    assert real_shapes == spec_shapes


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = O.clip_by_global_norm(tree, 1.0)
    assert np.isclose(float(norm), np.sqrt(10 * 9 + 10 * 16))
    cn = O.global_norm(clipped)
    assert float(cn) <= 1.0 + 1e-5


# ----------------------------------------------------------------- data --

def test_data_deterministic_and_resumable():
    a = SyntheticLMData(1000, 16, 8, seed=3).batch(5)
    b = SyntheticLMData(1000, 16, 8, seed=3).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = make_batch_iterator(1000, 16, 8, seed=3, start_step=5)
    step, c = next(it)
    assert step == 5
    np.testing.assert_array_equal(a["tokens"], c["tokens"])


def test_data_sharding_partitions_global_batch():
    src = SyntheticLMData(1000, 16, 8, seed=1)
    full = src.batch(2)
    sh0 = src.batch(2, shard=0, num_shards=2)
    sh1 = src.batch(2, shard=1, num_shards=2)
    np.testing.assert_array_equal(
        np.concatenate([sh0["tokens"], sh1["tokens"]]), full["tokens"])


def test_labels_shift_tokens():
    b = SyntheticLMData(1000, 16, 4, seed=0).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ----------------------------------------------------------- checkpoint --

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree))
    assert mgr.all_steps() == [20, 30]          # keep=2 gc'd step 10
    restored, step = mgr.restore(tree)
    assert step == 30
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(6.0).reshape(2, 3) + 30)


def test_checkpoint_ignores_incomplete(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones(3)}
    mgr.save(1, tree)
    # simulate a crashed write: dir exists but no _DONE marker
    os.makedirs(tmp_path / "step_0000000099")
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------- runner --

def test_runner_trains_resumes_and_monitors(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        return {"x": state["x"] + 1}, {"loss": 1.0 / (state["x"] + 1)}

    mgr = CheckpointManager(str(tmp_path))
    runner = TrainLoopRunner(step_fn, mgr, ckpt_every=4, log_every=100,
                             log_fn=lambda *a: None)

    def batches():
        return make_batch_iterator(10, 4, 2, seed=0)

    state = {"x": jnp.zeros((), jnp.int32)}
    state, hist = runner.run(state, batches(), num_steps=10)
    assert int(state["x"]) == 10
    assert len(hist) == 10
    # resume: latest checkpoint was step 8
    runner2 = TrainLoopRunner(step_fn, mgr, ckpt_every=4, log_every=100,
                              log_fn=lambda *a: None)
    resumed, start = runner2.resume_or({"x": jnp.zeros((), jnp.int32)})
    assert start == 8
    assert int(resumed["x"]) == 9   # state after step 8 ran


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0)
    for s in range(10):
        assert not mon.observe(s, 1.0)
    assert mon.observe(10, 5.0)
    assert len(mon.events) == 1
