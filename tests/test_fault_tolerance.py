"""repro.runtime.fault_tolerance — first unit coverage (PR-9).

The runner shipped in the seed untested; these pin its three contracts:
  * EWMA straggler detection flags slow steps against the running mean
    and keeps adapting afterwards;
  * a transiently failing ``train_step`` is retried boundedly with
    rollback-and-replay (state restored to the last checkpoint, the
    data stream replayed), every retry counted under
    ``resilience.retries{site=train_step}``;
  * SIGTERM preemption triggers one final checkpoint before exit, so a
    rerun resumes from the preempted step.
"""
import os
import signal

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.obs import counters as ocnt
from repro.runtime.fault_tolerance import StragglerMonitor, TrainLoopRunner


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------

def test_straggler_ewma_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0, alpha=0.5)
    assert mon.observe(0, 1.0) is False          # first sample: no baseline
    assert mon.ewma == 1.0
    assert mon.observe(1, 1.1) is False          # within threshold
    assert mon.observe(2, 5.0) is True           # > 2× the EWMA
    assert mon.events[0][0] == 2
    assert mon.events[0][1] == 5.0


def test_straggler_ewma_adapts():
    mon = StragglerMonitor(threshold=2.0, alpha=0.5)
    mon.observe(0, 1.0)
    mon.observe(1, 5.0)                          # straggler, but absorbed
    # EWMA rose to 3.0: the same 5.0 is no longer a straggler.
    assert mon.ewma == pytest.approx(3.0)
    assert mon.observe(2, 5.0) is False
    assert len(mon.events) == 1


def test_straggler_exact_threshold_is_not_flagged():
    mon = StragglerMonitor(threshold=2.0, alpha=0.1)
    mon.observe(0, 1.0)
    assert mon.observe(1, 2.0) is False          # dt == threshold·ewma


# ---------------------------------------------------------------------------
# TrainLoopRunner helpers
# ---------------------------------------------------------------------------

class ReplayBatches:
    """Resumable (step, batch) stream: ``iter()`` replays from the step
    the consumer is about to retry — the runner's rollback contract."""

    def __init__(self, n):
        self.n = n
        self.cursor = 0

    def __iter__(self):
        step = self.cursor
        while step < self.n:
            self.cursor = step
            yield step, {"x": float(step)}
            step += 1


def _runner(tmp_path, train_step, **kw):
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    kw.setdefault("log_fn", lambda *_: None)
    return TrainLoopRunner(train_step, ckpt, **kw)


def test_runner_happy_path_records_history(tmp_path):
    def step_fn(state, batch):
        return state + 1, {"loss": 1.0 / (state + 1)}

    runner = _runner(tmp_path, step_fn, ckpt_every=2)
    state, history = runner.run(0, ReplayBatches(5), 5)
    assert state == 5
    assert [h["step"] for h in history] == [0, 1, 2, 3, 4]
    # periodic checkpoints at steps 2 and 4
    assert runner.ckpt.all_steps() == [2, 4]


def test_runner_bounded_retry_replays_from_last_good(tmp_path):
    fail_at = {3: 2}                 # step 3 fails twice, then succeeds
    seen = []

    def step_fn(state, batch):
        step = int(batch["x"])
        seen.append(step)
        if fail_at.get(step, 0) > 0:
            fail_at[step] -= 1
            raise RuntimeError("transient interconnect blip")
        return state + 1, {"loss": 1.0}

    runner = _runner(tmp_path, step_fn, ckpt_every=2, max_retries=3)
    with ocnt.use_registry() as reg:
        state, history = runner.run(0, ReplayBatches(6), 6)
        assert reg.get("resilience.retries",
                       site="train_step") == 2
    assert state == 6
    assert len(history) == 6
    assert seen.count(3) == 3                    # two failures + success


def test_runner_nan_loss_is_a_step_failure(tmp_path):
    bad = {2: 1}

    def step_fn(state, batch):
        step = int(batch["x"])
        if bad.get(step, 0) > 0:
            bad[step] -= 1
            return state + 1, {"loss": float("nan")}
        return state + 1, {"loss": 0.5}

    runner = _runner(tmp_path, step_fn, max_retries=2)
    with ocnt.use_registry() as reg:
        state, history = runner.run(0, ReplayBatches(4), 4)
        assert reg.get("resilience.retries",
                       site="train_step") == 1
    assert all(np.isfinite(h["loss"]) for h in history)


def test_runner_retry_exhaustion_raises(tmp_path):
    def step_fn(state, batch):
        raise RuntimeError("permanently broken")

    runner = _runner(tmp_path, step_fn, max_retries=2)
    with ocnt.use_registry():
        with pytest.raises(RuntimeError, match="permanently broken"):
            runner.run(0, ReplayBatches(3), 3)


def test_runner_sigterm_takes_final_checkpoint(tmp_path):
    """Preemption mid-run: the handler sets the flag, the loop exits at
    the step boundary, and one final checkpoint lands."""
    def step_fn(state, batch):
        if int(batch["x"]) == 2:
            os.kill(os.getpid(), signal.SIGTERM)
        return state + 1, {"loss": 1.0}

    prev = signal.getsignal(signal.SIGTERM)
    try:
        runner = _runner(tmp_path, step_fn, ckpt_every=100)
        with ocnt.use_registry() as reg:
            state, history = runner.run(0, ReplayBatches(50), 50)
            assert reg.get("resilience.checkpoint.saves") == 1
    finally:
        signal.signal(signal.SIGTERM, prev)
    assert len(history) == 3                     # steps 0..2 then preempted
    assert runner.ckpt.latest_step() == 3
    restored, step = runner.ckpt.restore(0)
    assert (restored, step) == (3, 3)


def test_runner_resume_or_restores_latest(tmp_path):
    runner = _runner(tmp_path, lambda s, b: (s, {"loss": 1.0}))
    state, start = runner.resume_or(0)
    assert (state, start) == (0, 0)              # fresh directory
    runner.ckpt.save(7, 42)
    state, start = runner.resume_or(0)
    assert (int(np.asarray(state)), start) == (42, 7)
