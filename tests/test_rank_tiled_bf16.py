"""Rank-tiled + bf16-gather fused MTTKRP backends (PR-3 tentpole).

Coverage per the issue checklist:
  * exact-match vs the elementwise reference at R ∈ {128, 256, 512}
    across N ∈ {3, 4, 5} for ``pallas_fused_tiled``;
  * bf16 tolerance bounds (bf16 gathers, fp32 accumulate);
  * a hypothesis sweep asserting tiled ≡ untiled fused on small ranks;
  * dispatch tests that large-R configurations no longer fall back to
    the HBM-materialized path;
  * runtime threading: ``ModePlan.rank_slabs`` and
    ``DynasorRuntime.gather_dtype``.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import tune
from repro.core import distributed as dist
from repro.core.flycoo import build_flycoo
from repro.core.mttkrp import mttkrp_elementwise_ref, mttkrp_fused
from repro.core.tensors import random_sparse_tensor
from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import ops as kops

BLK, TILE = 32, 8

SHAPES = {3: (20, 16, 12), 4: (12, 10, 8, 6), 5: (8, 7, 6, 5, 4)}


def _sorted_case(shape, nnz, rank, mode, seed=0):
    rng = np.random.default_rng(seed)
    t = random_sparse_tensor(shape, nnz, seed=seed)
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    return idx, val, factors


def _device_step(idx, val, valid, factors, mode, rows_cap, backend,
                 gather_dtype="float32"):
    return kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=mode, rows_cap=rows_cap, row_offset=0, blk=BLK, tile_rows=TILE,
        interpret=True, backend=backend, gather_dtype=gather_dtype)


def _rel_err(got, ref):
    return np.abs(np.asarray(got) - ref).max() / (np.abs(ref).max() + 1e-9)


# ---------------------------------------------------------------------------
# Golden: tiled kernel vs elementwise ref and vs the untiled fused kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nmodes", [3, 4, 5])
@pytest.mark.parametrize("rank", [128, 256, 512])
def test_tiled_matches_ref_and_untiled(nmodes, rank):
    shape = SHAPES[nmodes]
    idx, val, factors = _sorted_case(shape, 150, rank, 0, seed=nmodes)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    ref = mttkrp_elementwise_ref(idx, val, factors, 0, out_rows=rows_cap)
    tiled = _device_step(idx, val, valid, factors, 0, rows_cap,
                         "pallas_fused_tiled")
    assert _rel_err(tiled, ref) < 1e-4, (nmodes, rank)
    # Slab-wise the tiled kernel performs the identical column-independent
    # arithmetic, so it must agree with the untiled kernel bitwise.
    untiled = _device_step(idx, val, valid, factors, 0, rows_cap,
                           "pallas_fused")
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(untiled))


def test_tiled_kernel_direct_multi_slab():
    """Kernel-level: a 4-slab layout against the pure-jnp fused oracle."""
    from repro.kernels.mttkrp import ref as kref
    rng = np.random.default_rng(11)
    cap, rows_cap, rank, n_in = 200, 4 * TILE, 512, 2
    local_row = np.sort(rng.integers(0, rows_cap, cap)).astype(np.int32)
    vals = rng.standard_normal(cap).astype(np.float32)
    rows_list = [rng.standard_normal((cap, rank)).astype(np.float32)
                 for _ in range(n_in)]
    n_pad = kops.n_pad_for(cap, rows_cap, BLK, TILE)
    slot, tile_of_block = kops.build_block_layout(
        jnp.asarray(local_row), jnp.ones(cap, bool), rows_cap=rows_cap,
        blk=BLK, tile_rows=TILE)
    al = lambda x: jnp.zeros((n_pad + 1,) + x.shape[1:], x.dtype)\
        .at[slot].set(x)[:-1]
    out = kkernel.fused_mttkrp_nmode_tiled(
        al(jnp.asarray(vals)), tuple(al(jnp.asarray(r)) for r in rows_list),
        al(jnp.asarray(local_row % TILE)), tile_of_block,
        rows_cap=rows_cap, blk=BLK, tile_rows=TILE, interpret=True)
    ref = kref.fused_mttkrp_ref(jnp.asarray(vals),
                                [jnp.asarray(r) for r in rows_list],
                                jnp.asarray(local_row), rows_cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_tiled_with_trailing_invalid_matches_materialized():
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 250, 256, 0, seed=3)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.arange(len(val)) < len(val) - 7
    val = np.where(valid, val, 0.0).astype(np.float32)
    a = _device_step(idx, val, valid, factors, 0, rows_cap,
                     "pallas_fused_tiled")
    b = _device_step(idx, val, valid, factors, 0, rows_cap, "pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# bf16: tolerance bounds + traffic accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nmodes", [3, 5])
def test_bf16_tolerance_bounds(nmodes):
    """bf16 gathers round each factor row to 8 mantissa bits; the fp32
    accumulate keeps the error at the per-element rounding level: the
    Hadamard product of N−1 bf16 rows carries ≲ (N−1)·2⁻⁸ relative
    error, far below any fp32-path mismatch but clearly above exact."""
    shape = SHAPES[nmodes]
    idx, val, factors = _sorted_case(shape, 200, 128, 0, seed=nmodes)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    exact = np.asarray(_device_step(idx, val, valid, factors, 0, rows_cap,
                                    "pallas_fused"))
    got = np.asarray(_device_step(idx, val, valid, factors, 0, rows_cap,
                                  "pallas_fused_bf16"))
    assert got.dtype == np.float32          # accumulate stays fp32
    rel = np.abs(got - exact).max() / np.abs(exact).max()
    assert rel < 4 * (nmodes - 1) * 2.0 ** -8, rel
    assert rel > 0.0                        # it really gathered bf16


def test_bf16_composes_with_tiling():
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 150, 256, 0, seed=7)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    a = _device_step(idx, val, valid, factors, 0, rows_cap,
                     "pallas_fused", gather_dtype="bfloat16")
    b = _device_step(idx, val, valid, factors, 0, rows_cap,
                     "pallas_fused_tiled", gather_dtype="bfloat16")
    c = _device_step(idx, val, valid, factors, 0, rows_cap,
                     "pallas_fused_bf16")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_bf16_halves_gather_budget_term():
    full = kkernel.fused_vmem_bytes(4, 1024, 512, 128)
    bf16 = kkernel.fused_vmem_bytes(4, 1024, 512, 128, gather_itemsize=2)
    gather_term = 4 * 512 * 1024 * 4
    assert full - bf16 == gather_term // 2
    # tiled working set is one slab wide, independent of padded rank
    assert kkernel.fused_tiled_vmem_bytes(4, 1024, 512, 128) == \
        kkernel.fused_tiled_vmem_bytes(4, 1 << 20, 512, 128) == \
        kkernel.fused_vmem_bytes(4, kkernel.RANK_SLAB, 512, 128)


def test_unknown_gather_dtype_rejected():
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 64, 128, 0, seed=1)
    with pytest.raises(ValueError, match="gather_dtype"):
        _device_step(idx, val, np.ones(len(val), bool), factors, 0, 2 * TILE,
                     "pallas_fused", gather_dtype="float16")


# ---------------------------------------------------------------------------
# Hypothesis sweep: tiled ≡ untiled on small ranks
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    nnz=st.integers(1, 250),
    rank=st.integers(8, 200),
    nmodes=st.sampled_from([3, 4, 5]),
)
def test_tiled_equals_untiled_property(seed, nnz, rank, nmodes):
    shape = SHAPES[nmodes]
    idx, val, factors = _sorted_case(shape, nnz, rank, 0, seed=seed)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    tiled = _device_step(idx, val, valid, factors, 0, rows_cap,
                         "pallas_fused_tiled")
    untiled = _device_step(idx, val, valid, factors, 0, rows_cap,
                           "pallas_fused")
    assert tiled.shape == untiled.shape == (rows_cap, rank)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(untiled))


# ---------------------------------------------------------------------------
# Dispatch: the large-R cliff onto the materialized path is gone
# ---------------------------------------------------------------------------

def test_large_rank_no_longer_falls_back_to_materialized():
    # Configurations the PR-2 static rule sent to `pallas` purely on
    # VMEM grounds (full-rank fused working set > budget): the slabbed
    # working set fits, so the dispatch keeps a fused variant.
    for nmodes, rank, blk in [(5, 8192, 512), (5, 2048, 2048),
                              (4, 4096, 2048)]:
        assert not kops.fused_fits_vmem(nmodes, rank, blk, 128)
        got = kops.select_backend("auto", nmodes=nmodes, rank=rank,
                                  blk=blk, tile_rows=128)
        assert got == "pallas_fused_tiled", (nmodes, rank, blk)


def test_auto_prefers_untiled_fused_when_it_fits():
    # No regression at moderate rank: untiled fused still wins (no slab
    # re-streaming of the scalar streams).
    assert kops.select_backend("auto", nmodes=4, rank=256) == "pallas_fused"


def test_min_mxu_rank_threads_the_mxu_multiple():
    # One constant: MXU lane width 128, guard = 128/16 = 8, slab = 128.
    assert kops.MXU_RANK_MULTIPLE == kkernel.MXU_RANK_MULTIPLE \
        == kkernel.RANK_SLAB
    assert kops.MIN_MXU_RANK == kops.MXU_RANK_MULTIPLE // 16
    assert kops.padded_rank(1) == kops.MXU_RANK_MULTIPLE
    assert kops.select_backend(
        "auto", nmodes=3, rank=kops.MIN_MXU_RANK - 1) == "ref"


# ---------------------------------------------------------------------------
# Runtime threading: ModePlan.rank_slabs + DynasorRuntime.gather_dtype
# ---------------------------------------------------------------------------

def _tiled_loving_table(rank_knots=(128, 512)):
    entries = [
        tune.CalibrationEntry(
            nmodes=3, rank=r, blk=32, tile_rows=8, density=1.0,
            timings_s={"pallas_fused_tiled": 0.001, "pallas": 1.0,
                       "ref": 1.0})
        for r in rank_knots
    ]
    return tune.CalibrationTable(entries=entries)


def test_plan_modes_records_rank_slabs():
    t = random_sparse_tensor((40, 30, 20), 400, seed=3,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64),
                      cache_bytes=1 << 20)
    plans = tune.plan_modes(_tiled_loving_table(), ft, 512)
    assert plans is not None
    for p in plans:
        assert p.backend == "pallas_fused_tiled"
        assert p.rank_slabs == kops.padded_rank(512) // kops.MXU_RANK_MULTIPLE
    # non-tiled plans carry the trivial single slab
    plans16 = tune.plan_modes(tune.calibrate(
        measure=lambda b, p: {"segsum": 0.1}.get(b, 1.0), quick=True), ft, 16)
    assert plans16 is not None and all(p.rank_slabs == 1 for p in plans16)


def test_runtime_threads_gather_dtype():
    t = random_sparse_tensor((40, 30, 20), 400, seed=3,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64),
                      cache_bytes=1 << 20)
    rt, _ = dist.prepare_runtime(ft, rank=16, tile_rows=8)
    assert rt.gather_dtype == "float32"      # default unchanged
    rt_bf, _ = dist.prepare_runtime(ft, rank=16, tile_rows=8,
                                    gather_dtype="bfloat16")
    assert rt_bf.gather_dtype == "bfloat16"
    # back-compat direct construction without the new fields
    rt_old = dist.DynasorRuntime(
        num_workers=1, nmodes=3, rank=8, rows_cap=(8, 8, 8),
        i_pad=(8, 8, 8), nnz_cap=8, bucket_cap=8, shape=(8, 8, 8))
    assert rt_old.gather_dtype == "float32"
    assert rt_old.plan_for(0, "pallas_fused_tiled").backend == \
        "pallas_fused_tiled"
    # typos fail at construction, not silently mid-decomposition
    with pytest.raises(ValueError, match="gather_dtype"):
        dist.prepare_runtime(ft, rank=16, tile_rows=8, gather_dtype="bf16")


def test_plan_for_rederives_rank_slabs():
    """rank_slabs always reflects the *resolved* backend."""
    tuned = dist.DynasorRuntime(
        num_workers=1, nmodes=3, rank=512, rows_cap=(8, 8, 8),
        i_pad=(8, 8, 8), nnz_cap=8, bucket_cap=8, shape=(8, 8, 8),
        mode_plans=(dist.ModePlan("pallas_fused_tiled", 32, 8, 4),) * 3)
    # explicit non-tiled override must not carry the tuned plan's slabs
    assert tuned.plan_for(0, "pallas").rank_slabs == 1
    assert tuned.plan_for(0, "auto").rank_slabs == 4
    # explicit tiled backend on an untuned runtime gets the real count
    untuned = dist.DynasorRuntime(
        num_workers=1, nmodes=3, rank=512, rows_cap=(8, 8, 8),
        i_pad=(8, 8, 8), nnz_cap=8, bucket_cap=8, shape=(8, 8, 8))
    assert untuned.plan_for(0, "pallas_fused_tiled").rank_slabs == \
        kops.padded_rank(512) // kops.MXU_RANK_MULTIPLE == 4
    assert untuned.plan_for(0, "pallas_fused").rank_slabs == 1


def test_mttkrp_fused_wrapper_gather_dtype():
    shape, rank = (14, 11, 9), 128
    t = random_sparse_tensor(shape, 150, seed=9)
    rng = np.random.default_rng(9)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    exact = mttkrp_fused(jnp.asarray(t.indices), jnp.asarray(t.values),
                         factors, 0, shape[0], blk=BLK, tile_rows=TILE,
                         backend="pallas_fused_tiled")
    approx = mttkrp_fused(jnp.asarray(t.indices), jnp.asarray(t.values),
                          factors, 0, shape[0], blk=BLK, tile_rows=TILE,
                          backend="pallas_fused_tiled",
                          gather_dtype="bfloat16")
    ref = mttkrp_elementwise_ref(t.indices, t.values, factors, 0)
    assert _rel_err(exact, ref) < 1e-4
    assert 0.0 < _rel_err(approx, np.asarray(exact)) < 0.05
