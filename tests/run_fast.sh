#!/usr/bin/env bash
# Fast verification tier: full suite minus `slow`/`perf` marks.
# Target: < 120 s wall on a 1-core CPU container.
#
#   tests/run_fast.sh            # fast tier
#   tests/run_fast.sh -x -k mttkrp   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m pytest -q -m "not slow and not perf" "$@"
