"""N-mode fused MTTKRP kernel + backend dispatch (golden tests).

Tentpole coverage: ``fused_mttkrp_nmode`` vs. the literal elementwise
reference on 2-/3-/4-/5-mode tensors across *all* output modes, the edge
cases of the blocked layout (empty shards, all-padding blocks, unaligned
rank, single output tile), and the ``auto`` dispatch decisions.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mttkrp import mttkrp_elementwise_ref, mttkrp_fused
from repro.core.tensors import random_sparse_tensor
from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import ops as kops
from repro.kernels.mttkrp import ref as kref

BLK, TILE = 32, 8


def _sorted_case(shape, nnz, rank, mode, seed=0):
    """Random COO stream sorted by the output mode + random factors."""
    rng = np.random.default_rng(seed)
    t = random_sparse_tensor(shape, nnz, seed=seed)
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    return idx, val, factors


def _device_step(idx, val, valid, factors, mode, rows_cap, backend):
    return kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=mode, rows_cap=rows_cap, row_offset=0, blk=BLK, tile_rows=TILE,
        interpret=True, backend=backend)


def _rel_err(got, ref):
    return np.abs(np.asarray(got) - ref).max() / (np.abs(ref).max() + 1e-9)


# ---------------------------------------------------------------------------
# Golden: fused N-mode == elementwise reference, all modes, orders 2..5
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (30, 4),                 # 2-mode: single input-factor operand
    (20, 16, 12),            # 3-mode (the old special case)
    (12, 10, 8, 6),          # 4-mode
    (8, 7, 6, 5, 4),         # 5-mode
])
def test_fused_nmode_matches_elementwise_ref_all_modes(shape):
    nnz, rank = 180, 16
    for mode in range(len(shape)):
        idx, val, factors = _sorted_case(shape, nnz, rank, mode, seed=mode)
        rows_cap = -(-shape[mode] // TILE) * TILE
        valid = np.ones(len(val), bool)
        ref = mttkrp_elementwise_ref(idx, val, factors, mode,
                                     out_rows=rows_cap)
        got = _device_step(idx, val, valid, factors, mode, rows_cap,
                           "pallas_fused")
        assert _rel_err(got, ref) < 1e-4, (shape, mode)


@pytest.mark.parametrize("shape", [(20, 16, 12), (12, 10, 8, 6)])
def test_fused_agrees_with_materialized_pallas(shape):
    idx, val, factors = _sorted_case(shape, 250, 24, 0, seed=3)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.arange(len(val)) < len(val) - 7    # trailing invalid
    val = np.where(valid, val, 0.0).astype(np.float32)
    a = _device_step(idx, val, valid, factors, 0, rows_cap, "pallas_fused")
    b = _device_step(idx, val, valid, factors, 0, rows_cap, "pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_fused_kernel_direct_vs_fused_ref():
    """Kernel-level: hand-built aligned layout, 4-mode, vs the jnp oracle."""
    rng = np.random.default_rng(5)
    cap, rows_cap, rank, n_in = 200, 4 * TILE, 128, 3
    local_row = np.sort(rng.integers(0, rows_cap, cap)).astype(np.int32)
    valid = jnp.ones(cap, bool)
    vals = rng.standard_normal(cap).astype(np.float32)
    rows_list = [rng.standard_normal((cap, rank)).astype(np.float32)
                 for _ in range(n_in)]

    n_pad = kops.n_pad_for(cap, rows_cap, BLK, TILE)
    slot, tile_of_block = kops.build_block_layout(
        jnp.asarray(local_row), valid, rows_cap=rows_cap, blk=BLK,
        tile_rows=TILE)
    al = lambda x: jnp.zeros((n_pad + 1,) + x.shape[1:], x.dtype)\
        .at[slot].set(x)[:-1]
    out = kkernel.fused_mttkrp_nmode(
        al(jnp.asarray(vals)), tuple(al(jnp.asarray(r)) for r in rows_list),
        al(jnp.asarray(local_row % TILE)), tile_of_block,
        rows_cap=rows_cap, blk=BLK, tile_rows=TILE, interpret=True)
    ref = kref.fused_mttkrp_ref(jnp.asarray(vals),
                                [jnp.asarray(r) for r in rows_list],
                                jnp.asarray(local_row), rows_cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_3mode_wrapper_back_compat():
    """fused_mttkrp_3mode (kept for callers of the old API) == nmode."""
    rng = np.random.default_rng(7)
    cap, rows_cap, rank = 100, 2 * TILE, 128
    local_row = np.sort(rng.integers(0, rows_cap, cap)).astype(np.int32)
    vals = rng.standard_normal(cap).astype(np.float32)
    ra, rb = (rng.standard_normal((cap, rank)).astype(np.float32)
              for _ in range(2))
    n_pad = kops.n_pad_for(cap, rows_cap, BLK, TILE)
    slot, tile_of_block = kops.build_block_layout(
        jnp.asarray(local_row), jnp.ones(cap, bool), rows_cap=rows_cap,
        blk=BLK, tile_rows=TILE)
    al = lambda x: jnp.zeros((n_pad + 1,) + x.shape[1:], x.dtype)\
        .at[slot].set(x)[:-1]
    args = (al(jnp.asarray(vals)), al(jnp.asarray(ra)), al(jnp.asarray(rb)),
            al(jnp.asarray(local_row % TILE)), tile_of_block)
    kw = dict(rows_cap=rows_cap, blk=BLK, tile_rows=TILE, interpret=True)
    out3 = kkernel.fused_mttkrp_3mode(*args, **kw)
    outn = kkernel.fused_mttkrp_nmode(args[0], (args[1], args[2]), args[3],
                                      args[4], **kw)
    np.testing.assert_array_equal(np.asarray(out3), np.asarray(outn))


def test_mttkrp_fused_wrapper_matches_ref():
    """core.mttkrp.mttkrp_fused (sort + dispatch) == elementwise ref."""
    shape, rank = (14, 11, 9, 7), 16
    t = random_sparse_tensor(shape, 150, seed=9)
    rng = np.random.default_rng(9)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    for mode in range(len(shape)):
        ref = mttkrp_elementwise_ref(t.indices, t.values, factors, mode)
        got = mttkrp_fused(jnp.asarray(t.indices), jnp.asarray(t.values),
                           factors, mode, shape[mode], blk=BLK,
                           tile_rows=TILE)
        assert _rel_err(got, ref) < 1e-4, mode


# ---------------------------------------------------------------------------
# Edge cases
# ---------------------------------------------------------------------------

def test_empty_shard_all_invalid_gives_zeros():
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 64, 16, 0, seed=1)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.zeros(len(val), bool)
    val = np.zeros_like(val)
    out = _device_step(idx, val, valid, factors, 0, rows_cap, "pallas_fused")
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_all_padding_blocks_between_sparse_tiles():
    """Nonzeros touch only the first and last tile — middle tiles stay 0."""
    shape = (8 * TILE, 10, 6, 5)
    rng = np.random.default_rng(2)
    cap, rank = 96, 16
    rows = np.concatenate([rng.integers(0, TILE, cap // 2),
                           rng.integers(7 * TILE, 8 * TILE, cap // 2)])
    rows.sort()
    idx = np.stack([rows] + [rng.integers(0, d, cap) for d in shape[1:]],
                   axis=1).astype(np.int32)
    val = rng.standard_normal(cap).astype(np.float32)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    valid = np.ones(cap, bool)
    ref = mttkrp_elementwise_ref(idx, val, factors, 0, out_rows=shape[0])
    got = _device_step(idx, val, valid, factors, 0, shape[0], "pallas_fused")
    assert _rel_err(got, ref) < 1e-4
    np.testing.assert_array_equal(np.asarray(got)[TILE:7 * TILE], 0.0)


@pytest.mark.parametrize("rank", [9, 24, 130])
def test_rank_not_multiple_of_128(rank):
    """Fused path pads rank to the MXU lane width and slices back."""
    shape = (16, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 120, rank, 0, seed=4)
    rows_cap = TILE * 2
    valid = np.ones(len(val), bool)
    ref = mttkrp_elementwise_ref(idx, val, factors, 0, out_rows=rows_cap)
    got = _device_step(idx, val, valid, factors, 0, rows_cap, "pallas_fused")
    assert got.shape == (rows_cap, rank)
    assert _rel_err(got, ref) < 1e-4


def test_single_output_tile():
    shape = (TILE, 9, 7, 5, 3)          # rows_cap == tile_rows, 5-mode
    idx, val, factors = _sorted_case(shape, 100, 16, 0, seed=6)
    valid = np.ones(len(val), bool)
    ref = mttkrp_elementwise_ref(idx, val, factors, 0, out_rows=TILE)
    got = _device_step(idx, val, valid, factors, 0, TILE, "pallas_fused")
    assert _rel_err(got, ref) < 1e-4


# ---------------------------------------------------------------------------
# Dispatch layer
# ---------------------------------------------------------------------------

def test_auto_picks_fused_when_eligible():
    assert kops.select_backend("auto", nmodes=3, rank=64) == "pallas_fused"
    assert kops.select_backend("auto", nmodes=4, rank=128) == "pallas_fused"
    assert kops.select_backend("auto", nmodes=5, rank=32) == "pallas_fused"


def test_auto_falls_back_on_tiny_rank():
    # rank < 8: one-hot MXU matmul would be ≥ 16x padding — segment-sum ref.
    assert kops.select_backend("auto", nmodes=3, rank=4) == "ref"
    assert kops.select_backend("auto", nmodes=5, rank=7) == "ref"


def test_auto_degrades_to_tiled_then_materialized_on_vmem_pressure():
    # Budget below the full-rank gathered working set, but above one
    # rank slab: the rank-tiled fused kernel keeps the traffic win.
    tight = kkernel.fused_vmem_bytes(3, 256, 512, 128) - 1
    assert kkernel.fused_tiled_vmem_bytes(3, 256, 512, 128) < tight
    assert kops.select_backend("auto", nmodes=4, rank=256,
                               vmem_budget=tight) == "pallas_fused_tiled"
    # Same rank, fewer input modes -> the untiled kernel fits again.
    assert kops.select_backend(
        "auto", nmodes=2, rank=256, vmem_budget=tight) == "pallas_fused"
    # Budget below even one slab -> the HBM-materialized path remains
    # the last resort.
    tiny = kkernel.fused_tiled_vmem_bytes(3, 256, 512, 128) - 1
    assert kops.select_backend("auto", nmodes=4, rank=256,
                               vmem_budget=tiny) == "pallas"


def test_explicit_backends_pass_through():
    for b in kops.BACKENDS:
        assert kops.select_backend(b, nmodes=4, rank=4) == b


def test_unknown_backend_rejected():
    # A typo'd backend must not silently fall through to the materialized
    # path ("segsum" lives in core.distributed, not here).
    for b in ("palas_fused", "segsum", ""):
        with pytest.raises(ValueError, match="unknown MTTKRP backend"):
            kops.select_backend(b, nmodes=4, rank=16)


def test_unknown_backend_rejected_at_distributed_layer():
    # ...and must not silently fall through to segsum one layer up either.
    from repro.core import distributed as dist
    rt = dist.DynasorRuntime(
        num_workers=1, nmodes=3, rank=8, rows_cap=(8, 8, 8),
        i_pad=(8, 8, 8), nnz_cap=8, bucket_cap=8, shape=(8, 8, 8))
    with pytest.raises(ValueError, match="unknown MTTKRP backend"):
        dist.device_mttkrp(jnp.zeros((8, 3), jnp.int32), jnp.zeros(8),
                           jnp.ones(8, bool), [jnp.ones((8, 8))] * 3,
                           0, rt, "pallas_fussed")


def test_auto_end_to_end_matches_ref():
    """backend='auto' through mttkrp_device_step on an eligible 4-mode case."""
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 150, 16, 2, seed=8)
    rows_cap = -(-shape[2] // TILE) * TILE
    valid = np.ones(len(val), bool)
    ref = mttkrp_elementwise_ref(idx, val, factors, 2, out_rows=rows_cap)
    got = _device_step(idx, val, valid, factors, 2, rows_cap, "auto")
    assert _rel_err(got, ref) < 1e-4
