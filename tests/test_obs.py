"""repro.obs — span tracing, counter registry, baseline gate (PR-7).

Coverage per the issue checklist:
  * tracer/counter invariants: spans nest/close correctly under
    arbitrary interleavings (hypothesis program sweep), per-span counter
    deltas, Chrome-trace export validates against the schema checker
    (and the checker catches corrupt traces);
  * ``StreamStats → CounterRegistry`` round-trip preserves the counted
    byte ordering the struct guarantees (``scheduled >= distinct`` and
    ``scheduled >= pipelined``) — both on synthetic stats and on a real
    chunked executor run;
  * the no-op tracer records nothing and adds zero counters;
  * emitters: ``select_backend`` → ``dispatch.backend{...}``,
    ``plan_residency`` → ``planner.*``, ``record_remap_exchange``
    arithmetic;
  * the baseline gate's diff demonstrably fails on a perturbed counter
    and on a changed dispatch decision, and its counted filter excludes
    host-dependent (``execution.*``, ``*_s``) metrics.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.obs import baseline as obaseline
from repro.obs import counters as ocnt
from repro.obs import tracer as otr


# ---------------------------------------------------------------------------
# CounterRegistry
# ---------------------------------------------------------------------------

def test_counter_key_round_trip():
    key = ocnt.counter_key("dispatch.backend",
                           {"source": "static", "backend": "ref"})
    assert key == "dispatch.backend{backend=ref,source=static}"
    name, labels = ocnt.split_key(key)
    assert name == "dispatch.backend"
    assert labels == {"backend": "ref", "source": "static"}
    assert ocnt.split_key("planner.plans") == ("planner.plans", {})


def test_registry_add_get_total_reset():
    reg = ocnt.CounterRegistry()
    reg.add("oocore.chunks", 3)
    reg.add("oocore.chunks", 2)
    reg.add("oocore.dma.scheduled_bytes", 100)
    assert reg.get("oocore.chunks") == 5
    assert reg.total("oocore.") == 105
    assert reg.total("oocore.dma.") == 100
    snap = reg.snapshot()
    assert snap == {"oocore.chunks": 5, "oocore.dma.scheduled_bytes": 100}
    reg.reset()
    assert len(reg) == 0
    snap["oocore.chunks"] = 99      # snapshot is a copy
    assert reg.get("oocore.chunks") == 0


def test_registry_rejects_undocumented_names():
    reg = ocnt.CounterRegistry()
    with pytest.raises(ValueError, match="NAMESPACES"):
        reg.add("oocore.dma.typo_bytes", 1)


def test_namespaces_sorted_literal():
    assert list(ocnt.NAMESPACES) == sorted(ocnt.NAMESPACES)
    assert len(set(ocnt.NAMESPACES)) == len(ocnt.NAMESPACES)


def test_use_registry_scopes_and_restores():
    before = ocnt.get_registry()
    with ocnt.use_registry() as reg:
        assert ocnt.get_registry() is reg
        ocnt.add("planner.plans")
        assert reg.get("planner.plans") == 1
    assert ocnt.get_registry() is before
    assert before.get("planner.plans", 0) != 1 or before is not reg


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_spans_nest_and_record():
    tracer = otr.Tracer()
    with tracer.span("sweep", sweep=0):
        with tracer.span("mode", mode=2):
            with tracer.span("mttkrp"):
                pass
        with tracer.span("mode", mode=3):
            pass
    assert tracer.open_spans == 0
    names = [r.name for r in tracer.records]       # closed-order
    assert names == ["mttkrp", "mode", "mode", "sweep"]
    by_sid = {r.sid: r for r in tracer.records}
    sweep = next(r for r in tracer.records if r.name == "sweep")
    assert sweep.parent == -1 and sweep.depth == 0
    for r in tracer.records:
        if r.name == "mode":
            assert by_sid[r.parent].name == "sweep" and r.depth == 1
        if r.name == "mttkrp":
            assert by_sid[r.parent].name == "mode" and r.depth == 2
        assert r.t1 >= r.t0


def test_span_closes_on_exception():
    tracer = otr.Tracer()
    with pytest.raises(RuntimeError, match="boom"):
        with tracer.span("outer"):
            with tracer.span("inner"):
                raise RuntimeError("boom")
    assert tracer.open_spans == 0
    assert [r.name for r in tracer.records] == ["inner", "outer"]


def test_span_counter_deltas():
    with ocnt.use_registry():
        tracer = otr.Tracer()
        with tracer.span("outer"):
            ocnt.add("planner.plans")
            with tracer.span("inner"):
                ocnt.add("oocore.chunks", 4)
        inner, outer = tracer.records
        assert inner.counters == {"oocore.chunks": 4}
        assert outer.counters == {"planner.plans": 1, "oocore.chunks": 4}


def test_export_with_open_span_raises():
    tracer = otr.Tracer()
    cm = tracer.span("dangling")
    cm.__enter__()
    with pytest.raises(RuntimeError, match="open span"):
        tracer.chrome_trace()
    with pytest.raises(RuntimeError, match="open span"):
        tracer.reset()
    cm.__exit__(None, None, None)
    tracer.chrome_trace()   # fine now


def test_exit_without_enter_raises():
    tracer = otr.Tracer()
    with pytest.raises(RuntimeError, match="no open span"):
        tracer._exit()


def test_chrome_trace_schema_and_roundtrip(tmp_path):
    tracer = otr.Tracer()
    with tracer.span("sweep", sweep=0):
        with tracer.span("mode", mode=1):
            pass
    path = tracer.write_chrome_trace(str(tmp_path / "t.json"),
                                     meta={"k": "v"})
    with open(path) as f:
        trace = json.load(f)
    assert otr.validate_chrome_trace(
        trace, expect_names=["sweep", "mode"]) == []
    assert trace["otherData"]["k"] == "v"
    ev = {e["name"]: e for e in trace["traceEvents"]}
    assert ev["mode"]["args"]["mode"] == 1
    # child is contained in parent
    assert ev["mode"]["ts"] >= ev["sweep"]["ts"]
    assert (ev["mode"]["ts"] + ev["mode"]["dur"]
            <= ev["sweep"]["ts"] + ev["sweep"]["dur"] + 1e-3)


def test_validator_rejects_bad_traces():
    assert otr.validate_chrome_trace([]) != []
    assert otr.validate_chrome_trace({"traceEvents": [{}]}) != []
    bad_ph = {"traceEvents": [dict(name="a", cat="c", ph="B", ts=0, dur=1,
                                   pid=1, tid=0, args={})]}
    assert any("ph" in e for e in otr.validate_chrome_trace(bad_ph))
    # overlapping (non-nested) events on one timeline
    overlap = {"traceEvents": [
        dict(name="a", cat="c", ph="X", ts=0.0, dur=10.0, pid=1, tid=0,
             args={}),
        dict(name="b", cat="c", ph="X", ts=5.0, dur=10.0, pid=1, tid=0,
             args={}),
    ]}
    assert any("overlaps" in e for e in otr.validate_chrome_trace(overlap))
    missing = {"traceEvents": []}
    assert any("sweep" in e for e in otr.validate_chrome_trace(
        missing, expect_names=["sweep"]))


def test_render_tree():
    with ocnt.use_registry():
        tracer = otr.Tracer()
        with tracer.span("sweep", sweep=0):
            with tracer.span("mode", mode=1):
                ocnt.add("planner.plans")
        text = tracer.render()
    lines = text.splitlines()
    assert lines[0].startswith("sweep")
    assert any(l.strip().startswith("mode") for l in lines)
    assert any("planner.plans" in l for l in lines)


def test_use_tracer_scopes_process_default():
    assert otr.get_tracer() is otr.NULL
    with otr.use_tracer() as tracer:
        assert otr.get_tracer() is tracer
        assert tracer.enabled
    assert otr.get_tracer() is otr.NULL
    otr.set_tracer(None)
    assert otr.get_tracer() is otr.NULL


def test_null_tracer_is_inert():
    with ocnt.use_registry() as reg:
        null = otr.NULL
        assert not null.enabled
        with null.span("sweep", sweep=0):
            with null.span("mode"):
                pass
        assert null.records == ()
        assert null.open_spans == 0
        assert len(reg) == 0        # zero counters from the no-op path
        null.reset()


# hypothesis: arbitrary well-formed push/pop interleavings keep the
# recorded forest consistent (parents, depths, containment) and export
# a schema-valid Chrome trace.
@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=0, max_size=40))
def test_span_nesting_under_arbitrary_interleavings(program):
    tracer = otr.Tracer(attach_counters=False)
    stack = []
    sid_depth = {}
    for op in program:
        if op == 0 and len(stack) < 6:          # push
            cm = tracer.span(f"s{len(tracer.records)}_{len(stack)}")
            cm.__enter__()
            stack.append(cm)
        elif stack:                              # pop
            stack.pop().__exit__(None, None, None)
    while stack:
        stack.pop().__exit__(None, None, None)
    assert tracer.open_spans == 0
    by_sid = {r.sid: r for r in tracer.records}
    for r in tracer.records:
        if r.parent == -1:
            assert r.depth == 0
        else:
            p = by_sid[r.parent]
            assert r.depth == p.depth + 1
            assert p.t0 <= r.t0 and r.t1 <= p.t1       # containment
    assert otr.validate_chrome_trace(tracer.chrome_trace()) == []


# ---------------------------------------------------------------------------
# Absorbers: StreamStats / remap exchange
# ---------------------------------------------------------------------------

class _FakeStats:
    """Duck-typed StreamStats (record_stream_stats never imports oocore)."""

    def __init__(self, s, d, p, i, backend="pallas_fused_gather_stream",
                 chunks=3):
        self.backend, self.chunks = backend, chunks
        self.scheduled_tile_bytes = s
        self.distinct_tile_bytes = d
        self.pipelined_tile_bytes = p
        self.index_stream_bytes = i


@settings(max_examples=50, deadline=None)
@given(
    scheduled=st.integers(0, 10**12),
    d_frac=st.floats(0.0, 1.0),
    p_frac=st.floats(0.0, 1.0),
    index=st.integers(0, 10**9),
)
def test_stream_stats_round_trip_preserves_ordering(scheduled, d_frac,
                                                    p_frac, index):
    # StreamStats' contract: distinct <= scheduled and
    # pipelined <= scheduled (pipelined may exceed distinct — chunk
    # boundaries re-fetch tiles the schedule only references once).
    distinct = int(scheduled * d_frac)
    pipelined = int(scheduled * p_frac)
    reg = ocnt.CounterRegistry()
    ocnt.record_stream_stats(
        _FakeStats(scheduled, distinct, pipelined, index), registry=reg)
    s = reg.get("oocore.dma.scheduled_bytes")
    d = reg.get("oocore.dma.distinct_bytes")
    p = reg.get("oocore.dma.pipelined_bytes")
    assert (s, d, p) == (scheduled, distinct, pipelined)
    assert d <= s and p <= s
    assert reg.get("oocore.dma.index_stream_bytes") == index
    assert reg.get("oocore.chunks") == 3
    assert reg.get("oocore.mode_steps",
                   backend="pallas_fused_gather_stream") == 1


def test_executor_emits_stream_stats():
    import jax.numpy as jnp

    from repro.core.tensors import random_sparse_tensor
    from repro.oocore.executor import mttkrp_out_of_core

    shape, mode, rank = (20, 300, 170), 0, 32
    rng = np.random.default_rng(0)
    t = random_sparse_tensor(shape, 200, seed=0)
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    valid = np.ones(len(val), bool)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    with ocnt.use_registry() as reg:
        _, stats = mttkrp_out_of_core(
            idx, val, valid, factors, mode=mode, rows_cap=24, blk=8,
            tile_rows=8, max_chunk_bytes=1200)
    assert stats.chunks >= 2
    assert reg.get("oocore.chunks") == stats.chunks
    assert reg.get("oocore.dma.scheduled_bytes") \
        == stats.scheduled_tile_bytes
    assert reg.get("oocore.dma.distinct_bytes") == stats.distinct_tile_bytes
    assert reg.get("oocore.dma.pipelined_bytes") \
        == stats.pipelined_tile_bytes
    assert stats.distinct_tile_bytes <= stats.scheduled_tile_bytes
    assert stats.pipelined_tile_bytes <= stats.scheduled_tile_bytes


def test_record_remap_exchange_arithmetic():
    reg = ocnt.CounterRegistry()
    caps, D, nmodes = [10, 7, 12], 4, 3
    ocnt.record_remap_exchange(caps, D, nmodes, registry=reg)
    per_pair = D * D * (4 * nmodes + 4)
    for n, cap in enumerate(caps):
        assert reg.get("remap.a2a.bytes", transition=n) == cap * per_pair
    assert reg.get("remap.a2a.uniform_bytes") \
        == len(caps) * max(caps) * per_pair
    assert reg.get("remap.transitions") == len(caps)
    # per-transition sizing never exceeds the uniform-cap allocation
    total = sum(reg.get("remap.a2a.bytes", transition=n)
                for n in range(len(caps)))
    assert total <= reg.get("remap.a2a.uniform_bytes")
    # uniform_cap=True sizes every transition to the max
    reg2 = ocnt.CounterRegistry()
    ocnt.record_remap_exchange(caps, D, nmodes, uniform_cap=True,
                               registry=reg2)
    for n in range(len(caps)):
        assert reg2.get("remap.a2a.bytes", transition=n) \
            == max(caps) * per_pair


# ---------------------------------------------------------------------------
# Emitters in the dispatch/planner layer
# ---------------------------------------------------------------------------

def test_select_backend_emits_dispatch_decisions():
    from repro.kernels.mttkrp import ops as kops

    with ocnt.use_registry() as reg:
        out = kops.select_backend("pallas_fused", nmodes=3, rank=128)
        assert out == "pallas_fused"
        assert reg.get("dispatch.backend", backend="pallas_fused",
                       source="explicit") == 1
        chosen = kops.select_backend("auto", nmodes=3, rank=128,
                                     factor_rows=(64, 64))
        assert reg.get("dispatch.backend", backend=chosen,
                       source="static") == 1
        # the static path went through the planner
        assert reg.get("planner.plans") >= 1
        assert reg.get("planner.vmem.plan_bytes", backend=chosen) > 0


def test_plan_residency_emits_planner_counters():
    from repro.oocore import planner

    with ocnt.use_registry() as reg:
        plan = planner.plan_residency(nmodes=3, rank=128,
                                      factor_rows=(64, 64))
        assert reg.get("planner.plans") == 1
        assert reg.get("planner.vmem.plan_bytes", backend=plan.backend) \
            == plan.vmem_bytes


# ---------------------------------------------------------------------------
# Baseline gate
# ---------------------------------------------------------------------------

def test_counted_filter_excludes_host_dependent():
    assert obaseline._is_counted("oocore.dma.scheduled_bytes")
    assert obaseline._is_counted(
        "dispatch.backend{backend=ref,source=static}")
    assert obaseline._is_counted("remap.a2a.bytes{transition=0}")
    assert not obaseline._is_counted("execution.fallback{platform=cpu}")
    assert not obaseline._is_counted("execution.resolve{mode=auto}")
    assert not obaseline._is_counted("tune.measure_s{backend=ref}")
    assert not obaseline._is_counted("serve.tokens")
    assert not obaseline._is_counted("dryrun.lower_s{arch=x}")


def test_baseline_diff_catches_perturbations():
    base = {"counters": {
        "dispatch.backend{backend=pallas_fused_gather,source=static}": 4,
        "oocore.dma.scheduled_bytes": 42205184,
    }}
    assert obaseline.diff(base, base) == []
    # a counted DMA byte count perturbed
    cur = json.loads(json.dumps(base))
    cur["counters"]["oocore.dma.scheduled_bytes"] += 1
    msgs = obaseline.diff(cur, base)
    assert len(msgs) == 1 and "oocore.dma.scheduled_bytes" in msgs[0]
    # a dispatch decision changed backend → old key missing + new key
    cur2 = {"counters": {
        "dispatch.backend{backend=pallas_fused,source=static}": 4,
        "oocore.dma.scheduled_bytes": 42205184,
    }}
    msgs2 = obaseline.diff(cur2, base)
    assert any(m.startswith("missing:") for m in msgs2)
    assert any(m.startswith("new:") for m in msgs2)


def test_baseline_artifact_is_committed_and_sane():
    base = obaseline.load_baseline()
    assert base["meta"]["schema"] == 1
    counters = base["counters"]
    assert counters, "committed baseline has no counters"
    for key, v in counters.items():
        assert obaseline._is_counted(key), f"host-dependent key {key}"
        assert isinstance(v, int) and v >= 0
    # the instrumented workload exercised every gated subsystem
    names = {ocnt.split_key(k)[0] for k in counters}
    for want in ("cpals.sweeps", "dispatch.backend", "planner.plans",
                 "oocore.dma.scheduled_bytes", "remap.a2a.bytes"):
        assert want in names, f"baseline missing {want}"


def test_run_gate_reports_failure_on_perturbed_baseline(tmp_path,
                                                        monkeypatch):
    # run_gate with a synthetic collect(): no jax run needed to prove
    # the gate's pass/fail/update mechanics.
    current = {"meta": {"schema": 1},
               "counters": {"planner.plans": 4, "oocore.chunks": 12}}
    monkeypatch.setattr(obaseline, "collect", lambda tracer=None: current)
    path = str(tmp_path / "BASELINE_counters.json")
    status, msgs = obaseline.run_gate(path=path)
    assert status == 1 and any("no baseline" in m for m in msgs)
    status, msgs = obaseline.run_gate(path=path, update=True)
    assert status == 0
    status, msgs = obaseline.run_gate(path=path)
    assert status == 0
    perturbed = {"meta": {"schema": 1},
                 "counters": {"planner.plans": 5, "oocore.chunks": 12}}
    monkeypatch.setattr(obaseline, "collect",
                        lambda tracer=None: perturbed)
    status, msgs = obaseline.run_gate(path=path)
    assert status == 1
    assert any("planner.plans" in m for m in msgs)


# ---------------------------------------------------------------------------
# `python -m repro.obs` CLI paths (report | export | validate | baseline)
# ---------------------------------------------------------------------------

from repro.obs import __main__ as obs_main  # noqa: E402


def _cli_collect(tracer=None):
    """Tiny stand-in for the instrumented workload: real spans, fixed
    counters, no jax run."""
    tracer = tracer if tracer is not None else otr.Tracer()
    with tracer.span("sweep", sweep=0):
        with tracer.span("mode", mode=0):
            with tracer.span("mttkrp"):
                pass
    return {"counters": {"planner.plans": 4, "oocore.chunks": 12}}


def test_cli_report_prints_tree_and_counters(monkeypatch, capsys):
    monkeypatch.setattr(obaseline, "collect", _cli_collect)
    assert obs_main.main(["report"]) == 0
    out = capsys.readouterr().out
    for needle in ("sweep", "mode", "mttkrp", "counters:",
                   "planner.plans = 4", "oocore.chunks = 12"):
        assert needle in out, needle


def test_cli_export_then_validate_round_trip(tmp_path, monkeypatch, capsys):
    monkeypatch.setattr(obaseline, "collect", _cli_collect)
    out_path = str(tmp_path / "trace.json")
    assert obs_main.main(["export", "--out", out_path]) == 0
    wrote = capsys.readouterr().out
    assert "wrote" in wrote and "3 spans" in wrote
    # export uniquifies rather than clobbering: the written path is the
    # one printed, not necessarily the one requested
    written = wrote.split()[1].rstrip(":")
    assert obs_main.main(
        ["validate", written, "--expect", "sweep,mode,mttkrp"]) == 0
    assert "trace valid" in capsys.readouterr().out


def test_cli_validate_rejects_corrupt_and_missing_names(tmp_path,
                                                        monkeypatch,
                                                        capsys):
    monkeypatch.setattr(obaseline, "collect", _cli_collect)
    out_path = str(tmp_path / "trace.json")
    assert obs_main.main(["export", "--out", out_path]) == 0
    written = capsys.readouterr().out.split()[1].rstrip(":")
    # a span name the trace doesn't contain
    assert obs_main.main(
        ["validate", written, "--expect", "oocore.mode_step"]) == 1
    assert "FAIL" in capsys.readouterr().out
    # structurally corrupt JSON
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    assert obs_main.main(["validate", str(bad)]) == 1


def test_cli_baseline_update_check_perturb(tmp_path, monkeypatch, capsys):
    monkeypatch.setattr(obaseline, "collect", _cli_collect)
    path = str(tmp_path / "BASELINE_counters.json")
    assert obs_main.main(["baseline", "--update-baseline",
                          "--path", path]) == 0
    assert obs_main.main(["baseline", "--path", path]) == 0
    perturbed = {"counters": {"planner.plans": 5, "oocore.chunks": 12}}
    monkeypatch.setattr(obaseline, "collect",
                        lambda tracer=None: perturbed)
    assert obs_main.main(["baseline", "--path", path]) == 1
    assert "planner.plans" in capsys.readouterr().out
