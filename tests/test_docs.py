"""Docs subsystem consistency (tier-1 wrapper over tests/check_docs.py).

The CI `docs` job runs ``python tests/check_docs.py`` standalone (no
jax needed); these tests run the same checks inside the normal suite
and additionally assert the ast-parsed backend list matches the live
module, so the text-level parse can't drift from the real constant.
"""
import check_docs


def test_markdown_links_resolve():
    errors, checked = check_docs.check_links()
    assert not errors, "\n".join(errors)
    assert checked > 0, "link scan found no intra-repo markdown links"


def test_kernels_doc_backends_in_sync():
    assert check_docs.check_backend_sync() == []


def test_kernels_doc_lowering_column_in_sync():
    assert check_docs.check_lowering_sync() == []


def test_lowering_artifact_covers_every_backend():
    # The committed BENCH_lowering.json must have a verdict for every
    # backend the docs matrix claims a lowering status for.
    from repro.kernels.mttkrp import ops as kops
    status = check_docs.lowering_status()
    assert set(status) == set(kops.BACKENDS)
    assert all(status.values()), status


def test_ast_parse_matches_live_module():
    from repro.kernels.mttkrp import ops as kops
    assert check_docs.ops_backends() == kops.BACKENDS
