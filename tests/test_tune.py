"""repro.tune: calibration table, cost model, dispatch wiring, runtime plans.

All calibrations here use a *stubbed* measure function (deterministic
seconds as a function of backend × configuration) so the tests exercise
exactly the production table/model/dispatch code paths without timing
noise or interpret-mode Pallas runs.
"""
import json

import numpy as np
import pytest

from repro import tune
from repro.core import distributed as dist
from repro.core.flycoo import build_flycoo
from repro.core.remap import remap_capacities
from repro.core.tensors import random_sparse_tensor, zipf_4d
from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import ops as kops
from repro.tune.microbench import BACKENDS, GridPoint
from repro.tune.table import (SCHEMA_VERSION, CalibrationTable,
                              SchemaVersionError)

_OPS_BACKENDS = kops.BACKENDS
_AUTO_BACKENDS = kops.AUTO_BACKENDS


# The production traffic-model stub (CI tune-smoke uses it via
# `calibrate --stub`) doubles as the test fixture — one source for the
# pseudo-timing crossovers: segsum/ref win at small rank, the in-kernel
# gather family beats the materializing fused family, and the bf16
# compositions are fastest overall (to prove auto never picks them).
fake_measure = tune.stub_measure


@pytest.fixture()
def table():
    return tune.calibrate(measure=fake_measure, quick=True)


# ---------------------------------------------------------------------------
# Table serialization
# ---------------------------------------------------------------------------

def test_json_round_trip(table, tmp_path):
    path = table.save(str(tmp_path / "t.json"))
    loaded = tune.load_table(path)
    assert loaded.schema_version == SCHEMA_VERSION
    assert loaded.entries == table.entries
    assert loaded.meta == table.meta
    # argmin decisions survive the round trip at every key
    for key in table.shape_keys():
        n, r, b, t = key
        kw = dict(nmodes=n, rank=r, blk=b, tile_rows=t)
        assert loaded.best_backend(**kw) == table.best_backend(**kw)


def test_schema_version_rejected(table, tmp_path):
    path = table.save(str(tmp_path / "t.json"))
    obj = json.load(open(path))
    for bad in (SCHEMA_VERSION + 1, 0, None):
        obj["schema_version"] = bad
        json.dump(obj, open(path, "w"))
        with pytest.raises(SchemaVersionError, match="schema_version"):
            tune.load_table(path)


def test_find_table_skips_foreign_host(table, tmp_path):
    """A table calibrated on another machine must not steer this one."""
    foreign = CalibrationTable(
        entries=list(table.entries),
        meta=dict(table.meta, machine="tpu-v5e", jax_backend="tpu"))
    foreign.save(str(tmp_path / "foreign.json"))
    assert tune.find_table(str(tmp_path)) is None
    got = tune.find_table(str(tmp_path), match_host=False)  # explicit opt-in
    assert got is not None and got.entries == table.entries
    table.save(str(tmp_path / "local.json"))                # matching host
    assert tune.find_table(str(tmp_path)) is not None


def test_find_table_never_serves_stub_tables(table, tmp_path):
    """A `calibrate --stub` table saved to the registry path must not
    silently steer real dispatch: its pseudo-timings are a schema/CLI
    smoke artifact, loadable only by explicit path."""
    stub = CalibrationTable(entries=list(table.entries),
                            meta=dict(table.meta, stub=True))
    path = stub.save(str(tmp_path / "stubbed.json"))
    assert tune.find_table(str(tmp_path)) is None
    assert tune.find_table(str(tmp_path), match_host=False) is None
    assert tune.load_table(path).entries == table.entries  # explicit path ok
    table.save(str(tmp_path / "real.json"))
    found = tune.find_table(str(tmp_path))
    assert found is not None and not found.meta.get("stub")


def test_model_cache_invalidated_on_entry_change():
    t = _table_with_ranks((16,), lambda r: {"pallas": 0.5, "ref": 0.1})
    kw = dict(nmodes=3, rank=16, blk=32, tile_rows=8)
    assert t.best_backend(**kw) == "ref"      # builds + caches the model
    t.entries.append(tune.CalibrationEntry(
        nmodes=3, rank=16, blk=32, tile_rows=8, density=4.0,
        timings_s={"pallas": 0.01, "ref": 0.9}))
    assert t.best_backend(**kw) == "pallas"   # cache rebuilt, not stale


def test_find_table_registry(table, tmp_path):
    assert tune.find_table(str(tmp_path / "missing")) is None
    # a corrupt file and a wrong-schema file are skipped, valid one found
    (tmp_path / "a_corrupt.json").write_text("{not json")
    bad = table.save(str(tmp_path / "b_wrongschema.json"))
    obj = json.load(open(bad))
    obj["schema_version"] = 999
    json.dump(obj, open(bad, "w"))
    table.save(str(tmp_path / "c_good.json"))
    found = tune.find_table(str(tmp_path))
    assert found is not None and found.entries == table.entries


# ---------------------------------------------------------------------------
# Cost model: interpolation
# ---------------------------------------------------------------------------

def _table_with_ranks(ranks, timings_fn):
    entries = [
        tune.CalibrationEntry(nmodes=3, rank=r, blk=32, tile_rows=8,
                              density=1.0, timings_s=timings_fn(r))
        for r in ranks
    ]
    return CalibrationTable(entries=entries)


def test_interpolation_at_off_grid_rank():
    # times linear in log2(rank) -> piecewise-linear interp is exact
    t = _table_with_ranks(
        (16, 64), lambda r: {"pallas": 0.01 * np.log2(r),
                             "ref": 0.08 - 0.01 * np.log2(r)})
    m = t.model
    got = m.predict("pallas", nmodes=3, rank=32, blk=32, tile_rows=8)
    assert got == pytest.approx(0.01 * 5.0)           # log2(32) = 5
    # crossover: pallas wins below log2(r)=4, ref above
    assert t.best_backend(nmodes=3, rank=16, blk=32, tile_rows=8) == "pallas"
    assert t.best_backend(nmodes=3, rank=64, blk=32, tile_rows=8) == "ref"
    # clamped extrapolation beyond the knots
    assert m.predict("pallas", nmodes=3, rank=1024, blk=32,
                     tile_rows=8) == pytest.approx(0.01 * 6.0)


def test_off_grid_shape_resolves_to_nearest_group():
    t = _table_with_ranks((16,), lambda r: {"pallas": 0.5, "ref": 0.1})
    # different (blk, tile_rows) than any entry: nearest group answers
    assert t.best_backend(nmodes=3, rank=16, blk=512, tile_rows=128) == "ref"
    # different nmodes too
    assert t.best_backend(nmodes=5, rank=16, blk=512, tile_rows=128) == "ref"


# ---------------------------------------------------------------------------
# Dispatch wiring: select_backend(table=...)
# ---------------------------------------------------------------------------

def test_select_backend_matches_measured_argmin_on_grid(table):
    """Acceptance: table-driven auto == measured best on EVERY grid key
    (argmin over the numerics-preserving AUTO_BACKENDS — never bf16).
    ``factor_rows`` comes from the measured case (as ``repro.tune
    check`` supplies it), so a measured-fast gather backend is a
    certifiable choice."""
    for key in table.shape_keys():
        n, r, b, t = key
        agg = {
            bk: float(np.median([e.timings_s[bk] for e in table.entries
                                 if e.shape_key == key]))
            for bk in BACKENDS
        }
        want = min(sorted(_AUTO_BACKENDS), key=lambda bk: (agg[bk], bk))
        got = kops.select_backend("auto", nmodes=n, rank=r, blk=b,
                                  tile_rows=t, table=table,
                                  factor_rows=tune.key_factor_rows(
                                      table, key))
        assert got == want, (key, got, want)


def test_select_backend_without_table_is_static(table):
    """No table (or an unanswerable one) -> bit-identical static choices."""
    empty = CalibrationTable(entries=[])
    for nmodes in (2, 3, 4, 5):
        for rank in (4, 16, 64, 256, 2048, 8192):
            for blk in (512, 2048):
                kw = dict(nmodes=nmodes, rank=rank, blk=blk, tile_rows=128)
                static = kops.select_backend("auto", **kw)
                # reimplementation of the documented static rule
                rpad = kops.padded_rank(rank)
                if rank < kops.MIN_MXU_RANK:
                    want = "ref"
                elif kkernel.fused_vmem_bytes(
                        nmodes - 1, rpad, blk, 128) <= \
                        kops.VMEM_BUDGET_BYTES:
                    want = "pallas_fused"
                elif kkernel.fused_tiled_vmem_bytes(
                        nmodes - 1, rpad, blk, 128) <= \
                        kops.VMEM_BUDGET_BYTES:
                    want = "pallas_fused_tiled"
                else:
                    want = "pallas"
                assert static == want
                assert kops.select_backend(
                    "auto", table=empty, **kw) == static


def test_select_backend_table_never_returns_segsum_or_bf16(table):
    # segsum is always fastest under fake_measure at rank 16 and bf16 is
    # fastest everywhere, but ops cannot run the former and auto must
    # not change numerics via the latter -- the table path restricts to
    # the numerics-preserving ops backends.
    for key in table.shape_keys():
        n, r, b, t = key
        got = kops.select_backend("auto", nmodes=n, rank=r, blk=b,
                                  tile_rows=t, table=table)
        assert got in _AUTO_BACKENDS


def test_explicit_backend_ignores_table(table):
    for bk in _OPS_BACKENDS:
        assert kops.select_backend(bk, nmodes=3, rank=16, table=table) == bk


def test_below_grid_rank_keeps_static_mxu_guard(table):
    """A table whose grid starts at rank 16 must not override the
    static rank<8 -> ref rule via clamped below-grid extrapolation."""
    for rank in (2, 4, 7):
        kw = dict(nmodes=3, rank=rank, blk=32, tile_rows=8)
        assert not table.covers(**kw)
        assert kops.select_backend("auto", table=table, **kw) == "ref"
    # ...but a rank the table actually measured answers from measurements
    low = CalibrationTable(entries=[tune.CalibrationEntry(
        nmodes=3, rank=4, blk=32, tile_rows=8, density=1.0,
        timings_s={"pallas": 0.001, "ref": 0.5})])
    assert low.covers(nmodes=3, rank=4, blk=32, tile_rows=8)
    assert kops.select_backend("auto", nmodes=3, rank=4, blk=32,
                               tile_rows=8, table=low) == "pallas"
    # plan_modes applies the same guard
    _, ft = _small_ft()
    plans = tune.plan_modes(table, ft, 4)
    assert plans is not None
    assert all(p.backend in ("ref", "segsum") for p in plans)


def test_table_cannot_pick_infeasible_fused():
    """VMEM feasibility is a hard constraint even when the table loves
    pallas_fused: extrapolating far beyond the measured grid must not
    select a fused working set that exceeds the budget. (The static
    fallback it lands on is now the rank-tiled kernel, whose slabbed
    working set always fits — the PR-2 rule fell all the way back to
    the materialized path here.)"""
    t = _table_with_ranks(
        (16, 256), lambda r: {"pallas_fused": 0.001, "pallas": 1.0,
                              "ref": 1.0})
    kw = dict(nmodes=5, rank=8192, blk=512, tile_rows=128)
    assert kkernel.fused_vmem_bytes(
        4, kops.padded_rank(8192), 512, 128) > kops.VMEM_BUDGET_BYTES
    got = kops.select_backend("auto", table=t, **kw)
    assert got == kops.select_backend("auto", **kw) == "pallas_fused_tiled"
    # ...and plan_modes applies the same guard per candidate shape
    entries = [tune.CalibrationEntry(nmodes=3, rank=r, blk=512,
                                     tile_rows=128, density=1.0,
                                     timings_s={"pallas_fused": 0.001,
                                                "pallas": 1.0})
               for r in (16, 256)]
    _, ft = _small_ft()          # 3-mode: fused needs rank 16384 to overflow
    assert kkernel.fused_vmem_bytes(
        2, kops.padded_rank(16384), 512, 128) > kops.VMEM_BUDGET_BYTES
    plans = tune.plan_modes(CalibrationTable(entries=entries), ft, 16384)
    assert plans is not None
    assert all(p.backend != "pallas_fused" for p in plans)


# ---------------------------------------------------------------------------
# Runtime wiring: bucket_caps + mode plans
# ---------------------------------------------------------------------------

def _small_ft(seed=3):
    t = random_sparse_tensor((40, 30, 20), 400, seed=seed,
                             distribution="powerlaw")
    return t, build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64),
                           cache_bytes=1 << 20)


def test_prepare_runtime_per_transition_caps():
    _, ft = _small_ft()
    rt, _ = dist.prepare_runtime(ft, rank=8, tile_rows=8)
    caps = remap_capacities(ft)
    assert rt.bucket_caps == tuple(caps)
    assert rt.bucket_cap == max(caps)
    for n in range(ft.nmodes):
        assert rt.bucket_cap_for(n) == caps[n] <= rt.bucket_cap


def test_prepare_runtime_uniform_cap_escape_hatch():
    _, ft = _small_ft()
    rt, _ = dist.prepare_runtime(ft, rank=8, tile_rows=8, uniform_cap=True)
    assert rt.bucket_caps is None
    for n in range(ft.nmodes):
        assert rt.bucket_cap_for(n) == rt.bucket_cap


def test_runtime_back_compat_construction():
    # direct construction without the new fields (old call sites) works
    rt = dist.DynasorRuntime(
        num_workers=1, nmodes=3, rank=8, rows_cap=(8, 8, 8),
        i_pad=(8, 8, 8), nnz_cap=8, bucket_cap=8, shape=(8, 8, 8))
    assert rt.bucket_cap_for(2) == 8
    assert rt.plan_for(1, "pallas") == dist.ModePlan("pallas", 512, 128)


def test_prepare_runtime_with_table_builds_plans(table):
    _, ft = _small_ft()
    rt, (idx, val, mask) = dist.prepare_runtime(ft, rank=16, table=table)
    assert rt.mode_plans is not None and len(rt.mode_plans) == ft.nmodes
    for n, plan in enumerate(rt.mode_plans):
        assert plan.backend in BACKENDS
        # grid shapes only: quick grid is blk=32, tile_rows=8
        assert (plan.blk, plan.tile_rows) == (32, 8)
        # rows_cap rounded to the tuned tile
        assert rt.rows_cap[n] % plan.tile_rows == 0
        # auto follows the plan; explicit backend overrides it
        assert rt.plan_for(n, "auto") == plan
        assert rt.plan_for(n, "segsum").backend == "segsum"
    assert idx.shape[0] == ft.params.num_workers


def test_plan_modes_unanswerable_returns_none():
    _, ft = _small_ft()
    assert tune.plan_modes(CalibrationTable(entries=[]), ft, 16) is None
    rt, _ = dist.prepare_runtime(ft, rank=16,
                                 table=CalibrationTable(entries=[]))
    assert rt.mode_plans is None          # static configuration kept


# ---------------------------------------------------------------------------
# zipf_4d generator (satellite fix)
# ---------------------------------------------------------------------------

def test_zipf_4d_keeps_nnz_and_uniqueness():
    shape, nnz = (150, 140, 600, 30), 4000
    t = zipf_4d(shape, nnz, seed=0)
    assert t.nnz == nnz
    flat = np.ravel_multi_index(tuple(t.indices.T), shape)
    assert len(np.unique(flat)) == nnz    # rejection worked: no duplicates
    # where the old power-law generator collapses
    old = random_sparse_tensor(shape, nnz, seed=0, distribution="powerlaw")
    assert old.nnz < nnz // 10


def test_zipf_4d_is_actually_skewed():
    shape, nnz = (200, 180, 500, 40), 5000
    t = zipf_4d(shape, nnz, seed=1)
    counts = np.sort(np.bincount(t.indices[:, 0], minlength=shape[0]))
    top_share = counts[-shape[0] // 100:].sum() / nnz
    u = random_sparse_tensor(shape, nnz, seed=1, distribution="uniform")
    uc = np.sort(np.bincount(u.indices[:, 0], minlength=shape[0]))
    u_share = uc[-shape[0] // 100:].sum() / u.nnz
    assert top_share > 3 * u_share        # hubs exist


def test_zipf_4d_rejects_impossible_nnz():
    with pytest.raises(ValueError, match="capacity"):
        zipf_4d((2, 2, 2, 2), 17, seed=0)
