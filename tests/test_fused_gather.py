"""In-kernel factor gather backends (PR-4 tentpole).

Coverage per the issue checklist:
  * bit-exactness of ``pallas_fused_gather`` (and its rank-tiled and
    bf16 compositions) vs the HBM-materializing ``pallas_fused`` path
    at R ∈ {128, 256, 512} across N ∈ {3, 4, 5};
  * trailing-invalid handling and the elementwise reference;
  * VMEM accounting: the index-stream term, bf16 residency halving,
    slab independence of the tiled resident set;
  * no-fallback dispatch: ``select_backend`` prefers the gather family
    whenever its VMEM predicate holds (``factor_rows`` supplied), is
    bit-identical to the old decisions when it isn't, and a calibration
    table cannot steer onto an uncertifiable gather choice;
  * runtime threading: ``ModePlan.rank_slabs`` for the tiled gather
    backend and tuned ``plan_modes`` feasibility;
  * schema back-compat: the committed v2 calibration table still loads.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import distributed as dist
from repro.core.mttkrp import mttkrp_elementwise_ref
from repro.core.tensors import random_sparse_tensor
from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import ops as kops

BLK, TILE = 32, 8

SHAPES = {3: (20, 16, 12), 4: (12, 10, 8, 6), 5: (8, 7, 6, 5, 4)}

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sorted_case(shape, nnz, rank, mode, seed=0):
    rng = np.random.default_rng(seed)
    t = random_sparse_tensor(shape, nnz, seed=seed)
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    return idx, val, factors


def _device_step(idx, val, valid, factors, mode, rows_cap, backend,
                 gather_dtype="float32"):
    return kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=mode, rows_cap=rows_cap, row_offset=0, blk=BLK, tile_rows=TILE,
        interpret=True, backend=backend, gather_dtype=gather_dtype)


def _rel_err(got, ref):
    return np.abs(np.asarray(got) - ref).max() / (np.abs(ref).max() + 1e-9)


# ---------------------------------------------------------------------------
# Golden: in-kernel gather vs the materializing fused kernel, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nmodes", [3, 4, 5])
@pytest.mark.parametrize("rank", [128, 256, 512])
def test_gather_bitexact_vs_fused(nmodes, rank):
    """The gather kernel performs the identical fp32 arithmetic in the
    identical order — only *where* the rows are fetched changes — so it
    must agree with the fused kernel bitwise, not just within
    tolerance."""
    shape = SHAPES[nmodes]
    idx, val, factors = _sorted_case(shape, 150, rank, 0, seed=nmodes)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    fused = _device_step(idx, val, valid, factors, 0, rows_cap,
                         "pallas_fused")
    gather = _device_step(idx, val, valid, factors, 0, rows_cap,
                          "pallas_fused_gather")
    tiled = _device_step(idx, val, valid, factors, 0, rows_cap,
                         "pallas_fused_gather_tiled")
    np.testing.assert_array_equal(np.asarray(gather), np.asarray(fused))
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(fused))
    ref = mttkrp_elementwise_ref(idx, val, factors, 0, out_rows=rows_cap)
    assert _rel_err(gather, ref) < 1e-4, (nmodes, rank)


def test_gather_nonzero_output_mode():
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 200, 128, 2, seed=5)
    rows_cap = -(-shape[2] // TILE) * TILE
    valid = np.ones(len(val), bool)
    fused = _device_step(idx, val, valid, factors, 2, rows_cap,
                         "pallas_fused")
    gather = _device_step(idx, val, valid, factors, 2, rows_cap,
                          "pallas_fused_gather")
    np.testing.assert_array_equal(np.asarray(gather), np.asarray(fused))


def test_gather_with_trailing_invalid():
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 250, 256, 0, seed=3)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.arange(len(val)) < len(val) - 7
    val = np.where(valid, val, 0.0).astype(np.float32)
    a = _device_step(idx, val, valid, factors, 0, rows_cap,
                     "pallas_fused_gather")
    b = _device_step(idx, val, valid, factors, 0, rows_cap, "pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_gather_bf16_compositions_match_materialized_bf16():
    """Casting the resident matrices to bf16 must equal the materialized
    path's cast-then-take bitwise, across all four bf16 spellings."""
    shape = (12, 10, 8, 6)
    idx, val, factors = _sorted_case(shape, 150, 256, 0, seed=7)
    rows_cap = -(-shape[0] // TILE) * TILE
    valid = np.ones(len(val), bool)
    want = _device_step(idx, val, valid, factors, 0, rows_cap,
                        "pallas_fused_bf16")
    got_name = _device_step(idx, val, valid, factors, 0, rows_cap,
                            "pallas_fused_gather_bf16")
    got_dtype = _device_step(idx, val, valid, factors, 0, rows_cap,
                             "pallas_fused_gather",
                             gather_dtype="bfloat16")
    got_tiled = _device_step(idx, val, valid, factors, 0, rows_cap,
                             "pallas_fused_gather_tiled",
                             gather_dtype="bfloat16")
    assert np.asarray(got_name).dtype == np.float32   # fp32 accumulate
    np.testing.assert_array_equal(np.asarray(got_name), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_dtype), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_tiled), np.asarray(want))
    exact = _device_step(idx, val, valid, factors, 0, rows_cap,
                         "pallas_fused_gather")
    rel = _rel_err(got_name, np.asarray(exact))
    assert 0.0 < rel < 4 * 3 * 2.0 ** -8              # it really gathered bf16


# ---------------------------------------------------------------------------
# VMEM accounting: index-stream term + resident-factor formulas
# ---------------------------------------------------------------------------

def test_fused_vmem_bytes_index_stream_term():
    # vals (fp32) + rows (int32) = 2·blk·4; the gather family adds one
    # int32 index stream per input mode.
    base = kkernel.fused_vmem_bytes(2, 256, 512, 128)
    with_idx = kkernel.fused_vmem_bytes(2, 256, 512, 128,
                                        index_stream_modes=2)
    assert with_idx - base == 2 * 512 * 4


def test_gather_vmem_bytes_formulas():
    k, rpad, blk, tile, fr = 3, 512, 512, 128, 10_000
    got = kkernel.gather_vmem_bytes(k, rpad, blk, tile, fr)
    resident = fr * rpad * 4
    contrib = blk * rpad * 4
    onehot = blk * tile * 4
    out_tile = tile * rpad * 4
    scalars = (2 + k) * blk * 4
    assert got == resident + contrib + onehot + out_tile + scalars
    # bf16 halves exactly the resident-factor term
    bf16 = kkernel.gather_vmem_bytes(k, rpad, blk, tile, fr,
                                     gather_itemsize=2)
    assert got - bf16 == resident // 2
    # the tiled resident set is one slab wide: independent of padded rank
    assert kkernel.gather_tiled_vmem_bytes(k, rpad, blk, tile, fr) == \
        kkernel.gather_tiled_vmem_bytes(k, 1 << 20, blk, tile, fr) == \
        kkernel.gather_vmem_bytes(k, kkernel.RANK_SLAB, blk, tile, fr)


# ---------------------------------------------------------------------------
# Dispatch: gather preferred under its predicate, never silently dropped
# ---------------------------------------------------------------------------

def test_auto_prefers_gather_when_factors_fit():
    for nmodes, rank, fr in [(3, 128, 20_000), (4, 256, 50_000),
                             (5, 512, 20_000)]:
        assert kops.gather_fits_vmem(nmodes, rank, 512, 128, fr)
        got = kops.select_backend("auto", nmodes=nmodes, rank=rank,
                                  factor_rows=fr)
        assert got == "pallas_fused_gather", (nmodes, rank, fr)


def test_auto_degrades_gather_to_slab_streamed_then_fused():
    # Factor-resident overflows at full rank but one slab of each factor
    # fits -> slab-streamed gather keeps the in-kernel win.
    nmodes, rank, blk = 4, 8192, 512
    fr = 100_000
    assert not kops.gather_fits_vmem(nmodes, rank, blk, 128, fr)
    assert kops.gather_fits_vmem(nmodes, rank, blk, 128, fr, tiled=True)
    assert kops.select_backend(
        "auto", nmodes=nmodes, rank=rank, blk=blk,
        factor_rows=fr) == "pallas_fused_gather_tiled"
    # Factors too large for even one slab -> the materializing fused
    # family takes over, exactly as before the gather family existed.
    huge = 600_000_000
    assert not kops.gather_fits_vmem(nmodes, 128, blk, 128, huge,
                                     tiled=True)
    assert kops.select_backend(
        "auto", nmodes=nmodes, rank=128, blk=blk,
        factor_rows=huge) == "pallas_fused"


def test_auto_without_factor_rows_is_bit_identical_to_pr3():
    """A purely shape-keyed query (factor sizes unknown) must reproduce
    the pre-gather decisions exactly — the gather family is only ever
    chosen on certified residency."""
    for nmodes in (3, 4, 5):
        for rank in (4, 64, 256, 2048, 8192):
            for blk in (512, 2048):
                kw = dict(nmodes=nmodes, rank=rank, blk=blk, tile_rows=128)
                got = kops.select_backend("auto", **kw)
                if rank < kops.MIN_MXU_RANK:
                    want = "ref"
                elif kops.fused_fits_vmem(nmodes, rank, blk, 128):
                    want = "pallas_fused"
                elif kops.fused_fits_vmem(nmodes, rank, blk, 128,
                                          tiled=True):
                    want = "pallas_fused_tiled"
                else:
                    want = "pallas"
                assert got == want, kw


def test_device_step_dispatch_no_silent_fallback():
    """End-to-end: mttkrp_device_step supplies factor_rows itself, so
    ``auto`` on a VMEM-eligible case must run the gather kernel — we
    prove it by matching the explicit gather backend bitwise (interpret
    mode makes each kernel's accumulation deterministic)."""
    shape = SHAPES[4]
    idx, val, factors = _sorted_case(shape, 150, 128, 0, seed=11)
    rows_cap = -(-shape[0] // TILE) * TILE
    fr = sum(shape[1:])
    assert kops.gather_fits_vmem(4, 128, BLK, TILE, fr)
    valid = np.ones(len(val), bool)
    auto = _device_step(idx, val, valid, factors, 0, rows_cap, "auto")
    explicit = _device_step(idx, val, valid, factors, 0, rows_cap,
                            "pallas_fused_gather")
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))


def test_table_cannot_pick_uncertifiable_gather():
    """A table that loves the gather backend may only steer onto it when
    the caller's factor_rows certifies VMEM residency."""
    entries = [
        tune.CalibrationEntry(
            nmodes=3, rank=r, blk=32, tile_rows=8, density=1.0,
            timings_s={"pallas_fused_gather": 0.001, "pallas_fused": 0.5,
                       "pallas": 1.0, "ref": 1.0}, factor_rows=128)
        for r in (128, 512)
    ]
    table = tune.CalibrationTable(entries=entries)
    kw = dict(nmodes=3, rank=128, blk=32, tile_rows=8)
    # certified: the table's preference is followed
    assert kops.select_backend("auto", table=table, factor_rows=1000,
                               **kw) == "pallas_fused_gather"
    # unknown factor sizes: discarded, static decision applies
    assert kops.select_backend("auto", table=table,
                               **kw) == "pallas_fused"
    # infeasible resident factor sizes: the preference is discarded just
    # the same; since PR 5 the static ladder then lands on the
    # out-of-core streamed gather (its bounded tile window fits at this
    # blk even though whole/slab residency cannot).
    assert kops.select_backend("auto", table=table,
                               factor_rows=600_000_000,
                               **kw) == kops.STREAM_BACKEND


# ---------------------------------------------------------------------------
# Runtime threading + tuned plans
# ---------------------------------------------------------------------------

def test_plan_for_gather_tiled_rank_slabs():
    rt = dist.DynasorRuntime(
        num_workers=1, nmodes=3, rank=512, rows_cap=(8, 8, 8),
        i_pad=(8, 8, 8), nnz_cap=8, bucket_cap=8, shape=(8, 8, 8))
    assert rt.plan_for(0, "pallas_fused_gather_tiled").rank_slabs == \
        kops.padded_rank(512) // kops.MXU_RANK_MULTIPLE == 4
    assert rt.plan_for(0, "pallas_fused_gather").rank_slabs == 1


def test_plan_modes_can_choose_gather_and_records_slabs():
    from repro.core.flycoo import build_flycoo
    t = random_sparse_tensor((40, 30, 20), 400, seed=3,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64),
                      cache_bytes=1 << 20)
    entries = [
        tune.CalibrationEntry(
            nmodes=3, rank=r, blk=32, tile_rows=8, density=1.0,
            timings_s={"pallas_fused_gather_tiled": 0.001, "pallas": 1.0,
                       "ref": 1.0}, factor_rows=128)
        for r in (128, 512)
    ]
    plans = tune.plan_modes(tune.CalibrationTable(entries=entries), ft, 512)
    assert plans is not None
    for p in plans:
        assert p.backend == "pallas_fused_gather_tiled"
        assert p.rank_slabs == kops.padded_rank(512) // \
            kops.MXU_RANK_MULTIPLE


# ---------------------------------------------------------------------------
# Schema back-compat: v2 tables (no factor_rows, no gather timings) load
# ---------------------------------------------------------------------------

def test_v2_calibration_table_still_loads():
    path = os.path.join(REPO_ROOT, "experiments", "tune", "fixtures",
                        "calibration_v2_example.json")
    table = tune.load_table(path)
    assert table.schema_version == tune.SCHEMA_VERSION
    assert table.meta.get("upgraded_from_schema") == 2
    assert table.entries
    for e in table.entries:
        assert e.factor_rows is None          # pre-v3: unrecorded
        assert not any(b.startswith("pallas_fused_gather")
                       for b in e.timings_s)
    # and the upgraded table still answers dispatch queries
    key = table.shape_keys()[0]
    nmodes, rank, blk, tile_rows = key
    got = kops.select_backend("auto", nmodes=nmodes, rank=rank, blk=blk,
                              tile_rows=tile_rows, table=table)
    assert got in kops.AUTO_BACKENDS + ("ref",)


def test_v3_round_trip_preserves_factor_rows(tmp_path):
    table = tune.calibrate(measure=tune.stub_measure, quick=True)
    for e in table.entries:
        assert e.factor_rows == (e.nmodes - 1) * 64
    path = table.save(str(tmp_path / "t.json"))
    loaded = tune.load_table(path)
    assert loaded.entries == table.entries
