"""Dynamic tensor remapping (paper §III-B): round-trip + capacity bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import remap as remap_lib
from repro.core.flycoo import build_flycoo, pack_mode
from repro.core.tensors import random_sparse_tensor


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 100), st.integers(1, 6), st.integers(4, 64))
def test_bucket_by_destination_is_lossless(seed, num_dev, n):
    """No element lost or duplicated when capacity suffices."""
    rng = np.random.default_rng(seed)
    dest = rng.integers(0, num_dev, n).astype(np.int32)
    payload = rng.standard_normal((n, 3)).astype(np.float32)
    cap = int(np.bincount(dest, minlength=num_dev).max())
    buckets, mask, dropped = remap_lib.bucket_by_destination(
        jnp.asarray(dest), jnp.asarray(payload), num_dev, cap)
    assert int(dropped) == 0
    got = np.asarray(buckets)[np.asarray(mask)]
    assert got.shape[0] == n
    assert np.isclose(sorted(got[:, 0].tolist()),
                      sorted(payload[:, 0].tolist())).all()
    # every row landed in its destination bucket
    for d in range(num_dev):
        rows = np.asarray(buckets[d])[np.asarray(mask[d])]
        want = payload[dest == d]
        assert np.isclose(sorted(rows[:, 1].tolist()),
                          sorted(want[:, 1].tolist())).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 100))
def test_bucket_counts_dropped_on_overflow(seed):
    rng = np.random.default_rng(seed)
    n, num_dev = 64, 2
    dest = np.zeros(n, np.int32)                 # all to device 0
    payload = rng.standard_normal((n, 2)).astype(np.float32)
    cap = 10
    _, mask, dropped = remap_lib.bucket_by_destination(
        jnp.asarray(dest), jnp.asarray(payload), num_dev, cap)
    assert int(dropped) == n - cap
    assert int(np.asarray(mask).sum()) == cap


def test_remap_capacity_is_a_true_upper_bound():
    t = random_sparse_tensor((40, 30, 20), 400, seed=3,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    cap = remap_lib.remap_capacity(ft)
    D = 4
    for n in range(t.nmodes):
        src = ft.owner_of(n).astype(np.int64)
        dst = ft.owner_of((n + 1) % t.nmodes).astype(np.int64)
        counts = np.bincount(src * D + dst, minlength=D * D)
        assert counts.max() <= cap


def test_compact_sorted_orders_and_truncates():
    payload = jnp.asarray(np.arange(12, dtype=np.float32).reshape(6, 2))
    mask = jnp.asarray([True, False, True, True, False, True])
    key = jnp.asarray([5, 0, 3, 1, 2, 4], jnp.int32)
    out, omask = remap_lib.compact_sorted(payload, mask, key, 4)
    assert out.shape == (4, 2)
    assert bool(omask.all())
    # sorted by key among valid: keys 1,3,4,5 -> rows 3,2,5,0
    assert np.array_equal(np.asarray(out[:, 0]), [6.0, 4.0, 10.0, 0.0])


def test_remap_local_oracle_is_pack_mode_and_takes_no_source_layout():
    import inspect

    t = random_sparse_tensor((40, 30, 20), 400, seed=3,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    for m in range(t.nmodes):
        got = remap_lib.remap_local(ft, m)
        want = pack_mode(ft, m)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # Contract: the expected post-remap layout depends only on (ft,
    # to_mode) — the oracle must not accept (and ignore) source-layout
    # arguments.
    assert list(inspect.signature(remap_lib.remap_local).parameters) == \
        ["ft", "to_mode"]
