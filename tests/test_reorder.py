"""repro.reorder — locality-aware nonzero ordering (PR-8 tentpole).

Coverage per the issue checklist:
  * every policy in ``ORDERINGS`` is a **true permutation** of the
    stream (bijectivity + per-mode multiset preservation, hypothesis
    sweep + example-based), keeping valid-first / output-tile-run
    contracts intact;
  * the in-jit ``build_block_layout(order_keys=...)`` path
    (``mttkrp_device_step(ordering=...)``) is bit-exact against the
    host-side ``reorder_stream`` permutation — same keys, same layout,
    same sums;
  * the out-of-core executor stays bit-exact vs the resident gather on
    a forced-multichunk skewed workload for every ordering, and
    ``planner.predict_stream_traffic`` agrees with the executor's
    counted ``StreamStats`` **exactly** (scheduled/distinct bytes,
    window tiles, chunk count) — post-sort and presort;
  * reordered CP-ALS matches the unsorted fit within fp32
    accumulation-order tolerance for N ∈ {3, 4, 5} (subprocess, 4 host
    devices — the ``test_distributed`` pattern);
  * schedule invariants: ``chunk_window_tiles`` tightens per chunk but
    never exceeds the global (VMEM-certified) windows,
    ``chunk_boundaries`` covers every block exactly once, and
    ``stream_chunk_bytes`` is the executor's budget arithmetic;
  * ``morton_key_words`` key properties: int32-safe words,
    injectivity, componentwise monotonicity.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tensors import random_sparse_tensor, zipf_4d
from repro.kernels.mttkrp import ops as kops
from repro.oocore import planner
from repro.oocore.executor import mttkrp_out_of_core
from repro.reorder.ordering import (
    FACTOR_ROW_TILE,
    MORTON_BITS,
    ORDERINGS,
    locality_keys,
    locality_lexsort,
    morton_bits_for,
    morton_key_words,
    reorder_stream,
    validate_ordering,
)

BLK, TILE = 32, 8


def _sorted_stream(shape, nnz, mode, seed=0, invalid_tail=0,
                   distribution="powerlaw"):
    """Executor-contract stream: sorted by output row, trailing invalids."""
    t = random_sparse_tensor(shape, nnz, seed=seed,
                             distribution=distribution)
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    valid = np.ones(len(val), bool)
    if invalid_tail:
        valid[-invalid_tail:] = False
        val = np.where(valid, val, 0.0).astype(np.float32)
    return idx, val, valid


def _factors(shape, rank, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
            for d in shape]


def _check_permutation(idx, val, valid, mode, ordering, tile_rows=TILE):
    idx2, val2, valid2, perm = reorder_stream(
        idx, val, valid, mode=mode, ordering=ordering, tile_rows=tile_rows)
    n = len(val)
    # bijection: perm is a permutation of range(n), and the outputs are
    # exactly the inputs routed through it
    assert np.array_equal(np.sort(perm), np.arange(n))
    assert np.array_equal(idx2, idx[perm])
    assert np.array_equal(val2, val[perm])
    assert np.array_equal(valid2, valid[perm])
    # per-mode multiset preserved (valid entries)
    for m in range(idx.shape[1]):
        assert np.array_equal(np.sort(idx2[valid2, m]),
                              np.sort(idx[valid, m]))
    # downstream contracts: valid-first, output-tile runs ascending
    nv = int(valid.sum())
    assert valid2[:nv].all() and not valid2[nv:].any()
    out_tile = idx2[valid2, mode] // tile_rows
    assert np.all(np.diff(out_tile) >= 0)
    return idx2, val2, valid2, perm


# ---------------------------------------------------------------------------
# Permutation property: bijectivity + multiset preservation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ordering", ORDERINGS)
@pytest.mark.parametrize("nmodes", [3, 4])
def test_reorder_stream_is_true_permutation(ordering, nmodes):
    shape = (40, 300, 170, 60)[:nmodes]
    idx, val, valid = _sorted_stream(shape, 250, 0, seed=nmodes,
                                     invalid_tail=9)
    _check_permutation(idx, val, valid, 0, ordering)


def test_reorder_none_is_stable_identity_on_sorted_stream():
    """ordering="none" degenerates to a stable sort by output tile —
    on an already row-sorted stream that's the identity."""
    idx, val, valid = _sorted_stream((40, 300, 170), 200, 0, seed=1)
    _, _, _, perm = reorder_stream(idx, val, valid, mode=0,
                                   ordering="none", tile_rows=TILE)
    assert np.array_equal(perm, np.arange(len(val)))


def test_validate_ordering_rejects_unknown():
    with pytest.raises(ValueError, match="unknown ordering"):
        validate_ordering("hilbert")
    with pytest.raises(ValueError, match="unknown ordering"):
        reorder_stream(np.zeros((4, 3), np.int32), np.zeros(4, np.float32),
                       np.ones(4, bool), mode=0, ordering="zcurve",
                       tile_rows=TILE)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    nnz=st.integers(20, 300),
    nmodes=st.integers(3, 5),
    mode=st.integers(0, 2),
    tile_rows=st.sampled_from([8, 16]),
    ordering=st.sampled_from(ORDERINGS),
    invalid_frac=st.floats(0.0, 0.3),
)
def test_reorder_stream_permutation_property(seed, nnz, nmodes, mode,
                                             tile_rows, ordering,
                                             invalid_frac):
    shape = (40, 300, 170, 60, 20)[:nmodes]
    idx, val, valid = _sorted_stream(shape, nnz, mode, seed=seed,
                                     invalid_tail=int(nnz * invalid_frac))
    _check_permutation(idx, val, valid, mode, ordering,
                       tile_rows=tile_rows)


# ---------------------------------------------------------------------------
# In-jit order_keys path ≡ host permutation, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ordering", ["tile", "morton"])
@pytest.mark.parametrize("nmodes", [3, 4])
def test_in_jit_ordering_bitexact_vs_host_reorder(ordering, nmodes):
    """mttkrp_device_step(ordering=X) sorts inside jit via
    build_block_layout's order_keys; feeding it the host-permuted stream
    with ordering="none" must produce the identical block layout and
    therefore the identical (bit-exact) output."""
    shape = (20, 300, 170, 6)[:nmodes]
    idx, val, valid = _sorted_stream(shape, 220, 0, seed=nmodes,
                                     invalid_tail=5)
    factors = _factors(shape, 128, seed=nmodes)
    rows_cap = -(-shape[0] // TILE) * TILE
    kw = dict(mode=0, rows_cap=rows_cap, row_offset=0, blk=BLK,
              tile_rows=TILE, interpret=True,
              backend="pallas_fused_gather")
    in_jit = kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        ordering=ordering, **kw)
    idx2, val2, valid2, _ = reorder_stream(
        idx, val, valid, mode=0, ordering=ordering, tile_rows=TILE)
    host = kops.mttkrp_device_step(
        jnp.asarray(idx2), jnp.asarray(val2), jnp.asarray(valid2), factors,
        ordering="none", **kw)
    np.testing.assert_array_equal(np.asarray(in_jit), np.asarray(host))


def test_locality_keys_shapes():
    idx_in = np.array([[0, 8], [17, 3], [5, 200]], np.int32)
    assert locality_keys(idx_in, "none") == ()
    tile_keys = locality_keys(idx_in, "tile")
    assert len(tile_keys) == 2
    assert np.array_equal(tile_keys[0], idx_in[:, 0] // FACTOR_ROW_TILE)
    morton_keys = locality_keys(idx_in, "morton")
    assert len(morton_keys) == -(-2 * MORTON_BITS // 30)
    for kk in tile_keys + morton_keys:
        assert kk.shape == (3,)


# ---------------------------------------------------------------------------
# Executor: bit-exact per ordering + predicted == counted, exactly
# ---------------------------------------------------------------------------

def _skewed_case():
    shape = (2000, 1000, 700, 40)
    t = zipf_4d(shape, 1500, alpha=1.3, seed=7)
    mode = 3
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    valid = np.ones(len(val), bool)
    factors = _factors(shape, 16, seed=0)
    rows_cap = -(-shape[mode] // TILE) * TILE
    budget = 16 * planner.stream_chunk_bytes(BLK, 3, (8, 8, 8))
    return shape, idx, val, valid, factors, mode, rows_cap, budget


def _run_ordering(ordering):
    shape, idx, val, valid, factors, mode, rows_cap, budget = _skewed_case()
    out, stats = mttkrp_out_of_core(
        idx, val, valid, factors, mode=mode, rows_cap=rows_cap, blk=BLK,
        tile_rows=TILE, max_chunk_bytes=budget, ordering=ordering)
    return (shape, idx, val, valid, factors, mode, rows_cap, budget,
            out, stats)


@pytest.mark.parametrize("ordering", ORDERINGS)
def test_executor_bitexact_and_predicted_eq_counted(ordering):
    (shape, idx, val, valid, factors, mode, rows_cap, budget,
     out, stats) = _run_ordering(ordering)
    assert stats.chunks >= 3, stats.chunks
    assert stats.ordering == ordering

    # bit-exact against the resident gather on the same permuted stream
    # (the in-jit ordering path — so this also cross-checks host vs jit)
    resident = kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=mode, rows_cap=rows_cap, row_offset=0, blk=BLK,
        tile_rows=TILE, interpret=True, backend="pallas_fused_gather",
        ordering=ordering)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(resident))

    # planner prediction on the post-sort stream == executor count, EXACT
    if ordering == "none":
        idx2, valid2 = idx, valid
    else:
        idx2, _, valid2, _ = reorder_stream(
            idx, val, valid, mode=mode, ordering=ordering, tile_rows=TILE)
    traffic_kw = dict(
        mode=mode, rows_cap=rows_cap, blk=BLK, tile_rows=TILE, rank=16,
        factor_rows=tuple(shape[w] for w in range(4) if w != mode),
        max_chunk_bytes=budget)
    t_post = planner.predict_stream_traffic(idx2, valid2,
                                            ordering=ordering, **traffic_kw)
    assert t_post.scheduled_tile_bytes == stats.scheduled_tile_bytes
    assert t_post.distinct_tile_bytes == stats.distinct_tile_bytes
    assert t_post.window_tiles == stats.window_tiles
    assert t_post.chunks == stats.chunks

    # presort fields == a fresh prediction on the unsorted stream
    t_pre = planner.predict_stream_traffic(idx, valid, ordering="none",
                                           **traffic_kw)
    if ordering == "none":
        assert stats.presort_scheduled_tile_bytes == 0
        assert stats.presort_distinct_tile_bytes == 0
    else:
        assert stats.presort_scheduled_tile_bytes == \
            t_pre.scheduled_tile_bytes
        assert stats.presort_distinct_tile_bytes == t_pre.distinct_tile_bytes


def test_reorder_reduces_refetch_on_skewed_stream():
    """The seeded counted check behind BENCH_reorder.json's headline:
    on the skewed zipf stream both locality policies lower the
    scheduled/distinct re-fetch ratio vs the unsorted stream."""
    ratios = {}
    for ordering in ORDERINGS:
        *_, stats = _run_ordering(ordering)
        ratios[ordering] = stats.scheduled_over_distinct
        if ordering != "none":
            # the presort prediction reproduces the "none" run's ratio
            assert stats.presort_scheduled_over_distinct == \
                pytest.approx(ratios["none"])
    assert ratios["tile"] < ratios["none"], ratios
    assert ratios["morton"] < ratios["none"], ratios


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    nnz=st.integers(100, 400),
    ordering=st.sampled_from(ORDERINGS),
    max_chunk_bytes=st.one_of(st.none(), st.integers(2_000, 40_000)),
)
def test_executor_ordering_bitexact_property(seed, nnz, ordering,
                                             max_chunk_bytes):
    """Streamed+reordered ≡ resident on the same permuted stream, for
    random workloads and chunk budgets."""
    shape = (40, 300, 170)
    idx, val, valid = _sorted_stream(shape, nnz, 0, seed=seed)
    factors = _factors(shape, 128, seed=seed)
    rows_cap = -(-shape[0] // TILE) * TILE
    out, _ = mttkrp_out_of_core(
        idx, val, valid, factors, mode=0, rows_cap=rows_cap, blk=BLK,
        tile_rows=TILE, max_chunk_bytes=max_chunk_bytes, ordering=ordering)
    resident = kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=0, rows_cap=rows_cap, row_offset=0, blk=BLK, tile_rows=TILE,
        interpret=True, backend="pallas_fused_gather", ordering=ordering)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(resident))


# ---------------------------------------------------------------------------
# Schedule invariants: per-chunk tightening, chunk cover, budget bytes
# ---------------------------------------------------------------------------

def _chunk_invariants(dcounts, chunks, windows):
    cwindows = planner.chunk_window_tiles(dcounts, chunks, windows)
    assert len(cwindows) == len(chunks)
    for (start, stop), cw in zip(chunks, cwindows):
        assert len(cw) == len(windows)
        for i, w in enumerate(cw):
            assert 1 <= w <= windows[i]
            # exact tightening: the chunk's own distinct-tile max,
            # clamped into [1, global window]
            assert w == min(windows[i],
                            max(1, int(dcounts[start:stop, i].max())))
    return cwindows


def test_chunk_window_tiles_example():
    dcounts = np.array([[1, 4], [1, 1], [2, 1], [5, 1], [1, 1], [1, 2]])
    windows = (4, 3)
    chunks = [(0, 2), (2, 4), (4, 6)]
    cw = _chunk_invariants(dcounts, chunks, windows)
    assert cw == [(1, 3), (4, 1), (1, 2)]
    # single chunk covering everything reproduces the global windows
    assert planner.chunk_window_tiles(dcounts, [(0, 6)], windows) \
        == [windows]


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    num_blocks=st.integers(1, 60),
    k=st.integers(1, 4),
    max_blocks=st.integers(1, 20),
)
def test_chunk_schedule_invariants_property(seed, num_blocks, k, max_blocks):
    rng = np.random.default_rng(seed)
    dcounts = rng.integers(1, 9, size=(num_blocks, k))
    tiles = np.sort(rng.integers(0, max(1, num_blocks // 3), num_blocks))
    windows = tuple(int(w) for w in dcounts.max(axis=0))
    chunks = planner.chunk_boundaries(tiles, max_blocks)
    # exact cover, in order, within budget
    assert chunks[0][0] == 0 and chunks[-1][1] == num_blocks
    for (a, b), (c, _) in zip(chunks, chunks[1:]):
        assert b == c and a < b
    assert all(b - a <= max_blocks for a, b in chunks)
    _chunk_invariants(dcounts, chunks, windows)


def test_stream_chunk_bytes_formula():
    blk, k, windows = 32, 3, (9, 4, 2)
    got = planner.stream_chunk_bytes(blk, k, windows)
    # values f32 + rows i32 + K index streams i32, plus one i32 schedule
    # entry per window slot — per block
    assert got == blk * (4 + 4 + 4 * k) + 4 * sum(windows)


# ---------------------------------------------------------------------------
# Morton key properties
# ---------------------------------------------------------------------------

def test_morton_key_words_int32_safe_and_deterministic():
    rng = np.random.default_rng(0)
    tiles = rng.integers(0, 1 << MORTON_BITS, size=(200, 3))
    w1 = morton_key_words(tiles)
    w2 = morton_key_words(tiles.copy())
    assert len(w1) == -(-3 * MORTON_BITS // 30)
    for a, b in zip(w1, w2):
        assert np.array_equal(a, b)
        assert a.min() >= 0 and a.max() < (1 << 30)     # int32-safe words


def test_morton_key_words_injective_and_monotone():
    rng = np.random.default_rng(1)
    tiles = np.unique(rng.integers(0, 1 << MORTON_BITS, size=(300, 2)),
                      axis=0)
    words = np.stack(morton_key_words(tiles), axis=1)
    # injective on distinct in-range tuples
    assert len(np.unique(words, axis=0)) == len(tiles)
    # componentwise monotone: a <= b per coordinate => code(a) <= code(b)
    # in the words' lexicographic (most-significant-first) order
    a = rng.integers(0, 1 << (MORTON_BITS - 1), size=(400, 3))
    b = a + rng.integers(0, 1 << (MORTON_BITS - 1), size=a.shape)
    wa = np.stack(morton_key_words(a), axis=1)
    wb = np.stack(morton_key_words(b), axis=1)
    neq = wa != wb
    first = np.argmax(neq, axis=1)
    rows = np.arange(len(a))
    differs = neq.any(axis=1)
    assert np.all(wa[rows[differs], first[differs]]
                  <= wb[rows[differs], first[differs]])


def test_morton_single_mode_orders_like_tile_ids():
    tiles = np.array([[7], [0], [3], [512], [3]])
    words = morton_key_words(tiles)
    order = np.lexsort(tuple(reversed(words)))
    assert np.array_equal(tiles[order, 0], np.sort(tiles[:, 0]))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 200),
       k=st.integers(1, 4))
def test_morton_key_words_property(seed, n, k):
    rng = np.random.default_rng(seed)
    tiles = rng.integers(0, 1 << MORTON_BITS, size=(n, k))
    words = morton_key_words(tiles)
    assert len(words) == -(-k * MORTON_BITS // 30)
    for w in words:
        assert w.shape == (n,)
        assert w.min() >= 0 and w.max() < (1 << 30)
    # equal tuples get equal codes (the keys are a function of the tiles)
    wm = np.stack(words, axis=1)
    _, inv = np.unique(tiles, axis=0, return_inverse=True)
    for g in range(inv.max() + 1):
        rows = wm[inv == g]
        assert (rows == rows[0]).all()


def test_locality_lexsort_primaries_dominate():
    """Locality keys only ever reorder *within* a primary group."""
    rng = np.random.default_rng(2)
    idx_in = rng.integers(0, 4000, size=(300, 2))
    primary = np.sort(rng.integers(0, 7, size=300))
    for ordering in ORDERINGS:
        perm = locality_lexsort(idx_in, ordering, primaries=(primary,))
        assert np.array_equal(np.sort(perm), np.arange(300))
        assert np.array_equal(primary[perm], primary)   # still grouped
        if ordering == "tile":
            tiles = idx_in[perm, 0] // FACTOR_ROW_TILE
            for p in np.unique(primary):
                assert np.all(np.diff(tiles[primary == p]) >= 0)


# ---------------------------------------------------------------------------
# CP-ALS: reordered fit == unsorted fit up to fp32 accumulation order
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core.tensors import random_sparse_tensor
from repro.core.flycoo import build_flycoo
from repro.core import distributed as dist
from repro.core.cpals import cp_als_distributed

mesh = Mesh(np.array(jax.devices()), (dist.AXIS,))
CASES = {
    3: ((40, 30, 20), 350),
    4: ((20, 15, 12, 10), 300),
    5: ((12, 10, 8, 7, 6), 250),
}
for nmodes, (shape, nnz) in CASES.items():
    t = random_sparse_tensor(shape, nnz, seed=nmodes,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(2, 8), g_bounds=(8, 64),
                      cache_bytes=1 << 20)
    fits = {}
    for ordering in ("none", "tile", "morton"):
        res = cp_als_distributed(ft, 4, mesh, iters=3, seed=1, tol=0.0,
                                 backend="pallas_fused",
                                 ordering=ordering)
        assert np.isfinite(res.fits).all(), (nmodes, ordering, res.fits)
        fits[ordering] = res.fits
    for ordering in ("tile", "morton"):
        # a true permutation changes only fp32 accumulation order
        diff = np.abs(np.asarray(fits[ordering])
                      - np.asarray(fits["none"])).max()
        assert diff < 1e-3, (nmodes, ordering, diff, fits)
print("REORDER-CPALS-OK")
"""


@pytest.mark.slow
def test_cpals_fit_invariant_under_reordering_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "REORDER-CPALS-OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# Morton overflow guard (PR-9): widen, never silently clamp
# ---------------------------------------------------------------------------

def test_morton_bits_for_widens_past_budget():
    assert morton_bits_for(1) == 16
    assert morton_bits_for(1 << 16) == 16          # exactly at the budget
    assert morton_bits_for((1 << 16) + 1) == 17    # one past → widened
    assert morton_bits_for(1 << 20) == 20
    assert morton_bits_for(3, bits=2) == 2
    assert morton_bits_for(5, bits=2) == 3


def test_morton_keys_at_exact_bit_limit():
    # the largest in-budget id (2^16 - 1) needs no widening and no error
    top = (1 << 16) - 1
    tiles = np.array([[top, 0], [0, top], [top, top]], np.int64)
    words = morton_key_words(tiles)
    assert len(words) == -(-16 * 2 // 30)
    # distinct inputs keep distinct keys at the boundary
    stacked = np.stack(words, axis=1)
    assert len({tuple(r) for r in stacked}) == 3


def test_morton_overflow_raises_without_max_tiles():
    tiles = np.array([[1 << 16, 0]], np.int64)     # one past the budget
    with pytest.raises(ValueError, match="Morton budget"):
        morton_key_words(tiles)
    # empty input never raises (nothing to truncate)
    morton_key_words(np.zeros((0, 2), np.int64))


def test_morton_widening_preserves_order_and_distinguishes_big_ids():
    # ids above 2^16: with max_tiles the budget widens and distant ids
    # stay distinct; componentwise monotonicity survives widening.
    big = 1 << 17
    tiles = np.array([[0, 0], [1, 0], [65536, 0], [65537, 0],
                      [big - 1, big - 1]], np.int64)
    words = morton_key_words(tiles, max_tiles=big)
    stacked = np.stack(words, axis=1)
    assert len({tuple(r) for r in stacked}) == len(tiles)
    order = np.lexsort(tuple(reversed(words)))
    np.testing.assert_array_equal(order, np.arange(len(tiles)))


def test_morton_widening_is_order_preserving_for_small_ids():
    # prepended zero planes: in-budget ids sort identically with and
    # without widening (key-layout stability for the common case).
    rng = np.random.default_rng(0)
    tiles = rng.integers(0, 1 << 10, size=(200, 3)).astype(np.int64)
    narrow = morton_key_words(tiles)
    wide = morton_key_words(tiles, max_tiles=1 << 20)
    o_narrow = np.lexsort((np.arange(len(tiles)),)
                          + tuple(reversed(narrow)))
    o_wide = np.lexsort((np.arange(len(tiles)),) + tuple(reversed(wide)))
    np.testing.assert_array_equal(o_narrow, o_wide)


def test_locality_keys_max_rows_threads_to_widened_budget():
    # factor rows past the 16-bit tile budget: locality_keys(max_rows=)
    # must produce keys that still separate distant rows.
    frow = 8
    rows = np.array([[0], [frow * ((1 << 16) + 5)]], np.int64)
    keys = locality_keys(rows, "morton", frow_tile=frow,
                         max_rows=int(rows.max()) + 1)
    stacked = np.stack(keys, axis=1)
    assert not np.array_equal(stacked[0], stacked[1])
    with pytest.raises(ValueError, match="Morton budget"):
        locality_keys(rows, "morton", frow_tile=frow)
