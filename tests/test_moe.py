"""MoE dispatch (Dynasor-style sort-into-buckets) vs. dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.params import init_params


def _params(d, f, E, n_shared, seed=0):
    specs = {"m": moe.moe_specs(d, f, E, n_shared, E)}
    return init_params(specs, seed=seed)["m"]


def dense_reference(params, x, n_real, top_k):
    """Per-token loop over its top-k experts (no capacity, no buckets)."""
    b, l, d = x.shape
    xf = np.asarray(x).reshape(-1, d)
    probs, ids, _ = moe.router_assign(jnp.asarray(xf),
                                      params["router"], n_real, top_k)
    probs, ids = np.asarray(probs), np.asarray(ids)
    wg, wu, wd = (np.asarray(params["w_gate"]), np.asarray(params["w_up"]),
                  np.asarray(params["w_down"]))
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(top_k):
            e = ids[t, j]
            g = xf[t] @ wg[e]
            u = xf[t] @ wu[e]
            h = (g / (1 + np.exp(-g))) * u
            out[t] += probs[t, j] * (h @ wd[e])
    if "shared" in params:
        sh = params["shared"]
        g = xf @ np.asarray(sh["w_gate"])
        u = xf @ np.asarray(sh["w_up"])
        out += ((g / (1 + np.exp(-g))) * u) @ np.asarray(sh["w_down"])
    return out.reshape(b, l, d)


@pytest.mark.parametrize("top_k,n_shared", [(1, 0), (2, 1)])
def test_moe_matches_dense_reference_with_ample_capacity(top_k, n_shared):
    d, f, E = 16, 32, 4
    params = _params(d, f, E, n_shared)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, d)), jnp.float32)
    y, metrics = moe.moe_apply(params, x, n_real=E, top_k=top_k,
                               deterministic_cap=64)
    assert int(metrics["moe_dropped"]) == 0
    ref = dense_reference(params, x, E, top_k)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=3e-4, atol=3e-4)


def test_padding_experts_never_routed():
    d, f = 8, 16
    E_real, E_pad = 3, 4
    specs = {"m": moe.moe_specs(d, f, E_pad, 0, E_real)}
    params = init_params(specs, seed=1)["m"]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, d)), jnp.float32)
    probs, ids, _ = moe.router_assign(x, params["router"], E_real, 2)
    assert int(np.asarray(ids).max()) < E_real


def test_overflow_drops_are_counted():
    d, f, E = 8, 16, 2
    params = _params(d, f, E, 0, seed=2)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 64, d)), jnp.float32)
    y, metrics = moe.moe_apply(params, x, n_real=E, top_k=1,
                               deterministic_cap=4)
    # 64 tokens into 2 experts with cap 4 → at least 56 dropped
    assert int(metrics["moe_dropped"]) >= 56
    assert np.all(np.isfinite(np.asarray(y)))


def test_aux_loss_favors_balance():
    d, f, E = 8, 16, 4
    params = _params(d, f, E, 0, seed=3)
    T = 256
    xf = jnp.asarray(np.random.default_rng(3).standard_normal((T, d)),
                     jnp.float32)
    _, _, aux = moe.router_assign(xf, params["router"], E, 1)
    # perfectly balanced → aux == 1; wildly imbalanced → > 1
    assert 0.9 < float(aux) < 4.0
