"""Launch layer: input specs, flops accounting, HLO collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, skip_reason
from repro.launch.flops import step_costs
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.models import steps as steps_lib


def test_input_specs_cover_all_runnable_cells():
    for name, cfg in ARCHS.items():
        for shape in SHAPES.values():
            if skip_reason(cfg, shape):
                continue
            specs = steps_lib.input_specs(cfg, shape)
            if shape.kind in ("train", "prefill"):
                assert "tokens" in specs
                tok = specs["tokens"]
                assert tok.dtype == jnp.int32
                assert tok.shape[0] == shape.global_batch
                if cfg.family == "encdec":
                    assert "frames" in specs
                    assert (specs["frames"].shape[1] + tok.shape[1]
                            == shape.seq_len)
                else:
                    assert tok.shape[1] == shape.seq_len
                if cfg.family == "vlm":
                    assert specs["img"].shape[1] == cfg.n_img_tokens
            else:
                assert set(specs) == {"cache", "token", "pos"}
                leaves = jax.tree.leaves(specs["cache"])
                assert leaves, name
                # attention caches carry the full context length
                if any(k in "".join(cfg.pattern)
                       for k in ("attn",)):
                    assert any(l.shape[2] == shape.seq_len
                               for l in leaves if l.ndim == 5), name


def test_flops_counter_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    costs = step_costs(f, x, w)
    assert costs["flops"] == 8 * 2 * 128 ** 3


def test_flops_counter_handles_remat_and_grad():
    def loss(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        body = jax.checkpoint(body)
        out, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(out ** 2)

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    fwd = step_costs(lambda w, x: loss(w, x), w, x)["flops"]
    both = step_costs(jax.grad(loss), w, x)["flops"]
    # grad ≈ fwd (recompute) + 2×fwd (two matmuls per dot in bwd) ⇒ ≥ 3×
    assert both >= 3 * fwd * 0.9


def test_collective_parser():
    hlo = """
  ENTRY main {
    %p = f32[16,128]{1,0} parameter(0)
    %ag = f32[64,128]{1,0} all-gather(%p), replica_groups={}
    %ar = f32[64,128]{1,0} all-reduce(%ag), to_apply=%add
    %a2a.1 = bf16[8,32]{1,0} all-to-all(%p), dimensions={0}
    %cp = f32[16,128]{1,0} collective-permute(%p), source_target_pairs={}
    %ard = f32[64,128]{1,0} all-reduce-done(%ar)
  }
"""
    out = collective_bytes(hlo)
    kinds = out["bytes_by_kind"]
    assert kinds["all-gather"] == 64 * 128 * 4
    assert kinds["all-reduce"] == 64 * 128 * 4    # -done not double counted
    assert kinds["all-to-all"] == 8 * 32 * 2
    assert kinds["collective-permute"] == 16 * 128 * 4


def test_roofline_terms_pick_dominant():
    t = roofline_terms(flops=197e12, hbm_bytes=0, coll_bytes=0)
    assert t["dominant"] == "compute_s" and abs(t["compute_s"] - 1.0) < 1e-6
    t = roofline_terms(flops=0, hbm_bytes=819e9, coll_bytes=0)
    assert t["dominant"] == "memory_s" and abs(t["memory_s"] - 1.0) < 1e-6
    t = roofline_terms(flops=0, hbm_bytes=0, coll_bytes=50e9)
    assert t["dominant"] == "collective_s"


def test_model_flops_vs_param_count_sane():
    """6·N·D consistency: qwen3 train cell."""
    from repro.launch.dryrun import _model_flops
    cfg = get_config("qwen3-32b")
    shape = SHAPES["train_4k"]
    per_chip = _model_flops(cfg, shape, 256)
    total = per_chip * 256
    expect = 6 * cfg.param_count(active_only=True) * 256 * 4096
    assert abs(total - expect) / expect < 1e-6
