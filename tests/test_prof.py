"""repro.obs.prof — timing harness, self-time attribution, roofline,
noise-aware timed gate (PR-10).

Coverage per the issue checklist:
  * robust stats: median/MAD arithmetic, modified-z outlier rejection
    (and its >=4-sample guard), deterministic fake-clock measurement;
  * self-time attribution: self times partition the wall clock exactly,
    top-down paths, bottom-up recursion guard under same-name nesting,
    collapsed-stack flamegraph format;
  * the same-name-nesting ``self_counters`` regression (the tracer fix
    this PR's roofline join relies on): aggregating self deltas by name
    never double-counts;
  * roofline join: ``planner.*`` bytes excluded, moved-bytes basis
    preference (pipelined+index_stream > model > sum), label folding,
    backend→rung defaulting, per-mode breakdown shares;
  * the timed gate — both directions, by arithmetic rather than luck:
    an injected 2x slowdown fails; seeded same-distribution jitter
    passes across many seeds; host-noise/fingerprint/sub-resolution/
    per-phase-noise all SKIP or soften instead of flaking;
  * ``run_profile`` with an injected fast collect, and every
    ``python -m repro.obs.prof`` CLI path (run/report/gate).
"""
import json
import random

import pytest

from repro.obs import counters as ocnt
from repro.obs import tracer as otr
from repro.obs.prof import gate as pgate
from repro.obs.prof import harness as ph
from repro.obs.prof import roofline as prf
from repro.obs.prof import selftime as pst
from repro.obs.prof import __main__ as prof_main


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _rec(sid, parent, name, t0, t1, *, args=None, counters=None,
         self_counters=None, depth=0):
    return otr.SpanRecord(sid=sid, parent=parent, depth=depth, name=name,
                          args=args or {}, t0=t0, t1=t1,
                          counters=counters or {},
                          self_counters=self_counters or {})


def _mk_prof(phases, *, noise=0.02, fingerprint=None):
    """A minimal schema-valid PROF artifact from {name: (median, mad_frac)}."""
    fp = fingerprint or ph.env_fingerprint()
    body = {}
    for name, (median, mad_frac) in phases.items():
        mad = mad_frac * median / ph.MAD_SIGMA
        body[name] = {"n": 3, "median_s": median, "mad_s": mad,
                      "mad_frac": mad_frac, "mean_s": median,
                      "min_s": median, "max_s": median, "rejected": 0,
                      "samples_s": [median] * 3}
    return {
        "meta": {"schema": pgate.PROF_SCHEMA, "fingerprint": fp,
                 "noise": {"mad_frac": noise}, "workload": {"tensor": "t"},
                 "repeats": 3, "warmup": 1},
        "phases": body,
        "selftime": {"top_down": [], "bottom_up": []},
        "roofline": [],
        "breakdown": [],
    }


# ---------------------------------------------------------------------------
# harness: robust stats + steady-state measurement
# ---------------------------------------------------------------------------

def test_robust_stats_median_mad():
    st = ph.robust_stats([1.0, 2.0, 3.0])
    assert st.median_s == 2.0
    assert st.mad_s == 1.0
    assert st.rejected == 0
    assert st.kept_s == (1.0, 2.0, 3.0)
    # even-length median is the midpoint
    assert ph.robust_stats([1.0, 2.0, 3.0, 4.0]).median_s == 2.5


def test_robust_stats_rejects_outlier_and_recomputes():
    samples = [1.0, 1.01, 0.99, 1.0, 1.02, 0.98, 100.0]
    st = ph.robust_stats(samples)
    assert st.rejected == 1
    assert 100.0 not in st.kept_s
    assert st.median_s == pytest.approx(1.0, abs=0.02)
    assert st.max_s < 2.0                       # summary is post-rejection
    assert st.samples_s == tuple(samples)       # raw samples preserved


def test_robust_stats_needs_four_samples_to_reject():
    # 3 samples: the 100.0 would dominate its own z-score; keep everything.
    st = ph.robust_stats([1.0, 1.0, 100.0])
    assert st.rejected == 0 and 100.0 in st.kept_s


def test_robust_stats_identical_samples_no_rejection():
    # MAD == 0 would divide by zero in the modified z-score; guard skips.
    st = ph.robust_stats([2.0] * 6)
    assert st.rejected == 0 and st.mad_s == 0.0 and st.mad_frac == 0.0


def test_robust_stats_empty_raises():
    with pytest.raises(ValueError):
        ph.robust_stats([])


def test_mad_frac_is_sigma_scaled():
    st = ph.robust_stats([1.0, 1.1, 0.9])
    assert st.mad_frac == pytest.approx(ph.MAD_SIGMA * st.mad_s / st.median_s)


def test_measure_steady_fake_clock_and_warmup():
    calls = []
    ticks = iter(range(1000))

    def clock():
        return float(next(ticks))

    def fn():
        calls.append(1)
        return len(calls)

    st = ph.measure_steady(fn, warmup=2, repeats=5, clock=clock, block=None)
    assert len(calls) == 7                      # 2 warmup + 5 timed
    assert len(st.samples_s) == 5
    # each sample brackets exactly one clock pair -> duration 1.0 tick
    assert st.median_s == 1.0 and st.mad_s == 0.0


def test_measure_steady_block_fences_every_call():
    fenced = []
    ph.measure_steady(lambda: "x", warmup=1, repeats=3,
                      block=lambda v: fenced.append(v))
    assert fenced == ["x"] * 4


def test_measure_steady_rejects_zero_repeats():
    with pytest.raises(ValueError):
        ph.measure_steady(lambda: None, repeats=0)


def test_fingerprint_strict_keys_only():
    fp = ph.env_fingerprint()
    assert ph.fingerprint_compatible(fp, dict(fp)) == []
    other = dict(fp)
    other["python"] = "0.0.0"                   # informational: ignored
    assert ph.fingerprint_compatible(fp, other) == []
    other["cpu_count"] = (fp.get("cpu_count") or 0) + 64
    mism = ph.fingerprint_compatible(fp, other)
    assert len(mism) == 1 and "cpu_count" in mism[0]


def test_noise_calibration_shape():
    noise = ph.noise_calibration(repeats=5, warmup=1)
    assert set(noise) >= {"workload", "median_s", "mad_frac", "samples_s"}
    assert noise["median_s"] > 0
    assert len(noise["samples_s"]) == 5


# ---------------------------------------------------------------------------
# self-time attribution
# ---------------------------------------------------------------------------

def _forest():
    # root[0,10] -> a[0,4] -> c[1,2]; root -> b[4,9]
    return [
        _rec(0, -1, "root", 0.0, 10.0),
        _rec(1, 0, "a", 0.0, 4.0, depth=1),
        _rec(2, 1, "c", 1.0, 2.0, depth=2),
        _rec(3, 0, "b", 4.0, 9.0, depth=1),
    ]


def test_self_times_partition_wall_clock():
    recs = _forest()
    selfs = pst.self_times_s(recs)
    assert selfs[0] == pytest.approx(1.0)       # 10 - (4 + 5)
    assert selfs[1] == pytest.approx(3.0)       # 4 - 1
    assert selfs[2] == pytest.approx(1.0)
    assert selfs[3] == pytest.approx(5.0)
    # the partition property: self times sum exactly to the root total
    assert sum(selfs.values()) == pytest.approx(10.0)


def test_self_time_clamped_at_zero():
    recs = [_rec(0, -1, "p", 0.0, 1.0),
            _rec(1, 0, "q", 0.0, 1.0 + 1e-12, depth=1)]
    assert pst.self_times_s(recs)[0] == 0.0


def test_topdown_paths_and_fractions():
    rows = pst.topdown_table(_forest())
    by_path = {r["path"]: r for r in rows}
    assert set(by_path) == {"root", "root;a", "root;a;c", "root;b"}
    assert by_path["root;b"]["self_s"] == pytest.approx(5.0)
    assert by_path["root;b"]["self_frac"] == pytest.approx(0.5)
    assert rows[0]["path"] == "root;b"          # sorted by self desc
    assert sum(r["self_frac"] for r in rows) == pytest.approx(1.0)


def test_bottomup_recursion_guard_same_name_nesting():
    # x[0,10] -> x[2,5]: inclusive total must count the outer span only.
    recs = [_rec(0, -1, "x", 0.0, 10.0),
            _rec(1, 0, "x", 2.0, 5.0, depth=1)]
    row = pst.bottomup_table(recs)[0]
    assert row["name"] == "x" and row["calls"] == 2
    assert row["total_s"] == pytest.approx(10.0)   # not 13
    assert row["self_s"] == pytest.approx(10.0)    # 7 outer + 3 inner


def test_flamegraph_collapsed_stack_format(tmp_path):
    lines = pst.flamegraph_lines(_forest())
    assert "root;a;c 1000000" in lines
    assert "root;b 5000000" in lines
    for ln in lines:
        path, _, val = ln.rpartition(" ")
        assert path and int(val) >= 0
    out = pst.write_flamegraph(_forest(), str(tmp_path / "f.folded"))
    text = open(out).read().strip().splitlines()
    assert sorted(text) == sorted(lines)
    # a second write without overwrite picks a fresh name
    out2 = pst.write_flamegraph(_forest(), str(tmp_path / "f.folded"))
    assert out2 != out


def test_span_paths_sanitize_names():
    recs = [_rec(0, -1, "bad name\nhere", 0.0, 1.0)]
    path = pst.span_paths(recs)[0]
    assert "\n" not in path
    assert path == otr.sanitize_span_name("bad name\nhere")


# ---------------------------------------------------------------------------
# self_counters under same-name nesting (the tracer regression this
# PR's roofline join depends on)
# ---------------------------------------------------------------------------

def test_self_counters_no_double_count_under_same_name_nesting():
    reg = ocnt.CounterRegistry()
    tracer = otr.Tracer()
    with ocnt.use_registry(reg):
        with tracer.span("oocore.mode_step"):
            reg.add("oocore.chunks", 5)
            with tracer.span("oocore.mode_step"):
                reg.add("oocore.chunks", 7)
            reg.add("oocore.chunks", 2)
    inner, outer = tracer.records          # inner closes first
    assert inner.name == outer.name == "oocore.mode_step"
    assert inner.self_counters == {"oocore.chunks": 7}
    assert outer.self_counters == {"oocore.chunks": 7}  # 5 + 2
    assert outer.counters == {"oocore.chunks": 14}      # inclusive
    # aggregate by name (what the roofline join does): no double count
    agg = {}
    for r in tracer.records:
        for k, v in r.self_counters.items():
            agg[k] = agg.get(k, 0) + v
    assert agg == {"oocore.chunks": 14}
    assert agg["oocore.chunks"] == reg.get("oocore.chunks")


# ---------------------------------------------------------------------------
# roofline join
# ---------------------------------------------------------------------------

def test_roofline_prefers_pipelined_plus_index_stream():
    recs = [_rec(0, -1, "oocore.mode_step", 0.0, 1.0,
                 args={"backend": "pallas_fused_gather_stream",
                       "rung": "stream", "ordering": "tile"},
                 self_counters={
                     "oocore.dma.pipelined_bytes": 1000,
                     "oocore.dma.index_stream_bytes": 24,
                     "oocore.dma.scheduled_bytes": 5000,
                     "oocore.dma.distinct_bytes": 800,
                 })]
    (row,) = prf.bandwidth_rows(recs)
    assert row["basis"] == "pipelined+index_stream"
    assert row["moved_bytes"] == 1024
    assert row["achieved_gbps"] == pytest.approx(1024 / 1e9)
    assert row["rung"] == "stream" and row["ordering"] == "tile"
    # the scheduled/distinct spread stays visible per counter
    assert row["per_counter_gbps"]["oocore.dma.scheduled_bytes"] == \
        pytest.approx(5000 / 1e9)


def test_roofline_model_basis_and_rung_default():
    recs = [_rec(0, -1, "ops.device_step", 0.0, 2.0,
                 args={"backend": "pallas_fused"},
                 self_counters={"ops.step.model_bytes{backend=pallas_fused}":
                                4096})]
    (row,) = prf.bandwidth_rows(recs)
    assert row["basis"] == "model"
    assert row["moved_bytes"] == 4096
    assert row["rung"] == prf.RUNG_BY_BACKEND["pallas_fused"]  # defaulted
    assert row["achieved_gbps"] == pytest.approx(4096 / 2.0 / 1e9)


def test_roofline_sum_fallback_and_label_folding():
    recs = [_rec(0, -1, "remap", 0.0, 1.0,
                 self_counters={"remap.a2a.exchanged_bytes{transition=0}": 60,
                                "remap.a2a.exchanged_bytes{transition=1}": 40})]
    (row,) = prf.bandwidth_rows(recs)
    assert row["basis"] == "sum"
    assert row["moved_bytes"] == 100
    assert row["counted_bytes"] == {"remap.a2a.exchanged_bytes": 100}


def test_roofline_excludes_planner_plan_bytes():
    # plan_bytes sizes a VMEM plan, not traffic — must never fabricate
    # a bandwidth row (the bug the baseline regeneration caught).
    recs = [_rec(0, -1, "mttkrp", 0.0, 1.0,
                 self_counters={"planner.vmem.plan_bytes{rung=whole}":
                                504832})]
    assert prf.bandwidth_rows(recs) == []
    recs2 = [_rec(0, -1, "mttkrp", 0.0, 1.0,
                  self_counters={"planner.vmem.plan_bytes": 504832,
                                 "ops.step.model_bytes": 100})]
    (row,) = prf.bandwidth_rows(recs2)
    assert row["moved_bytes"] == 100
    assert "planner.vmem.plan_bytes" not in row["counted_bytes"]


def test_roofline_groups_and_skips_byteless_spans():
    recs = [
        _rec(0, -1, "step", 0.0, 1.0, args={"backend": "ref"},
             self_counters={"ops.step.model_bytes": 100}),
        _rec(1, -1, "step", 1.0, 3.0, args={"backend": "ref"},
             self_counters={"ops.step.model_bytes": 300}),
        _rec(2, -1, "step", 3.0, 4.0, args={"backend": "pallas"},
             self_counters={"ops.step.model_bytes": 100}),
        _rec(3, -1, "solve", 4.0, 5.0),        # no bytes: no row
    ]
    rows = prf.bandwidth_rows(recs)
    assert len(rows) == 2
    ref = next(r for r in rows if r["backend"] == "ref")
    assert ref["calls"] == 2 and ref["moved_bytes"] == 400
    assert ref["time_s"] == pytest.approx(3.0)
    assert not any(r["span"] == "solve" for r in rows)


def test_mode_breakdown_shares_and_child_split():
    recs = [
        _rec(0, -1, "sweep", 0.0, 10.0),
        _rec(1, 0, "mode", 0.0, 6.0, args={"mode": 0}, depth=1),
        _rec(2, 1, "mttkrp", 0.0, 3.0, depth=2),
        _rec(3, 1, "solve", 3.0, 4.0, depth=2),
        _rec(4, 1, "remap", 4.0, 5.5, depth=2),
        _rec(5, 0, "mode", 6.0, 10.0, args={"mode": 1}, depth=1),
        _rec(6, 5, "mttkrp", 6.0, 8.0, depth=2),
    ]
    rows = prf.mode_breakdown(recs)
    assert [r["mode"] for r in rows] == [0, 1]
    m0, m1 = rows
    assert m0["total_s"] == pytest.approx(6.0)
    assert m0["mttkrp_s"] == pytest.approx(3.0)
    assert m0["solve_s"] == pytest.approx(1.0)
    assert m0["remap_s"] == pytest.approx(1.5)
    assert m0["other_s"] == pytest.approx(0.5)
    assert m1["mttkrp_s"] == pytest.approx(2.0)
    assert m0["share_frac"] + m1["share_frac"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# PROF schema validation
# ---------------------------------------------------------------------------

def test_validate_prof_accepts_synthetic_artifact():
    assert pgate.validate_prof(_mk_prof({"mttkrp": (1.0, 0.02)})) == []


@pytest.mark.parametrize("mutate,needle", [
    (lambda p: p.pop("meta"), "meta"),
    (lambda p: p["meta"].update(schema=99), "schema"),
    (lambda p: p["meta"].pop("noise"), "noise"),
    (lambda p: p["meta"]["noise"].pop("mad_frac"), "mad_frac"),
    (lambda p: p.update(phases={}), "phases"),
    (lambda p: p["phases"]["mttkrp"].pop("median_s"), "median_s"),
    (lambda p: p["phases"]["mttkrp"].update(samples_s=[]), "samples_s"),
    (lambda p: p.pop("selftime"), "selftime"),
    (lambda p: p.pop("roofline"), "roofline"),
    (lambda p: p.pop("breakdown"), "breakdown"),
])
def test_validate_prof_catches_each_break(mutate, needle):
    prof = _mk_prof({"mttkrp": (1.0, 0.02)})
    mutate(prof)
    errors = pgate.validate_prof(prof)
    assert errors and any(needle in e for e in errors)


def test_validate_prof_non_dict():
    assert pgate.validate_prof([1, 2]) == ["PROF artifact is not a dict"]


# ---------------------------------------------------------------------------
# the timed gate: both directions, by arithmetic not luck
# ---------------------------------------------------------------------------

def test_gate_catches_injected_2x_slowdown():
    base = _mk_prof({"mttkrp": (1.0, 0.02), "solve": (0.5, 0.02)})
    cur = _mk_prof({"mttkrp": (2.0, 0.02), "solve": (0.5, 0.02)})
    result = pgate.compare(cur, base)
    assert result.status == "fail" and result.exit_status == 1
    verdicts = {r["phase"]: r["verdict"] for r in result.phases}
    assert verdicts == {"mttkrp": "regressed", "solve": "ok"}
    # 2.0 > 1.5 + 3.0 * 0.04 = 1.62: the failure is threshold arithmetic
    row = next(r for r in result.phases if r["phase"] == "mttkrp")
    assert row["ratio"] == pytest.approx(2.0)
    assert row["threshold"] == pytest.approx(
        pgate.MAX_RATIO + pgate.TOLERANCE_Z * 0.04)
    assert any("re-baseline" in m for m in result.messages)


def test_gate_passes_on_seeded_same_distribution_jitter():
    # Noise tolerance proven by construction: for *any* seed, samples
    # drawn within ±8% of the same median stay under the noise-scaled
    # threshold, because the worst-case ratio 1.08/0.92 ≈ 1.17 < 1.5.
    for seed in range(20):
        rng = random.Random(seed)

        def draw():
            return ph.robust_stats(
                [1.0 * (1 + rng.uniform(-0.08, 0.08)) for _ in range(5)]
            ).to_json()

        base = _mk_prof({})
        cur = _mk_prof({})
        for prof in (base, cur):
            prof["phases"] = {"mttkrp": draw(), "sweep": draw()}
        result = pgate.compare(cur, base)
        assert result.status == "pass", (seed, result.messages)
        for row in result.phases:
            assert row["ratio"] < row["threshold"]
            assert row["threshold"] >= pgate.MAX_RATIO   # slack only widens


def test_gate_consecutive_runs_pass_against_same_baseline():
    # The acceptance shape: two fresh same-distribution runs, one
    # committed baseline, both gates green.
    rng = random.Random(1234)

    def fresh():
        return _mk_prof({}) | {"phases": {
            "mttkrp": ph.robust_stats(
                [0.8 + rng.uniform(-0.03, 0.03) for _ in range(5)]).to_json(),
            "run.total": ph.robust_stats(
                [2.0 + rng.uniform(-0.05, 0.05) for _ in range(5)]).to_json(),
        }}

    base = fresh()
    assert pgate.compare(fresh(), base).status == "pass"
    assert pgate.compare(fresh(), base).status == "pass"


def test_gate_skips_on_noisy_host():
    base = _mk_prof({"mttkrp": (1.0, 0.02)})
    cur = _mk_prof({"mttkrp": (5.0, 0.02)}, noise=0.5)  # 5x slower but...
    result = pgate.compare(cur, base)
    assert result.status == "skip" and result.exit_status == 0
    assert any("host-noise" in m for m in result.messages)
    # ...and symmetric: a noisy *baseline* also refuses to gate
    noisy_base = _mk_prof({"mttkrp": (1.0, 0.02)}, noise=0.5)
    assert pgate.compare(base, noisy_base).status == "skip"


def test_gate_skips_on_fingerprint_mismatch():
    fp = ph.env_fingerprint()
    other = dict(fp, cpu_count=(fp.get("cpu_count") or 0) + 64)
    base = _mk_prof({"mttkrp": (1.0, 0.02)}, fingerprint=other)
    cur = _mk_prof({"mttkrp": (9.0, 0.02)})
    result = pgate.compare(cur, base)
    assert result.status == "skip"
    assert any("fingerprint" in m for m in result.messages)


def test_gate_noisy_phase_reported_never_failed():
    base = _mk_prof({"mttkrp": (1.0, 0.02)})
    cur = _mk_prof({"mttkrp": (3.0, 0.40)})     # wildly noisy phase
    result = pgate.compare(cur, base)
    assert result.status == "pass"
    assert result.phases[0]["verdict"] == "noisy"


def test_gate_sub_resolution_phase_never_failed():
    base = _mk_prof({"tick": (1e-6, 0.0)})
    cur = _mk_prof({"tick": (5e-5, 0.0)})       # 50x but under 100µs
    result = pgate.compare(cur, base)
    assert result.status == "pass"
    assert result.phases[0]["verdict"] == "sub-resolution"


def test_gate_improvement_is_not_a_failure():
    base = _mk_prof({"mttkrp": (2.0, 0.02)})
    cur = _mk_prof({"mttkrp": (0.5, 0.02)})
    result = pgate.compare(cur, base)
    assert result.status == "pass"
    assert result.phases[0]["verdict"] == "improved"


def test_gate_notes_phase_set_drift():
    base = _mk_prof({"old": (1.0, 0.02), "both": (1.0, 0.02)})
    cur = _mk_prof({"new": (1.0, 0.02), "both": (1.0, 0.02)})
    result = pgate.compare(cur, base)
    assert result.status == "pass"
    assert any("'old' in baseline only" in m for m in result.messages)
    assert any("'new' is new" in m for m in result.messages)


def test_gate_no_common_phases_skips():
    result = pgate.compare(_mk_prof({"a": (1.0, 0.0)}),
                           _mk_prof({"b": (1.0, 0.0)}))
    assert result.status == "skip"


def test_gate_invalid_artifact_fails_loudly():
    good = _mk_prof({"mttkrp": (1.0, 0.02)})
    result = pgate.compare({"nope": 1}, good)
    assert result.status == "fail"
    assert any("current artifact invalid" in m for m in result.messages)


# ---------------------------------------------------------------------------
# run_profile with an injected fast collect + CLI paths
# ---------------------------------------------------------------------------

def _fake_collect_factory(extra_span_first_call=False):
    state = {"calls": 0}

    def collect(tracer=None):
        state["calls"] += 1
        with tracer.span("alpha", backend="ref"):
            with tracer.span("beta"):
                pass
        if extra_span_first_call and state["calls"] == 1:
            with tracer.span("flaky-once"):
                pass
        return {"counters": {"oocore.chunks": 3}}

    return collect, state


def test_run_profile_synthetic_collect_emits_valid_prof():
    collect, state = _fake_collect_factory()
    prof, records = prof_main.run_profile(repeats=3, warmup=1,
                                          collect=collect)
    assert pgate.validate_prof(prof) == []
    assert state["calls"] == 4                  # 1 warmup + 3 timed
    assert {"alpha", "beta", "run.total"} <= set(prof["phases"])
    for ph_row in prof["phases"].values():
        assert ph_row["n"] == 3                 # one sample per repeat
    assert prof["counters"] == {"oocore.chunks": 3}
    assert {r.name for r in records} == {"alpha", "beta"}


def test_run_profile_drops_phases_missing_from_some_repeat():
    collect, _ = _fake_collect_factory(extra_span_first_call=True)
    # warmup absorbs the first call, so the flaky span appears in zero
    # timed repeats here; flip warmup to 0 to land it in repeat 1 only.
    prof, _ = prof_main.run_profile(repeats=2, warmup=0, collect=collect)
    assert "flaky-once" not in prof["phases"]
    assert "alpha" in prof["phases"]


def test_run_profile_rejects_zero_repeats():
    collect, _ = _fake_collect_factory()
    with pytest.raises(ValueError):
        prof_main.run_profile(repeats=0, collect=collect)


@pytest.fixture()
def prof_tmp_paths(tmp_path, monkeypatch):
    """Point every prof CLI artifact at tmp so tests never touch the
    repo's committed experiments/obs/."""
    monkeypatch.setattr(prof_main, "RUN_PATH",
                        str(tmp_path / "PROF_run.json"))
    monkeypatch.setattr(prof_main, "BASELINE_PATH",
                        str(tmp_path / "PROF_baseline.json"))
    monkeypatch.setattr(prof_main, "FLAME_PATH",
                        str(tmp_path / "PROF_flame.folded"))
    monkeypatch.setattr(prof_main, "TRACE_PATH",
                        str(tmp_path / "PROF_trace.json"))
    return tmp_path


def test_cli_run_writes_artifacts(prof_tmp_paths, monkeypatch, capsys):
    from repro.obs import baseline as obaseline

    collect, _ = _fake_collect_factory()
    monkeypatch.setattr(obaseline, "collect", collect)
    rc = prof_main.main(["run", "--repeats", "2", "--warmup", "1"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "phases" in out
    prof = json.load(open(prof_tmp_paths / "PROF_run.json"))
    assert pgate.validate_prof(prof) == []
    assert (prof_tmp_paths / "PROF_flame.folded").exists()
    trace = json.load(open(prof_tmp_paths / "PROF_trace.json"))
    assert otr.validate_chrome_trace(trace, expect_names=["alpha", "beta"]) \
        == []


def test_cli_run_update_baseline_then_gate_passes(prof_tmp_paths,
                                                  monkeypatch, capsys):
    from repro.obs import baseline as obaseline

    collect, _ = _fake_collect_factory()
    monkeypatch.setattr(obaseline, "collect", collect)
    assert prof_main.main(["run", "--update-baseline"]) == 0
    assert (prof_tmp_paths / "PROF_baseline.json").exists()
    assert prof_main.main(["run"]) == 0
    capsys.readouterr()
    rc = prof_main.main(["gate",
                         "--current", str(prof_tmp_paths / "PROF_run.json"),
                         "--baseline",
                         str(prof_tmp_paths / "PROF_baseline.json")])
    out = capsys.readouterr().out
    # same host, same synthetic workload: pass — or skip iff this CI
    # runner's own measured noise exceeded the bar (printed either way)
    assert rc == 0
    assert ("timed gate passed" in out) or ("SKIP" in out)


def test_cli_gate_missing_baseline_skips(prof_tmp_paths, capsys):
    rc = prof_main.main(["gate",
                         "--current", str(prof_tmp_paths / "nope.json"),
                         "--baseline", str(prof_tmp_paths / "missing.json")])
    assert rc == 0
    assert "SKIP no timed baseline" in capsys.readouterr().out


def test_cli_gate_missing_current_fails(prof_tmp_paths, capsys):
    base = _mk_prof({"mttkrp": (1.0, 0.02)})
    bpath = prof_tmp_paths / "PROF_baseline.json"
    bpath.write_text(json.dumps(base))
    rc = prof_main.main(["gate", "--current",
                         str(prof_tmp_paths / "absent.json"),
                         "--baseline", str(bpath)])
    assert rc == 1
    assert "FAIL no current profile" in capsys.readouterr().out


def test_cli_gate_fails_on_2x_and_report_only_softens(prof_tmp_paths,
                                                      capsys):
    base = _mk_prof({"mttkrp": (1.0, 0.01)})
    cur = _mk_prof({"mttkrp": (2.0, 0.01)})
    bpath = prof_tmp_paths / "base.json"
    cpath = prof_tmp_paths / "cur.json"
    bpath.write_text(json.dumps(base))
    cpath.write_text(json.dumps(cur))
    argv = ["gate", "--current", str(cpath), "--baseline", str(bpath)]
    assert prof_main.main(argv) == 1
    assert "FAILED" in capsys.readouterr().out
    assert prof_main.main(argv + ["--report-only"]) == 0
    assert "exit forced to 0" in capsys.readouterr().out


def test_cli_report_renders_and_rejects_invalid(prof_tmp_paths, capsys):
    prof = _mk_prof({"mttkrp": (1.0, 0.02)})
    prof["selftime"]["top_down"] = [
        {"path": "sweep;mode", "calls": 2, "total_s": 1.0, "self_s": 0.5,
         "self_frac": 0.5, "self_counters": {}}]
    prof["selftime"]["bottom_up"] = [
        {"name": "mode", "calls": 2, "total_s": 1.0, "self_s": 0.5,
         "self_frac": 0.5, "self_counters": {}}]
    prof["roofline"] = [
        {"span": "oocore.mode_step", "backend": "s", "rung": "stream",
         "ordering": "tile", "calls": 3, "time_s": 1.0,
         "moved_bytes": 1024, "basis": "pipelined+index_stream",
         "achieved_gbps": 1.0e-6, "per_counter_gbps": {},
         "counted_bytes": {}}]
    prof["breakdown"] = [
        {"mode": 0, "calls": 1, "total_s": 1.0, "mttkrp_s": 0.5,
         "solve_s": 0.2, "remap_s": 0.2, "other_s": 0.1,
         "share_frac": 1.0}]
    path = prof_tmp_paths / "p.json"
    path.write_text(json.dumps(prof))
    assert prof_main.main(["report", str(path)]) == 0
    out = capsys.readouterr().out
    for needle in ("phases", "top-down", "bottom-up", "achieved bandwidth",
                   "per-mode breakdown", "sweep;mode", "GB/s"):
        assert needle in out, needle
    bad = prof_tmp_paths / "bad.json"
    bad.write_text(json.dumps({"meta": {}}))
    assert prof_main.main(["report", str(bad)]) == 1


def test_committed_prof_baseline_is_schema_valid():
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "obs", "PROF_baseline.json")
    prof = json.load(open(path))
    assert pgate.validate_prof(prof) == []
    # the profiled workload covered the paper's phases and the roofline
    # join produced real rows
    assert {"mttkrp", "solve", "remap", "sweep", "run.total"} \
        <= set(prof["phases"])
    assert prof["roofline"], "committed baseline has no roofline rows"
    for row in prof["roofline"]:
        assert row["moved_bytes"] > 0 and row["achieved_gbps"] > 0
        assert not any(b.startswith("planner.")
                       for b in row["counted_bytes"])
