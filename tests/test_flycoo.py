"""FLYCOO format invariants (paper §III)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.flycoo import build_flycoo, choose_partition_params, pack_mode
from repro.core.tensors import frostt_like, random_sparse_tensor


def small_tensor(seed=0, nnz=300):
    return random_sparse_tensor((40, 30, 20), nnz, seed=seed,
                                distribution="powerlaw")


def test_partition_covers_every_nonzero_once_per_mode():
    t = small_tensor()
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    for n in range(t.nmodes):
        owner = ft.owner_of(n)
        assert owner.shape == (t.nnz,)
        assert owner.min() >= 0 and owner.max() < 4
        # owners come from the super-shard of the output index
        mp = ft.modes[n]
        expect = mp.super_to_device[t.indices[:, n] // mp.m]
        assert np.array_equal(owner, expect)


def test_row_perm_is_permutation_and_device_major():
    t = small_tensor()
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    for mp in ft.modes:
        dim = t.shape[mp.mode]
        assert sorted(mp.row_perm.tolist()) == sorted(
            set(mp.row_perm.tolist()))
        # round trip
        assert np.array_equal(mp.row_unperm[mp.row_perm], np.arange(dim))
        # device-major: each row's slot // rows_cap == its owner device
        owner_of_row = mp.super_to_device[np.arange(dim) // mp.m]
        assert np.array_equal(mp.row_perm // mp.rows_cap, owner_of_row)


def test_pack_mode_sorted_and_complete():
    t = small_tensor()
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    for n in range(t.nmodes):
        idx, val, mask = pack_mode(ft, n)
        assert mask.sum() == t.nnz
        assert abs(val[mask].sum() - t.values.sum()) < 1e-3
        for d in range(4):
            rows = idx[d, mask[d], n]
            assert np.all(np.diff(rows) >= 0)          # sorted by output row
            assert np.all(rows // ft.modes[n].rows_cap == d)   # owned


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_partition_params_satisfy_eq2(seed):
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(8, 5000)) for _ in range(3))
    p = choose_partition_params(shape, nnz=100_000, num_workers=8)
    for dim, m in zip(shape, p.m):
        k = -(-dim // m)
        # Eq.2: super-shard count ≥ workers (divisible up to the ragged tail)
        assert k >= 1
        if dim > 8:
            assert k >= 8 or m == 1


def test_frostt_profiles_build():
    for name in ("nell-2", "vast"):
        t = frostt_like(name, scale=0.02)
        ft = build_flycoo(t, 4)
        assert ft.nnz == t.nnz
        assert ft.params.g >= 1
