"""FLYCOO format invariants (paper §III) — including the PR-8
``repro.reorder`` extension: ``build_flycoo(ordering=...)`` /
``pack_mode`` locality sorting and ``build_block_layout``'s
``order_keys`` path keep every layout contract intact."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.flycoo import build_flycoo, choose_partition_params, pack_mode
from repro.core.tensors import frostt_like, random_sparse_tensor
from repro.kernels.mttkrp import ops as kops
from repro.reorder.ordering import ORDERINGS, locality_keys


def small_tensor(seed=0, nnz=300):
    return random_sparse_tensor((40, 30, 20), nnz, seed=seed,
                                distribution="powerlaw")


def test_partition_covers_every_nonzero_once_per_mode():
    t = small_tensor()
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    for n in range(t.nmodes):
        owner = ft.owner_of(n)
        assert owner.shape == (t.nnz,)
        assert owner.min() >= 0 and owner.max() < 4
        # owners come from the super-shard of the output index
        mp = ft.modes[n]
        expect = mp.super_to_device[t.indices[:, n] // mp.m]
        assert np.array_equal(owner, expect)


def test_row_perm_is_permutation_and_device_major():
    t = small_tensor()
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    for mp in ft.modes:
        dim = t.shape[mp.mode]
        assert sorted(mp.row_perm.tolist()) == sorted(
            set(mp.row_perm.tolist()))
        # round trip
        assert np.array_equal(mp.row_unperm[mp.row_perm], np.arange(dim))
        # device-major: each row's slot // rows_cap == its owner device
        owner_of_row = mp.super_to_device[np.arange(dim) // mp.m]
        assert np.array_equal(mp.row_perm // mp.rows_cap, owner_of_row)


def test_pack_mode_sorted_and_complete():
    t = small_tensor()
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    for n in range(t.nmodes):
        idx, val, mask = pack_mode(ft, n)
        assert mask.sum() == t.nnz
        assert abs(val[mask].sum() - t.values.sum()) < 1e-3
        for d in range(4):
            rows = idx[d, mask[d], n]
            assert np.all(np.diff(rows) >= 0)          # sorted by output row
            assert np.all(rows // ft.modes[n].rows_cap == d)   # owned


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_partition_params_satisfy_eq2(seed):
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(8, 5000)) for _ in range(3))
    p = choose_partition_params(shape, nnz=100_000, num_workers=8)
    for dim, m in zip(shape, p.m):
        k = -(-dim // m)
        # Eq.2: super-shard count ≥ workers (divisible up to the ragged tail)
        assert k >= 1
        if dim > 8:
            assert k >= 8 or m == 1


def _block_layout_invariants(row, valid, idx_in, ordering, *, rows_cap,
                             blk, tile_rows):
    """build_block_layout contract, with and without locality keys."""
    n_el = len(row)
    n_pad = kops.n_pad_for(n_el, rows_cap, blk, tile_rows)
    keys = locality_keys(idx_in, ordering)
    slot, tile_of_block = kops.build_block_layout(
        jnp.asarray(row), jnp.asarray(valid), rows_cap=rows_cap, blk=blk,
        tile_rows=tile_rows, order_keys=keys or None)
    slot = np.asarray(slot)
    tile_of_block = np.asarray(tile_of_block)

    # invalid -> dump slot; valid -> injective in-range slots
    assert np.all(slot[~valid] == n_pad)
    vslots = slot[valid]
    assert np.all((0 <= vslots) & (vslots < n_pad))
    assert len(np.unique(vslots)) == len(vslots)
    # each element's block is attributed to exactly its own output tile —
    # locality keys reorder *within* a tile, never across
    vtile = row[valid] // tile_rows
    assert np.array_equal(tile_of_block[vslots // blk], vtile)
    assert np.all(np.diff(tile_of_block) >= 0)
    # tile_of_block is independent of the ordering policy (same nonzeros
    # per tile, so the same block counts)
    base_slot, base_tiles = kops.build_block_layout(
        jnp.asarray(row), jnp.asarray(valid), rows_cap=rows_cap, blk=blk,
        tile_rows=tile_rows)
    assert np.array_equal(tile_of_block, np.asarray(base_tiles))
    # within a tile, slot order realizes the locality keys (ascending
    # lexicographically, most significant first)
    if keys:
        key_mat = np.stack([np.asarray(kk) for kk in keys], axis=1)
        for t in np.unique(vtile):
            sel = vtile == t
            run = key_mat[valid][sel][np.argsort(vslots[sel])]
            for prev, cur in zip(run, run[1:]):
                assert tuple(prev) <= tuple(cur)
    return slot, tile_of_block


@pytest.mark.parametrize("ordering", ORDERINGS)
def test_build_block_layout_order_keys_invariants(ordering):
    rng = np.random.default_rng(3)
    tiles, tile_rows, blk, n_el = 5, 8, 16, 230
    rows_cap = tiles * tile_rows
    row = np.sort(rng.integers(0, rows_cap, n_el)).astype(np.int32)
    valid = np.ones(n_el, bool)
    valid[-11:] = False
    idx_in = rng.integers(0, 4000, size=(n_el, 2)).astype(np.int32)
    idx_in[~valid] = 0
    _block_layout_invariants(row, valid, idx_in, ordering,
                             rows_cap=rows_cap, blk=blk,
                             tile_rows=tile_rows)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_el=st.integers(1, 300),
    tiles=st.integers(1, 6),
    tile_rows=st.sampled_from([8, 16]),
    blk=st.sampled_from([8, 16, 32]),
    k=st.integers(1, 4),
    ordering=st.sampled_from(ORDERINGS),
    frac_invalid=st.floats(0.0, 0.4),
)
def test_build_block_layout_order_keys_property(seed, n_el, tiles,
                                                tile_rows, blk, k, ordering,
                                                frac_invalid):
    rows_cap = tiles * tile_rows
    rng = np.random.default_rng(seed)
    row = np.sort(rng.integers(0, rows_cap, n_el)).astype(np.int32)
    valid = np.ones(n_el, bool)
    ninv = int(n_el * frac_invalid)
    if ninv:
        valid[-ninv:] = False
    idx_in = rng.integers(0, 10_000, size=(n_el, k)).astype(np.int32)
    idx_in[~valid] = 0
    _block_layout_invariants(row, valid, idx_in, ordering,
                             rows_cap=rows_cap, blk=blk,
                             tile_rows=tile_rows)


@pytest.mark.parametrize("ordering", ["tile", "morton"])
def test_pack_mode_with_ordering_keeps_contract(ordering):
    """A reorder policy on the FLYCOO tensor must not disturb anything
    pack_mode guarantees: same multiset per device, rows still sorted
    and owned — the locality keys only break ties within an output row."""
    t = small_tensor()
    base = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64))
    ft = build_flycoo(t, 4, m_bounds=(4, 16), g_bounds=(8, 64),
                      ordering=ordering)
    assert ft.ordering == ordering
    for n in range(t.nmodes):
        idx0, val0, mask0 = pack_mode(base, n)
        idx, val, mask = pack_mode(ft, n)
        assert mask.sum() == t.nnz
        for d in range(4):
            rows = idx[d, mask[d], n]
            assert np.all(np.diff(rows) >= 0)          # still row-sorted
            assert np.all(rows // ft.modes[n].rows_cap == d)   # owned
            # same nonzeros per device as the unordered pack (a
            # permutation within the device's slice)
            assert np.array_equal(np.sort(val[d, mask[d]]),
                                  np.sort(val0[d, mask0[d]]))
            order0 = np.lexsort(idx0[d, mask0[d]].T)
            order1 = np.lexsort(idx[d, mask[d]].T)
            assert np.array_equal(idx0[d, mask0[d]][order0],
                                  idx[d, mask[d]][order1])


def test_build_flycoo_rejects_unknown_ordering():
    with pytest.raises(ValueError, match="unknown ordering"):
        build_flycoo(small_tensor(), 4, ordering="hilbert")


def test_frostt_profiles_build():
    for name in ("nell-2", "vast"):
        t = frostt_like(name, scale=0.02)
        ft = build_flycoo(t, 4)
        assert ft.nnz == t.nnz
        assert ft.params.g >= 1


# ---------------------------------------------------------------------------
# Input validation (PR-9): reject malformed tensors before partitioning
# ---------------------------------------------------------------------------

from repro.core.tensors import SparseTensor


def _tensor(indices, values, shape=(8, 6, 5)):
    return SparseTensor(np.asarray(indices, np.int64).reshape(-1, len(shape)),
                        np.asarray(values, np.float32), shape)


def test_build_flycoo_empty_tensor():
    t = SparseTensor(np.zeros((0, 3), np.int64), np.zeros((0,), np.float32),
                     (8, 6, 5))
    ft = build_flycoo(t, 2)
    assert ft.nnz == 0
    for n in range(3):
        idx, val, mask = pack_mode(ft, n)
        assert mask.sum() == 0


def test_build_flycoo_single_nonzero():
    t = _tensor([[3, 2, 1]], [2.5])
    ft = build_flycoo(t, 2)
    assert ft.nnz == 1
    for n in range(3):
        idx, val, mask = pack_mode(ft, n)
        assert mask.sum() == 1
        assert val[mask][0] == np.float32(2.5)


def test_build_flycoo_max_index_boundary():
    # index == dim-1 in every mode is legal; == dim is not.
    ok = _tensor([[7, 5, 4], [0, 0, 0]], [1.0, 2.0])
    assert build_flycoo(ok, 2).nnz == 2
    bad = _tensor([[7, 6, 4]], [1.0])
    with pytest.raises(ValueError, match=r"mode-1 index out of range"):
        build_flycoo(bad, 2)


def test_build_flycoo_rejects_negative_index():
    with pytest.raises(ValueError, match=r"mode-2 index out of range"):
        build_flycoo(_tensor([[1, 1, -1]], [1.0]), 2)


def test_build_flycoo_rejects_nonfinite_value_naming_offender():
    t = _tensor([[1, 1, 1], [2, 2, 2], [3, 3, 3]],
                [1.0, np.nan, np.inf])
    with pytest.raises(ValueError, match=r"non-finite value at nonzero 1"):
        build_flycoo(t, 2)


def test_validate_tensor_rejects_shape_mismatches():
    # SparseTensor's own asserts catch these at construction, so drive
    # the validator directly with duck-typed stand-ins.
    from repro.core.flycoo import _validate_tensor

    class BadIdx:
        indices = np.zeros((4, 2), np.int64)     # 2 cols for a 3-mode shape
        values = np.zeros((4,), np.float32)
        shape = (8, 6, 5)

    class BadVal:
        indices = np.zeros((4, 3), np.int64)
        values = np.zeros((3,), np.float32)
        shape = (8, 6, 5)

    with pytest.raises(ValueError, match="indices must be"):
        _validate_tensor(BadIdx())
    with pytest.raises(ValueError, match="values must be"):
        _validate_tensor(BadVal())


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_build_flycoo_adversarial_corruption(seed):
    """Any single corrupted nonzero (index out of range either side, or
    non-finite value) is rejected with a ValueError — never a silently
    wrong partition."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(2, 12)) for _ in range(3))
    nnz = int(rng.integers(1, 40))
    idx = np.stack([rng.integers(0, d, size=nnz) for d in shape],
                   axis=1).astype(np.int64)
    val = rng.standard_normal(nnz).astype(np.float32)
    victim = int(rng.integers(0, nnz))
    mode = int(rng.integers(0, 3))
    attack = rng.choice(["high", "neg", "nan", "inf"])
    if attack == "high":
        idx[victim, mode] = shape[mode] + int(rng.integers(0, 1000))
    elif attack == "neg":
        idx[victim, mode] = -1 - int(rng.integers(0, 1000))
    elif attack == "nan":
        val[victim] = np.nan
    else:
        val[victim] = np.inf
    with pytest.raises(ValueError):
        build_flycoo(SparseTensor(idx, val, shape), 2)
