"""flash_attention / decode_attention vs. naive softmax reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention


def naive(q, k, v, mode, window, pos_q, pos_k):
    b, lq, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    qr = q.reshape(b, lq, kh, g, dh)
    s = np.einsum("bqkgd,bskd->bkgqs", qr, k) / np.sqrt(dh)
    pq = pos_q[:, None, None, :, None]
    pk = pos_k[:, None, None, None, :]
    if mode == "full":
        m = np.ones_like(s, bool)
    else:
        m = pk <= pq
        if mode == "local" and window:
            m = m & ((pq // window) == (pk // window))
    s = np.where(m, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, lq, h, dh)


@pytest.mark.parametrize("mode,window", [("causal", 0), ("full", 0),
                                         ("local", 8)])
@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2)])
def test_flash_matches_naive(mode, window, h, kh):
    rng = np.random.default_rng(0)
    b, lq, lk, dh = 2, 32, 32, 16
    q = rng.standard_normal((b, lq, h, dh)).astype(np.float32)
    k = rng.standard_normal((b, lk, kh, dh)).astype(np.float32)
    v = rng.standard_normal((b, lk, kh, dh)).astype(np.float32)
    pos = np.broadcast_to(np.arange(lq, dtype=np.int32), (b, lq)).copy()
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          pos_q=jnp.asarray(pos), pos_k=jnp.asarray(pos),
                          mode=mode, window=window, q_chunk=8, kv_chunk=8)
    ref = naive(q, k, v, mode, window, pos, pos)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_flash_chunk_invariance():
    rng = np.random.default_rng(1)
    b, l, h, dh = 1, 64, 4, 8
    q = rng.standard_normal((b, l, h, dh)).astype(np.float32)
    k = rng.standard_normal((b, l, h, dh)).astype(np.float32)
    v = rng.standard_normal((b, l, h, dh)).astype(np.float32)
    pos = np.arange(l, dtype=np.int32)[None]
    outs = []
    for qc, kc in [(8, 8), (16, 32), (64, 64)]:
        outs.append(np.asarray(flash_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            pos_q=jnp.asarray(pos), pos_k=jnp.asarray(pos),
            mode="causal", q_chunk=qc, kv_chunk=kc)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-5)


def test_decode_matches_flash_last_position():
    rng = np.random.default_rng(2)
    b, S, h, kh, dh = 2, 16, 4, 2, 8
    q_full = rng.standard_normal((b, S, h, dh)).astype(np.float32)
    k = rng.standard_normal((b, S, kh, dh)).astype(np.float32)
    v = rng.standard_normal((b, S, kh, dh)).astype(np.float32)
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (b, S)).copy()
    full = flash_attention(jnp.asarray(q_full), jnp.asarray(k),
                           jnp.asarray(v), pos_q=jnp.asarray(pos),
                           pos_k=jnp.asarray(pos), mode="causal",
                           q_chunk=8, kv_chunk=8)
    dec = decode_attention(jnp.asarray(q_full[:, -1:]), jnp.asarray(k),
                           jnp.asarray(v), cur_pos=jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec)[:, 0],
                               np.asarray(full)[:, -1], rtol=2e-4, atol=2e-4)


def test_fully_masked_rows_are_finite():
    """Local mode can mask an entire row for early chunks — no NaNs."""
    rng = np.random.default_rng(3)
    b, l, h, dh = 1, 16, 2, 8
    q = rng.standard_normal((b, l, h, dh)).astype(np.float32)
    k = rng.standard_normal((b, l, h, dh)).astype(np.float32)
    v = rng.standard_normal((b, l, h, dh)).astype(np.float32)
    pos_q = np.zeros((b, l), np.int32)         # everything before the keys
    pos_k = np.full((b, l), 100, np.int32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          pos_q=jnp.asarray(pos_q),
                          pos_k=jnp.asarray(pos_k), mode="causal",
                          q_chunk=8, kv_chunk=8)
    assert np.all(np.isfinite(np.asarray(out)))
