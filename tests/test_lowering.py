"""Compiled-path honesty: execution-mode policy + Mosaic lowering tier.

Coverage per the issue checklist:
  * every backend in ``ops.BACKENDS`` lowers to Mosaic with
    ``interpret=False`` across the ≥ 3 smoke geometries (CPU-only — the
    AOT trace→lower path, no execution), with the full grid ``slow``;
  * dispatch-mode fallback: ``"auto"`` on a CPU-only host resolves to
    interpret with the probe reason surfaced, ``"compiled"`` raises a
    clear error instead of silently interpreting;
  * ``select_backend`` / ``plan_residency`` invariance: the mode changes
    execution, never planning;
  * hypothesis property sweep: any valid randomly-drawn geometry lowers
    for every backend (shrinks toward the minimal failing tuple);
  * grep regression: no ``interpret=`` ``True`` hardcode survives in
    ``src/`` or ``benchmarks/`` outside the policy module — every call
    site defers to ``repro.runtime.execution``;
  * the one-hot MXU gather (the compiled path's ``jnp.take``
    replacement) is bitwise the take-based gather, fp32 and bf16.
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import lowering as klow
from repro.kernels.mttkrp import ops as kops
from repro.oocore import planner
from repro.runtime import execution
from repro.tune.table import host_meta

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.skipif(
    jax.default_backend() == "tpu",
    reason="lowering tier is the CPU-only stand-in; on a TPU host the "
           "kernels compile (and run) for real")


# ---------------------------------------------------------------------------
# Lowering: every backend × smoke geometries (the CI-fast tier)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("geom", klow.SMOKE_GEOMETRIES,
                         ids=lambda g: g.label())
@pytest.mark.parametrize("backend", kops.BACKENDS)
def test_backend_lowers_smoke(backend, geom):
    r = klow.lower_backend(backend, geom)
    assert r.ok, f"{backend} @ {geom.label()}: {r.error}"
    # Pallas backends must have produced a real Mosaic module; ref is
    # plain XLA and must not have.
    assert r.mosaic == (backend != "ref")


@pytest.mark.slow
@pytest.mark.parametrize("geom",
                         [g for g in klow.FULL_GEOMETRIES
                          if g not in klow.SMOKE_GEOMETRIES],
                         ids=lambda g: g.label())
@pytest.mark.parametrize("backend", kops.BACKENDS)
def test_backend_lowers_full(backend, geom):
    r = klow.lower_backend(backend, geom)
    assert r.ok, f"{backend} @ {geom.label()}: {r.error}"


def test_smoke_grid_meets_issue_floor():
    # The acceptance criterion: >= 3 geometries per backend, every
    # geometry compiled-valid.
    assert len(klow.SMOKE_GEOMETRIES) >= 3
    for g in klow.FULL_GEOMETRIES:
        ok, reason = klow.compiled_geometry_ok(g)
        assert ok, reason


def test_non_mosaic_blk_is_reported_not_raised():
    # blk=32 violates the rank-1 block rule: the harness must return a
    # failing result (with the Mosaic message), never raise.
    geom = klow.Geometry(nmodes=3, rank=128, blk=32, tile_rows=8)
    ok, reason = klow.compiled_geometry_ok(geom)
    assert not ok and "128" in reason
    r = klow.lower_backend("pallas_fused", geom)
    assert not r.ok and r.error


# ---------------------------------------------------------------------------
# Execution-mode policy: probing, fallback, the compiled-mode error
# ---------------------------------------------------------------------------

def test_probe_on_cpu_host():
    cap = execution.CAPABILITY
    assert not cap.can_compile
    assert cap.platform == jax.default_backend()
    assert "tpu" in cap.reason.lower() or "mosaic" in cap.reason.lower()


def test_auto_resolves_to_interpret_with_reason_surfaced():
    with execution.execution_mode("auto") as cap:
        assert execution.resolve_interpret() is True
        assert execution.default_interpret() is True
        assert cap.reason  # the probe reason rides along


def test_interpret_mode_resolves_interpret():
    assert execution.resolve_interpret(mode="interpret") is True


def test_compiled_mode_raises_clear_error():
    with pytest.raises(execution.ExecutionModeError) as exc:
        execution.resolve_interpret(mode="compiled")
    msg = str(exc.value)
    assert "compiled" in msg
    assert execution.CAPABILITY.reason in msg     # probe reason surfaced
    assert "interpret" in msg                     # and a way out


def test_compiled_mode_raises_from_kernel_entry():
    # End to end: a kernel call under the compiled mode must fail fast,
    # not silently interpret.
    contrib = jnp.zeros((128, 128), jnp.float32)
    rows = jnp.zeros((128,), jnp.int32)
    tiles = jnp.zeros((1,), jnp.int32)
    with execution.execution_mode("compiled"):
        with pytest.raises(execution.ExecutionModeError):
            kkernel.segment_accumulate(
                contrib, rows, tiles, rows_cap=8, blk=128, tile_rows=8)


def test_explicit_override_beats_mode():
    with execution.execution_mode("compiled"):
        assert execution.resolve_interpret(True) is True
    with execution.execution_mode("interpret"):
        assert execution.resolve_interpret(False) is False


def test_mode_set_get_restore_and_validation():
    before = execution.get_execution_mode()
    with execution.execution_mode("interpret"):
        assert execution.get_execution_mode() == "interpret"
    assert execution.get_execution_mode() == before
    with pytest.raises(ValueError):
        execution.set_execution_mode("fast")
    with pytest.raises(ValueError):
        execution.resolve_interpret(mode="fast")


def test_host_meta_records_policy_not_hardcode():
    with execution.execution_mode("interpret"):
        meta = host_meta()
        assert meta["execution_mode"] == "interpret"
        assert meta["interpret"] is True
        assert "execution_probe" in meta
    with execution.execution_mode("compiled"):
        # unresolvable on this host -> recorded as None, not a lie
        assert host_meta()["interpret"] is None


# ---------------------------------------------------------------------------
# Mode never changes planning: select_backend / plan_residency invariance
# ---------------------------------------------------------------------------

_PLAN_CASES = [
    dict(nmodes=3, rank=128, blk=128, tile_rows=8, factor_rows=(64, 64)),
    dict(nmodes=4, rank=512, blk=512, tile_rows=128,
         factor_rows=(100_000, 2_000, 50)),
    dict(nmodes=3, rank=4, blk=128, tile_rows=8, factor_rows=(64, 64)),
    dict(nmodes=5, rank=256, blk=128, tile_rows=16, factor_rows=None),
]


@pytest.mark.parametrize("case", _PLAN_CASES,
                         ids=lambda c: f"N{c['nmodes']}_R{c['rank']}")
def test_selection_and_residency_invariant_under_mode(case):
    picks, plans = [], []
    for mode in execution.EXECUTION_MODES:
        with execution.execution_mode(mode):
            picks.append(kops.select_backend("auto", **case))
            plans.append(planner.plan_residency(
                nmodes=case["nmodes"], rank=case["rank"], blk=case["blk"],
                tile_rows=case["tile_rows"],
                factor_rows=case["factor_rows"]))
    assert len(set(picks)) == 1, picks
    assert len({str(p) for p in plans}) == 1, plans


# ---------------------------------------------------------------------------
# Property sweep: any valid geometry lowers, for every backend
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(
    backend=st.sampled_from(kops.BACKENDS),
    nmodes=st.integers(3, 5),
    rank=st.sampled_from([8, 100, 128, 256]),
    blk=st.sampled_from([128, 256]),
    tile_rows=st.sampled_from([8, 16, 128]),
    factor_rows=st.sampled_from([64, 130, 300]),
)
def test_any_valid_geometry_lowers(backend, nmodes, rank, blk, tile_rows,
                                   factor_rows):
    geom = klow.Geometry(nmodes=nmodes, rank=rank, blk=blk,
                         tile_rows=tile_rows, factor_rows=factor_rows)
    ok, reason = klow.compiled_geometry_ok(geom)
    assert ok, reason
    r = klow.lower_backend(backend, geom)
    assert r.ok, (backend, nmodes, rank, blk, r.error)


# ---------------------------------------------------------------------------
# Grep regression: the hardcode must not come back
# ---------------------------------------------------------------------------

def test_no_interpret_true_hardcode_outside_policy():
    """No ``interpret=True`` literal in src/ or benchmarks/.

    The policy module (src/repro/runtime/execution.py) is the one place
    allowed to spell the resolution out; tests/ pin interpret
    explicitly on purpose (they compare both forms). Everything else
    must defer to the policy — that is the whole point of the refactor.
    """
    allowed = {
        os.path.join("src", "repro", "runtime", "execution.py"),
        # The degradation policy's recorded compiled -> interpret
        # fallback (counted resilience.interpret_fallbacks) is the one
        # other legitimate place the flip is spelled out.
        os.path.join("src", "repro", "resilience", "policy.py"),
    }
    pattern = re.compile(r"interpret\s*=\s*True")
    offenders = []
    for top in ("src", "benchmarks"):
        for dirpath, _dirnames, filenames in os.walk(
                os.path.join(REPO_ROOT, top)):
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, REPO_ROOT)
                if rel in allowed:
                    continue
                with open(path, encoding="utf-8") as f:
                    for i, line in enumerate(f, 1):
                        if pattern.search(line):
                            offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, (
        "interpret hardcodes outside the execution policy:\n"
        + "\n".join(offenders))


# ---------------------------------------------------------------------------
# One-hot MXU gather ≡ take (the compiled path's gather replacement)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_onehot_gather_bitwise_equals_take(dtype):
    rng = np.random.default_rng(7)
    matrix = jnp.asarray(rng.standard_normal((96, 128)), dtype)
    idx = jnp.asarray(rng.integers(0, 96, size=64).astype(np.int32))
    take = kkernel._gather_rows(matrix, idx, onehot=False)
    onehot = kkernel._gather_rows(matrix, idx, onehot=True)
    # take returns matrix dtype; the Hadamard promotes it to fp32 — the
    # one-hot form lands there directly. Compare post-promotion, which
    # is the only form the kernels ever consume.
    assert np.array_equal(np.asarray(take.astype(jnp.float32)),
                          np.asarray(onehot))
    assert onehot.dtype == jnp.float32
