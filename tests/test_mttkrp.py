"""spMTTKRP engines agree with the literal elementwise reference (Eq. 4)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mttkrp import mttkrp, mttkrp_elementwise_ref, mttkrp_sorted
from repro.core.tensors import low_rank_sparse_tensor, random_sparse_tensor


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 16),
       st.sampled_from([(12, 9, 7), (5, 5, 5, 5), (30, 4)]))
def test_vectorized_matches_elementwise(seed, rank, shape):
    t = random_sparse_tensor(shape, 64, seed=seed)
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    for mode in range(len(shape)):
        ref = mttkrp_elementwise_ref(t.indices, t.values, factors, mode)
        got = np.asarray(mttkrp(jnp.asarray(t.indices), jnp.asarray(t.values),
                                factors, mode, shape[mode]))
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_sorted_variant_matches_on_sorted_stream():
    shape = (20, 15, 10)
    t = random_sparse_tensor(shape, 200, seed=1)
    order = np.argsort(t.indices[:, 1], kind="stable")
    idx = jnp.asarray(t.indices[order])
    val = jnp.asarray(t.values[order])
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.standard_normal((d, 8)), jnp.float32)
               for d in shape]
    ref = mttkrp_elementwise_ref(t.indices, t.values, factors, 1)
    got = np.asarray(mttkrp_sorted(idx, val, factors, 1, shape[1]))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
