"""Pallas MTTKRP kernel: shape/dtype sweeps vs. the pure-jnp oracle
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mttkrp import ops as kops
from repro.kernels.mttkrp import ref as kref


def _case(seed, n_el, rows, rank, frac_invalid=0.05):
    rng = np.random.default_rng(seed)
    row = np.sort(rng.integers(0, rows, n_el)).astype(np.int32)
    contrib = rng.standard_normal((n_el, rank)).astype(np.float32)
    valid = np.ones(n_el, bool)
    k = int(n_el * frac_invalid)
    if k:
        valid[-k:] = False
        contrib[-k:] = 0.0
        row[-k:] = rows - 1
    return jnp.asarray(contrib), jnp.asarray(row), jnp.asarray(valid)


@pytest.mark.parametrize("n_el,rows,rank,blk,tile_rows", [
    (64, 16, 4, 16, 8),
    (333, 64, 8, 32, 8),
    (1000, 256, 16, 128, 128),
    (777, 128, 32, 64, 16),
    (2048, 512, 128, 512, 128),     # production-aligned tile
    (100, 8, 3, 32, 8),             # rank not MXU-aligned → padded
])
def test_segment_accumulate_matches_ref(n_el, rows, rank, blk, tile_rows):
    contrib, row, valid = _case(0, n_el, rows, rank)
    out = kops.mttkrp_blocked(contrib, row, valid, rows_cap=rows, blk=blk,
                              tile_rows=tile_rows, interpret=True)
    ref = kref.segment_accumulate_ref(
        jnp.where(valid[:, None], contrib, 0),
        jnp.where(valid, row, 0), rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_accumulate_dtypes(dtype):
    contrib, row, valid = _case(1, 500, 64, 16)
    contrib = contrib.astype(dtype)
    out = kops.mttkrp_blocked(contrib, row, valid, rows_cap=64, blk=64,
                              tile_rows=16, interpret=True)
    ref = kref.segment_accumulate_ref(
        jnp.where(valid[:, None], contrib, 0).astype(jnp.float32),
        jnp.where(valid, row, 0), 64)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=tol, atol=tol)


@pytest.mark.parametrize("seed", range(4))
def test_fused_3mode_matches_device_step_ref(seed):
    """Fused Hadamard+scatter kernel == generic ref path on real layouts."""
    rng = np.random.default_rng(seed)
    cap, rows_cap, rank, nmodes = 300, 32, 8, 3
    idx = np.stack([
        np.sort(rng.integers(0, rows_cap, cap)),          # output rows
        rng.integers(0, 64, cap),
        rng.integers(0, 48, cap),
    ], axis=1).astype(np.int32)
    val = rng.standard_normal(cap).astype(np.float32)
    valid = np.arange(cap) < cap - 11
    factors = [jnp.asarray(rng.standard_normal((n, rank)), jnp.float32)
               for n in (rows_cap, 64, 48)]
    kw = dict(mode=0, rows_cap=rows_cap, row_offset=0, blk=32, tile_rows=8,
              interpret=True)
    ref = kops.mttkrp_device_step(jnp.asarray(idx), jnp.asarray(val),
                                  jnp.asarray(valid), factors,
                                  backend="ref", **kw)
    for backend in ("pallas", "pallas_fused"):
        got = kops.mttkrp_device_step(jnp.asarray(idx), jnp.asarray(val),
                                      jnp.asarray(valid), factors,
                                      backend=backend, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_build_block_layout_invariants():
    """Blocks never straddle an output row tile; slots are unique."""
    rng = np.random.default_rng(0)
    cap, rows_cap, blk, tile_rows = 500, 64, 32, 16
    row = np.sort(rng.integers(0, rows_cap, cap)).astype(np.int32)
    valid = np.ones(cap, bool)
    slot, tile_of_block = kops.build_block_layout(
        jnp.asarray(row), jnp.asarray(valid), rows_cap=rows_cap, blk=blk,
        tile_rows=tile_rows)
    slot = np.asarray(slot)
    assert len(np.unique(slot)) == cap            # injective
    blocks = slot // blk
    tob = np.asarray(tile_of_block)
    # every element's block is tagged with that element's tile
    np.testing.assert_array_equal(tob[blocks], row // tile_rows)
