"""Shared fixtures + optional-dependency shims.

NOTE: no XLA_FLAGS here — smoke tests must see the real single CPU device;
multi-device tests spawn subprocesses (test_distributed).

``hypothesis`` is an *optional* dependency: when absent, a stub module is
installed before test collection so the five property-test files still
import cleanly, with every ``@given`` test skipped with a clear reason
instead of erroring the whole collection.
"""
import sys
import types

import numpy as np
import pytest

_HYPOTHESIS_SKIP_REASON = (
    "hypothesis not installed (optional dependency) — property-based sweep "
    "skipped; example-based tests cover the same kernels"
)


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    mod.__repro_stub__ = True

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason=_HYPOTHESIS_SKIP_REASON)(fn)
        return deco

    class _Settings:
        """Accepts any decorator/profile usage and is a no-op."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    def assume(condition):
        return bool(condition)

    strategies = types.ModuleType("hypothesis.strategies")
    # Any strategy constructor (integers, floats, sampled_from, ...) returns
    # an inert placeholder — @given skips the test before strategies matter.
    strategies.__getattr__ = lambda name: (lambda *a, **k: None)

    mod.given = given
    mod.settings = _Settings
    mod.assume = assume
    mod.strategies = strategies
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
