"""Paper Alg. 3 (LPT greedy scheduling): Graham 4/3 bound + baselines."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.schedule import (block_cyclic_schedule, load_imbalance,
                                 lpt_schedule, makespan)


def brute_force_opt(sizes, bins):
    best = float("inf")
    for assign in itertools.product(range(bins), repeat=len(sizes)):
        loads = np.zeros(bins)
        for s, b in zip(sizes, assign):
            loads[b] += s
        best = min(best, loads.max())
    return best


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 50), min_size=1, max_size=7),
       st.integers(2, 3))
def test_lpt_within_4_3_of_optimal(sizes, bins):
    sizes = np.array(sizes)
    assign = lpt_schedule(sizes, bins)
    got = makespan(sizes, assign, bins)
    opt = brute_force_opt(list(sizes), bins)
    assert got <= 4 / 3 * opt + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 10_000), min_size=1, max_size=200),
       st.integers(2, 56))
def test_lpt_assigns_everything_and_beats_mean_bound(sizes, bins):
    sizes = np.array(sizes)
    assign = lpt_schedule(sizes, bins)
    assert assign.shape == (len(sizes),)
    assert assign.min() >= 0 and assign.max() < bins
    # Graham: makespan <= mean + max (another classical bound)
    got = makespan(sizes, assign, bins)
    assert got <= sizes.sum() / bins + sizes.max() + 1e-9


def test_lpt_beats_block_cyclic_on_skewed_load():
    """Paper Fig. 6: LPT vs block-cyclic on power-law super-shard sizes.

    Skew is capped so no single super-shard exceeds the mean bin load
    (matching FLYCOO preprocessing, where m_n bounds a super-shard's row
    interval); with one unboundedly-huge shard no schedule can balance.
    """
    rng = np.random.default_rng(0)
    sizes = (1000 * (1 + rng.pareto(2.0, size=512))).astype(np.int64)
    bins = 56
    sizes = np.minimum(sizes, sizes.sum() // bins)     # cap at mean load
    lpt = load_imbalance(sizes, lpt_schedule(sizes, bins), bins)
    cyc = load_imbalance(sizes, block_cyclic_schedule(len(sizes), bins), bins)
    assert lpt <= cyc
    assert lpt < 1.35          # LPT is near-balanced on capped-pareto sizes


def test_lpt_deterministic():
    sizes = np.array([5, 3, 3, 2, 8, 1])
    a = lpt_schedule(sizes, 3)
    b = lpt_schedule(sizes, 3)
    assert np.array_equal(a, b)
