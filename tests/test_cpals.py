"""CP-ALS (paper Alg. 1): convergence + exact recovery of low-rank truth."""
import itertools

import numpy as np
import pytest

from repro.core.cpals import cp_als
from repro.core.tensors import SparseTensor, random_sparse_tensor


def dense_lowrank_coo(shape, rank, seed=0):
    rng = np.random.default_rng(seed)
    facs = [rng.standard_normal((d, rank)) for d in shape]
    dense = np.einsum("ir,jr,kr->ijk", *facs)
    idx = np.array(list(itertools.product(*[range(d) for d in shape])),
                   dtype=np.int32)
    return SparseTensor(idx, dense.reshape(-1).astype(np.float32),
                        shape), dense


@pytest.mark.slow
def test_exact_recovery_rank4():
    t, dense = dense_lowrank_coo((16, 12, 10), 4, seed=0)
    res = cp_als(t, rank=4, iters=40, seed=1)
    assert res.fit > 0.999, res.fits
    rec = np.einsum("r,ir,jr,kr->ijk", res.lam, *res.factors)
    rel = np.linalg.norm(rec - dense) / np.linalg.norm(dense)
    assert rel < 1e-2


def test_fit_nondecreasing_after_warmup():
    t, _ = dense_lowrank_coo((12, 10, 8), 3, seed=2)
    res = cp_als(t, rank=3, iters=20, seed=3, tol=0.0)
    fits = np.array(res.fits)
    assert np.all(np.diff(fits[1:]) > -1e-3), fits


def test_fit_bounded_and_finite_on_random_tensor():
    t = random_sparse_tensor((30, 20, 10), 500, seed=4)
    res = cp_als(t, rank=8, iters=8, seed=5)
    assert np.isfinite(res.fit)
    assert res.fit <= 1.0 + 1e-6
    for n, f in enumerate(res.factors):
        assert f.shape == (t.shape[n], 8)
        assert np.all(np.isfinite(f))


def test_four_mode_tensor():
    t = random_sparse_tensor((8, 7, 6, 5), 300, seed=6)
    res = cp_als(t, rank=4, iters=5, seed=7)
    assert np.isfinite(res.fit)
    assert len(res.factors) == 4
