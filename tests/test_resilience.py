"""repro.resilience — fault injection, degradation policy, guarded solve,
checkpoint/resume (PR-9).

Coverage per the issue checklist:
  * seeded schedules are bit-reproducible and every spec validates
    against the closed site registry;
  * injector mechanics: index-matched firing, per-site counters advance
    on retry (a retried call gets a fresh index), pending() accounting,
    conflicting-spec rejection, nesting restores the outer injector;
  * the degradation ladder is a strict walk over real backend names
    (validated against ``ops.BACKENDS``);
  * ``RetryPolicy.run`` / ``dispatch`` walks with fake calls: bounded
    transient retry, compiled → interpret flip, recorded rung descent,
    corruption propagation, ``ResilienceExhausted`` at the floor —
    every decision visible in ``resilience.*`` counters;
  * ``guarded_solve`` is bit-identical to the plain solve on healthy
    input and escalates (ridge → lstsq) on non-finite/singular grams,
    eagerly and under jit;
  * checkpoint state round-trip + config-fingerprint validation, the
    chaos CP-ALS fit matches the fault-free run, and (slow) a
    SIGKILL-ed job resumes warm to the same decomposition / a save
    killed mid-write can never corrupt the newest complete checkpoint.
"""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.obs import counters as ocnt
from repro.resilience import (
    DEGRADATION_LADDER,
    CorruptionFault,
    FaultInjector,
    FaultSpec,
    GUARD_LEVELS,
    InjectedFault,
    ResilienceExhausted,
    ResourceFault,
    RetryPolicy,
    TransientFault,
    fault_site,
    guarded_solve,
    inject,
    next_rung,
    seeded_schedule,
)
from repro.resilience import checkpoint as rckpt
from repro.resilience.faults import FAULT_KINDS, SITES

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_REPO, "src")


# ---------------------------------------------------------------------------
# Seeded schedules + spec validation
# ---------------------------------------------------------------------------

def test_seeded_schedule_bit_reproducible():
    a = seeded_schedule(7, per_site=2, horizon=5)
    b = seeded_schedule(7, per_site=2, horizon=5)
    assert a == b
    assert a != seeded_schedule(8, per_site=2, horizon=5)
    assert len(a) == 2 * len(SITES)
    for s in a:
        assert 0 <= s.index < 5
        assert s.kind in FAULT_KINDS
    # per-site indices are distinct (drawn without replacement).
    for site in SITES:
        idxs = [s.index for s in a if s.site == site]
        assert len(set(idxs)) == len(idxs) == 2


def test_seeded_schedule_kind_override():
    specs = seeded_schedule(0, kinds={"ops.kernel": "transient"})
    kinds = {s.site: s.kind for s in specs}
    assert kinds["ops.kernel"] == "transient"
    assert kinds["tune.table_load"] == "corruption"


@pytest.mark.parametrize("bad", [
    dict(site="nope.site", index=0, kind="transient"),
    dict(site="ops.kernel", index=0, kind="nope"),
    dict(site="ops.kernel", index=-1, kind="transient"),
])
def test_fault_spec_validation(bad):
    with pytest.raises(ValueError):
        FaultSpec(**bad)


def test_fault_site_rejects_unregistered_name():
    with pytest.raises(ValueError, match="unknown fault site"):
        fault_site("not.a.site")


def test_fault_taxonomy():
    assert issubclass(TransientFault, InjectedFault)
    assert issubclass(ResourceFault, InjectedFault)
    assert issubclass(CorruptionFault, InjectedFault)
    e = TransientFault("ops.kernel", 3, note="dma hiccup")
    assert e.site == "ops.kernel" and e.index == 3
    assert "dma hiccup" in str(e)


# ---------------------------------------------------------------------------
# Injector mechanics
# ---------------------------------------------------------------------------

def test_injector_fires_on_index_match():
    with ocnt.use_registry() as reg:
        with inject([FaultSpec("ops.kernel", 1, "transient")]) as inj:
            fault_site("ops.kernel")                    # call 0: passes
            with pytest.raises(TransientFault):
                fault_site("ops.kernel")                # call 1: fires
            fault_site("ops.kernel")                    # call 2: passes
            assert inj.calls["ops.kernel"] == 3
            assert [s.index for s in inj.injected] == [1]
            assert inj.pending() == ()
        assert reg.get("resilience.injected",
                       site="ops.kernel", kind="transient") == 1
        assert reg.get("resilience.site_calls",
                       site="ops.kernel") == 3


def test_injector_pending_when_site_not_reached():
    with inject([FaultSpec("oocore.chunk", 4, "transient")]) as inj:
        fault_site("oocore.chunk")
    assert inj.pending() == (FaultSpec("oocore.chunk", 4, "transient"),)


def test_injector_rejects_conflicting_specs():
    with pytest.raises(ValueError, match="conflicting"):
        FaultInjector((FaultSpec("ops.kernel", 0, "transient"),
                       FaultSpec("ops.kernel", 0, "resource")))


def test_inject_nesting_restores_outer():
    from repro.resilience.faults import active_injector
    with inject([]) as outer:
        with inject([]) as inner:
            assert active_injector() is inner
        assert active_injector() is outer
    assert active_injector() is None


def test_fault_site_noop_without_injector():
    with ocnt.use_registry() as reg:
        fault_site("execution.resolve")
        assert reg.get("resilience.site_calls",
                       site="execution.resolve") == 1
        assert reg.total("resilience.injected") == 0


# ---------------------------------------------------------------------------
# Degradation ladder + retry policy
# ---------------------------------------------------------------------------

def test_ladder_is_real_backends_and_strictly_descending():
    from repro.kernels.mttkrp import ops
    for rung in DEGRADATION_LADDER:
        assert rung in ops.BACKENDS, rung
    assert len(set(DEGRADATION_LADDER)) == len(DEGRADATION_LADDER)
    walk = [DEGRADATION_LADDER[0]]
    while next_rung(walk[-1]) is not None:
        walk.append(next_rung(walk[-1]))
    assert tuple(walk) == DEGRADATION_LADDER
    assert next_rung("ref") is None
    assert next_rung("not_a_backend") is None


def test_retry_run_recovers_and_counts():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientFault("oocore.chunk", calls["n"] - 1)
        return "ok"

    with ocnt.use_registry() as reg:
        pol = RetryPolicy(max_retries=3)
        assert pol.run("oocore.chunk", flaky) == "ok"
        assert reg.get("resilience.retries",
                       site="oocore.chunk") == 2


def test_retry_run_exhausts():
    def always():
        raise TransientFault("oocore.chunk", 0)

    with ocnt.use_registry():
        with pytest.raises(ResilienceExhausted):
            RetryPolicy(max_retries=2).run("oocore.chunk", always)


def test_retry_run_propagates_non_transient():
    def res():
        raise ResourceFault("oocore.chunk", 0)

    with ocnt.use_registry():
        with pytest.raises(ResourceFault):
            RetryPolicy().run("oocore.chunk", res)


def test_retry_backoff_schedule_is_exponential():
    slept = []
    pol = RetryPolicy(max_retries=3, backoff_base_s=0.5, backoff_factor=2.0,
                      sleep=slept.append)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 3:
            raise TransientFault("oocore.chunk", 0)
        return 1

    with ocnt.use_registry():
        pol.run("oocore.chunk", flaky)
    assert slept == [0.5, 1.0, 2.0]


def _scripted_call(script):
    """A fake ``call(backend, interpret)``: pops the next scripted action."""
    log = []

    def call(backend, interpret):
        log.append((backend, interpret))
        action = script.pop(0) if script else "ok"
        if action == "ok":
            return ("done", backend, interpret)
        raise action

    return call, log


def test_dispatch_transient_retries_same_rung():
    call, log = _scripted_call([TransientFault("ops.kernel", 0), "ok"])
    with ocnt.use_registry() as reg:
        out = RetryPolicy().dispatch(call, "pallas_fused", False)
    assert out == ("done", "pallas_fused", False)
    assert log == [("pallas_fused", False)] * 2
    assert reg.get("resilience.retries",
                   site="ops.kernel") == 1


def test_dispatch_resource_flips_compiled_to_interpret_first():
    call, log = _scripted_call([ResourceFault("ops.kernel", 0), "ok"])
    with ocnt.use_registry() as reg:
        out = RetryPolicy().dispatch(call, "pallas_fused", False)
    assert out == ("done", "pallas_fused", True)   # same rung, interpreted
    assert log == [("pallas_fused", False), ("pallas_fused", True)]
    assert reg.get("resilience.interpret_fallbacks",
                   backend="pallas_fused") == 1
    assert reg.total("resilience.degradations") == 0


def test_dispatch_resource_under_interpret_steps_down():
    call, log = _scripted_call([ResourceFault("ops.kernel", 0), "ok"])
    with ocnt.use_registry() as reg:
        out = RetryPolicy().dispatch(call, "pallas_fused_gather", True)
    assert out == ("done", "pallas_fused_gather_tiled", True)
    assert reg.get("resilience.degradations",
                   **{"from": "pallas_fused_gather",
                      "to": "pallas_fused_gather_tiled"}) == 1


def test_dispatch_corruption_propagates_immediately():
    call, log = _scripted_call([CorruptionFault("ops.kernel", 0)])
    with ocnt.use_registry() as reg:
        with pytest.raises(CorruptionFault):
            RetryPolicy().dispatch(call, "pallas_fused", True)
    assert len(log) == 1
    assert reg.total("resilience.retries") == 0
    assert reg.total("resilience.degradations") == 0


def test_dispatch_exhausts_at_ladder_floor():
    call, log = _scripted_call(
        [ResourceFault("ops.kernel", i) for i in range(20)])
    with ocnt.use_registry() as reg:
        with pytest.raises(ResilienceExhausted):
            RetryPolicy().dispatch(call, "pallas", True)
    # pallas → ref → floor: two attempts, one recorded degradation.
    assert [b for b, _ in log] == ["pallas", "ref"]
    assert reg.get("resilience.degradations",
                   **{"from": "pallas", "to": "ref"}) == 1


def test_dispatch_execution_mode_error_flip_then_raise():
    from repro.runtime.execution import ExecutionModeError
    call, log = _scripted_call([ExecutionModeError("compiled gone"),
                                ExecutionModeError("still gone")])
    with ocnt.use_registry() as reg:
        with pytest.raises(ExecutionModeError):
            RetryPolicy().dispatch(call, "pallas_fused", None)
    # One flip (resolution said "compiled impossible"), then unrecoverable.
    assert [i for _, i in log] == [None, True]
    assert reg.get("resilience.interpret_fallbacks",
                   backend="pallas_fused") == 1


def test_use_policy_scoping():
    from repro.resilience import get_policy, use_policy
    assert get_policy() is None
    with use_policy() as pol:
        assert get_policy() is pol
        custom = RetryPolicy(max_retries=1)
        with use_policy(custom):
            assert get_policy() is custom
        assert get_policy() is pol
    assert get_policy() is None


# ---------------------------------------------------------------------------
# Guarded solve
# ---------------------------------------------------------------------------

def _healthy_vm(rng, r=6, rows=9):
    A = np.asarray(rng.standard_normal((r + 2, r)), np.float32)
    V = (A.T @ A + np.eye(r, dtype=np.float32)).astype(np.float32)
    M = np.asarray(rng.standard_normal((rows, r)), np.float32)
    return V, M


def test_guarded_solve_healthy_is_bit_identical(rng):
    import jax.numpy as jnp
    V, M = _healthy_vm(rng)
    X, level = guarded_solve(jnp.asarray(V), jnp.asarray(M))
    assert int(level) == 0 and GUARD_LEVELS[int(level)] == "clean"
    plain = jnp.linalg.solve(
        jnp.asarray(V) + 1e-9 * jnp.eye(V.shape[0]), jnp.asarray(M).T).T
    np.testing.assert_array_equal(np.asarray(X), np.asarray(plain))


def test_guarded_solve_nonfinite_escalates_to_finite(rng):
    import jax.numpy as jnp
    V, M = _healthy_vm(rng)
    M = M.copy()
    M[0, 0] = np.nan
    X, level = guarded_solve(jnp.asarray(V), jnp.asarray(M))
    assert int(level) >= 1
    assert np.isfinite(np.asarray(X)).all()


def test_guarded_solve_collapsed_column_escalates(rng):
    import jax.numpy as jnp
    V, M = _healthy_vm(rng)
    V = V.copy()
    V[2, :] = 0.0
    V[:, 2] = 0.0          # collapsed factor column → zero gram diagonal
    X, level = guarded_solve(jnp.asarray(V), jnp.asarray(M))
    assert int(level) >= 1
    assert np.isfinite(np.asarray(X)).all()


def test_guarded_solve_all_zero_hits_lstsq(rng):
    # Zero gram + huge M: the escalated ridge solve (V + 1e-6·I)⁻¹ M
    # overflows fp32 → the SVD pinv floor must produce a finite answer.
    import jax.numpy as jnp
    r = 5
    V = jnp.zeros((r, r), jnp.float32)
    M = jnp.full((7, r), 1e38, jnp.float32)
    X, level = guarded_solve(V, M, ridge=0.0)
    assert GUARD_LEVELS[int(level)] == "lstsq"
    assert np.isfinite(np.asarray(X)).all()


def test_guarded_solve_same_under_jit(rng):
    import jax
    import jax.numpy as jnp
    V, M = _healthy_vm(rng)
    jitted = jax.jit(guarded_solve)
    Xe, le = guarded_solve(jnp.asarray(V), jnp.asarray(M))
    Xj, lj = jitted(jnp.asarray(V), jnp.asarray(M))
    assert int(le) == int(lj) == 0
    np.testing.assert_allclose(np.asarray(Xe), np.asarray(Xj), rtol=1e-6)


# ---------------------------------------------------------------------------
# Checkpoint state adapter + manager hardening
# ---------------------------------------------------------------------------

def _tiny_state(rng, sweep=0, rank=4, **kw):
    factors = [np.asarray(rng.standard_normal((d, rank)), np.float32)
               for d in (6, 5)]
    lam = np.ones(rank, np.float32)
    return rckpt.make_state(factors, lam, [0.5], sweep=sweep, rank=rank,
                            **kw)


def test_checkpoint_state_round_trip(tmp_path, rng):
    mgr = rckpt.make_manager(str(tmp_path))
    state = _tiny_state(rng, sweep=2, backend="jax")
    with ocnt.use_registry() as reg:
        rckpt.save_state(mgr, state)
        got, sweep = rckpt.restore_state(
            mgr, _tiny_state(rng, sweep=0, backend="jax"))
        assert reg.get("resilience.checkpoint.saves") == 1
        assert reg.get("resilience.checkpoint.restores") == 1
    assert sweep == 2
    for a, b in zip(got["factors"], state["factors"]):
        np.testing.assert_array_equal(np.asarray(a), b)
    np.testing.assert_array_equal(np.asarray(got["lam"]), state["lam"])


def test_checkpoint_restore_empty_dir_is_fresh_start(tmp_path, rng):
    mgr = rckpt.make_manager(str(tmp_path))
    state, sweep = rckpt.restore_state(mgr, _tiny_state(rng))
    assert state is None and sweep is None
    assert rckpt.make_manager(None) is None


@pytest.mark.parametrize("mutate, match", [
    (dict(rank=5), "rank"),
    (dict(backend="pallas"), "backend"),
    (dict(ordering="morton"), "ordering"),
])
def test_checkpoint_restore_rejects_config_mismatch(tmp_path, rng, mutate,
                                                    match):
    mgr = rckpt.make_manager(str(tmp_path))
    with ocnt.use_registry():
        rckpt.save_state(mgr, _tiny_state(rng, backend="jax",
                                          ordering="none"))
        template = _tiny_state(rng, **{**dict(backend="jax",
                                              ordering="none"), **mutate})
        with pytest.raises(ValueError, match=match):
            rckpt.restore_state(mgr, template)


def test_checkpoint_restore_rejects_shape_mismatch(tmp_path, rng):
    mgr = rckpt.make_manager(str(tmp_path))
    with ocnt.use_registry():
        rckpt.save_state(mgr, _tiny_state(rng))
        template = _tiny_state(rng)
        template["factors"][0] = template["factors"][0][:-1]
        with pytest.raises(ValueError, match="shape"):
            rckpt.restore_state(mgr, template)


def test_manager_sweeps_stale_tmp_dirs(tmp_path):
    from repro.checkpoint import CheckpointManager
    stale = tmp_path / "tmp.7"
    stale.mkdir()
    (stale / "half_written.npy").write_bytes(b"\x00" * 16)
    CheckpointManager(str(tmp_path))
    assert not stale.exists()


# ---------------------------------------------------------------------------
# End-to-end: chaos CP-ALS + (slow) kill/resume and crash atomicity
# ---------------------------------------------------------------------------

def test_cp_als_checkpoint_resume_matches_uninterrupted(tmp_path, rng):
    """Single-device driver: stop at sweep 2, resume to 4 == straight 4."""
    from repro.core.cpals import cp_als
    from repro.core.tensors import random_sparse_tensor
    t = random_sparse_tensor((12, 10, 8), 120, seed=0)
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    with ocnt.use_registry() as reg:
        cp_als(t, 4, iters=2, seed=0, tol=0.0, checkpoint_dir=d1)
        resumed = cp_als(t, 4, iters=4, seed=0, tol=0.0, checkpoint_dir=d1)
        full = cp_als(t, 4, iters=4, seed=0, tol=0.0, checkpoint_dir=d2)
        assert reg.get("resilience.checkpoint.restores") == 1
    assert len(resumed.fits) == len(full.fits) == 4
    np.testing.assert_allclose(resumed.fits, full.fits, rtol=0, atol=0)
    for a, b in zip(resumed.factors, full.factors):
        np.testing.assert_array_equal(a, b)


def test_chaos_cp_als_fit_matches_fault_free(rng):
    """Faults at the kernel/remap boundaries; fit allclose, all counted."""
    import jax
    from jax.sharding import Mesh

    from repro.core import distributed as dist
    from repro.core.cpals import cp_als_distributed
    from repro.core.flycoo import build_flycoo
    from repro.core.tensors import random_sparse_tensor
    if jax.device_count() < 1:
        pytest.skip("needs a jax device")
    t = random_sparse_tensor((14, 12, 10), 150, seed=1)
    ft = build_flycoo(t, 1, m_bounds=(2, 8), g_bounds=(8, 64))
    mesh = Mesh(np.array(jax.devices()[:1]), (dist.AXIS,))

    def run(specs):
        jax.clear_caches()
        with ocnt.use_registry() as reg:
            if specs is None:
                res = cp_als_distributed(ft, 4, mesh, iters=2, seed=0,
                                         tol=0.0, backend="auto",
                                         resilience=RetryPolicy())
                return res, reg.snapshot(), None
            with inject(specs) as inj:
                res = cp_als_distributed(ft, 4, mesh, iters=2, seed=0,
                                         tol=0.0, backend="auto",
                                         resilience=RetryPolicy())
            return res, reg.snapshot(), inj

    ref, _, _ = run(None)
    specs = [FaultSpec("ops.kernel", 1, "transient"),
             FaultSpec("distributed.remap", 0, "transient")]
    chaos, snap, inj = run(specs)
    assert inj.pending() == ()
    np.testing.assert_allclose(chaos.fits, ref.fits, rtol=1e-4, atol=1e-5)
    handled = sum(v for k, v in snap.items()
                  if k.startswith(("resilience.retries",
                                   "resilience.degradations",
                                   "resilience.interpret_fallbacks")))
    assert handled >= len(specs)


@pytest.mark.slow
def test_cp_als_sigkill_resume(tmp_path):
    """A job SIGKILLed mid-run resumes warm and converges identically."""
    from repro.core.cpals import cp_als
    from repro.core.tensors import random_sparse_tensor
    d = str(tmp_path / "ck")
    child = textwrap.dedent("""
        import os, signal
        import repro.resilience.checkpoint as rc
        orig = rc.save_state
        def dying(mgr, state, _n=[0]):
            path = orig(mgr, state)
            _n[0] += 1
            if _n[0] >= 2:
                os.kill(os.getpid(), signal.SIGKILL)   # die after sweep 1
            return path
        rc.save_state = dying
        import repro.core.cpals as cp
        cp._ckpt.save_state = dying
        from repro.core.tensors import random_sparse_tensor
        t = random_sparse_tensor((12, 10, 8), 120, seed=0)
        cp.cp_als(t, 4, iters=5, seed=0, tol=0.0,
                  checkpoint_dir={d!r})
        raise SystemExit("unreachable: SIGKILL expected")
    """).format(d=d)
    env = dict(os.environ,
               PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                             ""))
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, proc.stderr

    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(d).latest_step() == 1   # sweeps 0,1 persisted

    from repro.core.tensors import random_sparse_tensor
    t = random_sparse_tensor((12, 10, 8), 120, seed=0)
    with ocnt.use_registry() as reg:
        resumed = cp_als(t, 4, iters=5, seed=0, tol=0.0, checkpoint_dir=d)
        assert reg.get("resilience.checkpoint.restores") == 1
    full = cp_als(t, 4, iters=5, seed=0, tol=0.0)
    assert len(resumed.fits) == len(full.fits) == 5
    np.testing.assert_allclose(resumed.fits, full.fits, rtol=0, atol=0)


@pytest.mark.slow
def test_checkpoint_crash_atomicity(tmp_path):
    """SIGKILL mid-save never corrupts the newest complete checkpoint."""
    d = str(tmp_path / "ck")
    child = textwrap.dedent("""
        import os, signal
        import numpy as np
        import repro.checkpoint.manager as m
        mgr = m.CheckpointManager({d!r})
        state = dict(x=np.arange(64, dtype=np.float32),
                     y=np.ones((8, 8), np.float32))
        mgr.save(1, state)                      # complete checkpoint
        orig = m._fsync_file
        def dying(path, _n=[0]):
            _n[0] += 1
            if _n[0] >= 2:                      # mid-way through save #2
                os.kill(os.getpid(), signal.SIGKILL)
            orig(path)
        m._fsync_file = dying
        state2 = dict(x=np.full(64, 9.0, np.float32),
                      y=np.zeros((8, 8), np.float32))
        mgr.save(2, state2)
        raise SystemExit("unreachable: SIGKILL expected")
    """).format(d=d)
    env = dict(os.environ,
               PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                             ""))
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, proc.stderr

    from repro.checkpoint import CheckpointManager
    half = [n for n in os.listdir(d) if n.startswith("tmp.")]
    assert half == ["tmp.2"]                 # the crash left its debris...
    mgr = CheckpointManager(d)               # ...which init sweeps
    assert [n for n in os.listdir(d) if n.startswith("tmp.")] == []
    assert mgr.all_steps() == [1]            # step 2 never became visible
    template = dict(x=np.zeros(64, np.float32),
                    y=np.zeros((8, 8), np.float32))
    restored, step = mgr.restore(template)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.arange(64, dtype=np.float32))
