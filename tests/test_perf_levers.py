"""§Perf levers: int8 KV cache, exact-causal block-skip attention,
remat-policy selection — correctness against the baseline paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.flops import step_costs
from repro.models import model as M
from repro.models.attention import (decode_attention, decode_attention_int8,
                                    flash_attention, quantize_per_channel,
                                    quantize_per_token)
from repro.models.params import init_params


def test_int8_decode_attention_close_to_fp():
    rng = np.random.default_rng(0)
    b, S, h, kh, dh = 2, 64, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((b, 1, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, S, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, S, kh, dh)), jnp.float32)
    ref = decode_attention(q, k, v, cur_pos=jnp.int32(S - 1))
    kq, ks = quantize_per_token(k)
    vq, vs = quantize_per_channel(v)
    got = decode_attention_int8(q, kq, ks, vq, vs, cur_pos=jnp.int32(S - 1))
    rel = (np.abs(np.asarray(got) - np.asarray(ref)).max()
           / np.abs(np.asarray(ref)).max())
    assert rel < 0.05, rel


def test_int8_cache_end_to_end_decode():
    cfg = dataclasses.replace(smoke_config("qwen3-32b"),
                              kv_cache_dtype="int8")
    cfg_fp = smoke_config("qwen3-32b")
    params = init_params(M.model_specs(cfg), seed=0)
    rng = np.random.default_rng(0)
    B, l = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, l + 1)), jnp.int32)
    full, _ = M.forward(cfg_fp, params, toks, remat=False)
    _, cache = M.prefill(cfg, params, toks[:, :l])
    def grow(c):
        if c.ndim >= 4 and c.shape[2] == l:
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, 1)
            return jnp.pad(c, pad)
        return c
    cache = jax.tree.map(grow, cache)
    lg, _ = M.decode_step(cfg, params, cache, toks[:, l:], jnp.int32(l))
    a = np.asarray(lg[:, 0], np.float32)
    b_ = np.asarray(full[:, -1], np.float32)
    # int8 KV: logits close; top-1 prediction preserved
    assert np.abs(a - b_).max() / (np.abs(b_).max() + 1e-9) < 0.12
    assert (a.argmax(-1) == b_.argmax(-1)).mean() >= 0.5


def test_exact_causal_matches_and_saves_flops():
    rng = np.random.default_rng(1)
    b, lq, h, kh, dh = 2, 64, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((b, lq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, lq, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, lq, kh, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(lq, dtype=jnp.int32), (b, lq))
    a = flash_attention(q, k, v, pos_q=pos, pos_k=pos, mode="causal",
                        q_chunk=16, kv_chunk=16, exact_causal=False)
    bq = flash_attention(q, k, v, pos_q=pos, pos_k=pos, mode="causal",
                         q_chunk=16, kv_chunk=16, exact_causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bq), atol=1e-5)

    qs = jax.ShapeDtypeStruct((1, 4096, 8, 64), jnp.float32)
    ps = jax.ShapeDtypeStruct((1, 4096), jnp.int32)
    def attn(flag):
        return lambda q, k, v, p: flash_attention(
            q, k, v, pos_q=p, pos_k=p, mode="causal", exact_causal=flag)
    f_full = step_costs(attn(False), qs, qs, qs, ps)["flops"]
    f_skip = step_costs(attn(True), qs, qs, qs, ps)["flops"]
    assert f_skip < 0.7 * f_full          # (nq+1)/2nq = 0.625 at nq=4


@pytest.mark.parametrize("policy", ["nothing", "dots"])
def test_remat_policy_both_train(policy):
    cfg = dataclasses.replace(smoke_config("qwen3-32b"),
                              remat_policy=policy)
    params = init_params(M.model_specs(cfg), seed=0)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    def loss(p):
        lg, _ = M.forward(cfg, p, toks)
        return jnp.mean(lg.astype(jnp.float32) ** 2)
    g = jax.jit(jax.grad(loss))(params)
    assert np.isfinite(float(jax.tree.leaves(g)[0].sum()))
