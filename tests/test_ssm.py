"""Mamba2 SSD: chunked algorithm vs. naive recurrence; decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import ssm
from repro.models.params import init_params


def naive_ssd(xdt, dA, B, C):
    """Literal recurrence h_t = exp(dA_t)·h_{t-1} + B_t xdt_t; y_t = C_t·h_t."""
    b, l, h, p = xdt.shape
    n = B.shape[-1]
    S = np.zeros((b, h, p, n))
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        S = np.exp(dA[:, t])[..., None, None] * S + np.einsum(
            "bhn,bhp->bhpn", B[:, t], xdt[:, t])
        ys[:, t] = np.einsum("bhn,bhpn->bhp", C[:, t], S)
    return ys


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_ssd_matches_recurrence(chunk):
    rng = np.random.default_rng(0)
    b, l, h, p, n = 2, 16, 3, 4, 5
    xdt = rng.standard_normal((b, l, h, p)).astype(np.float32)
    dA = -np.abs(rng.standard_normal((b, l, h))).astype(np.float32) * 0.5
    B = rng.standard_normal((b, l, h, n)).astype(np.float32)
    C = rng.standard_normal((b, l, h, n)).astype(np.float32)
    got = np.asarray(ssm._ssd_chunked(jnp.asarray(xdt), jnp.asarray(dA),
                                      jnp.asarray(B), jnp.asarray(C), chunk))
    ref = naive_ssd(xdt, dA, B, C)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_full_forward():
    """Prefill state + decode step == full-sequence forward's last output."""
    cfg = smoke_config("mamba2-370m")
    specs = {"m": ssm.mamba_specs(cfg)}
    params = init_params(specs, seed=0)["m"]
    rng = np.random.default_rng(1)
    b, l = 2, 16
    x = jnp.asarray(rng.standard_normal((b, l + 1, cfg.d_model)) * 0.2,
                    jnp.float32)
    full = np.asarray(ssm.mamba_apply(params, x, cfg))

    from repro.models.blocks import _mamba_prefill
    _, cache = _mamba_prefill(cfg, params, x[:, :l])
    dec, _ = ssm.mamba_decode(params, x[:, l:], cache, cfg)
    np.testing.assert_allclose(np.asarray(dec)[:, 0], full[:, l],
                               rtol=3e-3, atol=3e-3)


def test_mamba_cache_shapes():
    cfg = smoke_config("mamba2-370m")
    shapes = ssm.mamba_cache_shape(cfg, batch=3)
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.d_state
    assert shapes["conv"] == (3, cfg.d_conv - 1, di + 2 * g * n)
    assert shapes["ssd"] == (3, cfg.ssm_heads, cfg.ssm_headdim, n)
