"""Memory-efficient attention in pure JAX (TPU-lowerable, GSPMD-shardable).

``flash_attention`` never materializes the (lq × lkv) score matrix: an outer
``lax.scan`` over query chunks and an inner ``lax.scan`` over key/value
chunks carry the running (max, denom, accumulator) triple — the standard
online-softmax recurrence. This is what lets ``prefill_32k`` fit the HBM
budget at compile time (a dense 32k×32k×heads score tensor would be TBs).

GQA is handled by folding heads into (kv_heads, group); modes:
  * ``causal``  — autoregressive self-attention;
  * ``full``    — bidirectional (encoder) / cross-attention;
  * ``local``   — chunked-local causal attention (llama4 iRoPE style):
                  q attends only within its ``window``-sized block.

``decode_attention`` is the single-token path over a (possibly
sequence-sharded) KV cache; masking is by cache position, and the softmax
reductions partition cleanly under GSPMD when the cache's seq dim is sharded
(long-context serving).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["flash_attention", "decode_attention"]

_NEG = -1e30


def _mask(mode: str, window: int, pos_q, pos_k):
    """(…, lq, lk) bool mask from broadcast position vectors.
    Negative key positions mark chunk padding and are always masked."""
    pq = pos_q[..., :, None]
    pk = pos_k[..., None, :]
    valid = pk >= 0
    if mode == "full":
        return jnp.broadcast_to(valid,
                                jnp.broadcast_shapes(pq.shape, pk.shape))
    m = (pk <= pq) & valid
    if mode == "local" and window > 0:
        m = m & ((pq // window) == (pk // window))
    return m


def flash_attention(q, k, v, *, pos_q, pos_k, mode: str = "causal",
                    window: int = 0, q_chunk: int = 1024,
                    kv_chunk: int = 1024, exact_causal: bool = False):
    """Online-softmax attention.

    Args:
      q: ``(b, lq, h, dh)``; k/v: ``(b, lk, kh, dh)`` with ``h % kh == 0``.
      pos_q/pos_k: ``(b, lq)`` / ``(b, lk)`` int32 absolute positions.
      exact_causal: skip fully-masked (q-block × kv-block) pairs with a
        static python loop over q blocks — exact-causal executed flops
        (≈2× fewer attention flops at long seq) at the cost of nq unrolled
        scan programs in the HLO (§Perf compute-term lever).
    Returns ``(b, lq, h, dh)`` in q.dtype.
    """
    b, lq0, h, dh = q.shape
    lk0, kh = k.shape[1], k.shape[2]
    qc = min(q_chunk, lq0)
    kc = min(kv_chunk, lk0)
    if lq0 % qc:                            # pad queries (output sliced back)
        pad = qc - lq0 % qc
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_q = jnp.pad(pos_q, ((0, 0), (0, pad)), constant_values=0)
    if lk0 % kc:                            # pad keys (masked via pos = -1)
        pad = kc - lk0 % kc
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)), constant_values=-1)
    b, lq, h, dh = q.shape
    lk = k.shape[1]
    g = h // kh
    scale = dh ** -0.5
    nq, nk = lq // qc, lk // kc

    qr = q.reshape(b, nq, qc, kh, g, dh).transpose(1, 0, 3, 4, 2, 5)
    pqr = pos_q.reshape(b, nq, qc).transpose(1, 0, 2)
    kr = k.reshape(b, nk, kc, kh, dh).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nk, kc, kh, dh).transpose(1, 0, 3, 2, 4)
    pkr = pos_k.reshape(b, nk, kc).transpose(1, 0, 2)

    def make_q_step(n_kv: int):
        def q_step(_, q_in):
            qi, pqi = q_in                   # (b, kh, g, qc, dh), (b, qc)

            @functools.partial(
                jax.checkpoint,
                policy=jax.checkpoint_policies.nothing_saveable)
            def kv_step(carry, kv_in):
                m, l, acc = carry
                kj, vj, pkj = kv_in          # (b, kh, kc, dh), (b, kc)
                s = jnp.einsum("bkgqd,bksd->bkgqs", qi, kj,
                               preferred_element_type=jnp.float32) * scale
                msk = _mask(mode, window, pqi, pkj)[:, None, None]
                s = jnp.where(msk, s, _NEG)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                p = jnp.where(msk, p, 0.0)
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(vj.dtype), vj,
                                preferred_element_type=jnp.float32)
                acc_new = acc * corr[..., None] + pv
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((b, kh, g, qc), _NEG, jnp.float32)
            l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
            a0 = jnp.zeros((b, kh, g, qc, dh), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (kr[:n_kv], vr[:n_kv], pkr[:n_kv]))
            out = acc / jnp.maximum(l, 1e-20)[..., None]
            return None, out                 # (b, kh, g, qc, dh)

        return q_step

    if exact_causal and mode == "causal" and nq > 1 and lq == lk:
        # static python loop: q block i attends kv blocks [0, i] only.
        outs = []
        for i in range(nq):
            _, oi = make_q_step((i + 1) * (qc // kc) if qc >= kc
                                else i // (kc // qc) + 1)(
                None, (qr[i], pqr[i]))
            outs.append(oi)
        outs = jnp.stack(outs)
    else:
        _, outs = jax.lax.scan(make_q_step(nk), None, (qr, pqr))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, lq, h, dh)
    return out[:, :lq0].astype(q.dtype)


def quantize_per_token(x):
    """int8-quantize ``x[(b, s, kh, dh)]`` with a per-(token, head) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def quantize_per_channel(x):
    """int8-quantize with a per-(head, channel) scale shared over tokens —
    required so the scale factors out of the PV contraction."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[:, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention_int8(q, kq, k_scale, vq, v_scale, *, cur_pos,
                          mode: str = "causal", window: int = 0):
    """One-token attention over an int8-quantized KV cache (§Perf memory
    lever): K per-token scales, V per-channel scales, both contractions run
    int8×int8→int32, so the cache is read at 1 byte/element.

    Args: q ``(b,1,h,dh)``; kq/vq ``(b,S,kh,dh)`` int8;
          k_scale ``(b,S,kh)``; v_scale ``(b,kh,dh)``.
    """
    b, _, h, dh = q.shape
    S, kh = kq.shape[1], kq.shape[2]
    g = h // kh
    qr = q.reshape(b, kh, g, dh)
    q_scale = jnp.maximum(
        jnp.max(jnp.abs(qr.astype(jnp.float32)), axis=-1) / 127.0, 1e-8)
    qq = jnp.clip(jnp.round(qr.astype(jnp.float32) / q_scale[..., None]),
                  -127, 127).astype(jnp.int8)
    s32 = jnp.einsum("bkgd,bskd->bkgs", qq, kq,
                     preferred_element_type=jnp.int32)
    s = (s32.astype(jnp.float32) * q_scale[..., None]
         * k_scale.transpose(0, 2, 1)[:, :, None, :]) * dh ** -0.5
    slot = jnp.arange(S, dtype=jnp.int32)
    msk = slot[None, :] <= cur_pos
    if mode == "local" and window > 0:
        msk = msk & ((slot[None, :] // window) == (cur_pos // window))
    s = jnp.where(msk[:, None, None, :] if msk.ndim == 2
                  else msk[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    # dynamic per-row scale: flat rows have p ≈ 1/S « 1/127 otherwise
    p_scale = jnp.maximum(jnp.max(p, axis=-1, keepdims=True), 1e-9) / 127.0
    pq = jnp.clip(jnp.round(p / p_scale), -127, 127).astype(jnp.int8)
    o32 = jnp.einsum("bkgs,bskd->bkgd", pq, vq,
                     preferred_element_type=jnp.int32)
    out = (o32.astype(jnp.float32) * p_scale) * v_scale[:, :, None, :]
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, cur_pos, mode: str = "causal",
                     window: int = 0):
    """One-token attention over a KV cache.

    Args:
      q: ``(b, 1, h, dh)``; caches ``(b, S, kh, dh)``.
      cur_pos: scalar int32 — position of the new token; cache slots
        ``> cur_pos`` are masked (slot ``cur_pos`` holds the new K/V,
        written by the caller before this call).
    """
    b, _, h, dh = q.shape
    S, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qr = q.reshape(b, kh, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    slot = jnp.arange(S, dtype=jnp.int32)
    msk = slot[None, :] <= cur_pos
    if mode == "local" and window > 0:
        msk = msk & ((slot[None, :] // window) == (cur_pos // window))
    s = jnp.where(msk[:, None, None, :] if msk.ndim == 2
                  else msk[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)
