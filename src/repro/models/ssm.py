"""Mamba2 (SSD — state-space duality) mixer: chunked train/prefill + O(1) decode.

The chunked SSD algorithm (Dao & Gu 2024, "minimal SSD"): the sequence is
split into chunks of ``chunk`` steps; within a chunk the recurrence is
computed as a small quadratic attention-like matmul (MXU-friendly), across
chunks a linear ``lax.scan`` carries the (h, p, n) state. This keeps
training cost O(L·chunk) and — crucially for the ``long_500k`` cells — the
decode state is O(1) in sequence length (one (h, p, n) tensor + a d_conv-1
convolution tail).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import ParamSpec
from .layers import rms_norm
from .sharding import shard

__all__ = ["mamba_specs", "mamba_apply", "mamba_decode", "mamba_cache_shape"]


def _dims(cfg):
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.d_state
    h, p = cfg.ssm_heads, cfg.ssm_headdim
    return di, g, n, h, p


def mamba_specs(cfg, dtype=jnp.float32) -> dict:
    di, g, n, h, p = _dims(cfg)
    conv_ch = di + 2 * g * n
    return {
        "in_proj": ParamSpec((cfg.d_model, 2 * di + 2 * g * n + h),
                             ("embed", "mlp"), dtype=dtype),
        "conv_w": ParamSpec((cfg.d_conv, conv_ch), (None, "mlp"),
                            init="small", dtype=dtype),
        "conv_b": ParamSpec((conv_ch,), ("mlp",), init="zeros", dtype=dtype),
        "A_log": ParamSpec((h,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((h,), (None,), init="zeros", dtype=jnp.float32),
        "D": ParamSpec((h,), (None,), init="ones", dtype=jnp.float32),
        "norm": ParamSpec((di,), ("mlp",), init="ones", dtype=dtype),
        "out_proj": ParamSpec((di, cfg.d_model), ("mlp", "embed"),
                              dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along seq: ``x[(b, l, ch)]``, ``w[(dc, ch)]``."""
    dc = w.shape[0]
    out = x * w[-1][None, None, :]
    for t in range(dc - 1):
        shift = dc - 1 - t
        out = out + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] \
            * w[t][None, None, :]
    return out + b[None, None, :]


def _ssd_chunked(xdt, dA, B, C, chunk: int):
    """Chunked SSD. xdt: (b,l,h,p) = x·dt; dA: (b,l,h); B/C: (b,l,h,n)
    (groups pre-expanded to heads). Returns (b,l,h,p)."""
    b, l, h, p = xdt.shape
    n = B.shape[-1]
    c = min(chunk, l)
    if l % c:                      # pad tail (zero xdt ⇒ zero contribution)
        pad = c - l % c
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return _ssd_chunked(xdt, dA, B, C, c)[:, :l]
    nc = l // c
    xc = xdt.reshape(b, nc, c, h, p)
    dAc = dA.reshape(b, nc, c, h).transpose(0, 3, 1, 2)       # (b,h,nc,c)
    Bc = B.reshape(b, nc, c, h, n)
    Cc = C.reshape(b, nc, c, h, n)
    A_cs = jnp.cumsum(dAc, axis=-1)                            # (b,h,nc,c)

    # 1. intra-chunk (quadratic within chunk — the MXU part)
    seg = A_cs[..., :, None] - A_cs[..., None, :]              # (b,h,nc,c,c)
    tri = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    CB = jnp.einsum("bzlhn,bzshn->bhzls", Cc, Bc,
                    preferred_element_type=jnp.float32)
    M = (CB * L).astype(xdt.dtype)
    y = jnp.einsum("bhzls,bzshp->bzlhp", M, xc,
                   preferred_element_type=jnp.float32)

    # 2. per-chunk end states
    decay_to_end = jnp.exp(A_cs[..., -1:] - A_cs)              # (b,h,nc,c)
    states = jnp.einsum("bzlhn,bhzl,bzlhp->bzhpn", Bc, decay_to_end, xc,
                        preferred_element_type=jnp.float32)

    # 3. inter-chunk recurrence (linear scan over chunks)
    chunk_decay = jnp.exp(A_cs[..., -1]).transpose(0, 2, 1)    # (b,nc,h)

    def step(S, inp):
        st_z, dec_z = inp                       # (b,h,p,n), (b,h)
        out = S                                 # state entering this chunk
        S = S * dec_z[..., None, None] + st_z
        return S, out

    S0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, S_in = jax.lax.scan(step, S0,
                           (states.transpose(1, 0, 2, 3, 4),
                            chunk_decay.transpose(1, 0, 2)))
    S_in = S_in.transpose(1, 0, 2, 3, 4)        # (b,nc,h,p,n)

    # 4. state → output within chunk
    decay_from_start = jnp.exp(A_cs).transpose(0, 2, 3, 1)     # (b,nc,c,h)
    y_off = jnp.einsum("bzlhn,bzhpn,bzlh->bzlhp",
                       Cc, S_in.astype(jnp.float32), decay_from_start,
                       preferred_element_type=jnp.float32)
    return (y + y_off).reshape(b, l, h, p)


def _project(params, x, cfg):
    di, g, n, h, p = _dims(cfg)
    dt_ = x.dtype
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dt_))
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    return z, xBC, dt


def _split_xbc(xBC, cfg):
    di, g, n, h, p = _dims(cfg)
    b, l = xBC.shape[:2]
    xs, B, C = jnp.split(xBC, [di, di + g * n], axis=-1)
    xs = xs.reshape(b, l, h, p)
    B = jnp.repeat(B.reshape(b, l, g, n), h // g, axis=2)
    C = jnp.repeat(C.reshape(b, l, g, n), h // g, axis=2)
    return xs, B, C


def _finish(params, y, z, cfg):
    b, l = y.shape[:2]
    di = cfg.d_inner
    y = y.reshape(b, l, di).astype(z.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(y.dtype))


def mamba_apply(params, x, cfg):
    """Full-sequence SSD mixer: ``x[(b, l, d)]`` → ``(b, l, d)``."""
    di, g, n, h, p = _dims(cfg)
    z, xBC, dt = _project(params, x, cfg)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(x.dtype),
                                   params["conv_b"].astype(x.dtype)))
    xs, B, C = _split_xbc(xBC, cfg)
    # Shard SSD head dim over the model axis: the intra-chunk L/CB tensors
    # are O(b·h·l·chunk) and dominate activation memory if replicated.
    xs = shard(xs, "batch", "seq", "act_heads", None)
    B = shard(B, "batch", "seq", "act_heads", None)
    C = shard(C, "batch", "seq", "act_heads", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    dt = shard(dt, "batch", "seq", "act_heads")
    A = -jnp.exp(params["A_log"])                     # (h,)
    y = _ssd_chunked(xs.astype(jnp.float32) * dt[..., None],
                     dt * A[None, None, :], B.astype(jnp.float32),
                     C.astype(jnp.float32), cfg.ssm_chunk)
    y = shard(y, "batch", "seq", "act_heads", None)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    return _finish(params, y.astype(x.dtype), z, cfg)


def mamba_cache_shape(cfg, batch: int):
    di, g, n, h, p = _dims(cfg)
    conv_ch = di + 2 * g * n
    return {
        "conv": (batch, cfg.d_conv - 1, conv_ch),
        "ssd": (batch, h, p, n),
    }


def mamba_decode(params, x, cache, cfg):
    """One-token step: ``x[(b, 1, d)]``, cache {conv, ssd} → (y, cache')."""
    di, g, n, h, p = _dims(cfg)
    z, xBC, dt = _project(params, x, cfg)
    # conv over (state ++ new)
    window = jnp.concatenate([cache["conv"], xBC], axis=1)   # (b, dc, ch)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("btc,tc->bc", window, w) \
        + params["conv_b"].astype(x.dtype)
    xBC1 = jax.nn.silu(conv_out)[:, None, :]
    xs, B, C = _split_xbc(xBC1, cfg)                          # (b,1,h,·)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])[:, 0]   # (b,h)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                             # (b,h)
    xdt = xs[:, 0].astype(jnp.float32) * dt[..., None]        # (b,h,p)
    S = cache["ssd"] * dA[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xdt, B[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", C[:, 0].astype(jnp.float32), S)
    y = y + params["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
    out = _finish(params, y[:, None].astype(x.dtype), z, cfg)
    return out, {"conv": window[:, 1:], "ssd": S}
