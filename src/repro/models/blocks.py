"""Layer blocks: ``<mixer>+<ffn>`` kinds, with forward / prefill / decode.

Mixers: ``attn`` (causal self), ``attn_local`` (chunked-local causal,
llama4 iRoPE), ``xattn`` (cross-attention only, llama-3.2-vision style with
a learned gate), ``attn_cross`` (self then cross — enc-dec decoder),
``mamba`` (SSD). FFNs: ``mlp`` (SwiGLU), ``moe``, ``none``.

Every kind exposes the same three entry points so the model can scan over a
heterogeneous pattern uniformly:

  * ``block_apply``   — full-sequence training/encoding forward;
  * ``block_prefill`` — forward + build this block's decode cache;
  * ``block_decode``  — one-token step updating the cache in place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import apply_rope, mlp_apply, mlp_specs, norm_spec, rms_norm
from .params import ParamSpec
from .sharding import shard

__all__ = [
    "parse_kind", "block_specs", "block_apply", "block_prefill",
    "block_decode", "block_cache_specs",
]


def parse_kind(kind: str) -> tuple[str, str]:
    mixer, _, ffn = kind.partition("+")
    return mixer, (ffn or "none")


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _attn_specs(cfg, dtype, prefix="") -> dict:
    d, qd, kvd, dh = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    s = {
        prefix + "wq": ParamSpec((d, qd), ("embed", "heads"), dtype=dtype),
        prefix + "wk": ParamSpec((d, kvd), ("embed", "kv"), dtype=dtype),
        prefix + "wv": ParamSpec((d, kvd), ("embed", "kv"), dtype=dtype),
        prefix + "wo": ParamSpec((qd, d), ("heads", "embed"), dtype=dtype),
    }
    if cfg.qk_norm:
        s[prefix + "q_norm"] = ParamSpec((dh,), (None,), init="ones",
                                         dtype=dtype)
        s[prefix + "k_norm"] = ParamSpec((dh,), (None,), init="ones",
                                         dtype=dtype)
    return s


def block_specs(cfg, kind: str, dtype) -> dict:
    mixer, ffn = parse_kind(kind)
    s: dict = {"ln1": norm_spec(cfg.d_model, dtype)}
    if mixer in ("attn", "attn_local"):
        s.update(_attn_specs(cfg, dtype))
    elif mixer == "xattn":
        s.update(_attn_specs(cfg, dtype, prefix="x_"))
        s["x_gate"] = ParamSpec((1,), (None,), init="zeros", dtype=jnp.float32)
    elif mixer == "attn_cross":
        s.update(_attn_specs(cfg, dtype))
        s["ln_cross"] = norm_spec(cfg.d_model, dtype)
        s.update(_attn_specs(cfg, dtype, prefix="x_"))
    elif mixer == "mamba":
        s.update(ssm_lib.mamba_specs(cfg, dtype))
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if ffn == "mlp":
        s["ln2"] = norm_spec(cfg.d_model, dtype)
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        s["ln2"] = norm_spec(cfg.d_model, dtype)
        s["moe"] = moe_lib.moe_specs(
            cfg.d_model, cfg.d_ff_expert or cfg.d_ff, cfg.n_experts_padded,
            cfg.n_shared_experts, cfg.n_experts, dtype)
    elif ffn != "none":
        raise ValueError(f"unknown ffn {ffn!r}")
    return s


# ---------------------------------------------------------------------------
# Attention helpers
# ---------------------------------------------------------------------------

def _qkv(cfg, p, h, prefix=""):
    b, l, _ = h.shape
    dh = cfg.head_dim
    q = jnp.einsum("bld,de->ble", h, p[prefix + "wq"].astype(h.dtype))
    k = jnp.einsum("bld,de->ble", h, p[prefix + "wk"].astype(h.dtype))
    v = jnp.einsum("bld,de->ble", h, p[prefix + "wv"].astype(h.dtype))
    q = q.reshape(b, l, cfg.n_heads, dh)
    k = k.reshape(b, l, cfg.n_kv_heads, dh)
    v = v.reshape(b, l, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p[prefix + "q_norm"])
        k = rms_norm(k, p[prefix + "k_norm"])
    return q, k, v


def _kv_only(cfg, p, mem, prefix="x_"):
    b, lm, _ = mem.shape
    dh = cfg.head_dim
    k = jnp.einsum("bld,de->ble", mem, p[prefix + "wk"].astype(mem.dtype))
    v = jnp.einsum("bld,de->ble", mem, p[prefix + "wv"].astype(mem.dtype))
    k = k.reshape(b, lm, cfg.n_kv_heads, dh)
    v = v.reshape(b, lm, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        k = rms_norm(k, p[prefix + "k_norm"])
    return k, v


def _self_attn(cfg, p, h, pos, mode):
    q, k, v = _qkv(cfg, p, h)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q = shard(q, "batch", "seq", "act_heads", None)
    out = attn_lib.flash_attention(
        q, k, v, pos_q=pos, pos_k=pos, mode=mode, window=cfg.window,
        exact_causal=cfg.exact_causal_attn)
    b, l = h.shape[:2]
    out = out.reshape(b, l, cfg.q_dim)
    return jnp.einsum("ble,ed->bld", out, p["wo"].astype(h.dtype))


def _cross_attn(cfg, p, h, memory, pos_mem=None):
    b, l, _ = h.shape
    dh = cfg.head_dim
    q = jnp.einsum("bld,de->ble", h, p["x_wq"].astype(h.dtype))
    q = q.reshape(b, l, cfg.n_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["x_q_norm"])
    k, v = _kv_only(cfg, p, memory)
    lm = memory.shape[1]
    pos_q = jnp.zeros((b, l), jnp.int32)
    pos_k = jnp.zeros((b, lm), jnp.int32)
    out = attn_lib.flash_attention(q, k, v, pos_q=pos_q, pos_k=pos_k,
                                   mode="full")
    out = out.reshape(b, l, cfg.q_dim)
    out = jnp.einsum("ble,ed->bld", out, p["x_wo"].astype(h.dtype))
    if "x_gate" in p:
        out = jnp.tanh(p["x_gate"]).astype(out.dtype) * out
    return out


def _ffn(cfg, p, h, ffn: str):
    metrics = {}
    if ffn == "none":
        return h * 0.0, metrics          # residual no-op (mamba2 has no FFN)
    y = rms_norm(h, p["ln2"])
    if ffn == "mlp":
        return mlp_apply(p["mlp"], y), metrics
    y, metrics = moe_lib.moe_apply(
        p["moe"], y, n_real=cfg.n_experts, top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor, impl=cfg.moe_impl)
    return y, metrics


# ---------------------------------------------------------------------------
# Forward (train / encode)
# ---------------------------------------------------------------------------

def block_apply(cfg, kind: str, p, h, *, pos, memory=None, mode="causal"):
    """Full-sequence forward. Returns ``(h', metrics)``."""
    mixer, ffn = parse_kind(kind)
    x = rms_norm(h, p["ln1"])
    if mixer == "attn":
        mix = _self_attn(cfg, p, x, pos, mode)
    elif mixer == "attn_local":
        mix = _self_attn(cfg, p, x, pos, "local")
    elif mixer == "xattn":
        mix = _cross_attn(cfg, p, x, memory)
    elif mixer == "attn_cross":
        mix = _self_attn(cfg, p, x, pos, mode)
        h = h + mix
        x2 = rms_norm(h, p["ln_cross"])
        mix = _cross_attn(cfg, p, x2, memory)
    elif mixer == "mamba":
        mix = ssm_lib.mamba_apply(p, x, cfg)
    h = h + mix
    h = shard(h, "batch", "seq", "act_embed")
    y, metrics = _ffn(cfg, p, h, ffn)
    if parse_kind(kind)[1] != "none":
        h = h + y
    return h, metrics


# ---------------------------------------------------------------------------
# Prefill / decode caches
# ---------------------------------------------------------------------------

def block_cache_specs(cfg, kind: str, batch: int, seq: int, mem_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Cache shapes+logical axes for one block (used for dry-run specs)."""
    mixer, _ = parse_kind(kind)
    kv = ("batch", "seq_shard", None, None)
    out: dict = {}
    if mixer in ("attn", "attn_local", "attn_cross"):
        shp = (batch, seq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_dtype == "int8":
            out["k"] = (shp, kv, jnp.int8)
            out["v"] = (shp, kv, jnp.int8)
            out["k_scale"] = ((batch, seq, cfg.n_kv_heads),
                              ("batch", "seq_shard", None), jnp.float32)
            out["v_scale"] = ((batch, cfg.n_kv_heads, cfg.head_dim),
                              ("batch", None, None), jnp.float32)
        else:
            out["k"] = (shp, kv, dtype)
            out["v"] = (shp, kv, dtype)
    if mixer in ("xattn", "attn_cross"):
        shp = (batch, mem_len, cfg.n_kv_heads, cfg.head_dim)
        out["ck"] = (shp, ("batch", None, None, None), dtype)
        out["cv"] = (shp, ("batch", None, None, None), dtype)
    if mixer == "mamba":
        # state sharded over `model` on heads/channels: the decode compute
        # produces exactly that layout (in_proj is mlp-sharded), so an
        # unsharded spec would force a full-state all-gather every step
        # (§Perf iteration S2).
        shapes = ssm_lib.mamba_cache_shape(cfg, batch)
        out["conv"] = (shapes["conv"], ("batch", None, "act_mlp"),
                       jnp.float32)
        out["ssd"] = (shapes["ssd"], ("batch", "act_heads", None, None),
                      jnp.float32)
    return out


def block_prefill(cfg, kind: str, p, h, *, pos, memory=None):
    """Forward + build this block's decode cache. Returns (h', cache)."""
    mixer, ffn = parse_kind(kind)
    cache: dict = {}
    x = rms_norm(h, p["ln1"])
    if mixer in ("attn", "attn_local", "attn_cross"):
        q, k, v = _qkv(cfg, p, x)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        mode = "local" if mixer == "attn_local" else "causal"
        out = attn_lib.flash_attention(q, k, v, pos_q=pos, pos_k=pos,
                                       mode=mode, window=cfg.window,
                                       exact_causal=cfg.exact_causal_attn)
        b, l = h.shape[:2]
        mix = jnp.einsum("ble,ed->bld", out.reshape(b, l, cfg.q_dim),
                         p["wo"].astype(h.dtype))
        if cfg.kv_cache_dtype == "int8":
            kq, ks = attn_lib.quantize_per_token(k)
            vq, vs = attn_lib.quantize_per_channel(v)
            cache["k"] = shard(kq, "batch", "seq_shard", None, None)
            cache["v"] = shard(vq, "batch", "seq_shard", None, None)
            cache["k_scale"] = shard(ks, "batch", "seq_shard", None)
            cache["v_scale"] = vs
        else:
            cache["k"] = shard(k.astype(jnp.bfloat16),
                               "batch", "seq_shard", None, None)
            cache["v"] = shard(v.astype(jnp.bfloat16),
                               "batch", "seq_shard", None, None)
        h = h + mix
        if mixer == "attn_cross":
            x2 = rms_norm(h, p["ln_cross"])
            h = h + _cross_attn(cfg, p, x2, memory)
            ck, cv = _kv_only(cfg, p, memory)
            cache["ck"], cache["cv"] = (ck.astype(jnp.bfloat16),
                                        cv.astype(jnp.bfloat16))
    elif mixer == "xattn":
        mix = _cross_attn(cfg, p, x, memory)
        ck, cv = _kv_only(cfg, p, memory)
        cache["ck"], cache["cv"] = (ck.astype(jnp.bfloat16),
                                    cv.astype(jnp.bfloat16))
        h = h + mix
    elif mixer == "mamba":
        # Prefill the SSD state by running the full mixer, then replaying
        # the final state via a scan-free shortcut: run apply for outputs
        # and a per-chunk scan for the state. For simplicity and exactness
        # we recompute the state with a full scan over the sequence.
        mix, cache = _mamba_prefill(cfg, p, x)
        h = h + mix
    y, metrics = _ffn(cfg, p, h, ffn)
    if ffn != "none":
        h = h + y
    return h, cache


def _mamba_prefill(cfg, p, x):
    """SSD forward + final (conv, ssd) state for decode."""
    out = ssm_lib.mamba_apply(p, x, cfg)
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.d_state
    h_, pd = cfg.ssm_heads, cfg.ssm_headdim
    z, xBC, dt = ssm_lib._project(p, x, cfg)
    conv_state = xBC[:, -(cfg.d_conv - 1):, :].astype(jnp.float32)
    xBC = jax.nn.silu(ssm_lib._causal_conv(
        xBC, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype)))
    xs, B, C = ssm_lib._split_xbc(xBC, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    dA = dt * A[None, None, :]
    # final state via chunked scan (states only, no outputs)
    c = min(cfg.ssm_chunk, x.shape[1])
    b, l = x.shape[:2]
    xdt_flat = xs.astype(jnp.float32) * dt[..., None]
    if l % c:                     # pad tail: zero xdt / dA leave state as-is
        pad = c - l % c
        xdt_flat = jnp.pad(xdt_flat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // c
    xdt = xdt_flat.reshape(b, nc, c, h_, pd)
    dAc = dA.reshape(b, nc, c, h_).transpose(0, 3, 1, 2)
    A_cs = jnp.cumsum(dAc, axis=-1)
    Bc = B.astype(jnp.float32).reshape(b, nc, c, h_, n)
    decay_to_end = jnp.exp(A_cs[..., -1:] - A_cs)              # (b,h,nc,c)
    states = jnp.einsum("bzlhn,bhzl,bzlhp->bzhpn", Bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(A_cs[..., -1]).transpose(0, 2, 1)

    def step(S, inp):
        st, dec = inp
        return S * dec[..., None, None] + st, None

    S, _ = jax.lax.scan(step, jnp.zeros((b, h_, pd, n), jnp.float32),
                        (states.transpose(1, 0, 2, 3, 4),
                         chunk_decay.transpose(1, 0, 2)))
    return out, {"conv": conv_state, "ssd": S}


def block_decode(cfg, kind: str, p, h, cache, *, pos, memory=None):
    """One-token step. ``h[(b, 1, d)]``; ``pos`` scalar int32 = slot of the
    new token (cache slots ``< pos`` already filled). Returns (h', cache')."""
    mixer, ffn = parse_kind(kind)
    cache = dict(cache)
    x = rms_norm(h, p["ln1"])
    b = h.shape[0]
    if mixer in ("attn", "attn_local", "attn_cross"):
        q, k, v = _qkv(cfg, p, x)
        pos_b = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
        mode = "local" if mixer == "attn_local" else "causal"
        if cfg.kv_cache_dtype == "int8":
            kq, ks = attn_lib.quantize_per_token(k)
            # clamp the new V into the prefill-time per-channel scale
            vsc = cache["v_scale"][:, None]
            vq = jnp.clip(jnp.round(v.astype(jnp.float32) / vsc),
                          -127, 127).astype(jnp.int8)
            kc = jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos, 0, 0))
            ksc = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                               (0, pos, 0))
            cache["k"], cache["v"], cache["k_scale"] = kc, vc, ksc
            out = attn_lib.decode_attention_int8(
                q, kc, ksc, vc, cache["v_scale"], cur_pos=pos, mode=mode,
                window=cfg.window)
        else:
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            cache["k"], cache["v"] = kc, vc
            out = attn_lib.decode_attention(
                q, kc.astype(h.dtype), vc.astype(h.dtype), cur_pos=pos,
                mode=mode, window=cfg.window)
        mix = jnp.einsum("ble,ed->bld", out.reshape(b, 1, cfg.q_dim),
                         p["wo"].astype(h.dtype))
        h = h + mix
        if mixer == "attn_cross":
            x2 = rms_norm(h, p["ln_cross"])
            h = h + _decode_cross(cfg, p, x2, cache)
    elif mixer == "xattn":
        h = h + _decode_cross(cfg, p, x, cache)
    elif mixer == "mamba":
        mix, new_state = ssm_lib.mamba_decode(p, x, cache, cfg)
        cache.update(new_state)
        h = h + mix
    y, _ = _ffn(cfg, p, h, ffn)
    if ffn != "none":
        h = h + y
    return h, cache


def _decode_cross(cfg, p, x, cache):
    b = x.shape[0]
    dh = cfg.head_dim
    q = jnp.einsum("bld,de->ble", x, p["x_wq"].astype(x.dtype))
    q = q.reshape(b, 1, cfg.n_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["x_q_norm"])
    lm = cache["ck"].shape[1]
    out = attn_lib.decode_attention(
        q, cache["ck"].astype(x.dtype), cache["cv"].astype(x.dtype),
        cur_pos=jnp.int32(lm - 1), mode="full")
    out = jnp.einsum("ble,ed->bld", out.reshape(b, 1, cfg.q_dim),
                     p["x_wo"].astype(x.dtype))
    if "x_gate" in p:
        out = jnp.tanh(p["x_gate"]).astype(out.dtype) * out
    return out
