"""Activation-sharding context: logical constraints inside model code.

Model code calls ``shard(x, "batch", "seq", None)``; when a mesh context is
active (set by the step factories in ``launch``/``models.steps``) this
becomes a ``with_sharding_constraint`` under the active logical→mesh rules;
with no context it is a no-op (smoke tests on 1 device).

Rules are swappable per input shape: ``long_context_rules()`` turns off
batch sharding (batch=1) and shards KV-cache sequence dims over
``(data, model)`` instead.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding

from .params import LOGICAL_RULES, logical_to_spec

__all__ = ["use_mesh_rules", "shard", "active_mesh_rules",
           "default_rules", "long_context_rules"]

_CTX: contextvars.ContextVar[tuple[Mesh, Mapping[str, Any]] | None] = \
    contextvars.ContextVar("repro_mesh_rules", default=None)


def default_rules() -> dict[str, Any]:
    return dict(LOGICAL_RULES)


def long_context_rules() -> dict[str, Any]:
    """batch=1 long-context serving: shard sequence, not batch."""
    rules = dict(LOGICAL_RULES)
    rules.update({
        "batch": None,
        "batch_nopod": None,
        # decode activations have seq-len 1 — only the KV caches carry the
        # long dimension, sharded over the whole mesh:
        "seq_shard": ("data", "model"),
        "act_heads": None,              # heads follow seq-sharded KV instead
    })
    return rules


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh | None, rules: Mapping[str, Any] | None = None):
    token = _CTX.set((mesh, rules or default_rules()) if mesh else None)
    try:
        yield
    finally:
        _CTX.reset(token)


def active_mesh_rules():
    return _CTX.get()


def shard(x, *axes):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
