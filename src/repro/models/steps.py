"""Step functions: loss, train_step, prefill_step, decode_step + input specs.

The factories close over (cfg, mesh, rules) and return pure jittable
functions; ``input_specs``/``state_specs`` return sharded
``ShapeDtypeStruct`` trees so the multi-pod dry-run lowers every
(arch × shape × mesh) cell without allocating a single real buffer.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from . import model as model_lib
from .params import (ParamSpec, abstract_params, logical_to_spec,
                     tree_shardings)
from .sharding import default_rules, long_context_rules, use_mesh_rules
from .. import optim as optim_lib
from ..configs.common import ArchConfig, ShapeSpec

__all__ = [
    "loss_fn", "make_train_step", "make_prefill_step", "make_decode_step",
    "input_specs", "train_state_specs", "rules_for", "batch_sharding",
    "abstract_cache", "MEM_LEN_DIV",
]

# enc-dec / vlm memory length relative to seq (documented in DESIGN.md):
# train splits seq 50/50 between source and target; decode shapes use
# seq/8 source frames (speech prompt) and n_img_tokens patches for vlm.
MEM_LEN_DIV = {"train": 2, "prefill": 2, "decode": 8}


def rules_for(shape: ShapeSpec, cfg: ArchConfig | None = None):
    """Sharding rules per input shape.

    Serving (prefill/decode) replicates parameters over the data axes when
    they fit (ZeRO-3 FSDP at inference would all-gather every parameter on
    EVERY decode step — measured as the dominant collective term in the
    baseline sweep, §Perf iteration S1). Models too big for 16-way model
    sharding (jamba-398B, llama4-scout) keep FSDP and the gather cost is
    the documented price of their size.
    """
    if shape.name == "long_500k":
        rules = long_context_rules()
    else:
        rules = default_rules()
    if cfg is not None and shape.kind in ("prefill", "decode"):
        per_chip = cfg.param_count() * np.dtype(cfg.param_dtype).itemsize / 16
        if per_chip <= 9e9:
            rules["embed"] = None      # replicate over data/pod for serving
    return rules


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_fn(cfg: ArchConfig, params, batch, *, z_loss: float = 1e-4,
            moe_coef: float = 0.01):
    logits, aux = model_lib.forward(
        cfg, params, batch["tokens"], frames=batch.get("frames"),
        img=batch.get("img"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    # Mask vocab padding columns (vocab_padded > vocab).
    vmask = jnp.arange(logits.shape[-1]) < cfg.vocab
    logits = jnp.where(vmask[None, None, :], logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = ((lse - ll) * mask).sum() / denom
    zl = z_loss * ((lse ** 2) * mask).sum() / denom
    total = ce + zl + moe_coef * aux["moe_aux"]
    return total, {"ce": ce, "z_loss": zl, "moe_aux": aux["moe_aux"]}


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, optimizer: optim_lib.Optimizer,
                    mesh: Mesh | None = None, rules=None,
                    clip_norm: float = 1.0, grad_accum: int = 1,
                    param_shardings=None):
    """``grad_accum > 1`` scans over microbatches, accumulating gradients —
    the standard way to keep activation memory inside the HBM budget at
    global-batch 256 (the dry-run's fits-in-16GB proof uses this).

    The micro body is itself rematerialized — without this the
    accumulation scan's backward saves EVERY microbatch's residuals at
    once and defeats the purpose. ``param_shardings`` (optional pytree)
    pins the fp32 gradient accumulator to the parameters' layout so it
    never replicates.
    """
    rules = rules or default_rules()

    def _shard_batch_leaf(x):
        from .sharding import shard as _shard
        return _shard(x, "batch", *([None] * (x.ndim - 1)))

    def _pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def train_step(state, batch):
        with use_mesh_rules(mesh, rules):
            grad_fn = jax.value_and_grad(
                lambda p, b: loss_fn(cfg, p, b), has_aux=True)
            if grad_accum == 1:
                (loss, metrics), grads = grad_fn(state["params"], batch)
            else:
                k = grad_accum
                mb = jax.tree.map(
                    lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]),
                    batch)

                @functools.partial(
                    jax.checkpoint,
                    policy=jax.checkpoint_policies.nothing_saveable)
                def micro(carry, b):
                    gsum, lsum, msum = carry
                    b = jax.tree.map(_shard_batch_leaf, b)
                    (l, m), g = grad_fn(state["params"], b)
                    gsum = _pin(jax.tree.map(
                        lambda a, x: a + x.astype(a.dtype), gsum, g))
                    msum = jax.tree.map(lambda a, x: a + x, msum, m)
                    return (gsum, lsum + l, msum), None

                acc_dtype = jnp.dtype(cfg.grad_accum_dtype)
                g0 = _pin(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dtype),
                    state["params"]))
                m0 = {"ce": 0.0, "z_loss": 0.0, "moe_aux": 0.0}
                m0 = jax.tree.map(jnp.float32, m0)
                (gsum, lsum, msum), _ = jax.lax.scan(
                    micro, (g0, jnp.float32(0.0), m0), mb)
                grads = jax.tree.map(lambda g: g / k, gsum)
                loss = lsum / k
                metrics = jax.tree.map(lambda x: x / k, msum)
            grads, gnorm = optim_lib.clip_by_global_norm(grads, clip_norm)
            params, opt_state = optimizer.update(
                grads, state["opt"], state["params"])
            new_state = {"params": params, "opt": opt_state,
                         "step": state["step"] + 1}
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            return new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh: Mesh | None = None, rules=None):
    rules = rules or default_rules()

    def prefill_step(params, batch):
        with use_mesh_rules(mesh, rules):
            return model_lib.prefill(
                cfg, params, batch["tokens"], frames=batch.get("frames"),
                img=batch.get("img"))

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh: Mesh | None = None, rules=None):
    rules = rules or default_rules()

    def decode_step(params, cache, token, pos):
        with use_mesh_rules(mesh, rules):
            return model_lib.decode_step(cfg, params, cache, token, pos)

    return decode_step


# ---------------------------------------------------------------------------
# Abstract specs (dry-run: zero allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype, axes, mesh, rules):
    sharding = None
    if mesh is not None:
        sharding = NamedSharding(mesh, logical_to_spec(axes, rules, mesh))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_sharding(mesh, rules):
    return NamedSharding(mesh, logical_to_spec(("batch", "seq"), rules, mesh))


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh | None = None,
                rules=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    rules = rules or rules_for(shape)
    B, L = shape.global_batch, shape.seq_len
    mem_div = MEM_LEN_DIV[shape.kind]
    d_front = cfg.d_frontend or cfg.d_model
    tok = functools.partial(_sds, dtype=jnp.int32, mesh=mesh, rules=rules)
    emb = functools.partial(_sds, dtype=jnp.float32, mesh=mesh, rules=rules)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            l_tgt = L // 2
            batch = {
                "frames": emb((B, L - l_tgt, d_front),
                              axes=("batch", "seq", None)),
                "tokens": tok((B, l_tgt), axes=("batch", "seq")),
            }
            if shape.kind == "train":
                batch["labels"] = tok((B, l_tgt), axes=("batch", "seq"))
        else:
            batch = {"tokens": tok((B, L), axes=("batch", "seq"))}
            if cfg.family == "vlm":
                batch["img"] = emb((B, cfg.n_img_tokens, d_front),
                                   axes=("batch", None, None))
            if shape.kind == "train":
                batch["labels"] = tok((B, L), axes=("batch", "seq"))
        return batch

    # decode: cache + one token
    return {
        "cache": abstract_cache(cfg, shape, mesh, rules),
        "token": tok((B, 1), axes=("batch", None)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def abstract_cache(cfg: ArchConfig, shape: ShapeSpec, mesh, rules) -> dict:
    B, L = shape.global_batch, shape.seq_len
    mem_len = (cfg.n_img_tokens if cfg.family == "vlm"
               else L // MEM_LEN_DIV["decode"])
    tree = model_lib.cache_specs(cfg, B, L, mem_len)

    def leaf(entry):
        shp, axes, dtype = entry
        return _sds(shp, dtype, axes, mesh, rules)

    return jax.tree.map(leaf, tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        len(x) == 3 and isinstance(x[0], tuple))


def train_state_specs(cfg: ArchConfig, optimizer: optim_lib.Optimizer,
                      mesh: Mesh | None = None, rules=None):
    """Abstract sharded train state {params, opt, step} for .lower()."""
    rules = rules or default_rules()
    pspecs = model_lib.model_specs(cfg)
    params = abstract_params(pspecs, mesh, rules)
    opt = abstract_params(optimizer.state_specs(pspecs), mesh, rules)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return {"params": params, "opt": opt, "step": step}
