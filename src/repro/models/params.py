"""Module-free parameter trees: specs, init, and mesh shardings.

Models declare nested dicts of :class:`ParamSpec` (shape + *logical axes* +
init). From one spec tree we derive:

* materialized params (smoke tests / real training) — deterministic per-leaf
  PRNG streams;
* abstract ``ShapeDtypeStruct`` trees **with shardings attached** for the
  dry-run (no host allocation — a 398B model never touches RAM);
* ``NamedSharding`` trees from logical→mesh-axis rules (the MaxText-style
  indirection that lets one model definition run on any mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "ParamSpec", "init_params", "abstract_params", "tree_shardings",
    "LOGICAL_RULES", "logical_to_spec", "spec_bytes",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    init: str = "normal"                  # normal | zeros | ones | small
    dtype: Any = jnp.float32
    fan_in_dims: tuple[int, ...] = ()     # dims forming fan-in (default dim 0..-2)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# Logical axis → mesh axes. `embed` is the FSDP axis (params sharded over
# `data`); head/ffn/expert/vocab dims are the TP/EP axis (`model`). The
# `pod` axis is pure DP: params replicated across pods, batch split.
LOGICAL_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "batch_nopod": "data",
    "embed": ("pod", "data"),   # FSDP for params (ZeRO-3 across pods too)
    "vocab": "model",
    "heads": "model",       # fused n_heads*head_dim param dims
    "kv": "model",
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,     # expert inner dim (experts already take `model`)
    "layers": None,
    "seq": None,
    "seq_shard": "model",   # KV-cache seq dim (batch occupies `data`);
                            # long_context_rules remaps to ("data","model")
    "conv": None,
    "state": None,
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
}


def logical_to_spec(axes, rules: Mapping[str, Any] | None = None,
                    mesh: Mesh | None = None) -> PartitionSpec:
    rules = LOGICAL_RULES if rules is None else rules
    names = set(mesh.axis_names) if mesh is not None else None

    def resolve(a):
        if a is None:
            return None
        r = rules.get(a)
        if r is None:
            return None
        if isinstance(r, tuple):
            kept = tuple(x for x in r if names is None or x in names)
            return kept if kept else None
        if names is not None and r not in names:
            return None
        return r

    return PartitionSpec(*[resolve(a) for a in axes])


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_dims = spec.fan_in_dims or tuple(range(max(1, len(spec.shape) - 1)))
    fan_in = int(np.prod([spec.shape[d] for d in fan_dims])) or 1
    scale = 0.02 if spec.init == "small" else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale
            ).astype(spec.dtype)


def _iter_leaves(tree, path=()):
    if isinstance(tree, ParamSpec):
        yield path, tree
        return
    assert isinstance(tree, Mapping), type(tree)
    for k in sorted(tree):
        yield from _iter_leaves(tree[k], path + (k,))


def init_params(spec_tree, seed: int = 0):
    """Materialize the tree (deterministic per-leaf streams keyed by path)."""
    root = jax.random.key(seed)
    out: dict = {}
    for path, spec in _iter_leaves(spec_tree):
        key = root
        for part in path:
            key = jax.random.fold_in(key, hash(part) & 0x7FFFFFFF)
        node = out
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = _init_leaf(spec, key)
    return out


def abstract_params(spec_tree, mesh: Mesh | None = None,
                    rules: Mapping[str, Any] | None = None):
    """ShapeDtypeStruct tree (+ shardings when a mesh is given) — dry-run."""
    def leaf(spec: ParamSpec):
        sharding = None
        if mesh is not None:
            sharding = NamedSharding(mesh, logical_to_spec(spec.axes, rules, mesh))
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sharding)
    return jax.tree.map(leaf, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_shardings(spec_tree, mesh: Mesh,
                   rules: Mapping[str, Any] | None = None):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_to_spec(s.axes, rules, mesh)),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_bytes(spec_tree) -> int:
    total = 0
    for _, spec in _iter_leaves(spec_tree):
        total += int(np.prod(spec.shape)) * jnp.dtype(spec.dtype).itemsize
    return total
