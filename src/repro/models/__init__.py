"""LM substrate: model definitions for the assigned architectures."""
from . import (attention, blocks, layers, model, moe, params, sharding, ssm,
               steps)

__all__ = ["attention", "blocks", "layers", "model", "moe", "params",
           "sharding", "ssm", "steps"]
