"""Shared layer primitives: RMSNorm, RoPE, SwiGLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import ParamSpec

__all__ = [
    "rms_norm", "rope_freqs", "apply_rope", "swiglu", "mlp_specs", "mlp_apply",
    "norm_spec",
]


def norm_spec(d: int, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones", dtype=dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)
            ).astype(dtype)


def rope_freqs(head_dim: int, theta: float):
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """Rotate ``x[(b, l, h, dh)]`` by ``positions[(b, l)]`` (int32)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv     # (b, l, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mlp_specs(d: int, d_ff: int, dtype=jnp.float32) -> dict:
    return {
        "w_gate": ParamSpec((d, d_ff), ("embed", "mlp"), dtype=dtype),
        "w_up": ParamSpec((d, d_ff), ("embed", "mlp"), dtype=dtype),
        "w_down": ParamSpec((d_ff, d), ("mlp", "embed"), dtype=dtype),
    }


def swiglu(x, w_gate, w_up, w_down):
    dt = x.dtype
    g = jnp.einsum("bld,df->blf", x, w_gate.astype(dt))
    u = jnp.einsum("bld,df->blf", x, w_up.astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("blf,fd->bld", h, w_down.astype(dt))


def mlp_apply(params, x):
    return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])
