"""Mixture-of-Experts with Dynasor-style owner-computes dispatch.

MoE dispatch **is** the paper's sparse problem in disguise: tokens are
nonzeros, experts are super-shards (output owners), and routing is the
dynamic remap. We reuse the same sort-into-static-buckets primitive as
``core.remap.bucket_by_destination``: tokens are argsorted by expert id into
a capacity-padded ``(E, cap, d)`` buffer (lock-free — each expert's GEMM
reads a private contiguous slab), processed with stacked-expert einsums, and
combined with a masked scatter-add. Over-capacity tokens are dropped
(counted in metrics), exactly like the remap-capacity accounting in
``core.remap``.

Expert weights carry the ``experts`` logical axis → the `model` mesh axis
(expert parallelism); the token buffers shard the same way, so each device
computes only its owned experts — the paper's "all updates to an output row
happen on its owner" invariant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import shard_map as _shard_map
from .params import ParamSpec
from .sharding import active_mesh_rules, shard

__all__ = ["moe_specs", "moe_apply", "moe_apply_owner", "router_assign"]


def moe_specs(d: int, d_ff: int, n_experts_padded: int, n_shared: int,
              n_experts_real: int, dtype=jnp.float32) -> dict:
    E = n_experts_padded
    specs = {
        "router": ParamSpec((d, E), ("embed", None), init="small",
                            dtype=jnp.float32),
        "w_gate": ParamSpec((E, d, d_ff), ("experts", "embed", "expert_mlp"),
                            dtype=dtype, fan_in_dims=(1,)),
        "w_up": ParamSpec((E, d, d_ff), ("experts", "embed", "expert_mlp"),
                          dtype=dtype, fan_in_dims=(1,)),
        "w_down": ParamSpec((E, d_ff, d), ("experts", "expert_mlp", "embed"),
                            dtype=dtype, fan_in_dims=(1,)),
    }
    if n_shared:
        f = n_shared * d_ff
        specs["shared"] = {
            "w_gate": ParamSpec((d, f), ("embed", "mlp"), dtype=dtype),
            "w_up": ParamSpec((d, f), ("embed", "mlp"), dtype=dtype),
            "w_down": ParamSpec((f, d), ("mlp", "embed"), dtype=dtype),
        }
    return specs


def router_assign(xf, router_w, n_real: int, top_k: int):
    """Router: returns ``(probs[(T,k)], ids[(T,k)], aux_loss)``."""
    T, _ = xf.shape
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w)
    E_pad = logits.shape[-1]
    pad_mask = jnp.arange(E_pad) < n_real
    logits = jnp.where(pad_mask[None, :], logits, -1e30)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, ids = jax.lax.top_k(probs_full, top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss over real experts.
    density = jnp.zeros((E_pad,)).at[ids.reshape(-1)].add(1.0) / (T * top_k)
    mean_prob = probs_full.mean(0)
    aux = n_real * jnp.sum(density * mean_prob)
    return probs, ids.astype(jnp.int32), aux


def moe_apply(params, x, *, n_real: int, top_k: int,
              capacity_factor: float = 1.25, deterministic_cap: int = 0,
              impl: str = "auto"):
    """Apply the MoE block to ``x[(b, l, d)]`` → ``(y, metrics)``.

    ``impl='owner'`` (default whenever a mesh context is active) uses the
    Dynasor owner-computes dispatch under ``shard_map``: tokens stay on
    their data shard, every device locally buckets the tokens routed to
    *its* experts (the super-shard invariant — all updates to an output
    owner happen on that owner, lock-free), and one ``psum`` over the
    expert axis combines. No (tokens × d_model) tensor is ever replicated
    — the GSPMD gather fallback ('gather') materializes exactly that and
    is kept for single-device use and as the measured §Perf baseline.
    """
    ctx = active_mesh_rules()
    if impl == "auto":
        impl = "owner" if ctx is not None else "gather"
    if impl == "owner" and ctx is not None:
        return moe_apply_owner(params, x, n_real=n_real, top_k=top_k,
                               capacity_factor=capacity_factor,
                               deterministic_cap=deterministic_cap)
    return _moe_apply_gather(params, x, n_real=n_real, top_k=top_k,
                             capacity_factor=capacity_factor,
                             deterministic_cap=deterministic_cap)


def _moe_apply_gather(params, x, *, n_real: int, top_k: int,
                      capacity_factor: float = 1.25,
                      deterministic_cap: int = 0):
    """GSPMD gather/scatter dispatch (baseline path)."""
    b, l, d = x.shape
    T = b * l
    xf = shard(x.reshape(T, d), "batch", None)
    E = params["w_gate"].shape[0]
    probs, ids, aux = router_assign(xf, params["router"], n_real, top_k)

    cap = deterministic_cap or max(
        8, int(-(-T * top_k * capacity_factor // E)))
    # --- Dynasor dispatch: sort (token, slot) pairs by owning expert -----
    e_flat = ids.reshape(-1)                              # (T·k,)
    p_flat = probs.reshape(-1)
    tok = jnp.arange(T * top_k, dtype=jnp.int32) // top_k
    order = jnp.argsort(e_flat)                           # stable
    e_s = jnp.take(e_flat, order)
    tok_s = jnp.take(tok, order)
    p_s = jnp.take(p_flat, order)
    start = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - start.astype(jnp.int32)
    ok = pos < cap
    slot = jnp.where(ok, e_s * cap + pos, E * cap)        # dump slot
    buf_tok = jnp.zeros((E * cap + 1,), jnp.int32).at[slot].set(tok_s)[:-1]
    buf_p = jnp.zeros((E * cap + 1,), p_s.dtype).at[slot].set(p_s)[:-1]
    buf_ok = jnp.zeros((E * cap + 1,), bool).at[slot].set(ok)[:-1]
    dropped = jnp.sum(~ok)

    # --- owner-computes expert GEMMs ------------------------------------
    # Buffers shard (experts → model, capacity → data): each device owns a
    # private slab of its experts' tokens — the lock-free super-shard
    # property — and the (E, cap, d_ff) hidden never materializes anywhere.
    xe = jnp.take(xf, buf_tok, axis=0).reshape(E, cap, d)
    xe = jnp.where(buf_ok.reshape(E, cap, 1), xe, 0)
    xe = shard(xe, "experts", "batch", None)
    dt = x.dtype
    gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = shard(h, "experts", "batch", None)
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    out = shard(out, "experts", "batch", None)

    # --- combine (masked scatter-add, weighted by router prob) ----------
    w = jnp.where(buf_ok, buf_p, 0.0).astype(out.dtype)
    y = jnp.zeros((T, d), out.dtype).at[buf_tok.reshape(-1)].add(
        out.reshape(E * cap, d) * w.reshape(-1, 1))
    y = shard(y, "batch", None)

    if "shared" in params:
        sh = params["shared"]
        g = jnp.einsum("td,df->tf", xf, sh["w_gate"].astype(dt))
        u = jnp.einsum("td,df->tf", xf, sh["w_up"].astype(dt))
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u,
                           sh["w_down"].astype(dt))

    metrics = {"moe_aux": aux, "moe_dropped": dropped}
    return y.reshape(b, l, d), metrics


# ---------------------------------------------------------------------------
# Owner-computes dispatch (Dynasor super-shard semantics, shard_map)
# ---------------------------------------------------------------------------

def _resolve_axes(rules, name, mesh):
    r = rules.get(name)
    if r is None:
        return ()
    if isinstance(r, str):
        r = (r,)
    return tuple(a for a in r if a in mesh.axis_names)


def moe_apply_owner(params, x, *, n_real: int, top_k: int,
                    capacity_factor: float = 1.25,
                    deterministic_cap: int = 0):
    """Expert-parallel MoE with the paper's owner-computes invariant.

    Tokens stay sharded over the data axes (replicated over the expert
    axis); every device *locally* buckets the tokens routed to the experts
    it owns (sort-into-capacity-slabs — ``core.remap`` semantics), runs the
    expert GEMMs on its private slab, scatter-adds into a local partial
    output, and a single ``psum`` over the expert axis combines. The only
    other collective is the FSDP all-gather of the owned experts' weights.
    Nothing of size (tokens × d_model) is ever replicated.
    """
    from jax.sharding import PartitionSpec as P

    mesh, rules = active_mesh_rules()
    b, l, d = x.shape
    T = b * l
    xf = x.reshape(T, d)
    tok_axes = _resolve_axes(rules, "batch", mesh)
    exp_axes = _resolve_axes(rules, "experts", mesh)
    fsdp_axes = _resolve_axes(rules, "embed", mesh)
    if not exp_axes:
        return _moe_apply_gather(params, x, n_real=n_real, top_k=top_k,
                                 capacity_factor=capacity_factor,
                                 deterministic_cap=deterministic_cap)
    import math
    n_tok = math.prod(mesh.shape[a] for a in tok_axes) if tok_axes else 1
    n_exp = math.prod(mesh.shape[a] for a in exp_axes)
    E = params["w_gate"].shape[0]
    assert E % n_exp == 0, (E, n_exp)
    E_local = E // n_exp
    T_local = T // n_tok
    cap = deterministic_cap or max(
        8, int(-(-T_local * top_k * capacity_factor // E)))
    has_shared = "shared" in params
    dt = x.dtype

    def local(xf_l, router, wg_l, wu_l, wd_l, *shared_ws):
        eid = jax.lax.axis_index(exp_axes[0]) if len(exp_axes) == 1 else \
            jax.lax.axis_index(exp_axes)
        e0 = eid * E_local
        probs, ids, aux = router_assign(xf_l, router, n_real, top_k)
        e_flat = ids.reshape(-1)
        p_flat = probs.reshape(-1)
        tok = jnp.arange(T_local * top_k, dtype=jnp.int32) // top_k
        mine = (e_flat >= e0) & (e_flat < e0 + E_local)
        dest = jnp.where(mine, e_flat - e0, E_local)
        order = jnp.argsort(dest)
        d_s = jnp.take(dest, order)
        tok_s = jnp.take(tok, order)
        p_s = jnp.take(p_flat, order)
        start = jnp.searchsorted(d_s, d_s, side="left")
        pos = jnp.arange(d_s.shape[0], dtype=jnp.int32) - start.astype(
            jnp.int32)
        valid = d_s < E_local
        ok = valid & (pos < cap)
        slot = jnp.where(ok, d_s * cap + pos, E_local * cap)
        buf_tok = jnp.zeros((E_local * cap + 1,), jnp.int32
                            ).at[slot].set(tok_s)[:-1]
        buf_p = jnp.zeros((E_local * cap + 1,), p_s.dtype
                          ).at[slot].set(p_s)[:-1]
        buf_ok = jnp.zeros((E_local * cap + 1,), bool).at[slot].set(ok)[:-1]
        dropped = jnp.sum(valid) - jnp.sum(ok)

        xe = jnp.take(xf_l, buf_tok, axis=0).reshape(E_local, cap, d)
        xe = jnp.where(buf_ok.reshape(E_local, cap, 1), xe, 0)
        # FSDP gather of the owned experts' weights (ZeRO-3 style)
        wg = jax.lax.all_gather(wg_l, fsdp_axes, axis=1, tiled=True) \
            if fsdp_axes else wg_l
        wu = jax.lax.all_gather(wu_l, fsdp_axes, axis=1, tiled=True) \
            if fsdp_axes else wu_l
        wd = jax.lax.all_gather(wd_l, fsdp_axes, axis=2, tiled=True) \
            if fsdp_axes else wd_l
        gate = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dt))
        up = jnp.einsum("ecd,edf->ecf", xe, wu.astype(dt))
        hh = jax.nn.silu(gate) * up
        out = jnp.einsum("ecf,efd->ecd", hh, wd.astype(dt))
        w = jnp.where(buf_ok, buf_p, 0.0).astype(out.dtype)
        y = jnp.zeros((T_local, d), out.dtype).at[buf_tok.reshape(-1)].add(
            out.reshape(E_local * cap, d) * w.reshape(-1, 1))

        if has_shared:
            sg_l, su_l, sd_l = shared_ws
            # shared weights: f sharded over expert axis, d over fsdp
            sg = jax.lax.all_gather(sg_l, fsdp_axes, axis=0, tiled=True) \
                if fsdp_axes else sg_l
            su = jax.lax.all_gather(su_l, fsdp_axes, axis=0, tiled=True) \
                if fsdp_axes else su_l
            sd = jax.lax.all_gather(sd_l, fsdp_axes, axis=1, tiled=True) \
                if fsdp_axes else sd_l
            g = jnp.einsum("td,df->tf", xf_l, sg.astype(dt))
            u = jnp.einsum("td,df->tf", xf_l, su.astype(dt))
            y = y + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u,
                               sd.astype(dt))
        y = jax.lax.psum(y, exp_axes)
        # each routed pair has exactly one owner → plain global sum
        dropped = jax.lax.psum(dropped, exp_axes + tok_axes)
        if tok_axes:
            aux = jax.lax.pmean(aux, tok_axes)
        return y, aux, dropped

    tok_spec = tok_axes if tok_axes else None
    in_specs = [
        P(tok_spec, None),                                # xf
        P(None, None),                                    # router
        P(exp_axes, fsdp_axes or None, None),             # w_gate
        P(exp_axes, fsdp_axes or None, None),             # w_up
        P(exp_axes, None, fsdp_axes or None),             # w_down
    ]
    args = [xf, params["router"], params["w_gate"], params["w_up"],
            params["w_down"]]
    if has_shared:
        sh = params["shared"]
        in_specs += [P(fsdp_axes or None, exp_axes),      # shared w_gate
                     P(fsdp_axes or None, exp_axes),      # shared w_up
                     P(exp_axes, fsdp_axes or None)]      # shared w_down
        args += [sh["w_gate"], sh["w_up"], sh["w_down"]]
    y, aux, dropped = _shard_map(
        local, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(tok_spec, None), P(), P()),
    )(*args)
    return y.reshape(b, l, d), {"moe_aux": aux, "moe_dropped": dropped}
