"""Unified LM: embedding → scanned heterogeneous block pattern → logits.

One definition covers all ten assigned architectures. The repeating layer
``pattern`` (from the ArchConfig) is the scan body; parameters for each
pattern position are stacked along a leading ``layers`` axis, so the HLO
contains exactly one copy of the pattern-group body regardless of depth —
this is what keeps 72-layer/398B compiles tractable and is the standard
production trick (MaxText-style scanned layers + remat).

Entry points:
  * ``forward``      — full-sequence logits (training / encoder teacher-forcing);
  * ``prefill``      — logits + per-block decode caches;
  * ``decode_step``  — one token in, one token out, caches updated.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import blocks as blk
from .layers import norm_spec, rms_norm
from .params import ParamSpec
from .sharding import shard

__all__ = [
    "model_specs", "forward", "prefill", "decode_step", "cache_specs",
]


# ---------------------------------------------------------------------------
# Parameter tree
# ---------------------------------------------------------------------------

def _stack_specs(specs, n: int):
    def f(s: ParamSpec):
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, init=s.init,
                         dtype=s.dtype,
                         fan_in_dims=tuple(d + 1 for d in s.fan_in_dims)
                         or tuple(range(1, max(2, len(s.shape)))))
    return jax.tree.map(f, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def model_specs(cfg) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    specs: dict = {
        "embed": ParamSpec((cfg.vocab_padded, d), ("vocab", "embed"),
                           init="small", dtype=dtype),
        "out_norm": norm_spec(d, dtype),
        "blocks": {
            f"p{j}": _stack_specs(blk.block_specs(cfg, kind, dtype),
                                  cfg.n_repeats)
            for j, kind in enumerate(cfg.pattern)
        },
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.vocab_padded, d),
                                     ("vocab", "embed"), init="small",
                                     dtype=dtype)
    if cfg.family == "encdec":
        n_enc_rep = cfg.n_enc_layers // len(cfg.enc_pattern)
        specs["encoder"] = {
            "frontend_proj": ParamSpec(
                (cfg.d_frontend or d, d), (None, "embed"), dtype=dtype),
            "blocks": {
                f"p{j}": _stack_specs(blk.block_specs(cfg, kind, dtype),
                                      n_enc_rep)
                for j, kind in enumerate(cfg.enc_pattern)
            },
            "norm": norm_spec(d, dtype),
        }
    if cfg.family == "vlm":
        specs["img_proj"] = ParamSpec((cfg.d_frontend or d, d),
                                      (None, "embed"), dtype=dtype)
    return specs


# ---------------------------------------------------------------------------
# Core scans
# ---------------------------------------------------------------------------

def _scan_blocks(cfg, block_params, h, pos, memory, mode, remat: bool):
    """Forward scan over stacked pattern groups; accumulates MoE aux.

    Remat is applied per *layer*, not just per pattern group: a group body
    of e.g. 8 layers (jamba) would otherwise keep all 8 layers' recomputed
    backward residuals live at once.
    """

    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat_policy == "nothing"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def one_layer(kind, p, h):
        return blk.block_apply(cfg, kind, p, h, pos=pos, memory=memory,
                               mode=mode)

    # Only patterns with >2 layers per group get the inner per-layer
    # checkpoint (bounds the backward working set to one layer); short
    # groups would pay an extra forward recompute for nothing.
    if remat and len(cfg.pattern) > 2:
        one_layer = jax.checkpoint(one_layer, policy=policy,
                                   static_argnums=(0,))

    def body(carry, group):
        h, aux = carry
        for j, kind in enumerate(cfg.pattern):
            h, metrics = one_layer(kind, group[f"p{j}"], h)
            aux = aux + metrics.get("moe_aux", 0.0)
        h = shard(h, "batch", "seq", "act_embed")
        return (h, aux), None

    if remat:
        # outer checkpoint: only the group-boundary carry is saved per
        # scan step; inner per-layer checkpoints bound the recompute
        # working set to a single layer.
        body = jax.checkpoint(body, policy=policy)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               block_params)
    return h, aux


def _scan_enc(cfg, enc_params, h, pos, remat: bool):
    def body(carry, group):
        for j, kind in enumerate(cfg.enc_pattern):
            carry, _ = blk.block_apply(cfg, kind, group[f"p{j}"], carry,
                                       pos=pos, mode="full")
        return carry, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, enc_params["blocks"])
    return rms_norm(h, enc_params["norm"])


def _embed_tokens(cfg, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    return shard(h.astype(jnp.dtype(cfg.act_dtype)),
                 "batch", "seq", "act_embed")


def _unembed(cfg, params, h):
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bld,vd->blv", h, w.astype(h.dtype))
    return shard(logits, "batch", "seq", "vocab")


def _memory_of(cfg, params, frames=None, img=None, remat=True):
    """Stub-frontend → backbone memory (enc-dec encode / vlm projection)."""
    if cfg.family == "encdec":
        enc = params["encoder"]
        h = jnp.einsum("blf,fd->bld",
                       frames.astype(jnp.dtype(cfg.act_dtype)),
                       enc["frontend_proj"].astype(jnp.dtype(cfg.act_dtype)))
        h = shard(h, "batch", "seq", "act_embed")
        pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1], dtype=jnp.int32)[None],
            frames.shape[:2])
        return _scan_enc(cfg, enc, h, pos, remat)
    if cfg.family == "vlm":
        return jnp.einsum("blf,fd->bld",
                          img.astype(jnp.dtype(cfg.act_dtype)),
                          params["img_proj"].astype(jnp.dtype(cfg.act_dtype)))
    return None


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens, *, frames=None, img=None, remat=True):
    """Training forward: logits ``(b, l, vocab_padded)`` + aux losses."""
    memory = _memory_of(cfg, params, frames, img, remat)
    h = _embed_tokens(cfg, params, tokens)
    b, l = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    h, aux = _scan_blocks(cfg, params["blocks"], h, pos, memory, "causal",
                          remat)
    h = rms_norm(h, params["out_norm"])
    return _unembed(cfg, params, h), {"moe_aux": aux}


def prefill(cfg, params, tokens, *, frames=None, img=None):
    """Prompt processing: returns (last-token logits, cache pytree)."""
    memory = _memory_of(cfg, params, frames, img, remat=False)
    h = _embed_tokens(cfg, params, tokens)
    b, l = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))

    def body(h, group):
        caches = {}
        for j, kind in enumerate(cfg.pattern):
            h, cache = blk.block_prefill(cfg, kind, group[f"p{j}"], h,
                                         pos=pos, memory=memory)
            caches[f"p{j}"] = cache
        return h, caches

    h, cache = jax.lax.scan(body, h, params["blocks"])
    h = rms_norm(h, params["out_norm"])
    logits = _unembed(cfg, params, h[:, -1:, :])
    return logits, cache


def decode_step(cfg, params, cache, token, pos):
    """One decode step. ``token[(b, 1)]``, ``pos`` scalar int32 = slot of the
    new token. Returns (logits[(b, 1, V)], cache')."""
    h = _embed_tokens(cfg, params, token)

    def body(h, xs):
        group, cache_in = xs
        cache_out = {}
        for j, kind in enumerate(cfg.pattern):
            h, c = blk.block_decode(cfg, kind, group[f"p{j}"], h,
                                    cache_in[f"p{j}"], pos=pos)
            cache_out[f"p{j}"] = c
        return h, cache_out

    h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    h = rms_norm(h, params["out_norm"])
    return _unembed(cfg, params, h), new_cache


def cache_specs(cfg, batch: int, seq: int, mem_len: int) -> dict:
    """(shape, logical axes, dtype) tree matching prefill's cache output —
    stacked along the scan (layers) axis."""
    out = {}
    for j, kind in enumerate(cfg.pattern):
        per = blk.block_cache_specs(cfg, kind, batch, seq, mem_len)
        out[f"p{j}"] = {
            name: ((cfg.n_repeats,) + shape, ("layers",) + axes, dtype)
            for name, (shape, axes, dtype) in per.items()
        }
    return out
