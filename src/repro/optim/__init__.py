"""Optimizers (AdamW, Adafactor), LR schedules, global-norm clipping.

Implemented from scratch (no optax dependency). Each optimizer exposes:
  * ``init(params)``          — state pytree;
  * ``update(grads, state, params)`` → ``(new_params, new_state)``;
  * ``state_specs(param_specs)`` — ParamSpec tree for the state, so the
    dry-run can build sharded abstract optimizer state without allocating
    (a 398B model's Adam state is ~3TB — it must never touch host RAM).

Adafactor (factored second moment, no momentum) is what the largest
assigned configs (jamba-1.5-large-398b) use to fit the 16 GB/chip budget.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.params import ParamSpec

__all__ = [
    "Optimizer", "adamw", "adafactor", "cosine_schedule", "global_norm",
    "clip_by_global_norm", "make_optimizer",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params) -> (params, state)
    state_specs: Callable     # (param_spec_tree) -> state spec tree


def cosine_schedule(peak_lr: float = 3e-4, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def _is_spec(x):
    return isinstance(x, ParamSpec)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: Callable, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        lr_t = lr(c)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mh = m / (1 - b1 ** cf)
            vh = v / (1 - b2 ** cf)
            step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
                jnp.float32)
            newp = p.astype(jnp.float32) - lr_t * step
            return newp.astype(p.dtype), m.astype(state_dtype), \
                v.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": m, "v": v, "count": c}

    def state_specs(param_specs):
        as_state = lambda s: ParamSpec(s.shape, s.axes, init="zeros",
                                       dtype=state_dtype)
        return {"m": jax.tree.map(as_state, param_specs, is_leaf=_is_spec),
                "v": jax.tree.map(as_state, param_specs, is_leaf=_is_spec),
                "count": ParamSpec((), (), init="zeros", dtype=jnp.int32)}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored v, no momentum)
# ---------------------------------------------------------------------------

def adafactor(lr: Callable, *, decay=0.8, eps=1e-30, clip_thresh=1.0,
              weight_decay=0.0) -> Optimizer:
    def factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def per(p):
            if factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(per, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        beta = 1.0 - cf ** (-decay)
        lr_t = lr(c)

        def upd(g, vdict, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if factored(g.shape):
                vr = beta * vdict["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * vdict["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                       eps))
                u = g32 / jnp.sqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                v = beta * vdict["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(jnp.maximum(v, eps))
                nv = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            newp = p.astype(jnp.float32) - lr_t * (
                u + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        newp = tdef.unflatten([o[0] for o in outs])
        newv = tdef.unflatten([o[1] for o in outs])
        return newp, {"v": newv, "count": c}

    def state_specs(param_specs):
        def per(s: ParamSpec):
            if factored(s.shape):
                return {"vr": ParamSpec(s.shape[:-1], s.axes[:-1],
                                        init="zeros", dtype=jnp.float32),
                        "vc": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                        s.axes[:-2] + s.axes[-1:],
                                        init="zeros", dtype=jnp.float32)}
            return {"v": ParamSpec(s.shape, s.axes, init="zeros",
                                   dtype=jnp.float32)}
        return {"v": jax.tree.map(per, param_specs, is_leaf=_is_spec),
                "count": ParamSpec((), (), init="zeros", dtype=jnp.int32)}

    return Optimizer(init, update, state_specs)


def make_optimizer(name: str, lr: Callable | None = None, **kw) -> Optimizer:
    lr = lr or cosine_schedule()
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
