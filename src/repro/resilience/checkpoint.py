"""Checkpointed, resumable CP-ALS — the dormant manager, finally wired in.

The seed shipped an atomic :class:`repro.checkpoint.CheckpointManager`
(tmp-dir → fsync → rename → ``_DONE`` marker) that nothing used. This
module is the CP-ALS adapter: one sweep's complete algorithm state as a
flat array pytree the manager can persist, plus validated restore.

What a sweep checkpoint holds (``cp_als`` / ``cp_als_distributed``
``checkpoint_dir=``):

* the factor matrices (permuted row space for the distributed driver —
  the space the algorithm iterates in),
* ``lam`` (column weights) and the fit trace so far,
* the sweep index,
* for the distributed driver, the packed nonzero stream
  ``(idx, val, mask)`` — the remapped, locality-reordered layout as of
  the end of the sweep, so a resumed job continues with the *exact*
  stream (per-mode reorder permutations included) instead of
  re-preprocessing and re-paying the fp32 accumulation-order drift,
* config fingerprints (``rank``, ``ordering``, ``backend``) that
  :func:`restore_state` validates — resuming under a different
  configuration is a hard ``ValueError``, never a silently different
  decomposition.

Every save/restore is counted (``resilience.checkpoint.saves`` /
``resilience.checkpoint.restores``).
"""
from __future__ import annotations

import numpy as np

from ..checkpoint import CheckpointManager
from ..obs import counters as _obs

__all__ = [
    "STATE_VERSION",
    "make_manager",
    "make_state",
    "restore_state",
    "save_state",
]

STATE_VERSION = 1


def make_manager(directory: str | None, *, keep: int = 3
                 ) -> CheckpointManager | None:
    """A manager for ``directory`` (``None`` → checkpointing disabled)."""
    return None if directory is None else CheckpointManager(directory,
                                                            keep=keep)


def make_state(factors, lam, fits, *, sweep: int, rank: int,
               ordering: str = "none", backend: str = "",
               stream=None) -> dict:
    """Assemble the flat array pytree one sweep checkpoint persists.

    ``stream`` is the distributed driver's ``(idx, val, mask)`` triple
    (``None`` for the single-device driver). Strings ride as 0-d numpy
    unicode arrays — ``np.save`` round-trips them losslessly.
    """
    state = {
        "version": np.int64(STATE_VERSION),
        "sweep": np.int64(sweep),
        "rank": np.int64(rank),
        "ordering": np.asarray(ordering),
        "backend": np.asarray(backend),
        "lam": np.asarray(lam),
        "fits": np.asarray(fits, dtype=np.float64),
        "factors": [np.asarray(f) for f in factors],
    }
    if stream is not None:
        idx, val, mask = stream
        state["stream_idx"] = np.asarray(idx)
        state["stream_val"] = np.asarray(val)
        state["stream_mask"] = np.asarray(mask)
    return state


def save_state(mgr: CheckpointManager, state: dict) -> str:
    """Atomically persist one sweep's state; returns the step dir."""
    path = mgr.save(int(state["sweep"]), state)
    _obs.add("resilience.checkpoint.saves")
    return path


def restore_state(mgr: CheckpointManager, template: dict
                  ) -> tuple[dict | None, int | None]:
    """Restore the newest complete checkpoint, validated against ``template``.

    Returns ``(state, sweep)`` or ``(None, None)`` when the directory
    holds no complete checkpoint (a fresh start). A checkpoint whose
    config fingerprint (version / rank / ordering / backend) or factor
    shapes disagree with the template raises ``ValueError`` with the
    mismatch spelled out — a resume must continue the *same*
    decomposition or refuse.
    """
    restored, step = mgr.restore(template)
    if restored is None:
        return None, None
    for key in ("version", "rank", "ordering", "backend"):
        want, got = np.asarray(template[key]), np.asarray(restored[key])
        if want.shape == () and got.shape == () and str(want) != str(got):
            raise ValueError(
                f"checkpoint at {mgr.dir!r} step {step} was written with "
                f"{key}={got} but this run is configured with {key}={want} "
                "— resume with the original configuration or point "
                "checkpoint_dir at a fresh directory")
    for n, (t, r) in enumerate(zip(template["factors"],
                                   restored["factors"])):
        if np.asarray(t).shape != np.asarray(r).shape:
            raise ValueError(
                f"checkpoint factor {n} has shape {np.asarray(r).shape}, "
                f"this run expects {np.asarray(t).shape} — tensor/worker "
                "configuration changed; use a fresh checkpoint_dir")
    _obs.add("resilience.checkpoint.restores")
    return restored, int(step)
