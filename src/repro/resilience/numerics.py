"""Numerical guards for the CP-ALS normal-equations solve.

``A_n <- M_n V^+`` with ``V = Hadamard of the other modes' grams`` is the
one numerically fragile step of CP-ALS: a non-finite MTTKRP output (bad
input values), a collapsed factor column, or two nearly-parallel factor
columns make ``V`` singular and the plain ``linalg.solve`` emits
inf/NaN that silently poisons every later sweep. :func:`guarded_solve`
is the jit-safe escalation ladder:

  0. **clean** — the production path: ``solve(V + ridge·I)`` with the
     tiny baseline ridge, exactly what the unguarded solve computed;
  1. **ridge** — non-finite input/solution or a degenerate gram
     diagonal: re-solve with an escalated, scale-aware ridge
     (``escalated_scale · max|diag V|``);
  2. **lstsq** — still non-finite: minimum-norm least squares via the
     SVD pseudo-inverse, with non-finite inputs zeroed first.

The guard level is returned next to the solution so host-side drivers
can count every escalation (``resilience.solve.guards{level=...}`` —
never a silent fallback); the escalated branches live under
``lax.cond`` so a healthy solve never pays the SVD. Levels 1–2 cannot
trigger on finite, well-conditioned inputs — the guarded solve is
bit-identical to the unguarded one on every healthy run (pinned by
``tests/test_resilience.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["GUARD_LEVELS", "guarded_solve"]

# Index == the int32 level guarded_solve returns.
GUARD_LEVELS = ("clean", "ridge", "lstsq")


def guarded_solve(V, M, *, ridge: float = 1e-9,
                  escalated_scale: float = 1e-6,
                  diag_rtol: float = 1e-12):
    """Solve ``X Vᵀ = M`` (V symmetric) with escalating regularization.

    Returns ``(X, level)`` — ``X`` is ``M @ inv(V)`` shaped like ``M``,
    ``level`` an int32 scalar indexing :data:`GUARD_LEVELS`. Jit-safe
    (``lax.cond`` escalation, no data-dependent Python branching), so it
    runs identically inside the fused ``shard_map`` sweep and eagerly in
    the stepped driver.
    """
    R = V.shape[0]
    eye = jnp.eye(R, dtype=M.dtype)
    finite_in = jnp.isfinite(V).all() & jnp.isfinite(M).all()
    Vc = jnp.where(jnp.isfinite(V), V, 0.0)
    Mc = jnp.where(jnp.isfinite(M), M, 0.0)
    d = jnp.diagonal(Vc)
    scale = jnp.maximum(jnp.max(jnp.abs(d)), 1.0)
    # V is a Hadamard product of PSD grams: a ~zero diagonal entry means
    # a collapsed factor column — the cheap, conservative ill-condition
    # signal (no SVD on the hot path).
    illcond = jnp.min(d) <= diag_rtol * scale

    X0 = jnp.linalg.solve(Vc + ridge * eye, Mc.T).T
    clean = finite_in & ~illcond & jnp.isfinite(X0).all()

    def _take_clean(_):
        return X0, jnp.int32(0)

    def _escalate(_):
        X1 = jnp.linalg.solve(Vc + escalated_scale * scale * eye, Mc.T).T

        def _take_ridge(_):
            return X1, jnp.int32(1)

        def _lstsq(_):
            # Minimum-norm least squares (SVD pinv) — always finite.
            X2 = (jnp.linalg.pinv(Vc, rtol=1e-10) @ Mc.T).T
            return jnp.where(jnp.isfinite(X2), X2, 0.0), jnp.int32(2)

        return jax.lax.cond(jnp.isfinite(X1).all(), _take_ridge, _lstsq,
                            None)

    return jax.lax.cond(clean, _take_clean, _escalate, None)
