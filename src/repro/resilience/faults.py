"""Deterministic, seeded fault injection at the stack's failure boundaries.

A production decomposition dies in a handful of well-defined places: the
kernel call can fail to lower or OOM VMEM (``ops.mttkrp_device_step``),
a per-chunk factor-tile DMA can hiccup (``oocore.executor``), the remap
``all_to_all`` can drop a link (``core.distributed``), a calibration
table on disk can be corrupt (``tune.table``), and the execution-mode
resolution can discover mid-job that the compiled path is gone
(``runtime.execution``). Each of those boundaries calls
:func:`fault_site` with its registered site name. Normally that is a
counted no-op; inside an :func:`inject` block the active
:class:`FaultInjector` raises a *typed* fault when the site's call
index matches its schedule.

Design rules, mirroring ``repro.obs``:

* **Closed site registry.** :data:`SITES` is the complete list; an
  unregistered name raises ``ValueError`` at the call site, so the
  injection-site table in ``docs/resilience.md`` cannot silently rot.
* **Seeded, bit-reproducible schedules.** :func:`seeded_schedule` maps
  ``(seed, sites, horizon)`` to a fixed tuple of :class:`FaultSpec`
  via ``np.random.default_rng`` — the chaos CI run replays the exact
  same faults on every host.
* **Typed faults.** :class:`TransientFault` (retry-able — interconnect
  hiccup, preempted DMA), :class:`ResourceFault` (not retry-able at the
  same rung — VMEM OOM, failed lowering; the policy steps *down* the
  residency ladder), :class:`CorruptionFault` (bad bytes — never
  retried, never degraded through: the consumer must discard the
  artifact or abort). The degradation policy in
  :mod:`repro.resilience.policy` dispatches on these types.
* **Counted, never silent.** Every injection lands in the
  ``resilience.injected`` counter (site + kind labels); every site call
  in ``resilience.site_calls`` — the chaos gate asserts
  injected == handled so no fault can vanish into a retry loop
  unaccounted.

The hooks are host-side Python: for code that runs under ``jax.jit``
(the kernel dispatch, the remap) they fire at *trace* time, which is
exactly where real lowering/OOM failures surface — and a fault that
aborts a trace leaves no cache entry, so a retry re-traces and the
site's call counter advances deterministically.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

from ..obs import counters as _obs

__all__ = [
    "SITES",
    "FAULT_KINDS",
    "InjectedFault",
    "TransientFault",
    "ResourceFault",
    "CorruptionFault",
    "FaultSpec",
    "FaultInjector",
    "active_injector",
    "fault_site",
    "inject",
    "seeded_schedule",
]

# The closed injection-site registry — every fault_site() caller in the
# stack, one name per failure boundary. Keep sorted; the table in
# docs/resilience.md mirrors this tuple.
SITES = (
    "distributed.remap",     # core.distributed.device_remap — the all_to_all
    "execution.resolve",     # runtime.execution.resolve_interpret
    "oocore.chunk",          # oocore.executor — per-chunk DMA + kernel call
    "ops.kernel",            # kernels.mttkrp.ops.mttkrp_device_step dispatch
    "tune.table_load",       # tune.table — calibration table read/parse
)
_SITE_SET = frozenset(SITES)


class InjectedFault(RuntimeError):
    """Base of all injected faults; carries the site and call index."""

    kind = "injected"

    def __init__(self, site: str, index: int, note: str = ""):
        self.site = site
        self.index = index
        super().__init__(
            f"injected {self.kind} fault at site {site!r} (call #{index})"
            + (f": {note}" if note else ""))


class TransientFault(InjectedFault):
    """Retry-able blip (interconnect hiccup, preempted DMA)."""

    kind = "transient"


class ResourceFault(InjectedFault):
    """Out of resource at this rung (VMEM OOM, lowering failure) —
    retrying identically cannot succeed; step down the residency ladder."""

    kind = "resource"


class CorruptionFault(InjectedFault):
    """Bad bytes (truncated/garbled artifact) — never retried, never
    degraded through; the consumer discards the artifact or aborts."""

    kind = "corruption"


FAULT_KINDS = {
    "transient": TransientFault,
    "resource": ResourceFault,
    "corruption": CorruptionFault,
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: the ``index``-th call to ``site`` raises ``kind``."""

    site: str
    index: int
    kind: str

    def __post_init__(self):
        if self.site not in _SITE_SET:
            raise ValueError(
                f"unknown fault site {self.site!r}: expected one of {SITES}")
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}: expected one of "
                f"{tuple(FAULT_KINDS)}")
        if self.index < 0:
            raise ValueError(f"fault index must be >= 0, got {self.index}")


# The kind each site defaults to in a seeded schedule — the failure
# mode that boundary realistically produces.
_DEFAULT_KIND = {
    "distributed.remap": "transient",
    "execution.resolve": "resource",
    "oocore.chunk": "transient",
    "ops.kernel": "resource",
    "tune.table_load": "corruption",
}


def seeded_schedule(seed: int, *, sites=SITES, per_site: int = 1,
                    horizon: int = 3,
                    kinds: dict | None = None) -> tuple[FaultSpec, ...]:
    """Deterministic schedule: ``per_site`` faults per site from ``seed``.

    Call indices are drawn without replacement from ``[0, horizon)`` by
    ``np.random.default_rng(seed)`` — bit-reproducible across hosts and
    runs, which is what lets CI pin the chaos run's counter totals.
    ``kinds`` overrides the per-site default fault kind.
    """
    import numpy as np

    kinds = dict(_DEFAULT_KIND, **(kinds or {}))
    rng = np.random.default_rng(seed)
    specs = []
    for site in sites:
        take = min(per_site, horizon)
        for i in sorted(rng.choice(horizon, size=take, replace=False)):
            specs.append(FaultSpec(site=site, index=int(i), kind=kinds[site]))
    return tuple(specs)


class FaultInjector:
    """Replays a fault schedule against the stack's site hooks.

    Thread-safe per-site call counters; each spec fires exactly once
    (the site's counter advances on every call, so a retried call gets
    a fresh index and passes). ``injected`` records what actually fired,
    for the chaos gate's injected-vs-handled accounting.
    """

    def __init__(self, specs: tuple[FaultSpec, ...] = ()):
        self._lock = threading.Lock()
        self._sched: dict[str, dict[int, str]] = {}
        for s in specs:
            if isinstance(s, (tuple, list)):
                s = FaultSpec(*s)
            dup = self._sched.setdefault(s.site, {}).setdefault(
                s.index, s.kind)
            if dup != s.kind:
                raise ValueError(
                    f"conflicting specs for {s.site!r} call #{s.index}: "
                    f"{dup} vs {s.kind}")
        self.specs = tuple(specs)
        self.calls: dict[str, int] = {}
        self.injected: list[FaultSpec] = []

    def on_call(self, site: str) -> None:
        with self._lock:
            i = self.calls.get(site, 0)
            self.calls[site] = i + 1
            kind = self._sched.get(site, {}).get(i)
        if kind is not None:
            spec = FaultSpec(site=site, index=i, kind=kind)
            self.injected.append(spec)
            _obs.add("resilience.injected", site=site, kind=kind)
            raise FAULT_KINDS[kind](site, i)

    def pending(self) -> tuple[FaultSpec, ...]:
        """Scheduled faults that have not fired (site not called enough)."""
        fired = set(self.injected)
        return tuple(FaultSpec(site, i, kind)
                     for site, by_idx in self._sched.items()
                     for i, kind in by_idx.items()
                     if FaultSpec(site, i, kind) not in fired)


_active: FaultInjector | None = None


def active_injector() -> FaultInjector | None:
    return _active


@contextlib.contextmanager
def inject(specs_or_injector):
    """Activate fault injection for the block; restores on exit.

    Accepts a :class:`FaultInjector` or an iterable of
    :class:`FaultSpec`. Yields the injector so callers can assert on
    ``injected`` / ``pending()`` afterwards. Nesting replaces the outer
    injector for the inner block (sites see one injector at a time).
    """
    global _active
    inj = (specs_or_injector if isinstance(specs_or_injector, FaultInjector)
           else FaultInjector(tuple(specs_or_injector)))
    previous = _active
    _active = inj
    try:
        yield inj
    finally:
        _active = previous


def fault_site(site: str) -> None:
    """The stack-side hook: count the call, raise if scheduled.

    A no-op (plus one counter bump) when no injector is active — the
    production path pays a dict update per *host-level* call (kernel
    dispatch and remap hooks fire at jit-trace time, once per compiled
    signature; the chunk hook once per chunk), never per nonzero.
    """
    if site not in _SITE_SET:
        raise ValueError(
            f"unknown fault site {site!r}: expected one of {SITES} — "
            "register new failure boundaries in repro.resilience.faults."
            "SITES and document them in docs/resilience.md")
    _obs.add("resilience.site_calls", site=site)
    if _active is not None:
        _active.on_call(site)
