"""``repro.resilience`` — fault injection, graceful degradation, resume.

Three pieces, one contract (**never a silent wrong answer, never an
uncounted fallback**):

* :mod:`~repro.resilience.faults` — a closed registry of the stack's
  real failure boundaries (:data:`~repro.resilience.faults.SITES`) with
  deterministic, seeded fault injection for bit-reproducible chaos runs;
* :mod:`~repro.resilience.policy` — bounded retry with backoff for
  transient faults, and a recorded walk *down* the existing residency
  ladder (plus compiled → interpret) for resource/lowering faults;
* :mod:`~repro.resilience.checkpoint` + guarded numerics
  (:mod:`~repro.resilience.numerics`) — resumable CP-ALS sweeps through
  the atomic ``CheckpointManager``, and an escalating-ridge/lstsq solve
  guard.

``python -m repro.resilience`` is the seeded chaos smoke CI runs. The
fault taxonomy, injection-site table, and degradation diagram live in
``docs/resilience.md``.
"""
from .checkpoint import make_manager, make_state, restore_state, save_state
from .faults import (
    SITES,
    CorruptionFault,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    ResourceFault,
    TransientFault,
    fault_site,
    inject,
    seeded_schedule,
)
from .numerics import GUARD_LEVELS, guarded_solve
from .policy import (
    DEGRADATION_LADDER,
    ResilienceExhausted,
    RetryPolicy,
    get_policy,
    next_rung,
    use_policy,
)

__all__ = [
    "DEGRADATION_LADDER",
    "GUARD_LEVELS",
    "SITES",
    "CorruptionFault",
    "FaultInjector",
    "FaultSpec",
    "InjectedFault",
    "ResilienceExhausted",
    "ResourceFault",
    "RetryPolicy",
    "TransientFault",
    "fault_site",
    "get_policy",
    "guarded_solve",
    "inject",
    "make_manager",
    "make_state",
    "next_rung",
    "restore_state",
    "save_state",
    "seeded_schedule",
    "use_policy",
]
