"""``python -m repro.resilience`` — the seeded chaos smoke CI runs.

One deterministic program, three acceptance checks (the ISSUE's
contract, gated by the ``chaos-smoke`` CI step):

1. **Chaos completes and converges.** A distributed CP-ALS run under a
   seeded fault schedule (every registered site fires at least once:
   kernel dispatch, remap, execution resolution during the sweep; a
   forced-multichunk out-of-core step for ``oocore.chunk``; a corrupt
   calibration-table load for ``tune.table_load``) finishes with a fit
   allclose to the fault-free run.
2. **Zero silent fallbacks.** Every scheduled fault fired
   (``injector.pending() == ()``), every firing is counted
   (``resilience.injected`` == schedule size), and every recovery is
   visible (retries / degradations / interpret-fallbacks /
   table-fallbacks sum over the faults that needed one).
3. **Resume is exact.** A checkpointed run continued from its sweep-1
   checkpoint produces bit-identical fits to the same run left
   uninterrupted (the checkpoint carries the remapped nonzero stream).

Exit status 0 iff all three hold. ``--seed`` replays a different
schedule; the default is what CI pins.
"""
import os
import sys

# The distributed runs need a 4-device mesh; the device count is locked
# at first jax init, so set it before anything imports jax.
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import argparse
import tempfile


def _workload():
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from ..core import distributed as dist
    from ..core.flycoo import build_flycoo
    from ..core.tensors import random_sparse_tensor

    t = random_sparse_tensor((60, 50, 40), 600, seed=0,
                             distribution="powerlaw")
    ft = build_flycoo(t, 4, m_bounds=(2, 8), g_bounds=(8, 64))
    mesh = Mesh(np.array(jax.devices()[:4]), (dist.AXIS,))
    return ft, mesh


def _run_cpals(ft, mesh, *, resilience=None, checkpoint_dir=None, iters=3):
    import jax

    from ..core.cpals import cp_als_distributed

    jax.clear_caches()   # fresh traces → deterministic site-call indices
    return cp_als_distributed(
        ft, 8, mesh, iters=iters, seed=0, tol=0.0, backend="auto",
        resilience=resilience, checkpoint_dir=checkpoint_dir)


def _run_oocore(interpret=None):
    """Forced-multichunk out-of-core step — the ``oocore.chunk`` site."""
    import numpy as np

    from ..oocore.executor import mttkrp_out_of_core

    rng = np.random.default_rng(0)
    from ..core.tensors import random_sparse_tensor
    t = random_sparse_tensor((20000, 40, 9000, 30), 600, seed=3,
                             distribution="powerlaw")
    mode, tile_rows = 1, 8
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    valid = np.ones(len(val), bool)
    factors = [np.asarray(rng.standard_normal((d, 256)), np.float32)
               for d in t.shape]
    rows_cap = -(-t.shape[mode] // tile_rows) * tile_rows
    out, stats = mttkrp_out_of_core(
        idx, val, valid, factors, mode=mode, rows_cap=rows_cap, blk=32,
        tile_rows=tile_rows, max_chunk_bytes=2000, interpret=interpret)
    return stats.chunks


def _run_table_probes(tmpdir: str, calls: int):
    """``tune.table_load`` site: a valid table read ``calls`` times."""
    from ..tune.table import CalibrationTable, find_table

    path = os.path.join(tmpdir, "table.json")
    CalibrationTable(entries=[], meta={}).save(path)
    return [find_table(tmpdir) is not None for _ in range(calls)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.resilience")
    ap.add_argument("--seed", type=int, default=20240809,
                    help="fault-schedule seed (CI pins the default)")
    args = ap.parse_args(argv)

    import numpy as np

    from ..obs import counters as _obs
    from . import (
        RetryPolicy,
        inject,
        seeded_schedule,
        use_policy,
    )

    failures: list[str] = []

    def check(ok: bool, what: str):
        print(("ok   " if ok else "FAIL ") + what)
        if not ok:
            failures.append(what)

    ft, mesh = _workload()
    horizon = 3

    # -- reference: fault-free, same (stepped) driver ---------------------
    with _obs.use_registry():
        ref = _run_cpals(ft, mesh, resilience=RetryPolicy())
        _run_oocore()
    print(f"fault-free fits: {[round(f, 6) for f in ref.fits]}")

    # -- chaos: every registered site scheduled ---------------------------
    specs = seeded_schedule(args.seed, per_site=1, horizon=horizon)
    print(f"schedule (seed {args.seed}): "
          + ", ".join(f"{s.site}#{s.index}:{s.kind}" for s in specs))
    with _obs.use_registry() as reg, inject(specs) as inj, \
            tempfile.TemporaryDirectory() as td:
        chaos = _run_cpals(ft, mesh, resilience=RetryPolicy())
        with use_policy():   # chunk retries need an active policy scope
            _run_oocore()
        probes = _run_table_probes(td, calls=horizon)

        check(len(chaos.fits) == len(ref.fits), "chaos run completed")
        check(bool(np.allclose(chaos.fits, ref.fits, rtol=1e-4, atol=1e-5)),
              f"chaos fit {chaos.fit:.6f} allclose to fault-free "
              f"{ref.fit:.6f}")
        check(inj.pending() == (),
              f"all {len(specs)} scheduled faults fired "
              f"(pending: {inj.pending()})")
        injected = reg.total("resilience.injected")
        check(injected == len(specs),
              f"injected counter == schedule size ({injected} == "
              f"{len(specs)})")
        handled = (reg.total("resilience.retries")
                   + reg.total("resilience.degradations")
                   + reg.total("resilience.interpret_fallbacks")
                   + reg.total("resilience.table_fallbacks"))
        check(handled >= len(specs),
              f"every fault visibly handled (recoveries {int(handled)} >= "
              f"injected {len(specs)}) — zero silent fallbacks")
        check(probes.count(False) == 1,
              "corrupt table skipped exactly once, valid loads otherwise "
              f"({probes})")
        for k, v in sorted(reg.snapshot().items()):
            if k.startswith("resilience.") and "site_calls" not in k:
                print(f"  {k} = {int(v)}")

    # -- checkpoint/resume exactness --------------------------------------
    with _obs.use_registry() as reg, \
            tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        _run_cpals(ft, mesh, checkpoint_dir=d1, iters=2)
        resumed = _run_cpals(ft, mesh, checkpoint_dir=d1, iters=4)
        full = _run_cpals(ft, mesh, checkpoint_dir=d2, iters=4)
        check(reg.get("resilience.checkpoint.restores") == 1,
              "resumed run restored exactly one checkpoint")
        check(len(resumed.fits) == len(full.fits)
              and bool(np.allclose(resumed.fits, full.fits,
                                   rtol=0, atol=0)),
              f"resume is exact: {[round(f, 6) for f in resumed.fits]} == "
              f"{[round(f, 6) for f in full.fits]}")

    if failures:
        print(f"\nchaos smoke FAILED ({len(failures)}): {failures}")
        return 1
    print("\nchaos smoke passed: faults injected at every site, all "
          "recoveries counted, resume exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
