"""Graceful-degradation retry policy — the residency ladder as a safety net.

``repro.oocore.planner`` built the residency ladder (factors whole in
VMEM → one rank slab → streamed tile window → fused → rank-tiled →
materialized) for *performance*: pick the fastest rung that fits. This
module walks the same ladder as a *fallback* structure: when a rung
fails with a resource-class fault (VMEM OOM, failed lowering, an
injected :class:`~repro.resilience.faults.ResourceFault`), the dispatch
steps one rung **down** — every lower rung computes the same MTTKRP with
a strictly smaller working set — and when the compiled path itself is
the problem it flips compiled → interpret (an explicit override through
``runtime.execution.resolve_interpret``). Transient faults get bounded
retry with exponential backoff. Corruption faults are never retried and
never degraded through — a wrong answer must not be computable from bad
bytes, so they propagate.

Every decision is counted in the ``resilience.*`` namespace of the
closed ``repro.obs`` registry (``retries`` / ``degradations`` /
``interpret_fallbacks``), so a chaos run can assert
injected == handled: **zero silent fallbacks**.

This module deliberately imports nothing from the kernel stack (backend
names are string literals, validated against ``ops.BACKENDS`` by
``tests/test_resilience.py``) so ``ops.py`` can import it without a
cycle; the stack reaches the active policy through
:func:`get_policy` / :func:`use_policy`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
from typing import Callable

from ..obs import counters as _obs
from .faults import (
    CorruptionFault,
    InjectedFault,
    ResourceFault,
    TransientFault,
)

__all__ = [
    "DEGRADATION_LADDER",
    "ResilienceExhausted",
    "RetryPolicy",
    "get_policy",
    "next_rung",
    "use_policy",
]

_LOG = logging.getLogger(__name__)

# The dispatch-level degradation ladder, fastest/tightest rung first —
# the same order ``oocore.planner.plan_residency`` prefers, extended
# down to the segment-sum reference. Every rung computes the same mode
# step from the same inputs (the gather family bit-exactly, the
# fused/materialized/ref rungs up to fp32 accumulation order), so a
# step down trades only performance, never correctness.
DEGRADATION_LADDER = (
    "pallas_fused_gather",
    "pallas_fused_gather_tiled",
    "pallas_fused_gather_stream",
    "pallas_fused",
    "pallas_fused_tiled",
    "pallas",
    "ref",
)


def next_rung(backend: str) -> str | None:
    """The rung below ``backend`` (``None`` at/below the bottom).

    Backends outside the ladder (the bf16 aliases resolve before
    dispatch; ``ref`` is the floor) have nowhere to go.
    """
    try:
        i = DEGRADATION_LADDER.index(backend)
    except ValueError:
        return None
    return DEGRADATION_LADDER[i + 1] if i + 1 < len(DEGRADATION_LADDER) \
        else None


class ResilienceExhausted(RuntimeError):
    """Retries and the degradation ladder are both spent — the fault was
    real and unrecoverable. Chained to the last underlying fault; never
    raised in place of a *silent* wrong answer."""


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry + ladder degradation configuration.

    ``backoff_base_s=0`` (the default) disables sleeping — CI chaos runs
    replay deterministically without wall-clock cost; production sets a
    real base. ``sleep`` is injectable for tests.
    """

    max_retries: int = 3
    backoff_base_s: float = 0.0
    backoff_factor: float = 2.0
    sleep: Callable[[float], None] = time.sleep

    def _backoff(self, attempt: int) -> None:
        if self.backoff_base_s > 0:
            self.sleep(self.backoff_base_s
                       * self.backoff_factor ** (attempt - 1))

    def run(self, site: str, thunk: Callable):
        """Host-level bounded retry of ``thunk`` on transient faults.

        The driver-side wrapper: a per-mode jitted call (MTTKRP, remap)
        or a chunk launch that raises :class:`TransientFault` is retried
        up to ``max_retries`` times with backoff, each retry counted
        under ``resilience.retries{site=...}``. Resource and corruption
        faults propagate — they are handled at the dispatch layer (or
        not at all).
        """
        attempt = 0
        while True:
            try:
                return thunk()
            except TransientFault as e:
                attempt += 1
                _obs.add("resilience.retries", site=site)
                _LOG.warning("transient fault at %s (attempt %d/%d): %s",
                             site, attempt, self.max_retries, e)
                if attempt > self.max_retries:
                    raise ResilienceExhausted(
                        f"site {site!r}: {attempt} transient faults in a "
                        f"row exceeded max_retries={self.max_retries}"
                    ) from e
                self._backoff(attempt)

    def dispatch(self, call: Callable[[str, bool | None], object],
                 backend: str, interpret: bool | None):
        """Degradation-aware kernel dispatch: retry, flip, step down.

        ``call(backend, interpret)`` runs one concrete mode step (host
        Python — under jit this is trace time, where lowering/OOM
        failures actually surface). The walk:

        * :class:`TransientFault` — bounded retry at the same rung;
        * ``ExecutionModeError`` (from ``runtime.execution``) or a
          :class:`ResourceFault` while the compiled path is in play —
          first flip to an explicit ``interpret=True`` override at the
          same rung (counted ``resilience.interpret_fallbacks``);
        * :class:`ResourceFault` under interpret — step one rung down
          the ladder (counted ``resilience.degradations{from,to}``);
        * :class:`CorruptionFault` — propagate immediately;
        * ladder/retries exhausted — :class:`ResilienceExhausted`
          chained to the last fault. Never a silent wrong answer.
        """
        from ..runtime.execution import ExecutionModeError, resolve_interpret

        current = backend
        cur_interpret = interpret
        retries = 0
        while True:
            try:
                return call(current, cur_interpret)
            except CorruptionFault:
                raise
            except TransientFault as e:
                retries += 1
                _obs.add("resilience.retries", site="ops.kernel")
                if retries > self.max_retries:
                    raise ResilienceExhausted(
                        f"backend {current!r}: {retries} transient faults "
                        f"exceeded max_retries={self.max_retries}") from e
                self._backoff(retries)
            except (ResourceFault, ExecutionModeError) as e:
                # Effective flag the failing attempt ran with: an
                # explicit override wins; otherwise ask the policy (an
                # ExecutionModeError from resolution means "compiled
                # requested, impossible" — also not yet interpreting).
                if cur_interpret is not None:
                    was_interpret = cur_interpret
                elif isinstance(e, ExecutionModeError):
                    was_interpret = False
                else:
                    try:
                        was_interpret = resolve_interpret()
                    except (ExecutionModeError, InjectedFault):
                        # The probe itself goes through the
                        # execution.resolve fault site; an injected
                        # fault here means "resolution is broken" —
                        # same answer as ExecutionModeError.
                        was_interpret = False
                if not was_interpret:
                    cur_interpret = True
                    _obs.add("resilience.interpret_fallbacks",
                             backend=current)
                    _LOG.warning("compiled path failed at %s (%s); "
                                 "falling back to interpret", current, e)
                    continue
                if isinstance(e, ExecutionModeError):
                    raise           # interpret already forced; unrecoverable
                nxt = next_rung(current)
                if nxt is None:
                    raise ResilienceExhausted(
                        f"resource fault at the bottom of the degradation "
                        f"ladder (backend {current!r})") from e
                _obs.add("resilience.degradations", **{"from": current,
                                                       "to": nxt})
                _LOG.warning("resource fault at %s (%s); degrading to %s",
                             current, e, nxt)
                current = nxt


# ---------------------------------------------------------------------------
# The process-wide active policy — how the dispatch layer finds it
# ---------------------------------------------------------------------------

_policy: RetryPolicy | None = None


def get_policy() -> RetryPolicy | None:
    """The active policy, or ``None`` (the default: fail fast, exactly
    the pre-resilience behavior)."""
    return _policy


@contextlib.contextmanager
def use_policy(policy: RetryPolicy | None = None):
    """Activate a resilience policy for the block; restores on exit.

    ``None`` activates a default :class:`RetryPolicy`. While active,
    ``ops.mttkrp_device_step`` routes through :meth:`RetryPolicy.dispatch`
    and the oocore executor retries chunk launches — drivers
    (``cp_als_distributed(resilience=...)``) enter this scope for the
    whole decomposition.
    """
    global _policy
    scoped = RetryPolicy() if policy is None else policy
    previous = _policy
    _policy = scoped
    try:
        yield scoped
    finally:
        _policy = previous
