"""repro.oocore — out-of-core spMTTKRP: residency planning + chunked runs.

The gather family (PR 4) made factor residency the dispatch's central
question: its VMEM working set scales with the factor sizes, not the
nonzero count, and once ``Σ I_pad·slab·gi`` outgrew the budget the
dispatch fell all the way back to the HBM-materializing paths. This
package is the next level of the hierarchy — the same FLYCOO insight
("keep the big operand in slow memory, stream row tiles on a sorted
index stream") applied to the factor matrices themselves:

  * :mod:`repro.oocore.planner` — the **unified residency planner**:
    one :class:`~repro.oocore.planner.ResidencyPlan` decides, per mode
    and under an explicit byte budget, which factors stay whole-VMEM,
    which are rank-slabbed, and which are row-streamed through the
    ``fused_mttkrp_nmode_gather_stream`` kernel's bounded tile window.
    ``kernels.mttkrp.ops.select_backend`` and ``tune.model.plan_modes``
    consume it instead of their former ad-hoc VMEM checks.
  * :mod:`repro.oocore.executor` — **chunked execution**: splits a
    FLYCOO nonzero stream whose working set exceeds a byte budget into
    row-tile-aligned chunks, runs each through the same kernels with
    the running accumulator threaded as ``out_init`` (single-pass
    accumulation order, bit-exact), and counts the DMA traffic.

``python -m repro.oocore`` runs a forced-multi-chunk smoke check (CI).

The executor is imported lazily: it pulls in ``kernels.mttkrp.ops``,
which itself imports :mod:`repro.oocore.planner` — eager import here
would be circular.
"""
from . import planner  # noqa: F401
from .planner import (FactorResidency, ResidencyPlan, backend_fits,
                      plan_residency)

__all__ = [
    "planner",
    "executor",
    "FactorResidency",
    "ResidencyPlan",
    "backend_fits",
    "plan_residency",
]


def __getattr__(name):
    if name == "executor":
        # importlib, not `from . import …`: the fromlist machinery would
        # re-enter this __getattr__ before the submodule import finishes.
        import importlib
        return importlib.import_module(".executor", __name__)
    raise AttributeError(name)
