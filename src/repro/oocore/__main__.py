"""``python -m repro.oocore`` — forced-multi-chunk out-of-core smoke.

The CI step that keeps the oocore subsystem honest end-to-end: build a
small skewed tensor, run one mode step through the chunked streaming
executor under a byte budget tiny enough to force several chunks, and
assert the result is **bit-exact** against the factor-resident gather
backend. Exit status 0 iff every check passes.
"""
from __future__ import annotations

import sys

import numpy as np


def main(argv=None) -> int:
    import jax.numpy as jnp

    from .executor import mttkrp_out_of_core
    from . import planner
    from ..core.tensors import random_sparse_tensor
    from ..kernels.mttkrp import kernel as _kernel
    from ..kernels.mttkrp import ops as kops

    blk, tile_rows, rank, mode = 32, 8, 256, 1
    # Input factors with thousands of row tiles: slab residency would
    # need ~15 MiB while the bounded stream window stays ~4 MiB — the
    # regime the out-of-core backend exists for.
    shape = (20000, 40, 9000, 30)
    rng = np.random.default_rng(0)
    t = random_sparse_tensor(shape, 600, seed=3, distribution="powerlaw")
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    valid = np.ones(len(val), bool)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    rows_cap = -(-shape[mode] // tile_rows) * tile_rows

    resident = kops.mttkrp_device_step(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
        mode=mode, rows_cap=rows_cap, row_offset=0, blk=blk,
        tile_rows=tile_rows, backend="pallas_fused_gather")
    out, stats = mttkrp_out_of_core(
        idx, val, valid, factors, mode=mode, rows_cap=rows_cap, blk=blk,
        tile_rows=tile_rows, max_chunk_bytes=2000)

    failures = []
    if stats.chunks < 3:
        failures.append(f"budget did not force multi-chunk: {stats.chunks}")
    if not np.array_equal(np.asarray(out), np.asarray(resident)):
        failures.append("streamed chunked result != resident gather result")
    # At a budget exactly the static stream window, the planner must
    # certify the streaming rung (whole/slab residency both overflow).
    in_rows = tuple(shape[w] for w in range(len(shape)) if w != mode)
    windows_static = tuple(planner.stream_window_tiles(blk, r)
                           for r in in_rows)
    budget = _kernel.gather_stream_vmem_bytes(
        len(in_rows), kops.padded_rank(rank), blk, tile_rows,
        windows_static)
    plan = planner.plan_residency(
        nmodes=len(shape), rank=rank, blk=blk, tile_rows=tile_rows,
        factor_rows=in_rows, vmem_budget=budget)
    if plan.backend != planner.STREAM_BACKEND:
        failures.append(
            f"planner at window-sized budget chose {plan.backend}")
    for f in failures:
        print(f"FAIL {f}")
    if failures:
        return 1
    print(
        f"oocore smoke passed: {stats.chunks} chunks "
        f"(blocks per chunk {stats.chunk_block_counts}), windows "
        f"{stats.window_tiles}, streamed ≡ resident bit-exact; counted "
        f"DMA {stats.pipelined_tile_bytes} B tiles + "
        f"{stats.index_stream_bytes} B index streams for {stats.nnz} nnz")
    return 0


if __name__ == "__main__":
    sys.exit(main())
