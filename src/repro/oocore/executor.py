"""Chunked out-of-core spMTTKRP execution over the streaming kernel.

The kernels bound their *VMEM* working set, but a mode step still
materializes its block-aligned operand streams whole: ``O(n_pad)``
values/rows/indices (plus, for the materializing fused family,
``O(n_pad·R̂)`` of gathered rows). For nonzero streams that outgrow a
host/HBM working-set budget this module is the next level of the same
out-of-core idea: split the FLYCOO stream into **row-tile-aligned
chunks** of whole nonzero blocks, run every chunk through the same
kernel, and thread the running accumulator through each call's
``out_init`` so the result reproduces the single-pass accumulation
order **bit-exactly** — chunking is a pure re-bracketing of the very
same additions, never a re-ordering.

Chunk boundaries prefer output-row-tile edges (a tile's run of blocks
stays within one chunk, so most tiles are touched by exactly one chunk);
when a single tile's run alone exceeds the budget the split lands
mid-tile, which the ``out_init`` threading makes exact anyway.

:func:`mttkrp_out_of_core` is the entry point; it uses
:func:`repro.oocore.planner.plan_residency` for the window geometry and
returns counted DMA-traffic statistics (`StreamStats`) next to the
result — the numbers ``benchmarks/bench_oocore.py`` records.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time

import jax.numpy as jnp
import numpy as np

from ..kernels.mttkrp import kernel as _kernel
from ..kernels.mttkrp import ops as _ops
from ..obs import counters as _obs
from ..obs import tracer as _tracer_mod
from ..reorder import ordering as _reorder
from ..resilience import faults as _faults
from ..resilience import policy as _resilience
from . import planner as _planner

__all__ = [
    "StreamStats",
    "chunk_boundaries",
    "mttkrp_out_of_core",
]


@dataclasses.dataclass(frozen=True)
class StreamStats:
    """Counted traffic of one chunked out-of-core mode step.

    All byte counts are *counted* from the actual tile schedules (not
    timed): what the kernel's DMA engine is asked to move. The three
    tile-fetch counts bound each other —
    ``pipelined <= scheduled`` and ``distinct <= scheduled`` —
    ``scheduled`` being the naive every-slot-every-block cost,
    ``distinct`` what the schedule actually references (padding slots
    repeat a block's first tile), and ``pipelined`` what survives the
    Pallas revolving-buffer rule (a slot whose tile index is unchanged
    from the previous grid step is not re-fetched).
    """

    backend: str
    chunks: int
    num_blocks: int
    nnz: int                        # valid nonzeros
    blk: int
    rank_padded: int
    rank_slabs: int
    window_tiles: tuple[int, ...]   # per input mode
    chunk_block_counts: tuple[int, ...]
    scheduled_tile_bytes: int
    distinct_tile_bytes: int
    pipelined_tile_bytes: int
    index_stream_bytes: int         # vals + rows + K index streams, per slab
    window_vmem_bytes: int          # resident window per grid step
    resident_equiv_vmem_bytes: int  # what whole-factor residency would need
    # repro.reorder: the locality policy the stream was permuted with
    # ("none" = as given), and the counted cost the *unsorted* stream
    # would have paid — predicted by planner.predict_stream_traffic
    # before the permutation, so before/after is one mode step's worth
    # of data, not two runs. 0 when ordering is "none".
    ordering: str = "none"
    presort_scheduled_tile_bytes: int = 0
    presort_distinct_tile_bytes: int = 0

    @property
    def tile_bytes_per_nnz(self) -> float:
        return self.pipelined_tile_bytes / max(self.nnz, 1)

    @property
    def index_bytes_per_nnz(self) -> float:
        return self.index_stream_bytes / max(self.nnz, 1)

    @property
    def scheduled_over_distinct(self) -> float:
        """The tile re-fetch factor (≥ 1.0) the reorder pass attacks."""
        return self.scheduled_tile_bytes / max(self.distinct_tile_bytes, 1)

    @property
    def presort_scheduled_over_distinct(self) -> float:
        """Same ratio for the stream as it arrived (before reordering)."""
        return (self.presort_scheduled_tile_bytes
                / max(self.presort_distinct_tile_bytes, 1))


# Chunk planning lives in the planner (so predict_stream_traffic can
# replicate it without a circular import); re-exported here because this
# module is where chunks are *executed*.
chunk_boundaries = _planner.chunk_boundaries


def _schedule_fetch_stats(scheds, chunks, chunk_windows, frow_tile: int,
                          slab_cols: int, num_slabs: int, gi: int,
                          distinct_counts) -> tuple[int, int, int]:
    """Counted (scheduled, distinct, pipelined) tile-fetch bytes.

    Counts exactly what the chunk loop issues: each chunk's schedule is
    sliced to that chunk's tightened window widths, so ``scheduled`` is
    Σ_chunks blocks · Σ_modes w_chunk — the same arithmetic
    ``planner.predict_stream_traffic`` performs, which is why predicted
    and counted bytes agree exactly.
    """
    tile_bytes = frow_tile * slab_cols * gi
    scheduled = sum((stop - start) * sum(cw)
                    for (start, stop), cw in zip(chunks, chunk_windows))
    distinct = sum(int(d.sum()) for d in distinct_counts)
    pipelined = 0
    for i, s in enumerate(scheds):
        s = np.asarray(s)
        for (start, stop), cw in zip(chunks, chunk_windows):
            c = s[start:stop, :cw[i]]
            if len(c) == 0:
                continue
            pipelined += c.shape[1]                       # first block: all
            if len(c) > 1:
                pipelined += int((c[1:] != c[:-1]).sum())  # slot changed
    return (scheduled * tile_bytes * num_slabs,
            distinct * tile_bytes * num_slabs,
            pipelined * tile_bytes * num_slabs)


def mttkrp_out_of_core(
    idx, val, valid, factors, *, mode: int, rows_cap: int, row_offset=0,
    blk: int = 128, tile_rows: int = 128,
    vmem_budget: int = _planner.VMEM_BUDGET_BYTES,
    max_chunk_bytes: int | None = None,
    gather_dtype: str = "float32",
    interpret: bool | None = None,
    ordering: str = "none",
):
    """One mode step, out-of-core: streamed factor tiles + chunked blocks.

    Same data contract as ``ops.mttkrp_device_step`` (sorted-by-output-row
    stream, trailing invalids, replicated factor matrices), executed
    through ``fused_mttkrp_nmode_gather_stream`` in chunks:

      * the factor matrices stay HBM-resident; per input mode the kernel
        holds a bounded window of ``FACTOR_ROW_TILE``-row tiles in VMEM
        (widths from :func:`planner.plan_residency`, tightened to the
        measured per-block distinct-tile maximum — the executor sees the
        data, so unlike the jit dispatch it doesn't need the worst-case
        bound);
      * the block stream is split by :func:`chunk_boundaries` so no
        chunk's aligned operand arrays (values + rows + index streams +
        schedules) exceed ``max_chunk_bytes`` (``None`` = one chunk);
      * each chunk's kernel call receives the previous accumulator as
        ``out_init`` — the summation order is identical to the unchunked
        kernel, so the result is **bit-exact** against the resident
        gather backend for any chunk split.

    ``ordering`` (a ``repro.reorder`` policy) permutes the stream
    host-side for factor-tile locality before alignment: the counted
    cost of the stream *as it arrived* is predicted first
    (``planner.predict_stream_traffic`` — the same arithmetic as the
    count below, so it is exact) and recorded in the stats'
    ``presort_*`` fields; the run then pays the post-sort cost. The
    result stays bit-exact **per stream** (streamed ≡ resident on the
    same permuted stream); against the unsorted stream it differs only
    by fp32 accumulation order.

    Returns ``(out, stats)`` — ``out`` is ``(rows_cap, R)`` float32,
    ``stats`` a :class:`StreamStats` of counted DMA traffic.
    """
    if gather_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"unknown gather_dtype {gather_dtype!r}")
    _reorder.validate_ordering(ordering)
    gdt = jnp.bfloat16 if gather_dtype == "bfloat16" else jnp.float32
    gi = 2 if gather_dtype == "bfloat16" else 4
    frow = _kernel.FACTOR_ROW_TILE
    nmodes = np.asarray(idx).shape[1]
    in_modes = [w for w in range(nmodes) if w != mode]
    k = len(in_modes)
    rank = factors[mode].shape[-1]
    rpad = _ops.padded_rank(rank)
    num_slabs = rpad // _kernel.RANK_SLAB

    presort_scheduled_b = presort_distinct_b = 0
    if ordering != "none":
        traffic_kw = dict(
            mode=mode, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
            rank=rank,
            factor_rows=tuple(int(factors[w].shape[0]) for w in in_modes),
            row_offset=int(row_offset), gather_itemsize=gi,
            max_chunk_bytes=max_chunk_bytes)
        pre = _planner.predict_stream_traffic(
            idx, valid, ordering="none", **traffic_kw)
        presort_scheduled_b = pre.scheduled_tile_bytes
        presort_distinct_b = pre.distinct_tile_bytes
        idx, val, valid, _ = _reorder.reorder_stream(
            idx, val, valid, mode=mode, ordering=ordering,
            tile_rows=tile_rows, row_offset=int(row_offset),
            max_rows=max(int(factors[w].shape[0]) for w in in_modes))
    idx = jnp.asarray(idx)
    val = jnp.asarray(val)
    valid = jnp.asarray(valid)

    # Block-aligned streams, exactly like the in-jit gather paths.
    local_row = (idx[:, mode] - row_offset).astype(jnp.int32)
    local_row = jnp.where(valid, local_row, 0)
    n_pad = _ops.n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
    slot, tile_of_block = _ops.build_block_layout(
        local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows)
    v_al = _ops._align_to_blocks(jnp.where(valid, val, 0.0), slot, n_pad)
    r_al = _ops._align_to_blocks(
        (local_row % tile_rows).astype(jnp.int32), slot, n_pad)
    idx_in = jnp.stack([idx[:, w] for w in in_modes], axis=1)
    idx_in = jnp.where(valid[:, None], idx_in, 0).astype(jnp.int32)
    idx_al = _ops._align_to_blocks(idx_in, slot, n_pad)
    fmats = tuple(
        _ops._pad_factor_rows(_ops.pad_rank(jnp.asarray(factors[w]).astype(gdt)),
                              frow)
        for w in in_modes)

    # Window widths: the planner's static bound, tightened by the data.
    # One sorted-distinct analysis serves the window sizing, the tile
    # schedules and the fetch statistics (ops.tile_schedule re-derives
    # the same thing jit-side for the in-jit dispatch path; out here the
    # data is already on host, so doing it once in numpy is the cheap
    # route for streams long enough to need chunking).
    tiles_np = np.asarray(idx_al) // frow                 # (n_pad, K)
    per_block = tiles_np.reshape(-1, blk, k)
    st, first, rank_of, dcounts = _planner.block_tile_analysis(per_block)
    distinct_counts = [dcounts[:, i] for i in range(k)]
    windows = tuple(
        int(min(_planner.stream_window_tiles(blk, int(fmats[i].shape[0])),
                max(1, int(distinct_counts[i].max()))))
        for i in range(k))
    num_blocks_total = st.shape[0]
    scheds = []
    for i in range(k):
        width = windows[i]
        # Same construction as ops.tile_schedule: first occurrences
        # scatter to their distinct rank, duplicates to a dump column,
        # unfilled slots keep the block's first (smallest) tile.
        dest = np.where(first[:, :, i], rank_of[:, :, i], width)
        sched = np.broadcast_to(
            st[:, :1, i], (num_blocks_total, width + 1)).copy()
        sched[np.arange(num_blocks_total)[:, None], dest] = st[:, :, i]
        scheds.append(jnp.asarray(sched[:, :width].astype(np.int32)))
    scheds = tuple(scheds)

    # Chunking: bound each chunk's aligned-operand bytes, then tighten
    # every chunk's schedule width to its own blocks' distinct-tile
    # maximum. Each chunk is a separate kernel call with its own static
    # width, so the slice is free — and it is where a repro.reorder
    # locality sort cashes in: post-sort, almost every chunk's window
    # collapses to 1–2 while only the rare-tile tail pays the wide one.
    # (Slicing columns [w_c, width) off a schedule is safe: distinct
    # ranks occupy columns [0, d) with d <= w_c; everything past that is
    # padding repeating the block's first tile.)
    num_blocks = n_pad // blk
    if max_chunk_bytes is None:
        max_blocks = num_blocks
    else:
        max_blocks = max(
            1, max_chunk_bytes // _planner.stream_chunk_bytes(blk, k, windows))
    chunks = chunk_boundaries(tile_of_block, max_blocks)
    cwindows = _planner.chunk_window_tiles(dcounts, chunks, windows)

    # Counted traffic is fully determined by the schedules — build the
    # stats *before* launching so they can be recorded inside the
    # mode_step span below: the byte deltas then land in that span's
    # self_counters, which is the join the achieved-bandwidth roofline
    # (repro.obs.prof.roofline) reads. Registry totals are unchanged.
    slab_cols = min(rpad, _kernel.RANK_SLAB)
    scheduled_b, distinct_b, pipelined_b = _schedule_fetch_stats(
        scheds, chunks, cwindows, frow, slab_cols, num_slabs, gi,
        distinct_counts)
    stats = StreamStats(
        backend=_planner.STREAM_BACKEND,
        chunks=len(chunks),
        num_blocks=num_blocks,
        nnz=int(np.asarray(valid).sum()),
        blk=blk,
        rank_padded=rpad,
        rank_slabs=num_slabs,
        window_tiles=windows,
        chunk_block_counts=tuple(stop - start for start, stop in chunks),
        scheduled_tile_bytes=scheduled_b,
        distinct_tile_bytes=distinct_b,
        pipelined_tile_bytes=pipelined_b,
        index_stream_bytes=num_slabs * n_pad * (4 + 4 + 4 * k),
        window_vmem_bytes=_kernel.gather_stream_vmem_bytes(
            k, rpad, blk, tile_rows, windows, gather_itemsize=gi),
        resident_equiv_vmem_bytes=_kernel.gather_vmem_bytes(
            k, rpad, blk, tile_rows,
            sum(int(f.shape[0]) for f in fmats), gather_itemsize=gi),
        ordering=ordering,
        presort_scheduled_tile_bytes=presort_scheduled_b,
        presort_distinct_tile_bytes=presort_distinct_b,
    )

    tracer = _tracer_mod.get_tracer()
    out = jnp.zeros((rows_cap, rpad), jnp.float32)
    t_step = _time.perf_counter()
    with tracer.span("oocore.mode_step", mode=mode, chunks=len(chunks),
                     backend=_planner.STREAM_BACKEND, rung="stream",
                     ordering=ordering):
        # Emitted inside the span so the oocore.dma.* / reorder.dma.*
        # deltas attach to it (the tracer diffs the registry per span).
        _obs.record_stream_stats(stats)
        for ci, (start, stop) in enumerate(chunks):
            sl = slice(start * blk, stop * blk)
            cw = cwindows[ci]
            with tracer.span("oocore.chunk", chunk=ci,
                             blocks=stop - start):
                def _launch(out=out, sl=sl, start=start, stop=stop, cw=cw):
                    # Registered failure boundary (repro.resilience):
                    # one chunk = one bounded DMA window + kernel
                    # launch — the unit a transient blip costs, and
                    # the unit the retry policy replays.
                    _faults.fault_site("oocore.chunk")
                    return _kernel.fused_mttkrp_nmode_gather_stream(
                        v_al[sl], idx_al[sl], fmats, r_al[sl],
                        tile_of_block[start:stop],
                        tuple(s[start:stop, :cw[i]]
                              for i, s in enumerate(scheds)),
                        rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
                        interpret=interpret, out_init=out)

                pol = _resilience.get_policy()
                out = (_launch() if pol is None
                       else pol.run("oocore.chunk", _launch))
                if tracer.enabled:
                    out = out.block_until_ready()
    _obs.add("oocore.mode_step_s", _time.perf_counter() - t_step,
             backend=_planner.STREAM_BACKEND, ordering=ordering)
    return out[:, :rank], stats
