"""Chunked out-of-core spMTTKRP execution over the streaming kernel.

The kernels bound their *VMEM* working set, but a mode step still
materializes its block-aligned operand streams whole: ``O(n_pad)``
values/rows/indices (plus, for the materializing fused family,
``O(n_pad·R̂)`` of gathered rows). For nonzero streams that outgrow a
host/HBM working-set budget this module is the next level of the same
out-of-core idea: split the FLYCOO stream into **row-tile-aligned
chunks** of whole nonzero blocks, run every chunk through the same
kernel, and thread the running accumulator through each call's
``out_init`` so the result reproduces the single-pass accumulation
order **bit-exactly** — chunking is a pure re-bracketing of the very
same additions, never a re-ordering.

Chunk boundaries prefer output-row-tile edges (a tile's run of blocks
stays within one chunk, so most tiles are touched by exactly one chunk);
when a single tile's run alone exceeds the budget the split lands
mid-tile, which the ``out_init`` threading makes exact anyway.

:func:`mttkrp_out_of_core` is the entry point; it uses
:func:`repro.oocore.planner.plan_residency` for the window geometry and
returns counted DMA-traffic statistics (`StreamStats`) next to the
result — the numbers ``benchmarks/bench_oocore.py`` records.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from ..kernels.mttkrp import kernel as _kernel
from ..kernels.mttkrp import ops as _ops
from ..obs import counters as _obs
from ..obs import tracer as _tracer_mod
from . import planner as _planner

__all__ = [
    "StreamStats",
    "chunk_boundaries",
    "mttkrp_out_of_core",
]


@dataclasses.dataclass(frozen=True)
class StreamStats:
    """Counted traffic of one chunked out-of-core mode step.

    All byte counts are *counted* from the actual tile schedules (not
    timed): what the kernel's DMA engine is asked to move. The three
    tile-fetch counts bound each other —
    ``pipelined <= scheduled`` and ``distinct <= scheduled`` —
    ``scheduled`` being the naive every-slot-every-block cost,
    ``distinct`` what the schedule actually references (padding slots
    repeat a block's first tile), and ``pipelined`` what survives the
    Pallas revolving-buffer rule (a slot whose tile index is unchanged
    from the previous grid step is not re-fetched).
    """

    backend: str
    chunks: int
    num_blocks: int
    nnz: int                        # valid nonzeros
    blk: int
    rank_padded: int
    rank_slabs: int
    window_tiles: tuple[int, ...]   # per input mode
    chunk_block_counts: tuple[int, ...]
    scheduled_tile_bytes: int
    distinct_tile_bytes: int
    pipelined_tile_bytes: int
    index_stream_bytes: int         # vals + rows + K index streams, per slab
    window_vmem_bytes: int          # resident window per grid step
    resident_equiv_vmem_bytes: int  # what whole-factor residency would need

    @property
    def tile_bytes_per_nnz(self) -> float:
        return self.pipelined_tile_bytes / max(self.nnz, 1)

    @property
    def index_bytes_per_nnz(self) -> float:
        return self.index_stream_bytes / max(self.nnz, 1)


def chunk_boundaries(tile_of_block, max_blocks: int) -> list[tuple[int, int]]:
    """Split ``num_blocks`` blocks into chunks of at most ``max_blocks``.

    Boundaries prefer output-row-tile edges: a chunk ends at the last
    position ``<= max_blocks`` where ``tile_of_block`` changes, so a
    tile's contiguous run of blocks stays in one chunk whenever it fits.
    A run longer than ``max_blocks`` is split mid-tile (the executor's
    ``out_init`` threading keeps that exact). Returns ``[start, stop)``
    block ranges covering every block exactly once.
    """
    tiles = np.asarray(tile_of_block)
    num_blocks = len(tiles)
    assert max_blocks >= 1, max_blocks
    bounds = []
    start = 0
    while start < num_blocks:
        stop = min(start + max_blocks, num_blocks)
        if stop < num_blocks:
            aligned = stop
            while aligned > start + 1 and tiles[aligned] == tiles[aligned - 1]:
                aligned -= 1
            if aligned > start and tiles[aligned] != tiles[aligned - 1]:
                stop = aligned
        bounds.append((start, stop))
        start = stop
    return bounds


def _schedule_fetch_stats(scheds, chunks, frow_tile: int, slab_cols: int,
                          num_slabs: int, gi: int,
                          distinct_counts) -> tuple[int, int, int]:
    """Counted (scheduled, distinct, pipelined) tile-fetch bytes."""
    tile_bytes = frow_tile * slab_cols * gi
    scheduled = sum(int(s.shape[0]) * int(s.shape[1]) for s in scheds)
    distinct = sum(int(d.sum()) for d in distinct_counts)
    pipelined = 0
    for s in scheds:
        s = np.asarray(s)
        for start, stop in chunks:
            c = s[start:stop]
            if len(c) == 0:
                continue
            pipelined += c.shape[1]                       # first block: all
            if len(c) > 1:
                pipelined += int((c[1:] != c[:-1]).sum())  # slot changed
    return (scheduled * tile_bytes * num_slabs,
            distinct * tile_bytes * num_slabs,
            pipelined * tile_bytes * num_slabs)


def mttkrp_out_of_core(
    idx, val, valid, factors, *, mode: int, rows_cap: int, row_offset=0,
    blk: int = 128, tile_rows: int = 128,
    vmem_budget: int = _planner.VMEM_BUDGET_BYTES,
    max_chunk_bytes: int | None = None,
    gather_dtype: str = "float32",
    interpret: bool | None = None,
):
    """One mode step, out-of-core: streamed factor tiles + chunked blocks.

    Same data contract as ``ops.mttkrp_device_step`` (sorted-by-output-row
    stream, trailing invalids, replicated factor matrices), executed
    through ``fused_mttkrp_nmode_gather_stream`` in chunks:

      * the factor matrices stay HBM-resident; per input mode the kernel
        holds a bounded window of ``FACTOR_ROW_TILE``-row tiles in VMEM
        (widths from :func:`planner.plan_residency`, tightened to the
        measured per-block distinct-tile maximum — the executor sees the
        data, so unlike the jit dispatch it doesn't need the worst-case
        bound);
      * the block stream is split by :func:`chunk_boundaries` so no
        chunk's aligned operand arrays (values + rows + index streams +
        schedules) exceed ``max_chunk_bytes`` (``None`` = one chunk);
      * each chunk's kernel call receives the previous accumulator as
        ``out_init`` — the summation order is identical to the unchunked
        kernel, so the result is **bit-exact** against the resident
        gather backend for any chunk split.

    Returns ``(out, stats)`` — ``out`` is ``(rows_cap, R)`` float32,
    ``stats`` a :class:`StreamStats` of counted DMA traffic.
    """
    if gather_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"unknown gather_dtype {gather_dtype!r}")
    gdt = jnp.bfloat16 if gather_dtype == "bfloat16" else jnp.float32
    gi = 2 if gather_dtype == "bfloat16" else 4
    frow = _kernel.FACTOR_ROW_TILE
    idx = jnp.asarray(idx)
    val = jnp.asarray(val)
    valid = jnp.asarray(valid)
    nmodes = idx.shape[1]
    in_modes = [w for w in range(nmodes) if w != mode]
    k = len(in_modes)
    rank = factors[mode].shape[-1]
    rpad = _ops.padded_rank(rank)
    num_slabs = rpad // _kernel.RANK_SLAB

    # Block-aligned streams, exactly like the in-jit gather paths.
    local_row = (idx[:, mode] - row_offset).astype(jnp.int32)
    local_row = jnp.where(valid, local_row, 0)
    n_pad = _ops.n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
    slot, tile_of_block = _ops.build_block_layout(
        local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows)
    v_al = _ops._align_to_blocks(jnp.where(valid, val, 0.0), slot, n_pad)
    r_al = _ops._align_to_blocks(
        (local_row % tile_rows).astype(jnp.int32), slot, n_pad)
    idx_in = jnp.stack([idx[:, w] for w in in_modes], axis=1)
    idx_in = jnp.where(valid[:, None], idx_in, 0).astype(jnp.int32)
    idx_al = _ops._align_to_blocks(idx_in, slot, n_pad)
    fmats = tuple(
        _ops._pad_factor_rows(_ops.pad_rank(jnp.asarray(factors[w]).astype(gdt)),
                              frow)
        for w in in_modes)

    # Window widths: the planner's static bound, tightened by the data.
    # One sorted-distinct analysis serves the window sizing, the tile
    # schedules and the fetch statistics (ops.tile_schedule re-derives
    # the same thing jit-side for the in-jit dispatch path; out here the
    # data is already on host, so doing it once in numpy is the cheap
    # route for streams long enough to need chunking).
    tiles_np = np.asarray(idx_al) // frow                 # (n_pad, K)
    per_block = tiles_np.reshape(-1, blk, k)
    st = np.sort(per_block, axis=1)
    first = np.concatenate(
        [np.ones((st.shape[0], 1, k), bool), st[:, 1:] != st[:, :-1]], axis=1)
    rank_of = np.cumsum(first, axis=1) - 1                # distinct rank
    distinct_counts = [first[:, :, i].sum(axis=1) for i in range(k)]
    windows = tuple(
        int(min(_planner.stream_window_tiles(blk, int(fmats[i].shape[0])),
                max(1, int(distinct_counts[i].max()))))
        for i in range(k))
    num_blocks_total = st.shape[0]
    scheds = []
    for i in range(k):
        width = windows[i]
        # Same construction as ops.tile_schedule: first occurrences
        # scatter to their distinct rank, duplicates to a dump column,
        # unfilled slots keep the block's first (smallest) tile.
        dest = np.where(first[:, :, i], rank_of[:, :, i], width)
        sched = np.broadcast_to(
            st[:, :1, i], (num_blocks_total, width + 1)).copy()
        sched[np.arange(num_blocks_total)[:, None], dest] = st[:, :, i]
        scheds.append(jnp.asarray(sched[:, :width].astype(np.int32)))
    scheds = tuple(scheds)

    # Chunking: bound each chunk's aligned-operand bytes.
    num_blocks = n_pad // blk
    per_block_bytes = blk * (4 + 4 + 4 * k) + 4 * sum(windows)
    if max_chunk_bytes is None:
        max_blocks = num_blocks
    else:
        max_blocks = max(1, max_chunk_bytes // per_block_bytes)
    chunks = chunk_boundaries(tile_of_block, max_blocks)

    tracer = _tracer_mod.get_tracer()
    out = jnp.zeros((rows_cap, rpad), jnp.float32)
    with tracer.span("oocore.mode_step", mode=mode, chunks=len(chunks)):
        for ci, (start, stop) in enumerate(chunks):
            sl = slice(start * blk, stop * blk)
            with tracer.span("oocore.chunk", chunk=ci,
                             blocks=stop - start):
                out = _kernel.fused_mttkrp_nmode_gather_stream(
                    v_al[sl], idx_al[sl], fmats, r_al[sl],
                    tile_of_block[start:stop],
                    tuple(s[start:stop] for s in scheds),
                    rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
                    interpret=interpret, out_init=out)
                if tracer.enabled:
                    out = out.block_until_ready()

    slab_cols = min(rpad, _kernel.RANK_SLAB)
    scheduled_b, distinct_b, pipelined_b = _schedule_fetch_stats(
        scheds, chunks, frow, slab_cols, num_slabs, gi, distinct_counts)
    stats = StreamStats(
        backend=_planner.STREAM_BACKEND,
        chunks=len(chunks),
        num_blocks=num_blocks,
        nnz=int(np.asarray(valid).sum()),
        blk=blk,
        rank_padded=rpad,
        rank_slabs=num_slabs,
        window_tiles=windows,
        chunk_block_counts=tuple(stop - start for start, stop in chunks),
        scheduled_tile_bytes=scheduled_b,
        distinct_tile_bytes=distinct_b,
        pipelined_tile_bytes=pipelined_b,
        index_stream_bytes=num_slabs * n_pad * (4 + 4 + 4 * k),
        window_vmem_bytes=_kernel.gather_stream_vmem_bytes(
            k, rpad, blk, tile_rows, windows, gather_itemsize=gi),
        resident_equiv_vmem_bytes=_kernel.gather_vmem_bytes(
            k, rpad, blk, tile_rows,
            sum(int(f.shape[0]) for f in fmats), gather_itemsize=gi),
    )
    # The counted struct also lands in the shared obs registry — the
    # `oocore.*` namespace the span tracer and CI baseline read.
    _obs.record_stream_stats(stats)
    return out[:, :rank], stats
