"""Unified factor-residency planner for the MTTKRP backend dispatch.

Before this module, the "does it fit?" question was answered in three
places with three ad-hoc rule sets: ``ops.select_backend``'s static
ladder, the per-family guards in ``ops``'s table-validation path, and
``tune.model._feasible``'s copy of both. Every one of them was really
asking the same thing: **under a byte budget, what residency can the
per-mode factor operands afford?** This module owns that question.

:func:`plan_residency` returns a :class:`ResidencyPlan` — the full
decision for one mode step: the chosen backend, the per-input-factor
residency policy (``whole`` / ``slab`` / ``stream``), the VMEM bytes the
choice costs, and the stream-window geometry when the out-of-core
kernel is chosen. The policies map 1:1 onto the kernel families:

  ``whole``   the factor matrix is VMEM-resident across the grid sweep
              (``fused_mttkrp_nmode_gather``);
  ``slab``    one ``RANK_SLAB``-wide column slab of the factor is
              resident per slab pass (``fused_mttkrp_nmode_gather_tiled``);
  ``stream``  the factor stays **HBM-resident** and ``window_tiles``
              slots of ``FACTOR_ROW_TILE`` rows are DMA'd through VMEM
              per nonzero block (``fused_mttkrp_nmode_gather_stream``) —
              the out-of-core regime this package adds.

When even streaming cannot be certified (factor sizes unknown, or the
window itself overflows), the plan degrades through the materializing
family exactly as the pre-oocore dispatch did: fused → rank-tiled fused
→ ``pallas``.

The ladder is *monotone in the budget* by construction: every
feasibility predicate is ``bytes ≤ budget``, so growing the budget can
only move the decision toward earlier (more-resident) rungs — a
property ``tests/test_oocore.py`` sweeps.

Consumers: ``kernels.mttkrp.ops.select_backend`` (static decision +
calibration-table validation), ``tune.model.plan_modes`` (per-mode tuned
planning), ``oocore.executor`` (window geometry + chunk budgeting).
This module imports only ``kernels.mttkrp.kernel`` (the byte formulas),
never ``ops`` — ops imports *us*.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..kernels.mttkrp import kernel as _kernel
from ..obs import counters as _obs

__all__ = [
    "FACTOR_ROW_TILE",
    "MIN_MXU_RANK",
    "VMEM_BUDGET_BYTES",
    "STREAM_BACKEND",
    "FactorResidency",
    "ResidencyPlan",
    "StreamTraffic",
    "backend_fits",
    "block_tile_analysis",
    "chunk_boundaries",
    "chunk_window_tiles",
    "padded_rank",
    "plan_residency",
    "predict_stream_traffic",
    "stream_chunk_bytes",
    "stream_window_tiles",
]

FACTOR_ROW_TILE = _kernel.FACTOR_ROW_TILE

# Below this rank the one-hot MXU matmul pads R to MXU_RANK_MULTIPLE and
# wastes >= 16x of the array; the XLA segment-sum reference wins.
# (kernel.py owns these shared constants — it is the only module in the
# dispatch triangle with no intra-repo imports, so ops.py and this
# planner can alias one definition whichever is imported first.)
MIN_MXU_RANK = _kernel.MIN_MXU_RANK

# Per-core VMEM working-set budget (half of a v5e core's ~128 MiB VMEM —
# the same theta=0.5 cache-fraction stance as the paper's Eq. 3).
VMEM_BUDGET_BYTES = _kernel.VMEM_BUDGET_BYTES

# The out-of-core backend this package adds to ops.BACKENDS.
STREAM_BACKEND = _kernel.STREAM_BACKEND_NAME


# R rounded up to the MXU lane multiple — aliased from kernel.py, the
# single source shared with ops.py's dispatch arithmetic.
padded_rank = _kernel.padded_rank


def factor_row_tiles(rows: int, frow_tile: int = FACTOR_ROW_TILE) -> int:
    """Number of ``frow_tile``-row tiles covering a ``rows``-row factor."""
    return max(1, -(-rows // frow_tile))


def stream_window_tiles(blk: int, rows: int,
                        frow_tile: int = FACTOR_ROW_TILE) -> int:
    """Correctness bound on the stream kernel's per-mode window width.

    A block of ``blk`` nonzeros touches at most ``blk`` distinct factor
    row tiles, and never more tiles than the factor has — so a window of
    ``min(blk, ceil(rows / frow_tile))`` slots always holds every tile a
    block needs, for any index distribution. The executor may shrink
    this with measured per-block distinct-tile counts; the static
    dispatch (which cannot look at data) plans with the bound.
    """
    return min(blk, factor_row_tiles(rows, frow_tile))


@dataclasses.dataclass(frozen=True)
class FactorResidency:
    """Residency of one input-factor matrix under a :class:`ResidencyPlan`."""

    rows: int                   # factor rows (I_pad of the input mode)
    policy: str                 # "whole" | "slab" | "stream"
    window_tiles: int           # FACTOR_ROW_TILE-row tiles resident per pass
    rank_cols: int              # rank columns resident per pass
    resident_bytes: int         # VMEM bytes this factor holds per grid step

    @property
    def row_tiles(self) -> int:
        """Total row tiles of this factor (streamed tiles partition them)."""
        return factor_row_tiles(self.rows)

    def tile_spans(self) -> list[tuple[int, int]]:
        """Disjoint ``[start, stop)`` row ranges, one per row tile.

        The streaming schedule fetches whole tiles; these spans are the
        units it fetches. They must partition ``[0, rows)`` exactly —
        every factor row covered exactly once — which
        ``tests/test_oocore.py`` asserts as a plan invariant.
        """
        return [(t * FACTOR_ROW_TILE, min(self.rows, (t + 1) * FACTOR_ROW_TILE))
                for t in range(self.row_tiles)]


@dataclasses.dataclass(frozen=True)
class ResidencyPlan:
    """One mode step's residency decision under a byte budget."""

    backend: str                        # the certified kernels.mttkrp backend
    nmodes: int
    rank: int
    blk: int
    tile_rows: int
    vmem_budget: int
    gather_itemsize: int
    vmem_bytes: int                     # working set of the chosen backend
    rank_slabs: int                     # slab passes the choice implies
    window_tiles: tuple[int, ...]       # per input mode; () unless streaming
    factors: tuple[FactorResidency, ...]  # () when factor sizes are unknown

    @property
    def streams(self) -> bool:
        return self.backend == STREAM_BACKEND

    @property
    def fits(self) -> bool:
        """Did the chosen backend's working set fit the budget?

        ``pallas`` is the only rung allowed to exceed it (last resort:
        its block working set is what it is), and ``ref`` never competes
        for VMEM at all.
        """
        return self.vmem_bytes <= self.vmem_budget \
            or self.backend in ("pallas", "ref")


def _normalize_factor_rows(factor_rows, num_in_modes: int):
    """``factor_rows`` as (per-mode tuple | None, total | None).

    Callers know the factor sizes at three fidelities: not at all
    (``None`` — a purely shape-keyed dispatch query), as the total row
    count ``Σ I_pad`` (the historical ``select_backend`` int), or
    per input mode (``mttkrp_device_step``, the executor). Aggregate
    ints plan the stream window conservatively, as if every input
    factor had all the rows.
    """
    if factor_rows is None:
        return None, None
    if isinstance(factor_rows, (list, tuple)):
        per_mode = tuple(int(r) for r in factor_rows)
        assert len(per_mode) == num_in_modes, (per_mode, num_in_modes)
        return per_mode, sum(per_mode)
    total = int(factor_rows)
    return None, total


def backend_fits(backend: str, *, nmodes: int, rank: int, blk: int,
                 tile_rows: int, factor_rows=None,
                 vmem_budget: int = VMEM_BUDGET_BYTES,
                 gather_itemsize: int = 4,
                 window_tiles: Sequence[int] | None = None) -> bool:
    """Hard VMEM feasibility of one backend — the single predicate.

    This is what bounds a calibration table's preference in
    ``select_backend`` and filters ``plan_modes``' candidate pool: a
    measured-fast backend whose working set cannot be certified under
    the budget is an extrapolation and must be discarded. Non-Pallas
    and materializing-last-resort backends (``ref``, ``segsum``,
    ``pallas``) always "fit" — they manage their own memory. The
    ``*_bf16`` names fold into ``gather_itemsize=2``.

    ``window_tiles`` (streaming rung only) overrides the static
    worst-case per-input-mode window widths with measured/predicted
    ones — :func:`predict_stream_traffic` on a locality-reordered
    stream (``repro.reorder``) typically certifies the rung at budgets
    the data-blind bound cannot.
    """
    if backend.endswith("_bf16"):
        backend = backend[:-len("_bf16")]
        gather_itemsize = 2
    k, rpad = nmodes - 1, padded_rank(rank)
    if backend == "pallas_fused":
        return _kernel.fused_vmem_bytes(
            k, rpad, blk, tile_rows,
            gather_itemsize=gather_itemsize) <= vmem_budget
    if backend == "pallas_fused_tiled":
        return _kernel.fused_tiled_vmem_bytes(
            k, rpad, blk, tile_rows,
            gather_itemsize=gather_itemsize) <= vmem_budget
    per_mode, total = _normalize_factor_rows(factor_rows, k)
    if backend == "pallas_fused_gather":
        return total is not None and _kernel.gather_vmem_bytes(
            k, rpad, blk, tile_rows, total,
            gather_itemsize=gather_itemsize) <= vmem_budget
    if backend == "pallas_fused_gather_tiled":
        return total is not None and _kernel.gather_tiled_vmem_bytes(
            k, rpad, blk, tile_rows, total,
            gather_itemsize=gather_itemsize) <= vmem_budget
    if backend == STREAM_BACKEND:
        if total is None:
            return False
        if window_tiles is not None:
            windows = tuple(int(w) for w in window_tiles)
            assert len(windows) == k, (windows, k)
        elif per_mode is not None:
            windows = tuple(stream_window_tiles(blk, r) for r in per_mode)
        else:
            windows = (stream_window_tiles(blk, total),) * k
        return _kernel.gather_stream_vmem_bytes(
            k, rpad, blk, tile_rows, windows,
            gather_itemsize=gather_itemsize) <= vmem_budget
    # ref / pallas / segsum (and anything dispatched a layer up).
    return True


def _factor_states(per_mode, total, k: int, policy: str, blk: int,
                   rank_cols: int, gi: int,
                   windows=None) -> tuple[FactorResidency, ...]:
    rows_list = per_mode if per_mode is not None else (total,) * k
    states = []
    for i, rows in enumerate(rows_list):
        if policy == "stream":
            w = (int(windows[i]) if windows is not None
                 else stream_window_tiles(blk, rows))
            # A window covering every tile of the factor is de-facto
            # whole residency — the plan records it honestly.
            pol = "whole" if w >= factor_row_tiles(rows) else "stream"
            resident = w * FACTOR_ROW_TILE * rank_cols * gi
        else:
            pol, w = policy, factor_row_tiles(rows)
            resident = rows * rank_cols * gi
        states.append(FactorResidency(
            rows=rows, policy=pol, window_tiles=w, rank_cols=rank_cols,
            resident_bytes=resident))
    return tuple(states)


def plan_residency(*, nmodes: int, rank: int, blk: int = 512,
                   tile_rows: int = 128, factor_rows=None,
                   vmem_budget: int = VMEM_BUDGET_BYTES,
                   gather_itemsize: int = 4,
                   allow_stream: bool = True,
                   window_tiles: Sequence[int] | None = None
                   ) -> ResidencyPlan:
    """The full static residency ladder for one mode step.

    In order (each rung = one feasibility predicate against
    ``vmem_budget``; the first that holds wins, so the decision is
    monotone in the budget):

      1. ``rank < MIN_MXU_RANK`` → ``ref`` (MXU-padding waste);
      2. factors whole-VMEM        → ``pallas_fused_gather``;
      3. one rank slab resident    → ``pallas_fused_gather_tiled``;
      4. bounded tile window fits  → ``pallas_fused_gather_stream``
         (the out-of-core rung — factors stay in HBM);
      5. fused working set fits    → ``pallas_fused``;
      6. one fused rank slab fits  → ``pallas_fused_tiled``;
      7. otherwise                 → ``pallas``.

    Rungs 2–4 need ``factor_rows`` (an int total, or a per-input-mode
    sequence for exact stream windows); without it they are skipped and
    the decision is bit-identical to the pre-gather dispatch.
    ``allow_stream=False`` removes rung 4 (the pre-oocore ladder).

    ``window_tiles`` overrides rung 4's static worst-case window widths
    with measured/predicted per-input-mode ones (see
    :func:`predict_stream_traffic`): after a ``repro.reorder`` locality
    sort the per-block distinct-tile maxima shrink well below the
    data-blind ``min(blk, ceil(rows/128))`` bound, and this is how the
    stream rung gets certified — and picked — at budgets where the
    static bound overflows. The ladder stays monotone in the budget:
    the override only changes rung 4's (fixed) byte cost, never the
    predicate shape.
    """
    k, rpad = nmodes - 1, padded_rank(rank)
    gi = gather_itemsize
    kw = dict(nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
              vmem_budget=vmem_budget, gather_itemsize=gi)
    per_mode, total = _normalize_factor_rows(factor_rows, k)

    def finish(backend, vmem_bytes, rank_slabs=1, window=(), factors=()):
        # Static arithmetic (runs at jit-trace time) → counted once per
        # unique plan query per process: eligible for the obs baseline.
        _obs.add("planner.plans")
        _obs.add("planner.vmem.plan_bytes", int(vmem_bytes),
                 backend=backend)
        return ResidencyPlan(
            backend=backend, vmem_bytes=int(vmem_bytes),
            rank_slabs=rank_slabs, window_tiles=tuple(window),
            factors=tuple(factors), **kw)

    if rank < MIN_MXU_RANK:
        return finish("ref", 0)
    slabs = rpad // _kernel.RANK_SLAB
    if total is not None:
        if backend_fits("pallas_fused_gather", factor_rows=factor_rows,
                        **kw):
            return finish(
                "pallas_fused_gather",
                _kernel.gather_vmem_bytes(k, rpad, blk, tile_rows, total,
                                          gather_itemsize=gi),
                factors=_factor_states(per_mode, total, k, "whole", blk,
                                       rpad, gi))
        if backend_fits("pallas_fused_gather_tiled",
                        factor_rows=factor_rows, **kw):
            return finish(
                "pallas_fused_gather_tiled",
                _kernel.gather_tiled_vmem_bytes(
                    k, rpad, blk, tile_rows, total, gather_itemsize=gi),
                rank_slabs=slabs,
                factors=_factor_states(per_mode, total, k, "slab", blk,
                                       min(rpad, _kernel.RANK_SLAB), gi))
        if allow_stream and backend_fits(STREAM_BACKEND,
                                         factor_rows=factor_rows,
                                         window_tiles=window_tiles, **kw):
            if window_tiles is not None:
                windows = tuple(int(w) for w in window_tiles)
            elif per_mode is not None:
                windows = tuple(stream_window_tiles(blk, r) for r in per_mode)
            else:
                windows = (stream_window_tiles(blk, total),) * k
            return finish(
                STREAM_BACKEND,
                _kernel.gather_stream_vmem_bytes(
                    k, rpad, blk, tile_rows, windows, gather_itemsize=gi),
                rank_slabs=slabs, window=windows,
                factors=_factor_states(per_mode, total, k, "stream", blk,
                                       min(rpad, _kernel.RANK_SLAB), gi,
                                       windows=windows))
    if backend_fits("pallas_fused", **kw):
        return finish("pallas_fused",
                      _kernel.fused_vmem_bytes(k, rpad, blk, tile_rows,
                                               gather_itemsize=gi))
    if backend_fits("pallas_fused_tiled", **kw):
        return finish("pallas_fused_tiled",
                      _kernel.fused_tiled_vmem_bytes(
                          k, rpad, blk, tile_rows, gather_itemsize=gi),
                      rank_slabs=slabs)
    return finish("pallas",
                  _kernel.fused_vmem_bytes(0, rpad, blk, tile_rows,
                                           gather_itemsize=gi))


# ---------------------------------------------------------------------------
# Chunk planning (shared by the executor and the traffic predictor)
# ---------------------------------------------------------------------------

def chunk_boundaries(tile_of_block, max_blocks: int) -> list[tuple[int, int]]:
    """Split ``num_blocks`` blocks into chunks of at most ``max_blocks``.

    Boundaries prefer output-row-tile edges: a chunk ends at the last
    position ``<= max_blocks`` where ``tile_of_block`` changes, so a
    tile's contiguous run of blocks stays in one chunk whenever it fits.
    A run longer than ``max_blocks`` is split mid-tile (the executor's
    ``out_init`` threading keeps that exact). Returns ``[start, stop)``
    block ranges covering every block exactly once.
    """
    tiles = np.asarray(tile_of_block)
    num_blocks = len(tiles)
    assert max_blocks >= 1, max_blocks
    bounds = []
    start = 0
    while start < num_blocks:
        stop = min(start + max_blocks, num_blocks)
        if stop < num_blocks:
            aligned = stop
            while aligned > start + 1 and tiles[aligned] == tiles[aligned - 1]:
                aligned -= 1
            if aligned > start and tiles[aligned] != tiles[aligned - 1]:
                stop = aligned
        bounds.append((start, stop))
        start = stop
    return bounds


def chunk_window_tiles(distinct_counts, chunks, windows):
    """Per-chunk stream-window widths, tightened chunk by chunk.

    Every chunk is its own kernel call with its own *static* schedule
    width, so the width only has to cover that chunk's blocks — not the
    global worst block. ``distinct_counts`` is the ``(num_blocks, K)``
    per-block distinct-tile matrix from :func:`block_tile_analysis`,
    ``chunks`` the ``[start, stop)`` list from :func:`chunk_boundaries`,
    ``windows`` the global (VMEM-certified) per-mode widths that cap
    each chunk's. Returns one ``K``-tuple per chunk.

    This is the mechanism a ``repro.reorder`` locality sort cashes in
    on: post-sort, tile diversity concentrates into few blocks, so
    almost every chunk's width collapses to 1–2 while only the chunk
    holding the rare-tile tail pays the wide window. On an unsorted
    stream the per-block counts are i.i.d.-ish and every chunk's max is
    near the global max — tightening buys little.
    """
    distinct_counts = np.asarray(distinct_counts)
    k = distinct_counts.shape[1]
    assert len(windows) == k, (windows, k)
    return [
        tuple(int(min(windows[i],
                      max(1, int(distinct_counts[start:stop, i].max()))))
              for i in range(k))
        for start, stop in chunks
    ]


def stream_chunk_bytes(blk: int, k: int, windows) -> int:
    """Aligned-operand bytes one block contributes to a chunk budget.

    Values (f32) + local rows (i32) + ``K`` index streams (i32) per
    slot, plus one ``i32`` schedule row entry per window slot — the
    arrays the executor slices per chunk.
    """
    return blk * (4 + 4 + 4 * k) + 4 * sum(windows)


# ---------------------------------------------------------------------------
# Data-dependent stream-traffic prediction (the repro.reorder cost model)
# ---------------------------------------------------------------------------

def block_tile_analysis(per_block_tiles: np.ndarray):
    """Per-block sorted-distinct analysis of an aligned tile stream.

    ``per_block_tiles`` is ``(num_blocks, blk, K)`` int — the
    ``FACTOR_ROW_TILE``-tile id of every aligned stream slot, per
    gathered mode. Returns ``(sorted_tiles, first, rank_of,
    distinct_counts)``: the per-block sorted tiles, the first-occurrence
    mask, each slot's distinct rank, and the ``(num_blocks, K)``
    distinct-tile counts. This is the **one** analysis behind the
    executor's window tightening + tile schedules + counted
    ``StreamStats`` *and* :func:`predict_stream_traffic` — sharing it is
    what makes the planner's prediction and the executor's count agree
    exactly (``tests/test_reorder.py`` pins it).
    """
    st = np.sort(per_block_tiles, axis=1)
    first = np.concatenate(
        [np.ones((st.shape[0], 1, st.shape[2]), bool),
         st[:, 1:] != st[:, :-1]], axis=1)
    rank_of = np.cumsum(first, axis=1) - 1
    distinct_counts = first.sum(axis=1)
    return st, first, rank_of, distinct_counts


@dataclasses.dataclass(frozen=True)
class StreamTraffic:
    """Predicted tile-fetch traffic of one streamed mode step.

    Counted from the data (per-block distinct-tile analysis of the
    block-aligned stream), so it matches the executor's ``StreamStats``
    exactly — the point being that :func:`plan_residency` can consume
    ``window_tiles`` *before* running anything, and pick the stream
    rung when a ``repro.reorder`` pass makes it win.
    """

    ordering: str                   # stream the prediction was made on
    num_blocks: int
    nnz: int
    window_tiles: tuple[int, ...]   # global tightened widths, per input mode
    scheduled_tiles: int            # Σ_chunks blocks_c * Σ chunk windows
    distinct_tiles: int             # Σ per-block distinct, all modes
    tile_bytes: int                 # one FACTOR_ROW_TILE x slab tile
    rank_slabs: int
    chunks: int = 1

    @property
    def scheduled_tile_bytes(self) -> int:
        return self.scheduled_tiles * self.tile_bytes * self.rank_slabs

    @property
    def distinct_tile_bytes(self) -> int:
        return self.distinct_tiles * self.tile_bytes * self.rank_slabs

    @property
    def distinct_over_scheduled(self) -> float:
        """Fraction of scheduled fetches that are distinct (1.0 = no waste)."""
        return self.distinct_tiles / max(self.scheduled_tiles, 1)

    @property
    def scheduled_over_distinct(self) -> float:
        """The re-fetch factor the reorder pass attacks (≥ 1.0)."""
        return self.scheduled_tiles / max(self.distinct_tiles, 1)


def predict_stream_traffic(idx, valid, *, mode: int, rows_cap: int,
                           blk: int, tile_rows: int, rank: int,
                           factor_rows: Sequence[int],
                           row_offset: int = 0, gather_itemsize: int = 4,
                           ordering: str = "as-given",
                           max_chunk_bytes: int | None = None,
                           frow_tile: int = FACTOR_ROW_TILE
                           ) -> StreamTraffic:
    """Predict the stream kernel's tile traffic for a nonzero stream.

    A host-side (numpy) replication of ``ops.build_block_layout`` +
    ``_align_to_blocks`` on the index streams, followed by
    :func:`block_tile_analysis` — i.e. *exactly* the arithmetic the
    executor performs, on exactly the stream it would run, without
    touching a kernel. The input contract matches the executor's:
    ``idx (cap, N)`` with valid elements first and output-tile runs
    contiguous ascending (a row-sorted or ``repro.reorder``-ed stream).

    ``factor_rows`` is the per-input-mode factor row count (window
    bound). ``max_chunk_bytes`` replicates the executor's chunk
    budgeting (same :func:`chunk_boundaries` + :func:`stream_chunk_bytes`
    arithmetic), so the scheduled count includes the per-chunk window
    tightening the executor applies — the mechanism that turns a
    locality sort into counted byte savings. The returned
    :class:`StreamTraffic` carries the global tightened
    ``window_tiles`` — feed them to ``plan_residency(window_tiles=...)``
    to certify the stream rung under the *measured* window, and the
    predicted ``distinct/scheduled`` ratio the committed
    ``BENCH_reorder.json`` tracks before/after reordering.
    """
    idx = np.asarray(idx)
    valid = np.asarray(valid, bool)
    cap, nmodes = idx.shape
    in_modes = [w for w in range(nmodes) if w != mode]
    k = len(in_modes)
    assert len(factor_rows) == k, (factor_rows, k)
    num_tiles = rows_cap // tile_rows
    n_pad = ((cap + blk - 1) // blk) * blk + num_tiles * blk

    local_row = np.where(valid, idx[:, mode].astype(np.int64) - row_offset, 0)
    tile_of_elem = np.where(valid, local_row // tile_rows, num_tiles)
    counts = np.bincount(tile_of_elem[valid].astype(np.int64),
                         minlength=num_tiles)[:num_tiles]
    padded = ((counts + blk - 1) // blk) * blk
    offsets = np.concatenate([[0], np.cumsum(padded)]).astype(np.int64)
    first_of_tile = np.searchsorted(tile_of_elem, tile_of_elem, side="left")
    rank_in_tile = np.arange(cap, dtype=np.int64) - first_of_tile
    slot = np.where(valid, offsets[tile_of_elem] + rank_in_tile, n_pad)

    idx_in = np.where(valid[:, None], idx[:, in_modes], 0).astype(np.int64)
    aligned = np.zeros((n_pad + 1, k), np.int64)
    aligned[slot] = idx_in          # padding slots stay 0 -> tile 0
    per_block = (aligned[:n_pad] // frow_tile).reshape(-1, blk, k)
    _, _, _, distinct_counts = block_tile_analysis(per_block)

    windows = tuple(
        int(min(stream_window_tiles(blk, int(factor_rows[i])),
                max(1, int(distinct_counts[:, i].max()))))
        for i in range(k))
    num_blocks = per_block.shape[0]

    # The executor's chunking, replicated: tile_of_block from the block
    # layout's offsets, the chunk-byte budget, then per-chunk window
    # tightening — each chunk is its own kernel call whose static
    # schedule width only has to cover that chunk's blocks.
    block_start = np.arange(num_blocks, dtype=np.int64) * blk
    tile_of_block = np.clip(
        np.searchsorted(offsets, block_start, side="right") - 1,
        0, num_tiles - 1)
    if max_chunk_bytes is None:
        max_blocks = num_blocks
    else:
        max_blocks = max(
            1, max_chunk_bytes // stream_chunk_bytes(blk, k, windows))
    chunks = chunk_boundaries(tile_of_block, max_blocks)
    cwindows = chunk_window_tiles(distinct_counts, chunks, windows)
    scheduled = sum((stop - start) * sum(cw)
                    for (start, stop), cw in zip(chunks, cwindows))

    rpad = padded_rank(rank)
    return StreamTraffic(
        ordering=ordering,
        num_blocks=num_blocks,
        nnz=int(valid.sum()),
        window_tiles=windows,
        scheduled_tiles=int(scheduled),
        distinct_tiles=int(distinct_counts.sum()),
        tile_bytes=frow_tile * min(rpad, _kernel.RANK_SLAB)
        * gather_itemsize,
        rank_slabs=rpad // _kernel.RANK_SLAB,
        chunks=len(chunks),
    )
