"""Version-compatibility shims for the spread of jax releases in CI images.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to top-level ``jax.shard_map``
(where it is ``check_vma``). Every internal call site goes through
:func:`shard_map` so the repo runs on both API generations.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False,
    )
