"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron. [arXiv:2407.14679; hf]

Deviation note (DESIGN.md §Arch-applicability): nemotron uses squared-ReLU
MLPs; we use the framework-uniform SwiGLU (same parameter count with the
gate matrix folded in).
"""
from .common import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    pattern=("attn+mlp",),
    rope_theta=5e5,
)
