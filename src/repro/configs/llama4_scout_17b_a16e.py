"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 + 1 shared expert, iRoPE chunked-local
attention (3 local : 1 global). [hf:meta-llama/Llama-4-Scout-17B-16E]

``sub_quadratic=True``: 3/4 of the layers use 8192-chunk local attention,
so the arch is run for ``long_500k`` as a bonus cell (global layers decode
O(S); local layers O(window)). Early-fusion multimodality is out of scope
for the LM backbone cells (frontend stub rule).
"""
from .common import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    pattern=("attn_local+moe", "attn_local+moe", "attn_local+moe",
             "attn+moe"),
    window=8192,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    d_ff_expert=8192,
    rope_theta=5e5,
    sub_quadratic=True,
    note="iRoPE 3:1 local:global; long_500k runs as a bonus cell",
)
