from .common import ArchConfig, ShapeSpec, SHAPES, applicable, skip_reason
from .registry import ARCHS, get_config, smoke_config, smoke_shape

__all__ = [
    "ArchConfig", "ShapeSpec", "SHAPES", "applicable", "skip_reason",
    "ARCHS", "get_config", "smoke_config", "smoke_shape",
]
