"""mamba2-370m [ssm]: 48L d_model=1024, attn-free SSD (state-space
duality), ssm_state=128, vocab=50280. [arXiv:2405.21060]

No attention, no FFN (the Mamba2 block IS the layer). ``sub_quadratic``:
the decode state is O(1) in context length, so all long-context cells run.
Vocab padded 50280 → 50304 for the model axis. Embeddings tied (as in the
reference 370m checkpoint).
"""
from .common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,          # unused (attention-free); kept for schema validity
    n_kv_heads=16,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    pattern=("mamba",),
    d_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    sub_quadratic=True,
)
