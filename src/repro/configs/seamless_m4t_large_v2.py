"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. [arXiv:2308.11596; hf]

The speech frontend (w2v-BERT conformer feature extractor) is a STUB per
the assignment: ``input_specs`` provides precomputed frame embeddings
``(batch, n_frames, d_frontend)``; a learned projection maps them into the
backbone. Decoder layers are self+cross ("attn_cross+mlp"). Vocab is
padded 256206 → 256256 for the 16-way model axis.
"""
from .common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                 # decoder
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    pattern=("attn_cross+mlp",),
    enc_pattern=("attn+mlp",),
    d_frontend=1024,
    rope_theta=1e4,
)
