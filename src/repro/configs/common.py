"""Architecture + input-shape schema for the assigned (arch × shape) grid.

Every assigned architecture is an :class:`ArchConfig`; every input shape a
:class:`ShapeSpec`. ``applicable(cfg, shape)`` encodes the skip rules from
the assignment (documented in DESIGN.md §Shape-skips):

* ``long_500k`` runs only for sub-quadratic archs (SSM / hybrid / archs with
  chunked-local attention);
* decode shapes are skipped for encoder-only archs (none assigned here —
  seamless-m4t is enc-*dec* and decodes with its decoder).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "applicable", "skip_reason"]

LayerKind = str  # "<mixer>+<ffn>": mixer ∈ attn|attn_local|mamba|attn_cross; ffn ∈ mlp|moe|none


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "encdec", "vlm", "ssm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # Repeating layer pattern; len(pattern) must divide n_layers. The whole
    # pattern group is the scan body (stacked n_layers/len(pattern) times).
    pattern: tuple[LayerKind, ...] = ("attn+mlp",)
    qk_norm: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    d_state: int = 0
    d_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # attention variants
    window: int = 0                  # attn_local chunk width (llama4 iRoPE)
    # encoder–decoder
    n_enc_layers: int = 0
    enc_pattern: tuple[LayerKind, ...] = ("attn+mlp",)
    # multimodal stubs (precomputed embeddings; frontend out of scope per spec)
    n_img_tokens: int = 0            # vlm: patch embeddings per image
    d_frontend: int = 0              # stub embedding dim (0 → d_model)
    # numerics / optimizer (per-arch so 398B fits the dry-run memory budget)
    param_dtype: str = "float32"
    act_dtype: str = "bfloat16"
    grad_accum_dtype: str = "float32"   # 398B-scale configs use bfloat16
    optimizer: str = "adamw"
    # ---- perf levers (§Perf hillclimb; defaults = paper-faithful baseline)
    kv_cache_dtype: str = "bfloat16"    # "int8" → quantized KV cache
    exact_causal_attn: bool = False     # block-skip causal flash attention
    remat_policy: str = "nothing"       # "nothing" | "dots"
    moe_impl: str = "auto"              # auto | owner | gather (§Perf A/B)
    sub_quadratic: bool = False      # eligible for long_500k
    note: str = ""

    # -- derived ---------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the `model` mesh axis (16) divides it."""
        return -(-self.vocab // 128) * 128

    @property
    def n_experts_padded(self) -> int:
        """Experts rounded up to the `model` axis size (padding experts are
        masked to -inf in the router; weight overhead is reported)."""
        if self.n_experts == 0:
            return 0
        return -(-self.n_experts // 16) * 16

    @property
    def n_repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:                  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; ``active_only`` counts top_k routed +
        shared experts only (MODEL_FLOPS = 6·N_active·D for MoE)."""
        d, dh = self.d_model, self.head_dim
        total = 2 * self.vocab_padded * d if not self.tie_embeddings \
            else self.vocab_padded * d
        def attn():
            return d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        def mlp(ff):
            return 3 * d * ff
        def mamba():
            di, g, n, h = self.d_inner, self.ssm_groups, self.d_state, self.ssm_heads
            in_p = d * (2 * di + 2 * g * n + h)
            conv = self.d_conv * (di + 2 * g * n)
            return in_p + conv + 2 * h + di + di * d
        def moe():
            e = self.n_experts if not active_only else self.top_k
            routed = e * 3 * d * self.d_ff_expert
            shared = self.n_shared_experts * 3 * d * self.d_ff_expert
            router = d * self.n_experts
            return routed + shared + router
        kinds = list(self.pattern) * self.n_repeats
        if self.n_enc_layers:
            kinds += list(self.enc_pattern) * (
                self.n_enc_layers // len(self.enc_pattern))
        for kind in kinds:
            mixer, _, ffn = kind.partition("+")
            if mixer in ("attn", "attn_local"):
                total += attn()
            elif mixer == "attn_cross":
                total += 2 * attn()
            elif mixer == "mamba":
                total += mamba()
            if ffn == "mlp":
                total += mlp(self.d_ff)
            elif ffn == "moe":
                total += moe()
            total += 2 * d   # norms
        return total


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> str | None:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 500k context requires "
                "sub-quadratic attention (assignment skip rule)")
    return None


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    return skip_reason(cfg, shape) is None
