"""Architecture registry + reduced smoke configs.

``get_config(name)`` returns the full published config; ``smoke_config``
shrinks every dimension (layers, width, experts, vocab, state) while
preserving the *family structure* (pattern, GQA ratio, MoE top-k, SSD
grouping) so the CPU smoke tests exercise the same code paths as the full
dry-run cells.
"""
from __future__ import annotations

import dataclasses

from .common import ArchConfig, SHAPES, ShapeSpec, applicable, skip_reason
from .qwen3_32b import CONFIG as _qwen3
from .phi3_mini_3_8b import CONFIG as _phi3
from .internlm2_20b import CONFIG as _internlm2
from .minitron_8b import CONFIG as _minitron
from .qwen2_moe_a2_7b import CONFIG as _qwen2moe
from .llama4_scout_17b_a16e import CONFIG as _llama4
from .jamba_1_5_large_398b import CONFIG as _jamba
from .seamless_m4t_large_v2 import CONFIG as _seamless
from .llama_3_2_vision_11b import CONFIG as _llamav
from .mamba2_370m import CONFIG as _mamba2

__all__ = ["ARCHS", "get_config", "smoke_config", "smoke_shape",
           "SHAPES", "applicable", "skip_reason"]

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        _qwen3, _phi3, _internlm2, _minitron, _qwen2moe, _llama4, _jamba,
        _seamless, _llamav, _mamba2,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced config of the same family (2 pattern repeats, tiny dims)."""
    cfg = get_config(name)
    kw: dict = dict(
        n_layers=2 * len(cfg.pattern),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        rope_theta=1e4,
        window=16 if cfg.window else 0,
    )
    if cfg.n_kv_heads == cfg.n_heads:      # MHA archs stay MHA
        kw["n_kv_heads"] = kw["n_heads"]
    if cfg.n_experts:
        # capacity_factor ≥ n_experts_padded ⇒ drop-free: smoke tests can
        # assert exact train/serve consistency (production keeps 1.25 and
        # counts drops in metrics instead).
        kw.update(n_experts=6, top_k=min(cfg.top_k, 2),
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  d_ff_expert=32, capacity_factor=16.0)
    if "mamba" in "".join(cfg.pattern):
        kw.update(d_state=16, ssm_headdim=16, ssm_expand=2,
                  ssm_groups=min(cfg.ssm_groups, 2), ssm_chunk=8)
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 2 * len(cfg.enc_pattern)
    if cfg.family == "vlm":
        kw.update(n_img_tokens=8, d_frontend=24)
    if cfg.family == "encdec":
        kw.update(d_frontend=24)
    kw["param_dtype"] = "float32"
    return dataclasses.replace(cfg, **kw)


def smoke_shape(kind: str = "train") -> ShapeSpec:
    """Tiny shape for smoke tests (CPU, 1 device)."""
    if kind == "train":
        return ShapeSpec("smoke_train", 32, 2, "train")
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", 32, 2, "prefill")
    return ShapeSpec("smoke_decode", 32, 2, "decode")
