"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision]

Vision frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings ``(batch, 1601, 7680)`` (the ViT-H 1601-token output); a learned
projection maps them to d_model. Cross-attn layers are zero-init gated
(tanh gate), as in the reference implementation.
"""
from .common import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    pattern=("attn+mlp", "attn+mlp", "attn+mlp", "xattn+mlp", "attn+mlp"),
    n_img_tokens=1601,
    d_frontend=7680,
    rope_theta=5e5,
)
