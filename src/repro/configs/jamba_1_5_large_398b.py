"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2, Mamba:attn 7:1 interleave. [arXiv:2403.19887]

Pattern of 8 (scanned ×9): attention at position 4, MoE on odd positions
(4 MoE / 8 layers) — reproduces the published 398B total / ~94B active
split (our analytic count: 399.5B total / 94.5B active).

Numerics: ``param_dtype=bfloat16`` + Adafactor — required to fit the
16 GB/chip v5e budget at 256-way sharding (fp32 AdamW would need
18.6 GB/chip for optimizer state alone; see EXPERIMENTS.md §Dry-run).
"""
from .common import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    pattern=("mamba+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
             "attn+mlp", "mamba+moe", "mamba+mlp", "mamba+moe"),
    n_experts=16,
    top_k=2,
    d_ff_expert=24576,
    d_state=128,
    ssm_headdim=128,
    ssm_groups=8,
    ssm_chunk=256,
    rope_theta=1e6,
    param_dtype="bfloat16",
    grad_accum_dtype="bfloat16",   # fp32 grads alone are 12.4 GB/chip at
    optimizer="adafactor",         # 256-way sharding — documented trade-off
    sub_quadratic=True,
)
