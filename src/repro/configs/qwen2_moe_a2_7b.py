"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

60 experts are padded to 64 for the 16-way `model` mesh axis (padding
experts masked to -inf in the router; +6.7% expert weights, reported in
EXPERIMENTS.md).
"""
from .common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=151936,
    pattern=("attn+moe",),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_ff_expert=1408,
    rope_theta=1e6,
)
