"""Roofline-term extraction from compiled dry-run artifacts.

``cost_analysis()`` gives HLO FLOPs and HBM byte traffic of the per-device
SPMD module; collective bytes are NOT in cost_analysis, so we parse the
optimized HLO text and sum result-shape bytes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
— including their ``-start`` async forms; ``-done`` ops are skipped so
nothing is double-counted).

Terms (seconds, per chip — the SPMD module *is* the per-chip program):
  compute    = HLO_FLOPs / peak_FLOP/s
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / ICI_link_bw
"""
from __future__ import annotations

import re
from typing import Any

from .mesh import HW

__all__ = ["collective_bytes", "roofline_terms", "summarize_cell"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|"
                       r"s4|s8|s16|s32|s64|u4|u8|u16|u32|u64)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum result-shape bytes per collective op kind."""
    per_kind: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?.*?\)?)\s+"
                     r"([a-z\-]+)(?:-start)?\(", line)
        if not m:
            continue
        result_shape, op = m.groups()
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        b = _shape_bytes(result_shape)
        per_kind[base] = per_kind.get(base, 0) + b
        count[base] = count.get(base, 0) + 1
    return {"bytes_by_kind": per_kind, "count_by_kind": count,
            "total_bytes": sum(per_kind.values())}


def roofline_terms(flops: float, hbm_bytes: float,
                   coll_bytes: float) -> dict[str, float]:
    compute = flops / HW["peak_flops_bf16"]
    memory = hbm_bytes / HW["hbm_bw"]
    collective = coll_bytes / HW["ici_bw_per_link"]
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return dict(terms, dominant=dom, bound_s=bound,
                overlap_fraction=bound / total if total else 0.0)


def summarize_cell(compiled, lowered_text: str | None = None) -> dict:
    """All measurable quantities from one compiled (arch × shape × mesh)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        mem_info[attr] = int(getattr(mem, attr, 0) or 0)
    terms = roofline_terms(flops, hbm, coll["total_bytes"])
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collectives": coll,
        "memory_analysis": mem_info,
        "roofline": terms,
    }
