"""Production meshes.

``make_production_mesh()`` is a FUNCTION (not a module constant) so merely
importing this module never touches jax device state — the dry-run entry
point must set ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
*before* the first jax device query, and smoke tests must keep seeing one
device.

Topology: TPU v5e, 16×16 = 256 chips per pod; the multi-pod mesh adds a
leading ``pod`` axis (2 pods = 512 chips) that is pure data parallelism
over DCN — the axis that scales to 1000+ nodes (gradient reduction is
hierarchical: reduce-scatter over ICI inside the pod, all-reduce across
pods).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "HW"]

# TPU v5e hardware constants (per chip) for the roofline model.
HW = {
    "peak_flops_bf16": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # bytes/s
    "ici_bw_per_link": 50e9,        # bytes/s/link (~)
    "hbm_bytes": 16 * 1024**3,      # 16 GB
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """All available host devices on a ("data",) mesh (tests/examples)."""
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs), 1), ("data", "model"))
