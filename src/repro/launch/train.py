"""Training driver: real steps on the host mesh (reduced configs) or the
production mesh (TPU pods).

CPU-scale entry point (examples / CI):
  python -m repro.launch.train --arch qwen3-32b --smoke --steps 20

On hardware the same driver runs the full config:
  python -m repro.launch.train --arch qwen3-32b --shape train_4k \
      --ckpt-dir /ckpt/qwen3 --steps 10000

The loop is wrapped by ``runtime.TrainLoopRunner`` (atomic checkpoints,
auto-resume, bounded retry, straggler telemetry).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import SHAPES, get_config, smoke_config
from ..data import make_batch_iterator
from ..models import model as model_lib
from ..models import steps as steps_lib
from ..models.params import abstract_params, init_params, tree_shardings
from ..runtime import TrainLoopRunner
from .. import optim as optim_lib
from .mesh import make_host_mesh

__all__ = ["train", "main"]


def train(arch: str, *, smoke: bool = False, steps: int = 20,
          batch: int = 2, seq: int = 64, ckpt_dir: str | None = None,
          ckpt_every: int = 10, seed: int = 0, lr: float = 1e-3,
          log_fn=print, use_mesh: bool = True):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    mesh = make_host_mesh() if use_mesh and len(jax.devices()) > 1 else None

    opt = optim_lib.make_optimizer(
        cfg.optimizer, optim_lib.cosine_schedule(lr, max(2, steps // 10),
                                                 max(steps, 10)))
    specs = model_lib.model_specs(cfg)
    params = init_params(specs, seed=seed)
    state = {"params": params, "opt": opt.init(params),
             "step": jax.numpy.zeros((), jax.numpy.int32)}
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt, mesh))

    data = make_batch_iterator(cfg.vocab, seq, batch, seed=seed)

    def batched():
        for step, b in data:
            extra = {}
            if cfg.family == "encdec":
                rng = np.random.default_rng(seed * 131 + step)
                extra["frames"] = rng.standard_normal(
                    (batch, seq, cfg.d_frontend or cfg.d_model)
                ).astype(np.float32)
            if cfg.family == "vlm":
                rng = np.random.default_rng(seed * 131 + step)
                extra["img"] = rng.standard_normal(
                    (batch, cfg.n_img_tokens, cfg.d_frontend or cfg.d_model)
                ).astype(np.float32)
            yield step, dict(b, **extra)

    if ckpt_dir:
        ckpt = CheckpointManager(ckpt_dir)
        runner = TrainLoopRunner(step_fn, ckpt, ckpt_every=ckpt_every,
                                 log_fn=log_fn)
        state, start = runner.resume_or(state)
        state, history = runner.run(state, batched(), steps,
                                    start_step=start)
        return state, history

    history = []
    for step, b in batched():
        if step >= steps:
            break
        state, metrics = step_fn(state, b)
        loss = float(metrics["loss"])
        history.append({"step": step, "loss": loss})
        if step % 5 == 0:
            log_fn(f"step {step} loss {loss:.4f}")
    return state, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="full production shape (hardware only)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    if args.shape:
        shape = SHAPES[args.shape]
        args.batch, args.seq = shape.global_batch, shape.seq_len
    _, history = train(args.arch, smoke=args.smoke or not args.shape,
                       steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, lr=args.lr)
    if history:
        print(f"final loss {history[-1]['loss']:.4f} "
              f"(start {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
