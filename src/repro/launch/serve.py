"""Batched serving driver: prefill + decode loop with a request queue.

A deliberately small continuous-batching server: requests (prompts) are
padded into a fixed batch, prefilled once, then decoded token-by-token with
the per-layer cache pytree. Greedy or temperature sampling.

  python -m repro.launch.serve --arch mamba2-370m --smoke --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..models import steps as steps_lib
from ..models import model as model_lib
from ..models.params import init_params
from ..obs import counters as _obs
from ..obs import tracer as _tracer_mod

__all__ = ["ServeSession", "main"]


class ServeSession:
    def __init__(self, cfg, params, *, mesh=None, max_len: int = 128,
                 tracer=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        # Default: resolve the process tracer per generate() call so a
        # session built before `use_tracer(...)` still records into it.
        self._tracer = tracer
        self._prefill = jax.jit(steps_lib.make_prefill_step(cfg, mesh))
        self._decode = jax.jit(steps_lib.make_decode_step(cfg, mesh),
                               donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, n_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0,
                 extras: dict | None = None):
        """prompts: (b, l_prompt) int32 → (b, n_tokens) int32."""
        tracer = self._tracer or _tracer_mod.get_tracer()
        b, lp = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        batch.update(extras or {})
        with tracer.span("generate", batch=b, prompt_len=lp,
                         tokens=n_tokens):
            t0 = time.perf_counter()
            with tracer.span("prefill"):
                logits, cache = self._prefill(self.params, batch)
                if tracer.enabled:
                    logits = jax.block_until_ready(logits)
            _obs.add("serve.prefill_s", time.perf_counter() - t0)
            # decode caches from prefill are sized (l_prompt); re-pad the
            # attention K/V (+ scale) slots to max_len. Key-based: SSM
            # states must NOT be padded.
            cache = _pad_caches(cache, lp, self.max_len)
            out = []
            key = jax.random.key(seed)
            tok = _sample(logits[:, -1, :], temperature, key, self.cfg.vocab)
            out.append(tok)
            t0 = time.perf_counter()
            with tracer.span("decode", tokens=n_tokens - 1):
                for i in range(n_tokens - 1):
                    pos = jnp.int32(lp + i)
                    logits, cache = self._decode(self.params, cache,
                                                 tok[:, None], pos)
                    key = jax.random.fold_in(key, i)
                    tok = _sample(logits[:, -1, :], temperature, key,
                                  self.cfg.vocab)
                    out.append(tok)
                result = np.stack([np.asarray(t) for t in out], axis=1)
            _obs.add("serve.decode_s", time.perf_counter() - t0)
            _obs.add("serve.tokens", b * n_tokens)
        return result


def _pad_caches(cache, prompt_len: int, max_len: int):
    """Grow the seq dim (axis 2 after layer stacking) of K/V(+k_scale)
    entries; SSM conv/ssd states and cross-attention caches stay as-is."""
    def fix(path, c):
        key = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if key in ("k", "v", "k_scale") and c.shape[2] == prompt_len:
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, max_len - prompt_len)
            return jnp.pad(c, pad)
        return c
    return jax.tree_util.tree_map_with_path(fix, cache)


def _sample(logits, temperature, key, vocab):
    logits = logits[:, :vocab].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(model_lib.model_specs(cfg), seed=0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)
                           ).astype(np.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_frontend or cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        extras["img"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_img_tokens, cfg.d_frontend or cfg.d_model)),
            jnp.float32)

    sess = ServeSession(cfg, params,
                        max_len=args.prompt_len + args.tokens + 1)
    t0 = time.perf_counter()
    out = sess.generate(prompts, args.tokens, temperature=args.temperature,
                        extras=extras)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s incl. compile)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
