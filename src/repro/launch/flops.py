"""Exact FLOP / HBM-traffic accounting by walking the jaxpr.

Why: XLA's ``HloCostAnalysis`` (behind ``compiled.cost_analysis()``) counts
a ``while`` body ONCE, so any scanned-layers model under-reports flops by a
factor of ~n_layers (verified in this repo: an 8-step scanned matmul
reports 1× the matmul flops). The dry-run therefore records BOTH numbers:
the raw ``cost_analysis`` values and these loop-corrected ones; §Roofline
uses the corrected values.

``count_flops`` — 2·M·N·K per dot_general (plus conv/ragged-dot if ever
used), recursing into scan (×length), while (×extracted trip count when
static), cond (max branch), pjit/remat/custom-vjp calls. This includes
remat recompute and masked-attention waste — it is the *executed* flops,
exactly what the compute roofline term needs.

``count_hbm_bytes`` — fusion-aware traffic model: on TPU, elementwise
chains fuse, so the surviving HBM traffic is dominated by (a) dot_general
operand/result streams, (b) gather/scatter payloads, (c) scan carries +
per-step xs/ys slices. We count exactly those. This is a *model* (documented
in EXPERIMENTS.md): real HBM traffic adds fusion-boundary spills that only a
hardware profile can show.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Any

import jax
import numpy as np
from jax import core as jcore

__all__ = ["count_flops", "count_hbm_bytes", "analyze_jaxpr", "step_costs"]


def _size_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    a = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= a.shape[d]
    return 2.0 * float(np.prod(out.shape)) * float(k)


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * out_elems * (kernel spatial * in_channels)
    per_out = 2.0 * float(np.prod(rhs.shape[:-1]))
    return per_out * float(np.prod(out.shape))


_CALL_PRIMS = {"pjit", "closed_call", "core_call", "remat", "checkpoint",
               "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
               "xla_call"}


def analyze_jaxpr(jaxpr) -> dict[str, float]:
    """Returns {'flops', 'dot_bytes', 'gather_bytes', 'scan_io_bytes'}."""
    acc = {"flops": 0.0, "dot_bytes": 0.0, "gather_bytes": 0.0,
           "scan_io_bytes": 0.0}

    def visit(jx, mult: float):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name == "dot_general":
                acc["flops"] += mult * _dot_flops(eqn)
                acc["dot_bytes"] += mult * (
                    sum(_size_bytes(v.aval) for v in eqn.invars)
                    + sum(_size_bytes(v.aval) for v in eqn.outvars))
            elif name in ("conv_general_dilated",):
                acc["flops"] += mult * _conv_flops(eqn)
                acc["dot_bytes"] += mult * (
                    sum(_size_bytes(v.aval) for v in eqn.invars)
                    + sum(_size_bytes(v.aval) for v in eqn.outvars))
            elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                          "take", "dynamic_slice", "dynamic_update_slice"):
                acc["gather_bytes"] += mult * sum(
                    _size_bytes(v.aval) for v in eqn.outvars)
            elif name == "scan":
                length = float(eqn.params["length"])
                inner = eqn.params["jaxpr"]
                ncar = eqn.params["num_carry"]
                ncon = eqn.params["num_consts"]
                # xs slices read + ys written each step + carry traffic
                xs = eqn.invars[ncon + ncar:]
                ys = eqn.outvars[ncar:]
                per_step = sum(_size_bytes(v.aval) / max(
                    1, (v.aval.shape[0] if v.aval.shape else 1))
                    for v in xs + ys)
                carry = sum(_size_bytes(v.aval)
                            for v in eqn.invars[ncon:ncon + ncar])
                acc["scan_io_bytes"] += mult * length * (per_step + 2 * carry)
                visit(inner.jaxpr, mult * length)
            elif name == "while":
                body = eqn.params["body_jaxpr"]
                trips = _while_trips(eqn)
                visit(body.jaxpr, mult * trips)
            elif name == "shard_map":
                # inner jaxpr has per-shard shapes and every device runs
                # it → global cost = inner × mesh size
                inner = eqn.params["jaxpr"]
                msh = eqn.params.get("mesh")
                n_dev = 1
                if msh is not None:
                    try:
                        n_dev = int(np.prod(list(dict(msh.shape).values())))
                    except Exception:
                        n_dev = getattr(msh, "size", 1)
                visit(inner.jaxpr if hasattr(inner, "jaxpr") else inner,
                      mult * n_dev)
            elif name == "cond":
                branches = eqn.params["branches"]
                subs = []
                for br in branches:
                    sub = {"flops": 0.0, "dot_bytes": 0.0,
                           "gather_bytes": 0.0, "scan_io_bytes": 0.0}
                    _accumulate_into(br.jaxpr, 1.0, sub)
                    subs.append(sub)
                worst = max(subs, key=lambda s: s["flops"])
                for k in acc:
                    acc[k] += mult * worst[k]
            elif "jaxpr" in eqn.params:
                inner = eqn.params["jaxpr"]
                visit(inner.jaxpr if hasattr(inner, "jaxpr") else inner, mult)
            elif "call_jaxpr" in eqn.params:
                inner = eqn.params["call_jaxpr"]
                visit(inner.jaxpr if hasattr(inner, "jaxpr") else inner, mult)

    def _accumulate_into(jx, mult, target):
        nonlocal acc
        saved = acc
        acc = target
        try:
            visit(jx, mult)
        finally:
            acc = saved

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, 1.0)
    return acc


def _while_trips(eqn) -> float:
    return 1.0   # conservative: unknown trip count (we only emit scans)


def count_flops(jaxpr) -> float:
    return analyze_jaxpr(jaxpr)["flops"]


def count_hbm_bytes(jaxpr) -> float:
    a = analyze_jaxpr(jaxpr)
    return a["dot_bytes"] + a["gather_bytes"] + a["scan_io_bytes"]


def step_costs(fn, *abstract_args) -> dict[str, float]:
    """Trace ``fn`` on ShapeDtypeStructs and return global flops/bytes."""
    jx = jax.make_jaxpr(fn)(*abstract_args)
    a = analyze_jaxpr(jx)
    a["hbm_bytes_model"] = (a["dot_bytes"] + a["gather_bytes"]
                            + a["scan_io_bytes"])
    return a
