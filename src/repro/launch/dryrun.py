import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder devices.

Per cell:
  * build the step function (train_step for ``train_*``, prefill/serve
    steps for inference shapes),
  * ``jax.jit(step, ...).lower(**ShapeDtypeStruct specs)`` — no allocation,
  * ``.compile()`` — proves the sharding/collective program is coherent,
  * record ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
    (FLOPs/bytes) and parsed collective bytes → EXPERIMENTS.md §Dry-run /
    §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all          # every runnable cell
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, get_config, skip_reason
from ..obs import counters as _obs
from ..models import steps as steps_lib
from ..models.params import abstract_params, tree_shardings
from ..models import model as model_lib
from .. import optim as optim_lib
from .flops import step_costs
from .hlo_analysis import roofline_terms, summarize_cell
from .mesh import HW, make_production_mesh

__all__ = ["dryrun_cell", "main"]

# Microbatches per train step (activation-memory fit): per-device
# microbatch is exactly one sequence on either mesh (256/16/16 = 1,
# 256/8/32 = 1).
GRAD_ACCUM = {"16x16": 16, "2x16x16": 8}


def _train_lowered(cfg, shape, mesh, rules, grad_accum=None):
    if grad_accum is None:
        grad_accum = GRAD_ACCUM["2x16x16" if "pod" in mesh.axis_names
                                else "16x16"]
    opt = optim_lib.make_optimizer(cfg.optimizer)
    state = steps_lib.train_state_specs(cfg, opt, mesh, rules)
    p_sh = jax.tree.map(lambda s: s.sharding, state["params"])
    step_fn = steps_lib.make_train_step(cfg, opt, mesh, rules,
                                        grad_accum=grad_accum,
                                        param_shardings=p_sh)
    batch = steps_lib.input_specs(cfg, shape, mesh, rules)
    state_sh = jax.tree.map(lambda s: s.sharding, state)
    fn = jax.jit(step_fn, donate_argnums=(0,),
                 out_shardings=(state_sh, None))
    return fn.lower(state, batch), (step_fn, (state, batch))


def _prefill_lowered(cfg, shape, mesh, rules):
    step_fn = steps_lib.make_prefill_step(cfg, mesh, rules)
    params = abstract_params(model_lib.model_specs(cfg), mesh, rules)
    batch = steps_lib.input_specs(cfg, shape, mesh, rules)
    return jax.jit(step_fn).lower(params, batch), (step_fn, (params, batch))


def _decode_lowered(cfg, shape, mesh, rules):
    step_fn = steps_lib.make_decode_step(cfg, mesh, rules)
    params = abstract_params(model_lib.model_specs(cfg), mesh, rules)
    specs = steps_lib.input_specs(cfg, shape, mesh, rules)
    cache_sh = jax.tree.map(lambda s: s.sharding, specs["cache"])
    fn = jax.jit(step_fn, donate_argnums=(1,),
                 out_shardings=(None, cache_sh))
    args = (params, specs["cache"], specs["token"], specs["pos"])
    return fn.lower(*args), (step_fn, args)


def _parse_overrides(pairs):
    """['kv_cache_dtype=int8', 'exact_causal_attn=true'] → kwargs."""
    out = {}
    for p in pairs or ():
        k, _, v = p.partition("=")
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                save_hlo: str | None = None, overrides=None) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = steps_lib.rules_for(shape, cfg)
    # Monotonic clock (perf_counter), like every other timed module —
    # time.time() is wall-clock and can step backwards under NTP.
    t0 = time.perf_counter()
    with mesh:
        if shape.kind == "train":
            lowered, (fn, args) = _train_lowered(cfg, shape, mesh, rules)
        elif shape.kind == "prefill":
            lowered, (fn, args) = _prefill_lowered(cfg, shape, mesh, rules)
        else:
            lowered, (fn, args) = _decode_lowered(cfg, shape, mesh, rules)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        _obs.add("dryrun.lower_s", t_lower, arch=arch, shape=shape_name)
        _obs.add("dryrun.compile_s", t_compile, arch=arch, shape=shape_name)
        # loop-corrected global flops/bytes (cost_analysis counts while
        # bodies once — see launch/flops.py docstring)
        jcost = step_costs(fn, *args)
    info = summarize_cell(compiled)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())
    n_chips = 512 if multi_pod else 256
    model_flops = _model_flops(cfg, shape, n_chips)
    flops_chip = jcost["flops"] / n_chips
    bytes_chip = jcost["hbm_bytes_model"] / n_chips
    info["cost_analysis_raw"] = {"flops": info.pop("flops"),
                                 "hbm_bytes": info.pop("hbm_bytes")}
    info["jaxpr_costs_global"] = jcost
    info["roofline"] = roofline_terms(
        flops_chip, bytes_chip, info["collectives"]["total_bytes"])
    info.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "n_chips": n_chips,
        "flops_per_chip": flops_chip,
        "hbm_bytes_per_chip_model": bytes_chip,
        "model_flops_per_chip": model_flops,
        "useful_flops_ratio": (model_flops / flops_chip
                               if flops_chip else None),
        "param_count": cfg.param_count(),
        "param_count_active": cfg.param_count(active_only=True),
        "peak_hbm_frac": (info["memory_analysis"]["temp_size_in_bytes"]
                          + info["memory_analysis"]["argument_size_in_bytes"])
        / (HW["hbm_bytes"]),
    })
    ga = GRAD_ACCUM["2x16x16" if multi_pod else "16x16"]
    info.update(_analytic_memory(cfg, shape, n_chips, ga))
    return info


def _analytic_memory(cfg, shape, n_chips: int, grad_accum: int) -> dict:
    """TPU-realistic per-chip HBM model (bytes).

    CPU-XLA's ``memory_analysis`` materializes fp32 dot outputs that the
    MXU keeps in registers (verified in the HLO: fp32 copies of bf16
    weight-grad dots / hoisted converts), so it over-states TPU residency.
    This model counts what actually lives in HBM on TPU:
      params + optimizer state + gradient accumulator + one micro-grad
      tree + remat checkpoints + KV/state caches + a transient allowance
      (weight gathers + attention/SSD working set ≈ 2 GB).
    """
    import numpy as np
    P = cfg.param_count()
    psz = jnp.dtype(cfg.param_dtype).itemsize
    params = P * psz / n_chips
    if shape.kind == "train":
        gsz = jnp.dtype(cfg.grad_accum_dtype).itemsize
        opt = (2 * P * 4 if cfg.optimizer == "adamw" else P * 0.05) / n_chips
        grads = 2 * P * gsz / n_chips            # accumulator + micro tree
        batch_shards = max(1, n_chips // 16)      # data (× pod) axes
        tokens_dev = (shape.global_batch // grad_accum * shape.seq_len
                      // batch_shards)
        # per-group carry checkpoints (bf16) over the layer scan
        ckpt = cfg.n_repeats * tokens_dev * cfg.d_model * 2
        cache = 0
    else:
        opt = grads = ckpt = 0
        cache = 0
        if shape.kind == "decode":
            kv_layers = sum(1 for k in cfg.pattern
                            if k.startswith(("attn", "xattn"))) \
                * cfg.n_repeats
            cache = (2 * kv_layers * shape.global_batch * shape.seq_len
                     * cfg.kv_dim * 2) / n_chips
            if "mamba" in "".join(cfg.pattern):
                di = cfg.d_inner
                cache += (cfg.n_layers * shape.global_batch
                          * (cfg.ssm_heads * cfg.ssm_headdim * cfg.d_state
                             + (cfg.d_conv - 1)
                             * (di + 2 * cfg.ssm_groups * cfg.d_state))
                          * 4) / n_chips
    transient = 2e9
    total = params + opt + grads + ckpt + cache + transient
    return {"analytic_hbm_gb": round(total / 1e9, 2),
            "analytic_fits": bool(total <= HW["hbm_bytes"]),
            "analytic_parts_gb": {
                "params": round(params / 1e9, 2),
                "opt": round(opt / 1e9, 2),
                "grads": round(grads / 1e9, 2),
                "ckpt": round(ckpt / 1e9, 2),
                "cache": round(cache / 1e9, 2),
                "transient_allowance": 2.0}}


def _model_flops(cfg, shape, n_chips: int) -> float:
    """6·N_active·D per chip (training); forward-only thirds for serving."""
    n_active = cfg.param_count(active_only=True)
    tokens = shape.global_batch * shape.seq_len
    if cfg.family == "encdec":
        # encoder params see L/2 frames, decoder params L/2 tokens
        tokens //= 2
    if shape.kind == "train":
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens / n_chips
    # decode: one token per sequence (matmul flops only; attention reads
    # the KV cache — that cost shows up in the memory term, not FLOPs)
    return 2.0 * n_active * shape.global_batch / n_chips


def iter_cells():
    for arch in ARCHS:
        for shape_name in SHAPES:
            yield arch, shape_name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--override", action="append", default=None,
                    help="cfg field override, e.g. kv_cache_dtype=int8 "
                         "(repeatable); result tagged with --variant")
    ap.add_argument("--variant", default=None,
                    help="suffix for the output JSON of an override run")
    args = ap.parse_args()

    overrides = _parse_overrides(args.override)
    os.makedirs(args.out, exist_ok=True)
    cells = (list(iter_cells()) if args.all
             else [(args.arch, args.shape)])
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{'2x16x16' if args.multi_pod else '16x16'}"
        if args.variant:
            tag += f"__{args.variant}"
        out_path = os.path.join(args.out, tag + ".json")
        try:
            info = dryrun_cell(arch, shape_name, multi_pod=args.multi_pod,
                               save_hlo=args.save_hlo, overrides=overrides)
        except Exception:
            info = {"arch": arch, "shape": shape_name,
                    "mesh": "2x16x16" if args.multi_pod else "16x16",
                    "status": "error", "trace": traceback.format_exc()}
        with open(out_path, "w") as f:
            json.dump(info, f, indent=1, default=str)
        status = info["status"]
        extra = ""
        if status == "ok":
            r = info["roofline"]
            extra = (f" dom={r['dominant']} bound={r['bound_s']*1e3:.2f}ms"
                     f" compile={info['compile_s']}s")
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
        if status == "error":
            print(info["trace"], flush=True)


if __name__ == "__main__":
    main()
