"""Distributed spMTTKRP: owner-computes + dynamic remapping via shard_map.

This is Alg. 2 (Dynasor) on a JAX device mesh:

  * the ``workers`` mesh axis plays the CPU-thread role; every device owns
    the output rows of the super-shards LPT-assigned to it (baked into the
    FLYCOO row permutation, see ``core.flycoo``);
  * the per-device mode step is gather → Hadamard → segment-scatter
    (``ref``/``segsum`` backends) or the Pallas blocked kernel
    (``pallas`` materialized / ``pallas_fused`` N-mode fused /
    ``pallas_fused_tiled`` rank-slabbed / ``pallas_fused_gather`` and
    its tiled composition, which gather the factor rows *inside* the
    kernel / ``pallas_fused_gather_stream``, the out-of-core variant
    that keeps the factors HBM-resident behind a streamed VMEM tile
    window / the bf16-gather variants / ``auto`` dispatch — decision
    matrix in ``docs/kernels.md``);
  * **owner-computes means the output factor needs no psum** — only an
    all_gather to re-replicate it for later modes (on CPU this was "write
    once to shared DRAM");
  * while mode ``n`` computes, the tensor is re-bucketed for mode ``n+1``
    with a capacity-padded all_to_all (``core.remap``) — the dynamic memory
    layout that keeps storage at ``2·|T|``.

Also implemented, as the paper's comparison targets:

  * :func:`make_spmttkrp_all_modes` with ``remap=False`` — Fig. 9 "Case 2":
    tensor stays in mode-0 order; non-owner modes must produce dense
    partial outputs and all-reduce them;
  * :func:`make_baseline_all_modes` — ALTO/HiCOO-style nonzero-parallel
    execution: every mode all-reduces a dense ``(I_n, R)`` partial — the
    intermediate-value traffic Dynasor eliminates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map as _shard_map
from . import remap as remap_lib
from .flycoo import FlycooTensor, pack_mode
from ..kernels.mttkrp import ops as kops
from ..obs import counters as _obs
from ..resilience import faults as _faults

__all__ = [
    "AXIS",
    "DynasorRuntime",
    "ModePlan",
    "prepare_runtime",
    "init_factors",
    "make_spmttkrp_all_modes",
    "make_baseline_all_modes",
    "unpermute_factor",
]

AXIS = "workers"


class ModePlan(NamedTuple):
    """Tuned per-mode kernel configuration (from ``repro.tune``)."""

    backend: str                # segsum | ref | any kernels.mttkrp backend
    blk: int                    # Pallas nonzero block for this mode
    tile_rows: int              # Pallas output row tile for this mode
    # Rank slabs the fused kernel iterates for this mode: padded_rank /
    # RANK_SLAB when backend is one of the rank-slabbed kernels
    # (pallas_fused_tiled / pallas_fused_gather_tiled /
    # pallas_fused_gather_stream, which always slabs), else 1 (the
    # whole padded rank is one resident slab). Pure metadata for traffic
    # accounting / benches — the kernel derives its own grid from shapes.
    rank_slabs: int = 1
    # Out-of-core stream-window widths per *input* mode (the
    # repro.oocore planner's FACTOR_ROW_TILE-tile counts) when backend
    # is pallas_fused_gather_stream, else (). Metadata like rank_slabs:
    # the kernel derives its real windows from the factor shapes.
    window_tiles: tuple = ()
    # repro.reorder.ORDERINGS locality policy the mode step applies
    # in-jit (build_block_layout order_keys). Unlike rank_slabs /
    # window_tiles this is *not* metadata — it changes the aligned
    # stream the kernel sees.
    ordering: str = "none"


@dataclasses.dataclass(frozen=True)
class DynasorRuntime:
    """Static metadata threaded through the jitted distributed functions."""

    num_workers: int
    nmodes: int
    rank: int
    rows_cap: tuple[int, ...]   # owned output rows per worker, per mode
    i_pad: tuple[int, ...]      # num_workers * rows_cap, per mode
    nnz_cap: int                # per-worker nonzero capacity
    bucket_cap: int             # all_to_all per-(src,dst) capacity (max)
    shape: tuple[int, ...]      # natural tensor shape
    blk: int = 512              # Pallas nonzero block (FLYCOO shard g)
    tile_rows: int = 128        # Pallas output row tile
    # Per-transition all_to_all capacities (remap_capacities order: entry n
    # bounds the mode n -> n+1 exchange). None = uniform bucket_cap for
    # every transition (the pre-tuning behavior / `uniform_cap` hatch).
    bucket_caps: tuple[int, ...] | None = None
    # Tuned (backend, blk, tile_rows) per mode from a calibration table.
    # None = untuned: every mode uses (blk, tile_rows) above and the
    # caller's backend string.
    mode_plans: tuple[ModePlan, ...] | None = None
    # Dtype the fused kernels gather factor rows in ("float32" |
    # "bfloat16"). bf16 halves gather-operand VMEM/HBM traffic and
    # accumulates at fp32 (≈(N−1)·2⁻⁸ rel. error); it is threaded here — never
    # chosen by ``auto`` — so the whole decomposition opts in explicitly.
    gather_dtype: str = "float32"
    # repro.reorder.ORDERINGS locality policy threaded to every mode
    # step (untuned runtimes; tuned runtimes carry it per ModePlan).
    ordering: str = "none"

    def __post_init__(self):
        # Validate at construction: non-fused mode steps never read this,
        # so a typo ("bf16") would otherwise run fp32 silently or raise
        # mid-decomposition only once a fused backend is reached.
        if self.gather_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"unknown gather_dtype {self.gather_dtype!r}: expected "
                "'float32' or 'bfloat16'")
        from ..reorder import validate_ordering  # deferred: reorder→kernels
        validate_ordering(self.ordering)

    @property
    def payload_width(self) -> int:
        return self.nmodes + 1  # coords + value

    def bucket_cap_for(self, from_mode: int) -> int:
        """Exchange capacity of the ``from_mode -> from_mode+1`` remap."""
        if self.bucket_caps is None:
            return self.bucket_cap
        return self.bucket_caps[from_mode]

    def plan_for(self, mode: int, backend: str = "auto") -> ModePlan:
        """Resolve the kernel configuration for ``mode``.

        Tuned runtimes always use the plan's (blk, tile_rows) — rows_cap
        was rounded to the plan's tile — and substitute the plan's
        backend only when the caller asked for ``auto``.
        ``rank_slabs`` and the out-of-core ``window_tiles`` are
        re-derived from the *resolved* backend so an explicit override
        never carries stale residency metadata (and an explicit tiled
        or streaming backend on an untuned runtime gets the real slab /
        window counts — the runtime knows every mode's ``i_pad``); for
        an unresolved ``auto`` they stay trivial — only the ops-level
        dispatch knows what auto becomes.
        """
        if self.mode_plans is not None:
            p = self.mode_plans[mode]
            if backend != "auto":
                p = p._replace(backend=backend)
        else:
            p = ModePlan(backend, self.blk, self.tile_rows,
                         ordering=self.ordering)
        slabs = 1
        if p.backend in ("pallas_fused_tiled", "pallas_fused_gather_tiled",
                         kops.STREAM_BACKEND):
            slabs = kops.padded_rank(self.rank) // kops.MXU_RANK_MULTIPLE
        window = ()
        if p.backend == kops.STREAM_BACKEND:
            from ..oocore.planner import stream_window_tiles
            window = tuple(stream_window_tiles(p.blk, self.i_pad[w])
                           for w in range(self.nmodes) if w != mode)
        return p._replace(rank_slabs=slabs, window_tiles=window)


def prepare_runtime(
    ft: FlycooTensor, rank: int, *, blk: int | None = None,
    tile_rows: int = 8, uniform_cap: bool = False, table=None,
    gather_dtype: str = "float32", ordering: str | None = None,
) -> tuple[DynasorRuntime, tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Build runtime metadata + the initial mode-0 packed layout (H_0).

    Args:
      uniform_cap: escape hatch — size every remap exchange to the max
        transition capacity (the pre-tuning behavior) instead of each
        transition's own ``remap_capacities`` bound.
      table: optional calibration table / cost model from ``repro.tune``;
        when given, each mode gets a tuned ``(backend, blk, tile_rows)``
        plan (``rows_cap`` rounds to the tuned tile) and ``backend="auto"``
        callers follow it. ``None`` keeps the static configuration.
      gather_dtype: ``"float32"`` (default) or ``"bfloat16"`` — threaded
        to every fused-kernel mode step (see ``DynasorRuntime``).
      ordering: :data:`repro.reorder.ORDERINGS` locality policy threaded
        to every mode step (in-jit re-ranking — the order survives the
        dynamic remapping between modes). ``None`` (default) inherits
        ``ft.ordering``, so a tensor built with
        ``build_flycoo(..., ordering=...)`` keeps its policy end to end.
    """
    ordering = ft.ordering if ordering is None else ordering
    D = ft.params.num_workers
    plans = None
    if table is not None:
        from ..tune.model import plan_modes  # deferred: tune imports core
        plans = plan_modes(table, ft, rank, ordering=ordering)
    tiles = (
        tuple(p.tile_rows for p in plans) if plans is not None
        else (tile_rows,) * ft.nmodes
    )
    rows_cap = tuple(
        int(-(-mp.rows_cap // t) * t)                        # round to tile
        for mp, t in zip(ft.modes, tiles)
    )
    i_pad = tuple(D * rc for rc in rows_cap)
    blk = int(blk if blk is not None else min(ft.params.g, 512))
    caps = remap_lib.remap_capacities(ft)
    # Count the per-transition all_to_all allocation here, once per
    # runtime build — every driver (CP-ALS, benches, serving) that
    # constructs a runtime gets its collective traffic into the obs
    # registry without bench-side re-derivation.
    _obs.record_remap_exchange(caps, D, ft.nmodes, uniform_cap=uniform_cap)
    rt = DynasorRuntime(
        num_workers=D, nmodes=ft.nmodes, rank=rank, rows_cap=rows_cap,
        i_pad=i_pad, nnz_cap=ft.nnz_cap,
        bucket_cap=max(caps), shape=ft.tensor.shape,
        blk=blk, tile_rows=tile_rows,
        bucket_caps=None if uniform_cap else tuple(caps),
        mode_plans=plans, gather_dtype=gather_dtype, ordering=ordering,
    )
    # pack_mode used flycoo rows_cap; re-pad indices to tile-rounded layout.
    idx, val, mask = pack_mode(ft, 0)
    idx = _repad_indices(ft, idx, rows_cap)
    return rt, (idx, val, mask)


def _repad_indices(ft: FlycooTensor, idx: np.ndarray,
                   rows_cap: Sequence[int]) -> np.ndarray:
    """Map device-major slots from flycoo rows_cap to tile-rounded rows_cap."""
    out = idx.copy()
    for n, mp in enumerate(ft.modes):
        old, new = mp.rows_cap, rows_cap[n]
        if old == new:
            continue
        dev = idx[..., n] // old
        out[..., n] = dev * new + idx[..., n] % old
    return out


def permuted_factor_init(ft: FlycooTensor, mode: int, rank: int,
                         rows_cap: int, seed: int) -> np.ndarray:
    """Random factor in permuted row space; padding rows exactly zero."""
    rng = np.random.default_rng(seed * 1000 + mode)
    D = ft.params.num_workers
    nat = rng.standard_normal((ft.tensor.shape[mode], rank)).astype(np.float32)
    out = np.zeros((D * rows_cap, rank), np.float32)
    mp = ft.modes[mode]
    # natural row r lives at permuted slot row_perm[r] (re-padded to rows_cap)
    slot = (mp.row_perm // mp.rows_cap) * rows_cap + mp.row_perm % mp.rows_cap
    out[slot] = nat
    return out


def init_factors(ft: FlycooTensor, rt: DynasorRuntime, seed: int = 0):
    return [
        permuted_factor_init(ft, n, rt.rank, rt.rows_cap[n], seed)
        for n in range(rt.nmodes)
    ]


def unpermute_factor(ft: FlycooTensor, rt: DynasorRuntime, mode: int,
                     factor: np.ndarray) -> np.ndarray:
    """Permuted (i_pad, R) → natural (I_n, R)."""
    mp = ft.modes[mode]
    slot = (mp.row_perm // mp.rows_cap) * rt.rows_cap[mode] \
        + mp.row_perm % mp.rows_cap
    return np.asarray(factor)[slot]


# ---------------------------------------------------------------------------
# shard_map-inner primitives
# ---------------------------------------------------------------------------

def _pack_payload(idx, val):
    bits = jax.lax.bitcast_convert_type(idx.astype(jnp.int32), jnp.float32)
    return jnp.concatenate([bits, val[:, None].astype(jnp.float32)], axis=1)


def _unpack_payload(payload, nmodes):
    idx = jax.lax.bitcast_convert_type(payload[:, :nmodes], jnp.int32)
    return idx, payload[:, nmodes]


def device_mttkrp(idx, val, mask, factors, mode: int, rt: DynasorRuntime,
                  backend: str):
    """Owner-computes local MTTKRP for ``mode`` → (rows_cap, R) f32.

    A tuned runtime (``rt.mode_plans``) supplies this mode's
    ``(backend, blk, tile_rows)``; the plan's backend applies when the
    caller passes ``auto``, and may be ``segsum``.
    """
    if backend != "segsum" and backend != "auto" \
            and backend not in kops.BACKENDS:
        raise ValueError(
            f"unknown MTTKRP backend {backend!r}: expected 'segsum', "
            f"'auto' or one of {kops.BACKENDS}")
    plan = rt.plan_for(mode, backend)
    backend = plan.backend
    dev = jax.lax.axis_index(AXIS)
    rows_cap = rt.rows_cap[mode]
    if backend != "segsum":
        # interpret/compiled comes from the repro.runtime.execution
        # policy (the default), never a per-call hardcode.
        return kops.mttkrp_device_step(
            idx, val, mask, factors, mode=mode, rows_cap=rows_cap,
            row_offset=dev * rows_cap, blk=plan.blk,
            tile_rows=plan.tile_rows, backend=backend,
            gather_dtype=rt.gather_dtype, ordering=plan.ordering,
        )
    # segsum: plain XLA segment-sum path (dry-run / TPU-lowerable default).
    local_row = jnp.where(mask, idx[:, mode] - dev * rows_cap, 0)
    ell = jnp.where(mask, val, 0.0)[:, None].astype(factors[0].dtype)
    for w in range(rt.nmodes):
        if w != mode:
            ell = ell * jnp.take(factors[w], idx[:, w], axis=0)
    return jax.ops.segment_sum(
        ell.astype(jnp.float32), local_row, num_segments=rows_cap,
        indices_are_sorted=True,
    )


def device_remap(idx, val, mask, next_mode: int, rt: DynasorRuntime):
    """Dynamic tensor remapping: re-bucket owned nonzeros for ``next_mode``.

    The exchange is sized to *this transition's* capacity
    (``rt.bucket_cap_for``) — each all_to_all allocates only the padding
    its own (src, dst) bound requires, not the global max.

    Returns ``(idx', val', mask', dropped)`` — the new owner-sorted layout.
    """
    # Registered failure boundary (repro.resilience): the all_to_all is
    # the one collective of the sweep — an interconnect hiccup lands
    # here. Fires at trace time under jit; the stepped driver retries
    # the whole remap call.
    _faults.fault_site("distributed.remap")
    D = rt.num_workers
    cap = rt.bucket_cap_for((next_mode - 1) % rt.nmodes)
    dest = jnp.where(
        mask, (idx[:, next_mode] // rt.rows_cap[next_mode]).astype(jnp.int32), D
    )
    payload = _pack_payload(idx, val)
    buckets, bmask, dropped = remap_lib.bucket_by_destination(
        dest, payload, D, cap
    )
    recv, recv_mask = remap_lib.exchange(buckets, bmask, AXIS)
    flat = recv.reshape(D * cap, rt.payload_width)
    fmask = recv_mask.reshape(D * cap)
    ridx, _ = _unpack_payload(flat, rt.nmodes)
    key = ridx[:, next_mode]  # permuted slot == sort by local row
    out, omask = remap_lib.compact_sorted(flat, fmask, key, rt.nnz_cap)
    oidx, oval = _unpack_payload(out, rt.nmodes)
    oval = jnp.where(omask, oval, 0.0)
    # Padding entries point at row 0 (in-bounds gather, zero value: harmless).
    oidx = jnp.where(omask[:, None], oidx, 0)
    return oidx, oval, omask, dropped


def _dense_partial_mttkrp(idx, val, mask, factors, mode: int,
                          rt: DynasorRuntime):
    """Non-owner path: dense (i_pad, R) partial + all-reduce (baseline)."""
    ell = jnp.where(mask, val, 0.0)[:, None].astype(factors[0].dtype)
    for w in range(rt.nmodes):
        if w != mode:
            ell = ell * jnp.take(factors[w], idx[:, w], axis=0)
    partial = jax.ops.segment_sum(
        ell.astype(jnp.float32), jnp.where(mask, idx[:, mode], 0),
        num_segments=rt.i_pad[mode],
    )
    return jax.lax.psum(partial, AXIS)


# ---------------------------------------------------------------------------
# Top-level jitted builders
# ---------------------------------------------------------------------------

def make_spmttkrp_all_modes(
    rt: DynasorRuntime, mesh: Mesh, *, backend: str = "segsum",
    remap: bool = True,
) -> Callable:
    """spMTTKRP along all modes (the paper's headline benchmark op).

    Returns a jitted fn ``(idx, val, mask, factors) ->
    (mttkrp_outs, (idx', val', mask'), diagnostics)`` where ``mttkrp_outs``
    is a list of replicated ``(i_pad_n, R)`` MTTKRP results (pre-solve) and
    the primed tensors are the remapped layout (back at mode 0 after a full
    cycle).

    ``remap=False`` is Fig. 9 "Case 2": the layout stays in mode-0 order; for
    modes ≥ 1 each device computes a dense partial over *all* rows and
    all-reduces it (the intermediate-value traffic Dynasor avoids).
    """

    def inner(idx, val, mask, *factors):
        # shard_map blocks keep a leading (1, ...) device axis — drop it.
        idx, val, mask = idx[0], val[0], mask[0]
        factors = list(factors)
        outs = []
        diags = {"dropped": jnp.zeros((), jnp.int32)}
        for n in range(rt.nmodes):
            owner_ok = remap or n == 0
            if owner_ok:
                local = device_mttkrp(idx, val, mask, factors, n, rt, backend)
                full = jax.lax.all_gather(local, AXIS, axis=0, tiled=True)
            else:
                full = _dense_partial_mttkrp(idx, val, mask, factors, n, rt)
            outs.append(full)
            if remap:
                nxt = (n + 1) % rt.nmodes
                idx, val, mask, dropped = device_remap(idx, val, mask, nxt, rt)
                diags["dropped"] = diags["dropped"] + dropped.astype(jnp.int32)
        return outs, (idx[None], val[None], mask[None]), diags

    spec_t = P(AXIS)
    spec_r = P()
    shmapped = _shard_map(
        inner, mesh=mesh,
        in_specs=(spec_t, spec_t, spec_t) + (spec_r,) * rt.nmodes,
        out_specs=([spec_r] * rt.nmodes, (spec_t, spec_t, spec_t),
                   {"dropped": spec_r}),
    )
    return jax.jit(shmapped)


def make_baseline_all_modes(rt: DynasorRuntime, mesh: Mesh) -> Callable:
    """ALTO/HiCOO-style nonzero-parallel baseline.

    Tensor split evenly by nonzero count (no ownership structure); every
    mode produces a dense ``(i_pad_n, R)`` partial per device and all-reduces
    it. Same outputs as Dynasor; different (much larger) collective traffic.
    """

    def inner(idx, val, mask, *factors):
        idx, val, mask = idx[0], val[0], mask[0]
        factors = list(factors)
        outs = [
            _dense_partial_mttkrp(idx, val, mask, factors, n, rt)
            for n in range(rt.nmodes)
        ]
        return outs

    spec_t = P(AXIS)
    spec_r = P()
    shmapped = _shard_map(
        inner, mesh=mesh,
        in_specs=(spec_t, spec_t, spec_t) + (spec_r,) * rt.nmodes,
        out_specs=[spec_r] * rt.nmodes,
    )
    return jax.jit(shmapped)


def even_split_pack(ft: FlycooTensor, rt: DynasorRuntime):
    """Nonzero-parallel layout for the baseline: even chunks, natural order.

    Indices are still in permuted row space so baseline outputs are directly
    comparable with Dynasor outputs.
    """
    D = rt.num_workers
    nnz = ft.nnz
    cap = -(-nnz // D)
    idx = np.zeros((D, cap, ft.nmodes), np.int32)
    val = np.zeros((D, cap), np.float32)
    mask = np.zeros((D, cap), bool)
    perm_idx = _repad_indices(ft, ft.perm_indices.astype(np.int32), rt.rows_cap)
    for d in range(D):
        lo, hi = d * cap, min(nnz, (d + 1) * cap)
        k = hi - lo
        if k <= 0:
            continue
        idx[d, :k] = perm_idx[lo:hi]
        val[d, :k] = ft.tensor.values[lo:hi]
        mask[d, :k] = True
    return idx, val, mask
