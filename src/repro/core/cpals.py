"""CP-ALS (paper Alg. 1) on top of the Dynasor spMTTKRP engines.

Two drivers, one algorithm:

* :func:`cp_als` — single-device JAX reference (segment-sum MTTKRP). The
  correctness oracle and the laptop-scale path.
* :func:`cp_als_distributed` — the production path: owner-computes Dynasor
  MTTKRP under ``shard_map`` with dynamic tensor remapping between modes.
  Factors live in FLYCOO-permuted row space for the whole decomposition
  (grams, column norms and the fit are permutation-invariant) and are
  un-permuted once at the end.

Fit = 1 - ||X - X̂||_F / ||X||_F, computed with the standard sparse-CP
identity (SPLATT):  ||X̂||² = 1λᵀ(⊛_w Gramᵂ)λ1   and
<X, X̂> = Σ_r λ_r Σ_i M_last[i,r]·A_last[i,r]  where ``M_last`` is the final
mode's (pre-solve) MTTKRP output — no dense reconstruction ever happens.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..compat import shard_map as _shard_map
from ..obs import counters as _obs
from ..obs import tracer as _tracer
from ..resilience import checkpoint as _ckpt
from ..resilience import numerics as _numerics
from ..resilience import policy as _rpolicy
from . import distributed as dist
from .flycoo import FlycooTensor
from .mttkrp import mttkrp as mttkrp_jax

__all__ = ["CPResult", "cp_als", "cp_als_distributed", "fit_from_parts",
           "make_instrumented_mode_fns"]


@dataclasses.dataclass
class CPResult:
    """Decomposition [[λ; A_0 … A_{N-1}]] + convergence trace."""

    factors: list[np.ndarray]   # natural row space, (I_n, R) each
    lam: np.ndarray             # (R,) column weights
    fits: list[float]           # fit after each ALS sweep
    iters: int

    @property
    def fit(self) -> float:
        return self.fits[-1] if self.fits else float("nan")


def _normalize_columns(A, sweep0: bool):
    """Column-normalize; first sweep uses 2-norm, later sweeps max-norm
    (standard CP-ALS practice — keeps λ from oscillating)."""
    if sweep0:
        norms = jnp.linalg.norm(A, axis=0)
    else:
        norms = jnp.maximum(jnp.max(jnp.abs(A), axis=0), 1.0)
    norms = jnp.where(norms == 0, 1.0, norms)
    return A / norms, norms


def _solve_v_guarded(grams, mode: int, M, ridge: float = 1e-9):
    """A_n ← M_n · V⁺ with V = ⊛_{w≠n} G_w — guarded; returns (A, level).

    The solve runs through :func:`repro.resilience.numerics.guarded_solve`
    (non-finite/ill-conditioned gram → escalated ridge → lstsq); ``level``
    indexes ``GUARD_LEVELS`` so host-side drivers can count escalations
    (``resilience.solve.guards``). Level 0 is bit-identical to the
    historical plain ``solve(V + ridge·I)``.
    """
    R = M.shape[1]
    V = jnp.ones((R, R), M.dtype)
    for w, G in enumerate(grams):
        if w != mode:
            V = V * G
    return _numerics.guarded_solve(V, M, ridge=ridge)


def _solve_v(grams, mode: int, M, ridge: float = 1e-9):
    """A_n ← M_n · V⁺ with V = ⊛_{w≠n} G_w (Hadamard of grams)."""
    X, _level = _solve_v_guarded(grams, mode, M, ridge=ridge)
    return X


def fit_from_parts(x_norm_sq, lam, grams, M_last, A_last):
    """Sparse-CP fit from the identity above (no reconstruction)."""
    R = lam.shape[0]
    G = jnp.ones((R, R), M_last.dtype)
    for g in grams:
        G = G * g
    model_norm_sq = jnp.einsum("r,rs,s->", lam, G, lam)
    inner = jnp.einsum("ir,ir,r->", M_last, A_last, lam)
    resid_sq = jnp.maximum(x_norm_sq - 2.0 * inner + model_norm_sq, 0.0)
    return 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(x_norm_sq)


# ---------------------------------------------------------------------------
# Single-device reference driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("shape", "sweep0"))
def _sweep_jax(indices, values, factors, lam, shape: tuple[int, ...],
               sweep0: bool):
    factors = list(factors)
    grams = [f.T @ f for f in factors]
    M = None
    for n in range(len(shape)):
        M = mttkrp_jax(indices, values, factors, n, shape[n])
        A = _solve_v(grams, n, M)
        A, norms = _normalize_columns(A, sweep0)
        factors[n] = A
        grams[n] = A.T @ A
        lam = norms
    x_norm_sq = jnp.sum(values.astype(jnp.float32) ** 2)
    fit = fit_from_parts(x_norm_sq, lam, grams, M, factors[-1])
    return factors, lam, fit


def cp_als(tensor, rank: int, *, iters: int = 10, seed: int = 0,
           tol: float = 1e-5, tracer=None,
           checkpoint_dir: str | None = None,
           checkpoint_every: int = 1) -> CPResult:
    """Single-device CP-ALS (paper Alg. 1) — the correctness oracle.

    ``tracer`` (default: the process tracer, normally the no-op) records
    one ``sweep`` span per ALS sweep; the whole sweep is a single jitted
    call here, so there is no per-mode breakdown — use
    :func:`cp_als_distributed` for the full span taxonomy.

    ``checkpoint_dir`` turns on resumable sweeps: every
    ``checkpoint_every``-th completed sweep is persisted atomically
    (factors, λ, fit trace, sweep index) through the
    ``repro.checkpoint`` manager, and a rerun pointed at the same
    directory restores the newest complete checkpoint and continues —
    a killed job resumes warm instead of restarting, converging to the
    same decomposition (pinned by ``tests/test_resilience.py``).
    """
    tracer = _tracer.get_tracer() if tracer is None else tracer
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in tensor.shape]
    lam = jnp.ones((rank,), jnp.float32)
    idx = jnp.asarray(tensor.indices, jnp.int32)
    val = jnp.asarray(tensor.values, jnp.float32)
    fits: list[float] = []
    start_it = 0
    mgr = _ckpt.make_manager(checkpoint_dir)
    if mgr is not None:
        template = _ckpt.make_state(
            [np.asarray(f) for f in factors], np.asarray(lam), fits,
            sweep=0, rank=rank, backend="jax")
        state, _step = _ckpt.restore_state(mgr, template)
        if state is not None:
            factors = [jnp.asarray(f) for f in state["factors"]]
            lam = jnp.asarray(state["lam"])
            fits = [float(x) for x in state["fits"]]
            start_it = int(state["sweep"]) + 1
    for it in range(start_it, iters):
        t0 = time.perf_counter()
        with tracer.span("sweep", sweep=it, driver="single"):
            factors, lam, fit = _sweep_jax(idx, val, tuple(factors), lam,
                                           tuple(tensor.shape), it == 0)
            fit = float(fit)   # blocks: the sweep is fully resolved here
        _obs.add("cpals.sweep_s", time.perf_counter() - t0, driver="single")
        _obs.add("cpals.sweeps", driver="single")
        fits.append(fit)
        if mgr is not None and (it + 1) % checkpoint_every == 0:
            _ckpt.save_state(mgr, _ckpt.make_state(
                [np.asarray(f) for f in factors], np.asarray(lam), fits,
                sweep=it, rank=rank, backend="jax"))
        if it > 0 and abs(fits[-1] - fits[-2]) < tol:
            break
    return CPResult([np.asarray(f) for f in factors], np.asarray(lam),
                    fits, len(fits))


# ---------------------------------------------------------------------------
# Distributed Dynasor driver
# ---------------------------------------------------------------------------

def make_als_sweep(rt: dist.DynasorRuntime, mesh: Mesh, *,
                   backend: str = "segsum") -> Callable:
    """One full distributed ALS sweep (all modes, with dynamic remapping).

    ``backend`` is the per-device MTTKRP engine: ``segsum`` (plain XLA),
    ``ref``, ``pallas`` (materialized contrib), ``pallas_fused`` (N-mode
    fused gather–Hadamard–scatter — works for any tensor order), or
    ``auto`` (dispatch on mode count / rank padding / VMEM budget; see
    ``kernels.mttkrp.ops.select_backend``).

    Returned jitted fn:
      ``(idx, val, mask, factors, lam, sweep0) ->
        (idx', val', mask', factors', lam', fit_parts)``
    Factors are replicated ``(i_pad_n, R)`` arrays in permuted row space.
    The MTTKRP → solve → normalize → remap chain per mode follows Alg. 1/2;
    the solve happens on owned rows only (owner-computes extends to the
    least-squares update), then an all_gather re-replicates the factor.
    """

    def inner(idx, val, mask, x_norm_sq, *factors_lam):
        idx, val, mask = idx[0], val[0], mask[0]
        x_norm_sq = x_norm_sq[0]
        *factors, lam, sweep0 = factors_lam
        factors = list(factors)
        grams = [f.T @ f for f in factors]   # padding rows are 0 → exact
        M_last_local = A_last_local = None
        for n in range(rt.nmodes):
            local_M = dist.device_mttkrp(idx, val, mask, factors, n, rt,
                                         backend)
            A_local = _solve_v(grams, n, local_M)
            # Column norms need the full matrix: psum of local sums.
            sq = jax.lax.psum(jnp.sum(A_local ** 2, axis=0), dist.AXIS)
            mx = jax.lax.pmax(jnp.max(jnp.abs(A_local), axis=0), dist.AXIS)
            norms = jnp.where(sweep0, jnp.sqrt(sq), jnp.maximum(mx, 1.0))
            norms = jnp.where(norms == 0, 1.0, norms)
            A_local = A_local / norms
            lam = norms
            full = jax.lax.all_gather(A_local, dist.AXIS, axis=0, tiled=True)
            factors[n] = full
            grams[n] = full.T @ full
            if n == rt.nmodes - 1:
                M_last_local, A_last_local = local_M, A_local
            idx, val, mask, _ = dist.device_remap(
                idx, val, mask, (n + 1) % rt.nmodes, rt)
        # fit parts: <X, X̂> = Σ_r λ_r Σ_i M[i,r]·Â[i,r], owned rows psummed.
        inner_term = jax.lax.psum(
            jnp.einsum("ir,ir,r->", M_last_local, A_last_local, lam),
            dist.AXIS)
        R = lam.shape[0]
        G = jnp.ones((R, R), jnp.float32)
        for g in grams:
            G = G * g
        model_norm_sq = jnp.einsum("r,rs,s->", lam, G, lam)
        resid_sq = jnp.maximum(x_norm_sq - 2.0 * inner_term + model_norm_sq,
                               0.0)
        fit = 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(x_norm_sq)
        return ((idx[None], val[None], mask[None]),
                factors, lam, fit)

    from jax.sharding import PartitionSpec as P
    spec_t, spec_r = P(dist.AXIS), P()
    shmapped = _shard_map(
        inner, mesh=mesh,
        in_specs=(spec_t, spec_t, spec_t, spec_t)
        + (spec_r,) * (rt.nmodes + 2),
        out_specs=((spec_t, spec_t, spec_t), [spec_r] * rt.nmodes, spec_r,
                   spec_r),
    )
    return jax.jit(shmapped)


def make_instrumented_mode_fns(rt: dist.DynasorRuntime, mesh: Mesh, *,
                               backend: str = "segsum"):
    """Per-mode jitted pieces for the *instrumented* stepped driver.

    The production sweep (:func:`make_als_sweep`) is one jitted
    ``shard_map`` call over all modes — nothing inside it can carry a
    span boundary. When a tracer is enabled the driver instead steps
    through per-mode jitted pieces so mttkrp/solve/remap get real
    wall-time spans:

      * ``mttkrp_fns[n](idx, val, mask, *factors)`` → the **full**
        pre-solve MTTKRP ``(i_pad_n, R)``: each worker computes its
        owned rows exactly as in the fused sweep and ``out_specs=
        P(AXIS)`` concatenates them (owner-computes rows are contiguous
        per worker, so the concatenation *is* the factor row space);
      * ``remap_fns[n](idx, val, mask)`` → the ``n → n+1`` dynamic
        remap, same per-transition capacities as the fused sweep.

    The solve/normalize happens host-side on the full matrices — row-wise
    identical to the fused sweep's owned-rows solve (``_solve_v`` acts
    per row; a psum of local column sums equals the global sum) — so the
    stepped driver converges like the production one while every phase
    is observable. Counted metrics (dispatch, planner, remap bytes) are
    identical by construction: the same ``device_mttkrp`` path traces
    once per mode either way.
    """
    from jax.sharding import PartitionSpec as P
    spec_t, spec_r = P(dist.AXIS), P()
    mttkrp_fns, remap_fns = [], []
    for n in range(rt.nmodes):
        def mttkrp_inner(idx, val, mask, *factors, _n=n):
            idx, val, mask = idx[0], val[0], mask[0]
            return dist.device_mttkrp(idx, val, mask, list(factors), _n,
                                      rt, backend)
        mttkrp_fns.append(jax.jit(_shard_map(
            mttkrp_inner, mesh=mesh,
            in_specs=(spec_t, spec_t, spec_t) + (spec_r,) * rt.nmodes,
            out_specs=spec_t)))

        def remap_inner(idx, val, mask, _n=n):
            idx, val, mask = idx[0], val[0], mask[0]
            idx, val, mask, _ = dist.device_remap(
                idx, val, mask, (_n + 1) % rt.nmodes, rt)
            return idx[None], val[None], mask[None]
        remap_fns.append(jax.jit(_shard_map(
            remap_inner, mesh=mesh,
            in_specs=(spec_t, spec_t, spec_t),
            out_specs=(spec_t, spec_t, spec_t))))
    return mttkrp_fns, remap_fns


def _ckpt_state(rt, backend, factors, lam, fits, sweep, idx, val, mask):
    """Assemble one distributed-sweep checkpoint (stream included)."""
    return _ckpt.make_state(
        [np.asarray(f) for f in factors], np.asarray(lam), fits,
        sweep=sweep, rank=rt.rank, ordering=rt.ordering, backend=backend,
        stream=(np.asarray(idx), np.asarray(val), np.asarray(mask)))


def _cp_als_distributed_traced(ft, rank, mesh, rt, idx, val, mask, *,
                               iters, seed, tol, backend, tracer,
                               mgr=None, checkpoint_every: int = 1
                               ) -> CPResult:
    """Stepped Dynasor CP-ALS under an enabled tracer or resilience policy.

    Per-mode jitted pieces (see :func:`make_instrumented_mode_fns`) give
    every phase a real host-side call boundary — which is also what the
    resilience layer needs: an active :func:`repro.resilience.use_policy`
    scope makes the kernel dispatch walk the degradation ladder at trace
    time, the remap call here gets host-level bounded retry, and every
    solve escalation is counted. Checkpoints (``mgr``) persist the
    factors *and* the remapped nonzero stream, so a resumed job
    continues from the exact post-sweep state.
    """
    factors = [jnp.asarray(f) for f in dist.init_factors(ft, rt, seed=seed)]
    lam = jnp.ones((rank,), jnp.float32)
    mttkrp_fns, remap_fns = make_instrumented_mode_fns(rt, mesh,
                                                       backend=backend)
    x_norm_sq = jnp.float32(np.sum(ft.tensor.values.astype(np.float64) ** 2))
    fits: list[float] = []
    start_it = 0
    if mgr is not None:
        state, _step = _ckpt.restore_state(
            mgr, _ckpt_state(rt, backend, factors, lam, fits, 0,
                             idx, val, mask))
        if state is not None:
            factors = [jnp.asarray(f) for f in state["factors"]]
            lam = jnp.asarray(state["lam"])
            fits = [float(x) for x in state["fits"]]
            idx = jnp.asarray(state["stream_idx"])
            val = jnp.asarray(state["stream_val"])
            mask = jnp.asarray(state["stream_mask"])
            start_it = int(state["sweep"]) + 1
    pol = _rpolicy.get_policy()
    grams = [f.T @ f for f in factors]
    for it in range(start_it, iters):
        t_sweep = time.perf_counter()
        with tracer.span("sweep", sweep=it, driver="distributed"):
            M = A = None
            for n in range(rt.nmodes):
                with tracer.span("mode", mode=n):
                    t0 = time.perf_counter()
                    with tracer.span("mttkrp", backend=backend):
                        def _mttkrp(n=n, idx=idx, val=val, mask=mask,
                                    factors=tuple(factors)):
                            return jax.block_until_ready(
                                mttkrp_fns[n](idx, val, mask, *factors))
                        M = (_mttkrp() if pol is None
                             else pol.run("ops.kernel", _mttkrp))
                        # Layout-pin: the eager solve/normalize below must
                        # compute identically whether M arrived sharded
                        # (mid-run) or from restored host factors (resume)
                        # — reduction order follows layout, and resume
                        # exactness is part of the checkpoint contract.
                        M = jnp.asarray(np.asarray(M))
                    _obs.add("cpals.phase_s", time.perf_counter() - t0,
                             phase="mttkrp", mode=n)
                    t0 = time.perf_counter()
                    with tracer.span("solve"):
                        A, level = _solve_v_guarded(grams, n, M)
                        A, norms = _normalize_columns(A, it == 0)
                        A = jax.block_until_ready(A)
                        level = int(level)
                        if level:
                            _obs.add("resilience.solve.guards",
                                     level=_numerics.GUARD_LEVELS[level],
                                     mode=n)
                    _obs.add("cpals.phase_s", time.perf_counter() - t0,
                             phase="solve", mode=n)
                    factors[n] = A
                    grams[n] = A.T @ A
                    lam = norms
                    t0 = time.perf_counter()
                    with tracer.span("remap", transition=n):
                        def _remap(n=n, idx=idx, val=val, mask=mask):
                            return jax.block_until_ready(
                                remap_fns[n](idx, val, mask))
                        idx, val, mask = (
                            _remap() if pol is None
                            else pol.run("distributed.remap", _remap))
                    _obs.add("cpals.phase_s", time.perf_counter() - t0,
                             phase="remap", mode=n)
            fit = float(fit_from_parts(x_norm_sq, lam, grams, M, A))
        _obs.add("cpals.sweep_s", time.perf_counter() - t_sweep,
                 driver="distributed")
        _obs.add("cpals.sweeps", driver="distributed")
        fits.append(fit)
        if mgr is not None and (it + 1) % checkpoint_every == 0:
            _ckpt.save_state(mgr, _ckpt_state(rt, backend, factors, lam,
                                              fits, it, idx, val, mask))
        if it > 0 and abs(fits[-1] - fits[-2]) < tol:
            break
    nat = [dist.unpermute_factor(ft, rt, n, np.asarray(f))
           for n, f in enumerate(factors)]
    return CPResult(nat, np.asarray(lam), fits, len(fits))


def cp_als_distributed(ft: FlycooTensor, rank: int, mesh: Mesh, *,
                       iters: int = 10, seed: int = 0, tol: float = 1e-5,
                       backend: str = "segsum",
                       tile_rows: int = 8, table=None,
                       gather_dtype: str = "float32",
                       ordering: str | None = None,
                       tracer=None,
                       checkpoint_dir: str | None = None,
                       checkpoint_every: int = 1,
                       resilience: "_rpolicy.RetryPolicy | None" = None
                       ) -> CPResult:
    """Distributed CP-ALS: FLYCOO layout + Dynasor sweeps on ``mesh``.

    Works for tensors of any order: with ``backend="pallas_fused"`` (or
    ``"auto"``) every mode of a 3-/4-/5-mode decomposition runs the fused
    N-mode Pallas kernel end-to-end. ``table`` (a ``repro.tune``
    calibration table) gives every mode a tuned
    ``(backend, blk, tile_rows)`` plan, followed when ``backend="auto"``.
    ``gather_dtype="bfloat16"`` opts the whole decomposition into bf16
    factor-row gathers on every fused-family mode step (fp32
    accumulate); the end-to-end fit impact is measured by
    ``benchmarks/bench_bf16_convergence.py``.

    ``ordering`` (:data:`repro.reorder.ORDERINGS`; ``None`` inherits
    ``ft.ordering``) turns on locality-aware nonzero ordering for every
    fused-family mode step — same fit up to fp32 accumulation order
    (property-tested in ``tests/test_reorder.py``).

    ``tracer`` defaults to the process tracer (``repro.obs``), normally
    the no-op — the production path below stays untouched. An *enabled*
    tracer switches to the stepped driver
    (:func:`make_instrumented_mode_fns`): per-mode jitted pieces with
    nested ``sweep → mode → mttkrp|solve|remap`` spans and identical
    counted metrics.

    ``checkpoint_dir`` turns on resumable sweeps (atomic per-sweep
    checkpoints holding factors, λ, fit trace, sweep index *and* the
    remapped nonzero stream — a resumed job continues from the exact
    post-sweep state; see ``repro.resilience.checkpoint``).
    ``resilience`` (a ``repro.resilience.RetryPolicy``) turns on
    graceful degradation: the run switches to the stepped driver and
    every kernel dispatch / remap / chunk launch gets bounded retry and
    a recorded walk down the residency ladder — every fallback counted
    in the ``resilience.*`` namespace, never a silent wrong answer.
    """
    tracer = _tracer.get_tracer() if tracer is None else tracer
    rt, (idx, val, mask) = dist.prepare_runtime(ft, rank,
                                                tile_rows=tile_rows,
                                                table=table,
                                                gather_dtype=gather_dtype,
                                                ordering=ordering)
    mgr = _ckpt.make_manager(checkpoint_dir)
    if tracer.enabled or resilience is not None or mgr is not None:
        # The stepped driver is the resilient one: per-phase host call
        # boundaries are where retry/degradation/checkpointing attach.
        if resilience is None:
            return _cp_als_distributed_traced(
                ft, rank, mesh, rt, idx, val, mask, iters=iters, seed=seed,
                tol=tol, backend=backend, tracer=tracer, mgr=mgr,
                checkpoint_every=checkpoint_every)
        with _rpolicy.use_policy(resilience):
            return _cp_als_distributed_traced(
                ft, rank, mesh, rt, idx, val, mask, iters=iters, seed=seed,
                tol=tol, backend=backend, tracer=tracer, mgr=mgr,
                checkpoint_every=checkpoint_every)
    factors = [jnp.asarray(f) for f in dist.init_factors(ft, rt, seed=seed)]
    lam = jnp.ones((rank,), jnp.float32)
    sweep = make_als_sweep(rt, mesh, backend=backend)
    x_norm_sq = np.broadcast_to(
        np.float32(np.sum(ft.tensor.values.astype(np.float64) ** 2)),
        (rt.num_workers,)).copy()
    fits: list[float] = []
    for it in range(iters):
        t0 = time.perf_counter()
        (idx, val, mask), factors, lam, fit = sweep(
            idx, val, mask, x_norm_sq, *factors, lam,
            jnp.asarray(it == 0))
        fit = float(fit)   # blocks on the whole fused sweep
        _obs.add("cpals.sweep_s", time.perf_counter() - t0,
                 driver="distributed")
        _obs.add("cpals.sweeps", driver="distributed")
        fits.append(fit)
        if it > 0 and abs(fits[-1] - fits[-2]) < tol:
            break
    nat = [dist.unpermute_factor(ft, rt, n, np.asarray(f))
           for n, f in enumerate(factors)]
    return CPResult(nat, np.asarray(lam), fits, len(fits))
