"""Dynasor core: the paper's contribution as composable JAX modules.

flycoo      — FLYCOO format build: super-shards, shards, Eq.2/3 params
schedule    — Alg. 3 LPT greedy scheduling (+ block-cyclic baseline)
mttkrp      — elementwise/segment-sum spMTTKRP engines (Alg. 2 inner loop)
remap       — dynamic tensor remapping (§III-B) as bucketed all_to_all
distributed — shard_map owner-computes spMTTKRP (+ all-reduce baseline)
cpals       — Alg. 1 CP-ALS driver (single-device and distributed)
tensors     — sparse tensor containers, FROSTT profiles, .tns I/O
"""
from . import cpals, distributed, flycoo, mttkrp, remap, schedule, tensors

__all__ = ["cpals", "distributed", "flycoo", "mttkrp", "remap", "schedule",
           "tensors"]
