"""Dynamic tensor remapping (paper §III-B, Alg. 2 line 27).

On CPU, Dynasor writes each nonzero into a second ``|T|`` buffer at the slot
it needs for the *next* mode while computing the current one. On TPU the
equivalent is a **bucketed all_to_all**: while mode ``n`` is being computed,
every nonzero is bucketed by the device that owns its mode-``n+1`` output row
and exchanged. XLA schedules the collective asynchronously with the gather/
compute stream — the TPU analogue of the paper's "integrated same-thread
remapping" (Fig. 2). Storage stays ``2·|T|`` (send + receive buffers), never
``N·|T|`` mode-specific copies.

All shapes are static: bucket capacity is the preprocessing-time max bucket
size (like MoE capacity), padding is masked, and every element is accounted
for (the round-trip property is tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .flycoo import FlycooTensor

__all__ = [
    "remap_capacity",
    "remap_capacities",
    "bucket_by_destination",
    "exchange",
    "compact_sorted",
    "remap_local",
]


def remap_capacities(ft: FlycooTensor) -> list[int]:
    """Per-transition max (src, dst) exchange sizes, mode n → n+1 (cyclic).

    One entry per mode transition of the N-mode ALS cycle — the exact
    all_to_all payload bound each remap pays. ``remap_capacity`` (the max)
    sizes the static double buffer; the per-transition values feed the
    traffic accounting in ``benchmarks.bench_remap_traffic``.
    """
    D = ft.params.num_workers
    caps = []
    for n in range(ft.nmodes):
        nxt = (n + 1) % ft.nmodes
        src = ft.owner_of(n).astype(np.int64)
        dst = ft.owner_of(nxt).astype(np.int64)
        counts = np.bincount(src * D + dst, minlength=D * D)
        caps.append(max(1, int(counts.max())))
    return caps


def remap_capacity(ft: FlycooTensor) -> int:
    """Max nonzeros any (src, dst) pair exchanges over all mode transitions.

    Static upper bound for the all_to_all buckets, computed at preprocessing
    (the paper's shard-pointer metadata plays the same role).
    """
    return max(remap_capacities(ft))


def bucket_by_destination(dest, payload, num_devices: int, bucket_cap: int):
    """Scatter ``payload`` rows into per-destination buckets (static shape).

    Args:
      dest: ``(n,)`` int32 destination worker per element; ``>= num_devices``
        marks padding/invalid elements.
      payload: ``(n, F)`` element data (coords + value packed as float/int —
        caller packs).
      num_devices: D.
      bucket_cap: per-destination capacity B.

    Returns:
      ``(buckets[(D, B, F)], bucket_mask[(D, B)], dropped)`` — ``dropped`` is
      the number of elements that exceeded capacity (must be 0 when
      ``bucket_cap >= remap_capacity``; exposed for the fault-tolerance
      check).
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    dest_s = jnp.take(dest, order)
    payload_s = jnp.take(payload, order, axis=0)
    # Position of each element inside its destination bucket.
    start = jnp.searchsorted(dest_s, dest_s, side="left")
    pos = jnp.arange(n, dtype=dest.dtype) - start.astype(dest.dtype)
    ok = (dest_s < num_devices) & (pos < bucket_cap)
    slot = jnp.where(ok, dest_s * bucket_cap + pos, num_devices * bucket_cap)
    flat = jnp.zeros(
        (num_devices * bucket_cap + 1, payload.shape[1]), dtype=payload.dtype
    ).at[slot].set(payload_s)
    maskf = jnp.zeros((num_devices * bucket_cap + 1,), dtype=jnp.bool_)\
        .at[slot].set(ok)
    valid = dest_s < num_devices
    dropped = jnp.sum(valid & ~ok)
    return (
        flat[:-1].reshape(num_devices, bucket_cap, payload.shape[1]),
        maskf[:-1].reshape(num_devices, bucket_cap),
        dropped,
    )


def exchange(buckets, bucket_mask, axis_name: str):
    """all_to_all the buckets: entry ``[d]`` goes to worker ``d``.

    Must be called inside ``shard_map``. Returns the received buckets
    (``recv[s]`` = what source ``s`` sent here) and their mask.
    """
    recv = jax.lax.all_to_all(
        buckets, axis_name, split_axis=0, concat_axis=0, tiled=True
    )
    recv_mask = jax.lax.all_to_all(
        bucket_mask, axis_name, split_axis=0, concat_axis=0, tiled=True
    )
    return recv, recv_mask


def compact_sorted(payload_flat, mask_flat, sort_key, out_cap: int):
    """Compact valid elements, sorted by ``sort_key``, into ``(out_cap, F)``.

    Invalid entries sort last (key forced to +max) and are truncated;
    the caller guarantees ``valid_count <= out_cap`` (FLYCOO preprocessing
    bound). Returns ``(payload[(out_cap, F)], mask[(out_cap,)])``.
    """
    big = jnp.iinfo(sort_key.dtype).max
    key = jnp.where(mask_flat, sort_key, big)
    order = jnp.argsort(key, stable=True)[:out_cap]
    return jnp.take(payload_flat, order, axis=0), jnp.take(mask_flat, order)


def remap_local(ft: FlycooTensor, to_mode: int):
    """Single-worker reference remap (numpy): the post-remap layout oracle.

    The distributed all_to_all remap of ``pack_mode(ft, from_mode)``
    must equal ``pack_mode(ft, to_mode)`` up to padding — and since the
    FLYCOO preprocessing already knows every mode's packed layout, the
    oracle *is* ``pack_mode(ft, to_mode)``. The signature says exactly
    that: no source-layout arguments, because the expected result does
    not depend on them (an earlier version accepted and silently
    ignored ``from_mode``/``idx``/``val``/``mask``, which misstated the
    contract).
    """
    from .flycoo import pack_mode  # local import to avoid cycle at import time

    return pack_mode(ft, to_mode)
