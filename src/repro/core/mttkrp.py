"""Single-device spMTTKRP engines (paper §II-C elementwise computation).

Three tiers, each validated against the previous:
  1. :func:`mttkrp_elementwise_ref` — literal per-nonzero loop (paper Fig. 1 /
     Eq. 4). numpy, tests only.
  2. :func:`mttkrp` — vectorized JAX engine: gather input factor rows,
     Hadamard-product them, scale by the value, ``segment_sum`` into the
     output rows. This is the pure-jnp oracle for the Pallas kernel.
  3. ``repro.kernels.mttkrp.ops.mttkrp_blocked`` — the Pallas TPU kernel
     (shard = VMEM block; scatter = one-hot MXU matmul).
  4. :func:`mttkrp_fused` — single-device convenience over the N-mode fused
     Pallas path (``ops.mttkrp_device_step``): sorts the stream by output
     row and dispatches through the backend matrix (``auto`` by default).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.mttkrp import ops as _kops

__all__ = [
    "mttkrp_elementwise_ref",
    "hadamard_rows",
    "mttkrp",
    "mttkrp_sorted",
    "mttkrp_fused",
]


def mttkrp_elementwise_ref(indices, values, factors, mode, out_rows=None):
    """Literal Alg. 2 inner loop in numpy (lines 13-25). Tests only."""
    indices = np.asarray(indices)
    values = np.asarray(values)
    nmodes = indices.shape[1]
    rank = factors[0].shape[1]
    out_rows = out_rows if out_rows is not None else factors[mode].shape[0]
    out = np.zeros((out_rows, rank), dtype=np.float64)
    for i in range(len(values)):
        ell = np.ones(rank, dtype=np.float64)
        for w in range(nmodes):
            if w == mode:
                continue
            ell *= np.asarray(factors[w])[indices[i, w]].astype(np.float64)
        out[indices[i, mode]] += float(values[i]) * ell
    return out


def hadamard_rows(indices, values, factors, mode):
    """``value · ⊙_{w≠mode} Y_w[c_w]`` for every nonzero → ``(nnz, R)``.

    This is the gather + Hadamard stage (Alg. 2 lines 19-23); the remaining
    segment-reduction is the scatter stage handled either by
    ``jax.ops.segment_sum`` or by the Pallas kernel.
    """
    nmodes = indices.shape[1]
    ell = values[:, None].astype(factors[0].dtype)
    for w in range(nmodes):
        if w == mode:
            continue
        ell = ell * jnp.take(factors[w], indices[:, w], axis=0)
    return ell


@functools.partial(jax.jit, static_argnames=("mode", "out_rows"))
def mttkrp(indices, values, factors, mode: int, out_rows: int):
    """Vectorized spMTTKRP for one mode (unsorted nonzeros)."""
    ell = hadamard_rows(indices, values, factors, mode)
    return jax.ops.segment_sum(ell, indices[:, mode], num_segments=out_rows)


@functools.partial(
    jax.jit, static_argnames=("mode", "out_rows", "indices_sorted")
)
def mttkrp_sorted(indices, values, factors, mode: int, out_rows: int,
                  indices_sorted: bool = True):
    """spMTTKRP for nonzeros pre-sorted by output row (FLYCOO layout).

    Sortedness lets XLA use the monotonic segment-sum path; it is also the
    precondition for the Pallas blocked kernel.
    """
    ell = hadamard_rows(indices, values, factors, mode)
    return jax.ops.segment_sum(
        ell, indices[:, mode], num_segments=out_rows,
        indices_are_sorted=indices_sorted,
    )


@functools.partial(
    jax.jit,
    static_argnames=("mode", "out_rows", "blk", "tile_rows", "backend",
                     "interpret", "gather_dtype"),
)
def mttkrp_fused(indices, values, factors, mode: int, out_rows: int, *,
                 blk: int = 512, tile_rows: int = 128,
                 backend: str = "auto", interpret: bool | None = None,
                 gather_dtype: str = "float32"):
    """Single-device spMTTKRP through the fused N-mode Pallas path.

    Sorts the nonzero stream by output row (the FLYCOO precondition), pads
    the output to a whole number of row tiles, and dispatches through
    ``ops.mttkrp_device_step``'s backend matrix (``docs/kernels.md``) —
    ``auto`` picks fused vs. rank-tiled fused vs. materialized vs. ref
    from mode count, rank padding and VMEM budget. ``gather_dtype=
    "bfloat16"`` makes the fused family gather bf16 factor rows
    (fp32 accumulate).
    """
    order = jnp.argsort(indices[:, mode], stable=True)
    idx = jnp.take(indices, order, axis=0).astype(jnp.int32)
    val = jnp.take(values, order)
    valid = jnp.ones(val.shape, bool)
    rows_cap = -(-out_rows // tile_rows) * tile_rows
    out = _kops.mttkrp_device_step(
        idx, val, valid, list(factors), mode=mode, rows_cap=rows_cap,
        row_offset=0, blk=blk, tile_rows=tile_rows, interpret=interpret,
        backend=backend, gather_dtype=gather_dtype,
    )
    return out[:out_rows]
