"""FLYCOO tensor format (paper §III) adapted to the TPU runtime.

Per output mode ``n`` the format:
  * splits the ``|I_n|`` output-factor rows into equal intervals of ``m_n``
    rows; the nonzeros incident on an interval form a **super-shard**;
  * splits each super-shard into **shards** of ``g`` nonzeros (the cache /
    VMEM-fit unit for the compute kernel);
  * assigns super-shards to workers with the LPT greedy schedule (Alg. 3),
    so every nonzero that updates a given output row lands on exactly one
    worker → lock-free owner-computes execution;
  * records, for every nonzero, the shard it belongs to in *every* mode —
    this is what makes dynamic remapping (paper §III-B) a pure data
    movement with no recomputation.

TPU adaptation: "worker" is a mesh device on the ``data`` axis. We bake the
super-shard→device assignment into a **row permutation** per mode (device-
major layout, padded to equal rows per device), so the runtime sees plain
contiguous row ownership while preprocessing carries all the load-balancing
intelligence. Factor matrices live in permuted row space throughout CP-ALS
(gram matrices and column norms are permutation-invariant) and are
un-permuted once at the end.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .schedule import block_cyclic_schedule, lpt_schedule
from .tensors import SparseTensor

__all__ = [
    "PartitionParams",
    "ModePartition",
    "FlycooTensor",
    "choose_partition_params",
    "build_flycoo",
    "pack_mode",
    "gather_operand_bytes",
]


def gather_operand_bytes(nmodes: int, rank: int, g: int,
                         itemsize: int = 4) -> int:
    """Bytes of gathered input-factor rows one shard holds resident.

    The N-mode fused kernel streams N−1 gathered ``(g, R)`` factor-row
    blocks into VMEM per shard (``kernels.mttkrp.kernel.fused_mttkrp_nmode``)
    instead of one materialized contrib block — this is the extra working-set
    term Eq. 3 must carry when the fused path is enabled.
    """
    return (nmodes - 1) * g * rank * itemsize


@dataclasses.dataclass(frozen=True)
class PartitionParams:
    """Tensor partitioning parameters (paper Eq. 2 & 3)."""

    m: tuple[int, ...]        # rows per super-shard interval, per mode
    g: int                    # shard size in nonzeros (cache/VMEM unit)
    num_workers: int          # ν — threads on CPU, data-axis devices on TPU
    theta: float = 0.5        # cache fraction available to Dynasor (paper: 0.5)
    cache_bytes: int = 0      # Γ — informational
    satisfied: bool = True    # Eq.3 satisfied for all modes


@dataclasses.dataclass(frozen=True)
class ModePartition:
    """Per-mode FLYCOO partition metadata."""

    mode: int
    m: int                       # interval (super-shard) width in rows
    num_super: int               # k_n
    super_sizes: np.ndarray      # (k_n,) nnz per super-shard
    shard_counts: np.ndarray     # (k_n,) ceil(size / g)
    super_to_device: np.ndarray  # (k_n,) worker id (LPT or block-cyclic)
    rows_cap: int                # padded rows per worker (static shape)
    row_perm: np.ndarray         # (I_n,) natural row -> device-major slot
    row_unperm: np.ndarray       # (num_workers*rows_cap,) slot -> natural row, -1 pad
    nnz_counts: np.ndarray       # (num_workers,) owned nonzeros per worker


@dataclasses.dataclass(frozen=True)
class FlycooTensor:
    """A sparse tensor in FLYCOO format for ``num_workers`` workers."""

    tensor: SparseTensor
    params: PartitionParams
    modes: list[ModePartition]
    perm_indices: np.ndarray     # (nnz, N) indices mapped through row_perm per mode
    # repro.reorder.ORDERINGS policy pack_mode applies within equal
    # (owner, output-row) groups — factor-tile locality for the gathered
    # modes without disturbing the row sort the segsum path needs.
    ordering: str = "none"

    @property
    def nnz(self) -> int:
        return self.tensor.nnz

    @property
    def nmodes(self) -> int:
        return self.tensor.nmodes

    @property
    def nnz_cap(self) -> int:
        """Static per-worker nonzero capacity (max over modes × workers)."""
        return int(max(mp.nnz_counts.max() for mp in self.modes))

    def owner_of(self, mode: int) -> np.ndarray:
        """(nnz,) worker owning each nonzero for ``mode``."""
        mp = self.modes[mode]
        return mp.super_to_device[
            self.tensor.indices[:, mode] // mp.m
        ].astype(np.int32)

    def bits_per_nonzero(self) -> float:
        """FLYCOO storage model (paper §III-A)."""
        t, p = self.tensor, self.params
        shard_id_bits = t.nmodes * math.log2(max(2, t.nnz / p.g))
        index_bits = sum(math.log2(max(2, d)) for d in t.shape)
        return shard_id_bits + index_bits + 32.0  # β_float = fp32


def choose_partition_params(
    shape: Sequence[int],
    nnz: int,
    num_workers: int,
    *,
    rank: int = 16,
    cache_bytes: int = 128 * 1024 * 1024,
    theta: float = 0.5,
    m_bounds: tuple[int, int] = (1000, 16000),
    g_bounds: tuple[int, int] = (1024, 32768),
    itemsize: int = 4,
    fused_gather: bool = False,
) -> PartitionParams:
    """Pick ``m_n`` and ``g`` per paper Eq. 2 & 3.

    Eq. 2: ``|I_n| / m_n = q·ν`` — super-shard count divisible by workers.
    Eq. 3: ``θ·Γ >= (α·m_n·R + β·g)·ν + σ·Σ_j ceil(|SS_j|/g)`` — working set
    (output rows + one shard per worker + remap pointers) fits the cache
    budget. α = factor-row bytes, β = nonzero bytes, σ = pointer bytes.

    ``fused_gather=True`` targets the N-mode fused kernel: β additionally
    carries the N−1 gathered input-factor rows per nonzero
    (:func:`gather_operand_bytes` / g), shrinking ``g`` so the whole
    gather-operand block set stays cache/VMEM-resident.

    On TPU ``cache_bytes`` is the per-device VMEM budget (≈128 MB on v5e is
    the paper-analogue "total cache"; pass 64 MiB for a single core's view).
    """
    nmodes = len(shape)
    alpha = rank * itemsize
    beta = nmodes * 4 + itemsize        # N int32 coords + value
    if fused_gather:
        beta += gather_operand_bytes(nmodes, rank, 1, itemsize)  # per nnz
    sigma = 8                           # remap pointer
    budget = theta * cache_bytes

    ms: list[int] = []
    for dim in shape:
        if dim <= num_workers:
            m = 1                        # paper §V-A5: m_n = 1 when |I_n| < ν
        else:
            lo, hi = m_bounds
            target = int(np.clip(dim // (4 * num_workers), lo, hi))
            q = max(1, round(dim / (num_workers * target)))
            m = math.ceil(dim / (q * num_workers))
            m = max(1, m)
        ms.append(m)

    # Choose the largest g in bounds satisfying Eq. 3 for every mode
    # (bigger shards amortize grid overhead; the cache term caps them).
    satisfied = True
    g_lo, g_hi = g_bounds
    g = g_hi
    while g >= g_lo:
        ok = True
        for n, dim in enumerate(shape):
            k_n = math.ceil(dim / ms[n])
            est_shards = k_n + math.ceil(nnz / g)   # upper bound on Σ ceil(|SS|/g)
            used = (alpha * ms[n] + beta * g) * num_workers + sigma * est_shards
            if used > budget:
                ok = False
                break
        if ok:
            break
        g //= 2
    if g < g_lo:
        g, satisfied = g_lo, False

    return PartitionParams(
        m=tuple(ms), g=int(g), num_workers=num_workers, theta=theta,
        cache_bytes=cache_bytes, satisfied=satisfied,
    )


def _build_mode(
    t: SparseTensor, mode: int, m: int, g: int, num_workers: int, schedule: str
) -> ModePartition:
    dim = t.shape[mode]
    num_super = math.ceil(dim / m)
    super_of_nnz = t.indices[:, mode] // m
    super_sizes = np.bincount(super_of_nnz, minlength=num_super).astype(np.int64)
    shard_counts = np.ceil(np.maximum(super_sizes, 1) / g).astype(np.int64)

    if schedule == "lpt":
        super_to_device = lpt_schedule(shard_counts, num_workers)
    elif schedule == "cyclic":
        super_to_device = block_cyclic_schedule(num_super, num_workers)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")

    # Device-major row permutation. Super-shards keep their internal row
    # order (FLYCOO keeps rows of an interval together for locality).
    rows_per_dev = np.zeros(num_workers, dtype=np.int64)
    for j in range(num_super):
        lo = j * m
        hi = min(dim, lo + m)
        rows_per_dev[super_to_device[j]] += hi - lo
    rows_cap = int(rows_per_dev.max()) if num_workers > 0 else dim
    rows_cap = max(rows_cap, 1)

    row_perm = np.empty(dim, dtype=np.int64)
    fill = np.zeros(num_workers, dtype=np.int64)
    for j in range(num_super):
        d = super_to_device[j]
        lo = j * m
        hi = min(dim, lo + m)
        n_rows = hi - lo
        base = d * rows_cap + fill[d]
        row_perm[lo:hi] = np.arange(base, base + n_rows)
        fill[d] += n_rows

    row_unperm = np.full(num_workers * rows_cap, -1, dtype=np.int64)
    row_unperm[row_perm] = np.arange(dim)

    owner = super_to_device[super_of_nnz]
    nnz_counts = np.bincount(owner, minlength=num_workers).astype(np.int64)

    return ModePartition(
        mode=mode, m=m, num_super=num_super, super_sizes=super_sizes,
        shard_counts=shard_counts, super_to_device=super_to_device.astype(np.int32),
        rows_cap=rows_cap, row_perm=row_perm, row_unperm=row_unperm,
        nnz_counts=nnz_counts,
    )


def _validate_tensor(t: SparseTensor) -> None:
    """Reject malformed input before any partitioning arithmetic runs.

    FLYCOO preprocessing silently produced garbage on bad input: a
    negative index made ``//`` round toward a nonexistent super-shard, an
    out-of-range index scattered into another row's interval, and a
    non-finite value poisoned every sweep's fit. Each case is a
    ``ValueError`` naming the offending nonzero so the producer can fix
    its extraction, not a crash (or worse, a wrong decomposition) three
    layers down.
    """
    idx, vals = np.asarray(t.indices), np.asarray(t.values)
    if idx.ndim != 2 or idx.shape[1] != len(t.shape):
        raise ValueError(
            f"indices must be (nnz, {len(t.shape)}) for shape {t.shape}, "
            f"got {idx.shape}")
    if vals.shape != (idx.shape[0],):
        raise ValueError(
            f"values must be ({idx.shape[0]},) to match indices, got "
            f"{vals.shape}")
    if idx.size:
        for n, dim in enumerate(t.shape):
            col = idx[:, n]
            bad = np.flatnonzero((col < 0) | (col >= dim))
            if bad.size:
                b = int(bad[0])
                raise ValueError(
                    f"mode-{n} index out of range at nonzero {b}: index "
                    f"{int(col[b])} not in [0, {dim}) — fix the extraction "
                    f"or the declared shape {t.shape} ({bad.size} offending "
                    "nonzeros total)")
    if vals.size and not np.isfinite(vals).all():
        bad = np.flatnonzero(~np.isfinite(vals))
        b = int(bad[0])
        raise ValueError(
            f"non-finite value at nonzero {b}: {vals[b]!r} — a NaN/inf "
            "nonzero poisons every CP-ALS sweep's MTTKRP and fit; drop or "
            f"impute it before building FLYCOO ({bad.size} offending "
            "nonzeros total)")


def build_flycoo(
    t: SparseTensor,
    num_workers: int,
    *,
    params: PartitionParams | None = None,
    rank: int = 16,
    cache_bytes: int = 128 * 1024 * 1024,
    schedule: str = "lpt",
    m_bounds: tuple[int, int] = (1000, 16000),
    g_bounds: tuple[int, int] = (1024, 32768),
    fused_gather: bool = False,
    ordering: str = "none",
) -> FlycooTensor:
    """Preprocess ``t`` into FLYCOO format (paper §V-J stages 1–3).

    ``fused_gather=True`` sizes shards for the N-mode fused kernel's
    gather-operand working set (see :func:`choose_partition_params`).

    ``ordering`` (:data:`repro.reorder.ORDERINGS`) selects the
    locality-aware nonzero ordering :func:`pack_mode` applies within
    each (owner, output row) group — paid once here at preprocessing
    time, amortized over every ALS sweep.
    """
    from ..reorder import validate_ordering  # deferred: reorder imports kernels
    validate_ordering(ordering)
    _validate_tensor(t)
    if params is None:
        params = choose_partition_params(
            t.shape, t.nnz, num_workers, rank=rank, cache_bytes=cache_bytes,
            m_bounds=m_bounds, g_bounds=g_bounds, fused_gather=fused_gather,
        )
    modes = [
        _build_mode(t, n, params.m[n], params.g, num_workers, schedule)
        for n in range(t.nmodes)
    ]
    perm_indices = np.stack(
        [modes[n].row_perm[t.indices[:, n]] for n in range(t.nmodes)], axis=1
    ).astype(np.int64)
    return FlycooTensor(tensor=t, params=params, modes=modes,
                        perm_indices=perm_indices, ordering=ordering)


def pack_mode(
    ft: FlycooTensor, mode: int, cap: int | None = None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Group nonzeros by mode-``mode`` owner, sorted by permuted output row.

    Returns ``(idx[(D, cap, N)], val[(D, cap)], mask[(D, cap)])`` — the
    initial distributed layout ``H_mode`` of Alg. 2. Padding entries have
    ``val = 0`` and point at local row 0 (they contribute exactly zero).

    When ``ft.ordering != "none"`` the sort's primaries stay
    ``(owner, permuted output row)`` — so the stream remains
    row-sorted, exactly what the segsum path and
    ``build_block_layout`` require — but ties within an equal output
    row are broken by the policy's gathered-mode locality keys instead
    of original nonzero position.
    """
    D = ft.params.num_workers
    cap = int(cap if cap is not None else ft.nnz_cap)
    owner = ft.owner_of(mode)
    if ft.ordering != "none":
        from ..reorder import locality_lexsort  # deferred: reorder imports kernels
        in_modes = [w for w in range(ft.nmodes) if w != mode]
        order = locality_lexsort(
            ft.perm_indices[:, in_modes], ft.ordering,
            primaries=(owner.astype(np.int64),
                       ft.perm_indices[:, mode]),
            max_rows=max(ft.params.num_workers * ft.modes[w].rows_cap
                         for w in in_modes),
        )
    else:
        # max(initial=0) keeps the empty-tensor case (nnz == 0) a valid
        # all-padding layout instead of a ValueError on .max().
        key = owner.astype(np.int64) \
            * (ft.perm_indices[:, mode].max(initial=0) + 1) \
            + ft.perm_indices[:, mode]
        order = np.argsort(key, kind="stable")

    idx = np.zeros((D, cap, ft.nmodes), dtype=np.int32)
    val = np.zeros((D, cap), dtype=np.float32)
    mask = np.zeros((D, cap), dtype=bool)
    mp = ft.modes[mode]
    for d in range(D):
        sel = order[owner[order] == d]
        k = len(sel)
        if k > cap:
            raise ValueError(f"capacity {cap} < owned nnz {k} on worker {d}")
        idx[d, :k] = ft.perm_indices[sel]
        # Padding gathers factor row 0 of this device's range — harmless.
        idx[d, k:, mode] = d * mp.rows_cap
        val[d, :k] = ft.tensor.values[sel]
        mask[d, :k] = True
    return idx, val, mask
