"""Super-shard scheduling (paper Alg. 3) and baselines.

The paper assigns super-shards to CPU threads with an LPT greedy rule
(sort descending by shard count, assign to least-loaded bin), which gives
Graham's ``max_load <= 4/3 * OPT`` guarantee. We use the identical algorithm
to balance super-shards across mesh devices (and Pallas grid blocks), and
ship the block-cyclic distribution the paper compares against (Fig. 6).
"""
from __future__ import annotations

import heapq

import numpy as np

__all__ = [
    "lpt_schedule",
    "block_cyclic_schedule",
    "makespan",
    "load_imbalance",
]


def lpt_schedule(sizes: np.ndarray, num_bins: int) -> np.ndarray:
    """Longest-Processing-Time greedy (paper Alg. 3).

    Args:
      sizes: per-super-shard work (shard / nnz counts), shape ``(S,)``.
      num_bins: number of workers (threads on CPU, devices on TPU).

    Returns:
      ``assign[(S,)]`` — bin id per super-shard. Guarantees
      ``makespan(assign) <= 4/3 * OPT`` (Graham 1969).
    """
    sizes = np.asarray(sizes)
    order = np.argsort(-sizes, kind="stable")  # descending, stable => deterministic
    assign = np.empty(len(sizes), dtype=np.int32)
    # (load, bin) heap; bin index tiebreak keeps determinism.
    heap = [(0, b) for b in range(num_bins)]
    heapq.heapify(heap)
    for s in order:
        load, b = heapq.heappop(heap)
        assign[s] = b
        heapq.heappush(heap, (load + int(sizes[s]), b))
    return assign


def block_cyclic_schedule(num_items: int, num_bins: int, block: int = 1) -> np.ndarray:
    """Block-cyclic distribution (state-of-the-art baseline, paper §V-D)."""
    item = np.arange(num_items)
    return ((item // block) % num_bins).astype(np.int32)


def makespan(sizes: np.ndarray, assign: np.ndarray, num_bins: int) -> int:
    """Largest per-bin load under ``assign``."""
    return int(np.bincount(assign, weights=sizes, minlength=num_bins).max())


def load_imbalance(sizes: np.ndarray, assign: np.ndarray, num_bins: int) -> float:
    """makespan / mean-load; 1.0 == perfectly balanced."""
    loads = np.bincount(assign, weights=sizes, minlength=num_bins)
    mean = loads.sum() / num_bins
    return float(loads.max() / mean) if mean > 0 else 1.0
