"""Sparse tensor containers, synthetic generators and .tns I/O.

The paper evaluates on FROSTT tensors (Nell-1/2, Flickr, Delicious, Vast).
Those are multi-GB downloads, so the benchmark suite uses *FROSTT-scaled
synthetic* tensors: same mode counts, same qualitative index distributions
(power-law "hub" indices, as in web/NLP tensors), scaled nnz. Real .tns files
load through :func:`load_tns` when present.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "SparseTensor",
    "random_sparse_tensor",
    "zipf_4d",
    "low_rank_sparse_tensor",
    "frostt_like",
    "load_tns",
    "save_tns",
    "FROSTT_PROFILES",
]


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """COO sparse tensor: ``indices[(nnz, N)]``, ``values[(nnz,)]``."""

    indices: np.ndarray  # (nnz, N) int32/int64
    values: np.ndarray   # (nnz,) float
    shape: tuple[int, ...]

    def __post_init__(self):
        assert self.indices.ndim == 2
        assert self.indices.shape[1] == len(self.shape)
        assert self.values.shape == (self.indices.shape[0],)

    @property
    def nnz(self) -> int:
        return self.indices.shape[0]

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    def to_dense(self) -> np.ndarray:
        """Densify (tests only — small tensors)."""
        out = np.zeros(self.shape, dtype=np.float64)
        np.add.at(out, tuple(self.indices.T), self.values.astype(np.float64))
        return out

    def norm(self) -> float:
        return float(np.linalg.norm(self.values))

    def permuted_rows(self, perms: Sequence[np.ndarray]) -> "SparseTensor":
        """Relabel mode-n indices through ``perms[n]`` (natural -> permuted)."""
        idx = np.stack(
            [perms[n][self.indices[:, n]] for n in range(self.nmodes)], axis=1
        )
        return SparseTensor(idx.astype(self.indices.dtype), self.values, self.shape)


def _dedup(indices: np.ndarray, values: np.ndarray, shape) -> SparseTensor:
    """Sum duplicate coordinates (canonical COO)."""
    flat = np.ravel_multi_index(tuple(indices.T), shape)
    order = np.argsort(flat, kind="stable")
    flat, indices, values = flat[order], indices[order], values[order]
    uniq, start = np.unique(flat, return_index=True)
    summed = np.add.reduceat(values, start)
    return SparseTensor(indices[start].astype(np.int32), summed.astype(values.dtype), tuple(shape))


def random_sparse_tensor(
    shape: Sequence[int],
    nnz: int,
    *,
    seed: int = 0,
    distribution: str = "uniform",
    alpha: float = 1.1,
    dtype=np.float32,
) -> SparseTensor:
    """Random COO tensor.

    ``distribution='powerlaw'`` skews indices toward small ids (hub structure
    seen in FROSTT web/NLP tensors) — this is what makes super-shard loads
    *unbalanced* and the LPT schedule matter (paper Fig. 6).
    """
    rng = np.random.default_rng(seed)
    if distribution == "powerlaw":
        indices = _powerlaw_columns(rng, shape, nnz, alpha)
    else:
        indices = np.stack([rng.integers(0, dim, size=nnz) for dim in shape],
                           axis=1)
    values = rng.standard_normal(nnz).astype(dtype)
    values[values == 0] = 1.0
    return _dedup(indices, values, tuple(shape))


def _powerlaw_columns(rng, shape, n: int, alpha: float) -> np.ndarray:
    """(n, N) skewed coordinates via inverse-CDF on a truncated Pareto.

    The single source of the Zipf-like hub draw — used by
    ``random_sparse_tensor``, ``zipf_4d`` and the ``repro.tune``
    microbenchmark case generator.
    """
    cols = []
    for dim in shape:
        u = rng.random(n)
        raw = (1.0 - u) ** (-1.0 / alpha) - 1.0
        cols.append(np.minimum((raw * dim / max(raw.max(), 1e-12))
                               .astype(np.int64), dim - 1))
    return np.stack(cols, axis=1)


def zipf_4d(
    shape: Sequence[int],
    nnz: int,
    *,
    alpha: float = 1.3,
    seed: int = 0,
    max_rounds: int = 64,
    dtype=np.float32,
) -> SparseTensor:
    """Skewed (Zipf-like) tensor that keeps its nnz by rejecting duplicates.

    ``random_sparse_tensor(distribution='powerlaw')`` draws coordinates
    independently and then dedups — on small high-order (e.g. scaled
    4-mode) grids the hub coordinates collide so often that almost
    nothing survives, which is why the ``enron`` profile had to fall
    back to uniform indices (PR 1 note). This generator instead
    *rejects duplicates during sampling*: it keeps drawing skewed
    batches, keeps only coordinates not seen yet, and tops up with
    uniform draws if the hubs saturate — so skewed 4-mode tensors with
    full nnz exist for calibration and remap benchmarks.

    Named for its motivating use; works for any order.
    """
    shape = tuple(shape)
    capacity = math.prod(int(d) for d in shape)   # exact, unlike float prod
    if nnz > capacity:
        raise ValueError(f"nnz={nnz} exceeds tensor capacity {capacity}")
    rng = np.random.default_rng(seed)
    seen: set[int] = set()
    rows: list[np.ndarray] = []
    rounds = 0
    while len(seen) < nnz and rounds < max_rounds:
        rounds += 1
        want = nnz - len(seen)
        batch = _powerlaw_columns(rng, shape, max(want * 2, 64), alpha)
        flat = np.ravel_multi_index(tuple(batch.T), shape)
        # first occurrence within the batch, then against everything seen
        _, first = np.unique(flat, return_index=True)
        for i in np.sort(first):
            f = int(flat[i])
            if f not in seen:
                seen.add(f)
                rows.append(batch[i])
                if len(seen) >= nnz:
                    break
    if len(seen) < nnz:     # hubs saturated: vectorized uniform top-up
        missing = nnz - len(seen)
        seen_arr = np.fromiter(seen, np.int64, len(seen))
        if capacity <= max(4 * nnz, 1 << 20):
            # dense regime (nnz ~ capacity): enumerate the complement
            free = np.setdiff1d(np.arange(capacity, dtype=np.int64),
                                seen_arr, assume_unique=True)
            pick = rng.choice(free, size=missing, replace=False)
        else:
            # sparse regime: batched rejection, ≥ 3/4 hit rate per draw
            picks: list[np.ndarray] = []
            while missing > 0:
                cand = np.unique(rng.integers(0, capacity,
                                              size=max(2 * missing, 1024)))
                cand = cand[~np.isin(cand, seen_arr)][:missing]
                picks.append(cand)
                seen_arr = np.concatenate([seen_arr, cand])
                missing -= len(cand)
            pick = np.concatenate(picks)
        rows.extend(np.stack(np.unravel_index(pick, shape), axis=1))
    indices = np.stack(rows, axis=0).astype(np.int32)
    values = rng.standard_normal(nnz).astype(dtype)
    values[values == 0] = 1.0
    order = np.argsort(np.ravel_multi_index(tuple(indices.T), shape),
                       kind="stable")
    return SparseTensor(indices[order], values[order], shape)


def low_rank_sparse_tensor(
    shape: Sequence[int],
    rank: int,
    nnz: int,
    *,
    seed: int = 0,
    noise: float = 0.0,
    dtype=np.float32,
) -> tuple[SparseTensor, list[np.ndarray]]:
    """Sparse sample of a ground-truth rank-``rank`` tensor.

    Returns ``(tensor, true_factors)``; CP-ALS on the samples should recover
    factors congruent with the truth (test_cpals uses this).
    """
    rng = np.random.default_rng(seed)
    factors = [rng.standard_normal((dim, rank)).astype(np.float64) for dim in shape]
    idx = np.stack([rng.integers(0, dim, size=nnz) for dim in shape], axis=1)
    vals = np.ones(nnz, dtype=np.float64)
    for n, dim in enumerate(shape):
        pass
    prod = np.ones((nnz, rank), dtype=np.float64)
    for n in range(len(shape)):
        prod *= factors[n][idx[:, n]]
    vals = prod.sum(axis=1)
    if noise:
        vals = vals + noise * rng.standard_normal(nnz)
    t = _dedup(idx, vals.astype(dtype), tuple(shape))
    return t, [f.astype(dtype) for f in factors]


# FROSTT dataset profiles from paper Table II, scaled for a CPU container.
FROSTT_PROFILES: dict[str, dict] = {
    # name: (true shape, true nnz) -> scaled synthetic stand-in
    "nell-1": dict(shape=(2_900_000, 2_100_000, 25_500_000), nnz=143_600_000,
                   scaled_shape=(2900, 2100, 25500), scaled_nnz=143_600,
                   distribution="powerlaw"),
    "nell-2": dict(shape=(12_100, 9_200, 28_800), nnz=76_900_000,
                   scaled_shape=(1210, 920, 2880), scaled_nnz=76_900,
                   distribution="uniform"),
    "flickr": dict(shape=(319_600, 28_200_000, 1_600_000), nnz=112_900_000,
                   scaled_shape=(3196, 28200, 1600), scaled_nnz=112_900,
                   distribution="powerlaw"),
    "delicious": dict(shape=(532_900, 17_300_000, 2_500_000, 1_400), nnz=140_100_000,
                      scaled_shape=(5329, 17300, 2500, 140), scaled_nnz=140_100,
                      distribution="powerlaw"),
    # 4-mode FROSTT tensor (sender × receiver × word × date). Compact mode
    # sizes make it the N-mode fused-kernel benchmark target: every mode is
    # eligible for the fused gather-Hadamard-scatter path. Uniform indices:
    # the scaled-down power-law generator dedups 4-mode tensors to almost
    # nothing, and this tensor must keep its nnz to measure kernel traffic.
    "enron": dict(shape=(6_066, 5_699, 244_268, 1_176), nnz=54_202_099,
                  scaled_shape=(606, 569, 2442, 117), scaled_nnz=54_202,
                  distribution="uniform"),
    # Skewed variant of enron: same profile through the duplicate-rejecting
    # zipf_4d generator, so a 4-mode tensor with hub structure AND full nnz
    # exists (the plain power-law generator dedups 4-mode grids to almost
    # nothing). This is the per-transition remap-savings benchmark target.
    "enron-skew": dict(shape=(6_066, 5_699, 244_268, 1_176), nnz=54_202_099,
                       scaled_shape=(606, 569, 2442, 117), scaled_nnz=54_202,
                       distribution="zipf"),
    "vast": dict(shape=(165_400, 11_400, 2, 100, 89), nnz=26_000_000,
                 scaled_shape=(16540, 1140, 2, 100, 89), scaled_nnz=26_000,
                 distribution="uniform"),
}


def frostt_like(name: str, *, seed: int = 0, scale: float = 1.0) -> SparseTensor:
    """Synthetic stand-in for a FROSTT tensor (paper Table II), scaled."""
    prof = FROSTT_PROFILES[name]
    shape = tuple(max(2, int(d * scale)) if scale != 1.0 else d
                  for d in prof["scaled_shape"])
    nnz = max(16, int(prof["scaled_nnz"] * scale))
    if prof["distribution"] == "zipf":
        return zipf_4d(shape, min(nnz, math.prod(shape)), seed=seed)
    return random_sparse_tensor(shape, nnz, seed=seed, distribution=prof["distribution"])


def load_tns(path: str, *, one_indexed: bool = True) -> SparseTensor:
    """Load a FROSTT ``.tns`` text file (coords then value per line)."""
    data = np.loadtxt(path, dtype=np.float64, ndmin=2)
    idx = data[:, :-1].astype(np.int64)
    if one_indexed:
        idx -= 1
    vals = data[:, -1].astype(np.float32)
    shape = tuple(int(m) + 1 for m in idx.max(axis=0))
    return _dedup(idx, vals, shape)


def save_tns(t: SparseTensor, path: str, *, one_indexed: bool = True) -> None:
    off = 1 if one_indexed else 0
    with open(path, "w") as f:
        for i in range(t.nnz):
            coords = " ".join(str(int(c) + off) for c in t.indices[i])
            f.write(f"{coords} {float(t.values[i]):.9g}\n")
