"""`python -m repro.tune` — calibrate / show / check.

  calibrate   measure all backends over the grid, save a table JSON
  show        print a saved table (meta, per-config best + timings)
  check       verify dispatch decisions against the measured argmin
"""
from __future__ import annotations

import argparse
import sys

from ..kernels.mttkrp import ops as kops
from . import microbench
from .model import compare_dispatch
from .table import (CalibrationTable, aggregate_timings, default_table_path,
                    find_table, load_table, measured_best)


def _load(path: str | None) -> CalibrationTable | None:
    if path is not None:
        return load_table(path)
    return find_table()


def cmd_calibrate(args) -> int:
    measure = microbench.stub_measure if args.stub else None
    table = microbench.calibrate(quick=not args.full, seed=args.seed,
                                 iters=args.iters, measure=measure,
                                 meta_extra=dict(stub=True) if args.stub
                                 else None, verbose=True)
    path = args.out or default_table_path()
    table.save(path)
    kind = "stubbed " if args.stub else ""
    print(f"calibrated {len(table.entries)} {kind}grid points "
          f"({'full' if args.full else 'quick'} grid) -> {path}")
    return 0


def cmd_show(args) -> int:
    table = _load(args.table)
    if table is None:
        print("no calibration table found (run `python -m repro.tune "
              "calibrate` first); dispatch uses the static VMEM model")
        return 1
    print(f"schema_version={table.schema_version}")
    for k, v in sorted(table.meta.items()):
        print(f"meta.{k}={v}")
    if "upgraded_from_schema" in table.meta:
        print("note: table pre-dates the current backend set (the newest "
              "of the rank-tiled / bf16 / in-kernel-gather / out-of-core "
              "gather-stream backends are unmeasured, and factor_rows / "
              "stream_window_tiles may be unrecorded); re-run "
              "`python -m repro.tune calibrate` to time them")
    for key in table.shape_keys():
        nmodes, rank, blk, tile_rows = key
        agg = aggregate_timings(table, key)
        timings = " ".join(f"{b}={agg[b] * 1e3:.2f}ms"
                           for b in sorted(agg))
        print(f"nmodes={nmodes} rank={rank} blk={blk} "
              f"tile_rows={tile_rows} best={measured_best(agg)} {timings}")
    return 0


def cmd_check(args) -> int:
    table = _load(args.table)
    if table is None:
        print("no calibration table found; nothing to check")
        return 1
    bad = 0
    empty = CalibrationTable(entries=[])
    for key in table.shape_keys():
        nmodes, rank, blk, tile_rows = key
        cmp = compare_dispatch(table, key)
        kw = dict(nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows)
        model_best = table.best_backend(**kw)
        want_model = measured_best(cmp["agg"])
        fallback = kops.select_backend(
            "auto", table=empty, factor_rows=cmp["factor_rows"], **kw)
        ok = (model_best == want_model
              and cmp["calibrated"] == cmp["oracle"]
              and fallback == cmp["static"])
        bad += not ok
        print(f"{'ok ' if ok else 'BAD'} nmodes={nmodes} rank={rank} "
              f"blk={blk} tile_rows={tile_rows}: model={model_best} "
              f"(measured {want_model}), dispatch={cmp['calibrated']} "
              f"(measured {cmp['oracle']}), static={cmp['static']} "
              f"(empty-table fallback {fallback})")
    print(f"{len(table.shape_keys()) - bad}/{len(table.shape_keys())} "
          "dispatch keys consistent")
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("calibrate", help="measure backends, save a table")
    c.add_argument("--quick", action="store_true", default=True,
                   help="small grid (default)")
    c.add_argument("--full", action="store_true",
                   help="full grid (slow in interpret mode)")
    c.add_argument("--stub", action="store_true",
                   help="deterministic traffic-model pseudo-timings "
                        "instead of running kernels (CI schema/CLI smoke)")
    c.add_argument("--out", default=None,
                   help=f"output path (default {default_table_path()})")
    c.add_argument("--seed", type=int, default=0)
    c.add_argument("--iters", type=int, default=2)
    c.set_defaults(fn=cmd_calibrate)

    s = sub.add_parser("show", help="print a saved calibration table")
    s.add_argument("--table", default=None,
                   help="table path (default: newest in experiments/tune)")
    s.set_defaults(fn=cmd_show)

    k = sub.add_parser("check",
                       help="verify dispatch matches the measured argmin")
    k.add_argument("--table", default=None,
                   help="table path (default: newest in experiments/tune)")
    k.set_defaults(fn=cmd_check)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
