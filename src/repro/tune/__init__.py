"""repro.tune — measured autotuning for the Dynasor MTTKRP runtime.

The ``auto`` dispatch in ``kernels.mttkrp.ops`` and the exchange sizing
in ``core.distributed`` were originally driven by static models (a VMEM
working-set estimate and a worst-case bucket capacity). This package
replaces both with *measurements*:

  * :mod:`repro.tune.microbench` — times every backend (the
    ``kernels.mttkrp.ops.BACKENDS`` family — fused, rank-tiled fused,
    bf16-gather fused, the in-kernel-gather trio, materialized, ref —
    plus ``segsum``) over a grid of ``(nmodes, rank, blk, tile_rows,
    density)`` on the current host;
  * :mod:`repro.tune.table` — the versioned JSON calibration table
    those timings are saved into (``experiments/tune/``), with a
    registry that falls back deterministically to the static model when
    no table exists;
  * :mod:`repro.tune.model` — a cost model that interpolates the table
    to unseen configurations and plans per-mode
    ``(backend, blk, tile_rows)`` for ``DynasorRuntime``;
  * :mod:`repro.tune.cli` — ``python -m repro.tune calibrate|show|check``.

Tuning workflow
---------------

1. **Calibrate once per host** (writes ``experiments/tune/*.json``)::

       python -m repro.tune calibrate --quick     # or --full
       python -m repro.tune show                  # inspect the table
       python -m repro.tune check                 # dispatch == measured argmin

2. **Decompose with a tuned runtime** — the table steers the backend
   per mode, the tile shapes, and (independently of the table) each
   remap exchange is sized to its own transition::

       from repro.core import distributed as dist
       from repro.core.cpals import cp_als_distributed
       from repro.tune.table import find_table

       table = find_table()                       # None -> static model
       rt, packed = dist.prepare_runtime(ft, rank=32, table=table)
       res = cp_als_distributed(ft, 32, mesh, backend="auto", table=table)

3. **Single calls** — pass the table straight to the dispatch::

       from repro.kernels.mttkrp import ops as kops
       kops.select_backend("auto", nmodes=4, rank=64, table=table)

With ``table=None`` every decision is bit-identical to the static
model, so untuned hosts behave exactly as before calibration.
"""
from .microbench import (BACKENDS, GridPoint, calibrate, default_grid,
                         stub_measure)
from .model import CostModel, compare_dispatch, plan_modes
from .table import (AUTO_BACKENDS, COMPAT_SCHEMA_VERSIONS, OPS_BACKENDS,
                    SCHEMA_VERSION, CalibrationEntry, CalibrationTable,
                    SchemaVersionError, aggregate_timings,
                    default_table_path, find_table, key_factor_rows,
                    load_table, measured_best)

__all__ = [
    "BACKENDS",
    "OPS_BACKENDS",
    "AUTO_BACKENDS",
    "COMPAT_SCHEMA_VERSIONS",
    "GridPoint",
    "calibrate",
    "default_grid",
    "stub_measure",
    "key_factor_rows",
    "CostModel",
    "compare_dispatch",
    "plan_modes",
    "SCHEMA_VERSION",
    "CalibrationEntry",
    "CalibrationTable",
    "SchemaVersionError",
    "aggregate_timings",
    "measured_best",
    "default_table_path",
    "find_table",
    "load_table",
]
