"""Cost model: interpolate a calibration table to unseen configurations.

The table holds measurements on a discrete grid; the dispatch needs an
answer for *any* ``(nmodes, rank, blk, tile_rows)``. The model:

  * groups entries by ``(nmodes, blk, tile_rows, density)``;
  * within a group, interpolates backend time piecewise-linearly in
    ``log2(rank)`` (spMTTKRP traffic — and therefore time — is linear in
    R, so log-spaced rank knots interpolate well), clamped at the ends;
  * off-grid ``(nmodes, blk, tile_rows)`` resolve to the nearest
    measured group: exact ``nmodes`` preferred, then smallest log-ratio
    distance on ``(blk, tile_rows)``;
  * ``density=None`` aggregates over the measured densities (median),
    so an in-grid query reproduces the measured argmin exactly.

Every query can return ``None`` (table can't answer — e.g. no entries,
or a backend never measured); callers then fall back to the static VMEM
model, bit-identical to the untuned dispatch.

:func:`plan_modes` turns a table into per-mode tuned
``(backend, blk, tile_rows)`` plans for ``DynasorRuntime``.
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from ..core.distributed import ModePlan
from ..kernels.mttkrp.ops import (AUTO_BACKENDS, MIN_MXU_RANK,
                                  MXU_RANK_MULTIPLE, padded_rank,
                                  select_backend)
from ..oocore import planner as _planner

__all__ = ["CostModel", "compare_dispatch", "plan_modes"]


def _feasible(backends, nmodes: int, rank: int, blk: int, tile_rows: int,
              *, covered: bool, factor_rows=None):
    """Filter ``backends`` by the same hard constraints select_backend's
    table path applies: per-family VMEM feasibility via the one
    ``repro.oocore`` residency authority
    (:func:`repro.oocore.planner.backend_fits` — the gather family,
    streaming included, needs ``factor_rows`` to certify; ``None`` rules
    it out), and no MXU one-hot backend below ``MIN_MXU_RANK`` unless
    that rank was actually measured (``covered`` — below-grid
    extrapolation is not evidence)."""
    out = []
    for b in backends:
        if rank < MIN_MXU_RANK and not covered and b.startswith("pallas"):
            continue
        if not _planner.backend_fits(b, nmodes=nmodes, rank=rank, blk=blk,
                                     tile_rows=tile_rows,
                                     factor_rows=factor_rows):
            continue
        out.append(b)
    return out


class CostModel:
    """Interpolating view over a :class:`repro.tune.table.CalibrationTable`."""

    def __init__(self, table):
        entries = getattr(table, "entries", table)
        # (nmodes, blk, tile_rows) -> density -> backend -> {rank: seconds}
        groups: dict = {}
        backends: set[str] = set()
        for e in entries:
            key = (e.nmodes, e.blk, e.tile_rows)
            by_density = groups.setdefault(key, {})
            by_backend = by_density.setdefault(float(e.density), {})
            for b, t in e.timings_s.items():
                by_backend.setdefault(b, {})[e.rank] = float(t)
                backends.add(b)
        # Freeze each {rank: t} map into sorted knot arrays for np.interp.
        self._groups = {
            key: {
                d: {
                    b: (np.array(sorted(rt)),
                        np.array([rt[r] for r in sorted(rt)]))
                    for b, rt in bb.items()
                }
                for d, bb in by_density.items()
            }
            for key, by_density in groups.items()
        }
        self.backends = tuple(sorted(backends))

    # -- group / density resolution ---------------------------------------

    def _nearest_group(self, nmodes: int, blk: int, tile_rows: int):
        if not self._groups:
            return None
        exact = (nmodes, blk, tile_rows)
        if exact in self._groups:
            return self._groups[exact]

        def dist(key):
            n, b, t = key
            shape_d = (abs(math.log2(b / blk))
                       + abs(math.log2(t / tile_rows)))
            return (abs(n - nmodes), shape_d, key)

        return self._groups[min(self._groups, key=dist)]

    @staticmethod
    def _nearest_density(by_density: dict, density: float):
        return by_density[min(
            by_density,
            key=lambda d: (abs(math.log(max(d, 1e-9) / max(density, 1e-9))),
                           d),
        )]

    # -- queries -----------------------------------------------------------

    def predict(self, backend: str, *, nmodes: int, rank: int, blk: int,
                tile_rows: int, density: float | None = None) -> float | None:
        """Interpolated seconds for one backend, or ``None`` if unanswerable."""
        group = self._nearest_group(nmodes, blk, tile_rows)
        if group is None:
            return None
        if density is None:
            curves = [bb[backend] for bb in group.values() if backend in bb]
        else:
            bb = self._nearest_density(group, density)
            curves = [bb[backend]] if backend in bb else []
        if not curves:
            return None
        lr = math.log2(max(rank, 1))
        vals = [float(np.interp(lr, np.log2(ranks), times))
                for ranks, times in curves]
        return float(np.median(vals))

    def covers(self, *, nmodes: int, rank: int, blk: int,
               tile_rows: int) -> bool:
        """Is ``rank`` within the measured knot span of the resolved group?

        Queries outside the span are clamped extrapolations — fine for
        large ranks (the VMEM guard protects the only hazard there) but
        not a license to override the static rank<8 MXU-padding rule
        with timings never measured at tiny ranks; the dispatch checks
        this before letting a table answer below that threshold.
        """
        group = self._nearest_group(nmodes, blk, tile_rows)
        if group is None:
            return False
        knots = [r for bb in group.values()
                 for ranks, _ in bb.values() for r in ranks]
        return bool(knots) and min(knots) <= rank <= max(knots)

    def best_backend(self, *, nmodes: int, rank: int, blk: int,
                     tile_rows: int, allowed: Sequence[str] | None = None,
                     density: float | None = None) -> str | None:
        """Argmin backend over ``allowed`` (ties break by name), or ``None``."""
        candidates = self.backends if allowed is None else tuple(allowed)
        scored = []
        for b in sorted(set(candidates)):
            t = self.predict(b, nmodes=nmodes, rank=rank, blk=blk,
                             tile_rows=tile_rows, density=density)
            if t is not None:
                scored.append((t, b))
        if not scored:
            return None
        return min(scored)[1]

    def shape_candidates(self, nmodes: int) -> list[tuple[int, int]]:
        """Measured ``(blk, tile_rows)`` pairs, preferring exact ``nmodes``."""
        exact = sorted({(b, t) for (n, b, t) in self._groups if n == nmodes})
        if exact:
            return exact
        return sorted({(b, t) for (_, b, t) in self._groups})


def compare_dispatch(table, key) -> dict:
    """Static vs. calibrated vs. oracle decision at one dispatch key.

    The one shared definition of the consistency standard, used by both
    ``repro.tune check`` and ``benchmarks.bench_dispatch`` so they can
    never disagree. ``oracle`` is the measured argmin over the backends
    ``auto`` may actually pick (the numerics-preserving
    ``AUTO_BACKENDS`` — a measured-fast bf16 is not a valid target for a
    dispatch that must not change results); when the table timed none of
    them, the static rule *is* the standard (the table cannot answer).
    """
    from .table import (AUTO_BACKENDS, aggregate_timings, key_factor_rows,
                        measured_best)

    nmodes, rank, blk, tile_rows = key
    agg = aggregate_timings(table, key)
    # The measured case's factor sizes (v3 entries) — without them the
    # dispatch can't certify gather feasibility, so static/calibrated
    # both stay off the gather family, exactly like a live dispatch
    # whose caller doesn't know the factor shapes.
    factor_rows = key_factor_rows(table, key)
    kw = dict(nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
              factor_rows=factor_rows)
    static = select_backend("auto", **kw)
    calibrated = select_backend("auto", table=table, **kw)
    oracle = measured_best(agg, allowed=AUTO_BACKENDS)
    if oracle is None:
        oracle = static
    return dict(agg=agg, static=static, calibrated=calibrated,
                oracle=oracle, factor_rows=factor_rows)


def plan_modes(table, ft, rank: int, *,
               allowed: Sequence[str] | None = None,
               num_workers: int | None = None,
               ordering: str = "none") -> tuple[ModePlan, ...] | None:
    """Tuned per-mode ``(backend, blk, tile_rows)`` plans for a tensor.

    For every output mode the model scores each measured ``(blk,
    tile_rows)`` shape × backend at that mode's own nonzero density
    (per-worker nonzeros per ``blk × row-tile`` block — skewed modes
    have emptier blocks) and keeps the global argmin. Returns ``None``
    when the table cannot answer (empty / no overlapping backends), so
    callers keep the static configuration.

    With ``allowed=None`` the candidate pool is every measured backend
    *except* the bf16-gather variants — like ``select_backend``'s table
    path, an automatic planner must not change numerics on timing
    evidence. Pass ``allowed`` explicitly (e.g.
    ``table.model.backends``) to let a bf16-opted-in runtime plan with
    them.

    ``ordering`` (:data:`repro.reorder.ORDERINGS`) is carried verbatim
    into every plan — the locality policy is a numerics-order choice
    the caller owns, not something the cost model selects.
    """
    model = table if isinstance(table, CostModel) else CostModel(table)
    D = num_workers if num_workers is not None else ft.params.num_workers
    nnz_per_worker = max(1.0, ft.nnz / max(D, 1))
    plans = []
    for n in range(ft.nmodes):
        rows_per_worker = max(1, ft.modes[n].rows_cap)
        # Replicated input-factor rows this mode's gather kernel would
        # hold resident (per-mode i_pad over non-output modes; the final
        # tile-rounding of rows_cap adds at most D·tile_rows per mode —
        # noise against the VMEM budget). The per-mode tuple lets the
        # residency planner size exact stream windows.
        factor_rows = tuple(D * ft.modes[w].rows_cap
                            for w in range(ft.nmodes) if w != n)
        best = None
        for blk, tile_rows in model.shape_candidates(ft.nmodes):
            num_tiles = max(1, -(-rows_per_worker // tile_rows))
            density = nnz_per_worker / (num_tiles * blk)
            # Default pool = measured ∩ (AUTO_BACKENDS + segsum): the one
            # numerics-preserving policy defined in ops.py, plus the
            # distributed layer's own segsum path.
            cand_allowed = (
                [b for b in model.backends
                 if b == "segsum" or b in AUTO_BACKENDS]
                if allowed is None else allowed)
            cand_allowed = _feasible(
                cand_allowed, ft.nmodes, rank, blk, tile_rows,
                covered=model.covers(nmodes=ft.nmodes, rank=rank, blk=blk,
                                     tile_rows=tile_rows),
                factor_rows=factor_rows)
            choice = model.best_backend(
                nmodes=ft.nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
                allowed=cand_allowed, density=density)
            if choice is None:
                continue
            t = model.predict(choice, nmodes=ft.nmodes, rank=rank, blk=blk,
                              tile_rows=tile_rows, density=density)
            cand = (t, blk, tile_rows, choice)
            if best is None or cand < best:
                best = cand
        if best is None:
            return None
        _, blk, tile_rows, backend = best
        slabs = (padded_rank(rank) // MXU_RANK_MULTIPLE
                 if backend in ("pallas_fused_tiled",
                                "pallas_fused_gather_tiled",
                                _planner.STREAM_BACKEND) else 1)
        window = (tuple(_planner.stream_window_tiles(blk, r)
                        for r in factor_rows)
                  if backend == _planner.STREAM_BACKEND else ())
        plans.append(ModePlan(backend=backend, blk=blk, tile_rows=tile_rows,
                              rank_slabs=slabs, window_tiles=window,
                              ordering=ordering))
    return tuple(plans)
