"""Microbenchmark harness: measure every MTTKRP backend on a config grid.

Each grid point is a synthetic per-device mode step — a sorted,
power-law-skewed nonzero stream of the requested density plus random
factor matrices — timed through every backend:

  * the ``kernels.mttkrp.ops.BACKENDS`` family (``pallas_fused``,
    ``pallas``, ``pallas_fused_tiled``, ``pallas_fused_bf16``, the
    in-kernel-gather ``pallas_fused_gather`` trio, the out-of-core
    ``pallas_fused_gather_stream``, ``ref``) via ``mttkrp_device_step``
    (interpret mode on CPU — the timings rank the backends' *emulated*
    cost; on a real TPU the same harness calibrates compiled kernels);
  * ``segsum`` — the plain-XLA segment-sum path used by
    ``core.distributed.device_mttkrp``.

The bf16-gather timings are recorded like any other backend but the
``auto`` dispatch never follows them (numerics opt-in — see
``ops.AUTO_BACKENDS``); they exist so ``repro.tune show`` / the bench
suite can report what explicit bf16 opt-in would buy. Every v3 entry
also records ``factor_rows`` (see :func:`case_factor_rows`) so the
dispatch can certify the gather family's VMEM feasibility when
following the table.

The ``measure`` hook is injectable (``measure(backend, point) ->
seconds``) so tests calibrate with deterministic stub timings and the
table/selection logic stays exactly the code path production uses.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tensors import _powerlaw_columns
from ..kernels.mttkrp import ops as kops
from ..obs import counters as _obs
from ..obs import tracer as _tracer_mod
from .table import CalibrationEntry, CalibrationTable, host_meta

__all__ = [
    "BACKENDS",
    "GridPoint",
    "default_grid",
    "make_case",
    "case_factor_rows",
    "case_stream_window_tiles",
    "stub_measure",
    "calibrate",
]

# Everything the microbench times: the ops-runnable backends + the
# distributed layer's plain-XLA segsum path.
BACKENDS = kops.BACKENDS + ("segsum",)

# Dimension of the non-output modes in a synthetic case (gather breadth).
_SIDE_DIM = 64
# Output row tiles per case: rows_cap = _NUM_TILES * tile_rows.
_NUM_TILES = 4


@dataclasses.dataclass(frozen=True)
class GridPoint:
    """One microbenchmark configuration."""

    nmodes: int
    rank: int
    blk: int
    tile_rows: int
    density: float          # mean nonzeros per (blk × row-tile) block


def default_grid(quick: bool = True) -> list[GridPoint]:
    """The calibration grid; ``quick`` keeps interpret-mode runs short."""
    if quick:
        nmodes, ranks = (3, 4), (16, 128)
        blks, tiles, densities = (32,), (8,), (0.5, 2.0)
    else:
        # rank 512 = 4 rank slabs: the full grid actually exercises the
        # tiled kernel's slab loop, so its knots aren't extrapolations.
        nmodes, ranks = (3, 4, 5), (16, 32, 64, 128, 256, 512)
        blks, tiles, densities = (32, 128), (8, 16), (0.25, 1.0, 4.0)
    return [
        GridPoint(nmodes=n, rank=r, blk=b, tile_rows=t, density=d)
        for n in nmodes for r in ranks for b in blks for t in tiles
        for d in densities
    ]


def make_case(point: GridPoint, *, seed: int = 0):
    """Synthetic sorted stream + factors for one grid point.

    Returns ``(idx, val, valid, factors, rows_cap)`` with output mode 0:
    ``density`` sets the nonzero count per output-row tile relative to
    ``blk``, and rows are power-law skewed (hub structure, like the
    FROSTT tensors the dispatch will face).
    """
    rng = np.random.default_rng(seed)
    rows_cap = _NUM_TILES * point.tile_rows
    nnz = max(8, int(point.density * _NUM_TILES * point.blk))
    # Truncated-Pareto skew, same draw as the tensor generators.
    rows = np.sort(_powerlaw_columns(rng, (rows_cap,), nnz, 1.3)[:, 0])
    cols = [rows] + [rng.integers(0, _SIDE_DIM, size=nnz)
                     for _ in range(point.nmodes - 1)]
    idx = jnp.asarray(np.stack(cols, axis=1).astype(np.int32))
    val = jnp.asarray(rng.standard_normal(nnz).astype(np.float32))
    valid = jnp.ones((nnz,), bool)
    dims = (rows_cap,) + (_SIDE_DIM,) * (point.nmodes - 1)
    factors = [jnp.asarray(rng.standard_normal((d, point.rank)), jnp.float32)
               for d in dims]
    return idx, val, valid, factors, rows_cap


def case_factor_rows(point: GridPoint) -> int:
    """Total input-factor rows of :func:`make_case`'s synthetic case.

    The non-output modes all have ``_SIDE_DIM`` rows, so this is the
    resident set the in-kernel gather backends hold; it is recorded in
    every v3 calibration entry so the dispatch can check gather
    feasibility when following the table.
    """
    return (point.nmodes - 1) * _SIDE_DIM


def case_stream_window_tiles(point: GridPoint) -> int:
    """Per-input-mode stream-window width of the synthetic case.

    What ``pallas_fused_gather_stream`` holds in VMEM per mode when it
    runs the case: the ``repro.oocore`` planner's correctness bound for
    ``_SIDE_DIM``-row factors at this block size. Recorded in every v4
    calibration entry so a stream timing carries its window context.
    """
    from ..oocore.planner import stream_window_tiles

    return stream_window_tiles(point.blk, _SIDE_DIM)


def stub_measure(backend: str, point: GridPoint) -> float:
    """Deterministic pseudo-timings from the traffic model (no kernels run).

    For schema/CLI smoke runs (``python -m repro.tune calibrate --stub``
    in CI) and anywhere a full interpret-mode calibration is too slow:
    the relative ordering mirrors the counted per-nonzero HBM traffic of
    each backend (gather < fused < materialized, bf16 halving gather
    bytes, segment-sum paths winning at small rank), so the resulting
    table exercises exactly the production table/model/dispatch code
    paths with self-consistent argmins.
    """
    k = (point.nmodes - 1) * point.rank * (1.0 + 0.1 * point.density)
    return {
        "ref": 8e-4 * point.rank,
        "segsum": 6e-4 * point.rank,
        "pallas": 0.05 + 2e-4 * k + 1e-5 * point.blk,
        "pallas_fused": 0.09 + 7e-5 * k + 2e-5 * point.tile_rows,
        "pallas_fused_tiled": 0.095 + 7e-5 * k + 2e-5 * point.tile_rows,
        "pallas_fused_bf16": 0.04 + 4e-5 * k + 2e-5 * point.tile_rows,
        "pallas_fused_gather": 0.07 + 5e-5 * k + 2e-5 * point.tile_rows,
        "pallas_fused_gather_tiled":
            0.075 + 5e-5 * k + 2e-5 * point.tile_rows,
        "pallas_fused_gather_bf16":
            0.03 + 3e-5 * k + 2e-5 * point.tile_rows,
        # Streaming re-fetches window tiles per block: slower than the
        # resident gathers, still ahead of the materializing fused path
        # on traffic — mirroring the counted per-nonzero bytes.
        "pallas_fused_gather_stream":
            0.08 + 6e-5 * k + 2e-5 * point.tile_rows + 1e-5 * point.blk,
    }[backend]


def _segsum_step(idx, val, valid, factors, rows_cap: int):
    """The plain-XLA backend ``core.distributed.device_mttkrp`` uses."""
    local_row = jnp.where(valid, idx[:, 0], 0)
    ell = jnp.where(valid, val, 0.0)[:, None].astype(factors[0].dtype)
    for w in range(1, idx.shape[1]):
        ell = ell * jnp.take(factors[w], idx[:, w], axis=0)
    return jax.ops.segment_sum(
        ell.astype(jnp.float32), local_row, num_segments=rows_cap,
        indices_are_sorted=True,
    )


def _time(fn: Callable, *, warmup: int, iters: int) -> float:
    # Shared steady-state idiom (repro.obs.prof.harness): fenced warmup
    # + repeats, robust median with outlier rejection at iters >= 4 —
    # calibration runs long enough to catch a GC pause now reject it
    # instead of baking it into the table's argmins.
    from ..obs.prof import harness as _harness

    return _harness.measure_steady(fn, warmup=warmup, repeats=iters).median_s


def _real_measure(*, seed: int, warmup: int, iters: int) -> Callable:
    """Default ``measure(backend, point)``: actually run the kernels."""
    cases: dict = {}

    def measure(backend: str, point: GridPoint) -> float:
        if point not in cases:
            cases[point] = make_case(point, seed=seed)
        idx, val, valid, factors, rows_cap = cases[point]
        if backend == "segsum":
            step = jax.jit(_segsum_step, static_argnames=("rows_cap",))
            fn = lambda: step(idx, val, valid, factors, rows_cap=rows_cap)
        else:
            # Execution mode comes from the repro.runtime.execution
            # policy: interpret on CPU hosts, compiled on TPU — the same
            # resolution the production dispatch uses, so a table
            # calibrated on hardware times real Mosaic kernels.
            fn = lambda: kops.mttkrp_device_step(
                idx, val, valid, factors, mode=0, rows_cap=rows_cap,
                row_offset=0, blk=point.blk, tile_rows=point.tile_rows,
                backend=backend,
            )
        return _time(fn, warmup=warmup, iters=iters)

    return measure


def calibrate(
    grid: Iterable[GridPoint] | None = None,
    *,
    quick: bool = True,
    backends: Sequence[str] = BACKENDS,
    measure: Callable | None = None,
    seed: int = 0,
    warmup: int = 1,
    iters: int = 2,
    meta_extra: dict | None = None,
    verbose: bool = False,
) -> CalibrationTable:
    """Measure ``backends`` over ``grid`` and return a CalibrationTable.

    ``measure(backend, point) -> seconds`` defaults to real wall-clock
    measurement on this host; tests pass a deterministic stub.
    """
    points = list(grid) if grid is not None else default_grid(quick=quick)
    if measure is None:
        measure = _real_measure(seed=seed, warmup=warmup, iters=iters)
    tracer = _tracer_mod.get_tracer()
    entries = []
    measured_s: dict[str, float] = {}
    with tracer.span("calibrate", points=len(points),
                     backends=len(backends)):
        for point in points:
            timings = {}
            with tracer.span("point", nmodes=point.nmodes, rank=point.rank,
                             blk=point.blk, tile_rows=point.tile_rows,
                             density=point.density):
                for b in backends:
                    with tracer.span("measure", backend=b):
                        timings[b] = float(measure(b, point))
                    _obs.add("tune.measure_s", timings[b], backend=b)
                    measured_s[b] = measured_s.get(b, 0.0) + timings[b]
                _obs.add("tune.points")
            entries.append(CalibrationEntry(
                nmodes=point.nmodes, rank=point.rank, blk=point.blk,
                tile_rows=point.tile_rows, density=point.density,
                timings_s=timings, factor_rows=case_factor_rows(point),
                stream_window_tiles=case_stream_window_tiles(point),
            ))
            if verbose:
                best = entries[-1].best
                print(f"  {point}: best={best} "
                      + " ".join(f"{b}={t:.4f}s"
                                 for b, t in timings.items()),
                      flush=True)
    # The table carries its own observability summary: how much wall
    # time the calibration spent per backend and how many spans the
    # tracer recorded. A committed table thereby documents its
    # measurement cost, not just its argmins.
    obs_meta = {
        "points": len(points),
        "measure_s": {b: round(s, 6) for b, s in sorted(measured_s.items())},
        "spans": len(tracer.records) if tracer.enabled else 0,
    }
    meta = host_meta(dict(meta_extra or {}, quick=quick, seed=seed,
                          obs=obs_meta))
    return CalibrationTable(entries=entries, meta=meta)
