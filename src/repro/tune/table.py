"""Calibration table: the versioned on-disk artifact of ``repro.tune``.

A table is a list of grid entries, one per measured configuration
``(nmodes, rank, blk, tile_rows, density)``, each carrying the median
wall seconds of every MTTKRP backend on that configuration. Tables are
saved as JSON under ``experiments/tune/`` and loaded through a small
registry (:func:`find_table`) that returns the newest valid table — or
``None``, in which case every consumer falls back to the static VMEM
model (bit-identical to the untuned dispatch).

Schema versioning is strict with an explicit compatibility window:
:meth:`CalibrationTable.from_json` accepts the current
:data:`SCHEMA_VERSION` plus the versions in :data:`COMPAT_SCHEMA_VERSIONS`
(upgraded in-memory on load) and refuses anything else, so a stale table
from an incompatible layout can never silently steer the dispatch.

Version history (full field reference in ``experiments/tune/README.md``):
  * v1 — PR 2 original: grid entries over the 4 original backends.
  * v2 — rank-tiled + bf16 backends (``pallas_fused_tiled``,
    ``pallas_fused_bf16``) join the measured set. Entry structure is
    unchanged (``timings_s`` is an open backend→seconds map), so v1
    tables load under v2 — they simply carry no timings for the new
    backends and the model answers ``None`` for them.
  * v3 — in-kernel gather backends (``pallas_fused_gather`` and its
    tiled/bf16 compositions) join the measured set, and each entry
    records ``factor_rows`` — the total input-factor rows of the
    measured synthetic case — because the gather family's VMEM
    feasibility depends on factor residency, not just the dispatch
    shape key. v1/v2 tables load under v3 with ``factor_rows=None``
    (and no gather timings), so the dispatch simply never follows the
    table onto a gather backend for them.
  * v4 — the out-of-core streaming backend
    (``pallas_fused_gather_stream``, ``repro.oocore``) joins the
    measured set, and each entry records ``stream_window_tiles`` — the
    per-input-mode VMEM tile-window width of the measured case —
    because a gather-stream timing is only transferable to dispatch
    keys whose planned window is comparable. v1–v3 tables load under
    v4 with ``stream_window_tiles=None`` (and no stream timings).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import platform
from typing import Iterable, Sequence

from ..kernels.mttkrp import ops as _kops
from ..obs import counters as _obs
from ..resilience import faults as _faults

__all__ = [
    "SCHEMA_VERSION",
    "COMPAT_SCHEMA_VERSIONS",
    "OPS_BACKENDS",
    "AUTO_BACKENDS",
    "SchemaVersionError",
    "CalibrationEntry",
    "CalibrationTable",
    "aggregate_timings",
    "key_factor_rows",
    "measured_best",
    "default_table_path",
    "find_table",
    "load_table",
]

SCHEMA_VERSION = 4

# Older schema versions from_json still understands (upgraded on load).
COMPAT_SCHEMA_VERSIONS = (1, 2, 3)

# Backends ``kernels.mttkrp.ops.mttkrp_device_step`` can run itself —
# ``segsum`` dispatches one layer up (core.distributed.device_mttkrp).
# Single source of truth is ops.py so the tuner can never drift from
# the dispatch.
OPS_BACKENDS = _kops.BACKENDS

# The numerics-preserving subset ``auto`` may resolve to (see ops.py).
AUTO_BACKENDS = _kops.AUTO_BACKENDS

# Where `python -m repro.tune calibrate` writes and `find_table` searches.
DEFAULT_TABLE_DIR = os.path.join("experiments", "tune")


class SchemaVersionError(ValueError):
    """Raised when a table file's schema_version is not the current one."""


@dataclasses.dataclass(frozen=True)
class CalibrationEntry:
    """One measured grid point: per-backend median seconds."""

    nmodes: int
    rank: int
    blk: int
    tile_rows: int
    density: float               # mean nonzeros per (blk × row-tile) block
    timings_s: dict              # backend name -> median wall seconds
    # Total input-factor rows (Σ I over non-output modes) of the measured
    # case — what the in-kernel gather family's VMEM predicate needs.
    # None on entries loaded from pre-v3 tables: the dispatch then never
    # follows the table onto a gather backend for this key.
    factor_rows: int | None = None
    # Per-input-mode VMEM tile-window width of the measured case's
    # gather-stream run (``repro.oocore.planner.stream_window_tiles``) —
    # context for interpreting the ``pallas_fused_gather_stream``
    # timing. None on entries loaded from pre-v4 tables (which carry no
    # stream timings anyway).
    stream_window_tiles: int | None = None

    @property
    def best(self) -> str:
        """Measured-argmin backend (deterministic tie-break by name)."""
        return min(sorted(self.timings_s), key=lambda b: self.timings_s[b])

    @property
    def shape_key(self) -> tuple[int, int, int, int]:
        """The dispatch-relevant key (density aggregated out by the model)."""
        return (self.nmodes, self.rank, self.blk, self.tile_rows)

    def to_json(self) -> dict:
        return dict(
            nmodes=self.nmodes, rank=self.rank, blk=self.blk,
            tile_rows=self.tile_rows, density=self.density,
            timings_s={k: float(v) for k, v in self.timings_s.items()},
            factor_rows=self.factor_rows,
            stream_window_tiles=self.stream_window_tiles,
        )

    @classmethod
    def from_json(cls, obj: dict) -> "CalibrationEntry":
        factor_rows = obj.get("factor_rows")
        window = obj.get("stream_window_tiles")
        return cls(
            nmodes=int(obj["nmodes"]), rank=int(obj["rank"]),
            blk=int(obj["blk"]), tile_rows=int(obj["tile_rows"]),
            density=float(obj["density"]),
            timings_s={str(k): float(v)
                       for k, v in obj["timings_s"].items()},
            factor_rows=None if factor_rows is None else int(factor_rows),
            stream_window_tiles=None if window is None else int(window),
        )


@dataclasses.dataclass
class CalibrationTable:
    """A set of calibration entries + host metadata, JSON round-trippable."""

    entries: list
    meta: dict = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    def __post_init__(self):
        # Lazily-built CostModel, keyed on a snapshot of the entries so
        # appending/replacing entries after a query rebuilds it (value
        # comparison, not id() — object addresses can be reused).
        self._model = None
        self._model_entries = None

    # -- queries ----------------------------------------------------------

    @property
    def model(self):
        """The interpolating :class:`repro.tune.model.CostModel` (cached)."""
        if self._model is None or self._model_entries != self.entries:
            from . import model as _model  # deferred: model imports table
            self._model = _model.CostModel(self)
            self._model_entries = list(self.entries)
        return self._model

    def best_backend(self, *, nmodes: int, rank: int, blk: int,
                     tile_rows: int, allowed: Sequence[str] | None = None,
                     density: float | None = None) -> str | None:
        """Interpolated-argmin backend, or ``None`` if the table can't say.

        This is the duck-typed hook ``kernels.mttkrp.ops.select_backend``
        calls on its ``table=`` argument — ops never imports this package.
        """
        return self.model.best_backend(
            nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
            allowed=allowed, density=density,
        )

    def covers(self, *, nmodes: int, rank: int, blk: int,
               tile_rows: int) -> bool:
        """See :meth:`repro.tune.model.CostModel.covers`."""
        return self.model.covers(nmodes=nmodes, rank=rank, blk=blk,
                                 tile_rows=tile_rows)

    def shape_keys(self) -> list[tuple[int, int, int, int]]:
        """Unique dispatch keys, sorted (densities collapsed)."""
        return sorted({e.shape_key for e in self.entries})

    # -- (de)serialization -------------------------------------------------

    def to_json(self) -> dict:
        return dict(
            schema_version=self.schema_version,
            meta=dict(self.meta),
            grid=[e.to_json() for e in self.entries],
        )

    @classmethod
    def from_json(cls, obj: dict) -> "CalibrationTable":
        version = obj.get("schema_version")
        if version != SCHEMA_VERSION and version not in \
                COMPAT_SCHEMA_VERSIONS:
            raise SchemaVersionError(
                f"calibration table schema_version={version!r} is not the "
                f"supported version {SCHEMA_VERSION} (or compatible "
                f"{COMPAT_SCHEMA_VERSIONS}); re-run "
                "`python -m repro.tune calibrate`")
        entries = [CalibrationEntry.from_json(e) for e in obj.get("grid", [])]
        meta = dict(obj.get("meta", {}))
        if version != SCHEMA_VERSION:
            # Back-compat upgrade: v1 entries are structurally identical,
            # they just never measured the newer backends. Record the
            # provenance so `repro.tune show` can suggest re-calibrating.
            meta.setdefault("upgraded_from_schema", int(version))
        return cls(entries=entries, meta=meta,
                   schema_version=SCHEMA_VERSION)

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        # Registered failure boundary (repro.resilience): a table on
        # disk can be truncated or garbled — the injected
        # CorruptionFault stands in for exactly what from_json's parse
        # errors signal on real bad bytes.
        _faults.fault_site("tune.table_load")
        with open(path) as f:
            return cls.from_json(json.load(f))


def aggregate_timings(table: CalibrationTable, key) -> dict:
    """Median-over-density seconds per backend at one dispatch key."""
    import numpy as np

    entries = [e for e in table.entries if e.shape_key == key]
    backends = sorted({b for e in entries for b in e.timings_s})
    return {b: float(np.median([e.timings_s[b] for e in entries
                                if b in e.timings_s]))
            for b in backends}


def key_factor_rows(table: CalibrationTable, key) -> int | None:
    """``factor_rows`` recorded at one dispatch key (``None`` on pre-v3
    tables, or when the key was never measured) — the extra context the
    gather family's VMEM feasibility needs beyond the shape key."""
    for e in table.entries:
        if e.shape_key == key and e.factor_rows is not None:
            return int(e.factor_rows)
    return None


def measured_best(agg: dict, allowed=None) -> str | None:
    """Argmin backend among measured ones; ``None`` if none are eligible
    (e.g. a table calibrated on a backend subset disjoint from
    ``allowed``)."""
    pool = sorted(agg if allowed is None else
                  [b for b in agg if b in allowed])
    if not pool:
        return None
    return min(pool, key=lambda b: (agg[b], b))


def host_meta(extra: dict | None = None) -> dict:
    """Host fingerprint stored in ``meta`` — identifies where timings ran.

    Includes the :mod:`repro.runtime.execution` policy fingerprint
    (``execution_mode`` / resolved ``interpret`` / probe reason) so a
    table records whether its timings came from the interpreter or from
    compiled Mosaic kernels — never a hardcoded assumption.
    """
    import jax

    from ..runtime import execution

    meta = dict(
        platform=platform.platform(),
        machine=platform.machine(),
        python=platform.python_version(),
        jax=jax.__version__,
        jax_backend=jax.default_backend(),
        **execution.describe_meta(),
    )
    if extra:
        meta.update(extra)
    return meta


def default_table_path(table_dir: str = DEFAULT_TABLE_DIR) -> str:
    return os.path.join(
        table_dir, f"calibration_v{SCHEMA_VERSION}_{platform.machine()}.json")


def load_table(path: str) -> CalibrationTable:
    """Load one table file (raises on missing file / wrong schema)."""
    return CalibrationTable.load(path)


def _matches_host(meta: dict) -> bool:
    """Does a table's host fingerprint match this machine?

    Timings from another machine/backend must not silently steer the
    dispatch. Keys absent from ``meta`` are not checked (permissive for
    hand-built tables); explicit mismatches reject the table.
    """
    import jax

    current = dict(machine=platform.machine(),
                   jax_backend=jax.default_backend())
    return all(meta.get(k) in (None, v) for k, v in current.items())


def find_table(table_dir: str = DEFAULT_TABLE_DIR, *,
               match_host: bool = True) -> CalibrationTable | None:
    """Registry lookup: newest valid ``*.json`` table in ``table_dir``.

    Tables whose stored host fingerprint (machine / jax backend)
    contradicts the current host are skipped unless ``match_host=False``
    — calibrations are measurements of *a* machine and must not steer
    another one. Tables stamped ``meta.stub`` (``calibrate --stub``
    pseudo-timings for schema/CLI smoke runs) are *always* skipped: the
    registry's contract is measured calibrations, and a stub saved to
    the default path must not silently steer real dispatch; load them
    by explicit path instead. Returns ``None`` when the directory is
    missing or holds no loadable matching table — the deterministic
    signal for consumers to use the static VMEM-model dispatch
    unchanged.
    """
    paths = sorted(glob.glob(os.path.join(table_dir, "*.json")),
                   key=lambda p: (os.path.getmtime(p), p), reverse=True)
    for path in paths:
        try:
            table = CalibrationTable.load(path)
        except _faults.CorruptionFault:
            # Injected bad bytes: skip the table exactly like a real
            # parse failure — counted, never silently steering dispatch.
            _obs.add("resilience.table_fallbacks", reason="corrupt")
            continue
        except (SchemaVersionError, json.JSONDecodeError, KeyError,
                ValueError, OSError):
            _obs.add("resilience.table_fallbacks", reason="unloadable")
            continue
        if table.meta.get("stub"):
            continue
        if match_host and not _matches_host(table.meta):
            continue
        return table
    return None
