"""``python -m repro.obs`` — report / export / validate / baseline.

Subcommands:

* ``report``   — run the instrumented tiny CP-ALS workload (the baseline
  workload) and print the span tree with per-span counter deltas plus
  the counter registry.
* ``export``   — same run, written as Chrome-trace JSON
  (``--out trace.json``; load in ``chrome://tracing`` or Perfetto).
* ``validate`` — schema-check an exported trace file (stdlib only, no
  jax import); ``--expect sweep,mode,mttkrp`` additionally requires
  those span names. This is CI's trace check.
* ``baseline`` — run the counter-baseline gate (``--check``, the
  default) or rewrite the committed artifact (``--update-baseline``).
"""
import json
import os
import sys

# The instrumented workload needs a 4-device mesh; the device count is
# locked at first jax init, so set it before anything imports jax. The
# `validate` subcommand never imports jax and doesn't care.
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import argparse


def _run_instrumented():
    from . import baseline as _baseline
    from . import tracer as _tracer_mod

    tracer = _tracer_mod.Tracer()
    current = _baseline.collect(tracer=tracer)
    return tracer, current


def cmd_report(args) -> int:
    tracer, current = _run_instrumented()
    print(tracer.render())
    print()
    print("counters:")
    for k, v in sorted(current["counters"].items()):
        print(f"  {k} = {v}")
    return 0


def cmd_export(args) -> int:
    from . import baseline as _baseline

    tracer, current = _run_instrumented()
    path = tracer.write_chrome_trace(
        args.out, meta={"workload": _baseline.WORKLOAD,
                        "counters": current["counters"]})
    print(f"wrote {path}: {len(tracer.records)} spans, "
          f"{len(current['counters'])} counted metrics")
    return 0


def cmd_validate(args) -> int:
    from .tracer import validate_chrome_trace

    with open(args.path, encoding="utf-8") as f:
        trace = json.load(f)
    expect = [s for s in (args.expect or "").split(",") if s]
    errors = validate_chrome_trace(trace, expect_names=expect)
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        return 1
    n = len(trace["traceEvents"])
    print(f"trace valid: {n} events"
          + (f", all expected span names present ({args.expect})"
             if expect else ""))
    return 0


def cmd_baseline(args) -> int:
    from . import baseline as _baseline

    status, messages = _baseline.run_gate(
        update=args.update_baseline,
        path=args.path or _baseline.BASELINE_PATH)
    for m in messages:
        print(m)
    return status


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("report", help="print the instrumented run's span tree")

    p = sub.add_parser("export", help="export a Chrome-trace JSON")
    p.add_argument("--out", default="obs_trace.json")

    p = sub.add_parser("validate", help="schema-check a trace file")
    p.add_argument("path")
    p.add_argument("--expect", default="",
                   help="comma-separated span names that must appear")

    p = sub.add_parser("baseline", help="counter-baseline gate")
    g = p.add_mutually_exclusive_group()
    g.add_argument("--check", action="store_true", default=True)
    g.add_argument("--update-baseline", action="store_true")
    p.add_argument("--path", default=None,
                   help="baseline artifact path (default: the committed "
                        "experiments/obs/BASELINE_counters.json)")

    args = ap.parse_args(argv)
    return {"report": cmd_report, "export": cmd_export,
            "validate": cmd_validate, "baseline": cmd_baseline}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
