"""repro.obs — span tracing, unified counters, and the CI baseline gate.

The observability layer the rest of the stack emits into:

* :mod:`repro.obs.counters` — the process-wide :class:`CounterRegistry`
  with its closed, documented namespace (``oocore.dma.*``,
  ``remap.a2a.*``, ``dispatch.backend``, …);
* :mod:`repro.obs.tracer` — nested wall-time spans with per-span counter
  deltas, Chrome-trace/Perfetto export, no-op by default;
* :mod:`repro.obs.baseline` — the deterministic counted-metric baseline
  CI gates on (``experiments/obs/BASELINE_counters.json``).

CLI: ``python -m repro.obs report|export|validate|baseline``.
Docs: ``docs/observability.md``.
"""
from .counters import (
    NAMESPACES,
    CounterRegistry,
    add,
    counter_key,
    get_registry,
    record_remap_exchange,
    record_stream_stats,
    split_key,
    use_registry,
)
from .tracer import (
    NULL,
    NullTracer,
    SpanRecord,
    Tracer,
    get_tracer,
    sanitize_span_name,
    set_tracer,
    unique_path,
    use_tracer,
    validate_chrome_trace,
)

__all__ = [
    "NAMESPACES",
    "CounterRegistry",
    "add",
    "counter_key",
    "get_registry",
    "record_remap_exchange",
    "record_stream_stats",
    "split_key",
    "use_registry",
    "NULL",
    "NullTracer",
    "SpanRecord",
    "Tracer",
    "get_tracer",
    "sanitize_span_name",
    "set_tracer",
    "unique_path",
    "use_tracer",
    "validate_chrome_trace",
]
