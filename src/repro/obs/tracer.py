"""Nested span tracing with counter attachment and Chrome-trace export.

A :class:`Tracer` records a forest of wall-time spans::

    with tracer.span("sweep", sweep=0):
        with tracer.span("mode", mode=2):
            with tracer.span("mttkrp"):
                ...

Spans carry an ``args`` dict and, on exit, the *delta* of the active
:mod:`repro.obs.counters` registry across their lifetime — so a
``mode`` span shows exactly the DMA bytes / dispatch decisions its
children emitted, correlated without any per-layer plumbing. Recording
is off the hot path: enter pushes a frame (one ``perf_counter`` read +
one registry snapshot), exit appends one record; nothing is formatted
or allocated per nonzero, and the process-default tracer is the
:data:`NULL` no-op whose ``span`` returns a shared inert context
manager, so uninstrumented runs pay only a function call.

Export targets the Chrome trace-event format (complete ``"X"`` events,
microsecond ``ts``/``dur``), loadable in ``chrome://tracing`` and
Perfetto; :func:`validate_chrome_trace` is the schema check CI's
``obs-smoke`` step runs against the exported JSON.

Not thread-safe by design: one tracer models one logical instruction
stream (the drivers it instruments are single-threaded Python loops
around jitted calls). Scope a fresh tracer per thread if you need more.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time

from . import counters as _counters

__all__ = [
    "NULL",
    "NullTracer",
    "SpanRecord",
    "Tracer",
    "get_tracer",
    "sanitize_span_name",
    "set_tracer",
    "unique_path",
    "use_tracer",
    "validate_chrome_trace",
]

# Characters that break downstream span-name consumers: semicolons are
# the collapsed-stack (flamegraph) separator, braces collide with the
# counter-key label syntax, and control characters corrupt the rendered
# tree / confuse trace viewers even when JSON-escaped.
_NAME_BAD = {ord(c): "_" for c in ";{}"}
_NAME_BAD.update({c: "_" for c in range(0x20)})
_NAME_BAD[0x7F] = "_"


def sanitize_span_name(name) -> str:
    """A span name safe for Chrome-trace, flamegraph, and table exports.

    Non-strings are stringified; semicolons/braces/control characters
    become ``_``. Empty names render as ``"?"`` so a blank never
    produces an unlabeled frame.
    """
    out = str(name).translate(_NAME_BAD)
    return out if out else "?"


def unique_path(path: str) -> str:
    """``path`` if free, else the first ``stem-N.ext`` that is.

    Repeated exports must never silently overwrite an earlier trace —
    callers use the *returned* path as the artifact location.
    """
    if not os.path.exists(path):
        return path
    stem, ext = os.path.splitext(path)
    n = 2
    while os.path.exists(f"{stem}-{n}{ext}"):
        n += 1
    return f"{stem}-{n}{ext}"


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One closed span. ``sid``/``parent`` link the forest (-1 = root).

    ``counters`` is the *inclusive* counter delta over the span's
    lifetime (children included); ``self_counters`` excludes every
    direct child's inclusive delta — the share this span's own body
    emitted. Aggregating ``self_counters`` by name is double-count-free
    even when spans nest under the same name (``oocore.mode_step``
    inside a retried ``oocore.mode_step``, recursive phases, …), which
    is what the profiler's roofline join relies on.
    """

    sid: int
    parent: int
    depth: int
    name: str
    args: dict
    t0: float
    t1: float
    counters: dict
    self_counters: dict = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class _Frame:
    __slots__ = ("sid", "parent", "depth", "name", "args", "t0", "snap",
                 "child_delta")

    def __init__(self, sid, parent, depth, name, args, t0, snap):
        self.sid, self.parent, self.depth = sid, parent, depth
        self.name, self.args, self.t0, self.snap = name, args, t0, snap
        self.child_delta: dict = {}


class _SpanCM:
    """Reusable-shape context manager returned by :meth:`Tracer.span`."""

    __slots__ = ("_tracer", "_name", "_args")

    def __init__(self, tracer, name, args):
        self._tracer, self._name, self._args = tracer, name, args

    def __enter__(self):
        self._tracer._enter(self._name, self._args)
        return self

    def __exit__(self, exc_type, exc, tb):
        # Close on exception too — a failed phase still records its span
        # (the exception propagates; nesting never corrupts).
        self._tracer._exit()
        return False


class Tracer:
    """Collects nested spans; see module docstring."""

    enabled = True

    def __init__(self, *, clock=time.perf_counter, attach_counters=True):
        self._clock = clock
        self._attach = attach_counters
        self._stack: list[_Frame] = []
        self._next_sid = 0
        self.records: list[SpanRecord] = []

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **args) -> _SpanCM:
        return _SpanCM(self, name, args)

    def _enter(self, name: str, args: dict) -> None:
        sid, self._next_sid = self._next_sid, self._next_sid + 1
        parent = self._stack[-1].sid if self._stack else -1
        snap = _counters.get_registry().snapshot() if self._attach else None
        # Clock AFTER the snapshot: registry-copy cost stays outside the
        # measured interval.
        self._stack.append(
            _Frame(sid, parent, len(self._stack), name, args,
                   self._clock(), snap))

    def _exit(self) -> None:
        if not self._stack:
            raise RuntimeError("span exit with no open span")
        t1 = self._clock()
        f = self._stack.pop()
        delta: dict = {}
        self_delta: dict = {}
        if f.snap is not None:
            cur = _counters.get_registry().snapshot()
            delta = {k: v - f.snap.get(k, 0)
                     for k, v in cur.items() if v != f.snap.get(k, 0)}
            # Self-delta: the inclusive delta minus what this frame's
            # direct children already claimed. Same-name nesting is the
            # case that used to double-count — each child's inclusive
            # delta was folded into the parent's only record — so the
            # children's deltas are accumulated per frame on their exit
            # and subtracted here, never re-derived from names.
            self_delta = {k: v - f.child_delta.get(k, 0)
                          for k, v in delta.items()
                          if v != f.child_delta.get(k, 0)}
            if self._stack:
                parent_acc = self._stack[-1].child_delta
                for k, v in delta.items():
                    parent_acc[k] = parent_acc.get(k, 0) + v
        self.records.append(SpanRecord(
            sid=f.sid, parent=f.parent, depth=f.depth, name=f.name,
            args=f.args, t0=f.t0, t1=t1, counters=delta,
            self_counters=self_delta))

    @property
    def open_spans(self) -> int:
        return len(self._stack)

    def reset(self) -> None:
        if self._stack:
            raise RuntimeError(
                f"reset with {len(self._stack)} open span(s): "
                + " > ".join(fr.name for fr in self._stack))
        self.records.clear()
        self._next_sid = 0

    # -- export ------------------------------------------------------------

    def chrome_trace(self, *, meta: dict | None = None) -> dict:
        """The recorded forest as a Chrome trace-event JSON object.

        Complete (``ph="X"``) events with microsecond timestamps
        rebased to the earliest span; span args and the per-span
        counter deltas ride in ``args``. Raises if spans are still
        open — a partial forest would export misleading durations.
        """
        if self._stack:
            raise RuntimeError(
                f"cannot export with {len(self._stack)} open span(s): "
                + " > ".join(fr.name for fr in self._stack))
        pid = os.getpid()
        base = min((r.t0 for r in self.records), default=0.0)
        events = []
        for r in sorted(self.records, key=lambda r: (r.t0, r.depth)):
            args = {str(k): v for k, v in r.args.items()}
            if r.counters:
                args["counters"] = dict(r.counters)
            if r.self_counters and r.self_counters != r.counters:
                args["self_counters"] = dict(r.self_counters)
            events.append({
                "name": sanitize_span_name(r.name),
                "cat": "repro",
                "ph": "X",
                "ts": (r.t0 - base) * 1e6,
                "dur": max(0.0, (r.t1 - r.t0) * 1e6),
                "pid": pid,
                "tid": 0,
                "args": args,
            })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": dict(meta or {}, exporter="repro.obs"),
        }

    def write_chrome_trace(self, path: str, *, meta: dict | None = None,
                           overwrite: bool = False) -> str:
        """Write the trace JSON; returns the path actually written.

        By default an existing file is never clobbered — the export goes
        to the first free ``stem-N.json`` variant instead (repeated
        exports used to silently overwrite). ``overwrite=True`` restores
        the old behavior for callers that manage their own paths.
        """
        if not overwrite:
            path = unique_path(path)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(meta=meta), f, indent=1, default=str)
        return path

    def render(self) -> str:
        """Human-readable span tree with durations and counter deltas."""
        children: dict[int, list[SpanRecord]] = {}
        for r in self.records:
            children.setdefault(r.parent, []).append(r)
        for sibs in children.values():
            sibs.sort(key=lambda r: r.t0)
        lines: list[str] = []

        def emit(r: SpanRecord) -> None:
            arg_s = " ".join(f"{k}={v}" for k, v in r.args.items())
            head = "  " * r.depth + r.name + (f" [{arg_s}]" if arg_s else "")
            lines.append(f"{head:<56s} {r.duration_s * 1e3:10.2f} ms")
            for key, v in sorted(r.counters.items()):
                lines.append("  " * (r.depth + 1) + f"+ {key} = {v}")
            for c in children.get(r.sid, ()):
                emit(c)

        for root in children.get(-1, ()):
            emit(root)
        return "\n".join(lines)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The default no-op tracer: zero records, zero counters, ~zero cost."""

    enabled = False
    records: tuple = ()
    open_spans = 0

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def reset(self) -> None:
        pass


NULL = NullTracer()

_tracer = NULL


def get_tracer():
    """The process-default tracer (:data:`NULL` unless one was set)."""
    return _tracer


def set_tracer(tracer) -> None:
    global _tracer
    _tracer = NULL if tracer is None else tracer


@contextlib.contextmanager
def use_tracer(tracer: Tracer | None = None):
    """Scope the process-default tracer (fresh one by default)."""
    global _tracer
    scoped = Tracer() if tracer is None else tracer
    previous = _tracer
    _tracer = scoped
    try:
        yield scoped
    finally:
        _tracer = previous


def validate_chrome_trace(trace, *, expect_names=()) -> list[str]:
    """Schema-check a Chrome trace object; returns error strings.

    Checks the trace-event contract this exporter relies on (dict with a
    ``traceEvents`` list of complete ``"X"`` events carrying numeric
    ``ts``/``dur`` and a dict ``args``), plus proper nesting per
    ``(pid, tid)``: events must be disjoint or fully contained — an
    overlap means the span forest was corrupted. ``expect_names``
    additionally requires each named span to appear at least once (how
    CI asserts the sweep/mode/phase taxonomy actually got exported).
    """
    errors: list[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["trace is not a dict with a 'traceEvents' key"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not a dict")
            continue
        for key in ("name", "ph", "ts", "dur", "pid", "tid", "cat"):
            if key not in ev:
                errors.append(f"event {i}: missing {key!r}")
        if ev.get("ph") != "X":
            errors.append(f"event {i}: ph={ev.get('ph')!r}, expected 'X' "
                          "(complete event)")
        for key in ("ts", "dur"):
            v = ev.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"event {i}: {key} must be a number >= 0, "
                              f"got {v!r}")
        if not isinstance(ev.get("args", {}), dict):
            errors.append(f"event {i}: args must be a dict")
    if errors:
        return errors
    # Nesting: per timeline, an event starting inside an open one must
    # also end inside it (tiny tolerance for float microsecond math).
    eps = 1e-3
    timelines: dict[tuple, list[dict]] = {}
    for ev in events:
        timelines.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for tl, evs in timelines.items():
        evs = sorted(evs, key=lambda e: (e["ts"], -e["dur"]))
        open_stack: list[tuple[float, float, str]] = []
        for ev in evs:
            lo, hi = ev["ts"], ev["ts"] + ev["dur"]
            while open_stack and lo >= open_stack[-1][1] - eps:
                open_stack.pop()
            if open_stack and hi > open_stack[-1][1] + eps:
                errors.append(
                    f"timeline {tl}: span {ev['name']!r} "
                    f"[{lo:.3f}, {hi:.3f}] overlaps the end of open span "
                    f"{open_stack[-1][2]!r} [.., {open_stack[-1][1]:.3f}]")
            open_stack.append((lo, hi, ev["name"]))
    names = {ev["name"] for ev in events}
    for want in expect_names:
        if want not in names:
            errors.append(f"expected span name {want!r} not present "
                          f"(saw: {sorted(names)})")
    return errors
