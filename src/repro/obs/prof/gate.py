"""The noise-aware timed regression gate over PROF artifacts.

Timed numbers are host-local and noisy — a naive "current > baseline"
gate would be the flakiest check in CI. This gate is built to *never*
fail on noise:

* **ratio threshold** — a phase regresses only when its median exceeds
  ``baseline_median × max_ratio`` (default 1.5×: real regressions in
  this stack are 2×+ — a backend fell off a residency rung, a remap
  stopped overlapping — not 10%).
* **MAD-scaled tolerance** — the threshold widens by ``z ×
  (mad_frac_baseline + mad_frac_current)``: a phase whose own samples
  spread 10% gets 10%·z extra headroom, per side.
* **calibration bar** — every PROF artifact records a host-noise score
  (a fixed pure-python workload's ``mad_frac``); when either side's
  score exceeds :data:`NOISE_BAR` the gate SKIPs rather than judging
  timings the host can't reproduce.
* **fingerprint check** — baselines from a different host class
  (platform/machine/cpu/devices) SKIP; cross-host ratios are not
  regressions.
* **phase noise guard** — an individual phase spreading past
  :data:`PHASE_NOISE_BAR` is reported but can't fail the gate.

``tests/test_prof.py`` pins both directions: an injected 2× slowdown
fails, and repeated same-distribution runs pass by tolerance
arithmetic, not luck.
"""
from __future__ import annotations

import dataclasses

from . import harness as _harness

__all__ = [
    "GateResult",
    "MAX_RATIO",
    "NOISE_BAR",
    "PHASE_NOISE_BAR",
    "PROF_SCHEMA",
    "TOLERANCE_Z",
    "compare",
    "validate_prof",
]

PROF_SCHEMA = 1
# Median-ratio ceiling before a phase counts as regressed.
MAX_RATIO = 1.5
# How many sigma-equivalent mad_fracs of slack each side contributes.
TOLERANCE_Z = 3.0
# Host-noise calibration mad_frac above which the whole gate SKIPs.
NOISE_BAR = 0.10
# Per-phase mad_frac above which that phase is reported, never failed.
PHASE_NOISE_BAR = 0.35
# Phases faster than this are clock-granularity territory; never gated.
MIN_GATED_S = 1e-4


@dataclasses.dataclass
class GateResult:
    """Outcome of one timed comparison."""

    status: str                 # "pass" | "fail" | "skip"
    messages: list[str]
    phases: list[dict]          # per-phase verdict rows

    @property
    def exit_status(self) -> int:
        return 1 if self.status == "fail" else 0


def validate_prof(obj) -> list[str]:
    """Schema-check a PROF artifact; returns error strings (CI runs
    this against the freshly emitted JSON)."""
    errors: list[str] = []
    if not isinstance(obj, dict):
        return ["PROF artifact is not a dict"]
    meta = obj.get("meta")
    if not isinstance(meta, dict):
        errors.append("missing meta dict")
    else:
        if meta.get("schema") != PROF_SCHEMA:
            errors.append(f"meta.schema is {meta.get('schema')!r}, "
                          f"expected {PROF_SCHEMA}")
        for key in ("fingerprint", "noise", "workload"):
            if not isinstance(meta.get(key), dict):
                errors.append(f"meta.{key} missing or not a dict")
        noise = meta.get("noise")
        if isinstance(noise, dict) and not isinstance(
                noise.get("mad_frac"), (int, float)):
            errors.append("meta.noise.mad_frac missing")
    phases = obj.get("phases")
    if not isinstance(phases, dict) or not phases:
        errors.append("phases missing or empty")
    else:
        for name, ph in phases.items():
            if not isinstance(ph, dict):
                errors.append(f"phase {name!r}: not a dict")
                continue
            for key in ("median_s", "mad_s", "mad_frac"):
                if not isinstance(ph.get(key), (int, float)):
                    errors.append(f"phase {name!r}: missing {key}")
            if not isinstance(ph.get("samples_s"), list) \
                    or not ph.get("samples_s"):
                errors.append(f"phase {name!r}: missing samples_s")
    st = obj.get("selftime")
    if not isinstance(st, dict) or not isinstance(st.get("top_down"), list) \
            or not isinstance(st.get("bottom_up"), list):
        errors.append("selftime.top_down/bottom_up tables missing")
    if not isinstance(obj.get("roofline"), list):
        errors.append("roofline rows missing")
    if not isinstance(obj.get("breakdown"), list):
        errors.append("breakdown rows missing")
    return errors


def _phase_verdict(name: str, base: dict, cur: dict, *, max_ratio: float,
                   z: float) -> dict:
    b_med, c_med = float(base["median_s"]), float(cur["median_s"])
    noise = float(base.get("mad_frac", 0.0)) + float(cur.get("mad_frac", 0.0))
    threshold = max_ratio + z * noise
    ratio = c_med / b_med if b_med > 0 else float("inf")
    row = {
        "phase": name,
        "baseline_median_s": b_med,
        "current_median_s": c_med,
        "ratio": ratio,
        "threshold": threshold,
        "noise_frac": noise,
        "verdict": "ok",
    }
    if max(float(base.get("mad_frac", 0)), float(cur.get("mad_frac", 0))) \
            > PHASE_NOISE_BAR:
        row["verdict"] = "noisy"     # reported, never failed
    elif max(b_med, c_med) < MIN_GATED_S:
        row["verdict"] = "sub-resolution"
    elif ratio > threshold:
        row["verdict"] = "regressed"
    elif ratio < 1.0 / threshold:
        row["verdict"] = "improved"
    return row


def compare(current: dict, baseline: dict, *, max_ratio: float = MAX_RATIO,
            z: float = TOLERANCE_Z, noise_bar: float = NOISE_BAR
            ) -> GateResult:
    """Gate ``current`` against ``baseline`` (both PROF artifacts)."""
    msgs: list[str] = []
    for label, obj in (("current", current), ("baseline", baseline)):
        errs = validate_prof(obj)
        if errs:
            return GateResult("fail", [f"{label} artifact invalid: {e}"
                                       for e in errs], [])
    fp_mismatch = _harness.fingerprint_compatible(
        current["meta"]["fingerprint"], baseline["meta"]["fingerprint"])
    if fp_mismatch:
        return GateResult(
            "skip",
            ["SKIP fingerprint mismatch (cross-host timings are not "
             "comparable): " + "; ".join(fp_mismatch),
             "refresh with `python -m repro.obs.prof run "
             "--update-baseline` on this host"], [])
    for label, obj in (("current", current), ("baseline", baseline)):
        nf = float(obj["meta"]["noise"]["mad_frac"])
        if nf > noise_bar:
            return GateResult(
                "skip",
                [f"SKIP host-noise calibration on {label} run is "
                 f"{nf:.3f} > bar {noise_bar} — timings on this host "
                 "are not reproducible enough to gate"], [])
    rows = []
    for name in sorted(set(baseline["phases"]) & set(current["phases"])):
        rows.append(_phase_verdict(name, baseline["phases"][name],
                                   current["phases"][name],
                                   max_ratio=max_ratio, z=z))
    only_base = sorted(set(baseline["phases"]) - set(current["phases"]))
    only_cur = sorted(set(current["phases"]) - set(baseline["phases"]))
    for name in only_base:
        msgs.append(f"NOTE phase {name!r} in baseline only (instrumentation "
                    "changed?) — re-baseline to re-cover it")
    for name in only_cur:
        msgs.append(f"NOTE phase {name!r} is new (not gated) — re-baseline "
                    "to cover it")
    regressed = [r for r in rows if r["verdict"] == "regressed"]
    for r in rows:
        tag = "FAIL" if r["verdict"] == "regressed" else "ok  "
        note = ("" if r["verdict"] in ("ok", "regressed")
                else f", {r['verdict']}")
        msgs.append(
            f"{tag} {r['phase']}: {r['current_median_s'] * 1e3:.2f} ms vs "
            f"baseline {r['baseline_median_s'] * 1e3:.2f} ms "
            f"(ratio {r['ratio']:.2f}, threshold {r['threshold']:.2f}{note})")
    if not rows:
        return GateResult("skip", msgs + ["SKIP no common phases to gate"],
                          rows)
    if regressed:
        msgs.append(
            f"timed gate FAILED: {len(regressed)} phase(s) regressed past "
            "the noise-scaled threshold. If intentional, re-baseline with "
            "`python -m repro.obs.prof run --update-baseline` and commit.")
        return GateResult("fail", msgs, rows)
    msgs.append(f"timed gate passed: {len(rows)} phases within "
                f"{max_ratio}x (noise-scaled)")
    return GateResult("pass", msgs, rows)
