"""Span self-time attribution: top-down / bottom-up tables, flamegraphs.

The tracer records *inclusive* span durations — a ``sweep`` span's time
contains its ``mode`` children, which contain ``mttkrp``/``solve``/
``remap``. Attribution turns that forest into the two classic profiler
views plus a flamegraph:

* **self time** — a span's duration minus its direct children's
  durations: the share its own body (host glue, un-spanned work) is
  responsible for. Self times sum exactly to the roots' total, so a
  table of them is a partition of the wall clock, never a double count.
* **top-down** — one row per *path* (``sweep;mode;mttkrp``): where did
  the time go, structurally.
* **bottom-up** — one row per span *name*, aggregated across every
  path it appears under: which phase is expensive overall. Inclusive
  totals here skip spans nested under a same-named ancestor (a
  recursive/retried phase must not count its own tail twice); self
  times need no such care.
* **collapsed stacks** — ``path<space>self_µs`` lines, the format
  flamegraph.pl / speedscope / inferno all consume, written next to
  the Chrome-trace export.

stdlib-only; operates on any iterable of ``SpanRecord``-shaped objects.
"""
from __future__ import annotations

from ..tracer import sanitize_span_name, unique_path

__all__ = [
    "bottomup_table",
    "flamegraph_lines",
    "self_times_s",
    "span_paths",
    "topdown_table",
    "write_flamegraph",
]


def _by_sid(records) -> dict:
    return {r.sid: r for r in records}


def self_times_s(records) -> dict[int, float]:
    """``{sid: self seconds}`` — duration minus direct children's.

    Clamped at 0: with microsecond-scale spans, float rounding can make
    children sum to epsilon more than the parent.
    """
    child_sum: dict[int, float] = {}
    for r in records:
        child_sum[r.parent] = child_sum.get(r.parent, 0.0) + r.duration_s
    return {r.sid: max(0.0, r.duration_s - child_sum.get(r.sid, 0.0))
            for r in records}


def span_paths(records) -> dict[int, str]:
    """``{sid: "root;child;...;name"}`` with sanitized components."""
    by_sid = _by_sid(records)
    cache: dict[int, str] = {}

    def path(sid: int) -> str:
        if sid in cache:
            return cache[sid]
        r = by_sid[sid]
        name = sanitize_span_name(r.name)
        p = name if r.parent == -1 or r.parent not in by_sid \
            else f"{path(r.parent)};{name}"
        cache[sid] = p
        return p

    return {r.sid: path(r.sid) for r in records}


def _merge_counters(acc: dict, delta: dict) -> None:
    for k, v in delta.items():
        acc[k] = acc.get(k, 0) + v


def topdown_table(records) -> list[dict]:
    """One row per path: calls, inclusive total, self time, self counters.

    Sorted by self time descending — the first row is where the wall
    clock actually went. ``self_frac`` is relative to the forest's
    root total (the profiled wall time).
    """
    selfs = self_times_s(records)
    paths = span_paths(records)
    total = sum(r.duration_s for r in records if r.parent == -1) or 1.0
    rows: dict[str, dict] = {}
    for r in records:
        row = rows.setdefault(paths[r.sid], {
            "path": paths[r.sid], "calls": 0, "total_s": 0.0,
            "self_s": 0.0, "self_counters": {}})
        row["calls"] += 1
        row["total_s"] += r.duration_s
        row["self_s"] += selfs[r.sid]
        _merge_counters(row["self_counters"],
                        getattr(r, "self_counters", {}) or {})
    out = sorted(rows.values(), key=lambda x: -x["self_s"])
    for row in out:
        row["self_frac"] = row["self_s"] / total
    return out


def bottomup_table(records) -> list[dict]:
    """One row per span *name*, aggregated across all paths.

    ``total_s`` counts a span only when no ancestor shares its name —
    the standard recursion guard, without which a retried
    ``oocore.mode_step`` inside an ``oocore.mode_step`` would inflate
    its own inclusive total. ``self_s`` needs no guard (self times
    partition the wall clock by construction).
    """
    selfs = self_times_s(records)
    by_sid = _by_sid(records)
    rows: dict[str, dict] = {}
    for r in records:
        name = sanitize_span_name(r.name)
        row = rows.setdefault(name, {
            "name": name, "calls": 0, "total_s": 0.0, "self_s": 0.0,
            "self_counters": {}})
        row["calls"] += 1
        row["self_s"] += selfs[r.sid]
        _merge_counters(row["self_counters"],
                        getattr(r, "self_counters", {}) or {})
        anc, nested = r.parent, False
        while anc != -1 and anc in by_sid:
            if by_sid[anc].name == r.name:
                nested = True
                break
            anc = by_sid[anc].parent
        if not nested:
            row["total_s"] += r.duration_s
    out = sorted(rows.values(), key=lambda x: -x["self_s"])
    total = sum(r.duration_s for r in records if r.parent == -1) or 1.0
    for row in out:
        row["self_frac"] = row["self_s"] / total
    return out


def flamegraph_lines(records, *, unit: float = 1e6) -> list[str]:
    """Collapsed-stack lines: ``root;child;... <self time in µs>``.

    Zero-self-time paths are kept (count 0 lines are legal and preserve
    structure); values are integers as the collapsed-stack consumers
    expect.
    """
    selfs = self_times_s(records)
    paths = span_paths(records)
    acc: dict[str, float] = {}
    for r in records:
        acc[paths[r.sid]] = acc.get(paths[r.sid], 0.0) + selfs[r.sid]
    return [f"{p} {int(round(v * unit))}" for p, v in sorted(acc.items())]


def write_flamegraph(records, path: str, *, overwrite: bool = False) -> str:
    """Write collapsed stacks to ``path`` (uniquified unless asked not
    to); returns the path actually written."""
    if not overwrite:
        path = unique_path(path)
    with open(path, "w", encoding="utf-8") as f:
        f.write("\n".join(flamegraph_lines(records)))
        f.write("\n")
    return path
