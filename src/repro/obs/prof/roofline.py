"""Achieved-bandwidth roofline: measured span time × counted bytes.

The repo's byte counters (``oocore.dma.*``, ``reorder.dma.*``,
``remap.a2a.*``, ``ops.step.model_bytes``) are *counted* — exact,
host-independent — and its spans are *measured*. Joining the two per
span gives the number neither has alone: **achieved GB/s**, the
paper-style roofline coordinate for every kernel backend and residency
rung, with no perf-counter infrastructure required.

The join reads each span's ``self_counters`` (double-count-free even
under same-name nesting — the PR's tracer fix) and divides the moved
bytes by the span's *inclusive* duration (the DMA time lives in child
``oocore.chunk`` spans; the bytes are recorded by the parent).

What counts as "moved":

* ``oocore.mode_step`` — ``pipelined + index_stream`` bytes: what the
  revolving-buffer DMA engine actually transfers (``scheduled`` is the
  naive bound, ``distinct`` the lower bound; both are reported per-key).
* ``ops.device_step`` — the first-order counted traffic model
  (:func:`repro.kernels.mttkrp.ops.step_traffic_bytes`) emitted as
  ``ops.step.model_bytes`` by the timed wrapper.
* anything else — the sum of its ``*_bytes`` self-counters.

stdlib-only; rows are plain dicts ready for the PROF artifact.
"""
from __future__ import annotations

from ..counters import split_key
from ..tracer import sanitize_span_name

__all__ = [
    "RUNG_BY_BACKEND",
    "bandwidth_rows",
    "mode_breakdown",
    "moved_bytes",
]

# Kernel backend -> repro.oocore.planner residency-ladder rung.
RUNG_BY_BACKEND = {
    "pallas_fused_gather": "whole",
    "pallas_fused_gather_bf16": "whole",
    "pallas_fused_gather_tiled": "slab",
    "pallas_fused_gather_stream": "stream",
    "pallas_fused": "fused",
    "pallas_fused_bf16": "fused",
    "pallas_fused_tiled": "tiled",
    "pallas": "materialized",
    "ref": "reference",
    "segsum": "reference",
}


def _byte_counters(self_counters: dict) -> dict[str, int]:
    """Sum *moved*-``_bytes`` self-counters by base name (labels folded).

    ``planner.vmem.plan_bytes`` is excluded: it sizes a VMEM *plan*
    (emitted at trace time inside whatever span the first dispatch
    happens under), not traffic — dividing a span's time by it would
    fabricate a bandwidth.
    """
    out: dict[str, int] = {}
    for key, v in (self_counters or {}).items():
        base, _ = split_key(key)
        if base.endswith("_bytes") and not base.startswith("planner."):
            out[base] = out.get(base, 0) + v
    return out


def moved_bytes(by_base: dict[str, int]) -> tuple[int, str]:
    """``(bytes actually moved, basis string)`` for one span's counters.

    Prefers the physically-meaningful combination when the oocore
    counters are present; falls back to the plain sum otherwise.
    """
    if "oocore.dma.pipelined_bytes" in by_base:
        moved = (by_base["oocore.dma.pipelined_bytes"]
                 + by_base.get("oocore.dma.index_stream_bytes", 0))
        return moved, "pipelined+index_stream"
    if "ops.step.model_bytes" in by_base:
        return by_base["ops.step.model_bytes"], "model"
    return sum(by_base.values()), "sum"


def bandwidth_rows(records) -> list[dict]:
    """Achieved-GB/s rows, one per (span name, backend, rung, ordering).

    Only spans carrying ``*_bytes`` self-counters contribute. Byte
    counts aggregate from ``self_counters`` (never double-counted);
    durations aggregate inclusively (the transfer happens inside the
    span, children included). Per-counter GB/s rides along so the
    scheduled/distinct/pipelined spread stays visible.
    """
    groups: dict[tuple, dict] = {}
    for r in records:
        by_base = _byte_counters(getattr(r, "self_counters", None)
                                 or r.counters)
        if not by_base:
            continue
        args = r.args or {}
        backend = str(args.get("backend", ""))
        rung = str(args.get("rung", "")) or RUNG_BY_BACKEND.get(backend, "")
        key = (sanitize_span_name(r.name), backend, rung,
               str(args.get("ordering", "")))
        g = groups.setdefault(key, {
            "span": key[0], "backend": backend, "rung": rung,
            "ordering": key[3], "calls": 0, "time_s": 0.0, "bytes": {}})
        g["calls"] += 1
        g["time_s"] += r.duration_s
        for base, v in by_base.items():
            g["bytes"][base] = g["bytes"].get(base, 0) + v
    rows = []
    for g in groups.values():
        moved, basis = moved_bytes(g["bytes"])
        t = g["time_s"]
        rows.append({
            **{k: g[k] for k in ("span", "backend", "rung", "ordering",
                                 "calls", "time_s")},
            "moved_bytes": moved,
            "basis": basis,
            "achieved_gbps": (moved / t / 1e9) if t > 0 else 0.0,
            "per_counter_gbps": {
                base: (v / t / 1e9) if t > 0 else 0.0
                for base, v in sorted(g["bytes"].items())},
            "counted_bytes": dict(sorted(g["bytes"].items())),
        })
    rows.sort(key=lambda x: -x["achieved_gbps"])
    return rows


def mode_breakdown(records) -> list[dict]:
    """Paper-style per-mode total-time table for the CP-ALS driver.

    One row per ``mode`` span argument value: inclusive total plus the
    mttkrp/solve/remap child split (the figure the source paper reports
    per mode and per method). ``share_frac`` is each mode's share of
    the summed mode time.
    """
    by_sid = {r.sid: r for r in records}
    rows: dict = {}
    for r in records:
        if r.name != "mode":
            continue
        mode = r.args.get("mode", "?")
        row = rows.setdefault(mode, {
            "mode": mode, "calls": 0, "total_s": 0.0,
            "mttkrp_s": 0.0, "solve_s": 0.0, "remap_s": 0.0})
        row["calls"] += 1
        row["total_s"] += r.duration_s
    for r in records:
        p = by_sid.get(r.parent)
        if p is None or p.name != "mode" or r.name not in (
                "mttkrp", "solve", "remap"):
            continue
        rows[p.args.get("mode", "?")][f"{r.name}_s"] += r.duration_s
    out = sorted(rows.values(), key=lambda x: str(x["mode"]))
    total = sum(r["total_s"] for r in out) or 1.0
    for row in out:
        row["other_s"] = max(0.0, row["total_s"] - row["mttkrp_s"]
                             - row["solve_s"] - row["remap_s"])
        row["share_frac"] = row["total_s"] / total
    return out
