"""``repro.obs.prof`` — wall-clock profiling over the obs tracer.

Four pieces, all stdlib-only at import time (jax is only touched when a
profile actually runs):

* :mod:`.harness`  — steady-state timing (warmup + fenced repeats),
  median/MAD robust stats, host fingerprint, noise calibration.
* :mod:`.selftime` — span self-time attribution: top-down / bottom-up
  tables and collapsed-stack flamegraph export.
* :mod:`.roofline` — achieved bandwidth: measured span time joined with
  counted byte deltas, per kernel backend and residency rung.
* :mod:`.gate`     — the noise-aware timed regression gate over
  versioned ``experiments/obs/PROF_*.json`` artifacts.

CLI: ``python -m repro.obs.prof run|report|gate [--update-baseline]``.
"""
from .gate import (GateResult, MAX_RATIO, NOISE_BAR, PROF_SCHEMA,
                   TOLERANCE_Z, compare, validate_prof)
from .harness import (MAD_SIGMA, OUTLIER_Z, PhaseStats, env_fingerprint,
                      fingerprint_compatible, measure_steady,
                      noise_calibration, robust_stats)
from .roofline import (RUNG_BY_BACKEND, bandwidth_rows, mode_breakdown,
                       moved_bytes)
from .selftime import (bottomup_table, flamegraph_lines, self_times_s,
                       span_paths, topdown_table, write_flamegraph)

__all__ = [
    "GateResult",
    "MAD_SIGMA",
    "MAX_RATIO",
    "NOISE_BAR",
    "OUTLIER_Z",
    "PROF_SCHEMA",
    "PhaseStats",
    "RUNG_BY_BACKEND",
    "TOLERANCE_Z",
    "bandwidth_rows",
    "bottomup_table",
    "compare",
    "env_fingerprint",
    "fingerprint_compatible",
    "flamegraph_lines",
    "measure_steady",
    "mode_breakdown",
    "moved_bytes",
    "noise_calibration",
    "robust_stats",
    "self_times_s",
    "span_paths",
    "topdown_table",
    "validate_prof",
    "write_flamegraph",
]
