"""``python -m repro.obs.prof`` — run / report / gate.

Subcommands:

* ``run``    — profile the pinned baseline workload to steady state:
  noise calibration, ``--warmup`` un-timed collects, then ``--repeats``
  timed collects. Emits a versioned PROF artifact
  (``experiments/obs/PROF_run.json``, or ``PROF_baseline.json`` with
  ``--update-baseline``) plus a collapsed-stack flamegraph and a
  Chrome-trace JSON from the last repeat.
* ``report`` — pretty-print a PROF artifact: phase stats, self-time
  tables, the achieved-bandwidth roofline, and the paper-style per-mode
  breakdown. No jax import.
* ``gate``   — the noise-aware timed regression gate:
  ``PROF_run.json`` vs the committed ``PROF_baseline.json``.
  ``--report-only`` prints verdicts but always exits 0 (what CI runs —
  timed numbers from shared runners inform, they don't block).

The timed artifact is deliberately separate from the *counted*
baseline (``python -m repro.obs baseline``): counted bytes gate
strictly in CI because they are exact; timed medians gate with
MAD-scaled tolerance and a host-noise skip because they are not.
"""
import json
import os
import sys

# Same 4-device requirement as `python -m repro.obs` — the profiled
# workload runs the distributed CP-ALS driver. Must precede jax import.
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import argparse
import time

from . import gate as _gate
from . import harness as _harness
from . import roofline as _roofline
from . import selftime as _selftime

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))))))
PROF_DIR = os.path.join(_REPO_ROOT, "experiments", "obs")
RUN_PATH = os.path.join(PROF_DIR, "PROF_run.json")
BASELINE_PATH = os.path.join(PROF_DIR, "PROF_baseline.json")
FLAME_PATH = os.path.join(PROF_DIR, "PROF_flame.folded")
TRACE_PATH = os.path.join(PROF_DIR, "PROF_trace.json")


def run_profile(*, repeats: int = 3, warmup: int = 1, collect=None,
                clock=time.perf_counter) -> tuple[dict, list]:
    """Profile the baseline workload; return ``(PROF dict, last records)``.

    ``collect`` is injectable (tests swap in a fast fake); the default
    is the counter-baseline's pinned workload, so the timed and counted
    gates describe the very same run shape.
    """
    from .. import baseline as _baseline
    from .. import tracer as _tracer_mod

    if repeats < 1:
        raise ValueError("run_profile needs repeats >= 1")
    collect_fn = collect if collect is not None else _baseline.collect
    noise = _harness.noise_calibration(clock=clock)
    for _ in range(warmup):
        collect_fn(tracer=_tracer_mod.Tracer())
    runs = []
    for _ in range(repeats):
        tracer = _tracer_mod.Tracer()
        t0 = clock()
        current = collect_fn(tracer=tracer)
        runs.append((tracer.records, current, clock() - t0))

    # Per-phase samples: one number per repeat per span name (inclusive,
    # recursion-guarded bottom-up totals), plus the end-to-end run time.
    per_name: dict[str, list[float]] = {}
    for records, _cur, elapsed in runs:
        for row in _selftime.bottomup_table(records):
            per_name.setdefault(row["name"], []).append(row["total_s"])
        per_name.setdefault("run.total", []).append(elapsed)
    phases = {name: _harness.robust_stats(samples).to_json()
              for name, samples in sorted(per_name.items())
              if len(samples) == len(runs)}   # present in every repeat

    records, current, _ = runs[-1]
    prof = {
        "meta": {
            "schema": _gate.PROF_SCHEMA,
            "fingerprint": _harness.env_fingerprint(),
            "noise": noise,
            "workload": _baseline.WORKLOAD,
            "repeats": repeats,
            "warmup": warmup,
            "update_with": "PYTHONPATH=src python -m repro.obs.prof run "
                           "--update-baseline",
        },
        "phases": phases,
        "selftime": {
            "top_down": _selftime.topdown_table(records),
            "bottom_up": _selftime.bottomup_table(records),
        },
        "roofline": _roofline.bandwidth_rows(records),
        "breakdown": _roofline.mode_breakdown(records),
        "counters": {k: int(v)
                     for k, v in sorted(current.get("counters", {}).items())},
    }
    return prof, records


def _write_json(obj: dict, path: str) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def _load_json(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def cmd_run(args) -> int:
    from .. import tracer as _tracer_mod

    prof, records = run_profile(repeats=args.repeats, warmup=args.warmup)
    errors = _gate.validate_prof(prof)
    if errors:   # a malformed emission must never land on disk silently
        for e in errors:
            print(f"FAIL emitted artifact invalid: {e}")
        return 1
    out = args.out or (BASELINE_PATH if args.update_baseline else RUN_PATH)
    path = _write_json(prof, out)
    flame = _selftime.write_flamegraph(records, FLAME_PATH, overwrite=True)
    tr = _tracer_mod.Tracer()
    tr.records.extend(records)
    trace = tr.write_chrome_trace(
        TRACE_PATH, meta={"prof": os.path.basename(path)}, overwrite=True)
    rel = os.path.relpath(path, _REPO_ROOT)
    print(f"wrote {rel}: {len(prof['phases'])} phases, "
          f"{len(prof['roofline'])} roofline rows, "
          f"noise mad_frac {prof['meta']['noise']['mad_frac']:.4f}")
    print(f"wrote {os.path.relpath(flame, _REPO_ROOT)} "
          f"({len(records)} spans)")
    print(f"wrote {os.path.relpath(trace, _REPO_ROOT)}")
    if args.update_baseline:
        print("timed baseline updated — commit it")
    return 0


def _fmt_time(s: float) -> str:
    return f"{s * 1e3:9.3f} ms"


def cmd_report(args) -> int:
    prof = _load_json(args.path)
    errors = _gate.validate_prof(prof)
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        return 1
    meta = prof["meta"]
    fp = meta["fingerprint"]
    print(f"PROF schema {meta['schema']} | host {fp.get('platform')}/"
          f"{fp.get('machine')} cpu={fp.get('cpu_count')} "
          f"devices={fp.get('devices')} | noise mad_frac "
          f"{meta['noise']['mad_frac']:.4f}")
    print(f"workload: {meta['workload'].get('tensor')} "
          f"x{meta['workload'].get('tensor_scale')} rank "
          f"{meta['workload'].get('rank')} | repeats {meta['repeats']} "
          f"warmup {meta['warmup']}")
    print("\nphases (median ± sigma-equivalent MAD):")
    for name, ph in sorted(prof["phases"].items(),
                           key=lambda kv: -kv[1]["median_s"]):
        print(f"  {name:<24} {_fmt_time(ph['median_s'])} "
              f"± {100 * ph['mad_frac']:5.1f}%  (n={ph['n']}, "
              f"rejected {ph['rejected']})")
    print("\ntop-down self time (last repeat):")
    for row in prof["selftime"]["top_down"][:args.limit]:
        print(f"  {100 * row['self_frac']:5.1f}%  "
              f"{_fmt_time(row['self_s'])}  x{row['calls']:<4} "
              f"{row['path']}")
    print("\nbottom-up by span name:")
    for row in prof["selftime"]["bottom_up"][:args.limit]:
        print(f"  {100 * row['self_frac']:5.1f}%  self "
              f"{_fmt_time(row['self_s'])}  total "
              f"{_fmt_time(row['total_s'])}  x{row['calls']:<4} "
              f"{row['name']}")
    if prof["roofline"]:
        print("\nachieved bandwidth (measured time x counted bytes):")
        for row in prof["roofline"]:
            where = "/".join(x for x in (row["backend"], row["rung"],
                                         row["ordering"]) if x)
            print(f"  {row['achieved_gbps']:8.3f} GB/s  "
                  f"{row['moved_bytes']:>12} B ({row['basis']})  "
                  f"x{row['calls']:<3} {row['span']}"
                  + (f" [{where}]" if where else ""))
    if prof["breakdown"]:
        print("\nper-mode breakdown:")
        for row in prof["breakdown"]:
            print(f"  mode {row['mode']}: {_fmt_time(row['total_s'])} "
                  f"({100 * row['share_frac']:4.1f}%) = mttkrp "
                  f"{_fmt_time(row['mttkrp_s'])} + solve "
                  f"{_fmt_time(row['solve_s'])} + remap "
                  f"{_fmt_time(row['remap_s'])} + other "
                  f"{_fmt_time(row['other_s'])}")
    return 0


def cmd_gate(args) -> int:
    if not os.path.exists(args.baseline):
        print(f"SKIP no timed baseline at "
              f"{os.path.relpath(args.baseline, _REPO_ROOT)} — create one "
              "with `python -m repro.obs.prof run --update-baseline`")
        return 0
    if not os.path.exists(args.current):
        print(f"FAIL no current profile at "
              f"{os.path.relpath(args.current, _REPO_ROOT)} — run "
              "`python -m repro.obs.prof run` first")
        return 1
    result = _gate.compare(_load_json(args.current),
                           _load_json(args.baseline),
                           max_ratio=args.max_ratio, noise_bar=args.noise_bar)
    for m in result.messages:
        print(m)
    if args.report_only and result.status == "fail":
        print("(report-only: regression reported, exit forced to 0)")
        return 0
    return result.exit_status


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs.prof")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="profile the baseline workload")
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--warmup", type=int, default=1)
    p.add_argument("--update-baseline", action="store_true",
                   help="write PROF_baseline.json instead of PROF_run.json")
    p.add_argument("--out", default=None,
                   help="explicit output path (overrides the defaults)")

    p = sub.add_parser("report", help="pretty-print a PROF artifact")
    p.add_argument("path", nargs="?", default=RUN_PATH)
    p.add_argument("--limit", type=int, default=12,
                   help="rows per self-time table")

    p = sub.add_parser("gate", help="timed regression gate")
    p.add_argument("--current", default=RUN_PATH)
    p.add_argument("--baseline", default=BASELINE_PATH)
    p.add_argument("--max-ratio", type=float, default=_gate.MAX_RATIO)
    p.add_argument("--noise-bar", type=float, default=_gate.NOISE_BAR)
    p.add_argument("--report-only", action="store_true",
                   help="print verdicts but always exit 0 (CI mode)")

    args = ap.parse_args(argv)
    return {"run": cmd_run, "report": cmd_report,
            "gate": cmd_gate}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
