"""Steady-state wall-clock measurement with robust statistics.

Everything the repo timed before this module was a bare
``median-of-3`` (``benchmarks.common.timeit``, ``tune.microbench._time``)
with no spread estimate, no outlier handling, and no record of the host
that produced the number. This harness is the one timing idiom the
profiler, the microbench, and the timed gate now share:

* **fencing** — every sample brackets a call whose result is passed
  through ``block`` (``jax.block_until_ready`` by default when jax is
  importable), so async dispatch never leaks device time out of the
  measured interval;
* **steady state** — ``warmup`` un-timed calls absorb compilation and
  cache effects before the first sample;
* **robust stats** — median + MAD (scaled to a sigma-equivalent via
  1.4826), with modified-z-score outlier rejection (Iglewicz–Hoaglin,
  |z| > 3.5) so one GC pause or scheduler hiccup cannot move the
  reported number;
* **environment fingerprint** — enough host identity that a timed
  artifact can refuse comparison against a different machine;
* **noise calibration** — a fixed pure-python workload timed the same
  way; its relative spread is the host-noise score the timed gate
  checks before trusting any ratio.

stdlib-only at import time (jax is looked up lazily inside
``measure_steady``), so schema/validate/report paths never pay a jax
import.
"""
from __future__ import annotations

import dataclasses
import os
import platform
import sys
import time

__all__ = [
    "MAD_SIGMA",
    "OUTLIER_Z",
    "PhaseStats",
    "env_fingerprint",
    "fingerprint_compatible",
    "measure_steady",
    "noise_calibration",
    "robust_stats",
]

# MAD -> sigma-equivalent scale for normally distributed samples.
MAD_SIGMA = 1.4826
# Modified z-score cutoff for outlier rejection (Iglewicz & Hoaglin).
OUTLIER_Z = 3.5


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


@dataclasses.dataclass(frozen=True)
class PhaseStats:
    """Robust summary of one timed phase's samples (seconds)."""

    samples_s: tuple[float, ...]    # every sample, pre-rejection
    kept_s: tuple[float, ...]       # samples surviving outlier rejection
    median_s: float
    mad_s: float                    # raw median absolute deviation
    mean_s: float
    min_s: float
    max_s: float
    rejected: int

    @property
    def mad_frac(self) -> float:
        """Sigma-equivalent relative spread: ``1.4826·MAD / median``.

        The noise term every timed-gate tolerance is scaled by; 0 for a
        perfectly steady phase, ~0.05 for a quiet host, >0.2 when the
        host is too noisy to gate on.
        """
        if self.median_s <= 0:
            return 0.0
        return MAD_SIGMA * self.mad_s / self.median_s

    def to_json(self) -> dict:
        return {
            "n": len(self.samples_s),
            "median_s": self.median_s,
            "mad_s": self.mad_s,
            "mad_frac": self.mad_frac,
            "mean_s": self.mean_s,
            "min_s": self.min_s,
            "max_s": self.max_s,
            "rejected": self.rejected,
            "samples_s": list(self.samples_s),
        }

    @classmethod
    def from_json(cls, obj: dict) -> "PhaseStats":
        return robust_stats(obj["samples_s"])


def robust_stats(samples) -> PhaseStats:
    """Median/MAD summary of ``samples`` with outlier rejection.

    Rejection needs >= 4 samples (with fewer, a "modified z score" is
    dominated by the sample itself) and recomputes the summary on the
    survivors; the raw samples are kept in the result so a reader can
    always re-derive everything.
    """
    samples = [float(x) for x in samples]
    if not samples:
        raise ValueError("robust_stats needs at least one sample")
    med = _median(samples)
    mad = _median([abs(x - med) for x in samples])
    kept = samples
    if len(samples) >= 4 and mad > 0:
        kept = [x for x in samples
                if abs(0.6745 * (x - med) / mad) <= OUTLIER_Z] or samples
    med_k = _median(kept)
    mad_k = _median([abs(x - med_k) for x in kept])
    return PhaseStats(
        samples_s=tuple(samples),
        kept_s=tuple(kept),
        median_s=med_k,
        mad_s=mad_k,
        mean_s=sum(kept) / len(kept),
        min_s=min(kept),
        max_s=max(kept),
        rejected=len(samples) - len(kept),
    )


def _default_block():
    try:
        import jax

        return jax.block_until_ready
    except Exception:  # pragma: no cover - jax-less host
        return lambda x: x


def measure_steady(fn, *, warmup: int = 2, repeats: int = 5,
                   clock=time.perf_counter, block="auto") -> PhaseStats:
    """Time ``fn()`` to steady state: warmup, then ``repeats`` samples.

    ``block`` fences each call (``"auto"`` = ``jax.block_until_ready``
    when jax imports, identity otherwise; pass an explicit callable or
    ``None`` to disable). ``clock`` is injectable so tests measure with
    a deterministic fake clock instead of hoping the host is quiet.
    """
    if repeats < 1:
        raise ValueError("measure_steady needs repeats >= 1")
    fence = _default_block() if block == "auto" else (block or (lambda x: x))
    for _ in range(warmup):
        fence(fn())
    samples = []
    for _ in range(repeats):
        t0 = clock()
        fence(fn())
        samples.append(clock() - t0)
    return robust_stats(samples)


# Fingerprint keys that must match for a cross-run timed comparison to
# mean anything; the rest (versions, pid-ish details) are informational.
_FINGERPRINT_STRICT = ("platform", "machine", "cpu_count", "devices")


def env_fingerprint() -> dict:
    """Host identity for timed artifacts — who produced these numbers."""
    fp = {
        "platform": sys.platform,
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count() or 1,
        "devices": "unknown",
    }
    try:
        import jax

        fp["jax"] = jax.__version__
        fp["devices"] = (f"{jax.device_count()}x"
                         f"{jax.devices()[0].platform}")
    except Exception:  # pragma: no cover - jax-less host
        pass
    try:
        from ...runtime import execution as _exec

        fp["execution_mode"] = _exec.get_execution_mode()
    except Exception:  # pragma: no cover
        pass
    return fp


def fingerprint_compatible(a: dict, b: dict) -> list[str]:
    """Strict-key mismatches between two fingerprints (empty = same host
    class; timed ratios are meaningful)."""
    return [f"{k}: {a.get(k)!r} != {b.get(k)!r}"
            for k in _FINGERPRINT_STRICT if a.get(k) != b.get(k)]


def _noise_workload(n: int = 80_000) -> int:
    # Fixed pure-python arithmetic: deterministic work, no allocation
    # spikes, long enough (~5ms) that the clock granularity vanishes.
    acc = 0
    for i in range(n):
        acc += i * i
    return acc


def noise_calibration(*, repeats: int = 9, warmup: int = 2,
                      clock=time.perf_counter) -> dict:
    """Time the fixed workload; its spread is the host-noise score.

    A quiet host lands ``mad_frac`` well under 0.05; a noisy, contended
    one (CI neighbors, thermal throttling) pushes past 0.1–0.3, at
    which point the timed gate refuses to fail anyone
    (:data:`repro.obs.prof.gate.NOISE_BAR`).
    """
    stats = measure_steady(_noise_workload, warmup=warmup, repeats=repeats,
                           clock=clock, block=None)
    return {
        "workload": "sum-of-squares-80k",
        "median_s": stats.median_s,
        "mad_frac": stats.mad_frac,
        "samples_s": list(stats.samples_s),
    }
