"""The deterministic counter baseline — CI's silent-perf-change gate.

The counted metrics in the obs registry (DMA bytes, remap exchange
bytes, dispatch/planner decisions) are **host-independent**: they come
from static arithmetic and data-dependent schedules, never from clocks.
So one instrumented tiny run has exactly one right answer, and that
answer is committed as ``experiments/obs/BASELINE_counters.json``. CI
re-collects and diffs: a PR that changes a dispatch decision, a VMEM
plan, a remap capacity, or a single counted DMA byte fails the gate
until it either fixes the regression or *explicitly* re-baselines with
``python -m repro.obs baseline --update-baseline`` (committing the new
file, which makes the change reviewable instead of silent).

What the gate covers (:data:`COUNTED_PREFIXES`): ``cpals.*``,
``dispatch.*``, ``oocore.*``, ``planner.*``, ``remap.*``,
``reorder.*``, ``resilience.*`` (the fault-free run pins every
``site_calls`` count — a hook that silently stops firing, or a
fallback that fires with no fault injected, lands here). Wall-time
counters (``*_s`` suffixed) and ``execution.*`` / ``serve.*`` /
``dryrun.*`` / ``tune.*`` events are host- or config-dependent and are
filtered out before comparison.

Determinism notes (why :func:`collect` is shaped the way it is):

* dispatch/planner counters fire at **jit-trace time** — once per
  unique static signature per process. A fresh CI process traces each
  mode function exactly once; mid-process collection calls
  ``jax.clear_caches()`` first so a previously traced signature counts
  again.
* Everything runs inside ``use_registry()`` so process history never
  leaks into the collected snapshot.
* The workload pins every degree of freedom: seeds, 4 workers, 2 sweeps
  with ``tol=0.0`` (``abs(diff) < 0.0`` is never true → never
  early-stops), and a forced-multichunk out-of-core step with the same
  geometry as ``python -m repro.oocore``.
"""
from __future__ import annotations

import json
import os

__all__ = [
    "BASELINE_PATH",
    "COUNTED_PREFIXES",
    "collect",
    "diff",
    "load_baseline",
    "run_gate",
    "write_baseline",
]

# Repo-relative home of the committed baseline artifact.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
BASELINE_PATH = os.path.join(_REPO_ROOT, "experiments", "obs",
                             "BASELINE_counters.json")

# Base-name prefixes whose counters are host-independent (counted, not
# timed) and therefore eligible for the committed baseline.
COUNTED_PREFIXES = ("cpals.", "dispatch.", "oocore.", "planner.", "remap.",
                    "reorder.", "resilience.")

# The pinned workload configuration — recorded in the artifact's meta so
# a baseline mismatch can be reproduced byte-for-byte.
WORKLOAD = dict(
    tensor="enron", tensor_scale=0.05, tensor_seed=0,
    num_workers=4, rank=16, iters=2, tol=0.0, backend="auto",
    seed=0,
    oocore=dict(shape=(20000, 40, 9000, 30), nnz=600, nnz_seed=3,
                distribution="powerlaw", blk=32, tile_rows=8, rank=256,
                mode=1, max_chunk_bytes=2000,
                orderings=("none", "tile", "morton")),
)


def _is_counted(key: str) -> bool:
    from .counters import split_key

    name, _ = split_key(key)
    return name.startswith(COUNTED_PREFIXES) and not name.endswith("_s")


def collect(tracer=None) -> dict:
    """Run the pinned instrumented workload; return the baseline object.

    Needs >= 4 jax devices (CI sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``; so does
    ``python -m repro.obs``). Returns ``{"meta": ..., "counters": ...}``
    with counters filtered to the host-independent set and values
    int-ified (every counted metric is a whole number of bytes/events).
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from ..core import distributed as dist
    from ..core.cpals import cp_als_distributed
    from ..core.flycoo import build_flycoo
    from ..core.tensors import frostt_like, random_sparse_tensor
    from ..oocore.executor import mttkrp_out_of_core
    from . import counters as _counters
    from . import tracer as _tracer_mod

    if jax.device_count() < WORKLOAD["num_workers"]:
        raise RuntimeError(
            f"baseline collection needs >= {WORKLOAD['num_workers']} jax "
            f"devices, found {jax.device_count()} — set XLA_FLAGS="
            "--xla_force_host_platform_device_count=4 before importing "
            "jax (python -m repro.obs does this for you)")
    # Trace-time counters (dispatch/planner) fire once per compiled
    # signature; drop cached traces so a mid-process collect counts the
    # same events a fresh CI process would.
    jax.clear_caches()
    tracer = _tracer_mod.Tracer() if tracer is None else tracer
    with _counters.use_registry() as reg, _tracer_mod.use_tracer(tracer):
        w = WORKLOAD
        t = frostt_like(w["tensor"], scale=w["tensor_scale"],
                        seed=w["tensor_seed"])
        ft = build_flycoo(t, w["num_workers"], m_bounds=(2, 8),
                          g_bounds=(8, 64))
        mesh = Mesh(np.array(jax.devices()[:w["num_workers"]]),
                    (dist.AXIS,))
        result = cp_als_distributed(
            ft, w["rank"], mesh, iters=w["iters"], seed=w["seed"],
            tol=w["tol"], backend=w["backend"], tracer=tracer)

        # A forced-multichunk out-of-core step (same geometry as
        # `python -m repro.oocore`): pins the oocore.dma.* byte counts.
        oo = w["oocore"]
        rng = np.random.default_rng(0)
        oot = random_sparse_tensor(tuple(oo["shape"]), oo["nnz"],
                                   seed=oo["nnz_seed"],
                                   distribution=oo["distribution"])
        order = np.argsort(oot.indices[:, oo["mode"]], kind="stable")
        idx = oot.indices[order].astype(np.int32)
        val = oot.values[order].astype(np.float32)
        valid = np.ones(len(val), bool)
        factors = [np.asarray(rng.standard_normal((d, oo["rank"])),
                              np.float32) for d in oo["shape"]]
        rows_cap = -(-oo["shape"][oo["mode"]] // oo["tile_rows"]) \
            * oo["tile_rows"]
        # One run per reorder policy: "none" pins the oocore.dma.*
        # bytes exactly as before the reorder pass existed; "tile" and
        # "morton" additionally pin the reorder.dma.* presort/postsort
        # bytes and the reorder.perms count — a silent change to the
        # permutation keys, the chunk-window tightening, or the
        # predictor arithmetic lands here as a byte diff.
        with tracer.span("oocore.baseline"):
            for ordering in oo["orderings"]:
                mttkrp_out_of_core(
                    idx, val, valid, factors, mode=oo["mode"],
                    rows_cap=rows_cap, blk=oo["blk"],
                    tile_rows=oo["tile_rows"],
                    max_chunk_bytes=oo["max_chunk_bytes"],
                    ordering=ordering)
        snapshot = reg.snapshot()

    counters = {k: int(v) for k, v in snapshot.items() if _is_counted(k)}
    return {
        "meta": {
            "schema": 1,
            "workload": WORKLOAD,
            "counted_prefixes": list(COUNTED_PREFIXES),
            "update_with": "PYTHONPATH=src python -m repro.obs baseline "
                           "--update-baseline",
            "final_fit": round(result.fits[-1], 6),
        },
        "counters": counters,
    }


def diff(current: dict, baseline: dict) -> list[str]:
    """Human-readable mismatches between two baseline objects."""
    cur = current["counters"]
    base = baseline["counters"]
    out = []
    for k in sorted(set(base) | set(cur)):
        if k not in cur:
            out.append(f"missing: {k} (baseline {base[k]}, current absent)")
        elif k not in base:
            out.append(f"new: {k} = {cur[k]} (absent from baseline)")
        elif cur[k] != base[k]:
            out.append(f"changed: {k} baseline {base[k]} -> "
                       f"current {cur[k]}")
    return out


def load_baseline(path: str = BASELINE_PATH) -> dict:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def write_baseline(obj: dict, path: str = BASELINE_PATH) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def run_gate(*, update: bool = False, path: str = BASELINE_PATH,
             tracer=None) -> tuple[int, list[str]]:
    """Collect and compare (or rewrite) the baseline.

    Returns ``(exit_status, messages)`` — status 0 iff the gate passes
    (or the baseline was updated).
    """
    current = collect(tracer=tracer)
    if update:
        write_baseline(current, path)
        return 0, [f"baseline updated: {os.path.relpath(path, _REPO_ROOT)} "
                   f"({len(current['counters'])} counters) — commit it"]
    if not os.path.exists(path):
        return 1, [f"no baseline at {os.path.relpath(path, _REPO_ROOT)} — "
                   "run with --update-baseline and commit the artifact"]
    mismatches = diff(current, load_baseline(path))
    if mismatches:
        return 1, [f"FAIL {m}" for m in mismatches] + [
            "baseline gate failed: counted traffic/dispatch changed. If "
            "intentional, re-baseline with `python -m repro.obs baseline "
            "--update-baseline` and commit the diff."]
    return 0, [f"baseline gate passed: {len(current['counters'])} counted "
               "metrics match the committed baseline"]
