"""Process-wide counter registry — one namespace for every counted metric.

Before this module, counted metrics lived in per-layer one-offs: the
oocore executor returned a ``StreamStats`` struct, remap exchange bytes
were re-derived inside individual benches, dispatch decisions were only
visible as the backend a kernel happened to run, and the execution-mode
fallback was a bare ``logging`` line. Nothing could correlate them. The
:class:`CounterRegistry` is the shared sink: every layer emits into one
flat dotted namespace (``oocore.dma.scheduled_bytes``,
``remap.a2a.bytes{transition=0}``, ``dispatch.backend{...}``), and
tooling — the span tracer's per-span counter deltas, ``python -m
repro.obs report``, and the CI baseline gate
(:mod:`repro.obs.baseline`) — reads it back uniformly.

Design rules:

* **Closed namespace.** Every counter's base name must be a member of
  :data:`NAMESPACES` (a pure literal, parsed by ``tests/check_docs.py``
  with ``ast`` and synced against the table in
  ``docs/observability.md``). An undocumented counter is a
  ``ValueError`` at the emit site, the same stance ``ops.BACKENDS``
  takes with the kernel matrix.
* **Labels, not name explosions.** Dimensional breakdowns attach as
  sorted ``{key=value}`` label suffixes — ``dispatch.backend{backend=
  pallas_fused_gather,source=static}`` — so the base name stays a
  stable aggregation key (:meth:`CounterRegistry.total`).
* **Counted, not timed, unless suffixed ``_s``.** Byte/decision/count
  metrics are host-independent and eligible for the committed baseline
  (``repro.obs.baseline.COUNTED_PREFIXES``); wall-time counters carry a
  ``_s`` suffix and never enter the gate.
* **stdlib only.** This module imports nothing from the rest of the
  repo (and no jax), so any layer — the residency planner included —
  can emit without an import cycle.

Emission is a dict update behind a lock; hot paths that emit do so at
trace/plan time (dispatch, planner) or once per host-level step
(oocore, remap), never per nonzero.
"""
from __future__ import annotations

import contextlib
import threading

__all__ = [
    "NAMESPACES",
    "CounterRegistry",
    "add",
    "counter_key",
    "get_registry",
    "record_remap_exchange",
    "record_stream_stats",
    "split_key",
    "use_registry",
]

# The closed counter namespace. Pure literal — tests/check_docs.py reads
# it with ``ast`` and fails CI when docs/observability.md's counter
# table and this tuple disagree (either direction). Keep it sorted.
NAMESPACES = (
    "cpals.phase_s",
    "cpals.sweep_s",
    "cpals.sweeps",
    "dispatch.backend",
    "dryrun.compile_s",
    "dryrun.lower_s",
    "execution.fallback",
    "execution.resolve",
    "oocore.chunks",
    "oocore.dma.distinct_bytes",
    "oocore.dma.index_stream_bytes",
    "oocore.dma.pipelined_bytes",
    "oocore.dma.scheduled_bytes",
    "oocore.mode_step_s",
    "oocore.mode_steps",
    "ops.step.model_bytes",
    "ops.step_s",
    "planner.plans",
    "planner.vmem.plan_bytes",
    "remap.a2a.bytes",
    "remap.a2a.uniform_bytes",
    "remap.transitions",
    "reorder.dma.postsort_distinct_bytes",
    "reorder.dma.postsort_scheduled_bytes",
    "reorder.dma.presort_distinct_bytes",
    "reorder.dma.presort_scheduled_bytes",
    "reorder.perms",
    "resilience.checkpoint.restores",
    "resilience.checkpoint.saves",
    "resilience.degradations",
    "resilience.injected",
    "resilience.interpret_fallbacks",
    "resilience.retries",
    "resilience.site_calls",
    "resilience.solve.guards",
    "resilience.table_fallbacks",
    "serve.decode_s",
    "serve.prefill_s",
    "serve.tokens",
    "tune.measure_s",
    "tune.points",
)

_NAMESPACE_SET = frozenset(NAMESPACES)


def counter_key(name: str, labels: dict | None = None) -> str:
    """Canonical registry key: ``name`` or ``name{k=v,...}`` (sorted keys)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def split_key(key: str) -> tuple[str, dict]:
    """Inverse of :func:`counter_key`: ``(base_name, labels)``."""
    if not key.endswith("}") or "{" not in key:
        return key, {}
    name, _, inner = key.partition("{")
    labels = {}
    for part in inner[:-1].split(","):
        if part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


class CounterRegistry:
    """A flat, labeled, validated counter store.

    Values accumulate with :meth:`add` (ints stay ints; a float emit
    makes the counter float). Thread-safe; snapshots are plain dicts so
    the tracer can diff them per span.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counts: dict[str, float] = {}

    def add(self, name: str, value=1, **labels) -> None:
        """Accumulate ``value`` into ``name`` (with optional labels).

        ``name`` must be a member of :data:`NAMESPACES` — an
        undocumented counter fails loudly at the emit site.
        """
        if name not in _NAMESPACE_SET:
            raise ValueError(
                f"counter {name!r} is not in repro.obs.counters.NAMESPACES "
                "— add it there and document it in docs/observability.md")
        key = counter_key(name, labels)
        with self._lock:
            self._counts[key] = self._counts.get(key, 0) + value

    def get(self, name: str, default=0, **labels):
        return self._counts.get(counter_key(name, labels), default)

    def total(self, prefix: str) -> float:
        """Sum of every counter whose base name starts with ``prefix``."""
        with self._lock:
            return sum(v for k, v in self._counts.items()
                       if split_key(k)[0].startswith(prefix))

    def snapshot(self) -> dict[str, float]:
        """Point-in-time copy (sorted keys — deterministic serialization)."""
        with self._lock:
            return {k: self._counts[k] for k in sorted(self._counts)}

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()

    def __len__(self) -> int:
        return len(self._counts)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"CounterRegistry({len(self._counts)} counters)"


# The process-wide default registry. Emitters resolve it through
# get_registry() at emit time so ``use_registry`` can scope collection
# (the baseline gate runs inside a fresh scoped registry).
_REGISTRY = CounterRegistry()


def get_registry() -> CounterRegistry:
    """The currently active process-wide registry."""
    return _REGISTRY


def add(name: str, value=1, **labels) -> None:
    """Emit into the active registry — the one-liner every layer uses."""
    _REGISTRY.add(name, value, **labels)


@contextlib.contextmanager
def use_registry(registry: CounterRegistry | None = None):
    """Scope the active registry (fresh one by default), then restore.

    Everything emitted inside the block — from any module — lands in the
    scoped registry, which is how the baseline gate collects one run's
    counters without inheriting whatever the process did before.
    """
    global _REGISTRY
    scoped = CounterRegistry() if registry is None else registry
    previous = _REGISTRY
    _REGISTRY = scoped
    try:
        yield scoped
    finally:
        _REGISTRY = previous


# ---------------------------------------------------------------------------
# Absorbers: the previously scattered counted structs -> one namespace
# ---------------------------------------------------------------------------

def record_stream_stats(stats, *, registry: CounterRegistry | None = None
                        ) -> None:
    """Absorb an oocore ``StreamStats`` into the registry.

    Duck-typed on the stat fields so this module never imports the
    executor. The counted-byte ordering contract
    (``scheduled >= distinct >= pipelined``) survives the round-trip by
    construction — each field maps to exactly one counter —
    which ``tests/test_obs.py`` property-checks.
    """
    reg = _REGISTRY if registry is None else registry
    reg.add("oocore.mode_steps", 1, backend=stats.backend)
    reg.add("oocore.chunks", stats.chunks)
    reg.add("oocore.dma.scheduled_bytes", stats.scheduled_tile_bytes)
    reg.add("oocore.dma.distinct_bytes", stats.distinct_tile_bytes)
    reg.add("oocore.dma.pipelined_bytes", stats.pipelined_tile_bytes)
    reg.add("oocore.dma.index_stream_bytes", stats.index_stream_bytes)
    # Locality-reordered runs (repro.reorder) additionally record the
    # before/after tile traffic under the reorder.dma.* names, labeled
    # with the policy — presort is the counted cost the same stream
    # would have paid unsorted, postsort duplicates the oocore.dma.*
    # bytes so one namespace tells the whole before/after story.
    if getattr(stats, "ordering", "none") != "none":
        o = stats.ordering
        reg.add("reorder.dma.presort_scheduled_bytes",
                stats.presort_scheduled_tile_bytes, ordering=o)
        reg.add("reorder.dma.presort_distinct_bytes",
                stats.presort_distinct_tile_bytes, ordering=o)
        reg.add("reorder.dma.postsort_scheduled_bytes",
                stats.scheduled_tile_bytes, ordering=o)
        reg.add("reorder.dma.postsort_distinct_bytes",
                stats.distinct_tile_bytes, ordering=o)


def record_remap_exchange(caps, num_workers: int, nmodes: int, *,
                          uniform_cap: bool = False,
                          registry: CounterRegistry | None = None) -> None:
    """Absorb a runtime's per-transition all_to_all sizing.

    ``caps`` is ``remap_capacities(ft)`` — entry ``n`` bounds the mode
    ``n -> n+1`` exchange. Bytes per transition are the allocated
    payload ``D * D * cap * (4 * nmodes + 4)`` (coords + value), the
    same arithmetic ``benchmarks.common.exchange_sizing`` reports;
    recording it at ``prepare_runtime`` time means every driver that
    builds a runtime — CP-ALS, benches, the serving path — counts its
    collective allocation without bench-side re-derivation.
    """
    reg = _REGISTRY if registry is None else registry
    caps = [int(c) for c in caps]
    elem_bytes = 4 * nmodes + 4
    per_pair = num_workers * num_workers * elem_bytes
    cap_used = [max(caps)] * len(caps) if uniform_cap else caps
    for n, cap in enumerate(cap_used):
        reg.add("remap.a2a.bytes", cap * per_pair, transition=n)
    reg.add("remap.a2a.uniform_bytes", len(caps) * max(caps) * per_pair)
    reg.add("remap.transitions", len(caps))
