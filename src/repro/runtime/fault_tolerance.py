"""Fault-tolerant training-loop runner + straggler monitoring.

The runner wraps a pure ``train_step`` with the operational loop a
1000+-node job needs:

* periodic atomic checkpoints + auto-resume (CheckpointManager);
* bounded retry on transient step failures (device OOM/interconnect hiccup
  → re-materialize state from the last checkpoint and replay data);
* straggler detection: per-step wall-time EWMA; a step slower than
  ``threshold×`` the EWMA is logged (on TPU pods the mitigation is
  re-scheduling the slow host; with the paper's static LPT load balance the
  compute itself cannot skew, so stragglers are infrastructural);
* preemption hooks: SIGTERM triggers a final checkpoint before exit.

Elastic scaling: state is saved unsharded and restored with *current*-mesh
shardings; the deterministic data pipeline (`repro.data`) is keyed by step,
so a job resumed on a different topology replays an identical stream.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Iterator

from ..checkpoint import CheckpointManager
from ..obs import counters as _obs

__all__ = ["StragglerMonitor", "TrainLoopRunner"]


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        straggler = False
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.events.append((step, dt, self.ewma))
            straggler = True
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        return straggler


class TrainLoopRunner:
    def __init__(self, train_step: Callable, ckpt: CheckpointManager, *,
                 ckpt_every: int = 50, max_retries: int = 2,
                 log_every: int = 10, log_fn: Callable = print):
        self.train_step = train_step
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.log_every = log_every
        self.log = log_fn
        self.monitor = StragglerMonitor()
        self._preempted = False

    def _install_sigterm(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def resume_or(self, state_template, shardings=None):
        """Restore the latest checkpoint or return the template as-is."""
        restored, step = self.ckpt.restore(state_template, shardings=shardings)
        if restored is None:
            return state_template, 0
        self.log(f"[runner] resumed from step {step}")
        return restored, int(step)

    def run(self, state, batches: Iterator, num_steps: int,
            start_step: int = 0) -> tuple[Any, list[dict]]:
        self._install_sigterm()
        history: list[dict] = []
        last_good = state
        retries = 0
        step = start_step
        it = iter(batches)
        while step < num_steps and not self._preempted:
            data_step, batch = next(it)
            assert data_step == step, (data_step, step)
            t0 = time.perf_counter()
            try:
                state, metrics = self.train_step(state, batch)
                loss = float(metrics["loss"])
                if loss != loss:           # NaN: treat as step failure
                    raise FloatingPointError(f"NaN loss at step {step}")
            except Exception as e:          # noqa: BLE001 — retry path
                retries += 1
                _obs.add("resilience.retries", site="train_step")
                self.log(f"[runner] step {step} failed ({e!r}); "
                         f"retry {retries}/{self.max_retries}")
                if retries > self.max_retries:
                    raise
                state = last_good            # roll back and replay
                it = iter(batches)           # caller passes resumable iter
                continue
            dt = time.perf_counter() - t0
            if self.monitor.observe(step, dt):
                self.log(f"[runner] straggler: step {step} took {dt:.3f}s "
                         f"(ewma {self.monitor.ewma:.3f}s)")
            history.append({"step": step, "loss": loss, "time_s": dt})
            if step % self.log_every == 0:
                self.log(f"[runner] step {step} loss {loss:.4f} "
                         f"{dt*1e3:.1f} ms")
            if self.ckpt_every and step and step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
                _obs.add("resilience.checkpoint.saves")
                last_good = state
                retries = 0
            step += 1
        if self._preempted:
            self.log(f"[runner] SIGTERM — checkpointing step {step}")
            self.ckpt.save(step, state)
            _obs.add("resilience.checkpoint.saves")
        return state, history
