from .execution import (CAPABILITY, Capability, ExecutionModeError,
                        EXECUTION_MODES, execution_mode, get_execution_mode,
                        resolve_interpret, set_execution_mode)
from .fault_tolerance import TrainLoopRunner, StragglerMonitor

__all__ = [
    "CAPABILITY",
    "Capability",
    "ExecutionModeError",
    "EXECUTION_MODES",
    "execution_mode",
    "get_execution_mode",
    "resolve_interpret",
    "set_execution_mode",
    "TrainLoopRunner",
    "StragglerMonitor",
]
