from .fault_tolerance import TrainLoopRunner, StragglerMonitor

__all__ = ["TrainLoopRunner", "StragglerMonitor"]
