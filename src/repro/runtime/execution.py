"""Execution-mode policy: the one interpret / compiled / auto switch.

Every Pallas entry point in this repo takes ``interpret: bool | None``
and resolves ``None`` here, so "does this kernel run in the interpreter
or compile to Mosaic?" is a single session-wide policy instead of a
per-call hardcode scattered through the stack (kernel.py → ops.py →
oocore/executor.py → tune/microbench.py → core/distributed.py all
defer).

Three modes (:data:`EXECUTION_MODES`):

  * ``"interpret"`` — always run the Pallas interpreter. Works on any
    backend; this is what CPU-only CI executes.
  * ``"compiled"`` — always compile to Mosaic. Raises
    :class:`ExecutionModeError` (with the probe's reason) when the host
    cannot execute Mosaic kernels, rather than silently interpreting —
    a wall-clock claim made under this mode is honest by construction.
  * ``"auto"`` (default) — compiled when the capability probe finds an
    attached TPU, otherwise interpret (the fallback reason is logged
    once and recorded in :func:`describe_meta`).

The capability probe runs once at import of this module (the dispatch
layer's import), answering "can a ``pallas_call(interpret=False)``
*execute* here?". Note the distinction from *lowering*: StableHLO +
Mosaic lowering works on any host via the AOT path
(``jax.jit(f).trace(...).lower(lowering_platforms=("tpu",))``) — that is
what ``repro.kernels.mttkrp.lowering`` validates on CPU-only CI.

Mode changes clear jax's compilation caches: the resolved interpret
flag is baked into traces as a static argument, so a cached jit entry
from the previous mode would otherwise keep executing the old policy.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging

from ..obs import counters as _obs
from ..resilience import faults as _faults

__all__ = [
    "EXECUTION_MODES",
    "Capability",
    "ExecutionModeError",
    "CAPABILITY",
    "probe_capability",
    "get_execution_mode",
    "set_execution_mode",
    "execution_mode",
    "resolve_interpret",
    "default_interpret",
    "describe_meta",
]

_LOG = logging.getLogger(__name__)

EXECUTION_MODES = ("interpret", "compiled", "auto")


class ExecutionModeError(RuntimeError):
    """``execution_mode="compiled"`` on a host that cannot run Mosaic."""


@dataclasses.dataclass(frozen=True)
class Capability:
    """What the capability probe found on this host.

    ``can_compile`` answers "can a ``pallas_call(interpret=False)``
    execute here?" — i.e. is a TPU attached. ``reason`` is the
    human-readable explanation when it cannot (empty when it can); it is
    surfaced in the ``"compiled"``-mode error and in the logged
    ``"auto"`` fallback.
    """

    platform: str
    can_compile: bool
    reason: str


def probe_capability() -> Capability:
    """Probe once whether compiled (Mosaic) Pallas execution is possible.

    The probe is deliberately cheap and deterministic: Mosaic kernels
    execute only on TPU backends, so ``jax.default_backend()`` is the
    whole story — there is no speculative trial compilation to a device
    that may be busy.
    """
    import jax

    platform = jax.default_backend()
    if platform == "tpu":
        return Capability(platform=platform, can_compile=True, reason="")
    return Capability(
        platform=platform, can_compile=False,
        reason=(f"jax default backend is {platform!r}, not 'tpu': Mosaic "
                "(compiled Pallas) kernels cannot execute on this host — "
                "only lowering validation is possible "
                "(repro.kernels.mttkrp.lowering)"))


# Probed at import of the dispatch module, per the policy contract above.
CAPABILITY = probe_capability()

_mode: str = "auto"
_fallback_logged: bool = False


def get_execution_mode() -> str:
    """The session's current execution mode."""
    return _mode


def set_execution_mode(mode: str) -> str:
    """Set the session execution mode; returns the previous mode.

    Clears jax's compilation caches (see module docstring): traces bake
    the resolved interpret flag in, so stale entries from the previous
    mode must not survive. Set the mode at configuration time, not in an
    inner loop.
    """
    global _mode
    if mode not in EXECUTION_MODES:
        raise ValueError(
            f"unknown execution_mode {mode!r}: expected one of "
            f"{EXECUTION_MODES}")
    previous = _mode
    if mode != previous:
        _mode = mode
        import jax

        jax.clear_caches()
        _LOG.info("execution_mode: %s -> %s", previous, mode)
    return previous


@contextlib.contextmanager
def execution_mode(mode: str):
    """Context manager: run a block under ``mode``, then restore."""
    previous = set_execution_mode(mode)
    try:
        yield CAPABILITY
    finally:
        set_execution_mode(previous)


def resolve_interpret(override: bool | None = None,
                      mode: str | None = None) -> bool:
    """Resolve the effective ``interpret`` flag for one kernel call.

    ``override`` is a caller's explicit bool (wins unconditionally;
    ``None`` defers to the policy). ``mode`` defaults to the session
    mode. Raises :class:`ExecutionModeError` for ``"compiled"`` on an
    incapable host — never silently interprets under that mode.
    """
    global _fallback_logged
    if override is not None:
        return bool(override)
    # Registered failure boundary (repro.resilience): resolution can
    # discover mid-job that the compiled path is gone. The hook sits
    # after the override check so a degradation policy's explicit
    # ``interpret=True`` fallback bypasses the faulty resolution.
    _faults.fault_site("execution.resolve")
    if mode is None:
        mode = _mode
    if mode not in EXECUTION_MODES:
        raise ValueError(
            f"unknown execution_mode {mode!r}: expected one of "
            f"{EXECUTION_MODES}")
    if mode == "interpret":
        _obs.add("execution.resolve", mode=mode, interpret=True)
        return True
    if mode == "compiled":
        if not CAPABILITY.can_compile:
            raise ExecutionModeError(
                "execution_mode='compiled' but compiled Pallas execution "
                f"is unavailable: {CAPABILITY.reason}. Use "
                "execution_mode='interpret' (or 'auto', which falls back "
                "with this reason) on this host.")
        _obs.add("execution.resolve", mode=mode, interpret=False)
        return False
    # auto
    if CAPABILITY.can_compile:
        _obs.add("execution.resolve", mode=mode, interpret=False)
        return False
    if not _fallback_logged:
        # Strictly once per process, and as an obs event first: the
        # counted `execution.fallback` record survives into traces and
        # reports even when nobody configured logging.
        _obs.add("execution.fallback", platform=CAPABILITY.platform)
        _LOG.info("execution_mode='auto' resolves to interpret: %s",
                  CAPABILITY.reason)
        _fallback_logged = True
    _obs.add("execution.resolve", mode=mode, interpret=True)
    return True


def default_interpret() -> bool:
    """The policy's answer with no per-call override — kernel.py's hook."""
    return resolve_interpret()


def describe_meta() -> dict:
    """Fingerprint of the active policy, for calibration-table metadata.

    ``interpret`` is the resolved flag the session's kernel calls use
    (``None`` if the mode cannot resolve on this host — a ``"compiled"``
    setting that would raise); ``execution_probe`` carries the probe's
    fallback reason so a saved table records *why* it was measured the
    way it was.
    """
    try:
        interpret = resolve_interpret()
    except ExecutionModeError:
        interpret = None
    return dict(
        execution_mode=_mode,
        interpret=interpret,
        execution_probe=CAPABILITY.reason or "tpu",
    )
