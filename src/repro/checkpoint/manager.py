"""Atomic, resharding-tolerant checkpointing.

Fault-tolerance contract (DESIGN.md §4):

* **Atomicity** — a step directory is written under ``<dir>/tmp.<step>``,
  fsynced, then ``rename``d to ``step_<step>``; a crash mid-write can never
  corrupt the latest valid checkpoint.
* **Auto-resume** — ``latest_step()`` scans for the newest complete step
  (marker file ``_DONE``); the training loop restarts from there and the
  deterministic data pipeline replays the exact stream.
* **Elastic restore** — leaves are stored *unsharded* (host-gathered) with
  the pytree structure in ``tree.json``; on restore they are
  ``jax.device_put`` with whatever shardings the *new* mesh prescribes, so
  a job can come back on a different device count (elastic scaling).
  For 1000+-node scale the same layout extends to per-shard files keyed by
  (leaf, shard-index) — the manager's API is already per-leaf.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree"]


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    # Directory fsync is what makes a rename durable on POSIX; platforms
    # that refuse O_RDONLY on directories (Windows) simply skip it.
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - non-POSIX
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_pytree(tree, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    manifest = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        fpath = os.path.join(path, fname)
        np.save(fpath, arr)
        _fsync_file(fpath)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    mpath = os.path.join(path, "tree.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())


def restore_pytree(template, path: str, shardings=None):
    """Restore into the structure of ``template``; ``shardings`` (optional
    matching pytree) re-shards each leaf for the current mesh (elastic)."""
    with open(os.path.join(path, "tree.json")) as f:
        manifest = json.load(f)
    keys = [k for k, _ in _flatten_with_paths(template)]
    leaves = []
    flat_sh = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(keys))
    for key, sh in zip(keys, flat_sh):
        arr = np.load(os.path.join(path, manifest[key]["file"]))
        if arr.dtype.kind not in "biufc":
            # Non-numeric leaves (config-fingerprint strings) have no JAX
            # dtype — they stay host numpy for the caller to validate.
            leaves.append(arr)
        elif sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        # A crash mid-save leaves a tmp.<step> behind; it can never be
        # restored from (no rename happened), so sweep it at startup
        # rather than letting dead half-written trees accumulate.
        for name in os.listdir(directory):
            if name.startswith("tmp."):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, tree) -> str:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(tree, tmp)
        with open(os.path.join(tmp, "_DONE"), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        # fsync order is the atomicity: every file in tmp is durable,
        # then the tmp dir entry list, then the rename, then the parent
        # so the rename itself survives power loss.
        _fsync_dir(tmp)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _fsync_dir(self.dir)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "_DONE")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        return restore_pytree(template, self._step_dir(step), shardings), step
