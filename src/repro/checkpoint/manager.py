"""Atomic, resharding-tolerant checkpointing.

Fault-tolerance contract (DESIGN.md §4):

* **Atomicity** — a step directory is written under ``<dir>/tmp.<step>``,
  fsynced, then ``rename``d to ``step_<step>``; a crash mid-write can never
  corrupt the latest valid checkpoint.
* **Auto-resume** — ``latest_step()`` scans for the newest complete step
  (marker file ``_DONE``); the training loop restarts from there and the
  deterministic data pipeline replays the exact stream.
* **Elastic restore** — leaves are stored *unsharded* (host-gathered) with
  the pytree structure in ``tree.json``; on restore they are
  ``jax.device_put`` with whatever shardings the *new* mesh prescribes, so
  a job can come back on a different device count (elastic scaling).
  For 1000+-node scale the same layout extends to per-shard files keyed by
  (leaf, shard-index) — the manager's API is already per-leaf.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree"]


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_pytree(tree, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    manifest = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(path, fname), arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    with open(os.path.join(path, "tree.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore_pytree(template, path: str, shardings=None):
    """Restore into the structure of ``template``; ``shardings`` (optional
    matching pytree) re-shards each leaf for the current mesh (elastic)."""
    with open(os.path.join(path, "tree.json")) as f:
        manifest = json.load(f)
    keys = [k for k, _ in _flatten_with_paths(template)]
    leaves = []
    flat_sh = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(keys))
    for key, sh in zip(keys, flat_sh):
        arr = np.load(os.path.join(path, manifest[key]["file"]))
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, tree) -> str:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(tree, tmp)
        with open(os.path.join(tmp, "_DONE"), "w") as f:
            f.write(str(step))
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "_DONE")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        return restore_pytree(template, self._step_dir(step), shardings), step
