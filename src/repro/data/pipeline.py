"""Deterministic, shardable synthetic LM data pipeline.

Production posture without a corpus dependency: every (step, shard) cell of
the token stream is a pure function of ``(seed, step, global_example_id)``
via a counter-based hash (splitmix64), so:

* any host can generate exactly its shard — no data server, no files;
* restart/resume replays the exact stream from the checkpointed step
  (fault-tolerance requirement: step replay is bit-exact);
* elastic re-sharding (different host count after restart) still yields the
  same global batch order.

Tokens follow a Zipf-like marginal with a deterministic n-gram-ish
structure (next token depends on previous via a mixing hash) so models have
learnable signal — the quickstart example's loss visibly drops.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLMData", "make_batch_iterator"]


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.2

    def _tokens(self, step: int, example_ids: np.ndarray) -> np.ndarray:
        """(len(example_ids), seq_len+1) int32 token stream."""
        n = len(example_ids)
        base = (np.uint64(self.seed) * np.uint64(0x100000001B3)
                + np.uint64(step) * np.uint64(0x1000193))
        pos = np.arange(self.seq_len + 1, dtype=np.uint64)[None, :]
        eid = example_ids.astype(np.uint64)[:, None]
        h = _splitmix64(base + eid * np.uint64(1 << 20) + pos)
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        # Zipf-ish marginal via inverse CDF u^(1/(alpha-1)) flavor
        ranks = np.minimum(
            (self.vocab * u ** self.zipf_alpha).astype(np.int64),
            self.vocab - 1)
        # inject structure: token_t also depends on token_{t-1} bucket
        prev = np.roll(ranks, 1, axis=1)
        prev[:, 0] = 0
        mixed = (ranks + (prev % 17) * 31) % self.vocab
        return mixed.astype(np.int32)

    def batch(self, step: int, *, shard: int = 0, num_shards: int = 1):
        """Host-sharded batch: dict(tokens, labels, loss_mask)."""
        per = self.global_batch // num_shards
        ids = np.arange(per, dtype=np.int64) + shard * per \
            + np.int64(step) * self.global_batch
        stream = self._tokens(step, ids)
        return {
            "tokens": stream[:, :-1],
            "labels": stream[:, 1:].astype(np.int32),
            "loss_mask": np.ones((per, self.seq_len), np.float32),
        }


def make_batch_iterator(vocab: int, seq_len: int, global_batch: int, *,
                        seed: int = 0, start_step: int = 0,
                        shard: int = 0, num_shards: int = 1):
    """Infinite deterministic iterator, resumable at ``start_step``."""
    src = SyntheticLMData(vocab, seq_len, global_batch, seed=seed)
    step = start_step
    while True:
        yield step, src.batch(step, shard=shard, num_shards=num_shards)
        step += 1
