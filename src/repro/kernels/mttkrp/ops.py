"""jit'd wrappers: FLYCOO shard layout construction + Pallas MTTKRP dispatch.

``build_block_layout`` turns the sorted per-device nonzero stream into the
block-aligned layout the kernel requires (no block straddles an output row
tile — the runtime equivalent of FLYCOO's shard/super-shard alignment), then
``mttkrp_device_step`` runs gather → (fused) Hadamard → blocked scatter.

Backend matrix (``mttkrp_device_step(backend=...)``), valid for any tensor
order N:

  ================  =========================================================
  backend           path
  ================  =========================================================
  ``pallas_fused``  N-mode fused kernel (``fused_mttkrp_nmode``): gathered
                    factor-row blocks stream into VMEM and the Hadamard
                    product is formed inside the kernel body. Cheapest HBM
                    traffic — the per-nonzero ``contrib`` row is never
                    materialized (saves 2·R·4 B/nonzero of contrib
                    write+read vs. ``pallas``).
  ``pallas``        materialized path: the ``(cap, R)`` contrib is built by
                    XLA in HBM, then ``segment_accumulate`` scatters it.
                    Smallest VMEM working set (one contrib block, no
                    per-input-mode operands) — the fallback when N−1
                    gathered blocks would blow the VMEM budget.
  ``ref``           pure-jnp sorted ``segment_sum`` oracle — tiny ranks
                    (MXU one-hot padding to R=128 wastes the array) and
                    A/B testing.
  ``auto``          picks one of the above from (mode count, rank padding,
                    VMEM budget) via :func:`select_backend`.
  ================  =========================================================

(The plain-XLA ``segsum`` backend used by dry-runs lives one level up in
``core.distributed.device_mttkrp`` — it never reaches this module.)

Everything here is static-shape and jit-safe so it can live inside
``shard_map`` per device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

__all__ = [
    "build_block_layout",
    "fused_fits_vmem",
    "mttkrp_blocked",
    "mttkrp_device_step",
    "pad_rank",
    "select_backend",
    "VMEM_BUDGET_BYTES",
]

# Per-core VMEM working-set budget for the auto dispatch (half of a v5e
# core's ~128 MiB VMEM — same θ=0.5 cache-fraction stance as the paper's
# Eq. 3).
VMEM_BUDGET_BYTES = 64 * 1024 * 1024

# Below this rank the one-hot MXU matmul pads R to 128 and wastes ≥ 16× of
# the array; the XLA segment-sum reference wins.
_MIN_MXU_RANK = 8


def pad_rank(x, multiple: int = 128):
    """Pad the trailing (rank) dim to an MXU-aligned multiple."""
    r = x.shape[-1]
    pad = (-r) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def padded_rank(rank: int, multiple: int = 128) -> int:
    """Static version of :func:`pad_rank` for dispatch arithmetic."""
    return rank + (-rank) % multiple


def fused_fits_vmem(nmodes: int, rank: int, blk: int, tile_rows: int,
                    vmem_budget: int = VMEM_BUDGET_BYTES) -> bool:
    """Hard feasibility: does the fused kernel's working set fit VMEM?

    The single predicate both dispatch layers use (static rule here,
    tuned planning in ``repro.tune.model``) — a calibration table may
    *prefer* ``pallas_fused``, but never past this bound.
    """
    fused_bytes = _kernel.fused_vmem_bytes(
        nmodes - 1, padded_rank(rank), blk, tile_rows)
    return fused_bytes <= vmem_budget


def select_backend(
    backend: str,
    *,
    nmodes: int,
    rank: int,
    blk: int = 512,
    tile_rows: int = 128,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    table=None,
) -> str:
    """Resolve ``auto`` to a concrete backend; pass others through.

    When a calibration ``table`` (a ``repro.tune`` ``CalibrationTable``
    or ``CostModel`` — anything with a ``best_backend`` method) is
    given, ``auto`` follows the *measured* argmin interpolated to this
    configuration instead of the static model below. The table is
    consulted duck-typed so this module never imports ``repro.tune``;
    if it cannot answer (no entries near this configuration) the static
    decision applies, bit-identical to the no-table path. VMEM
    feasibility is a hard constraint, not a preference: a table answer
    of ``pallas_fused`` whose working set exceeds ``vmem_budget`` (an
    extrapolation beyond the measured grid) is discarded and the static
    decision applies.

    Static decision, in order (all static — safe to call under jit
    tracing):
      1. ``rank < 8`` → ``ref``: the MXU one-hot scatter pads R to 128, so
         ≥ 16× of every matmul is padding; plain segment-sum wins.
      2. fused VMEM working set (N−1 gathered factor blocks + contrib +
         one-hot + out tile, see ``kernel.fused_vmem_bytes``) fits the
         budget → ``pallas_fused``: minimum HBM traffic.
      3. otherwise → ``pallas``: materialize contrib in HBM, keeping only
         one block in VMEM per grid step.
    """
    if backend != "auto":
        if backend not in ("pallas", "pallas_fused", "ref"):
            raise ValueError(
                f"unknown MTTKRP backend {backend!r}: expected 'auto', "
                "'pallas', 'pallas_fused' or 'ref' (the plain-XLA 'segsum' "
                "path is handled by core.distributed.device_mttkrp)")
        return backend
    if table is not None:
        # Below the MXU-padding threshold the table may only answer from
        # ranks it actually measured (a `covers` check, duck-typed like
        # best_backend) — clamped below-grid extrapolation must not
        # override the static rank<8 -> ref rule.
        covers = getattr(table, "covers", None)
        rank_ok = rank >= _MIN_MXU_RANK or (
            covers is not None and covers(nmodes=nmodes, rank=rank,
                                          blk=blk, tile_rows=tile_rows))
        choice = table.best_backend(
            nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
            allowed=("pallas", "pallas_fused", "ref"),
        ) if rank_ok else None
        if choice == "pallas_fused" and not fused_fits_vmem(
                nmodes, rank, blk, tile_rows, vmem_budget):
            choice = None               # infeasible extrapolation
        if choice is not None:
            return choice
    if rank < _MIN_MXU_RANK:
        return "ref"
    if fused_fits_vmem(nmodes, rank, blk, tile_rows, vmem_budget):
        return "pallas_fused"
    return "pallas"


def n_pad_for(cap: int, rows_cap: int, blk: int, tile_rows: int) -> int:
    """Static aligned-stream length: every tile wastes < blk slots."""
    num_tiles = rows_cap // tile_rows
    return ((cap + blk - 1) // blk) * blk + num_tiles * blk


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows")
)
def build_block_layout(local_row, valid, *, rows_cap: int, blk: int,
                       tile_rows: int):
    """Compute block-aligned slots for a sorted nonzero stream.

    Args:
      local_row: ``(cap,)`` int32 output row per element, ascending among
        valid elements; invalid elements trail.
      valid: ``(cap,)`` bool.
      rows_cap: output rows (multiple of ``tile_rows``).

    Returns:
      ``(slot, tile_of_block)`` — ``slot[(cap,)]`` destination of each
      element in the aligned stream (``n_pad_for(...)`` = dump slot for
      invalid), ``tile_of_block[(n_pad//blk,)]`` non-decreasing output tile
      per block.
    """
    cap = local_row.shape[0]
    num_tiles = rows_cap // tile_rows
    n_pad = n_pad_for(cap, rows_cap, blk, tile_rows)

    tile_of_elem = jnp.where(valid, local_row // tile_rows, num_tiles)
    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), tile_of_elem, num_segments=num_tiles + 1
    )[:num_tiles]
    padded = ((counts + blk - 1) // blk) * blk
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(padded).astype(jnp.int32)])
    # Elements are sorted by (valid desc, row asc) => per-tile runs contiguous.
    first_of_tile = jnp.searchsorted(tile_of_elem, tile_of_elem, side="left")
    rank_in_tile = jnp.arange(cap, dtype=jnp.int32) - first_of_tile.astype(jnp.int32)
    slot = jnp.where(
        valid,
        jnp.take(offsets, tile_of_elem, fill_value=0) + rank_in_tile,
        n_pad,
    )
    block_start = jnp.arange(n_pad // blk, dtype=jnp.int32) * blk
    tile_of_block = jnp.clip(
        jnp.searchsorted(offsets, block_start, side="right") - 1,
        0, num_tiles - 1,
    ).astype(jnp.int32)
    return slot, tile_of_block


def _align_to_blocks(x, slot, n_pad: int):
    """Scatter ``(cap, ...)`` stream rows into their block-aligned slots.

    Slot ``n_pad`` is the dump row for invalid elements; it is allocated and
    then sliced off, so invalid entries vanish regardless of their payload.
    """
    out_shape = (n_pad + 1,) + x.shape[1:]
    return jnp.zeros(out_shape, x.dtype).at[slot].set(x)[:-1]


@functools.partial(
    jax.jit,
    static_argnames=("rows_cap", "blk", "tile_rows", "interpret", "use_ref"),
)
def mttkrp_blocked(contrib, local_row, valid, *, rows_cap: int,
                   blk: int = 512, tile_rows: int = 128,
                   interpret: bool = True, use_ref: bool = False):
    """Scatter stage on a sorted stream via the Pallas kernel.

    ``use_ref=True`` routes to the pure-jnp oracle (A/B testing and the
    CPU-bench path).
    """
    if use_ref:
        masked = jnp.where(valid[:, None], contrib, 0.0)
        row = jnp.where(valid, local_row, 0)
        return _ref.segment_accumulate_ref(masked, row, rows_cap)

    n_pad = n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
    slot, tile_of_block = build_block_layout(
        local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows
    )
    rank = contrib.shape[-1]
    contrib_pad = pad_rank(contrib)
    aligned = _align_to_blocks(
        jnp.where(valid[:, None], contrib_pad, 0.0), slot, n_pad
    )
    row_aligned = _align_to_blocks(
        (local_row % tile_rows).astype(jnp.int32), slot, n_pad
    )
    out = _kernel.segment_accumulate(
        aligned, row_aligned, tile_of_block,
        rows_cap=rows_cap, blk=blk, tile_rows=tile_rows, interpret=interpret,
    )
    return out[:, :rank]


@functools.partial(
    jax.jit,
    static_argnames=("mode", "rows_cap", "blk", "tile_rows", "interpret",
                     "backend"),
)
def mttkrp_device_step(idx, val, valid, factors, *, mode: int, rows_cap: int,
                       row_offset, blk: int = 512, tile_rows: int = 128,
                       interpret: bool = True, backend: str = "pallas"):
    """Full per-device mode step: gather → Hadamard → blocked scatter.

    Args:
      idx: ``(cap, N)`` permuted coordinates of owned nonzeros, sorted by
        output row (valid first).
      val: ``(cap,)`` values (0 on padding).
      valid: ``(cap,)`` bool.
      factors: list of ``(I_pad_w, R)`` replicated factor matrices (permuted
        row space).
      mode: output mode.
      rows_cap: owned output rows.
      row_offset: scalar — first owned permuted row (``device_id*rows_cap``).
      backend: ``pallas`` | ``pallas_fused`` (any N) | ``ref`` | ``auto``
        (see the module docstring's backend matrix).

    Returns ``(rows_cap, R)`` float32 local output factor rows.
    """
    nmodes = idx.shape[1]
    rank = factors[mode].shape[-1]
    backend = select_backend(
        backend, nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows
    )
    local_row = (idx[:, mode] - row_offset).astype(jnp.int32)
    local_row = jnp.where(valid, local_row, 0)

    in_modes = [w for w in range(nmodes) if w != mode]
    if backend == "pallas_fused":
        vals = jnp.where(valid, val, 0.0)
        n_pad = n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
        slot, tile_of_block = build_block_layout(
            local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows
        )
        rows_al = tuple(
            _align_to_blocks(
                pad_rank(jnp.take(factors[w], idx[:, w], axis=0)), slot, n_pad
            )
            for w in in_modes
        )
        v_al = _align_to_blocks(vals, slot, n_pad)
        r_al = _align_to_blocks(
            (local_row % tile_rows).astype(jnp.int32), slot, n_pad
        )
        out = _kernel.fused_mttkrp_nmode(
            v_al, rows_al, r_al, tile_of_block,
            rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
            interpret=interpret,
        )
        return out[:, :rank]

    # Materialized path: contrib built in HBM, then blocked scatter.
    ell = jnp.where(valid, val, 0.0)[:, None].astype(factors[0].dtype)
    for w in in_modes:
        ell = ell * jnp.take(factors[w], idx[:, w], axis=0)
    use_ref = backend == "ref"
    return mttkrp_blocked(
        ell.astype(jnp.float32), local_row, valid, rows_cap=rows_cap,
        blk=blk, tile_rows=tile_rows, interpret=interpret, use_ref=use_ref,
    )
