"""jit'd wrappers: FLYCOO shard layout construction + Pallas MTTKRP call.

``build_block_layout`` turns the sorted per-device nonzero stream into the
block-aligned layout the kernel requires (no block straddles an output row
tile — the runtime equivalent of FLYCOO's shard/super-shard alignment), then
``mttkrp_device_step`` runs gather → (fused) Hadamard → blocked scatter.

Everything here is static-shape and jit-safe so it can live inside
``shard_map`` per device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

__all__ = [
    "build_block_layout",
    "mttkrp_blocked",
    "mttkrp_device_step",
    "pad_rank",
]


def pad_rank(x, multiple: int = 128):
    """Pad the trailing (rank) dim to an MXU-aligned multiple."""
    r = x.shape[-1]
    pad = (-r) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def n_pad_for(cap: int, rows_cap: int, blk: int, tile_rows: int) -> int:
    """Static aligned-stream length: every tile wastes < blk slots."""
    num_tiles = rows_cap // tile_rows
    return ((cap + blk - 1) // blk) * blk + num_tiles * blk


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows")
)
def build_block_layout(local_row, valid, *, rows_cap: int, blk: int,
                       tile_rows: int):
    """Compute block-aligned slots for a sorted nonzero stream.

    Args:
      local_row: ``(cap,)`` int32 output row per element, ascending among
        valid elements; invalid elements trail.
      valid: ``(cap,)`` bool.
      rows_cap: output rows (multiple of ``tile_rows``).

    Returns:
      ``(slot, tile_of_block)`` — ``slot[(cap,)]`` destination of each
      element in the aligned stream (``n_pad_for(...)`` = dump slot for
      invalid), ``tile_of_block[(n_pad//blk,)]`` non-decreasing output tile
      per block.
    """
    cap = local_row.shape[0]
    num_tiles = rows_cap // tile_rows
    n_pad = n_pad_for(cap, rows_cap, blk, tile_rows)

    tile_of_elem = jnp.where(valid, local_row // tile_rows, num_tiles)
    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), tile_of_elem, num_segments=num_tiles + 1
    )[:num_tiles]
    padded = ((counts + blk - 1) // blk) * blk
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(padded).astype(jnp.int32)])
    # Elements are sorted by (valid desc, row asc) => per-tile runs contiguous.
    first_of_tile = jnp.searchsorted(tile_of_elem, tile_of_elem, side="left")
    rank_in_tile = jnp.arange(cap, dtype=jnp.int32) - first_of_tile.astype(jnp.int32)
    slot = jnp.where(
        valid,
        jnp.take(offsets, tile_of_elem, fill_value=0) + rank_in_tile,
        n_pad,
    )
    block_start = jnp.arange(n_pad // blk, dtype=jnp.int32) * blk
    tile_of_block = jnp.clip(
        jnp.searchsorted(offsets, block_start, side="right") - 1,
        0, num_tiles - 1,
    ).astype(jnp.int32)
    return slot, tile_of_block


@functools.partial(
    jax.jit,
    static_argnames=("rows_cap", "blk", "tile_rows", "interpret", "use_ref"),
)
def mttkrp_blocked(contrib, local_row, valid, *, rows_cap: int,
                   blk: int = 512, tile_rows: int = 128,
                   interpret: bool = True, use_ref: bool = False):
    """Scatter stage on a sorted stream via the Pallas kernel.

    ``use_ref=True`` routes to the pure-jnp oracle (A/B testing and the
    CPU-bench path).
    """
    if use_ref:
        masked = jnp.where(valid[:, None], contrib, 0.0)
        row = jnp.where(valid, local_row, 0)
        return _ref.segment_accumulate_ref(masked, row, rows_cap)

    n_pad = n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
    slot, tile_of_block = build_block_layout(
        local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows
    )
    rank = contrib.shape[-1]
    contrib_pad = pad_rank(contrib)
    rpad = contrib_pad.shape[-1]
    aligned = jnp.zeros((n_pad + 1, rpad), contrib_pad.dtype)\
        .at[slot].set(jnp.where(valid[:, None], contrib_pad, 0.0))[:-1]
    row_aligned = jnp.zeros((n_pad + 1,), jnp.int32)\
        .at[slot].set((local_row % tile_rows).astype(jnp.int32))[:-1]
    out = _kernel.segment_accumulate(
        aligned, row_aligned, tile_of_block,
        rows_cap=rows_cap, blk=blk, tile_rows=tile_rows, interpret=interpret,
    )
    return out[:, :rank]


@functools.partial(
    jax.jit,
    static_argnames=("mode", "rows_cap", "blk", "tile_rows", "interpret",
                     "backend"),
)
def mttkrp_device_step(idx, val, valid, factors, *, mode: int, rows_cap: int,
                       row_offset, blk: int = 512, tile_rows: int = 128,
                       interpret: bool = True, backend: str = "pallas"):
    """Full per-device mode step: gather → Hadamard → blocked scatter.

    Args:
      idx: ``(cap, N)`` permuted coordinates of owned nonzeros, sorted by
        output row (valid first).
      val: ``(cap,)`` values (0 on padding).
      valid: ``(cap,)`` bool.
      factors: list of ``(I_pad_w, R)`` replicated factor matrices (permuted
        row space).
      mode: output mode.
      rows_cap: owned output rows.
      row_offset: scalar — first owned permuted row (``device_id*rows_cap``).
      backend: ``pallas`` | ``pallas_fused`` (3-mode) | ``ref``.

    Returns ``(rows_cap, R)`` float32 local output factor rows.
    """
    nmodes = idx.shape[1]
    local_row = (idx[:, mode] - row_offset).astype(jnp.int32)
    local_row = jnp.where(valid, local_row, 0)

    in_modes = [w for w in range(nmodes) if w != mode]
    if backend == "pallas_fused" and len(in_modes) == 2:
        rows_a = jnp.take(factors[in_modes[0]], idx[:, in_modes[0]], axis=0)
        rows_b = jnp.take(factors[in_modes[1]], idx[:, in_modes[1]], axis=0)
        vals = jnp.where(valid, val, 0.0)
        n_pad = n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
        slot, tile_of_block = build_block_layout(
            local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows
        )
        rank = rows_a.shape[-1]
        ra = pad_rank(rows_a)
        rb = pad_rank(rows_b)
        rpad = ra.shape[-1]
        ra_al = jnp.zeros((n_pad + 1, rpad), ra.dtype).at[slot].set(ra)[:-1]
        rb_al = jnp.zeros((n_pad + 1, rpad), rb.dtype).at[slot].set(rb)[:-1]
        v_al = jnp.zeros((n_pad + 1,), vals.dtype).at[slot].set(vals)[:-1]
        r_al = jnp.zeros((n_pad + 1,), jnp.int32)\
            .at[slot].set((local_row % tile_rows).astype(jnp.int32))[:-1]
        out = _kernel.fused_mttkrp_3mode(
            v_al, ra_al, rb_al, r_al, tile_of_block,
            rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
            interpret=interpret,
        )
        return out[:, :rank]

    # Generic N-mode: materialize contrib, then blocked scatter.
    ell = jnp.where(valid, val, 0.0)[:, None].astype(factors[0].dtype)
    for w in in_modes:
        ell = ell * jnp.take(factors[w], idx[:, w], axis=0)
    use_ref = backend == "ref"
    return mttkrp_blocked(
        ell.astype(jnp.float32), local_row, valid, rows_cap=rows_cap,
        blk=blk, tile_rows=tile_rows, interpret=interpret, use_ref=use_ref,
    )
