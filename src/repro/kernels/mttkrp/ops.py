"""jit'd wrappers: FLYCOO shard layout construction + Pallas MTTKRP dispatch.

``build_block_layout`` turns the sorted per-device nonzero stream into the
block-aligned layout the kernel requires (no block straddles an output row
tile — the runtime equivalent of FLYCOO's shard/super-shard alignment), then
``mttkrp_device_step`` runs gather → (fused) Hadamard → blocked scatter.

The runnable backends are the :data:`BACKENDS` tuple (``ref`` / ``pallas``
/ ``pallas_fused`` / ``pallas_fused_tiled`` / ``pallas_fused_bf16`` /
``pallas_fused_gather`` / ``pallas_fused_gather_tiled`` /
``pallas_fused_gather_bf16`` / ``pallas_fused_gather_stream``), plus
``auto`` which resolves through :func:`select_backend`. **The full
backend decision matrix — per-backend traffic/VMEM characteristics, the
working-set formulas, and worked ``auto`` examples — lives in
``docs/kernels.md``;** this module deliberately doesn't duplicate that
table. Short version: ``auto`` picks the cheapest numerics-preserving
path whose residency the :mod:`repro.oocore.planner` can certify under
the VMEM budget (in-kernel gather → slab-streamed gather → out-of-core
row-streamed gather → fused → rank-tiled → materialized, with a
segment-sum ``ref`` below the MXU-padding rank threshold; the gather
family needs the factor sizes — ``factor_rows`` — to be considered);
the bf16-gather variants (bf16 gathers, fp32 accumulate — halve gather
traffic, ≈(N−1)·2⁻⁸ rel. error) are opt-in only and never chosen by
``auto``.

(The plain-XLA ``segsum`` backend used by dry-runs lives one level up in
``core.distributed.device_mttkrp`` — it never reaches this module.)

Everything here is static-shape and jit-safe so it can live inside
``shard_map`` per device.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref
from ...obs import counters as _obs
from ...obs import tracer as _tracer_mod
from ...oocore import planner as _planner
# Imported as the submodule path (not via the package __init__) so the
# reorder ↔ kernels import cycle resolves: ordering.py only needs
# ``kernel`` (already initialized when this module loads), and we only
# touch _reorder attributes at call time.
from ...reorder import ordering as _reorder
from ...resilience import faults as _faults
from ...resilience import policy as _resilience

__all__ = [
    "BACKENDS",
    "AUTO_BACKENDS",
    "GATHER_BACKENDS",
    "STREAM_BACKEND",
    "MIN_MXU_RANK",
    "MXU_RANK_MULTIPLE",
    "build_block_layout",
    "fused_fits_vmem",
    "gather_fits_vmem",
    "gather_stream_fits_vmem",
    "mttkrp_blocked",
    "mttkrp_device_step",
    "pad_rank",
    "select_backend",
    "step_traffic_bytes",
    "tile_schedule",
    "timed_device_step",
    "VMEM_BUDGET_BYTES",
]

# MXU lane width — rank padding multiple and the rank-slab width of the
# tiled kernel. Single source of truth in kernel.py.
MXU_RANK_MULTIPLE = _kernel.MXU_RANK_MULTIPLE

# Per-core VMEM working-set budget for the auto dispatch (half of a v5e
# core's ~128 MiB VMEM — same θ=0.5 cache-fraction stance as the paper's
# Eq. 3). Single source of truth in kernel.py (shared with the
# repro.oocore planner, which may be imported before this module).
VMEM_BUDGET_BYTES = _kernel.VMEM_BUDGET_BYTES

# Below this rank the one-hot MXU matmul pads R to MXU_RANK_MULTIPLE and
# wastes ≥ 16× of the array; the XLA segment-sum reference wins.
# (kernel.py owns it so dispatch and planner can never disagree.)
MIN_MXU_RANK = _kernel.MIN_MXU_RANK

# Backends this module can run (mttkrp_device_step / select_backend).
# docs/kernels.md's decision matrix is CI-checked against this tuple
# (tests/check_docs.py); ``segsum`` dispatches one level up in
# core.distributed and ``auto`` is the select_backend resolver, so
# neither appears here.
BACKENDS = (
    "ref",
    "pallas",
    "pallas_fused",
    "pallas_fused_tiled",
    "pallas_fused_bf16",
    "pallas_fused_gather",
    "pallas_fused_gather_tiled",
    "pallas_fused_gather_bf16",
    "pallas_fused_gather_stream",
)

# What ``auto`` may resolve to (statically or via a calibration table):
# every BACKENDS member that preserves fp32 numerics. The bf16-gather
# variants trade accuracy for gather traffic and must be requested
# explicitly (backend string or DynasorRuntime.gather_dtype) — a timing
# table must never silently change numerics.
AUTO_BACKENDS = tuple(b for b in BACKENDS if not b.endswith("_bf16"))

# The in-kernel gather family mttkrp_device_step runs through the
# gather kernels (after the *_bf16 name is folded into gather_dtype):
# these skip the HBM materialization of gathered factor rows entirely.
GATHER_BACKENDS = ("pallas_fused_gather", "pallas_fused_gather_tiled")

# The out-of-core member of the gather family: factors stay HBM-resident
# and stream through a bounded VMEM tile window (``repro.oocore``).
STREAM_BACKEND = _kernel.STREAM_BACKEND_NAME


def pad_rank(x, multiple: int = MXU_RANK_MULTIPLE):
    """Pad the trailing (rank) dim to an MXU-aligned multiple."""
    r = x.shape[-1]
    pad = (-r) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


# Static version of :func:`pad_rank` for dispatch arithmetic — aliased
# from kernel.py, the single source shared with the residency planner.
padded_rank = _kernel.padded_rank


def _pad_factor_rows(x, multiple: int):
    """Pad a factor's leading (row) dim to a whole number of stream tiles.

    The stream kernel DMAs ``FACTOR_ROW_TILE``-row tiles out of the
    HBM-resident factor, so its row count must divide evenly; padding
    rows are zero and unreachable (indices are < the true row count).
    """
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)


def fused_fits_vmem(nmodes: int, rank: int, blk: int, tile_rows: int,
                    vmem_budget: int = VMEM_BUDGET_BYTES, *,
                    tiled: bool = False, gather_itemsize: int = 4) -> bool:
    """Hard feasibility: does a fused kernel's working set fit VMEM?

    Thin delegate to :func:`repro.oocore.planner.backend_fits` — the one
    residency authority every dispatch layer shares (static rule here,
    tuned planning in ``repro.tune.model``). A calibration table may
    *prefer* a fused backend, but never past this bound. ``tiled=True``
    budgets one ``RANK_SLAB``-wide slab instead of the full padded rank
    (the rank-tiled kernel's working set); ``gather_itemsize=2`` sizes
    the bf16-gather variants.
    """
    return _planner.backend_fits(
        "pallas_fused_tiled" if tiled else "pallas_fused",
        nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
        vmem_budget=vmem_budget, gather_itemsize=gather_itemsize)


def gather_fits_vmem(nmodes: int, rank: int, blk: int, tile_rows: int,
                     factor_rows: int, vmem_budget: int = VMEM_BUDGET_BYTES,
                     *, tiled: bool = False,
                     gather_itemsize: int = 4) -> bool:
    """Hard feasibility of the resident in-kernel gather family.

    ``factor_rows`` is the total row count of the N−1 replicated
    input-factor matrices (Σ I_pad over non-output modes) — the resident
    operand the gather kernels hold in VMEM. ``tiled=True`` budgets one
    ``RANK_SLAB``-wide column slab of each factor instead of the full
    padded rank (the slab-streamed regime); ``gather_itemsize=2`` sizes
    the bf16-gather variants. Delegates to the ``repro.oocore`` planner.
    """
    return _planner.backend_fits(
        "pallas_fused_gather_tiled" if tiled else "pallas_fused_gather",
        nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
        factor_rows=factor_rows, vmem_budget=vmem_budget,
        gather_itemsize=gather_itemsize)


def gather_stream_fits_vmem(nmodes: int, rank: int, blk: int,
                            tile_rows: int, factor_rows,
                            vmem_budget: int = VMEM_BUDGET_BYTES, *,
                            gather_itemsize: int = 4) -> bool:
    """Hard feasibility of the out-of-core row-streamed gather.

    Unlike the resident family, this scales with the *window* (``Σ_w
    min(blk, ceil(rows_w / FACTOR_ROW_TILE))`` tiles of 128 rows, one
    rank slab wide), not with the factor sizes — only the window must
    fit. ``factor_rows`` may be the aggregate int (conservative windows)
    or a per-input-mode sequence (exact). Delegates to the
    ``repro.oocore`` planner.
    """
    return _planner.backend_fits(
        STREAM_BACKEND, nmodes=nmodes, rank=rank, blk=blk,
        tile_rows=tile_rows, factor_rows=factor_rows,
        vmem_budget=vmem_budget, gather_itemsize=gather_itemsize)


def select_backend(
    backend: str,
    *,
    nmodes: int,
    rank: int,
    blk: int = 512,
    tile_rows: int = 128,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    table=None,
    factor_rows=None,
) -> str:
    """Resolve ``auto`` to a concrete backend; pass others through.

    ``factor_rows`` describes the N−1 replicated input-factor matrices
    (rows over non-output modes) — the information the gather family's
    residency planning needs: an int total (Σ I_pad, the historical
    form), or a per-input-mode sequence (exact stream-window planning).
    ``None`` means the caller doesn't know the factor sizes (a purely
    shape-keyed dispatch query), and the gather family — the out-of-core
    streamed member included — is then never chosen: its feasibility
    cannot be certified. ``mttkrp_device_step`` always passes the
    per-mode sequence, so end-to-end ``auto`` prefers the gather family
    whenever it fits.

    When a calibration ``table`` (a ``repro.tune`` ``CalibrationTable``
    or ``CostModel`` — anything with a ``best_backend`` method) is
    given, ``auto`` follows the *measured* argmin interpolated to this
    configuration instead of the static model below. The table is
    consulted duck-typed so this module never imports ``repro.tune``;
    if it cannot answer (no entries near this configuration) the static
    decision applies, bit-identical to the no-table path. Two hard
    constraints bound the table, preference never overrides them:

      * VMEM feasibility — every table answer is re-certified by the
        ``repro.oocore`` residency planner
        (:func:`repro.oocore.planner.backend_fits`): a fused/tiled
        choice whose working set exceeds ``vmem_budget``, or a gather
        choice (resident, slab-streamed or out-of-core row-streamed)
        whose residency cannot be certified (``factor_rows`` unknown, or
        over budget), is an extrapolation beyond the measured grid — it
        is discarded and the static decision applies;
      * numerics — the table is only consulted over :data:`AUTO_BACKENDS`,
        so a measured-fast bf16-gather variant never changes results
        behind ``auto``'s back.

    Static decision: the :func:`repro.oocore.planner.plan_residency`
    ladder (all static — safe to call under jit tracing; worked
    examples in ``docs/kernels.md``): ``ref`` below the MXU-padding rank
    threshold, else the first residency rung whose working set fits the
    budget — factors whole-VMEM (``pallas_fused_gather``) → one rank
    slab resident (``pallas_fused_gather_tiled``) → out-of-core tile
    window (``pallas_fused_gather_stream``; factors stay HBM-resident) →
    fused (``pallas_fused``) → rank-tiled fused (``pallas_fused_tiled``)
    → materialized ``pallas``. Rungs that need the factor sizes are
    skipped when ``factor_rows`` is ``None``.
    """
    # Every resolution emits a ``dispatch.backend`` counter with the
    # decision *and* why (explicit | table | static). select_backend runs
    # at jit-trace time, so the count is once per unique static signature
    # per process — host-independent, which is what lets the obs baseline
    # gate pin dispatch decisions in CI.
    if backend != "auto":
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown MTTKRP backend {backend!r}: expected 'auto' or "
                f"one of {BACKENDS} (the plain-XLA 'segsum' path is "
                "handled by core.distributed.device_mttkrp)")
        _obs.add("dispatch.backend", backend=backend, source="explicit")
        return backend
    if table is not None:
        # Below the MXU-padding threshold the table may only answer from
        # ranks it actually measured (a `covers` check, duck-typed like
        # best_backend) — clamped below-grid extrapolation must not
        # override the static rank<MIN_MXU_RANK -> ref rule.
        covers = getattr(table, "covers", None)
        rank_ok = rank >= MIN_MXU_RANK or (
            covers is not None and covers(nmodes=nmodes, rank=rank,
                                          blk=blk, tile_rows=tile_rows))
        choice = table.best_backend(
            nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
            allowed=AUTO_BACKENDS,
        ) if rank_ok else None
        if choice is not None and not _planner.backend_fits(
                choice, nmodes=nmodes, rank=rank, blk=blk,
                tile_rows=tile_rows, factor_rows=factor_rows,
                vmem_budget=vmem_budget):
            choice = None               # infeasible extrapolation
        if choice is not None:
            _obs.add("dispatch.backend", backend=choice, source="table")
            return choice
    chosen = _planner.plan_residency(
        nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
        factor_rows=factor_rows, vmem_budget=vmem_budget).backend
    _obs.add("dispatch.backend", backend=chosen, source="static")
    return chosen


def n_pad_for(cap: int, rows_cap: int, blk: int, tile_rows: int) -> int:
    """Static aligned-stream length: every tile wastes < blk slots."""
    num_tiles = rows_cap // tile_rows
    return ((cap + blk - 1) // blk) * blk + num_tiles * blk


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows")
)
def build_block_layout(local_row, valid, *, rows_cap: int, blk: int,
                       tile_rows: int, order_keys=None):
    """Compute block-aligned slots for a sorted nonzero stream.

    Args:
      local_row: ``(cap,)`` int32 output row per element, ascending among
        valid elements; invalid elements trail. (Strictly: only the
        output-**tile** runs must be contiguous ascending — the order of
        elements within a tile run is free, which is the freedom the
        ``order_keys`` path spends.)
      valid: ``(cap,)`` bool.
      rows_cap: output rows (multiple of ``tile_rows``).
      order_keys: optional tuple of ``(cap,)`` int arrays (most
        significant first — ``repro.reorder.locality_keys``). When
        given, elements are ranked within their output-tile run by
        these keys instead of by stream position, so the aligned stream
        comes out locality-ordered *in-jit* — no host-side permutation,
        and the ordering survives the dynamic remapping between modes
        (which re-sorts by row every transition). With keys the input
        need not be sorted at all beyond valid-first: the lexsort
        groups the tile runs itself.

    Returns:
      ``(slot, tile_of_block)`` — ``slot[(cap,)]`` destination of each
      element in the aligned stream (``n_pad_for(...)`` = dump slot for
      invalid), ``tile_of_block[(n_pad//blk,)]`` non-decreasing output tile
      per block.
    """
    cap = local_row.shape[0]
    num_tiles = rows_cap // tile_rows
    n_pad = n_pad_for(cap, rows_cap, blk, tile_rows)

    tile_of_elem = jnp.where(valid, local_row // tile_rows, num_tiles)
    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), tile_of_elem, num_segments=num_tiles + 1
    )[:num_tiles]
    padded = ((counts + blk - 1) // blk) * blk
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(padded).astype(jnp.int32)])
    if order_keys:
        # Rank within the tile run = position under the (tile, keys,
        # position) lexsort — the jit twin of the host-side
        # repro.reorder.locality_lexsort (same keys, same tiebreak, so
        # the two produce bit-identical aligned streams).
        pos = jnp.arange(cap, dtype=jnp.int32)
        keys = tuple(jnp.asarray(kk).astype(jnp.int32) for kk in order_keys)
        order = jnp.lexsort((pos,) + keys[::-1] + (tile_of_elem,))
        inv = jnp.zeros(cap, jnp.int32).at[order].set(pos)
        sorted_tiles = jnp.take(tile_of_elem, order)
        first_of_tile = jnp.searchsorted(sorted_tiles, tile_of_elem,
                                         side="left")
        rank_in_tile = inv - first_of_tile.astype(jnp.int32)
    else:
        # Elements sorted by (valid desc, row asc) => per-tile runs
        # contiguous; rank = distance from the run's first position.
        first_of_tile = jnp.searchsorted(tile_of_elem, tile_of_elem,
                                         side="left")
        rank_in_tile = (jnp.arange(cap, dtype=jnp.int32)
                        - first_of_tile.astype(jnp.int32))
    slot = jnp.where(
        valid,
        jnp.take(offsets, tile_of_elem, fill_value=0) + rank_in_tile,
        n_pad,
    )
    block_start = jnp.arange(n_pad // blk, dtype=jnp.int32) * blk
    tile_of_block = jnp.clip(
        jnp.searchsorted(offsets, block_start, side="right") - 1,
        0, num_tiles - 1,
    ).astype(jnp.int32)
    return slot, tile_of_block


def _align_to_blocks(x, slot, n_pad: int):
    """Scatter ``(cap, ...)`` stream rows into their block-aligned slots.

    Slot ``n_pad`` is the dump row for invalid elements; it is allocated and
    then sliced off, so invalid entries vanish regardless of their payload.
    """
    out_shape = (n_pad + 1,) + x.shape[1:]
    return jnp.zeros(out_shape, x.dtype).at[slot].set(x)[:-1]


def tile_schedule(indices_aligned, blk: int, window: int,
                  frow_tile: int = _kernel.FACTOR_ROW_TILE):
    """Per-block factor-tile schedule for the out-of-core stream kernel.

    ``indices_aligned`` is one mode's block-aligned ``(n_pad,)`` int32
    factor-row stream. Returns a ``(n_pad // blk, window)`` int32 array:
    row ``b`` holds the sorted distinct ``frow_tile``-row factor tiles
    block ``b``'s nonzeros touch, padded (by repeating the first tile)
    up to ``window`` slots. Correct whenever ``window >=`` the block's
    distinct-tile count — guaranteed for ``window = min(blk,
    ceil(rows / frow_tile))`` (``planner.stream_window_tiles``), since a
    block holds ``blk`` nonzeros and a factor only has that many tiles.
    jit-safe (static shapes throughout); this is the schedule the
    kernel's BlockSpec index maps consume via scalar prefetch.
    """
    tiles = (indices_aligned // frow_tile).astype(jnp.int32)
    per_block = tiles.reshape(-1, blk)
    num_blocks = per_block.shape[0]
    st = jnp.sort(per_block, axis=1)
    first = jnp.concatenate(
        [jnp.ones((num_blocks, 1), bool), st[:, 1:] != st[:, :-1]], axis=1)
    rank_of = jnp.cumsum(first, axis=1) - 1            # distinct rank
    # Scatter each first occurrence to its rank; duplicates go to a dump
    # column that is sliced off. Unfilled slots keep the block's first
    # (smallest) tile so padding never schedules a tile the window
    # wouldn't otherwise hold.
    dest = jnp.where(first, rank_of, window)
    sched = jnp.broadcast_to(st[:, :1], (num_blocks, window + 1))
    sched = sched.at[jnp.arange(num_blocks)[:, None], dest].set(st)
    return sched[:, :window].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("rows_cap", "blk", "tile_rows", "interpret", "use_ref"),
)
def mttkrp_blocked(contrib, local_row, valid, *, rows_cap: int,
                   blk: int = 512, tile_rows: int = 128,
                   interpret: bool | None = None, use_ref: bool = False):
    """Scatter stage on a sorted stream via the Pallas kernel.

    ``use_ref=True`` routes to the pure-jnp oracle (A/B testing and the
    CPU-bench path).
    """
    if use_ref:
        masked = jnp.where(valid[:, None], contrib, 0.0)
        row = jnp.where(valid, local_row, 0)
        return _ref.segment_accumulate_ref(masked, row, rows_cap)

    n_pad = n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
    slot, tile_of_block = build_block_layout(
        local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows
    )
    rank = contrib.shape[-1]
    contrib_pad = pad_rank(contrib)
    aligned = _align_to_blocks(
        jnp.where(valid[:, None], contrib_pad, 0.0), slot, n_pad
    )
    row_aligned = _align_to_blocks(
        (local_row % tile_rows).astype(jnp.int32), slot, n_pad
    )
    out = _kernel.segment_accumulate(
        aligned, row_aligned, tile_of_block,
        rows_cap=rows_cap, blk=blk, tile_rows=tile_rows, interpret=interpret,
    )
    return out[:, :rank]


@functools.partial(
    jax.jit,
    static_argnames=("mode", "rows_cap", "blk", "tile_rows", "interpret",
                     "backend", "gather_dtype", "ordering"),
)
def mttkrp_device_step(idx, val, valid, factors, *, mode: int, rows_cap: int,
                       row_offset, blk: int = 512, tile_rows: int = 128,
                       interpret: bool | None = None,
                       backend: str = "pallas",
                       gather_dtype: str = "float32",
                       ordering: str = "none"):
    """Full per-device mode step: gather → Hadamard → blocked scatter.

    Args:
      idx: ``(cap, N)`` permuted coordinates of owned nonzeros, sorted by
        output row (valid first).
      val: ``(cap,)`` values (0 on padding).
      valid: ``(cap,)`` bool.
      factors: list of ``(I_pad_w, R)`` replicated factor matrices (permuted
        row space).
      mode: output mode.
      rows_cap: owned output rows.
      row_offset: scalar — first owned permuted row (``device_id*rows_cap``).
      interpret: ``None`` (default) defers to the
        :mod:`repro.runtime.execution` policy (interpret / compiled /
        auto); a bool forces the Pallas interpreter (True) or Mosaic
        compilation (False) for this call.
      backend: one of :data:`BACKENDS` or ``auto`` (decision matrix in
        ``docs/kernels.md``).
      gather_dtype: ``"float32"`` | ``"bfloat16"`` — dtype the fused
        family gathers factor rows in (the accumulate is always fp32).
        ``"bfloat16"`` composes with any fused backend (in-kernel gather
        included: the resident factor matrices — or the streamed tile
        windows of ``pallas_fused_gather_stream`` — are held in bf16);
        the ``pallas_fused_bf16`` / ``pallas_fused_gather_bf16`` backend
        names are the untiled kernels with this forced on (so a plain
        backend-string API can reach them). The materialized/``ref``
        paths ignore it.
      ordering: :data:`repro.reorder.ORDERINGS` policy. Anything but
        ``"none"`` re-ranks nonzeros *within* each output-row-tile run
        by the gathered modes' factor-tile locality keys (in-jit, via
        ``build_block_layout``'s ``order_keys`` path) before block
        alignment, shrinking the stream backend's per-block tile
        schedules. Applied to the whole fused/gather family (same
        aligned stream everywhere ⇒ A/B bit-exactness across backends
        is preserved per ordering); the materialized/``ref`` paths
        don't block-align gathered indices, so they ignore it.

    Returns ``(rows_cap, R)`` float32 local output factor rows.
    """
    # Validate before dispatch: non-fused resolutions never read
    # gather_dtype, and a typo must not pass silently on those paths.
    if gather_dtype not in ("float32", "bfloat16"):
        raise ValueError(
            f"unknown gather_dtype {gather_dtype!r}: expected "
            "'float32' or 'bfloat16'")
    _reorder.validate_ordering(ordering)
    nmodes = idx.shape[1]
    rank = factors[mode].shape[-1]
    in_modes = [w for w in range(nmodes) if w != mode]
    backend = select_backend(
        backend, nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
        factor_rows=tuple(factors[w].shape[0] for w in in_modes),
    )

    def _dispatch(backend: str, interpret, gather_dtype=gather_dtype):
        if backend == "pallas_fused_bf16":
            backend, gather_dtype = "pallas_fused", "bfloat16"
        if backend == "pallas_fused_gather_bf16":
            backend, gather_dtype = "pallas_fused_gather", "bfloat16"
        local_row = (idx[:, mode] - row_offset).astype(jnp.int32)
        local_row = jnp.where(valid, local_row, 0)

        if backend in GATHER_BACKENDS + (STREAM_BACKEND, "pallas_fused",
                                         "pallas_fused_tiled"):
            gdt = jnp.bfloat16 if gather_dtype == "bfloat16" else jnp.float32
            vals = jnp.where(valid, val, 0.0)
            n_pad = n_pad_for(local_row.shape[0], rows_cap, blk, tile_rows)
            idx_in = jnp.stack([idx[:, w] for w in in_modes], axis=1)
            idx_in = jnp.where(valid[:, None], idx_in, 0).astype(jnp.int32)
            # max_rows is static (factor shapes), so host-side sorts
            # derive the identical Morton bit budget — and huge modes
            # widen the key words instead of clamping tile ids.
            order_keys = _reorder.locality_keys(
                idx_in, ordering,
                max_rows=max(factors[w].shape[0] for w in in_modes))
            slot, tile_of_block = build_block_layout(
                local_row, valid, rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
                order_keys=order_keys,
            )
            v_al = _align_to_blocks(vals, slot, n_pad)
            r_al = _align_to_blocks(
                (local_row % tile_rows).astype(jnp.int32), slot, n_pad
            )
            if backend in GATHER_BACKENDS + (STREAM_BACKEND,):
                # In-kernel gather: no per-factor take, no _align_to_blocks
                # of R-wide rows — only the int32 index stream is
                # block-aligned, and the replicated factor matrices go to
                # the kernel whole. Padding/invalid slots point at factor
                # row 0 (in-bounds gather; their value is 0 so the
                # contribution vanishes). Casting the resident matrices to
                # the gather dtype is what halves both the VMEM residency
                # and the factor-load traffic for bf16 (same values as the
                # materialized path's cast-then-take).
                idx_al = _align_to_blocks(idx_in, slot, n_pad)
                fmats = tuple(pad_rank(factors[w].astype(gdt))
                              for w in in_modes)
                if backend == STREAM_BACKEND:
                    # Out-of-core: factors stay HBM-resident; the kernel
                    # streams FACTOR_ROW_TILE-row tiles through a bounded
                    # VMEM window, driven by the per-block tile schedule.
                    # Window widths are the planner's static correctness
                    # bound, so this path is jit-safe for any index data.
                    frow = _kernel.FACTOR_ROW_TILE
                    fmats = tuple(_pad_factor_rows(f, frow) for f in fmats)
                    scheds = tuple(
                        tile_schedule(
                            idx_al[:, i], blk,
                            _planner.stream_window_tiles(blk, f.shape[0]))
                        for i, f in enumerate(fmats))
                    out = _kernel.fused_mttkrp_nmode_gather_stream(
                        v_al, idx_al, fmats, r_al, tile_of_block, scheds,
                        rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
                        interpret=interpret,
                    )
                    return out[:, :rank]
                kern = (_kernel.fused_mttkrp_nmode_gather_tiled
                        if backend == "pallas_fused_gather_tiled"
                        else _kernel.fused_mttkrp_nmode_gather)
                out = kern(
                    v_al, idx_al, fmats, r_al, tile_of_block,
                    rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
                    interpret=interpret,
                )
                return out[:, :rank]
            # Cast the factor *matrix* before the take so the gather itself
            # moves gather_dtype-sized rows (the traffic the bf16 variant
            # halves), not fp32 rows cast afterwards.
            rows_al = tuple(
                _align_to_blocks(
                    pad_rank(jnp.take(factors[w].astype(gdt), idx[:, w], axis=0)),
                    slot, n_pad
                )
                for w in in_modes
            )
            kern = (_kernel.fused_mttkrp_nmode_tiled
                    if backend == "pallas_fused_tiled"
                    else _kernel.fused_mttkrp_nmode)
            out = kern(
                v_al, rows_al, r_al, tile_of_block,
                rows_cap=rows_cap, blk=blk, tile_rows=tile_rows,
                interpret=interpret,
            )
            return out[:, :rank]

        # Materialized path: contrib built in HBM, then blocked scatter.
        ell = jnp.where(valid, val, 0.0)[:, None].astype(factors[0].dtype)
        for w in in_modes:
            ell = ell * jnp.take(factors[w], idx[:, w], axis=0)
        use_ref = backend == "ref"
        return mttkrp_blocked(
            ell.astype(jnp.float32), local_row, valid, rows_cap=rows_cap,
            blk=blk, tile_rows=tile_rows, interpret=interpret, use_ref=use_ref,
        )

    def _attempt(backend: str, interpret):
        # Registered failure boundary (repro.resilience): this is
        # where lowering failures and VMEM OOM surface (at trace
        # time under jit — a fault here aborts the trace, leaving
        # no cache entry, so a retry re-dispatches for real).
        _faults.fault_site("ops.kernel")
        return _dispatch(backend, interpret)

    pol = _resilience.get_policy()
    if pol is None:
        # No active policy: fail fast — exactly the pre-resilience
        # dispatch, one attempt at the selected backend.
        return _attempt(backend, interpret)
    return pol.dispatch(_attempt, backend, interpret)


def step_traffic_bytes(*, cap: int, nmodes: int, rank: int, rows_cap: int,
                       gather_dtype: str = "float32") -> int:
    """First-order counted traffic model of one device mode step.

    What the step minimally moves, independent of backend: the nonzero
    stream (values + local rows + K gathered-mode indices, 4 B each),
    one gathered factor row per nonzero per input mode (``rpad``
    gather-dtype elements), and the output factor write. Deliberately a
    *model*, not a measurement — it is the denominator-side constant the
    roofline divides a measured step time by (``ops.step.model_bytes``),
    playing the role the oocore path's exact schedule-counted bytes play
    for the stream backend.
    """
    k = nmodes - 1
    gi = 2 if gather_dtype == "bfloat16" else 4
    rpad = padded_rank(rank)
    stream_b = cap * (4 + 4 + 4 * k)
    gather_b = cap * k * rpad * gi
    out_b = rows_cap * rpad * 4
    return stream_b + gather_b + out_b


def timed_device_step(idx, val, valid, factors, *, mode: int, rows_cap: int,
                      row_offset, blk: int = 512, tile_rows: int = 128,
                      interpret: bool | None = None,
                      backend: str = "pallas",
                      gather_dtype: str = "float32",
                      ordering: str = "none"):
    """:func:`mttkrp_device_step`, fenced and timed from the host.

    The device step itself is jitted — no host clock can live inside
    it — so wall-clock observability needs this one-call-out wrapper:
    an ``ops.device_step`` span around the call plus
    ``block_until_ready``, with the step's modeled traffic
    (:func:`step_traffic_bytes`) emitted *inside* the span so the
    roofline can join measured seconds with counted bytes. Emits
    ``ops.step_s`` (wall seconds, labeled by backend). The backend label
    is the *requested* backend (``auto`` stays ``auto``): resolving it
    here would re-emit the dispatch counters the jitted step already
    emits at trace time.
    """
    tracer = _tracer_mod.get_tracer()
    cap = int(idx.shape[0])
    nmodes = int(idx.shape[1])
    rank = int(factors[mode].shape[-1])
    model_b = step_traffic_bytes(cap=cap, nmodes=nmodes, rank=rank,
                                 rows_cap=rows_cap,
                                 gather_dtype=gather_dtype)
    t0 = time.perf_counter()
    with tracer.span("ops.device_step", backend=backend, mode=mode,
                     ordering=ordering):
        _obs.add("ops.step.model_bytes", model_b, backend=backend)
        out = mttkrp_device_step(
            idx, val, valid, factors, mode=mode, rows_cap=rows_cap,
            row_offset=row_offset, blk=blk, tile_rows=tile_rows,
            interpret=interpret, backend=backend,
            gather_dtype=gather_dtype, ordering=ordering)
        out = jax.block_until_ready(out)
    _obs.add("ops.step_s", time.perf_counter() - t0, backend=backend)
    return out
