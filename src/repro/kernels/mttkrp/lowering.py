"""Mosaic lowering validation: compile every backend on a CPU-only host.

The compiled-path honesty harness. A Pallas kernel that only ever runs
in the interpreter can silently accumulate Mosaic
incompatibilities — ops without a TPU lowering rule (``gather``),
BlockSpec shapes that violate sublane/lane tiling, dtype/layout
mistakes — and nothing notices until a real TPU job dies. This module
catches that class of rot with **no TPU attached**: jax's AOT path

    jax.jit(f).trace(*abstract_args).lower(lowering_platforms=("tpu",))

runs the full StableHLO + Mosaic kernel compilation pipeline on any
host (only *execution* needs the device — see
:mod:`repro.runtime.execution` for that half of the story), so CI can
assert that every backend in :data:`repro.kernels.mttkrp.ops.BACKENDS`
compiles, per representative geometry, on every commit.

What is validated per (backend, geometry): ``ops.mttkrp_device_step`` —
the real dispatch entry (layout build + kernel), not a test double —
lowered whole with ``interpret=False``; for Pallas backends the result
must contain a ``tpu_custom_call`` (the serialized Mosaic module), for
``ref`` it must simply lower (plain XLA).

Compiled-geometry constraint (Mosaic, not this harness): the kernels'
rank-1 ``(blk,)`` scalar-stream blocks require ``blk % 128 == 0``
(:data:`MOSAIC_BLK_MULTIPLE`); the interpreter accepts any ``blk``.
Geometries here respect it — see :func:`compiled_geometry_ok`.

Entry points: :func:`lower_backend` (one check), :func:`run` (a grid →
``LoweringResult`` rows, the payload of ``BENCH_lowering.json``), and
``python -m repro.kernels.mttkrp.lowering`` (the CI ``lowering-smoke``
step; ``--full`` for the slow grid).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ops as _ops

__all__ = [
    "MOSAIC_BLK_MULTIPLE",
    "Geometry",
    "LoweringResult",
    "SMOKE_GEOMETRIES",
    "FULL_GEOMETRIES",
    "compiled_geometry_ok",
    "device_step_args",
    "lower_backend",
    "run",
    "main",
]

# Mosaic requires rank-1 block shapes — the kernels' (blk,) value/row
# streams — to be a multiple of the 128-lane tiling (or the whole array
# dimension, which the blocked layout never is). Execution-mode
# geometry constraint only: the interpreter accepts any blk.
MOSAIC_BLK_MULTIPLE = 128


@dataclasses.dataclass(frozen=True)
class Geometry:
    """One lowering-validation configuration.

    ``factor_rows`` is the row count of every non-output-mode factor —
    it sizes the resident gather operands and (after padding to
    ``FACTOR_ROW_TILE``) the stream backend's per-mode window,
    ``min(blk, ceil(rows/128))`` tiles. ``num_tiles`` output row tiles
    give ``rows_cap = num_tiles * tile_rows``; ``nnz_cap`` is the
    unaligned stream length (the layout build pads it).
    """

    nmodes: int
    rank: int
    blk: int
    tile_rows: int
    factor_rows: int = 64
    num_tiles: int = 4
    nnz_cap: int = 256

    @property
    def rows_cap(self) -> int:
        return self.num_tiles * self.tile_rows

    @property
    def window_tiles(self) -> int:
        """The stream backend's per-mode window at this geometry."""
        from ...oocore import planner as _planner

        frow = _kernel.FACTOR_ROW_TILE
        padded = self.factor_rows + (-self.factor_rows) % frow
        return _planner.stream_window_tiles(self.blk, padded)

    def label(self) -> str:
        return (f"N{self.nmodes}_R{self.rank}_blk{self.blk}"
                f"_t{self.tile_rows}_rows{self.factor_rows}")


# The CI smoke grid: one small-everything point, a higher-order point,
# and a multi-slab + multi-tile-window point — ≥ 3 geometries per
# backend, each exercising a distinct BlockSpec regime (whole-rank vs
# rank-slab factors, window width 1 vs >1), all < a second to lower.
SMOKE_GEOMETRIES = (
    Geometry(nmodes=3, rank=128, blk=128, tile_rows=8, factor_rows=64),
    Geometry(nmodes=4, rank=128, blk=128, tile_rows=128, factor_rows=96),
    Geometry(nmodes=3, rank=256, blk=256, tile_rows=8, factor_rows=300),
)

# The slow full grid: adds a 5-mode point, a non-128-multiple rank (the
# pad_rank path), wide blocks, and a many-tile stream window.
FULL_GEOMETRIES = SMOKE_GEOMETRIES + (
    Geometry(nmodes=5, rank=128, blk=128, tile_rows=16, factor_rows=64),
    Geometry(nmodes=3, rank=200, blk=128, tile_rows=8, factor_rows=64),
    Geometry(nmodes=3, rank=512, blk=384, tile_rows=128, factor_rows=700),
    Geometry(nmodes=4, rank=256, blk=256, tile_rows=32, factor_rows=1000,
             num_tiles=8, nnz_cap=1024),
)


@dataclasses.dataclass(frozen=True)
class LoweringResult:
    """Outcome of one (backend, geometry) lowering attempt."""

    backend: str
    geometry: Geometry
    ok: bool
    mosaic: bool            # StableHLO contains a tpu_custom_call
    seconds: float
    error: str = ""

    def row(self) -> dict:
        """Flat dict for ``BENCH_lowering.json`` / the CLI report."""
        g = self.geometry
        return dict(
            backend=self.backend, nmodes=g.nmodes, rank=g.rank, blk=g.blk,
            tile_rows=g.tile_rows, factor_rows=g.factor_rows,
            window_tiles=g.window_tiles, lowered_ok=self.ok,
            mosaic=self.mosaic, seconds=round(self.seconds, 4),
            error=self.error,
        )


def compiled_geometry_ok(geom: Geometry) -> tuple[bool, str]:
    """Is this geometry expressible on the compiled path at all?

    Returns ``(ok, reason)``. The only compiled-vs-interpret geometry
    restriction the kernels carry is the rank-1 block-shape rule on
    ``blk``; everything else (rank, tile_rows, windows) is already
    padded/tiled into Mosaic-legal shapes by construction.
    """
    if geom.blk % MOSAIC_BLK_MULTIPLE != 0:
        return False, (f"blk={geom.blk} is not a multiple of "
                       f"{MOSAIC_BLK_MULTIPLE}: Mosaic rejects the rank-1 "
                       "(blk,) scalar-stream blocks")
    return True, ""


def device_step_args(geom: Geometry, *, mode: int = 0):
    """Abstract (ShapeDtypeStruct) operands for ``mttkrp_device_step``.

    No data is materialized — lowering is shape/dtype-driven, which is
    what lets the full grid stay cheap on CPU.
    """
    cap = geom.nnz_cap
    idx = jax.ShapeDtypeStruct((cap, geom.nmodes), jnp.int32)
    val = jax.ShapeDtypeStruct((cap,), jnp.float32)
    valid = jax.ShapeDtypeStruct((cap,), jnp.bool_)
    factors = [
        jax.ShapeDtypeStruct(
            (geom.rows_cap if w == mode else geom.factor_rows, geom.rank),
            jnp.float32)
        for w in range(geom.nmodes)
    ]
    row_offset = jax.ShapeDtypeStruct((), jnp.int32)
    return idx, val, valid, factors, row_offset


def lower_backend(backend: str, geom: Geometry, *,
                  platform: str = "tpu") -> LoweringResult:
    """Lower one backend at one geometry with ``interpret=False``.

    Uses the AOT trace-then-lower path so the Mosaic pipeline runs even
    when jax's default backend is CPU. Never raises: failures come back
    as ``ok=False`` with the exception message, so a grid sweep reports
    every broken backend instead of stopping at the first.
    """
    idx, val, valid, factors, row_offset = device_step_args(geom)
    t0 = time.perf_counter()
    try:
        lowered = _ops.mttkrp_device_step.trace(
            idx, val, valid, factors, mode=0, rows_cap=geom.rows_cap,
            row_offset=row_offset, blk=geom.blk, tile_rows=geom.tile_rows,
            interpret=False, backend=backend,
        ).lower(lowering_platforms=(platform,))
        text = lowered.as_text()
    except Exception as e:  # noqa: BLE001 — every failure is a result row
        return LoweringResult(
            backend=backend, geometry=geom, ok=False, mosaic=False,
            seconds=time.perf_counter() - t0,
            error=f"{type(e).__name__}: {e}")
    seconds = time.perf_counter() - t0
    mosaic = "tpu_custom_call" in text
    # ref is plain XLA — no Mosaic module expected. Every Pallas backend
    # must actually have produced one, or the "lowering" proved nothing.
    ok = bool(text) and (mosaic or backend == "ref")
    err = "" if ok else "lowered without a tpu_custom_call (Mosaic module)"
    return LoweringResult(backend=backend, geometry=geom, ok=ok,
                          mosaic=mosaic, seconds=seconds, error=err)


def run(geometries=SMOKE_GEOMETRIES, backends=_ops.BACKENDS,
        *, platform: str = "tpu") -> list[LoweringResult]:
    """Lower every backend at every geometry; returns all results."""
    return [lower_backend(b, g, platform=platform)
            for b in backends for g in geometries]


def main(argv=None) -> int:
    """CLI for the CI ``lowering-smoke`` step: 0 iff everything lowers."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.kernels.mttkrp.lowering", description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="the full geometry grid (slow) instead of smoke")
    args = ap.parse_args(argv)
    geometries = FULL_GEOMETRIES if args.full else SMOKE_GEOMETRIES
    results = run(geometries)
    failures = [r for r in results if not r.ok]
    for r in results:
        status = "ok  " if r.ok else "FAIL"
        print(f"{status} {r.backend:28s} {r.geometry.label():32s} "
              f"{r.seconds:6.2f}s"
              + (f"  {r.error}" if r.error else ""))
    n = len(results)
    print(f"lowering {'smoke' if not args.full else 'full'}: "
          f"{n - len(failures)}/{n} (backend, geometry) points lower to "
          f"Mosaic with interpret=False")
    return 1 if failures else 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
