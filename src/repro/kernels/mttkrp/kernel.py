"""Pallas TPU kernels: blocked segmented MTTKRP accumulation.

TPU-native adaptation of the paper's elementwise gather–Hadamard–scatter
(Alg. 2 lines 13-25). The FLYCOO *shard* (``g`` nonzeros, cache-sized)
becomes the VMEM nonzero block; the *super-shard* row interval becomes the
output row tile; and — the key rethinking for the MXU — the random scatter
into output rows becomes a **one-hot matmul**:

    out_tile (T×R)  +=  onehot(local_row, T)ᵀ (T×B)  @  contrib (B×R)

which is dense, layout-friendly and runs on the systolic array. Correctness
relies on the FLYCOO invariant that nonzeros are sorted by output row and
blocks are padded to never straddle a row tile (ops.py builds that layout),
so the sequential TPU grid revisits each output tile over a contiguous run
of blocks and accumulates in VMEM.

Kernel matrix (see ops.py for the dispatch layer that picks between them):

  ==========================  =============================================
  kernel                      contract
  ==========================  =============================================
  ``segment_accumulate``      scatter-only: takes an HBM-materialized
                              ``contrib (B×R)`` block per grid step. Pays
                              2·R·4 B/nonzero of HBM traffic (write + read
                              of ``contrib``) that the fused kernels avoid.
  ``fused_mttkrp_nmode``      gather-Hadamard-scatter for **any** tensor
                              order: takes N−1 gathered factor-row blocks
                              and forms ``contrib = val ⊙ ⊙_w rows_w``
                              entirely in VMEM (loop over input modes inside
                              the kernel body). ``contrib`` never exists in
                              HBM.
  ``fused_mttkrp_nmode_tiled``  the same gather-Hadamard-scatter with a
                              second grid axis over ``RANK_SLAB``-wide rank
                              slabs: each grid step holds only one slab of
                              the N−1 factor blocks / contrib / out tile,
                              so the VMEM working set is independent of R
                              and the fused traffic win survives arbitrary
                              rank (the scalar streams are re-read once per
                              slab — the only extra cost).
  ``fused_mttkrp_3mode``      **deprecated alias** (warns): the 3-mode
                              special case of the N-mode kernel.
  ``fused_mttkrp_nmode_gather``  gather **inside the kernel**: takes the
                              full replicated factor matrices (VMEM-resident
                              across grid steps) plus a block-aligned
                              ``(n_pad, N−1)`` int32 index stream, and forms
                              each nonzero's factor rows in the body
                              (one-hot MXU matmul when compiled — the
                              ``gather`` primitive has no Mosaic lowering —
                              ``jnp.take`` in the interpreter; bitwise
                              identical). The gathered operands never
                              exist in HBM at all — the per-nonzero stream
                              shrinks from ``(N−1)·R̂·4`` B of rows to
                              ``(N−1)·4`` B of indices.
  ``fused_mttkrp_nmode_gather_tiled``  the in-kernel gather composed with
                              the rank-slab grid axis: only one
                              ``RANK_SLAB``-wide column slab of each factor
                              is resident per slab pass, so the resident
                              set is ``ΣI_pad·RANK_SLAB·gi`` instead of
                              ``ΣI_pad·R̂·gi`` (the index/scalar streams are
                              re-read once per slab).
  ``fused_mttkrp_nmode_gather_stream``  **out-of-core** in-kernel gather:
                              the factor matrices stay HBM-resident and the
                              Pallas pipeline DMAs ``FACTOR_ROW_TILE``-row
                              factor tiles into a per-mode window of
                              ``window_tiles`` VMEM slots, double-buffered
                              across grid steps and driven by a
                              scalar-prefetched per-block *tile schedule*
                              derived from the nonzero index stream. VMEM
                              holds ``Σ W_w·128·slab·gi`` of factor data
                              instead of ``ΣI_pad·…`` — arbitrarily large
                              factor dimensions stream through a bounded
                              window (composes with the rank-slab axis).
  ==========================  =============================================

Both fused kernels accept bf16 factor-row operands (``ops.py``'s
``pallas_fused_bf16`` backend / ``gather_dtype="bfloat16"``): the Hadamard
product is accumulated in fp32 inside the kernel regardless, so bf16 only
halves the *gathered-operand* footprint and HBM gather traffic.

Grid: one step per nonzero block. ``tile_of_block`` is scalar-prefetched and
drives the output BlockSpec index_map. The output is zero-initialized via
``input_output_aliases`` (an aliased zeros operand), so empty tiles stay
zero without needing a first-visit flag.

Execution mode: every entry point takes ``interpret: bool | None``.
``None`` — the default everywhere — resolves through
:mod:`repro.runtime.execution`, the session-wide
interpret / compiled / auto policy with capability probing; a bool is an
explicit per-call override (the lowering harness passes ``False``).
Compiled (Mosaic) geometry constraint: the rank-1 ``(blk,)`` scalar-stream
blocks require ``blk`` to be a multiple of 128 (the interpreter accepts
any ``blk``); ``tests/test_lowering.py`` lowers every kernel wrapper with
``interpret=False`` to keep the compiled path honest on CPU-only hosts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "MXU_RANK_MULTIPLE",
    "RANK_SLAB",
    "FACTOR_ROW_TILE",
    "segment_accumulate",
    "fused_mttkrp_nmode",
    "fused_mttkrp_nmode_tiled",
    "fused_mttkrp_nmode_gather",
    "fused_mttkrp_nmode_gather_tiled",
    "fused_mttkrp_nmode_gather_stream",
    "fused_mttkrp_3mode",
    "fused_vmem_bytes",
    "fused_tiled_vmem_bytes",
    "gather_vmem_bytes",
    "gather_tiled_vmem_bytes",
    "gather_stream_vmem_bytes",
]

# MXU lane width: the rank dimension is padded to a multiple of this for the
# one-hot scatter matmul, and the rank-tiled kernel slabs the rank axis in
# exactly this width. The single source of truth — ops.py (``pad_rank`` /
# ``padded_rank`` / the rank<8 MXU-padding guard) and tune/model.py derive
# from it rather than re-hardcoding 128.
MXU_RANK_MULTIPLE = 128

# Width of one rank slab in ``fused_mttkrp_nmode_tiled`` — one MXU lane tile.
RANK_SLAB = MXU_RANK_MULTIPLE

# Row height of one streamed factor tile in the out-of-core gather kernel:
# the unit the Pallas pipeline DMAs from the HBM-resident factor into a
# VMEM window slot. 128 rows = 16 fp32 sublane tiles — big enough that a
# tile fetch is one long coalesced burst, small enough that a window of a
# few slots stays far under the VMEM budget. ``repro.oocore`` derives all
# its tile arithmetic from this constant.
FACTOR_ROW_TILE = 128

# Below this rank the one-hot MXU matmul pads R to MXU_RANK_MULTIPLE and
# wastes ≥ 16× of the array; the XLA segment-sum reference wins. Lives
# here (the only module with no intra-repo imports) so ops.py and
# repro.oocore.planner alias one definition instead of each other —
# either may be imported first.
MIN_MXU_RANK = MXU_RANK_MULTIPLE // 16

# Per-core VMEM working-set budget for residency planning (half of a
# v5e core's ~128 MiB VMEM — same θ=0.5 cache-fraction stance as the
# paper's Eq. 3). Same single-source rationale as MIN_MXU_RANK.
VMEM_BUDGET_BYTES = 64 * 1024 * 1024

# Dispatch-level name of the out-of-core streaming kernel
# (fused_mttkrp_nmode_gather_stream) in ops.BACKENDS.
STREAM_BACKEND_NAME = "pallas_fused_gather_stream"


def padded_rank(rank: int, multiple: int = MXU_RANK_MULTIPLE) -> int:
    """R rounded up to the MXU lane multiple — static dispatch arithmetic.

    The one definition (ops.py and repro.oocore.planner alias it, like
    the constants above) so feasibility math can never desynchronize
    between the dispatch and the residency planner.
    """
    return rank + (-rank) % multiple


def fused_vmem_bytes(num_in_modes: int, rank_padded: int, blk: int,
                     tile_rows: int, itemsize: int = 4,
                     gather_itemsize: int | None = None,
                     index_stream_modes: int = 0) -> int:
    """VMEM working set of one ``fused_mttkrp_nmode`` grid step.

    N−1 gathered factor-row blocks + the in-register ``contrib`` block +
    the one-hot scatter matrix + the resident output tile + the scalar
    streams. ops.py's ``auto`` dispatch compares this against the
    per-core VMEM budget.

    ``gather_itemsize`` sizes only the gathered factor-row blocks (2 for
    the bf16-gather variant); contrib / one-hot / out tile always
    accumulate at ``itemsize`` (fp32).

    The scalar-stream term is explicit about its members: the fp32
    values block and the int32 local-row block (both 4 B/element, hence
    ``2·blk·4``), plus — for the gather-in-kernel family, which streams
    its factor indices instead of pre-gathered rows —
    ``index_stream_modes`` int32 index blocks of ``blk`` elements each
    (``index_stream_modes = N−1``; 0 for the kernels whose operands are
    already gathered).
    """
    gi = itemsize if gather_itemsize is None else gather_itemsize
    factor_blocks = num_in_modes * blk * rank_padded * gi
    contrib_block = blk * rank_padded * itemsize
    onehot = blk * tile_rows * itemsize
    out_tile = tile_rows * rank_padded * itemsize
    scalars = (2 + index_stream_modes) * blk * itemsize
    return factor_blocks + contrib_block + onehot + out_tile + scalars


def fused_tiled_vmem_bytes(num_in_modes: int, rank_padded: int, blk: int,
                           tile_rows: int, rank_slab: int = RANK_SLAB,
                           itemsize: int = 4,
                           gather_itemsize: int | None = None) -> int:
    """VMEM working set of one ``fused_mttkrp_nmode_tiled`` grid step.

    Identical to :func:`fused_vmem_bytes` with the rank axis clamped to one
    slab — the whole point of the tiled kernel is that this is independent
    of R, so the fused path never has to fall back to the HBM-materialized
    kernel on rank growth.
    """
    return fused_vmem_bytes(
        num_in_modes, min(rank_padded, rank_slab), blk, tile_rows,
        itemsize=itemsize, gather_itemsize=gather_itemsize)


def gather_vmem_bytes(num_in_modes: int, rank_padded: int, blk: int,
                      tile_rows: int, factor_rows: int, itemsize: int = 4,
                      gather_itemsize: int | None = None) -> int:
    """VMEM working set of one ``fused_mttkrp_nmode_gather`` grid step.

    The replicated input-factor matrices themselves are the resident
    operands (``factor_rows`` = Σ I_pad over the N−1 input modes), and
    the per-nonzero streams are scalars only: values, local rows, and
    one int32 factor index per input mode. ``gather_itemsize`` sizes the
    resident matrices (2 for bf16 gathers); contrib / one-hot / out tile
    always accumulate at ``itemsize`` (fp32).
    """
    gi = itemsize if gather_itemsize is None else gather_itemsize
    resident_factors = factor_rows * rank_padded * gi
    return resident_factors + fused_vmem_bytes(
        0, rank_padded, blk, tile_rows, itemsize=itemsize,
        index_stream_modes=num_in_modes)


def gather_tiled_vmem_bytes(num_in_modes: int, rank_padded: int, blk: int,
                            tile_rows: int, factor_rows: int,
                            rank_slab: int = RANK_SLAB, itemsize: int = 4,
                            gather_itemsize: int | None = None) -> int:
    """VMEM working set of one ``fused_mttkrp_nmode_gather_tiled`` step.

    :func:`gather_vmem_bytes` with the rank axis clamped to one slab:
    only a ``rank_slab``-wide column slab of each factor matrix is
    resident per slab pass, so very large R cannot push the resident
    factors past the budget — only very large factor dimensions can.
    """
    return gather_vmem_bytes(
        num_in_modes, min(rank_padded, rank_slab), blk, tile_rows,
        factor_rows, itemsize=itemsize, gather_itemsize=gather_itemsize)


def gather_stream_vmem_bytes(num_in_modes: int, rank_padded: int, blk: int,
                             tile_rows: int, window_tiles,
                             frow_tile: int = FACTOR_ROW_TILE,
                             rank_slab: int = RANK_SLAB, itemsize: int = 4,
                             gather_itemsize: int | None = None) -> int:
    """VMEM working set of one ``fused_mttkrp_nmode_gather_stream`` step.

    The factors are *not* resident: per input mode only ``window_tiles``
    slots of ``frow_tile`` factor rows are held in VMEM (one rank slab
    wide — the stream kernel always composes with the rank-slab axis).
    ``window_tiles`` may be a single int applied to every input mode or
    a per-mode sequence. The scalar-prefetched schedules live in SMEM
    (the body reads them scalar-by-scalar) and — like ``tile_of_block``
    in every other kernel's accounting — are not counted here.
    """
    gi = itemsize if gather_itemsize is None else gather_itemsize
    if isinstance(window_tiles, int):
        window_tiles = (window_tiles,) * num_in_modes
    assert len(window_tiles) == num_in_modes, (window_tiles, num_in_modes)
    slab = min(rank_padded, rank_slab)
    windows = sum(w * frow_tile * slab * gi for w in window_tiles)
    return windows + fused_vmem_bytes(
        0, slab, blk, tile_rows, itemsize=itemsize,
        index_stream_modes=num_in_modes)


def _scatter_update(rows, contrib, tile_rows: int):
    """One-hot MXU scatter: ``(T×B) @ (B×R)`` update for the output tile."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows.shape[0], tile_rows), 1)
    onehot = (rows[:, None] == iota).astype(contrib.dtype)
    return jax.lax.dot_general(
        onehot, contrib,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _gather_rows(matrix, idx, *, onehot: bool):
    """In-kernel row gather: ``out[i] = matrix[idx[i]]`` → ``(B, R)`` fp32.

    Two bitwise-identical implementations behind one switch:

      * ``onehot=False`` — ``jnp.take``. The cheap form (O(B) work) the
        interpreter runs, but the ``gather`` primitive it lowers to has
        no Pallas TPU (Mosaic) lowering rule.
      * ``onehot=True`` — the MXU form, the gather mirror of
        :func:`_scatter_update`: ``onehot(idx, I) (B×I) @ matrix (I×R)``
        on the systolic array. This is what the compiled path uses.

    Equivalence is exact for in-range indices and any finite data: each
    output row is ``1.0·matrix[idx[i]]`` plus exact ``+0.0`` terms, and a
    bf16 ``matrix`` promotes to fp32 losslessly (the ``take`` form's
    bf16 rows promote identically at the Hadamard multiply) — so
    interpret and compiled execution stay bit-exact against each other.
    tests/test_lowering.py locks the equivalence down.
    """
    if not onehot:
        return jnp.take(matrix, idx, axis=0)
    iota = jax.lax.broadcasted_iota(
        jnp.int32, (idx.shape[0], matrix.shape[0]), 1)
    sel = (idx[:, None] == iota).astype(matrix.dtype)
    return jax.lax.dot_general(
        sel, matrix,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _resolve_interpret(interpret):
    """Resolve a per-call ``interpret`` override against the session policy.

    ``None`` — the default on every kernel entry point — defers to
    :mod:`repro.runtime.execution` (the one ``execution_mode`` switch:
    interpret / compiled / auto with capability probing). A bool is an
    explicit per-call override and wins. The policy module is imported
    lazily so this module keeps its no-import-time-intra-repo-deps
    property (ops.py and the oocore planner both alias its constants and
    may be imported in either order).
    """
    if interpret is not None:
        return bool(interpret)
    from ...runtime import execution as _execution
    return _execution.default_interpret()


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows", "interpret")
)
def segment_accumulate(
    contrib,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    interpret: bool | None = None,
):
    """Blocked segmented accumulation (scatter stage of spMTTKRP).

    Args:
      contrib: ``(num_blocks*blk, R)`` block-aligned contributions; padding
        rows are zero. R should be a multiple of 128 for MXU alignment
        (ops.py pads).
      local_row_in_tile: ``(num_blocks*blk,)`` int32 row *within its tile*
        (``0 <= r < tile_rows``); padding points at row 0 with zero contrib.
      tile_of_block: ``(num_blocks,)`` int32 output tile per block,
        non-decreasing (FLYCOO sort order).
      rows_cap: total output rows (multiple of tile_rows).

    Returns:
      ``(rows_cap, R)`` float32 accumulated output.
    """
    interpret = _resolve_interpret(interpret)
    n_pad, rank = contrib.shape
    assert n_pad % blk == 0, (n_pad, blk)
    assert rows_cap % tile_rows == 0, (rows_cap, tile_rows)
    num_blocks = n_pad // blk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,              # tile_of_block
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),          # local_row
            pl.BlockSpec((blk, rank), lambda b, tiles: (b, 0)),   # contrib
            pl.BlockSpec((tile_rows, rank),
                         lambda b, tiles: (tiles[b], 0)),         # out_init alias
        ],
        out_specs=pl.BlockSpec((tile_rows, rank),
                               lambda b, tiles: (tiles[b], 0)),
    )
    out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    return pl.pallas_call(
        functools.partial(_accum_body_aliased, tile_rows=tile_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        input_output_aliases={3: 0},        # out_init -> out (indices incl. prefetch)
        interpret=interpret,
    )(tile_of_block, local_row_in_tile, contrib, out_init)


def _accum_body_aliased(tile_ref, row_ref, contrib_ref, init_ref, out_ref,
                        *, tile_rows: int):
    """Aliased variant: out_ref starts as the (zeros) alias content."""
    del tile_ref, init_ref
    update = _scatter_update(row_ref[...], contrib_ref[...], tile_rows)
    out_ref[...] += update.astype(out_ref.dtype)


def _fused_nmode_body(*refs, tile_rows: int):
    """Fused Hadamard (Alg. 2 lines 19-23) + scatter, any tensor order.

    Ref layout (positional, after scalar prefetch): ``tile_ref, row_ref,
    val_ref, rows_0 … rows_{K-1}, init_ref, out_ref`` where K = N−1 input
    modes. ``contrib`` is built by looping ``contrib *= rows_w`` over the
    gathered factor-row blocks — entirely in VMEM, never in HBM. The
    factor blocks may be bf16 (the bf16-gather variant); ``contrib``
    starts fp32 so every product accumulates at fp32.

    The same body serves the untiled and the rank-tiled kernel: the
    BlockSpecs decide whether a ref covers the full padded rank or one
    ``RANK_SLAB`` column slab, and the arithmetic is columnwise
    independent either way.
    """
    tile_ref, row_ref, val_ref = refs[0], refs[1], refs[2]
    factor_refs = refs[3:-2]
    init_ref, out_ref = refs[-2], refs[-1]
    del tile_ref, init_ref
    rows = row_ref[...]
    contrib = val_ref[...][:, None].astype(jnp.float32)
    for rows_w in factor_refs:
        contrib = contrib * rows_w[...]
    update = _scatter_update(rows, contrib, tile_rows)
    out_ref[...] += update.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows", "interpret")
)
def fused_mttkrp_nmode(
    vals,
    factor_rows,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    interpret: bool | None = None,
    out_init=None,
):
    """N-mode fused variant: Hadamard product formed in VMEM, never in HBM.

    Saves 2·R·4 bytes/nonzero of HBM traffic vs. ``segment_accumulate`` on a
    pre-materialized ``contrib`` (the §Perf memory-term optimization), for a
    tensor of **any** order.

    Args:
      vals: ``(num_blocks*blk,)`` block-aligned nonzero values; padding is 0.
      factor_rows: tuple/list of K = N−1 arrays, each ``(num_blocks*blk, R)``
        — the gathered input-factor rows per nonzero, block-aligned with
        ``vals``. R must be identical across operands (a multiple of
        ``MXU_RANK_MULTIPLE`` for MXU alignment; ops.py pads). fp32 or
        bf16 — the Hadamard product always accumulates at fp32.
      local_row_in_tile: ``(num_blocks*blk,)`` int32 row within its tile.
      tile_of_block: ``(num_blocks,)`` int32 output tile per block,
        non-decreasing.
      rows_cap: total output rows (multiple of tile_rows).
      out_init: optional ``(rows_cap, R)`` float32 accumulator to add
        into (aliased — the kernel's output starts from it). ``None``
        means zeros. ``repro.oocore``'s chunked executor threads the
        running accumulator through here so splitting a stream into
        chunks reproduces the single-pass accumulation order bit-exactly.

    Returns:
      ``(rows_cap, R)`` float32 accumulated output.
    """
    interpret = _resolve_interpret(interpret)
    factor_rows = tuple(factor_rows)
    assert factor_rows, "need at least one input-factor operand"
    n_pad, rank = factor_rows[0].shape
    for fr in factor_rows:
        assert fr.shape == (n_pad, rank), (fr.shape, (n_pad, rank))
    assert n_pad % blk == 0, (n_pad, blk)
    assert rows_cap % tile_rows == 0, (rows_cap, tile_rows)
    num_blocks = n_pad // blk
    n_in = len(factor_rows)

    in_specs = (
        [
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),          # local_row
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),          # vals
        ]
        + [
            pl.BlockSpec((blk, rank), lambda b, tiles: (b, 0))    # rows_w
            for _ in range(n_in)
        ]
        + [
            pl.BlockSpec((tile_rows, rank),
                         lambda b, tiles: (tiles[b], 0)),         # out_init alias
        ]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_rows, rank),
                               lambda b, tiles: (tiles[b], 0)),
    )
    if out_init is None:
        out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_nmode_body, tile_rows=tile_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        # out_init -> out; operand index counts prefetch + row/val + factors.
        input_output_aliases={3 + n_in: 0},
        interpret=interpret,
    )(tile_of_block, local_row_in_tile, vals, *factor_rows, out_init)


@functools.partial(
    jax.jit,
    static_argnames=("rows_cap", "blk", "tile_rows", "rank_slab",
                     "interpret"),
)
def fused_mttkrp_nmode_tiled(
    vals,
    factor_rows,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    rank_slab: int = RANK_SLAB,
    interpret: bool | None = None,
    out_init=None,
):
    """Rank-tiled N-mode fused variant: VMEM working set independent of R.

    Same contract as :func:`fused_mttkrp_nmode` with one extra
    precondition — R must be a multiple of ``rank_slab`` (ops.py's
    ``pad_rank`` guarantees this; padding columns are zero and sliced
    off by the caller). The grid gains a *major* axis over rank slabs:

        grid = (R // rank_slab, num_blocks)

    so for each slab the kernel makes a full pass over the nonzero
    stream, holding only ``(blk, rank_slab)`` factor/contrib blocks and a
    ``(tile_rows, rank_slab)`` output tile — the working set that made
    very large R overflow VMEM in the untiled kernel no longer scales
    with R. The block axis stays *minor* so, within a slab pass, each
    output tile is still revisited over a contiguous run of blocks (the
    FLYCOO sort-order invariant the accumulation relies on). Cost of
    tiling: the scalar streams (values, local rows) are re-read once per
    slab — ``2·4 B`` per nonzero per slab, negligible against the
    ``(N−1)·R·4 B`` gather traffic each slab pass moves anyway.
    """
    interpret = _resolve_interpret(interpret)
    factor_rows = tuple(factor_rows)
    assert factor_rows, "need at least one input-factor operand"
    n_pad, rank = factor_rows[0].shape
    for fr in factor_rows:
        assert fr.shape == (n_pad, rank), (fr.shape, (n_pad, rank))
    assert n_pad % blk == 0, (n_pad, blk)
    assert rank % rank_slab == 0, (rank, rank_slab)
    assert rows_cap % tile_rows == 0, (rows_cap, tile_rows)
    num_blocks = n_pad // blk
    num_slabs = rank // rank_slab
    n_in = len(factor_rows)

    in_specs = (
        [
            pl.BlockSpec((blk,), lambda s, b, tiles: (b,)),        # local_row
            pl.BlockSpec((blk,), lambda s, b, tiles: (b,)),        # vals
        ]
        + [
            pl.BlockSpec((blk, rank_slab),
                         lambda s, b, tiles: (b, s))               # rows_w
            for _ in range(n_in)
        ]
        + [
            pl.BlockSpec((tile_rows, rank_slab),
                         lambda s, b, tiles: (tiles[b], s)),       # out_init
        ]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_slabs, num_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_rows, rank_slab),
                               lambda s, b, tiles: (tiles[b], s)),
    )
    if out_init is None:
        out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_nmode_body, tile_rows=tile_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        # out_init -> out; operand index counts prefetch + row/val + factors.
        input_output_aliases={3 + n_in: 0},
        interpret=interpret,
    )(tile_of_block, local_row_in_tile, vals, *factor_rows, out_init)


def _fused_gather_body(*refs, tile_rows: int, onehot_gather: bool):
    """In-kernel gather + Hadamard + scatter (Alg. 2 lines 13-25 whole).

    Ref layout (positional, after scalar prefetch): ``tile_ref, row_ref,
    val_ref, idx_ref, factors_0 … factors_{K-1}, init_ref, out_ref``.
    Unlike :func:`_fused_nmode_body`, the factor refs here are the
    (replicated, VMEM-resident) factor *matrices*, not pre-gathered row
    blocks: each nonzero's rows are formed by :func:`_gather_rows` on
    its int32 index stream inside the body, so the gathered operands
    never touch HBM. ``onehot_gather`` picks the gather form (one-hot
    MXU matmul on the compiled path, ``jnp.take`` in the interpreter —
    bitwise-identical). The factor refs may be bf16 (bf16-gather
    variants); ``contrib`` starts fp32 so every product accumulates at
    fp32.

    The same body serves the factor-resident and the rank-slabbed
    kernel: the BlockSpecs decide whether a factor ref covers the full
    padded rank or one ``RANK_SLAB`` column slab.
    """
    tile_ref, row_ref, val_ref, idx_ref = refs[0], refs[1], refs[2], refs[3]
    factor_refs = refs[4:-2]
    init_ref, out_ref = refs[-2], refs[-1]
    del tile_ref, init_ref
    rows = row_ref[...]
    idx = idx_ref[...]
    contrib = val_ref[...][:, None].astype(jnp.float32)
    for w, f_ref in enumerate(factor_refs):
        contrib = contrib * _gather_rows(f_ref[...], idx[:, w],
                                         onehot=onehot_gather)
    update = _scatter_update(rows, contrib, tile_rows)
    out_ref[...] += update.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows", "interpret")
)
def fused_mttkrp_nmode_gather(
    vals,
    idx_stream,
    factors,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    interpret: bool | None = None,
    out_init=None,
):
    """Factor-resident in-kernel gather variant of the fused kernel.

    Where :func:`fused_mttkrp_nmode` receives N−1 HBM-materialized
    gathered row blocks (``(N−1)·R̂·4`` B written *and* re-read per
    nonzero by the caller), this kernel receives the replicated factor
    matrices whole — held in VMEM across every grid step via a
    constant-index BlockSpec — plus a block-aligned int32 index stream,
    and performs the gather in the body. The per-nonzero HBM stream is
    ``(N−1)·4`` B of indices.

    Args:
      vals: ``(num_blocks*blk,)`` block-aligned nonzero values; padding 0.
      idx_stream: ``(num_blocks*blk, K)`` int32, K = N−1 input modes —
        the factor row index of each nonzero per input mode, in the same
        order as ``factors``; padding slots point at row 0 (harmless:
        their value is 0).
      factors: tuple/list of K ``(I_pad_w, R)`` replicated input-factor
        matrices (the output mode's factor is *not* passed). R identical
        across operands, a multiple of ``MXU_RANK_MULTIPLE`` (ops.py
        pads). fp32 or bf16 — the Hadamard always accumulates at fp32.
      local_row_in_tile: ``(num_blocks*blk,)`` int32 row within its tile.
      tile_of_block: ``(num_blocks,)`` int32 output tile per block,
        non-decreasing.
      rows_cap: total output rows (multiple of tile_rows).

    Returns:
      ``(rows_cap, R)`` float32 accumulated output.
    """
    interpret = _resolve_interpret(interpret)
    factors = tuple(factors)
    assert factors, "need at least one input-factor matrix"
    n_pad, n_in = idx_stream.shape
    assert n_in == len(factors), (n_in, len(factors))
    rank = factors[0].shape[1]
    for f in factors:
        assert f.shape[1] == rank, (f.shape, rank)
    assert n_pad % blk == 0, (n_pad, blk)
    assert rows_cap % tile_rows == 0, (rows_cap, tile_rows)
    num_blocks = n_pad // blk

    in_specs = (
        [
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),           # local_row
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),           # vals
            pl.BlockSpec((blk, n_in), lambda b, tiles: (b, 0)),    # idx stream
        ]
        + [
            # Whole replicated factor matrix, block index pinned at the
            # origin: resident in VMEM for the entire grid sweep.
            pl.BlockSpec(f.shape, lambda b, tiles: (0, 0))
            for f in factors
        ]
        + [
            pl.BlockSpec((tile_rows, rank),
                         lambda b, tiles: (tiles[b], 0)),          # out_init
        ]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_rows, rank),
                               lambda b, tiles: (tiles[b], 0)),
    )
    if out_init is None:
        out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_gather_body, tile_rows=tile_rows,
                          onehot_gather=not interpret),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        # out_init -> out; operand index counts prefetch + row/val/idx +
        # the K factor matrices.
        input_output_aliases={4 + n_in: 0},
        interpret=interpret,
    )(tile_of_block, local_row_in_tile, vals, idx_stream, *factors, out_init)


@functools.partial(
    jax.jit,
    static_argnames=("rows_cap", "blk", "tile_rows", "rank_slab",
                     "interpret"),
)
def fused_mttkrp_nmode_gather_tiled(
    vals,
    idx_stream,
    factors,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    rank_slab: int = RANK_SLAB,
    interpret: bool | None = None,
    out_init=None,
):
    """Slab-streamed in-kernel gather: one rank slab of each factor resident.

    Same contract as :func:`fused_mttkrp_nmode_gather` with R required to
    be a multiple of ``rank_slab`` (ops.py's ``pad_rank`` guarantees it).
    The grid gains a *major* axis over rank slabs, exactly like
    :func:`fused_mttkrp_nmode_tiled`:

        grid = (R // rank_slab, num_blocks)

    and each factor's BlockSpec selects the slab's column window of the
    matrix, so the resident set per step is ``ΣI_pad·rank_slab·gi``
    instead of ``ΣI_pad·R̂·gi`` — huge ranks no longer force the factors
    out of VMEM. The block axis stays minor (FLYCOO sort-order
    invariant); cost of slabbing: the scalar + index streams are re-read
    once per slab (``(2+K)·4`` B per nonzero per slab), still a factor
    ``R̂/rank_slab`` smaller than streaming pre-gathered rows.
    """
    interpret = _resolve_interpret(interpret)
    factors = tuple(factors)
    assert factors, "need at least one input-factor matrix"
    n_pad, n_in = idx_stream.shape
    assert n_in == len(factors), (n_in, len(factors))
    rank = factors[0].shape[1]
    for f in factors:
        assert f.shape[1] == rank, (f.shape, rank)
    assert n_pad % blk == 0, (n_pad, blk)
    assert rank % rank_slab == 0, (rank, rank_slab)
    assert rows_cap % tile_rows == 0, (rows_cap, tile_rows)
    num_blocks = n_pad // blk
    num_slabs = rank // rank_slab

    in_specs = (
        [
            pl.BlockSpec((blk,), lambda s, b, tiles: (b,)),        # local_row
            pl.BlockSpec((blk,), lambda s, b, tiles: (b,)),        # vals
            pl.BlockSpec((blk, n_in), lambda s, b, tiles: (b, 0)),  # idx
        ]
        + [
            # One rank slab of the whole factor matrix per slab pass.
            pl.BlockSpec((f.shape[0], rank_slab),
                         lambda s, b, tiles: (0, s))
            for f in factors
        ]
        + [
            pl.BlockSpec((tile_rows, rank_slab),
                         lambda s, b, tiles: (tiles[b], s)),       # out_init
        ]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_slabs, num_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_rows, rank_slab),
                               lambda s, b, tiles: (tiles[b], s)),
    )
    if out_init is None:
        out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_gather_body, tile_rows=tile_rows,
                          onehot_gather=not interpret),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        # out_init -> out; operand index counts prefetch + row/val/idx +
        # the K factor matrices.
        input_output_aliases={4 + n_in: 0},
        interpret=interpret,
    )(tile_of_block, local_row_in_tile, vals, idx_stream, *factors, out_init)


def _fused_gather_stream_body(*refs, tile_rows: int, num_in_modes: int,
                              window_tiles: tuple, frow_tile: int,
                              onehot_gather: bool):
    """Out-of-core gather: windowed factor tiles + Hadamard + scatter.

    Ref layout (positional): ``tile_ref, sched_pref_0 … sched_pref_{K-1}``
    (the scalar-prefetched SMEM schedules — consumed both by the
    BlockSpec index maps *and* here), then ``row_ref, val_ref, idx_ref,
    win_{0,0} … win_{K-1,W_{K-1}-1}, init_ref, out_ref``. Each
    ``win_{w,j}`` is one ``(frow_tile, slab)`` VMEM slot whose HBM source
    tile the prefetched schedule selected for this block. The body maps
    each nonzero's global factor row to its window slot by scanning this
    block's schedule row — read scalar-by-scalar from SMEM (a ``(1, W)``
    VMEM copy would violate Mosaic's sublane tiling), the scan unrolled
    over the static window width, reverse order so the *first* matching
    slot wins:

        slot  = first j with  global_row // frow_tile == sched[b, j]
        local = slot · frow_tile + global_row % frow_tile

    The gathered values are bitwise the rows the factor-resident kernel
    would have gathered, so the arithmetic (and its order) is unchanged
    — streamed ≡ resident bit-exactly. Padding/invalid nonzeros may miss
    every scheduled tile (no hit keeps the default slot 0); they then
    gather an arbitrary in-window row, harmless at value 0.
    """
    k = num_in_modes
    sched_refs = refs[1:1 + k]
    row_ref, val_ref, idx_ref = refs[1 + k], refs[2 + k], refs[3 + k]
    win_refs = refs[4 + k:-2]
    out_ref = refs[-1]
    b = pl.program_id(1)                     # grid = (slabs, blocks)
    rows = row_ref[...]
    idx = idx_ref[...]
    contrib = val_ref[...][:, None].astype(jnp.float32)
    off = 0
    for w in range(k):
        width = window_tiles[w]
        slots = [win_refs[off + j][...] for j in range(width)]
        off += width
        window = slots[0] if width == 1 else jnp.concatenate(slots, axis=0)
        gtile = (idx[:, w] // frow_tile).astype(jnp.int32)
        slot = jnp.zeros_like(gtile)
        for j in range(width - 1, -1, -1):
            slot = jnp.where(gtile == sched_refs[w][b, j], j, slot)
        local = slot * frow_tile + idx[:, w] % frow_tile
        contrib = contrib * _gather_rows(window, local,
                                         onehot=onehot_gather)
    update = _scatter_update(rows, contrib, tile_rows)
    out_ref[...] += update.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("rows_cap", "blk", "tile_rows", "frow_tile",
                     "rank_slab", "interpret"),
)
def fused_mttkrp_nmode_gather_stream(
    vals,
    idx_stream,
    factors,
    local_row_in_tile,
    tile_of_block,
    tile_schedules,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    frow_tile: int = FACTOR_ROW_TILE,
    rank_slab: int = RANK_SLAB,
    interpret: bool | None = None,
    out_init=None,
):
    """Out-of-core in-kernel gather: HBM-resident factors, windowed tiles.

    Same contract as :func:`fused_mttkrp_nmode_gather` except the factor
    matrices are **never** held resident: per input mode ``w`` the kernel
    keeps a window of ``W_w = tile_schedules[w].shape[1]`` VMEM slots of
    ``frow_tile`` factor rows each, and the Pallas pipeline DMAs each
    slot's HBM tile per grid step — double-buffered against the previous
    step's compute, exactly like every other streamed operand — with the
    source tile chosen by the scalar-prefetched ``tile_schedules``
    (ops.py's ``_tile_schedule`` builds them from the index stream: the
    sorted distinct ``frow_tile``-row tiles each block touches).
    FLYCOO's row-sorted blocks keep the schedule monotone within a
    block, so when consecutive blocks keep a slot on the same tile the
    pipeline skips the re-fetch.

    Extra preconditions over the resident kernel:
      * each factor's row count is a multiple of ``frow_tile`` (ops.py
        pads);
      * ``tile_schedules[w]`` is ``(num_blocks, W_w)`` int32 with every
        tile of block ``b``'s nonzeros present in row ``b`` — guaranteed
        by construction when ``W_w >= min(blk, ceil(rows_w /
        frow_tile))``, the bound ``repro.oocore.planner`` plans with;
      * R is a multiple of ``rank_slab`` (the stream kernel always
        composes with the rank-slab grid axis — grid =
        ``(R // rank_slab, num_blocks)`` — so the window cost is
        independent of R; pass ``rank_slab=R̂`` to disable slabbing).

    ``out_init`` as in :func:`fused_mttkrp_nmode`: the accumulator the
    output starts from (``None`` = zeros), which lets the chunked
    executor reproduce single-pass accumulation order bit-exactly.

    Returns ``(rows_cap, R)`` float32 accumulated output.
    """
    interpret = _resolve_interpret(interpret)
    factors = tuple(factors)
    tile_schedules = tuple(tile_schedules)
    assert factors, "need at least one input-factor matrix"
    n_pad, n_in = idx_stream.shape
    assert n_in == len(factors) == len(tile_schedules), (
        n_in, len(factors), len(tile_schedules))
    rank = factors[0].shape[1]
    for f in factors:
        assert f.shape[1] == rank, (f.shape, rank)
        assert f.shape[0] % frow_tile == 0, (f.shape, frow_tile)
    assert n_pad % blk == 0, (n_pad, blk)
    assert rank % rank_slab == 0, (rank, rank_slab)
    assert rows_cap % tile_rows == 0, (rows_cap, tile_rows)
    num_blocks = n_pad // blk
    num_slabs = rank // rank_slab
    window_tiles = tuple(s.shape[1] for s in tile_schedules)
    for w, s in enumerate(tile_schedules):
        assert s.shape == (num_blocks, window_tiles[w]), (s.shape, w)

    in_specs = (
        [
            pl.BlockSpec((blk,), lambda s, b, tiles, *scheds: (b,)),
            pl.BlockSpec((blk,), lambda s, b, tiles, *scheds: (b,)),
            pl.BlockSpec((blk, n_in),
                         lambda s, b, tiles, *scheds: (b, 0)),
        ]
        + [
            # Window slot j of mode w: one frow_tile-row, rank_slab-wide
            # factor tile, whose source the prefetched schedule picks.
            # The factor itself stays in HBM; only these slots are VMEM.
            pl.BlockSpec((frow_tile, rank_slab),
                         lambda s, b, tiles, *scheds, w=w, j=j:
                         (scheds[w][b, j], s))
            for w in range(n_in) for j in range(window_tiles[w])
        ]
        + [
            pl.BlockSpec((tile_rows, rank_slab),
                         lambda s, b, tiles, *scheds: (tiles[b], s)),
        ]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1 + n_in,       # tile_of_block + K schedules
        grid=(num_slabs, num_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_rows, rank_slab),
                               lambda s, b, tiles, *scheds: (tiles[b], s)),
    )
    if out_init is None:
        out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    window_operands = [factors[w] for w in range(n_in)
                       for _ in range(window_tiles[w])]
    return pl.pallas_call(
        functools.partial(
            _fused_gather_stream_body, tile_rows=tile_rows,
            num_in_modes=n_in, window_tiles=window_tiles,
            frow_tile=frow_tile, onehot_gather=not interpret),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        # out_init -> out; operand index counts the 1+K prefetch args +
        # row/val/idx + ΣW_w window slots (the body reads the schedules
        # straight from the SMEM prefetch refs — no VMEM copy).
        input_output_aliases={4 + n_in + sum(window_tiles): 0},
        interpret=interpret,
    )(tile_of_block, *tile_schedules, local_row_in_tile, vals, idx_stream,
      *window_operands, out_init)


def fused_mttkrp_3mode(
    vals,
    rows_a,
    rows_b,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    interpret: bool | None = None,
):
    """Deprecated alias: the 3-mode special case of the N-mode kernel.

    Kept only for pre-N-mode callers; there is one kernel entry per
    family and this is not it. Call :func:`fused_mttkrp_nmode` with
    ``factor_rows=(rows_a, rows_b)`` instead — identical output,
    bitwise.
    """
    import warnings

    warnings.warn(
        "fused_mttkrp_3mode is a deprecated alias; call "
        "fused_mttkrp_nmode(vals, (rows_a, rows_b), ...) instead",
        DeprecationWarning, stacklevel=2)
    return fused_mttkrp_nmode(
        vals, (rows_a, rows_b), local_row_in_tile, tile_of_block,
        rows_cap=rows_cap, blk=blk, tile_rows=tile_rows, interpret=interpret,
    )
