"""Pallas TPU kernel: blocked segmented MTTKRP accumulation.

TPU-native adaptation of the paper's elementwise gather–Hadamard–scatter
(Alg. 2 lines 13-25). The FLYCOO *shard* (``g`` nonzeros, cache-sized)
becomes the VMEM nonzero block; the *super-shard* row interval becomes the
output row tile; and — the key rethinking for the MXU — the random scatter
into output rows becomes a **one-hot matmul**:

    out_tile (T×R)  +=  onehot(local_row, T)ᵀ (T×B)  @  contrib (B×R)

which is dense, layout-friendly and runs on the systolic array. Correctness
relies on the FLYCOO invariant that nonzeros are sorted by output row and
blocks are padded to never straddle a row tile (ops.py builds that layout),
so the sequential TPU grid revisits each output tile over a contiguous run
of blocks and accumulates in VMEM.

Grid: one step per nonzero block. ``tile_of_block`` is scalar-prefetched and
drives the output BlockSpec index_map. The output is zero-initialized via
``input_output_aliases`` (an aliased zeros operand), so empty tiles stay
zero without needing a first-visit flag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["segment_accumulate", "fused_mttkrp_3mode"]


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows", "interpret")
)
def segment_accumulate(
    contrib,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    interpret: bool = True,
):
    """Blocked segmented accumulation (scatter stage of spMTTKRP).

    Args:
      contrib: ``(num_blocks*blk, R)`` block-aligned contributions; padding
        rows are zero. R should be a multiple of 128 for MXU alignment
        (ops.py pads).
      local_row_in_tile: ``(num_blocks*blk,)`` int32 row *within its tile*
        (``0 <= r < tile_rows``); padding points at row 0 with zero contrib.
      tile_of_block: ``(num_blocks,)`` int32 output tile per block,
        non-decreasing (FLYCOO sort order).
      rows_cap: total output rows (multiple of tile_rows).

    Returns:
      ``(rows_cap, R)`` float32 accumulated output.
    """
    n_pad, rank = contrib.shape
    assert n_pad % blk == 0, (n_pad, blk)
    assert rows_cap % tile_rows == 0, (rows_cap, tile_rows)
    num_blocks = n_pad // blk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,              # tile_of_block
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),          # local_row
            pl.BlockSpec((blk, rank), lambda b, tiles: (b, 0)),   # contrib
            pl.BlockSpec((tile_rows, rank),
                         lambda b, tiles: (tiles[b], 0)),         # out_init alias
        ],
        out_specs=pl.BlockSpec((tile_rows, rank),
                               lambda b, tiles: (tiles[b], 0)),
    )
    out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    return pl.pallas_call(
        functools.partial(_accum_body_aliased, tile_rows=tile_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        input_output_aliases={3: 0},        # out_init -> out (indices incl. prefetch)
        interpret=interpret,
    )(tile_of_block, local_row_in_tile, contrib, out_init)


def _accum_body_aliased(tile_ref, row_ref, contrib_ref, init_ref, out_ref,
                        *, tile_rows: int):
    """Aliased variant: out_ref starts as the (zeros) alias content."""
    del tile_ref, init_ref
    rows = row_ref[...]
    contrib = contrib_ref[...]
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows.shape[0], tile_rows), 1)
    onehot = (rows[:, None] == iota).astype(contrib.dtype)
    update = jax.lax.dot_general(
        onehot, contrib,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += update.astype(out_ref.dtype)


def _fused_body(tile_ref, row_ref, val_ref, ra_ref, rb_ref, init_ref, out_ref,
                *, tile_rows: int):
    """Fused Hadamard (Alg. 2 lines 19-23) + scatter: contrib built in VMEM."""
    del tile_ref, init_ref
    rows = row_ref[...]
    contrib = (val_ref[...][:, None] * ra_ref[...] * rb_ref[...])
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows.shape[0], tile_rows), 1)
    onehot = (rows[:, None] == iota).astype(contrib.dtype)
    update = jax.lax.dot_general(
        onehot, contrib,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += update.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("rows_cap", "blk", "tile_rows", "interpret")
)
def fused_mttkrp_3mode(
    vals,
    rows_a,
    rows_b,
    local_row_in_tile,
    tile_of_block,
    *,
    rows_cap: int,
    blk: int = 512,
    tile_rows: int = 128,
    interpret: bool = True,
):
    """3-mode fused variant: Hadamard product formed in VMEM, never in HBM.

    Saves 2·R·4 bytes/nonzero of HBM traffic vs. ``segment_accumulate`` on a
    pre-materialized ``contrib`` (the §Perf memory-term optimization).
    """
    n_pad, rank = rows_a.shape
    assert n_pad % blk == 0
    assert rows_cap % tile_rows == 0
    num_blocks = n_pad // blk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),          # local_row
            pl.BlockSpec((blk,), lambda b, tiles: (b,)),          # vals
            pl.BlockSpec((blk, rank), lambda b, tiles: (b, 0)),   # rows_a
            pl.BlockSpec((blk, rank), lambda b, tiles: (b, 0)),   # rows_b
            pl.BlockSpec((tile_rows, rank),
                         lambda b, tiles: (tiles[b], 0)),         # out_init alias
        ],
        out_specs=pl.BlockSpec((tile_rows, rank),
                               lambda b, tiles: (tiles[b], 0)),
    )
    out_init = jnp.zeros((rows_cap, rank), dtype=jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_body, tile_rows=tile_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, rank), jnp.float32),
        input_output_aliases={5: 0},        # out_init -> out (indices incl. prefetch)
        interpret=interpret,
    )(tile_of_block, local_row_in_tile, vals, rows_a, rows_b, out_init)
