"""Pallas TPU kernel for blocked segmented spMTTKRP (FLYCOO shards → VMEM)."""
from . import kernel, ops, ref  # noqa: F401
