"""Pure-jnp oracle for the blocked MTTKRP scatter kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_accumulate_ref", "fused_mttkrp_ref"]


def segment_accumulate_ref(contrib, local_row, rows_cap: int):
    """``out[r] = Σ_{i: local_row[i]==r} contrib[i]`` — the scatter stage.

    Args:
      contrib: ``(nnz, R)`` per-nonzero contribution (value × Hadamard of
        input factor rows). Padding rows must be exactly zero.
      local_row: ``(nnz,)`` int32 output row per nonzero, sorted ascending.
      rows_cap: number of output rows.
    """
    return jax.ops.segment_sum(
        contrib, local_row, num_segments=rows_cap, indices_are_sorted=True
    )


def fused_mttkrp_ref(vals, rows_list, local_row, rows_cap: int):
    """Fused Hadamard + scatter oracle (3+ mode).

    ``out[r] += vals[i] * ⊙_w rows_list[w][i]`` — same contract as the fused
    Pallas kernel: the per-nonzero ``(nnz, R)`` contribution is *never*
    materialized in HBM.
    """
    ell = vals[:, None].astype(rows_list[0].dtype)
    for rows in rows_list:
        ell = ell * rows
    return jax.ops.segment_sum(
        ell.astype(jnp.float32), local_row, num_segments=rows_cap,
        indices_are_sorted=True,
    )
