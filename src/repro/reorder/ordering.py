"""Locality-aware nonzero ordering — the paper's remapping idea, aimed
at the stream kernel's tile re-fetch gap.

``pallas_fused_gather_stream`` (``repro.oocore``) DMAs a window of
``FACTOR_ROW_TILE``-row factor tiles per nonzero block; the counted gap
PR 5 measured is that on an unsorted stream consecutive blocks touch
near-disjoint tile sets, so ``scheduled`` bytes run ~3× ``distinct``
(``BENCH_oocore.json``). The FLYCOO stream contract only fixes the
*output-row-tile* grouping (``ops.build_block_layout`` needs per-tile
runs contiguous, nothing more), which leaves the order of nonzeros
**within** an output-tile run completely free. This module spends that
freedom: permute each run so nonzeros touching the same gathered factor
tiles sit in the same blocks.

Two policies beyond ``"none"``:

* ``"tile"`` — per-mode tile-cluster sort: within each output-tile run,
  sort by the tuple of ``FACTOR_ROW_TILE``-tile ids of the gathered
  (input) modes, first gathered mode major. Greedy per-mode locality.
* ``"morton"`` — Morton/Z-order interleaving of the per-mode tile ids:
  bit-plane interleave across all gathered modes at once, so no single
  mode dominates and locality is traded evenly — the multi-mode
  analogue of the paper's remapped layout (and of ALTO's bit-interleaved
  linearization).

Everything here is a **true permutation** of the nonzero stream (a
bijection; ``tests/test_reorder.py`` property-checks multiset
preservation per mode), so CP-ALS results differ from the unsorted
stream only by fp32 accumulation order. The sort is paid once per mode
at preprocessing time and amortized across every ALS sweep, exactly
like the FLYCOO permutation itself.

Key helpers are written against the *array operator set* shared by
numpy and ``jax.numpy`` (``//``, ``>>``, ``&``, ``|``, ``.clip``), so
:func:`locality_keys` / :func:`morton_key_words` produce bit-identical
keys host-side (``np.lexsort`` in :func:`locality_lexsort`) and inside
jit (``jnp.lexsort`` in ``ops.build_block_layout``'s ``order_keys``
path) — the agreement ``tests/test_reorder.py`` pins bit-exactly.
"""
from __future__ import annotations

import numpy as np

from ..kernels.mttkrp import kernel as _kernel
from ..obs import counters as _obs

__all__ = [
    "FACTOR_ROW_TILE",
    "MORTON_BITS",
    "ORDERINGS",
    "locality_keys",
    "locality_lexsort",
    "morton_bits_for",
    "morton_key_words",
    "reorder_stream",
    "validate_ordering",
]

FACTOR_ROW_TILE = _kernel.FACTOR_ROW_TILE

# The ordering policies every layer accepts (FlycooTensor / ModePlan /
# DynasorRuntime / mttkrp_device_step / the oocore executor).
ORDERINGS = ("none", "tile", "morton")

# Bits of tile id each mode contributes to the Morton code: 16 bits =
# 65536 FACTOR_ROW_TILE-row tiles = 8.4M factor rows per mode. Callers
# that know the mode sizes (``max_rows=`` threading from ops /
# oocore / pack_mode) widen past this automatically via
# :func:`morton_bits_for`; without that knowledge an id beyond the
# budget raises host-side rather than silently clamping (a clamp
# merges distinct tiles into one key — the ordering quietly stops
# doing its job exactly on the huge tensors it exists for).
MORTON_BITS = 16

# jax runs with x64 disabled (int32 default), so interleaved codes are
# packed into words of at most this many bits — int32-safe on both the
# host and the jit path.
_WORD_BITS = 30


def validate_ordering(ordering: str) -> str:
    if ordering not in ORDERINGS:
        raise ValueError(
            f"unknown ordering {ordering!r}: expected one of {ORDERINGS}")
    return ordering


def morton_bits_for(max_tiles: int, bits: int = MORTON_BITS) -> int:
    """Bits per mode covering tile ids ``[0, max_tiles)``.

    Never below ``bits`` (key-layout stability for the common case),
    widened when the mode is bigger — the word packing grows with it,
    so no tile id is ever truncated. Widening only prepends zero bit
    planes for ids that fit anyway, so it never changes the sort order
    of in-budget keys.
    """
    if max_tiles <= 1:
        return bits
    return max(bits, int(max_tiles - 1).bit_length())


def morton_key_words(tiles, bits: int = MORTON_BITS, *,
                     max_tiles: int | None = None):
    """Morton (Z-order) code of per-mode tile ids, as int32-safe words.

    ``tiles`` is ``(n, K)`` — one tile id per gathered mode. The K
    modes' low ``bits`` bits are interleaved MSB-first (bit ``b`` of
    mode 0, then bit ``b`` of mode 1, …) and packed into words of at
    most 30 bits. Returns a tuple of words, **most significant first** —
    the comparison order ``lexsort`` needs. Works on numpy and
    ``jax.numpy`` arrays alike (operator arithmetic only).

    ``max_tiles`` (static: the largest gathered mode's tile count)
    widens ``bits`` via :func:`morton_bits_for` so big modes never
    truncate — jit callers must pass it (tracers carry no values to
    check). Without it, a host-side id beyond the ``bits`` budget is a
    ``ValueError``: distinct tiles silently merging into one clamped
    key is precisely the failure mode this module exists to avoid.
    """
    k = tiles.shape[1]
    if max_tiles is not None:
        bits = morton_bits_for(int(max_tiles), bits)
    elif isinstance(tiles, np.ndarray) and tiles.size:
        top = int(tiles.max())
        if top >= (1 << bits):
            raise ValueError(
                f"tile id {top} needs {top.bit_length()} bits, over the "
                f"{bits}-bit Morton budget — pass max_tiles= (or "
                "max_rows= one level up) so the word count widens "
                "instead of silently clamping distinct tiles together")
    tiles = tiles.clip(0, (1 << bits) - 1)
    planes = [(tiles[:, i] >> b) & 1
              for b in reversed(range(bits)) for i in range(k)]
    words = []
    for start in range(0, len(planes), _WORD_BITS):
        word = planes[start]
        for plane in planes[start + 1:start + _WORD_BITS]:
            word = (word << 1) | plane
        words.append(word)
    return tuple(words)


def locality_keys(idx_in, ordering: str,
                  frow_tile: int = FACTOR_ROW_TILE,
                  max_rows: int | None = None):
    """Sort keys realizing ``ordering`` over gathered-mode indices.

    ``idx_in`` is ``(n, K)`` — the factor-row index of each nonzero in
    each gathered (input) mode. Returns a tuple of equal-length key
    arrays, most significant first (``()`` for ``"none"``). Generic
    over numpy / ``jax.numpy`` inputs; the jit consumer is
    ``ops.build_block_layout(order_keys=...)``.

    ``max_rows`` (static: the largest gathered mode's factor row
    count) sizes the Morton bit budget — see :func:`morton_key_words`.
    Host and jit callers must agree on it for bit-identical keys (they
    derive it from the same factor shapes, so they do).
    """
    validate_ordering(ordering)
    if ordering == "none":
        return ()
    tiles = idx_in // frow_tile
    if ordering == "tile":
        return tuple(tiles[:, i] for i in range(tiles.shape[1]))
    max_tiles = (None if max_rows is None
                 else -(-int(max_rows) // frow_tile))
    return morton_key_words(tiles, max_tiles=max_tiles)


def locality_lexsort(idx_in, ordering: str, *, primaries=(),
                     frow_tile: int = FACTOR_ROW_TILE,
                     max_rows: int | None = None) -> np.ndarray:
    """Host-side stable permutation: primaries, then locality, then position.

    ``primaries`` are given most significant first (e.g. the output-tile
    id, or ``(owner, output_row)`` for ``flycoo.pack_mode``); the
    locality keys order elements *within* each primary group, and the
    original position breaks remaining ties — so ``ordering="none"``
    degenerates to a stable sort by ``primaries`` alone.
    """
    idx_in = np.asarray(idx_in)
    keys = locality_keys(idx_in, ordering, frow_tile=frow_tile,
                         max_rows=max_rows)
    seq = ((np.arange(idx_in.shape[0]),)
           + tuple(reversed(keys))
           + tuple(reversed([np.asarray(p) for p in primaries])))
    perm = np.lexsort(seq)
    if ordering != "none":
        _obs.add("reorder.perms", ordering=ordering)
    return perm


def reorder_stream(idx, val, valid, *, mode: int, ordering: str,
                   tile_rows: int, row_offset: int = 0,
                   frow_tile: int = FACTOR_ROW_TILE,
                   max_rows: int | None = None):
    """Permute one mode's nonzero stream for factor-tile locality.

    Input contract = the executor's (``oocore.mttkrp_out_of_core``):
    ``idx (cap, N)`` sorted by output row with trailing invalids. The
    returned stream keeps what downstream layers actually require —
    valid elements first, output-**tile** runs contiguous and ascending
    (``ops.build_block_layout``'s real precondition) — while ordering
    each run by the policy's locality keys. Returns
    ``(idx', val', valid', perm)`` with ``x'[i] = x[perm[i]]``.
    """
    idx = np.asarray(idx)
    val = np.asarray(val)
    valid = np.asarray(valid, bool)
    nmodes = idx.shape[1]
    in_modes = [w for w in range(nmodes) if w != mode]
    local_row = idx[:, mode].astype(np.int64) - row_offset
    # Invalid elements sort after every real output tile.
    out_tile = np.where(valid, local_row // tile_rows, np.int64(2 ** 62))
    idx_in = np.where(valid[:, None], idx[:, in_modes], 0)
    perm = locality_lexsort(idx_in, ordering, primaries=(out_tile,),
                            frow_tile=frow_tile, max_rows=max_rows)
    return idx[perm], val[perm], valid[perm], perm
