"""``python -m repro.reorder`` — locality-ordering end-to-end smoke.

The CI step that keeps the reorder subsystem honest: build a small
skewed tensor, run one mode step through the chunked streaming executor
with each ordering policy under a byte budget small enough to force
several chunks, and assert

  * the streamed result is **bit-exact** against the factor-resident
    gather backend on the same permuted stream (a reorder is a pure
    permutation — it must never change what one kernel call computes);
  * ``planner.predict_stream_traffic`` agrees **exactly** with the
    executor's counted ``StreamStats`` (scheduled/distinct bytes,
    window widths, chunk count) — the predictor and the executor share
    one arithmetic, and this is where that contract is exercised on a
    multi-chunk workload every CI run;
  * the stats' presort fields reproduce a fresh unsorted prediction;
  * the planner certifies the stream rung at a budget sized to the
    *measured* post-sort windows.

Exit status 0 iff every check passes.
"""
from __future__ import annotations

import sys

import numpy as np


def main(argv=None) -> int:
    import jax.numpy as jnp

    from ..core.tensors import zipf_4d
    from ..kernels.mttkrp import kernel as _kernel
    from ..kernels.mttkrp import ops as kops
    from ..oocore import planner
    from ..oocore.executor import mttkrp_out_of_core
    from . import ORDERINGS, reorder_stream

    blk, tile_rows, rank, mode = 32, 8, 16, 3
    shape = (3000, 1400, 900, 50)
    t = zipf_4d(shape, 3000, alpha=1.3, seed=7)
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    valid = np.ones(len(val), bool)
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    rows_cap = -(-shape[mode] // tile_rows) * tile_rows
    in_modes = [w for w in range(len(shape)) if w != mode]
    frows = tuple(shape[w] for w in in_modes)
    k = len(in_modes)
    budget = 24 * planner.stream_chunk_bytes(blk, k, (8,) * k)

    failures = []
    ratios = {}
    for ordering in ORDERINGS:
        out, stats = mttkrp_out_of_core(
            idx, val, valid, factors, mode=mode, rows_cap=rows_cap,
            blk=blk, tile_rows=tile_rows, max_chunk_bytes=budget,
            ordering=ordering)
        if stats.chunks < 3:
            failures.append(
                f"[{ordering}] budget did not force multi-chunk: "
                f"{stats.chunks}")
        if ordering == "none":
            i2, v2, m2 = idx, val, valid
        else:
            i2, v2, m2, _ = reorder_stream(
                idx, val, valid, mode=mode, ordering=ordering,
                tile_rows=tile_rows)
        resident = kops.mttkrp_device_step(
            jnp.asarray(i2), jnp.asarray(v2), jnp.asarray(m2), factors,
            mode=mode, rows_cap=rows_cap, row_offset=0, blk=blk,
            tile_rows=tile_rows, backend="pallas_fused_gather")
        if not np.array_equal(np.asarray(out), np.asarray(resident)):
            failures.append(
                f"[{ordering}] streamed result != resident gather result")
        predicted = planner.predict_stream_traffic(
            i2, m2, mode=mode, rows_cap=rows_cap, blk=blk,
            tile_rows=tile_rows, rank=rank, factor_rows=frows,
            max_chunk_bytes=budget, ordering=ordering)
        if (predicted.scheduled_tile_bytes != stats.scheduled_tile_bytes
                or predicted.distinct_tile_bytes != stats.distinct_tile_bytes
                or predicted.window_tiles != stats.window_tiles
                or predicted.chunks != stats.chunks):
            failures.append(
                f"[{ordering}] predicted != counted: "
                f"{predicted} vs {stats}")
        ratios[ordering] = stats.scheduled_over_distinct
        if ordering != "none":
            pre = planner.predict_stream_traffic(
                idx, valid, mode=mode, rows_cap=rows_cap, blk=blk,
                tile_rows=tile_rows, rank=rank, factor_rows=frows,
                max_chunk_bytes=budget, ordering="none")
            if (stats.presort_scheduled_tile_bytes != pre.scheduled_tile_bytes
                    or stats.presort_distinct_tile_bytes
                    != pre.distinct_tile_bytes):
                failures.append(
                    f"[{ordering}] presort fields != unsorted prediction")
            # The measured post-sort windows must certify the stream
            # rung at a budget sized exactly to them.
            wbudget = _kernel.gather_stream_vmem_bytes(
                k, kops.padded_rank(rank), blk, tile_rows,
                predicted.window_tiles)
            plan = planner.plan_residency(
                nmodes=len(shape), rank=rank, blk=blk, tile_rows=tile_rows,
                factor_rows=frows, vmem_budget=wbudget,
                window_tiles=predicted.window_tiles)
            if plan.backend != planner.STREAM_BACKEND:
                failures.append(
                    f"[{ordering}] planner at measured-window budget chose "
                    f"{plan.backend}")

    for f in failures:
        print(f"FAIL {f}")
    if failures:
        return 1
    print("reorder smoke passed: "
          + ", ".join(f"{o}: sched/dist={r:.3f}" for o, r in ratios.items())
          + "; streamed ≡ resident bit-exact per policy, predicted ≡ "
            "counted exactly, stream rung certified at measured windows")
    return 0


if __name__ == "__main__":
    sys.exit(main())
