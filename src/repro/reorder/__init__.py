"""repro.reorder — locality-aware nonzero ordering (dynamic remapping).

The preprocessing pass that closes the stream kernel's tile re-fetch
gap: permute each mode's FLYCOO nonzero stream so consecutive blocks
reuse the same ``FACTOR_ROW_TILE``-row factor tiles. Policy definitions
and the permutation machinery live in :mod:`repro.reorder.ordering`;
the consumers are ``core.flycoo.pack_mode`` (preprocessing-time),
``kernels.mttkrp.ops.build_block_layout`` (in-jit, per mode step, so
the order survives dynamic remapping between modes) and
``oocore.mttkrp_out_of_core`` (host-side, with counted before/after
traffic). ``python -m repro.reorder`` is the bit-exact smoke CI runs.

Data-flow picture in ``docs/ARCHITECTURE.md``; the counted effect on
the stream rung in ``docs/kernels.md`` and ``BENCH_reorder.json``.
"""
from .ordering import (
    MORTON_BITS,
    ORDERINGS,
    locality_keys,
    locality_lexsort,
    morton_bits_for,
    morton_key_words,
    reorder_stream,
    validate_ordering,
)

__all__ = [
    "MORTON_BITS",
    "ORDERINGS",
    "locality_keys",
    "locality_lexsort",
    "morton_bits_for",
    "morton_key_words",
    "reorder_stream",
    "validate_ordering",
]
