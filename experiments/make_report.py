"""Generate the §Dry-run and §Roofline tables for EXPERIMENTS.md from
experiments/dryrun/*.json, plus a benchmark-artifact inventory from
experiments/bench/BENCH_*.json (the ``common.write_bench_json``
artifacts — the retired lowercase ``<suite>.json`` dumps are ignored).

  PYTHONPATH=src python experiments/make_report.py > experiments/roofline_tables.md
"""
import glob
import json
import os
import sys

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ORDER_ARCHS = [
    "qwen3-32b", "phi3-mini-3.8b", "internlm2-20b", "minitron-8b",
    "qwen2-moe-a2.7b", "llama4-scout-17b-a16e", "jamba-1.5-large-398b",
    "seamless-m4t-large-v2", "llama-3.2-vision-11b", "mamba2-370m",
]

HINT = {
    "compute_s": "compute-bound: cut remat recompute / causal-skip attention",
    "memory_s": "HBM-bound: bf16 caches, fuse gathers, raise AI",
    "collective_s": "ICI-bound: reshard to cut gathers / overlap",
}


def fmt_ms(x):
    return f"{x*1e3:.2f}"


def load(dirname):
    cells = {}
    for p in glob.glob(os.path.join(dirname, "*.json")):
        stem = os.path.basename(p)[:-5]
        if len(stem.split("__")) != 3:
            continue                      # hillclimb variants live alongside
        with open(p) as f:
            d = json.load(f)
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


# Suites whose rows are *counted* (bytes/decisions from static
# arithmetic and schedules — identical on any host) and therefore worth
# committing; every other suite's rows carry wall-clock timings that
# only mean something on the host that measured them.
COUNTED_SUITES = {"BENCH_lowering.json", "BENCH_oocore.json",
                  "BENCH_dispatch.json", "BENCH_reorder.json"}

# Suites that *join* both kinds: measured wall time divided by counted
# bytes (the repro.obs.prof roofline). Host-local like any timed number,
# but each row carries its counted denominator, so the artifact is
# interpretable across hosts even though it is not comparable.
PROFILED_SUITES = {"BENCH_prof.json"}


def bench_inventory(bench_dir="experiments/bench"):
    """Summarize the BENCH_*.json artifacts (the survivors).

    One line per artifact: suite name, row count, the `bench=` row kinds
    inside, and whether the suite is counted (host-independent, lives in
    git), timed (host-local, regenerate with `python -m
    benchmarks.run`), or profiled (timed ÷ counted — the roofline
    suites) — enough to see at a glance which figures have data and
    which numbers are portable without parsing each file.
    """
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    print("\n### §Benchmarks — artifact inventory "
          f"({len(paths)} BENCH_*.json)\n")
    if not paths:
        print("(no artifacts; run `python -m benchmarks.run`)")
        return
    print("| artifact | kind | rows | row kinds |")
    print("|---|---|---|---|")
    for p in paths:
        name = os.path.basename(p)
        if name in COUNTED_SUITES:
            kind = "counted (committed)"
        elif name in PROFILED_SUITES:
            kind = "profiled (timed ÷ counted, host-local)"
        else:
            kind = "timed (host-local)"
        try:
            with open(p) as f:
                rows = json.load(f)
            kinds = sorted({r.get("bench", "?") for r in rows})
            print(f"| {name} | {kind} | {len(rows)} | {', '.join(kinds)} |")
        except (json.JSONDecodeError, OSError) as e:
            print(f"| {name} | {kind} | — | unreadable: {e} |")


def main():
    cells = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")

    print("### §Dry-run — per (arch × shape × mesh): status, fits-HBM, "
          "compile\n")
    print("| arch | shape | mesh | status | peak HBM frac | collective "
          "bytes/dev | compile s |")
    print("|---|---|---|---|---|---|---|")
    for a in ORDER_ARCHS:
        for s in ORDER_SHAPES:
            for m in ("16x16", "2x16x16"):
                d = cells.get((a, s, m))
                if d is None:
                    print(f"| {a} | {s} | {m} | MISSING | | | |")
                elif d["status"] == "skipped":
                    reason = d["reason"].split(":")[0]
                    print(f"| {a} | {s} | {m} | skipped ({reason}) | | | |")
                elif d["status"] != "ok":
                    print(f"| {a} | {s} | {m} | **{d['status']}** | | | |")
                else:
                    cb = d["collectives"]["total_bytes"]
                    print(f"| {a} | {s} | {m} | ok | "
                          f"{d['peak_hbm_frac']:.2f} | {cb/1e6:.0f} MB | "
                          f"{d['compile_s']} |")

    print("\n### §Roofline — single-pod (16×16, 256 chips), per cell\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | useful-flops ratio | roofline frac | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ORDER_ARCHS:
        for s in ORDER_SHAPES:
            d = cells.get((a, s, "16x16"))
            if not d or d.get("status") != "ok":
                continue
            r = d["roofline"]
            # roofline fraction: useful model flops time / bound time
            t_useful = (d["model_flops_per_chip"]
                        / 197e12)
            frac = t_useful / r["bound_s"] if r["bound_s"] else 0
            print(f"| {a} | {s} | {fmt_ms(r['compute_s'])} | "
                  f"{fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} | "
                  f"{r['dominant'][:-2]} | "
                  f"{(d.get('useful_flops_ratio') or 0):.2f} | "
                  f"{frac:.2f} | {HINT[r['dominant']]} |")

    # summary stats
    ok = [d for d in cells.values() if d["status"] == "ok"]
    sk = [d for d in cells.values() if d["status"] == "skipped"]
    err = [d for d in cells.values()
           if d["status"] not in ("ok", "skipped")]
    fits = [d for d in ok if d.get("peak_hbm_frac", 9) <= 1.0]
    print(f"\ncells: ok={len(ok)} skipped={len(sk)} error={len(err)} "
          f"fit_hbm={len(fits)}/{len(ok)}")

    bench_inventory()


if __name__ == "__main__":
    main()
