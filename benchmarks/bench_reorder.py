"""Locality-aware nonzero ordering: counted stream re-fetch before/after.

The ``repro.reorder`` pass permutes a mode's FLYCOO stream inside each
output-row-tile run so nonzeros touching the same ``FACTOR_ROW_TILE``
tiles of the gathered factors land in the same blocks, and the
executor's per-chunk window tightening turns that into counted DMA
savings. Everything here is *counted* (the predictor and the executor
share one arithmetic, so the bytes are exact), in two sections:

  * ``reorder_traffic`` — per (tensor, mode, ordering): the predicted
    post-sort ``scheduled/distinct`` tile-byte ratio of the chunked
    stream schedule, next to the unsorted baseline and the reduction
    factor. The skewed 4-mode zipf tensor is the headline (the
    acceptance row: ``morton`` reduces the ratio ≥2× on the hot short
    mode); the scaled ``enron-skew`` profile is the negative control —
    its streams are already near-distinct-optimal, reordering *clumps*
    rare tiles and loses, and the rows record that honestly. The
    predictor is how callers tell the two cases apart before paying for
    a permutation.
  * ``reorder_exec`` — a forced-multichunk executor run per ordering on
    a smaller tensor: bit-exactness against the factor-resident gather
    backend on the same permuted stream, and exact agreement between
    ``planner.predict_stream_traffic`` and the executor's counted
    ``StreamStats`` (the invariant ``tests/test_reorder.py`` pins).

Everything lands in ``BENCH_reorder.json``.
"""
from __future__ import annotations

import numpy as np

from repro.core.tensors import frostt_like, zipf_4d
from repro.kernels.mttkrp import ops as kops
from repro.oocore import planner
from repro.oocore.executor import mttkrp_out_of_core
from repro.reorder import ORDERINGS, reorder_stream

from .common import row, write_bench_json

# The validated skewed cell: factor dims with tens-to-hundreds of row
# tiles, moderate density (hub tiles hot, tail tiles rare) — the regime
# where the unsorted schedule re-fetches 3.5-4.6x the distinct bytes.
_SHAPE = (20000, 9000, 4000, 50)
_ALPHA = 1.3
_BLK, _TILE, _RANK = 32, 8, 16
# ~96-block chunks (sized at a nominal 8-tile window) — the executor's
# per-chunk window tightening grain.
_CHUNK_BLOCKS = 96


def _chunk_budget(k: int) -> int:
    return _CHUNK_BLOCKS * planner.stream_chunk_bytes(_BLK, k, (8,) * k)


def _sorted_stream(t, mode: int):
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    return idx, val, np.ones(len(val), bool)


def _traffic_rows(tensor_name: str, t, modes, quick: bool) -> list[dict]:
    shape = t.shape
    nmodes = len(shape)
    out = []
    for mode in modes:
        idx, val, valid = _sorted_stream(t, mode)
        in_modes = [w for w in range(nmodes) if w != mode]
        frows = tuple(int(shape[w]) for w in in_modes)
        rows_cap = -(-shape[mode] // _TILE) * _TILE
        budget = _chunk_budget(len(in_modes))
        base = None
        for ordering in ORDERINGS:
            if ordering == "none":
                i2, m2 = idx, valid
            else:
                i2, _, m2, _ = reorder_stream(
                    idx, val, valid, mode=mode, ordering=ordering,
                    tile_rows=_TILE)
            tr = planner.predict_stream_traffic(
                i2, m2, mode=mode, rows_cap=rows_cap, blk=_BLK,
                tile_rows=_TILE, rank=_RANK, factor_rows=frows,
                max_chunk_bytes=budget, ordering=ordering)
            if ordering == "none":
                base = tr
            out.append(row(
                "reorder_traffic", tensor=tensor_name, mode=mode,
                ordering=ordering, nnz=tr.nnz, blk=_BLK, tile_rows=_TILE,
                rank=_RANK, num_blocks=tr.num_blocks, chunks=tr.chunks,
                window_tiles=list(tr.window_tiles),
                scheduled_tile_MB=round(tr.scheduled_tile_bytes / 2**20, 4),
                distinct_tile_MB=round(tr.distinct_tile_bytes / 2**20, 4),
                scheduled_over_distinct=round(tr.scheduled_over_distinct, 3),
                unsorted_scheduled_over_distinct=round(
                    base.scheduled_over_distinct, 3),
                refetch_reduction_x=round(
                    base.scheduled_over_distinct
                    / max(tr.scheduled_over_distinct, 1e-12), 2),
                note="counted via planner.predict_stream_traffic "
                     "(== executor StreamStats by construction)"))
    return out


def _exec_rows(quick: bool) -> list[dict]:
    import jax.numpy as jnp

    shape = (3000, 1400, 900, 50)
    mode, nnz = 3, 3000 if quick else 9000
    t = zipf_4d(shape, nnz, alpha=_ALPHA, seed=7)
    idx, val, valid = _sorted_stream(t, mode)
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.standard_normal((d, _RANK)), jnp.float32)
               for d in shape]
    in_modes = [w for w in range(len(shape)) if w != mode]
    frows = tuple(int(shape[w]) for w in in_modes)
    rows_cap = -(-shape[mode] // _TILE) * _TILE
    budget = _CHUNK_BLOCKS // 2 * planner.stream_chunk_bytes(
        _BLK, len(in_modes), (8,) * len(in_modes))
    out = []
    for ordering in ORDERINGS:
        got, stats = mttkrp_out_of_core(
            idx, val, valid, factors, mode=mode, rows_cap=rows_cap,
            blk=_BLK, tile_rows=_TILE, max_chunk_bytes=budget,
            ordering=ordering)
        if ordering == "none":
            i2, v2, m2 = idx, val, valid
        else:
            i2, v2, m2, _ = reorder_stream(
                idx, val, valid, mode=mode, ordering=ordering,
                tile_rows=_TILE)
        predicted = planner.predict_stream_traffic(
            i2, m2, mode=mode, rows_cap=rows_cap, blk=_BLK,
            tile_rows=_TILE, rank=_RANK, factor_rows=frows,
            max_chunk_bytes=budget, ordering=ordering)
        resident = kops.mttkrp_device_step(
            jnp.asarray(i2), jnp.asarray(v2), jnp.asarray(m2), factors,
            mode=mode, rows_cap=rows_cap, row_offset=0, blk=_BLK,
            tile_rows=_TILE, backend="pallas_fused_gather")
        out.append(row(
            "reorder_exec", ordering=ordering, nnz=stats.nnz,
            chunks=stats.chunks, window_tiles=list(stats.window_tiles),
            scheduled_tile_MB=round(stats.scheduled_tile_bytes / 2**20, 4),
            distinct_tile_MB=round(stats.distinct_tile_bytes / 2**20, 4),
            scheduled_over_distinct=round(stats.scheduled_over_distinct, 3),
            presort_scheduled_over_distinct=round(
                stats.presort_scheduled_over_distinct, 3),
            predicted_eq_counted=bool(
                predicted.scheduled_tile_bytes == stats.scheduled_tile_bytes
                and predicted.distinct_tile_bytes
                == stats.distinct_tile_bytes
                and predicted.window_tiles == stats.window_tiles
                and predicted.chunks == stats.chunks),
            bitexact_vs_resident=bool(
                np.array_equal(np.asarray(got), np.asarray(resident))),
            note="interpret-mode run; traffic counted, not timed"))
    return out


def run(quick: bool = True):
    nnz = 30000 if quick else 70000
    zipf = zipf_4d(_SHAPE, nnz, alpha=_ALPHA, seed=7)
    zipf_modes = (3,) if quick else (0, 3)
    rows = _traffic_rows("zipf_4d", zipf, zipf_modes, quick)
    enron = frostt_like("enron-skew", seed=0, scale=0.4 if quick else 0.6)
    rows += _traffic_rows("enron-skew", enron, (3,), quick)
    rows += _exec_rows(quick)
    write_bench_json("reorder", rows)
    return rows
