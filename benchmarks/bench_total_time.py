"""Paper Fig. 3/4 + Table III: total spMTTKRP time across all modes —
Dynasor layout vs. the baseline strategies the paper compares against.

Variants (single-device kernels; the distributed collective-traffic
comparison is in bench_remap_traffic + the dry-run):

* ``dynasor``     — FLYCOO owner-sorted stream → sorted segment-sum per
                    mode, tensor already in output-mode order (the dynamic
                    remap is amortized into the previous mode; its cost is
                    measured separately in Fig. 8/bench_remap_traffic).
* ``coo_scatter`` — plain COO scatter-add (`.at[].add`) — the "no layout"
                    baseline with random output-row writes.
* ``resort``      — re-sorts the whole tensor for every mode before a
                    sorted segment-sum — what a mode-agnostic format pays
                    without dynamic remapping (ALTO-style linearization
                    cost stand-in).
* ``stef_like``   — caches the per-nonzero partial Hadamard product from
                    the previous mode and reuses it (STeF's intermediate
                    saving), at (nnz × R) extra memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flycoo import build_flycoo, pack_mode
from repro.core.mttkrp import hadamard_rows, mttkrp, mttkrp_sorted

from .common import (BENCH_TENSORS, bench_tensor, row, timeit,
                     write_bench_json)


def _dynasor_all_modes(ft, rank, seed=0):
    t = ft.tensor
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in t.shape]
    packs = []
    for n in range(t.nmodes):
        order = np.argsort(t.indices[:, n], kind="stable")
        packs.append((jnp.asarray(t.indices[order]),
                      jnp.asarray(t.values[order])))

    @jax.jit
    def run():
        outs = []
        for n in range(t.nmodes):
            idx, val = packs[n]
            ell = hadamard_rows(idx, val, factors, n)
            outs.append(jax.ops.segment_sum(
                ell, idx[:, n], num_segments=t.shape[n],
                indices_are_sorted=True))
        return outs

    return run


def _coo_scatter_all_modes(t, rank, seed=0):
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in t.shape]
    idx = jnp.asarray(t.indices)
    val = jnp.asarray(t.values)

    @jax.jit
    def run():
        outs = []
        for n in range(t.nmodes):
            ell = hadamard_rows(idx, val, factors, n)
            out = jnp.zeros((t.shape[n], rank), jnp.float32)
            outs.append(out.at[idx[:, n]].add(ell))
        return outs

    return run


def _resort_all_modes(t, rank, seed=0):
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in t.shape]
    idx0 = jnp.asarray(t.indices)
    val0 = jnp.asarray(t.values)

    @jax.jit
    def run():
        outs = []
        for n in range(t.nmodes):
            order = jnp.argsort(idx0[:, n], stable=True)   # paid EVERY mode
            idx = jnp.take(idx0, order, axis=0)
            val = jnp.take(val0, order)
            ell = hadamard_rows(idx, val, factors, n)
            outs.append(jax.ops.segment_sum(
                ell, idx[:, n], num_segments=t.shape[n],
                indices_are_sorted=True))
        return outs

    return run


def _stef_like_all_modes(t, rank, seed=0):
    """3-mode only: mode 0 computes val·C[k]; mode 1 reuses it."""
    if t.nmodes != 3:
        return None
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in t.shape]
    idx = jnp.asarray(t.indices)
    val = jnp.asarray(t.values)

    @jax.jit
    def run():
        # mode 0: partial = val · C[k]; out0 = seg_i(partial ∘ B[j])
        partial = val[:, None] * jnp.take(factors[2], idx[:, 2], axis=0)
        out0 = jax.ops.segment_sum(
            partial * jnp.take(factors[1], idx[:, 1], axis=0), idx[:, 0],
            num_segments=t.shape[0])
        # mode 1 REUSES partial (STeF's saved intermediate)
        out1 = jax.ops.segment_sum(
            partial * jnp.take(factors[0], idx[:, 0], axis=0), idx[:, 1],
            num_segments=t.shape[1])
        # mode 2: no reusable partial → recompute
        ell = (val[:, None] * jnp.take(factors[0], idx[:, 0], axis=0)
               * jnp.take(factors[1], idx[:, 1], axis=0))
        out2 = jax.ops.segment_sum(ell, idx[:, 2], num_segments=t.shape[2])
        return out0, out1, out2

    return run


def run(quick: bool = True, ranks=(16, 64), scale: float = 1.0):
    rows = []
    tensors = BENCH_TENSORS[:3] if quick else BENCH_TENSORS
    for name in tensors:
        t = bench_tensor(name, scale=scale)
        ft = build_flycoo(t, num_workers=8)
        for rank in ranks:
            variants = {
                "dynasor": _dynasor_all_modes(ft, rank),
                "coo_scatter": _coo_scatter_all_modes(t, rank),
                "resort": _resort_all_modes(t, rank),
            }
            st = _stef_like_all_modes(t, rank)
            if st is not None:
                variants["stef_like"] = st
            times = {}
            for vname, fn in variants.items():
                times[vname] = timeit(fn, iters=3 if quick else 5)
            base = times["dynasor"]
            for vname, tt in times.items():
                rows.append(row("total_time_fig3", tensor=name, rank=rank,
                                variant=vname, seconds=round(tt, 5),
                                speedup_vs_dynasor=round(tt / base, 3)))
    write_bench_json("total_time", rows)
    return rows
