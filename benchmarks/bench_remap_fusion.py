"""Paper Fig. 2: integrated remap+compute vs. separated execution.

The paper found that performing dynamic tensor remapping in the SAME
thread as the elementwise computation beats dedicating separate threads.
The JAX analogue: one fused jit computing (MTTKRP, next-mode reorder)
together — XLA can interleave the sort with the gather/segment-sum streams
— vs. two sequential jits with a host sync between them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mttkrp import hadamard_rows

from .common import (BENCH_TENSORS, bench_tensor, row, timeit,
                     write_bench_json)


def _make(t, rank, mode=0, seed=0):
    """Mode ``mode`` compute + remap toward mode ``mode+1`` (cyclic) —
    fused-in-one-jit vs. two jits with a host sync. Works for any order N."""
    nxt = (mode + 1) % t.nmodes
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in t.shape]
    idx = jnp.asarray(t.indices[np.argsort(t.indices[:, mode], kind="stable")])
    val = jnp.asarray(t.values)

    @jax.jit
    def fused(idx, val):
        ell = hadamard_rows(idx, val, factors, mode)
        out = jax.ops.segment_sum(ell, idx[:, mode],
                                  num_segments=t.shape[mode],
                                  indices_are_sorted=True)
        order = jnp.argsort(idx[:, nxt], stable=True)   # remap for next mode
        return out, jnp.take(idx, order, axis=0), jnp.take(val, order)

    @jax.jit
    def compute_only(idx, val):
        ell = hadamard_rows(idx, val, factors, mode)
        return jax.ops.segment_sum(ell, idx[:, mode],
                                   num_segments=t.shape[mode],
                                   indices_are_sorted=True)

    @jax.jit
    def remap_only(idx, val):
        order = jnp.argsort(idx[:, nxt], stable=True)
        return jnp.take(idx, order, axis=0), jnp.take(val, order)

    def split(idx, val):
        out = compute_only(idx, val)
        jax.block_until_ready(out)          # host sync between the passes
        return out, remap_only(idx, val)

    return fused, split, (idx, val)


def run(quick: bool = True, rank: int = 32, scale: float = 1.0):
    rows = []
    # enron is covered by the dedicated per-mode-transition loop below.
    tensors = BENCH_TENSORS[:3] if quick else tuple(
        n for n in BENCH_TENSORS if n != "enron")
    for name in tensors:
        t = bench_tensor(name, scale=scale)
        fused, split, args = _make(t, rank)
        t_fused = timeit(fused, *args)
        t_split = timeit(split, *args)
        rows.append(row("remap_fusion_fig2", tensor=name, rank=rank,
                        fused_s=round(t_fused, 5),
                        split_s=round(t_split, 5),
                        speedup=round(t_split / t_fused, 3)))
    # N-mode coverage: the full remap cycle of the 4-mode tensor — every
    # mode transition of the ALS sweep, not just 0 -> 1.
    t = bench_tensor("enron", scale=0.25 if quick else scale)
    for mode in range(t.nmodes):
        fused, split, args = _make(t, rank, mode=mode)
        t_fused = timeit(fused, *args)
        t_split = timeit(split, *args)
        rows.append(row("remap_fusion_fig2", tensor="enron",
                        mode=f"{mode}->{(mode + 1) % t.nmodes}", rank=rank,
                        fused_s=round(t_fused, 5),
                        split_s=round(t_split, 5),
                        speedup=round(t_split / t_fused, 3)))
    write_bench_json("remap_fusion", rows)
    return rows
