"""Paper Fig. 6: LPT super-shard scheduling vs. block-cyclic.

On a 1-core container parallel wall-clock is not observable, so we report
the *load-imbalance factor* (max thread load / mean load) — the exact
quantity the paper's speedup bound (Graham 4/3) is about: modeled parallel
time = imbalance × ideal time.
"""
from __future__ import annotations

import numpy as np

from repro.core.flycoo import build_flycoo
from repro.core.schedule import (block_cyclic_schedule, load_imbalance,
                                 lpt_schedule)

from .common import BENCH_TENSORS, bench_tensor, row, write_bench_json


def run(quick: bool = True, workers: int = 56, scale: float = 0.25):
    rows = []
    tensors = BENCH_TENSORS if not quick else BENCH_TENSORS[:4]
    for name in tensors:
        t = bench_tensor(name, scale=scale)
        ft = build_flycoo(t, num_workers=workers)
        for n, mp in enumerate(ft.modes):
            sizes = mp.shard_counts
            lpt = load_imbalance(sizes, lpt_schedule(sizes, workers),
                                 workers)
            cyc = load_imbalance(
                sizes, block_cyclic_schedule(len(sizes), workers), workers)
            rows.append(row("schedule_fig6", tensor=name, mode=n,
                            workers=workers,
                            lpt_imbalance=round(lpt, 4),
                            cyclic_imbalance=round(cyc, 4),
                            modeled_speedup=round(cyc / lpt, 3)))
    write_bench_json("schedule", rows)
    return rows
