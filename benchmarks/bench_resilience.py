"""Resilience overhead: what the safety net costs when nothing fails.

The PR-9 layer must be effectively free on the happy path and bounded
under faults. Four sections, all on a small fixed workload:

  * ``drivers`` — fault-free wall time of the fused one-jit sweep vs
    the stepped driver (what an active policy forces) vs stepped +
    per-sweep checkpointing: the cost of host-side call boundaries and
    of atomic persistence, as ratios over the fused baseline;
  * ``chaos`` — the same stepped run under a seeded fault schedule:
    wall-time ratio vs the fault-free stepped run plus the counted
    recovery story (injected / retries / degradations) — re-traces are
    the dominant cost, so the ratio bounds "what does a fault cost";
  * ``solve_guard`` — ``guarded_solve`` vs the plain
    ``linalg.solve`` it replaced, jitted, healthy input (the clean
    branch must not pay for the SVD floor it guards);
  * ``checkpoint`` — save/restore latency and on-disk bytes of one
    sweep state (factors + λ + fits + the packed stream).

Everything lands in ``BENCH_resilience.json``.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import distributed as dist
from repro.core.cpals import cp_als_distributed
from repro.core.flycoo import build_flycoo
from repro.core.tensors import random_sparse_tensor
from repro.obs import counters as _obs
from repro.resilience import (
    RetryPolicy,
    guarded_solve,
    inject,
    seeded_schedule,
)
from repro.resilience import checkpoint as rckpt

from .common import row, timeit, write_bench_json

_SHAPE, _NNZ, _RANK = (40, 30, 20), 350, 8


def _workload():
    t = random_sparse_tensor(_SHAPE, _NNZ, seed=0, distribution="powerlaw")
    ft = build_flycoo(t, 1, m_bounds=(2, 8), g_bounds=(8, 64))
    mesh = Mesh(np.array(jax.devices()[:1]), (dist.AXIS,))
    return ft, mesh


def _wall(fn) -> tuple[float, object]:
    jax.clear_caches()          # include re-trace cost: that IS the story
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def _driver_rows(ft, mesh, iters: int) -> list[dict]:
    def fused():
        return cp_als_distributed(ft, _RANK, mesh, iters=iters, seed=0,
                                  tol=0.0, backend="auto")

    def stepped():
        return cp_als_distributed(ft, _RANK, mesh, iters=iters, seed=0,
                                  tol=0.0, backend="auto",
                                  resilience=RetryPolicy())

    rows = []
    with _obs.use_registry():
        base_s, base = _wall(fused)
    rows.append(row("resilience", section="drivers", driver="fused",
                    iters=iters, wall_s=round(base_s, 3),
                    fit=round(base.fit, 6), ratio=1.0))
    with _obs.use_registry():
        step_s, step = _wall(stepped)
    rows.append(row("resilience", section="drivers", driver="stepped_policy",
                    iters=iters, wall_s=round(step_s, 3),
                    fit=round(step.fit, 6),
                    ratio=round(step_s / base_s, 2)))
    with tempfile.TemporaryDirectory() as d:
        with _obs.use_registry() as reg:
            ck_s, ck = _wall(lambda: cp_als_distributed(
                ft, _RANK, mesh, iters=iters, seed=0, tol=0.0,
                backend="auto", resilience=RetryPolicy(),
                checkpoint_dir=d))
            saves = int(reg.get("resilience.checkpoint.saves"))
        disk = sum(os.path.getsize(os.path.join(r, f))
                   for r, _, fs in os.walk(d) for f in fs)
    rows.append(row("resilience", section="drivers",
                    driver="stepped_policy_ckpt", iters=iters,
                    wall_s=round(ck_s, 3), fit=round(ck.fit, 6),
                    ratio=round(ck_s / base_s, 2), ckpt_saves=saves,
                    ckpt_disk_bytes=disk))

    # chaos: same stepped run, seeded faults at the in-sweep sites.
    specs = seeded_schedule(7, sites=("ops.kernel", "distributed.remap"),
                            per_site=1, horizon=2)
    with _obs.use_registry() as reg, inject(specs) as inj:
        chaos_s, chaos = _wall(stepped)
        snap = reg.snapshot()
    rows.append(row(
        "resilience", section="chaos", iters=iters,
        wall_s=round(chaos_s, 3), ratio_vs_stepped=round(chaos_s / step_s, 2),
        fit_drift=round(abs(chaos.fit - base.fit), 8),
        injected=len(inj.injected), pending=len(inj.pending()),
        retries=int(sum(v for k, v in snap.items()
                        if k.startswith("resilience.retries"))),
        degradations=int(sum(v for k, v in snap.items()
                             if k.startswith("resilience.degradations"))),
        interpret_fallbacks=int(sum(
            v for k, v in snap.items()
            if k.startswith("resilience.interpret_fallbacks")))))
    return rows


def _solve_guard_rows(rank: int) -> list[dict]:
    rng = np.random.default_rng(0)
    A = rng.standard_normal((rank + 4, rank)).astype(np.float32)
    V = jnp.asarray(A.T @ A + np.eye(rank, dtype=np.float32))
    M = jnp.asarray(rng.standard_normal((256, rank)).astype(np.float32))
    eye = jnp.eye(rank, dtype=jnp.float32)

    plain = jax.jit(lambda V, M: jnp.linalg.solve(V + 1e-9 * eye, M.T).T)
    guarded = jax.jit(guarded_solve)
    plain_s = timeit(plain, V, M, warmup=2, iters=5)
    guard_s = timeit(guarded, V, M, warmup=2, iters=5)
    X, level = guarded(V, M)
    return [row("resilience", section="solve_guard", rank=rank,
                plain_us=round(plain_s * 1e6, 1),
                guarded_us=round(guard_s * 1e6, 1),
                ratio=round(guard_s / max(plain_s, 1e-9), 2),
                level=int(level))]


def _checkpoint_rows(ft, mesh) -> list[dict]:
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, _RANK)).astype(np.float32)
               for d in _SHAPE]
    lam = np.ones(_RANK, np.float32)
    nnz_cap = ft.nnz
    stream = (rng.integers(0, 16, size=(1, nnz_cap, 3)).astype(np.int32),
              rng.standard_normal((1, nnz_cap)).astype(np.float32),
              np.ones((1, nnz_cap), bool))
    state = rckpt.make_state(factors, lam, [0.9], sweep=0, rank=_RANK,
                             backend="auto", stream=stream)
    with tempfile.TemporaryDirectory() as d, _obs.use_registry():
        mgr = rckpt.make_manager(d)
        t0 = time.perf_counter()
        rckpt.save_state(mgr, state)
        save_s = time.perf_counter() - t0
        disk = sum(os.path.getsize(os.path.join(r, f))
                   for r, _, fs in os.walk(d) for f in fs)
        t0 = time.perf_counter()
        restored, sweep = rckpt.restore_state(mgr, state)
        restore_s = time.perf_counter() - t0
        assert sweep == 0 and restored is not None
    return [row("resilience", section="checkpoint", nnz=nnz_cap,
                save_ms=round(save_s * 1e3, 2),
                restore_ms=round(restore_s * 1e3, 2),
                disk_bytes=disk)]


def run(quick: bool = True) -> list[dict]:
    ft, mesh = _workload()
    iters = 2 if quick else 5
    rows = _driver_rows(ft, mesh, iters)
    rows += _solve_guard_rows(_RANK if quick else 32)
    rows += _checkpoint_rows(ft, mesh)
    write_bench_json("resilience", rows)
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
