"""Out-of-core spMTTKRP: counted DMA traffic of the streaming executor.

Two sections, both *counted* (interpret-mode wall time cannot show DMA
overlap; the byte counts are exact):

  * ``oocore_stream`` — per mode of a 4-mode tensor whose factor
    dimensions overflow whole/slab VMEM residency at the chosen budget:
    the chunked streaming executor's tile-fetch bytes (scheduled /
    distinct / pipelined — see ``repro.oocore.executor.StreamStats``),
    the index-stream bytes, the chunk count a small working-set budget
    forces, and a bit-exactness check against the factor-resident
    gather backend (interpret mode can always run it, even when a real
    VMEM budget could not).
  * ``residency_ladder`` — the ``repro.oocore.planner`` decision swept
    across VMEM budgets for one dispatch shape: the budget bands where
    whole residency, slab residency, the streamed window, and the
    materializing fused family win, with the resident bytes of each.

Everything lands in ``BENCH_oocore.json``.
"""
from __future__ import annotations

import numpy as np

from repro.core.tensors import random_sparse_tensor
from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import ops as kops
from repro.oocore import planner
from repro.oocore.executor import mttkrp_out_of_core

from .common import row, write_bench_json

# Factor dims with hundreds of row tiles: whole/slab residency is MiB-
# to-GiB scale while the bounded stream window stays a few MiB.
_SHAPE = (20000, 9000, 4000, 50)
_BLK, _TILE = 32, 8


def _stream_rows(quick: bool) -> list[dict]:
    import jax.numpy as jnp

    rank = 128 if quick else 256
    nnz = 500 if quick else 2000
    rng = np.random.default_rng(0)
    t = random_sparse_tensor(_SHAPE, nnz, seed=1, distribution="powerlaw")
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in _SHAPE]
    out = []
    modes = (0, 3) if quick else range(len(_SHAPE))
    for mode in modes:
        order = np.argsort(t.indices[:, mode], kind="stable")
        idx = t.indices[order].astype(np.int32)
        val = t.values[order].astype(np.float32)
        valid = np.ones(len(val), bool)
        rows_cap = -(-_SHAPE[mode] // _TILE) * _TILE
        resident = kops.mttkrp_device_step(
            jnp.asarray(idx), jnp.asarray(val), jnp.asarray(valid), factors,
            mode=mode, rows_cap=rows_cap, row_offset=0, blk=_BLK,
            tile_rows=_TILE, backend="pallas_fused_gather")
        got, stats = mttkrp_out_of_core(
            idx, val, valid, factors, mode=mode, rows_cap=rows_cap,
            blk=_BLK, tile_rows=_TILE, max_chunk_bytes=4096)
        in_rows = [d for w, d in enumerate(_SHAPE) if w != mode]
        out.append(row(
            "oocore_stream", nmodes=len(_SHAPE), mode=mode, rank=rank,
            nnz=stats.nnz, blk=_BLK, tile_rows=_TILE,
            chunks=stats.chunks, rank_slabs=stats.rank_slabs,
            window_tiles=list(stats.window_tiles),
            window_vmem_MB=round(stats.window_vmem_bytes / 2**20, 3),
            resident_equiv_vmem_MB=round(
                stats.resident_equiv_vmem_bytes / 2**20, 3),
            scheduled_tile_MB=round(stats.scheduled_tile_bytes / 2**20, 3),
            distinct_tile_MB=round(stats.distinct_tile_bytes / 2**20, 3),
            pipelined_tile_MB=round(stats.pipelined_tile_bytes / 2**20, 3),
            tile_B_per_nnz=round(stats.tile_bytes_per_nnz, 1),
            index_stream_B_per_nnz=round(stats.index_bytes_per_nnz, 1),
            fused_operand_B_per_nnz=(len(_SHAPE) - 1)
            * kops.padded_rank(rank) * 4,
            static_backend=kops.select_backend(
                "auto", nmodes=len(_SHAPE), rank=rank, blk=_BLK,
                tile_rows=_TILE, factor_rows=tuple(in_rows)),
            bitexact_vs_resident=bool(
                np.array_equal(np.asarray(got), np.asarray(resident))),
            note="interpret-mode run; traffic is counted, not timed"))
    return out


def _residency_ladder_rows() -> list[dict]:
    """Planner decision vs budget: the whole→slab→stream→fused bands."""
    nmodes, rank, blk, tile_rows = 4, 256, 32, 8
    in_rows = tuple(d for d in _SHAPE[1:])
    rpad = kops.padded_rank(rank)
    k = nmodes - 1
    windows = tuple(planner.stream_window_tiles(blk, r) for r in in_rows)
    anchors = dict(
        whole=kkernel.gather_vmem_bytes(k, rpad, blk, tile_rows,
                                        sum(in_rows)),
        slab=kkernel.gather_tiled_vmem_bytes(k, rpad, blk, tile_rows,
                                             sum(in_rows)),
        stream=kkernel.gather_stream_vmem_bytes(k, rpad, blk, tile_rows,
                                                windows),
        fused=kkernel.fused_vmem_bytes(k, rpad, blk, tile_rows),
    )
    out = []
    for label, budget in [
        ("above_whole", anchors["whole"] + 1),
        ("at_slab", anchors["slab"]),
        ("at_stream_window", anchors["stream"]),
        ("below_stream_window", anchors["stream"] - 1),
        ("at_fused", anchors["fused"]),
    ]:
        plan = planner.plan_residency(
            nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
            factor_rows=in_rows, vmem_budget=budget)
        out.append(row(
            "residency_ladder", label=label, nmodes=nmodes, rank=rank,
            blk=blk, tile_rows=tile_rows, vmem_budget_MB=round(
                budget / 2**20, 3),
            backend=plan.backend, plan_vmem_MB=round(
                plan.vmem_bytes / 2**20, 3),
            rank_slabs=plan.rank_slabs,
            window_tiles=list(plan.window_tiles),
            policies=[f.policy for f in plan.factors]))
    out.append(row(
        "residency_ladder_anchors",
        **{f"{k_}_MB": round(v / 2**20, 3) for k_, v in anchors.items()}))
    return out


def run(quick: bool = True):
    rows = _stream_rows(quick) + _residency_ladder_rows()
    write_bench_json("oocore", rows)
    return rows
