"""Profiler suite: timed device steps + achieved-bandwidth roofline.

Unlike the counted suites, every number here is **timed** on this host
(via ``repro.obs.prof.harness`` — fenced steady-state repeats, robust
median) and therefore host-local: the artifact records the environment
fingerprint and noise score next to the numbers, and nothing in CI
diffs them (the noise-aware gate lives in ``python -m repro.obs.prof
gate``; this suite is the figure-style sweep).

Two sections:

  * ``prof_step`` — :func:`repro.kernels.mttkrp.ops.timed_device_step`
    per backend on one microbench grid point: median wall seconds,
    spread, the counted first-order traffic model
    (``ops.step.model_bytes``) and the model-achieved GB/s — the
    roofline coordinate per kernel backend.
  * ``prof_stream`` — one chunked out-of-core mode step per ordering
    policy under an enabled tracer: the ``oocore.mode_step`` span's
    measured time joined with its counted ``self_counters`` bytes by
    ``repro.obs.prof.roofline.bandwidth_rows`` — per-rung achieved GB/s
    exactly as ``python -m repro.obs.prof run`` computes it.

Everything lands in ``BENCH_prof.json`` (host-local, not committed).
"""
from __future__ import annotations

import numpy as np

from repro.obs import counters as _obs
from repro.obs import tracer as _tracer_mod
from repro.obs.prof import bandwidth_rows, env_fingerprint, measure_steady
from repro.tune.microbench import GridPoint, make_case

from .common import row, write_bench_json

_POINT = GridPoint(nmodes=3, rank=32, blk=32, tile_rows=8, density=1.0)
# One member per residency family — quick enough under interpret mode.
_BACKENDS = ("ref", "pallas_fused_gather", "pallas_fused", "pallas")


def _step_rows(quick: bool) -> list[dict]:
    from repro.kernels.mttkrp import ops as kops

    backends = [b for b in _BACKENDS if b in kops.BACKENDS]
    repeats = 3 if quick else 5
    idx, val, valid, factors, rows_cap = make_case(_POINT, seed=0)
    model_b = kops.step_traffic_bytes(
        cap=int(idx.shape[0]), nmodes=_POINT.nmodes, rank=_POINT.rank,
        rows_cap=rows_cap)
    fp = env_fingerprint()
    out = []
    for backend in backends:
        with _obs.use_registry(), _tracer_mod.use_tracer() as tracer:
            stats = measure_steady(
                lambda: kops.timed_device_step(
                    idx, val, valid, factors, mode=0, rows_cap=rows_cap,
                    row_offset=0, blk=_POINT.blk, tile_rows=_POINT.tile_rows,
                    backend=backend),
                warmup=1, repeats=repeats, block=None)  # wrapper self-fences
        out.append(row(
            "prof_step", backend=backend, nmodes=_POINT.nmodes,
            rank=_POINT.rank, blk=_POINT.blk, tile_rows=_POINT.tile_rows,
            median_s=round(stats.median_s, 6),
            mad_frac=round(stats.mad_frac, 4),
            rejected=stats.rejected,
            model_bytes=model_b,
            model_gbps=round(model_b / max(stats.median_s, 1e-12) / 1e9, 4),
            spans=len(tracer.records),
            devices=fp.get("devices"),
        ))
    return out


def _stream_rows(quick: bool) -> list[dict]:
    import jax.numpy as jnp

    from repro.oocore.executor import mttkrp_out_of_core

    shape = (20000, 40, 9000, 30)
    blk, tile_rows, rank = 32, 8, 128 if quick else 256
    from repro.core.tensors import random_sparse_tensor
    t = random_sparse_tensor(shape, 600, seed=3, distribution="powerlaw")
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in shape]
    mode = 1
    order = np.argsort(t.indices[:, mode], kind="stable")
    idx = t.indices[order].astype(np.int32)
    val = t.values[order].astype(np.float32)
    valid = np.ones(len(val), bool)
    rows_cap = -(-shape[mode] // tile_rows) * tile_rows
    out = []
    for ordering in ("none", "tile", "morton"):
        with _obs.use_registry(), _tracer_mod.use_tracer() as tracer:
            mttkrp_out_of_core(
                idx, val, valid, factors, mode=mode, rows_cap=rows_cap,
                blk=blk, tile_rows=tile_rows, max_chunk_bytes=2000,
                ordering=ordering)
            rows = bandwidth_rows(tracer.records)
        for r in rows:
            out.append(row(
                "prof_stream", span=r["span"], backend=r["backend"],
                rung=r["rung"], ordering=ordering, calls=r["calls"],
                time_s=round(r["time_s"], 6),
                moved_bytes=r["moved_bytes"], basis=r["basis"],
                achieved_gbps=round(r["achieved_gbps"], 4),
            ))
    return out


def run(quick: bool = True) -> list[dict]:
    rows = _step_rows(quick) + _stream_rows(quick)
    write_bench_json("prof", rows)
    return rows
