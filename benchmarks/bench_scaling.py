"""Paper Fig. 7: scalability with worker count (R = 16).

Reported metric: modeled parallel efficiency = mean-load / max-load under
the LPT schedule as workers grow 1 → 64 (the paper's measured 8.5–21×
speedup at 56 threads is bounded by exactly this quantity times the
memory-bandwidth ceiling; wall-clock parallelism is not observable on one
core)."""
from __future__ import annotations

import numpy as np

from repro.core.flycoo import build_flycoo
from repro.core.schedule import load_imbalance, lpt_schedule

from .common import BENCH_TENSORS, bench_tensor, row, write_bench_json


def run(quick: bool = True, scale: float = 0.25):
    rows = []
    tensors = BENCH_TENSORS[:3] if quick else BENCH_TENSORS
    for name in tensors:
        t = bench_tensor(name, scale=scale)
        for workers in (1, 2, 4, 8, 16, 32, 56, 64):
            ft = build_flycoo(t, num_workers=workers)
            worst = max(
                load_imbalance(mp.shard_counts,
                               lpt_schedule(mp.shard_counts, workers),
                               workers)
                for mp in ft.modes)
            rows.append(row("scaling_fig7", tensor=name, workers=workers,
                            worst_mode_imbalance=round(worst, 4),
                            modeled_speedup=round(workers / worst, 2)))
    write_bench_json("scaling", rows)
    return rows
