"""Compiled-path lowering status: every backend × geometry, as data.

Not a timing benchmark — a *capability* artifact. Each row records
whether one ``(backend, geometry)`` point lowers to Mosaic with
``interpret=False`` (the AOT ``trace().lower(lowering_platforms=
("tpu",))`` path, CPU-only, no execution) plus the lowering wall time.
``BENCH_lowering.json`` is the checked-in evidence behind the
"lowers (Mosaic)" column of ``docs/kernels.md``'s backend matrix —
``tests/check_docs.py`` syncs the column against this file, so the
docs can only claim what a sweep actually demonstrated.

Quick = the CI smoke grid (3 geometries/backend); ``--full`` = the
slow 7-geometry grid from ``repro.kernels.mttkrp.lowering``.
"""
from __future__ import annotations

from repro.kernels.mttkrp import lowering as klow

from .common import row, write_bench_json


def run(quick: bool = True) -> list[dict]:
    geometries = klow.SMOKE_GEOMETRIES if quick else klow.FULL_GEOMETRIES
    results = klow.run(geometries)
    rows = [row("lowering", grid="smoke" if quick else "full", **r.row())
            for r in results]
    n_ok = sum(r.ok for r in results)
    rows.append(row("lowering_summary",
                    grid="smoke" if quick else "full",
                    points=len(results), lowered_ok=n_ok,
                    backends=len(set(r.backend for r in results)),
                    all_backends_lower=all(r.ok for r in results)))
    write_bench_json("lowering", rows)
    return rows
