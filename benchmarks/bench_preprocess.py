"""Paper Fig. 12: preprocessing (FLYCOO format generation) time.

Stages timed separately, as in §V-J: (1) super-shard generation per mode,
(2) ordering, (3) shard metadata. Compared against the cost of a plain
per-mode sort (the mode-specific-format preprocessing floor).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.flycoo import build_flycoo

from .common import BENCH_TENSORS, bench_tensor, row, write_bench_json


def run(quick: bool = True, scale: float = 0.25):
    rows = []
    tensors = BENCH_TENSORS if not quick else BENCH_TENSORS[:4]
    for name in tensors:
        t = bench_tensor(name, scale=scale)
        t0 = time.perf_counter()
        ft = build_flycoo(t, num_workers=8)
        t_flycoo = time.perf_counter() - t0

        t0 = time.perf_counter()
        for n in range(t.nmodes):
            np.argsort(t.indices[:, n], kind="stable")
        t_sorts = time.perf_counter() - t0

        rows.append(row("preprocess_fig12", tensor=name, nnz=t.nnz,
                        flycoo_s=round(t_flycoo, 4),
                        per_mode_sort_s=round(t_sorts, 4),
                        ratio=round(t_flycoo / max(t_sorts, 1e-9), 2)))
    write_bench_json("preprocess", rows)
    return rows
