"""Paper §IV claim, measured on compiled programs: Dynasor's owner-computes
layout eliminates the dense intermediate-value reduction.

We compile BOTH distributed spMTTKRP programs for 8 workers and parse the
collective ops out of the optimized HLO:

* baseline (nonzero-parallel, ALTO/HiCOO traffic): every mode all-reduces a
  dense (I_pad × R) partial from every worker — the "intermediate values"
  the paper talks about;
* Dynasor: owned output rows are all-gathered once (each row moves once),
  plus the capacity-padded all_to_all of the dynamic remap.

Reported with ring-cost weights (all-reduce moves ≈2× its payload on a
ring; gather/scatter/a2a ≈1×). Runs in a subprocess so the 8-device XLA
flag never leaks into the bench process.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import row, write_bench_json

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core import distributed as dist
from repro.core.flycoo import build_flycoo
from repro.core.tensors import frostt_like
from repro.launch.hlo_analysis import collective_bytes

out = {}
for name in %TENSORS%:
    t = frostt_like(name, scale=0.25)
    ft = build_flycoo(t, 8)
    rt, (idx, val, mask) = dist.prepare_runtime(ft, rank=%RANK%)
    mesh = Mesh(np.array(jax.devices()), (dist.AXIS,))
    factors = dist.init_factors(ft, rt, seed=0)
    sds = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
    res = {}
    for label, maker, pack in (
        ("dynasor", lambda: dist.make_spmttkrp_all_modes(rt, mesh), (idx, val, mask)),
        ("baseline_allreduce", lambda: dist.make_baseline_all_modes(rt, mesh),
         dist.even_split_pack(ft, rt)),
    ):
        import jax.numpy as jnp
        fn = maker()
        compiled = jax.jit(fn).lower(
            *[sds(np.asarray(x)) for x in pack],
            *[sds(np.asarray(f)) for f in factors]).compile()
        cb = collective_bytes(compiled.as_text())
        kinds = cb["bytes_by_kind"]
        weighted = sum(v * (2.0 if k == "all-reduce" else 1.0)
                       for k, v in kinds.items())
        res[label] = {"by_kind": kinds, "weighted_bytes": weighted}
    out[name] = res
print("JSON" + json.dumps(out))
"""


def run(quick: bool = True, rank: int = 64):
    tensors = ["nell-2", "flickr"] if quick else [
        "nell-2", "nell-1", "flickr", "delicious", "vast"]
    script = _SCRIPT.replace("%TENSORS%", repr(tensors)).replace(
        "%RANK%", str(rank))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1800)
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("JSON"):
            data = json.loads(line[4:])
            for tensor, res in data.items():
                dyn = res["dynasor"]["weighted_bytes"]
                base = res["baseline_allreduce"]["weighted_bytes"]
                rows.append(row(
                    "collective_traffic", tensor=tensor, rank=rank,
                    dynasor_MB=round(dyn / 1e6, 2),
                    baseline_MB=round(base / 1e6, 2),
                    traffic_ratio=round(base / max(dyn, 1), 2),
                    dynasor_kinds=str(res["dynasor"]["by_kind"]),
                    baseline_kinds=str(
                        res["baseline_allreduce"]["by_kind"])))
    if not rows:
        rows = [row("collective_traffic", status="error",
                    stderr=proc.stderr[-300:])]
    write_bench_json("collective_traffic", rows)
    return rows
