"""Dispatch quality: `auto` (static) vs. `auto` (calibrated) vs. oracle.

For every dispatch key of a calibration table (an existing table under
``experiments/tune/`` if present, else a quick in-process calibration),
three decisions are compared:

  * ``static``     — ``select_backend("auto", ...)`` with no table (the
                     VMEM-model + rank<8 rule);
  * ``calibrated`` — the same call with ``table=`` (measured argmin,
                     interpolated by the ``repro.tune`` cost model);
  * ``oracle``     — the measured-best backend from the table itself.

``regret_ms`` is the measured time lost by each policy vs. the oracle.
A second section records the per-transition vs. uniform remap-exchange
allocation on a skewed 4-mode tensor (the ``DynasorRuntime.bucket_caps``
win). Everything lands in ``BENCH_dispatch.json``.
"""
from __future__ import annotations

from repro.core.flycoo import build_flycoo
from repro.tune import microbench
from repro.tune.model import compare_dispatch
from repro.tune.table import find_table

from .common import bench_tensor, exchange_sizing, row, write_bench_json

_WORKERS = 8


def _dispatch_rows(table) -> list[dict]:
    rows = []
    agree_static = agree_cal = 0
    keys = table.shape_keys()
    for key in keys:
        nmodes, rank, blk, tile_rows = key
        cmp = compare_dispatch(table, key)
        agg, oracle = cmp["agg"], cmp["oracle"]
        agree_static += cmp["static"] == oracle
        agree_cal += cmp["calibrated"] == oracle

        def regret(choice):
            # a policy's choice may be un-timed (table calibrated on a
            # backend subset) — regret is then unknowable, not a crash
            if choice not in agg or oracle not in agg:
                return None
            return round((agg[choice] - agg[oracle]) * 1e3, 3)

        rows.append(row(
            "dispatch", nmodes=nmodes, rank=rank, blk=blk,
            tile_rows=tile_rows, static=cmp["static"],
            calibrated=cmp["calibrated"], oracle=oracle,
            static_regret_ms=regret(cmp["static"]),
            calibrated_regret_ms=regret(cmp["calibrated"]),
        ))
    if keys:
        rows.append(row(
            "dispatch_summary", keys=len(keys),
            static_oracle_agreement=round(agree_static / len(keys), 3),
            calibrated_oracle_agreement=round(agree_cal / len(keys), 3),
            note="interpret-mode timings on CPU; re-calibrate on TPU"))
    return rows


def _remap_savings_rows(scale: float) -> list[dict]:
    """Per-transition vs. uniform exchange allocation on a skewed tensor."""
    rows = []
    for name in ("enron-skew", "enron"):
        t = bench_tensor(name, scale=scale)
        ft = build_flycoo(t, num_workers=_WORKERS)
        sizing = exchange_sizing(ft, _WORKERS)
        rows.append(row(
            "remap_exchange_sizing", tensor=name, nnz=t.nnz,
            transition_caps=sizing["caps"],
            uniform_cap=max(sizing["caps"]),
            alltoall_uniform_MB=round(sizing["uniform_bytes"] / 1e6, 3),
            alltoall_pertransition_MB=round(
                sizing["per_transition_bytes"] / 1e6, 3),
            pertransition_savings_frac=round(sizing["savings_frac"], 4)))
    return rows


def run(quick: bool = True, scale: float = 0.25):
    table = find_table()
    if table is None or not table.entries:
        table = microbench.calibrate(quick=True)
    rows = _dispatch_rows(table) + _remap_savings_rows(scale)
    write_bench_json("dispatch", rows)
    return rows
