"""Dispatch quality: `auto` (static) vs. `auto` (calibrated) vs. oracle.

For every dispatch key of a calibration table (an existing table under
``experiments/tune/`` if present, else a quick in-process calibration),
three decisions are compared:

  * ``static``     — ``select_backend("auto", ...)`` with no table (the
                     VMEM-model + rank<8 rule);
  * ``calibrated`` — the same call with ``table=`` (measured argmin,
                     interpolated by the ``repro.tune`` cost model);
  * ``oracle``     — the measured-best backend from the table itself.

``regret_ms`` is the measured time lost by each policy vs. the oracle
(oracle = measured argmin over the numerics-preserving ``AUTO_BACKENDS``;
a ``bf16_measured_ms`` column records what explicit bf16 opt-in would
buy at each key). A second section records the per-transition vs.
uniform remap-exchange allocation on a skewed 4-mode tensor (the
``DynasorRuntime.bucket_caps`` win), a third (``rank_cliff``) the
static-dispatch record of the removed large-R fallback: configs the
PR-2 rule sent to the HBM-materialized path on VMEM grounds that the
rank-tiled kernel now keeps fused, and a fourth (``gather_traffic``)
the PR-4 in-kernel-gather record: counted per-nonzero HBM operand
bytes — ``(N−1)·4`` B of indices for the gather family vs
``(N−1)·R̂·4`` B of HBM-materialized gathered rows for the older fused
path — next to the static decision with factor-size knowledge.
Everything lands in ``BENCH_dispatch.json``.
"""
from __future__ import annotations

from repro.core.flycoo import build_flycoo
from repro.kernels.mttkrp import kernel as kkernel
from repro.kernels.mttkrp import ops as kops
from repro.tune import microbench
from repro.tune.model import compare_dispatch
from repro.tune.table import find_table

from .common import (bench_tensor, exchange_sizing, pr2_static_backend,
                     row, write_bench_json)

_WORKERS = 8


def _dispatch_rows(table) -> list[dict]:
    rows = []
    agree_static = agree_cal = 0
    keys = table.shape_keys()
    for key in keys:
        nmodes, rank, blk, tile_rows = key
        cmp = compare_dispatch(table, key)
        agg, oracle = cmp["agg"], cmp["oracle"]
        agree_static += cmp["static"] == oracle
        agree_cal += cmp["calibrated"] == oracle

        def regret(choice):
            # a policy's choice may be un-timed (table calibrated on a
            # backend subset) — regret is then unknowable, not a crash
            if choice not in agg or oracle not in agg:
                return None
            return round((agg[choice] - agg[oracle]) * 1e3, 3)

        bf16_ms = agg.get("pallas_fused_bf16")
        rows.append(row(
            "dispatch", nmodes=nmodes, rank=rank, blk=blk,
            tile_rows=tile_rows, static=cmp["static"],
            calibrated=cmp["calibrated"], oracle=oracle,
            static_regret_ms=regret(cmp["static"]),
            calibrated_regret_ms=regret(cmp["calibrated"]),
            bf16_measured_ms=(None if bf16_ms is None
                              else round(bf16_ms * 1e3, 3)),
        ))
    if keys:
        rows.append(row(
            "dispatch_summary", keys=len(keys),
            static_oracle_agreement=round(agree_static / len(keys), 3),
            calibrated_oracle_agreement=round(agree_cal / len(keys), 3),
            note="interpret-mode timings on CPU; re-calibrate on TPU"))
    return rows


def _remap_savings_rows(scale: float) -> list[dict]:
    """Per-transition vs. uniform exchange allocation on a skewed tensor."""
    rows = []
    for name in ("enron-skew", "enron"):
        t = bench_tensor(name, scale=scale)
        ft = build_flycoo(t, num_workers=_WORKERS)
        sizing = exchange_sizing(ft, _WORKERS)
        rows.append(row(
            "remap_exchange_sizing", tensor=name, nnz=t.nnz,
            transition_caps=sizing["caps"],
            uniform_cap=max(sizing["caps"]),
            alltoall_uniform_MB=round(sizing["uniform_bytes"] / 1e6, 3),
            alltoall_pertransition_MB=round(
                sizing["per_transition_bytes"] / 1e6, 3),
            pertransition_savings_frac=round(sizing["savings_frac"], 4)))
    return rows


def _rank_cliff_rows() -> list[dict]:
    """Static-dispatch record of the removed large-R VMEM cliff.

    Pure decision arithmetic (no timing): for shard-sized blocks and
    growing rank, what the PR-2 static rule chose (fused iff the full
    padded-rank working set fits, else materialized) vs. what
    ``select_backend`` chooses now that the rank-tiled kernel exists.
    ``contrib_traffic_MB`` is the per-mode HBM contrib write+read the
    materialized fallback pays and the fused family avoids — the cost of
    the cliff, per 1M nonzeros.
    """
    rows = []
    for nmodes, rank, blk in [
        (4, 1024, 2048), (4, 4096, 2048),
        (5, 1024, 2048), (5, 2048, 2048), (5, 4096, 2048),
        (5, 8192, 512),
    ]:
        tile_rows = 128
        now = kops.select_backend("auto", nmodes=nmodes, rank=rank,
                                  blk=blk, tile_rows=tile_rows)
        pr2 = pr2_static_backend(nmodes, rank, blk, tile_rows)
        rows.append(row(
            "rank_cliff", nmodes=nmodes, rank=rank, blk=blk,
            tile_rows=tile_rows,
            fused_vmem_MB=round(kkernel.fused_vmem_bytes(
                nmodes - 1, kops.padded_rank(rank), blk, tile_rows) / 2**20,
                1),
            tiled_vmem_MB=round(kkernel.fused_tiled_vmem_bytes(
                nmodes - 1, kops.padded_rank(rank), blk, tile_rows) / 2**20,
                1),
            pr2_static=pr2, static=now,
            cliff_removed=pr2 == "pallas" and now != "pallas",
            contrib_traffic_MB_per_Mnnz=round(2 * rank * 4, 1),
        ))
    return rows


def _gather_traffic_rows() -> list[dict]:
    """PR-4 record: counted per-nonzero operand bytes, gather vs fused.

    Pure decision/traffic arithmetic (no timing): for realistic factor
    sizes, what ``select_backend`` picks once the caller supplies
    ``factor_rows`` (as ``mttkrp_device_step`` always does), and the
    per-nonzero HBM stream each family moves — the gather family's
    ``(N−1)·4`` B index stream vs the ``(N−1)·R̂·4`` B of materialized
    gathered rows the PR-3 fused path wrote and re-read.
    """
    rows = []
    for nmodes, rank, factor_rows in [
        (3, 128, 20_000), (4, 128, 50_000), (4, 256, 50_000),
        (5, 512, 100_000), (4, 256, 40_000_000),   # huge factors: no resident fit
    ]:
        blk, tile_rows = 512, 128
        rpad = kops.padded_rank(rank)
        with_fr = kops.select_backend(
            "auto", nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows,
            factor_rows=factor_rows)
        without_fr = kops.select_backend(
            "auto", nmodes=nmodes, rank=rank, blk=blk, tile_rows=tile_rows)
        rows.append(row(
            "gather_traffic", nmodes=nmodes, rank=rank, blk=blk,
            tile_rows=tile_rows, factor_rows=factor_rows,
            gather_resident_MB=round(kkernel.gather_vmem_bytes(
                nmodes - 1, rpad, blk, tile_rows, factor_rows) / 2**20, 1),
            gather_tiled_resident_MB=round(kkernel.gather_tiled_vmem_bytes(
                nmodes - 1, rpad, blk, tile_rows, factor_rows) / 2**20, 1),
            static_with_factor_rows=with_fr,
            static_without_factor_rows=without_fr,
            gather_index_stream_B_per_nnz=(nmodes - 1) * 4,
            fused_operand_B_per_nnz=(nmodes - 1) * rpad * 4,
            in_kernel_gather=with_fr in kops.GATHER_BACKENDS,
        ))
    return rows


def run(quick: bool = True, scale: float = 0.25):
    table = find_table()
    if table is None or not table.entries:
        table = microbench.calibrate(quick=True)
    rows = (_dispatch_rows(table) + _remap_savings_rows(scale)
            + _rank_cliff_rows() + _gather_traffic_rows())
    write_bench_json("dispatch", rows)
    return rows
