"""Paper Fig. 10: total execution time vs. factor-matrix rank R.

spMTTKRP is memory-bound; traffic ∝ R ⇒ time ≈ linear in R. We measure the
Dynasor sorted-stream engine across R ∈ {16 … 256} and fit the linearity.
"""
from __future__ import annotations

import numpy as np

from repro.core.flycoo import build_flycoo

from .bench_total_time import _dynasor_all_modes
from .common import bench_tensor, row, timeit


def run(quick: bool = True, scale: float = 1.0):
    rows = []
    tensors = ("nell-2", "flickr") if quick else (
        "nell-2", "nell-1", "flickr", "delicious", "vast")
    ranks = (16, 32, 64, 128, 256)
    for name in tensors:
        t = bench_tensor(name, scale=scale)
        ft = build_flycoo(t, num_workers=8)
        times = []
        for rank in ranks:
            fn = _dynasor_all_modes(ft, rank)
            tt = timeit(fn, iters=3)
            times.append(tt)
            rows.append(row("rank_fig10", tensor=name, rank=rank,
                            seconds=round(tt, 5)))
        # linearity: correlation of time vs rank
        r = float(np.corrcoef(ranks, times)[0, 1])
        rows.append(row("rank_fig10", tensor=name, rank="linearity_r",
                        seconds=round(r, 4)))
    return rows
