"""Paper Fig. 10: total execution time vs. factor-matrix rank R — plus the
N-mode fused-vs-materialized kernel comparison.

spMTTKRP is memory-bound; traffic ∝ R ⇒ time ≈ linear in R. We measure the
Dynasor sorted-stream engine across R ∈ {16 … 256} and fit the linearity.

The second half measures what the tentpole dispatch buys: on the 4-mode
``enron`` tensor, ``pallas_fused`` (Hadamard formed in VMEM) vs. ``pallas``
(contrib materialized in HBM) across all modes. The materialized path pays
2·R·4 B/nonzero of extra HBM traffic (contrib write + read); the fused rows
report that modeled saving alongside measured wall time, and are written to
``experiments/bench/BENCH_rank.json``.

The ``gather_in_kernel`` section is the PR-4 tentpole record: on the
same 4-mode tensor, ``pallas_fused_gather`` (factor matrices resident in
VMEM, gather performed inside the kernel on an int32 index stream) vs.
``pallas_fused`` (gathered factor rows materialized in HBM by the
caller). The counted per-nonzero operand stream drops from
``(N−1)·R̂·4`` B of rows to ``(N−1)·4`` B of indices — a factor R̂ —
and each row records both terms plus the end-to-end ``auto`` decision
with and without factor-size knowledge.

The third section (``rank_tiled_largeR``) is the rank-cliff record: a
5-mode tensor at FLYCOO-shard-sized blocks (``blk=2048``), swept across
R ≥ 1024. At this block size the PR-2 static dispatch abandons the
fused win from R = 2048 up (the full-rank working set crosses the
64 MiB budget between the R=1024 row, which still fits, and the R=2048
row — both are recorded so the crossing is visible in the data). The
rank-tiled kernel (``pallas_fused_tiled``) keeps the fused traffic
saving at every rank (``rank_slabs`` × slab passes), and the
bf16-gather variant halves the gather bytes on top; each row records
the timed backends and the ``auto`` decision next to the PR-2 decision.
"""
from __future__ import annotations

import numpy as np

from repro.core.flycoo import build_flycoo
from repro.core.mttkrp import mttkrp_fused
from repro.core.tensors import random_sparse_tensor
from repro.kernels.mttkrp import ops as kops

from .bench_total_time import _dynasor_all_modes
from .common import (bench_tensor, pr2_static_backend, row, timeit,
                     write_bench_json)


def _fused_vs_materialized(t, rank, blk=512, tile_rows=128):
    """Timed all-mode spMTTKRP through each Pallas backend."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
               for d in t.shape]
    idx = jnp.asarray(t.indices.astype(np.int32))
    val = jnp.asarray(t.values.astype(np.float32))

    def make(backend):
        def run():
            outs = []
            for n in range(t.nmodes):
                outs.append(mttkrp_fused(idx, val, factors, n, t.shape[n],
                                         blk=blk, tile_rows=tile_rows,
                                         backend=backend))
            return outs
        return run

    return make("pallas_fused"), make("pallas")


def run(quick: bool = True, scale: float = 1.0):
    rows = []
    tensors = ("nell-2", "flickr") if quick else (
        "nell-2", "nell-1", "flickr", "delicious", "vast")
    ranks = (16, 32, 64, 128, 256)
    for name in tensors:
        t = bench_tensor(name, scale=scale)
        ft = build_flycoo(t, num_workers=8)
        times = []
        for rank in ranks:
            fn = _dynasor_all_modes(ft, rank)
            tt = timeit(fn, iters=3)
            times.append(tt)
            rows.append(row("rank_fig10", tensor=name, rank=rank,
                            seconds=round(tt, 5)))
        # linearity: correlation of time vs rank
        r = float(np.corrcoef(ranks, times)[0, 1])
        rows.append(row("rank_fig10", tensor=name, rank="linearity_r",
                        seconds=round(r, 4)))

    # --- 4-mode fused vs materialized (tentpole traffic win) --------------
    fused_rows = []
    t4 = bench_tensor("enron", scale=0.25 if quick else 1.0)
    for rank in ((32, 128) if quick else (32, 64, 128, 256)):
        fused, mat = _fused_vs_materialized(t4, rank)
        t_f = timeit(fused, warmup=1, iters=2)
        t_m = timeit(mat, warmup=1, iters=2)
        # contrib write+read the fused kernel never pays, per mode sweep —
        # the counted-traffic comparison. Wall times are labeled *_interp_s:
        # both backends run in the Pallas interpreter on CPU here, so they
        # measure emulation overhead, not the compiled-kernel HBM win.
        saved = t4.nmodes * t4.nnz * 2 * rank * 4
        fused_rows.append(row(
            "rank_fused_4mode", tensor="enron", nmodes=t4.nmodes,
            nnz=t4.nnz, rank=rank,
            fused_interp_s=round(t_f, 5),
            materialized_interp_s=round(t_m, 5),
            contrib_traffic_saved_MB=round(saved / 1e6, 3),
            note="times are interpret-mode emulation; traffic is counted"))
    rows.extend(fused_rows)

    # --- gather-in-kernel: index stream vs materialized rows --------------
    gather_rows = _gather_in_kernel_rows(t4, quick)
    rows.extend(gather_rows)

    # --- rank-tiled + bf16 at R >= 1024 (the removed VMEM cliff) ----------
    large_rows = _large_rank_rows(quick)
    rows.extend(large_rows)
    # The suite's full row set is the artifact (run.py no longer writes
    # side-channel dumps): fig-10 linearity rows included.
    write_bench_json("rank", rows)
    return rows


def _gather_in_kernel_rows(t4, quick: bool) -> list[dict]:
    """PR-4 tentpole: per-nonzero HBM operand bytes, gather vs fused.

    The fused path materializes every gathered factor row in HBM —
    ``(N−1)·R̂·4`` B written and re-read per nonzero before the kernel
    ever runs. The gather family streams ``(N−1)·4`` B of int32 indices
    instead and holds the replicated factors in VMEM. Wall times are
    interpret-mode emulation; the counted bytes are the record.
    """
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    nmodes = t4.nmodes
    idx = jnp.asarray(t4.indices.astype(np.int32))
    val = jnp.asarray(t4.values.astype(np.float32))
    out = []
    for rank in ((32, 128) if quick else (32, 64, 128, 256)):
        factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
                   for d in t4.shape]

        def make(backend):
            def run():
                return [mttkrp_fused(idx, val, factors, n, t4.shape[n],
                                     blk=512, tile_rows=128, backend=backend)
                        for n in range(nmodes)]
            return run

        t_gather = timeit(make("pallas_fused_gather"), warmup=1, iters=2)
        t_fused = timeit(make("pallas_fused"), warmup=1, iters=2)
        rpad = kops.padded_rank(rank)
        fused_operand_B = (nmodes - 1) * rpad * 4     # materialized rows
        index_stream_B = (nmodes - 1) * 4             # int32 indices
        factor_rows = sum(t4.shape) - min(t4.shape)   # worst mode resident
        auto_fr = kops.select_backend(
            "auto", nmodes=nmodes, rank=rank, blk=512, tile_rows=128,
            factor_rows=factor_rows)
        auto_no_fr = kops.select_backend(
            "auto", nmodes=nmodes, rank=rank, blk=512, tile_rows=128)
        out.append(row(
            "gather_in_kernel", tensor="enron", nmodes=nmodes, nnz=t4.nnz,
            rank=rank, rank_padded=rpad,
            gather_interp_s=round(t_gather, 5),
            fused_interp_s=round(t_fused, 5),
            fused_operand_B_per_nnz=fused_operand_B,
            gather_index_stream_B_per_nnz=index_stream_B,
            operand_traffic_ratio=round(fused_operand_B / index_stream_B, 1),
            operand_traffic_saved_MB=round(
                t4.nnz * nmodes * (fused_operand_B - index_stream_B) / 1e6,
                3),
            auto_with_factor_rows=auto_fr,
            auto_without_factor_rows=auto_no_fr,
            note="times are interpret-mode emulation; traffic is counted"))
    return out


def _large_rank_rows(quick: bool) -> list[dict]:
    """5-mode, shard-sized blocks, R from 1024 up: fused wins past the
    old cliff. Wall times are interpret-mode emulation (CPU container);
    the dispatch decisions and counted traffic are the record."""
    import jax.numpy as jnp

    shape = (256, 48, 32, 24, 16)
    nmodes = len(shape)
    blk, tile_rows = 2048, 128          # FLYCOO g-sized nonzero block
    t5 = random_sparse_tensor(shape, 1500 if quick else 4000, seed=0)
    idx = jnp.asarray(t5.indices.astype(np.int32))
    val = jnp.asarray(t5.values.astype(np.float32))
    rng = np.random.default_rng(1)
    out = []
    for rank in ((1024, 2048) if quick else (1024, 2048, 4096)):
        factors = [jnp.asarray(rng.standard_normal((d, rank)), jnp.float32)
                   for d in shape]

        def make(backend, gather_dtype="float32"):
            return lambda: mttkrp_fused(
                idx, val, factors, 0, shape[0], blk=blk,
                tile_rows=tile_rows, backend=backend,
                gather_dtype=gather_dtype)

        t_tiled = timeit(make("pallas_fused_tiled"), warmup=1, iters=2)
        t_mat = timeit(make("pallas"), warmup=1, iters=2)
        t_bf16 = timeit(make("pallas_fused_tiled", "bfloat16"),
                        warmup=1, iters=2)
        auto = kops.select_backend("auto", nmodes=nmodes, rank=rank,
                                   blk=blk, tile_rows=tile_rows)
        pr2 = pr2_static_backend(nmodes, rank, blk, tile_rows)
        slabs = kops.padded_rank(rank) // kops.MXU_RANK_MULTIPLE
        contrib_saved = t5.nnz * 2 * rank * 4       # write+read never paid
        bf16_saved = t5.nnz * (nmodes - 1) * rank * 2   # gathers at 2B not 4B
        out.append(row(
            "rank_tiled_largeR", tensor="synth5", nmodes=nmodes,
            nnz=t5.nnz, rank=rank, blk=blk, tile_rows=tile_rows,
            rank_slabs=slabs,
            fused_tiled_interp_s=round(t_tiled, 5),
            materialized_interp_s=round(t_mat, 5),
            bf16_tiled_interp_s=round(t_bf16, 5),
            auto_backend=auto, pr2_auto_backend=pr2,
            contrib_traffic_saved_MB=round(contrib_saved / 1e6, 3),
            bf16_gather_saved_MB=round(bf16_saved / 1e6, 3),
            note="times are interpret-mode emulation; traffic is counted"))
    return out
