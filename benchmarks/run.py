"""Benchmark driver: one module per paper figure/table.

  PYTHONPATH=src python -m benchmarks.run             # quick suite
  PYTHONPATH=src python -m benchmarks.run --full
  PYTHONPATH=src python -m benchmarks.run --only total_time,schedule

Rows print as `k=v` CSV lines; every suite persists its own artifact
through ``common.write_bench_json`` — the single naming authority —
as ``experiments/bench/BENCH_<suite>.json`` (the pre-PR-5 lowercase
``<suite>.json`` dumps are retired). A suite that *errors* still gets a
BENCH file recording the failure, so downstream tooling can glob
``BENCH_*.json`` and see every suite accounted for.
"""
from __future__ import annotations

import argparse
import os
import time

from . import (bench_bf16_convergence, bench_collective_traffic,
               bench_dispatch, bench_lowering, bench_memory, bench_oocore,
               bench_preprocess, bench_prof, bench_rank, bench_remap_fusion,
               bench_remap_traffic, bench_reorder, bench_resilience,
               bench_scaling,
               bench_schedule, bench_total_time, roofline)
from . import common
from .common import print_rows, write_bench_json

SUITES = {
    "remap_fusion": bench_remap_fusion.run,      # Fig. 2
    "total_time": bench_total_time.run,          # Fig. 3/4 + Table III
    "schedule": bench_schedule.run,              # Fig. 6
    "scaling": bench_scaling.run,                # Fig. 7
    "remap_traffic": bench_remap_traffic.run,    # Fig. 8
    "roofline": roofline.run,                    # Fig. 9 + §Roofline
    "rank": bench_rank.run,                      # Fig. 10
    "memory": bench_memory.run,                  # Fig. 11
    "preprocess": bench_preprocess.run,          # Fig. 12
    "collective_traffic": bench_collective_traffic.run,   # §IV lock-free claim
    "dispatch": bench_dispatch.run,              # repro.tune calibrated auto
    "bf16_convergence": bench_bf16_convergence.run,   # bf16 gathers, fit gap
    "oocore": bench_oocore.run,                  # out-of-core streamed gather
    "reorder": bench_reorder.run,                # locality-ordered streams
    "resilience": bench_resilience.run,          # fault-injection overhead
    "lowering": bench_lowering.run,              # interpret=False Mosaic status
    "prof": bench_prof.run,                      # timed steps + roofline GB/s
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    names = list(SUITES) if not args.only else args.only.split(",")
    os.makedirs(args.out, exist_ok=True)
    common.BENCH_OUT_DIR = args.out     # BENCH_*.json follow --out
    all_rows = []
    for name in names:
        fn = SUITES[name]
        t0 = time.perf_counter()
        try:
            rows = fn(quick=not args.full)
        except Exception as e:                    # noqa: BLE001
            rows = [dict(bench=name, status="error", error=repr(e)[:200])]
            write_bench_json(name, rows)
        dt = time.perf_counter() - t0
        print(f"## {name} ({dt:.1f}s)", flush=True)
        print_rows(rows)
        all_rows.extend(rows)
    print(f"## done: {len(all_rows)} rows -> {args.out}/", flush=True)


if __name__ == "__main__":
    main()
