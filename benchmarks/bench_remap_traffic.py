"""Paper Fig. 8: dynamically remapped data volume vs. total elementwise
traffic — counted EXACTLY (the paper instruments its code with counters;
we count the same quantities from shapes).

Per mode n:
  elementwise traffic = nnz·(N−1)·R·4 B   (input factor-row loads)
                      + nnz·(coords+value) B (tensor stream)
                      + I_n·R·4 B          (output rows written once, owner)
  remap traffic       = nnz·(coords+value) B moved to the next mode's
                        buckets (the 2·|T| double-buffer write).

Paper's claim: remap < 15 % of elementwise traffic on FROSTT tensors.

Additionally, the *allocated* all_to_all payload is counted from the
FLYCOO schedule via ``remap_capacities`` — and compared two ways, the
two sizings ``DynasorRuntime`` supports:

  * ``alltoall_uniform_GB`` — every transition padded to the *max*
    capacity (the old ``bucket_cap`` behavior / ``uniform_cap=True``);
  * ``alltoall_pertransition_GB`` — each transition sized to its own
    bound (the tuned default).

Their gap is pure padding the per-transition runtime no longer
allocates or exchanges; ``pertransition_savings_frac`` is largest on
skewed tensors (``enron-skew``), where one hub-heavy transition forces
the uniform cap far above the others. The same rows are written
machine-readably to ``BENCH_remap_traffic.json``.
"""
from __future__ import annotations

from repro.core.flycoo import build_flycoo

from .common import (BENCH_TENSORS, bench_tensor, exchange_sizing, row,
                     write_bench_json)

_WORKERS = 8


def run(quick: bool = True, rank: int = 16, scale: float = 0.25):
    rows = []
    for name in BENCH_TENSORS + ("enron-skew",):
        t = bench_tensor(name, scale=scale)
        N = t.nmodes
        elem_bytes_per_nnz = 4 * N + 4          # coords + value
        total_elem = 0
        total_remap = 0
        for n in range(N):
            elem = (t.nnz * (N - 1) * rank * 4
                    + t.nnz * elem_bytes_per_nnz
                    + t.shape[n] * rank * 4)
            remap = t.nnz * elem_bytes_per_nnz
            total_elem += elem
            total_remap += remap
        frac = total_remap / total_elem
        ft = build_flycoo(t, num_workers=_WORKERS)
        sizing = exchange_sizing(ft, _WORKERS)
        rows.append(row("remap_traffic_fig8", tensor=name, rank=rank,
                        elementwise_GB=round(total_elem / 1e9, 4),
                        remap_GB=round(total_remap / 1e9, 4),
                        remap_fraction=round(frac, 4),
                        alltoall_uniform_GB=round(
                            sizing["uniform_bytes"] / 1e9, 4),
                        alltoall_pertransition_GB=round(
                            sizing["per_transition_bytes"] / 1e9, 4),
                        pertransition_savings_frac=round(
                            sizing["savings_frac"], 4),
                        alltoall_pad_factor=round(
                            sizing["per_transition_bytes"]
                            / max(total_remap, 1), 3),
                        paper_claim_under_15pct=bool(frac < 0.15)))
    write_bench_json("remap_traffic", rows)
    return rows
