"""Paper Fig. 11: resilience to limited external memory.

The paper's point: Dynasor's working set is ``2·|T| + factors + pointers``
and *does not grow with R beyond the factors*, while intermediate-heavy
formats (ALTO's per-thread partial outputs, mode-specific copies) explode.
We model peak bytes exactly (same accounting as the paper's Table/Fig.11
setup) at FULL FROSTT scales — no allocation, pure arithmetic — and report
which (format × memory budget) cells fit.
"""
from __future__ import annotations

import numpy as np

from repro.core.tensors import FROSTT_PROFILES

from .common import row, write_bench_json

GB = 1024 ** 3


def _bytes(profile, rank, fmt, threads: int = 56):
    shape, nnz = profile["shape"], profile["nnz"]
    N = len(shape)
    elem = 4 * N + 4                       # coords + value
    factors = sum(shape) * rank * 4
    if fmt == "dynasor":                   # 2|T| double buffer + pointers
        return 2 * nnz * elem + factors + 8 * (nnz // 1024 + sum(shape) // 1000)
    if fmt == "alto_like":                 # |T| + per-thread dense partials
        partials = threads * max(shape) * rank * 4
        return nnz * elem + factors + partials
    if fmt == "mode_specific":             # N tensor copies (CSF-ish)
        return N * nnz * elem + factors
    raise ValueError(fmt)


def run(quick: bool = True):
    rows = []
    for name, prof in FROSTT_PROFILES.items():
        for rank in (16, 64, 256):
            for fmt in ("dynasor", "alto_like", "mode_specific"):
                b = _bytes(prof, rank, fmt)
                rows.append(row(
                    "memory_fig11", tensor=name, rank=rank, fmt=fmt,
                    peak_GB=round(b / GB, 2),
                    fits_16GB=bool(b <= 16 * GB),
                    fits_128GB=bool(b <= 128 * GB)))
    write_bench_json("memory", rows)
    return rows
